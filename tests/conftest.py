"""Shared test fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see 1 device; multi-device tests spawn subprocesses (helpers below)."""

from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

REPO = Path(__file__).resolve().parents[1]


def run_multidevice(code: str, n_devices: int = 8, timeout: int = 600) -> str:
    """Run ``code`` in a subprocess with ``n_devices`` forced host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", code], env=env, timeout=timeout,
                         capture_output=True, text=True)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


@pytest.fixture(scope="session")
def spatial_data():
    from repro.data.pipeline import spatial_points, spatial_queries

    return spatial_points(2048, seed=0), spatial_queries(512, seed=1)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
