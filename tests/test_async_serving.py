"""Async AIDW serving subsystem: admission queue, deadline-aware coalescing,
telemetry, sync/async drive-mode equivalence, and write-path serialization.

Acceptance criteria covered here (ISSUE 3):
(a) AsyncAidwServer results bit-identical to the synchronous engine for the
    same request set with no deadlines;
(b) p99 latency reported and no lost/duplicated requests across >= 3
    interleaved delta updates;
(c) deadline-aware mode sheds expired requests instead of serving them late;
plus the satellite regressions: per-call vs cumulative engine stats,
per-request overflow propagation, and no-deadline FIFO coalescing
byte-for-byte compatibility.

The whole module also runs under the CI serving-suite job's 8-forced-host-
device config (``XLA_FLAGS=--xla_force_host_platform_device_count=8``): the
mesh tests below pick up every visible device, and the slow-marked
subprocess test forces the 8-device mesh regardless of this process's
device count.
"""

from __future__ import annotations

from collections import deque

import numpy as np
import pytest

from conftest import run_multidevice
from repro.core import AidwConfig, execute
from repro.data.pipeline import spatial_points, spatial_queries
from repro.serving import (AdmissionQueue, AdmissionQueueFull, AidwEngine,
                           AsyncAidwServer, DeadlineCoalescer,
                           ExecuteTimeModel, InterpolationRequest,
                           LatencyHistogram)


def _requests(qs, n_reqs, per=64, deadline=None):
    return [InterpolationRequest(uid=i, queries_xy=qs[per * i:per * (i + 1)],
                                 deadline=deadline)
            for i in range(n_reqs)]


# ---------------------------------------------------------------------------
# admission queue
# ---------------------------------------------------------------------------


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t


def test_admission_queue_fifo_bound_and_shed():
    clock = FakeClock()
    q = AdmissionQueue(max_depth=3, clock=clock)
    a = InterpolationRequest(uid=0, queries_xy=np.zeros((1, 2), np.float32))
    b = InterpolationRequest(uid=1, queries_xy=np.zeros((1, 2), np.float32))
    assert q.put(a) and q.put(b)
    # expired on arrival: refused admission, counted, NOT enqueued
    ex = InterpolationRequest(uid=2, queries_xy=np.zeros((1, 2), np.float32),
                              deadline=-1.0)
    assert q.put(ex) is False
    assert q.counters["shed_expired"] == 1
    assert len(q) == 2
    # bounded depth: non-blocking put raises once full
    q.put(InterpolationRequest(uid=3,
                               queries_xy=np.zeros((1, 2), np.float32)))
    with pytest.raises(AdmissionQueueFull):
        q.put(InterpolationRequest(uid=4,
                                   queries_xy=np.zeros((1, 2), np.float32)),
              block=False)
    assert q.counters["rejected_full"] == 1
    # blocking put with timeout also rejects loudly (clock never advances the
    # consumer, so use a real-time-free zero timeout)
    with pytest.raises(AdmissionQueueFull):
        q.put(InterpolationRequest(uid=5,
                                   queries_xy=np.zeros((1, 2), np.float32)),
              timeout=0.0)
    # FIFO pop order
    assert q.get().uid == 0
    assert q.get().uid == 1
    assert [r.uid for r in q.drain()] == [3]
    q.close()
    assert q.get() is None
    with pytest.raises(Exception):
        q.put(a)


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------


def test_telemetry_reset_isolates_warmup():
    from repro.serving import Telemetry

    class _R:
        queries_xy = np.zeros((4, 2), np.float32)
        overflow = 0
        t_submit = 1.0
        t_dispatch = 2.0
        t_done = 3.0

    t = Telemetry()
    t.record_submit(_R())
    t.record_batch([_R()], 0.5)
    assert t.counters["completed"] == 1
    t.reset()                                # post-warmup: a clean window
    assert t.counters["completed"] == t.counters["submitted"] == 0
    assert t.total.count == 0 and t.queries_per_s() == 0.0
    t.record_batch([_R()], 0.5)              # still records after reset
    assert t.counters["completed"] == 1


def test_latency_histogram_percentiles():
    h = LatencyHistogram()
    assert h.percentile(99) == 0.0
    for ms in range(1, 101):                 # 1..100 ms uniform
        h.record(ms / 1000.0)
    snap = h.snapshot()
    assert snap["count"] == 100
    assert 0.040 <= snap["p50_s"] <= 0.070   # log-binned upper-edge estimate
    assert 0.090 <= snap["p95_s"] <= 0.110
    assert 0.095 <= snap["p99_s"] <= 0.100   # clamped to observed max
    assert snap["max_s"] == pytest.approx(0.1)
    assert snap["p50_s"] <= snap["p95_s"] <= snap["p99_s"] <= snap["max_s"]


# ---------------------------------------------------------------------------
# deadline-aware coalescing (deterministic: fake clock + primed estimator)
# ---------------------------------------------------------------------------


def _greedy_reference(requests, max_batch):
    """The pre-subsystem FIFO coalescing (PR 1 engine loop), verbatim."""
    groups, i = [], 0
    while i < len(requests):
        group = [requests[i]]
        size = group[0].queries_xy.shape[0]
        i += 1
        while i < len(requests) and \
                size + requests[i].queries_xy.shape[0] <= max_batch:
            group.append(requests[i])
            size += requests[i].queries_xy.shape[0]
            i += 1
        groups.append(group)
    return groups


def test_no_deadline_coalescing_matches_greedy_byte_for_byte():
    """Satellite: a no-deadline workload reproduces the classic FIFO
    coalescing exactly — same groups, same member order — across random
    request-size mixes."""
    rng = np.random.default_rng(0)
    for trial in range(20):
        sizes = rng.integers(1, 400, size=rng.integers(1, 30))
        reqs = [InterpolationRequest(uid=i,
                                     queries_xy=np.zeros((s, 2), np.float32))
                for i, s in enumerate(sizes)]
        max_batch = int(rng.choice([256, 512, 1024]))
        coal = DeadlineCoalescer(max_batch, ExecuteTimeModel(),
                                 clock=FakeClock())
        groups, shed = coal.coalesce(reqs)
        assert shed == []
        ref = _greedy_reference(reqs, max_batch)
        assert [[r.uid for r in g] for g in groups] == \
            [[r.uid for r in g] for g in ref], (trial, sizes, max_batch)


def test_near_deadline_closes_batch_early():
    """A measured execute-time estimate + a tight deadline close the batch
    before max_batch; without the deadline the same requests coalesce."""
    clock = FakeClock(100.0)
    est = ExecuteTimeModel(min_bucket=64)
    est.record(64, 0.010)        # 64-bucket measured at 10ms
    est.record(128, 0.050)       # crossing into the 128 bucket costs 50ms
    coal = DeadlineCoalescer(1024, est, clock=clock)

    def reqs(deadline):
        return [InterpolationRequest(
            uid=i, queries_xy=np.zeros((48, 2), np.float32),
            deadline=deadline) for i in range(4)]

    # no deadline: all four coalesce (48*4=192 <= max_batch)
    groups, _ = coal.coalesce(reqs(None), now=clock())
    assert [len(g) for g in groups] == [4]
    # 30ms deadline: 48 fits (64-bucket, 10ms) but growing to 96 queries
    # crosses into the 128 bucket (50ms > 30ms) -> close early at one request
    groups, shed = coal.coalesce(reqs(clock() + 0.030), now=clock())
    assert shed == []
    assert [len(g) for g in groups] == [1, 1, 1, 1]
    # 80ms deadline: 96 queries (128 bucket, 50ms) still meets it, growing to
    # 144 (256-bucket extrapolation ~100ms) does not -> pairs
    groups, _ = coal.coalesce(reqs(clock() + 0.080), now=clock())
    assert [len(g) for g in groups] == [2, 2]


def test_estimator_keys_on_dataset_size():
    """Satellite: the execute-time model keys on (query bucket, n_points
    bucket), so deadline early-close stays calibrated right after a large
    delta update instead of trusting EWMAs measured at the old size."""
    est = ExecuteTimeModel(min_bucket=64, n_points=4096)
    est.record(64, 0.010)                    # small dataset: 10ms
    est.n_points = 65536                     # large delta update lands
    assert est.estimate(64) == pytest.approx(0.010)   # fallback: nearest m
    est.record(64, 0.080)                    # measured at the new size
    assert est.estimate(64) == pytest.approx(0.080)
    est.n_points = 4096                      # shrink back: old key still live
    assert est.estimate(64) == pytest.approx(0.010)
    # unseen query bucket: nearest n at the SAME dataset size, scaled in n
    assert est.estimate(128) == pytest.approx(0.020)


def test_deadline_close_recalibrates_after_resize():
    """Satellite regression (primed estimator + fake clock): after a large
    update the coalescer's early-close uses the estimate measured AT the
    new dataset size, not the stale small-dataset EWMA."""
    clock = FakeClock(100.0)
    est = ExecuteTimeModel(min_bucket=64, n_points=4096)
    est.record(64, 0.005)                    # 64-bucket cheap when small
    est.record(128, 0.008)
    est.n_points = 65536                     # resize
    est.record(64, 0.020)
    est.record(128, 0.200)                   # 128-bucket now blows the SLO
    coal = DeadlineCoalescer(1024, est, clock=clock)

    def reqs(deadline):
        return [InterpolationRequest(
            uid=i, queries_xy=np.zeros((48, 2), np.float32),
            deadline=deadline) for i in range(4)]

    # 50ms deadline at the LARGE size: growing 48 -> 96 queries crosses into
    # the 128 bucket (200ms > 50ms) -> singles.  The stale small-dataset
    # model (8ms) would have coalesced and missed the deadline.
    groups, shed = coal.coalesce(reqs(clock() + 0.050), now=clock())
    assert shed == [] and [len(g) for g in groups] == [1, 1, 1, 1]
    est.n_points = 4096                      # back at the small size: the
    groups, _ = coal.coalesce(reqs(clock() + 0.050), now=clock())
    assert [len(g) for g in groups] == [4]   # old calibration still applies


def test_engine_update_refreshes_estimator_n_points(spatial_data):
    """The engine keeps the estimator's dataset key in sync with the
    session across full and delta updates."""
    pts, qs = spatial_data
    eng = AidwEngine(pts, max_batch=256, query_domain=qs)
    assert eng.estimator.n_points == eng.session.plan.n_points
    eng.update_dataset(inserts=spatial_points(32, seed=5))
    assert eng.estimator.n_points == eng.session.plan.n_points \
        == pts.shape[0] + 32


def test_expired_requests_shed_at_dispatch():
    clock = FakeClock(10.0)
    coal = DeadlineCoalescer(1024, ExecuteTimeModel(), clock=clock)
    live = InterpolationRequest(uid=0,
                                queries_xy=np.zeros((8, 2), np.float32))
    dead = InterpolationRequest(uid=1,
                                queries_xy=np.zeros((8, 2), np.float32),
                                deadline=9.0)
    groups, shed = coal.coalesce([dead, live], now=clock())
    assert [r.uid for g in groups for r in g] == [0]
    assert [r.uid for r in shed] == [1]
    assert shed[0].status == "shed" and shed[0].done
    assert shed[0].values is None            # never served late


def test_coalescer_stops_at_update_barrier():
    class Barrier:                            # no queries_xy attribute
        deadline = None

    reqs = [InterpolationRequest(uid=i,
                                 queries_xy=np.zeros((8, 2), np.float32))
            for i in range(3)]
    pending = deque([reqs[0], reqs[1], Barrier(), reqs[2]])
    coal = DeadlineCoalescer(1024, ExecuteTimeModel(), clock=FakeClock())
    group, shed = coal.next_batch(pending)
    assert [r.uid for r in group] == [0, 1] and not shed
    assert not hasattr(pending[0], "queries_xy")   # barrier left for caller
    # the list-drive mode has no barrier handler: reject loudly, never hang
    with pytest.raises(ValueError):
        coal.coalesce([reqs[0], Barrier(), reqs[2]])


# ---------------------------------------------------------------------------
# synchronous engine facade (stats split + deadline semantics + overflow)
# ---------------------------------------------------------------------------


def test_engine_stats_per_call_vs_cumulative(spatial_data):
    """Satellite regression: run() reports THIS call; self.stats accumulates
    — the two were previously mixed in one dict."""
    pts, qs = spatial_data
    eng = AidwEngine(pts, max_batch=256, query_domain=qs)
    r1 = eng.run(_requests(qs, 4))
    assert (r1["requests"], r1["queries"]) == (4, 256)
    assert "wall_s" in r1 and "queries_per_s" in r1
    r2 = eng.run(_requests(qs, 2))
    # per-call report counts ONLY the second call...
    assert (r2["requests"], r2["queries"]) == (2, 128)
    assert r2["batches"] <= r1["batches"]
    # ...while the cumulative counters sum both and carry no timing keys
    assert eng.stats["requests"] == 6
    assert eng.stats["queries"] == 384
    assert eng.stats["batches"] == r1["batches"] + r2["batches"]
    assert "wall_s" not in eng.stats and "queries_per_s" not in eng.stats


def test_engine_sheds_expired_serves_rest(spatial_data):
    pts, qs = spatial_data
    eng = AidwEngine(pts, max_batch=256, query_domain=qs)
    now = eng.clock()
    reqs = _requests(qs, 4)
    reqs[1].deadline = now - 1.0             # expired on arrival
    reqs[3].deadline = now + 60.0            # comfortably live
    rep = eng.run(reqs)
    assert rep["shed"] == 1 and rep["requests"] == 4
    assert reqs[1].status == "shed" and reqs[1].values is None
    assert all(r.status == "done" and r.values is not None
               for i, r in enumerate(reqs) if i != 1)
    assert eng.stats["shed"] == 1
    assert eng.telemetry.counters["shed"] == 1


class StepClock:
    """Monotonic fake clock that advances by ``step`` on every read."""

    def __init__(self, step: float = 0.1):
        self.t = 0.0
        self.step = step

    def __call__(self) -> float:
        now = self.t
        self.t += self.step
        return now


def test_engine_sheds_requests_that_expire_mid_run(spatial_data):
    """Regression: the sync engine forms batches with a FRESH clock per
    batch (like the async worker) — a request whose deadline expires while
    earlier groups execute is shed at dispatch time, not served late."""
    pts, qs = spatial_data
    clock = StepClock(0.1)
    eng = AidwEngine(pts, max_batch=64, query_domain=qs, clock=clock)
    reqs = _requests(qs, 3)                  # 64 queries each: 3 batches
    reqs[2].deadline = 0.05                  # expires after the first read
    rep = eng.run(reqs)
    assert reqs[2].status == "shed" and reqs[2].values is None
    assert rep["shed"] == 1 and rep["batches"] == 2
    assert all(r.status == "done" for r in reqs[:2])


def test_throughput_window_anchored_at_submit(spatial_data):
    """Regression: a single-batch run must report sane q/s — the window
    opens at the first submit, not at the first completion (which would be
    zero-width and divide by epsilon)."""
    pts, qs = spatial_data
    eng = AidwEngine(pts, max_batch=512, query_domain=qs)
    eng.run(_requests(qs, 2))                # coalesces into ONE batch
    assert eng.telemetry.counters["batches"] == 1
    qps = eng.telemetry.queries_per_s()
    assert 0 < qps < 1e9, qps                # epsilon window would be ~1e11


def test_async_submit_validates_queries(spatial_data):
    """Malformed arrays are rejected at the submit() boundary (a ValueError
    for the offending caller), never admitted to crash the shared worker."""
    pts, qs = spatial_data
    with AsyncAidwServer(pts, query_domain=qs) as srv:
        for bad in (np.zeros((4, 3), np.float32),     # wrong width
                    np.zeros((4,), np.float32),       # 1-D
                    np.zeros((0, 2), np.float32),     # empty
                    np.zeros((4, 2), np.int32)):      # non-float
            with pytest.raises(ValueError):
                srv.submit(bad)
        ok = srv.submit(qs[:8])                       # server still healthy
        assert srv.result(ok, timeout=120).status == "done"
        # auto-uids skip caller-supplied ones instead of colliding
        with_uid = srv.submit(qs[:8], uid=1)
        auto = [srv.submit(qs[:8]) for _ in range(3)]
        assert len({r.uid for r in [with_uid] + auto}) == 4
        srv.flush(timeout=120)


def test_async_worker_death_fails_fast_not_hangs(spatial_data):
    """Regression: a dead worker resolves queued update barriers and closes
    the admission queue, so update_dataset/submit raise instead of hanging
    forever (and close() surfaces the crash)."""
    pts, qs = spatial_data
    srv = AsyncAidwServer(pts, query_domain=qs)
    try:
        good = srv.submit(qs[:16])
        srv.result(good, timeout=120)

        def boom(*a, **k):
            raise RuntimeError("injected session fault")

        srv.session.query = boom             # next dispatch kills the worker
        srv.submit(qs[:8])
        with pytest.raises(Exception):
            srv.update_dataset(inserts=spatial_points(4, seed=1),
                               timeout=60)
        with pytest.raises(Exception):               # worker died or closed
            for _ in range(100):
                srv.submit(qs[:8])
        # a request that COMPLETED before the crash stays retrievable
        assert srv.result(good, timeout=10).status == "done"
    finally:
        with pytest.raises(RuntimeError):    # close() surfaces the crash
            srv.close()


def test_per_request_overflow_propagation():
    """Satellite: per-batch overflow attributes back to the OWNING requests
    (summing the per-query mask per slice), not just engine-wide."""
    pts = spatial_points(2048, seed=0, clustered=True)
    qs = spatial_queries(256, seed=1)
    cfg = AidwConfig(window=64)              # clustered cells overflow w=64
    eng = AidwEngine(pts, cfg, max_batch=512, query_domain=qs)
    reqs = _requests(qs, 4)
    rep = eng.run(reqs)
    res = execute(eng.session.plan, qs)
    mask = np.asarray(res.overflow_mask)
    assert 0 < mask.sum() < len(qs)          # partial overflow: informative
    for i, r in enumerate(reqs):
        assert r.overflow == int(mask[64 * i:64 * (i + 1)].sum()), i
    assert rep["overflow"] == sum(r.overflow for r in reqs) == mask.sum()


def test_engine_no_deadline_results_unchanged(spatial_data):
    """The refactored engine serves a no-deadline workload bit-identically
    to one execute over the same concatenation (the PR 1 contract)."""
    pts, qs = spatial_data
    eng = AidwEngine(pts, max_batch=256, query_domain=qs)
    reqs = _requests(qs, 6)
    eng.run(reqs)
    got = np.concatenate([r.values for r in reqs])
    want = np.asarray(execute(eng.session.plan, qs[:384]).values)
    assert np.array_equal(got, want)


# ---------------------------------------------------------------------------
# async server
# ---------------------------------------------------------------------------


def test_async_matches_sync_bitwise(spatial_data):
    """Acceptance (a): same request set, no deadlines -> async results are
    bit-identical to the synchronous engine's."""
    pts, qs = spatial_data
    eng = AidwEngine(pts, max_batch=256, query_domain=qs)
    sync_reqs = _requests(qs, 8, per=48)
    eng.run(sync_reqs)
    with AsyncAidwServer(pts, max_batch=256, query_domain=qs) as srv:
        async_reqs = [srv.submit(qs[48 * i:48 * (i + 1)]) for i in range(8)]
        srv.flush(timeout=120)
    for s, a in zip(sync_reqs, async_reqs):
        assert a.status == "done"
        assert np.array_equal(s.values, a.values), (s.uid, a.uid)
        assert s.overflow == a.overflow


def test_async_no_lost_or_dup_across_delta_updates(spatial_data):
    """Acceptance (b): >= 3 interleaved incremental dataset updates; every
    request resolves exactly once, updates are FIFO barriers (requests after
    an update see the new dataset), and p99 latency is reported."""
    pts, qs = spatial_data
    m = pts.shape[0]
    with AsyncAidwServer(pts, max_batch=512, query_domain=qs) as srv:
        waves = []
        rng = np.random.default_rng(7)
        for wave in range(4):                # u.q.q.q | u.q.q.q | ... x3 upd
            if wave:
                srv.update_dataset(
                    inserts=spatial_points(16, seed=40 + wave),
                    deletes=rng.choice(m - 32, 16, replace=False))
            waves.append([srv.submit(qs[32 * i:32 * (i + 1)])
                          for i in range(8)])
        srv.flush(timeout=240)
        report = srv.report()
        # no lost or duplicated requests: 32 submitted, 32 distinct uids,
        # every one terminal with exactly one result
        all_reqs = [r for w in waves for r in w]
        assert len({r.uid for r in all_reqs}) == 32
        assert all(r.status == "done" and r.values is not None
                   for r in all_reqs)
        assert report["completed"] == 32 and report["shed"] == 0
        assert report["queries"] == 32 * 32
        assert srv.session.stats["delta_updates"] == 3
        # p99 is reported for all three latency axes
        for axis in ("queue", "execute", "total"):
            assert report["latency"][axis]["count"] > 0
            assert report["latency"][axis]["p99_s"] > 0.0
    # post-update correctness: last wave matches a synchronous engine that
    # applied the same updates in the same order
    eng = AidwEngine(pts, max_batch=512, query_domain=qs)
    rng = np.random.default_rng(7)
    for wave in range(1, 4):
        eng.update_dataset(inserts=spatial_points(16, seed=40 + wave),
                           deletes=rng.choice(m - 32, 16, replace=False))
    ref = _requests(qs, 8, per=32)
    eng.run(ref)
    for a, b in zip(waves[-1], ref):
        assert np.array_equal(np.asarray(a.values), b.values)


def test_async_sheds_expired_instead_of_serving_late(spatial_data):
    """Acceptance (c): deadline-aware mode sheds expired requests with the
    distinct 'shed' status; live requests in the same stream still serve."""
    pts, qs = spatial_data
    with AsyncAidwServer(pts, max_batch=256, query_domain=qs) as srv:
        dead = srv.submit(qs[:64], deadline_s=-0.5)   # expired on arrival
        live = srv.submit(qs[64:128], deadline_s=600.0)
        srv.flush(timeout=120)
        assert dead.status == "shed" and dead.values is None and dead.done
        assert live.status == "done" and live.values is not None
        rep = srv.report()
        assert rep["shed"] == 1 and rep["completed"] == 1
        assert rep["admission"]["shed_expired"] == 1


def test_async_update_error_propagates_to_caller(spatial_data):
    pts, qs = spatial_data
    with AsyncAidwServer(pts, query_domain=qs) as srv:
        with pytest.raises(IndexError):      # delete index out of range
            srv.update_dataset(deletes=[pts.shape[0] + 5], timeout=120)
        # the worker survives a poisoned update: queries still serve
        r = srv.submit(qs[:32])
        srv.result(r, timeout=120)
        assert r.status == "done"


def test_async_flush_under_rapid_submit_cycles(spatial_data):
    """Regression: in-flight accounting must count a request BEFORE the
    worker can complete it — a late increment strands flush() forever when
    the worker wins the race between put() and the bookkeeping."""
    pts, qs = spatial_data
    with AsyncAidwServer(pts, max_batch=128, query_domain=qs) as srv:
        for _ in range(5):                   # warm executables => fast worker
            reqs = [srv.submit(qs[16 * i:16 * (i + 1)]) for i in range(8)]
            srv.flush(timeout=120)
            assert all(r.status == "done" for r in reqs)


def test_async_result_reap_and_duplicate_uid(spatial_data):
    pts, qs = spatial_data
    with AsyncAidwServer(pts, query_domain=qs) as srv:
        r = srv.submit(qs[:32], uid=77)
        assert srv.result(77, timeout=120).status == "done"
        with pytest.raises(ValueError):
            srv.submit(qs[:32], uid=77)      # duplicate uid rejected
        assert srv.reap() == 1               # terminal request dropped
        r2 = srv.submit(qs[:32], uid=77)     # uid reusable after reap
        assert srv.result(r2, timeout=120).status == "done"


def test_async_server_on_mesh(spatial_data):
    """One async server serving every visible device (1 in the fast gate,
    8 under the CI serving-suite job): results bit-identical to the
    single-device synchronous engine."""
    import jax

    from repro.core.jax_compat import make_auto_mesh

    pts, qs = spatial_data
    mesh = make_auto_mesh((len(jax.devices()),), ("q",))
    eng = AidwEngine(pts, max_batch=256, query_domain=qs)
    ref = _requests(qs, 4)
    eng.run(ref)
    with AsyncAidwServer(pts, max_batch=256, query_domain=qs,
                         mesh=mesh) as srv:
        got = [srv.submit(qs[64 * i:64 * (i + 1)]) for i in range(4)]
        srv.flush(timeout=240)
    assert srv.session.stats["devices"] == len(jax.devices())
    for a, b in zip(got, ref):
        assert np.array_equal(np.asarray(a.values), b.values)


@pytest.mark.slow
def test_async_server_forced_8device_mesh():
    """Acceptance (a)+(b)+(c) on a REAL 8-lane host mesh (subprocess with
    forced host devices, like tests/test_distributed.py)."""
    out = run_multidevice("""
import numpy as np, jax
from repro.core.jax_compat import make_auto_mesh
from repro.data.pipeline import spatial_points, spatial_queries
from repro.serving import AidwEngine, AsyncAidwServer, InterpolationRequest

assert len(jax.devices()) == 8
pts = spatial_points(2048, seed=0)
qs = spatial_queries(512, seed=1)
mesh = make_auto_mesh((8,), ("q",))

eng = AidwEngine(pts, max_batch=256, query_domain=qs)
ref = [InterpolationRequest(uid=i, queries_xy=qs[64*i:64*(i+1)])
       for i in range(8)]
eng.run(ref)

srv = AsyncAidwServer(pts, max_batch=256, query_domain=qs, mesh=mesh)
subs = [srv.submit(qs[64*i:64*(i+1)]) for i in range(4)]
srv.update_dataset(inserts=spatial_points(8, seed=3), deletes=[0, 1])
post = [srv.submit(qs[64*i:64*(i+1)]) for i in range(4, 8)]
dead = srv.submit(qs[:64], deadline_s=-1.0)
srv.flush(timeout=300)
assert all(np.array_equal(np.asarray(a.values), b.values)
           for a, b in zip(subs, ref[:4])), 'pre-update mismatch'
assert all(r.status == 'done' for r in post)
assert dead.status == 'shed'
assert srv.session.stats['devices'] == 8
assert srv.session.stats['delta_updates'] == 1
rep = srv.report()
assert rep['latency']['total']['p99_s'] > 0
assert rep['completed'] == 8 and rep['shed'] == 1
srv.close()
print('8dev async ok', rep['completed'], rep['shed'])
""")
    assert "8dev async ok 8 1" in out
