"""Property tests (hypothesis): kNN exactness on adversarial point clouds,
CSR cell-table invariants, and fused-vs-unfused Stage-2 agreement.

Runs wherever dev deps are installed (``pip install -r requirements-dev.txt``,
e.g. the CI gate); skips cleanly on minimal containers.
"""

from __future__ import annotations

import pytest

pytest.importorskip("hypothesis")

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import (adaptive_alpha, bin_points, brute_knn, cell_ids,
                        grid_knn, plan_grid)

CLOUDS = ("uniform", "duplicates", "collinear", "single_cell")


def _cloud(mode: str, m: int, rng) -> np.ndarray:
    """Random (m, 2) point cloud, including degenerate configurations."""
    if mode == "duplicates":        # heavy exact-tie pressure on the top-k
        base = rng.random((max(m // 4, 1), 2))
        xy = base[rng.integers(0, len(base), m)]
    elif mode == "collinear":       # all points on one line
        t = rng.random(m)
        xy = np.stack([t, 0.2 + 0.6 * t], axis=1)
    elif mode == "single_cell":     # all points inside one grid cell
        xy = 0.5 + rng.random((m, 2)) * 1e-4
    else:
        xy = rng.random((m, 2))
    return xy.astype(np.float32)


@settings(max_examples=40, deadline=None)
@given(st.integers(20, 300), st.integers(1, 20), st.integers(0, 10_000),
       st.sampled_from(CLOUDS))
def test_grid_knn_exact_matches_brute(m, k, seed, mode):
    """grid_knn(exact=True) == brute_knn wherever exactness was certified."""
    rng = np.random.default_rng(seed)
    xy = _cloud(mode, m, rng)
    pts = np.concatenate([xy, rng.random((m, 1), np.float64)], 1).astype(np.float32)
    qs = rng.random((32, 2)).astype(np.float32)
    spec = plan_grid(pts[:, :2], qs)
    table = bin_points(spec, jnp.array(pts[:, 0]), jnp.array(pts[:, 1]),
                       jnp.array(pts[:, 2]))
    res = grid_knn(spec, table, jnp.array(qs), k, None, 4096, 32, True)
    bd2, _ = brute_knn(jnp.array(pts[:, :2]), jnp.array(qs), k)
    certified = ~np.asarray(res.overflow)
    assert certified.any()          # the window must be generous enough here
    got = np.sort(np.asarray(res.d2), 1)[certified]
    want = np.sort(np.asarray(bd2), 1)[certified]
    np.testing.assert_allclose(got, want, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 500), st.integers(0, 10_000), st.floats(0.3, 3.0),
       st.sampled_from(CLOUDS))
def test_cell_table_csr_invariants(m, seed, cell_factor, mode):
    """cell_start is a monotone CSR: starts at 0, ends at m, diffs = counts."""
    rng = np.random.default_rng(seed)
    xy = _cloud(mode, m, rng)
    z = rng.random(m).astype(np.float32)
    spec = plan_grid(xy, cell_factor=cell_factor)
    table = bin_points(spec, jnp.array(xy[:, 0]), jnp.array(xy[:, 1]),
                       jnp.array(z))
    cs = np.asarray(table.cell_start)
    assert cs.shape == (spec.n_cells + 1,)
    assert (np.diff(cs) >= 0).all()                 # monotone
    assert cs[0] == 0
    assert cs[-1] == m                              # every point binned once
    ids = np.asarray(cell_ids(spec, jnp.array(xy[:, 0]), jnp.array(xy[:, 1])))
    counts = np.bincount(ids, minlength=spec.n_cells)
    assert (np.diff(cs) == counts).all()


@settings(max_examples=15, deadline=None)
@given(st.integers(50, 400), st.integers(1, 200), st.integers(0, 1000))
def test_fused_stage2_matches_unfused(m, n, seed):
    """Alpha-in-kernel fused Stage 2 == alpha-outside + tiled weighting."""
    from repro.kernels.aidw import ops as aidw_ops

    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.random((n, 2)), jnp.float32)
    p = jnp.asarray(rng.random((m, 2)), jnp.float32)
    z = jnp.asarray(np.sin(rng.random(m) * 7), jnp.float32)
    r_obs = jnp.asarray(rng.uniform(0.0, 0.2, n), jnp.float32)
    kw = dict(tile_q=8, tile_d=128, interpret=True)
    fused, _ = aidw_ops.fused_stage2(q, p, z, r_obs, n_points=float(m),
                                     area=1.0, **kw)
    alpha = adaptive_alpha(r_obs, float(m), 1.0)
    unfused, _ = aidw_ops.tiled_interpolate(q, p, z, alpha, **kw)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               rtol=1e-5, atol=1e-5)
