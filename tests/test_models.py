"""Per-architecture smoke tests: REDUCED same-family configs, one forward /
train step on CPU, shape + finiteness asserts (the FULL configs are exercised
only via the dry-run)."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import api
from repro.nn.param import count_params, init_params

B, S = 2, 64


def _batch(cfg, rng, kind="train"):
    s_txt = S - cfg.n_vis_tokens if cfg.family == "vlm" else S
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, s_txt)),
                                   jnp.int32)}
    if kind == "train":
        batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, s_txt)),
                                      jnp.int32)
    if cfg.family == "vlm":
        batch["vis_embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.n_vis_tokens, cfg.d_model)), jnp.float32)
    if cfg.enc_dec:
        batch["enc_embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.enc_len, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    """One gradient step on the reduced config: finite loss, grads flow."""
    from repro.optim import adamw
    from repro.training import trainer

    cfg = reduced(get_config(arch))
    rng = np.random.default_rng(hash(arch) % 2**31)
    params = init_params(api.param_defs(cfg), jax.random.PRNGKey(0))
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt = trainer.init_opt_state(opt_cfg, params)
    step = jax.jit(trainer.make_train_step(cfg, opt_cfg))
    batch = _batch(cfg, rng)
    new_params, new_opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # parameters actually moved
    moved = jax.tree.reduce(
        lambda acc, pair: acc or bool(jnp.any(pair)),
        jax.tree.map(lambda a, b: jnp.any(a != b), params, new_params), False)
    assert moved
    assert int(new_opt["step"]) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_shapes(arch):
    cfg = reduced(get_config(arch))
    rng = np.random.default_rng(0)
    params = init_params(api.param_defs(cfg), jax.random.PRNGKey(1))
    loss = api.loss_fn(cfg)(params, _batch(cfg, rng))
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    logits, cache = api.prefill_fn(cfg)(params, _batch(cfg, rng, "prefill"))
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert cache  # non-empty


@pytest.mark.parametrize("arch", ["deepseek-7b", "qwen3-moe-30b-a3b",
                                  "mamba2-130m", "zamba2-2.7b",
                                  "whisper-medium", "internvl2-76b"])
def test_arch_decode_consistency(arch):
    """decode(prefill(S-1)) logits == forward(S) last-position logits."""
    cfg = reduced(get_config(arch))
    rng = np.random.default_rng(1)
    params = init_params(api.param_defs(cfg), jax.random.PRNGKey(2))
    batch = _batch(cfg, rng, "prefill")
    toks = batch["tokens"]
    pre = dict(batch)
    pre["tokens"] = toks[:, :-1]
    _, cache = api.prefill_fn(cfg)(params, pre)
    cache = dict(cache)
    for kk in ("k", "v"):
        if kk in cache:
            pad = [(0, 0)] * cache[kk].ndim
            pad[2] = (0, 1)
            cache[kk] = jnp.pad(cache[kk], pad)
    n_vis = cfg.n_vis_tokens if cfg.family == "vlm" else 0
    pos = jnp.int32(toks.shape[1] - 1 + n_vis)
    got, _ = api.decode_fn(cfg)(params, cache,
                                {"tokens": toks[:, -1:], "pos": pos})
    if cfg.enc_dec:
        from repro.models import encdec
        from repro.nn import layers as L

        enc_out = encdec.encode(params, cfg, batch["enc_embeds"])
        Bq, Sq = toks.shape
        x = L.embed(toks, params["embed"]) + \
            encdec.sinusoid_pos(Sq, cfg.d_model).astype(cfg.dtype)
        p = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32), (Bq, Sq))
        xf, _ = encdec._run_decoder(params, cfg, x, enc_out, q_pos=p, k_pos=p,
                                    k_valid=jnp.ones((Bq, Sq), bool), mode="train")
        want = encdec._dec_logits(params, cfg, xf)[:, -1]
    else:
        from repro.models import lm

        want = lm.forward(params, cfg, batch)[:, -1]
    tol = 2e-2 if cfg.is_moe else 1e-4  # MoE: capacity-dropping nondeterminism
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_param_counts_match_analytic():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        defs = api.param_defs(cfg)
        assert count_params(defs) == cfg.param_count(), arch


def test_full_configs_match_assignment():
    """Spot-check the exact assigned hyperparameters."""
    c = get_config("command-r-plus-104b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) \
        == (64, 12288, 96, 8, 33792, 256000)
    q = get_config("qwen3-moe-30b-a3b")
    assert (q.n_experts, q.top_k, q.moe_d_ff, q.vocab) == (128, 8, 768, 151936)
    m = get_config("mamba2-130m")
    assert (m.n_layers, m.d_model, m.ssm_state, m.vocab) == (24, 768, 128, 50280)
    z = get_config("zamba2-2.7b")
    assert (z.n_layers, z.d_model, z.attn_every, z.ssm_state) == (54, 2560, 6, 64)
    w = get_config("whisper-medium")
    assert w.enc_dec and (w.n_layers, w.n_enc_layers, w.d_model) == (24, 24, 1024)


def test_shape_applicability_policy():
    from repro.models.api import SHAPES, applicable

    long = SHAPES["long_500k"]
    assert applicable(get_config("mamba2-130m"), long)[0]
    assert applicable(get_config("zamba2-2.7b"), long)[0]
    assert not applicable(get_config("deepseek-7b"), long)[0]
    assert not applicable(get_config("whisper-medium"), long)[0]
    for arch in ARCH_IDS:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert applicable(get_config(arch), SHAPES[s])[0]
