"""Training loop integration: loss decreases, grad-accum equivalence."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.data.pipeline import LMStreamConfig, lm_batch
from repro.models import api
from repro.nn.param import init_params
from repro.optim import adamw
from repro.training import trainer

# full training loops + train-driver subprocess; compressed-training
# convergence still open on jax 0.4.x (ROADMAP 'Open items')
pytestmark = pytest.mark.slow


def _setup(arch="granite-3-2b", lr=2e-3, **kw):
    cfg = reduced(get_config(arch))
    params = init_params(api.param_defs(cfg), jax.random.PRNGKey(0))
    ocfg = adamw.AdamWConfig(lr=lr, warmup_steps=5, total_steps=100,
                             weight_decay=0.0)
    opt = trainer.init_opt_state(ocfg, params, compress=kw.get("compress", False))
    step = jax.jit(trainer.make_train_step(cfg, ocfg, **kw))
    stream = LMStreamConfig(vocab=cfg.vocab, seq_len=64, global_batch=8)
    return cfg, params, opt, step, stream


def test_loss_decreases_on_learnable_stream():
    cfg, params, opt, step, stream = _setup(lr=3e-3)
    losses = []
    for s in range(40):
        b = {k: jnp.asarray(v) for k, v in lm_batch(stream, s).items()}
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert last < first * 0.9, (first, last)


def test_grad_accum_matches_full_batch():
    cfg, params, opt, step1, stream = _setup(lr=1e-3)
    _, params4, opt4, step4, _ = _setup(lr=1e-3, grad_accum=4)
    b = {k: jnp.asarray(v) for k, v in lm_batch(stream, 0).items()}
    p1, o1, m1 = step1(params, opt, b)
    p4, o4, m4 = step4(params4, opt4, b)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
    diff = jax.tree.reduce(
        max, jax.tree.map(lambda a, b: float(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)).max()), p1, p4))
    assert diff < 1e-4


def test_compressed_training_still_learns():
    """int8 error-feedback compression must (a) still learn and (b) track
    the UNCOMPRESSED trajectory at identical settings.

    The seed's absolute bar (0.9x in 30 steps at lr=2e-3) failed on
    jax-0.4.37/CPU for compressed AND uncompressed alike — both land at
    0.919x, and per-channel quantization scales change nothing — so the
    budget was miscalibrated, not the quantizer (ROADMAP, seed-failure
    triage).  40 steps gives both paths room (~0.87x), and the parity bound
    pins the quantizer's actual contract: the error-feedback memory keeps
    the compressed optimizer on the uncompressed trajectory.
    """
    cfg, params, opt, step, stream = _setup(compress=True)
    _, params_u, opt_u, step_u, _ = _setup()     # uncompressed reference
    losses, losses_u = [], []
    for s in range(40):
        b = {k: jnp.asarray(v) for k, v in lm_batch(stream, s).items()}
        params, opt, m = step(params, opt, b)
        params_u, opt_u, m_u = step_u(params_u, opt_u, b)
        losses.append(float(m["loss"]))
        losses_u.append(float(m_u["loss"]))
    last, last_u = np.mean(losses[-5:]), np.mean(losses_u[-5:])
    assert last < np.mean(losses[:5]) * 0.9, (losses[:5], losses[-5:])
    assert last < last_u * 1.02, (last, last_u)  # tracks uncompressed


def test_train_driver_end_to_end(tmp_path):
    """launch.train main() via subprocess: run, checkpoint, resume."""
    import subprocess
    import sys
    from pathlib import Path

    repo = Path(__file__).resolve().parents[1]
    env_args = [sys.executable, "-m", "repro.launch.train",
                "--arch", "granite-3-2b", "--reduced", "--steps", "8",
                "--batch", "4", "--seq", "32", "--ckpt-every", "4",
                "--ckpt-dir", str(tmp_path)]
    import os
    env = dict(os.environ, PYTHONPATH=str(repo / "src"))
    r1 = subprocess.run(env_args, env=env, capture_output=True, text=True,
                        timeout=600)
    assert r1.returncode == 0, r1.stderr
    r2 = subprocess.run(env_args + ["--resume"], env=env, capture_output=True,
                        text=True, timeout=600)
    assert r2.returncode == 0, r2.stderr
    assert "resumed from step 8" in r2.stdout
