"""Observability spine (ISSUE 8): tracer, metrics registry, trace
propagation, and the report()/state() schema contracts.

Acceptance criteria covered here:
(a) span recording is retroactive, sampled once at the root, and a no-op
    when the trace is unsampled (the <2% overhead story);
(b) Chrome ``trace_event`` export is structurally valid and multi-host
    span collections land in per-host lanes;
(c) the registry merges bin-exactly across hosts and exports Prometheus
    text under the documented ``aidw_<slash_name>`` scheme;
(d) fleet QPS is computed over the UNION wall window (fake-clock exact),
    with the legacy summed rate exposed as ``queries_per_s_summed``;
(e) ``AsyncAidwServer.report()`` keeps its schema (the keys downstream
    dashboards and ``merge_reports`` read), now including ``stages`` and
    ``registry`` blocks;
(f) session timing aliases: ``stats['last_plan_s']`` and
    ``res.timings['query']`` mirror the newest registry observations, and
    ``profile=True`` stage walls are additive.
The 2-host kill-mid-batch trace-propagation test lives in
tests/test_cluster.py next to the other fleet-death coverage.
"""

from __future__ import annotations

import json

import numpy as np
import pytest

from repro.data.pipeline import spatial_points, spatial_queries
from repro.obs import Registry, Tracer, chrome_trace, new_span_id
from repro.serving import AsyncAidwServer, Telemetry
from repro.serving.cluster import merge_reports


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


def test_tracer_retroactive_record_and_wall_anchor():
    clk, wall = FakeClock(5.0), FakeClock(105.0)
    tr = Tracer(clock=clk, wall=wall, sample_rate=1.0, host="h9")
    tid = tr.new_trace()
    assert tid is not None
    root = tr.record("plan", 5.0, 7.5, trace_id=tid)
    child = tr.record("bin", 5.0, 6.0, trace_id=tid, parent_id=root)
    assert root and child and root != child
    spans = tr.spans()
    assert [s["name"] for s in spans] == ["plan", "bin"]
    # wall anchoring: offset = wall - clock at construction (100.0)
    assert spans[0]["t0"] == pytest.approx(105.0)
    assert spans[0]["dur"] == pytest.approx(2.5)
    assert spans[1]["parent_id"] == root
    assert all(s["trace_id"] == tid and s["host"] == "h9" for s in spans)


def test_tracer_rate_zero_is_total_noop():
    tr = Tracer(clock=FakeClock(), wall=None, sample_rate=0.0)
    assert tr.new_trace() is None
    # record with an unsampled trace: returns None, stores nothing — every
    # call site's cost is exactly this one if
    assert tr.record("x", 0.0, 1.0, trace_id=None) is None
    with tr.span("y", trace_id=None) as sp:
        assert sp.span_id is None
    assert tr.spans() == []


def test_tracer_sampling_is_decided_at_the_root():
    tr = Tracer(clock=FakeClock(), wall=None, sample_rate=0.5, seed=7)
    decisions = [tr.new_trace() is not None for _ in range(200)]
    assert 40 < sum(decisions) < 160            # probabilistic, seeded
    # children never re-decide: a sampled trace records everything
    tid = next(t for t in iter(tr.new_trace, "") if t is not None)
    assert tr.record("child", 0.0, 1.0, trace_id=tid) is not None


def test_tracer_span_context_manager_and_drain():
    clk = FakeClock(0.0)
    tr = Tracer(clock=clk, wall=None, sample_rate=1.0)
    tid = tr.new_trace()
    with tr.span("phase1", trace_id=tid) as sp:
        clk.t += 0.25
        tr.record("inner", 0.1, 0.2, trace_id=tid, parent_id=sp.span_id)
    spans = tr.drain()
    assert {s["name"] for s in spans} == {"inner", "phase1"}
    ph1 = next(s for s in spans if s["name"] == "phase1")
    assert ph1["dur"] == pytest.approx(0.25)
    inner = next(s for s in spans if s["name"] == "inner")
    assert inner["parent_id"] == ph1["span_id"]
    assert tr.spans() == []                     # drain cleared the buffer


def test_tracer_retention_cap_counts_drops():
    tr = Tracer(clock=FakeClock(), wall=None, sample_rate=1.0, max_spans=2)
    tid = tr.new_trace()
    for i in range(5):
        tr.record(f"s{i}", 0.0, 1.0, trace_id=tid)
    assert len(tr.spans()) == 2 and tr.dropped == 3


def test_pregenerated_root_ids_parent_before_record():
    # the fleet-router pattern: children are parented on a root id that is
    # only recorded (retroactively) after they already completed
    tr = Tracer(clock=FakeClock(), wall=None, sample_rate=1.0)
    tid, root = tr.new_trace(), new_span_id()
    tr.record("queue_wait", 0.0, 0.5, trace_id=tid, parent_id=root)
    assert tr.record("route", 0.0, 1.0, trace_id=tid, span_id=root) == root
    spans = tr.spans()
    ids = {s["span_id"] for s in spans}
    assert all(s["parent_id"] in ids | {None} for s in spans)


def test_chrome_trace_export_is_structurally_valid(tmp_path):
    tr = Tracer(clock=FakeClock(1.0), wall=None, sample_rate=1.0, host="3")
    tid = tr.new_trace()
    tr.record("stage1", 1.0, 1.5, trace_id=tid, args={"queries": 64})
    path = tmp_path / "trace.json"
    tr.export_chrome(str(path))
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    (ev,) = doc["traceEvents"]
    assert ev["ph"] == "X" and ev["cat"] == "aidw"
    assert ev["ts"] == pytest.approx(1.0 * 1e6)     # microseconds
    assert ev["dur"] == pytest.approx(0.5 * 1e6)
    assert ev["pid"] == "host-3"
    assert ev["args"]["trace_id"] == tid and ev["args"]["queries"] == 64


def test_chrome_trace_merges_hosts_into_lanes():
    dicts = [{"name": "route", "trace_id": "t", "span_id": "a",
              "parent_id": None, "t0": 0.0, "dur": 1.0, "host": "router"},
             {"name": "execute", "trace_id": "t", "span_id": "b",
              "parent_id": "a", "t0": 0.2, "dur": 0.5, "host": "1"}]
    doc = chrome_trace(dicts)
    assert {e["pid"] for e in doc["traceEvents"]} \
        == {"host-router", "host-1"}
    assert json.dumps(doc)                          # serializable as-is


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_merge_is_bin_exact_and_gauge_mode_aware():
    a, b = Registry(), Registry()
    for reg, vals in ((a, (0.001, 0.01)), (b, (0.1, 1.0))):
        for v in vals:
            reg.observe("session/query_s", v)
        reg.inc("serving/batches", 2)
    a.set("ingest/staged_bytes", 100, merge="sum")
    b.set("ingest/staged_bytes", 50, merge="sum")
    a.set("ingest/ring_occupancy", 0.2, merge="max")
    b.set("ingest/ring_occupancy", 0.7, merge="max")
    fleet = Registry.merge_states([a.state(), b.state()])
    snap = fleet.snapshot()
    assert snap["counters"]["serving/batches"] == 4
    assert snap["gauges"]["ingest/staged_bytes"] == 150
    assert snap["gauges"]["ingest/ring_occupancy"] == pytest.approx(0.7)
    h = snap["histograms"]["session/query_s"]
    # bin-exact: identical to one histogram fed all four observations
    one = Registry()
    for v in (0.001, 0.01, 0.1, 1.0):
        one.observe("session/query_s", v)
    assert h == one.snapshot()["histograms"]["session/query_s"]


def test_registry_prometheus_text_naming_scheme():
    reg = Registry()
    reg.observe("serving/queue_wait_s", 0.004)
    reg.inc("serving/batches")
    reg.set("ingest/ring_occupancy", 0.5)
    text = reg.prometheus_text()
    assert "# TYPE aidw_serving_batches_total counter" in text
    assert "aidw_serving_batches_total 1" in text
    assert "# TYPE aidw_ingest_ring_occupancy gauge" in text
    assert "# TYPE aidw_serving_queue_wait_s summary" in text
    assert 'aidw_serving_queue_wait_s{quantile="0.99"}' in text
    assert "aidw_serving_queue_wait_s_count 1" in text


def test_reset_histogram_keeps_registration_and_binning():
    reg = Registry()
    reg.histogram("x", lo=1e-3, hi=1e2, bins_per_decade=5).record(0.5)
    h = reg.reset_histogram("x")
    assert h.count == 0 and (h.lo, h.hi, h.bins_per_decade) == (1e-3, 1e2, 5)
    reg.observe("x", 0.1)
    assert reg.snapshot()["histograms"]["x"]["count"] == 1


# ---------------------------------------------------------------------------
# fleet QPS: union wall window (satellite b)
# ---------------------------------------------------------------------------


class _Req:
    queries_xy = np.zeros((100, 2), np.float32)
    overflow = 0
    t_submit, t_dispatch, t_done = 1.0, 1.5, 2.0


def _host_report(wall_at: float, host_id: int) -> dict:
    t = Telemetry(clock=FakeClock(10.0), wall=FakeClock(wall_at))
    t.record_batch([_Req()], 0.5)
    return {"merge": t.state(), "epoch": 0, "host_id": host_id}


def test_fleet_qps_uses_union_wall_window_not_summed_rates():
    # two hosts each serve 100 queries over a 1s window, but the windows
    # are DISJOINT in wall time: true fleet throughput is 200/2s = 100 q/s,
    # while the pre-PR-8 summed rate over-reports 200 q/s
    reports = [_host_report(1000.0, 0), _host_report(1001.0, 1)]
    fleet = merge_reports(reports)
    assert fleet["queries_per_s"] == pytest.approx(100.0)
    assert fleet["queries_per_s_summed"] == pytest.approx(200.0)


def test_fleet_qps_identical_windows_match_summed():
    reports = [_host_report(1000.0, 0), _host_report(1000.0, 1)]
    fleet = merge_reports(reports)
    assert fleet["queries_per_s"] == pytest.approx(200.0)
    assert fleet["queries_per_s_summed"] == pytest.approx(200.0)


def test_fleet_qps_falls_back_to_summed_without_windows():
    reports = [_host_report(1000.0, 0), _host_report(1001.0, 1)]
    for r in reports:                       # legacy per-host state shape
        del r["merge"]["window"]
    fleet = merge_reports(reports)
    assert fleet["queries_per_s"] == pytest.approx(200.0)


# ---------------------------------------------------------------------------
# server report schema + serving spans (needs jax; small shapes)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def traced_server_report():
    pts = spatial_points(2048, seed=0)
    with AsyncAidwServer(pts, max_batch=512, trace_sample_rate=1.0,
                         query_domain=spatial_queries(256, seed=1)) as srv:
        reqs = [srv.submit(spatial_queries(32 + i, seed=2 + i), block=False)
                for i in range(4)]
        srv.update_dataset(inserts=spatial_points(8, seed=9),
                           deletes=np.arange(8), timeout=300)
        srv.flush(timeout=300)
        yield srv.report(), srv.spans(), reqs, srv.metrics_text()


def test_server_report_schema_regression(traced_server_report):
    rep, _, reqs, _ = traced_server_report
    assert all(r.status == "done" for r in reqs)
    # the stable top-level surface: telemetry counters + rate + latency,
    # server attribution, and (PR 8) the stages/registry blocks
    for key in ("submitted", "completed", "shed", "rejected_full",
                "batches", "queries", "overflow_queries", "dataset_updates",
                "queries_per_s", "latency", "epoch", "admission",
                "queue_depth", "session", "merge", "stages", "registry"):
        assert key in rep, f"report() lost key {key!r}"
    for axis in ("queue", "execute", "total", "shed"):
        snap = rep["latency"][axis]
        assert {"count", "mean_s", "p50_s", "p95_s", "p99_s",
                "max_s"} <= set(snap)
    # the mergeable block: counters + rate + wall window + full hist states
    assert {"counters", "queries_per_s", "window", "hists"} \
        <= set(rep["merge"])
    assert {"t0_wall", "t1_wall", "queries"} == set(rep["merge"]["window"])
    assert rep["merge"]["window"]["queries"] == rep["queries"]
    # the stage block: serving + session walls from ONE registry
    hists = rep["stages"]["histograms"]
    for name in ("serving/queue_wait_s", "serving/execute_s",
                 "serving/total_s", "serving/coalesce_s",
                 "serving/scatter_s", "session/plan_s"):
        assert name in hists, f"stages block lost {name!r}"
    assert hists["serving/queue_wait_s"]["count"] == len(reqs)
    json.dumps(rep)                             # stays JSON-serializable


def test_serving_spans_cover_every_traced_request(traced_server_report):
    _, spans, reqs, _ = traced_server_report
    by_trace: dict = {}
    for s in spans:
        by_trace.setdefault(s["trace_id"], []).append(s)
    req_traces = [by_trace[r.trace_id] for r in reqs]
    for trace in req_traces:
        names = sorted(s["name"] for s in trace)
        assert names == ["coalesce", "execute", "queue_wait", "scatter"]
        assert all(s["parent_id"] == trace[0]["parent_id"] for s in trace)
    # the epoch barrier got its own trace with an apply_epoch span
    assert any(any(s["name"] == "apply_epoch" for s in t)
               for t in by_trace.values())


def test_server_prometheus_endpoint(traced_server_report):
    _, _, _, text = traced_server_report
    assert "# TYPE aidw_serving_queue_wait_s summary" in text
    assert "aidw_serving_coalesce_s" in text
    assert "aidw_session_plan_s" in text


def test_server_without_tracer_serves_and_reports_no_spans():
    pts = spatial_points(2048, seed=0)
    with AsyncAidwServer(pts, max_batch=512,
                         query_domain=spatial_queries(256, seed=1)) as srv:
        r = srv.submit(spatial_queries(32, seed=2))
        srv.flush(timeout=300)
        assert r.status == "done" and r.trace_id is None
        assert srv.spans() == []
        assert srv.report()["stages"]["histograms"][
            "serving/queue_wait_s"]["count"] == 1


# ---------------------------------------------------------------------------
# session timing aliases (satellite a)
# ---------------------------------------------------------------------------


def test_session_timing_aliases_mirror_registry():
    from repro.core import AidwConfig, InterpolationSession

    pts = spatial_points(2048, seed=0)
    qs = spatial_queries(256, seed=1)
    sess = InterpolationSession(pts, AidwConfig(), query_domain=qs)
    # stats["last_plan_s"] is the documented alias of the newest
    # session/plan_s observation
    snap = sess.registry.snapshot()["histograms"]
    assert snap["session/plan_s"]["count"] == 1
    assert snap["session/plan_s"]["mean_s"] \
        == pytest.approx(sess.stats["last_plan_s"])

    sess.query(qs)                                  # compile the bucket
    sess.registry.reset_histogram("session/query_s")
    res = sess.query(qs, timings=True)
    h = sess.registry.snapshot()["histograms"]["session/query_s"]
    assert h["count"] == 1
    # res.timings["query"] is the alias of the same wall
    assert h["mean_s"] == pytest.approx(res.timings["query"])

    prof = sess.query(qs, profile=True)
    assert prof.timings["stage1"] + prof.timings["stage2"] \
        == pytest.approx(prof.timings["query"])
    h = sess.registry.snapshot()["histograms"]
    assert h["session/stage1_s"]["count"] == 1
    assert h["session/stage2_s"]["count"] == 1
    # profiled split is bit-identical to the fused path
    assert np.array_equal(np.asarray(prof.values), np.asarray(res.values))


def test_session_spans_nest_plan_and_profiled_query():
    from repro.core import AidwConfig, InterpolationSession

    pts = spatial_points(2048, seed=0)
    qs = spatial_queries(256, seed=1)
    tr = Tracer(sample_rate=1.0, host="s")
    sess = InterpolationSession(pts, AidwConfig(), query_domain=qs,
                                tracer=tr)
    sess.query(qs, profile=True)
    spans = tr.spans()
    names = {s["name"] for s in spans}
    assert {"plan", "bin", "query", "stage1", "stage2"} <= names
    plan = next(s for s in spans if s["name"] == "plan")
    binsp = next(s for s in spans if s["name"] == "bin")
    assert binsp["parent_id"] == plan["span_id"]
    assert binsp["dur"] <= plan["dur"]
    query = next(s for s in spans if s["name"] == "query")
    for st in ("stage1", "stage2"):
        sp = next(s for s in spans if s["name"] == st)
        assert sp["parent_id"] == query["span_id"]
        assert sp["trace_id"] == query["trace_id"]
