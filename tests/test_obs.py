"""Observability spine (ISSUES 8+9): tracer, metrics registry, trace
propagation, flight recorder, SLO monitor, tail attribution, and the
report()/state() schema contracts.

Acceptance criteria covered here:
(a) span recording is retroactive, sampled once at the root, and a no-op
    when the trace is unsampled (the <2% overhead story);
(b) Chrome ``trace_event`` export is structurally valid and multi-host
    span collections land in per-host lanes;
(c) the registry merges bin-exactly across hosts and exports Prometheus
    text under the documented ``aidw_<slash_name>`` scheme — with an
    EXACT-exposition regression (``# HELP``/``# TYPE`` per family);
(d) fleet QPS is computed over the UNION wall window (fake-clock exact),
    with the legacy summed rate exposed as ``queries_per_s_summed``;
(e) ``AsyncAidwServer.report()`` keeps its schema (the keys downstream
    dashboards and ``merge_reports`` read), now including ``stages``,
    ``registry``, ``slo`` and ``recorder`` blocks;
(f) session timing aliases: ``stats['last_plan_s']`` and
    ``res.timings['query']`` mirror the newest registry observations, and
    ``profile=True`` stage walls are additive;
(g) PR 9: flight-recorder retention is DETERMINISTIC under fake clocks
    (anomaly classes, FIFO ring eviction, explicit dropped counters, the
    prior-window slow rule), SLO burn rates match hand-computed
    arithmetic with edge-triggered breach events, the tail attribution
    decomposes p99-p50 into per-stage contributions that SUM to the gap,
    and histogram exemplars merge bin-exactly.
The 2-host kill-mid-batch trace-propagation test and the fleet debugz
bundle tests live in tests/test_cluster.py next to the other
fleet-death coverage.
"""

from __future__ import annotations

import json

import numpy as np
import pytest

from repro.data.pipeline import spatial_points, spatial_queries
from repro.obs import (FlightRecorder, Registry, SloMonitor, Tracer,
                       chrome_trace, fleet_epoch_events, new_span_id,
                       tail_attribution)
from repro.obs.metrics import Histogram
from repro.serving import AsyncAidwServer, Telemetry
from repro.serving.cluster import merge_reports


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


def test_tracer_retroactive_record_and_wall_anchor():
    clk, wall = FakeClock(5.0), FakeClock(105.0)
    tr = Tracer(clock=clk, wall=wall, sample_rate=1.0, host="h9")
    tid = tr.new_trace()
    assert tid is not None
    root = tr.record("plan", 5.0, 7.5, trace_id=tid)
    child = tr.record("bin", 5.0, 6.0, trace_id=tid, parent_id=root)
    assert root and child and root != child
    spans = tr.spans()
    assert [s["name"] for s in spans] == ["plan", "bin"]
    # wall anchoring: offset = wall - clock at construction (100.0)
    assert spans[0]["t0"] == pytest.approx(105.0)
    assert spans[0]["dur"] == pytest.approx(2.5)
    assert spans[1]["parent_id"] == root
    assert all(s["trace_id"] == tid and s["host"] == "h9" for s in spans)


def test_tracer_rate_zero_is_total_noop():
    tr = Tracer(clock=FakeClock(), wall=None, sample_rate=0.0)
    assert tr.new_trace() is None
    # record with an unsampled trace: returns None, stores nothing — every
    # call site's cost is exactly this one if
    assert tr.record("x", 0.0, 1.0, trace_id=None) is None
    with tr.span("y", trace_id=None) as sp:
        assert sp.span_id is None
    assert tr.spans() == []


def test_tracer_sampling_is_decided_at_the_root():
    tr = Tracer(clock=FakeClock(), wall=None, sample_rate=0.5, seed=7)
    decisions = [tr.new_trace() is not None for _ in range(200)]
    assert 40 < sum(decisions) < 160            # probabilistic, seeded
    # children never re-decide: a sampled trace records everything
    tid = next(t for t in iter(tr.new_trace, "") if t is not None)
    assert tr.record("child", 0.0, 1.0, trace_id=tid) is not None


def test_tracer_span_context_manager_and_drain():
    clk = FakeClock(0.0)
    tr = Tracer(clock=clk, wall=None, sample_rate=1.0)
    tid = tr.new_trace()
    with tr.span("phase1", trace_id=tid) as sp:
        clk.t += 0.25
        tr.record("inner", 0.1, 0.2, trace_id=tid, parent_id=sp.span_id)
    spans = tr.drain()
    assert {s["name"] for s in spans} == {"inner", "phase1"}
    ph1 = next(s for s in spans if s["name"] == "phase1")
    assert ph1["dur"] == pytest.approx(0.25)
    inner = next(s for s in spans if s["name"] == "inner")
    assert inner["parent_id"] == ph1["span_id"]
    assert tr.spans() == []                     # drain cleared the buffer


def test_tracer_retention_cap_counts_drops():
    tr = Tracer(clock=FakeClock(), wall=None, sample_rate=1.0, max_spans=2)
    tid = tr.new_trace()
    for i in range(5):
        tr.record(f"s{i}", 0.0, 1.0, trace_id=tid)
    assert len(tr.spans()) == 2 and tr.dropped == 3


def test_pregenerated_root_ids_parent_before_record():
    # the fleet-router pattern: children are parented on a root id that is
    # only recorded (retroactively) after they already completed
    tr = Tracer(clock=FakeClock(), wall=None, sample_rate=1.0)
    tid, root = tr.new_trace(), new_span_id()
    tr.record("queue_wait", 0.0, 0.5, trace_id=tid, parent_id=root)
    assert tr.record("route", 0.0, 1.0, trace_id=tid, span_id=root) == root
    spans = tr.spans()
    ids = {s["span_id"] for s in spans}
    assert all(s["parent_id"] in ids | {None} for s in spans)


def test_chrome_trace_export_is_structurally_valid(tmp_path):
    tr = Tracer(clock=FakeClock(1.0), wall=None, sample_rate=1.0, host="3")
    tid = tr.new_trace()
    tr.record("stage1", 1.0, 1.5, trace_id=tid, args={"queries": 64})
    path = tmp_path / "trace.json"
    tr.export_chrome(str(path))
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    (ev,) = doc["traceEvents"]
    assert ev["ph"] == "X" and ev["cat"] == "aidw"
    assert ev["ts"] == pytest.approx(1.0 * 1e6)     # microseconds
    assert ev["dur"] == pytest.approx(0.5 * 1e6)
    assert ev["pid"] == "host-3"
    assert ev["args"]["trace_id"] == tid and ev["args"]["queries"] == 64


def test_chrome_trace_merges_hosts_into_lanes():
    dicts = [{"name": "route", "trace_id": "t", "span_id": "a",
              "parent_id": None, "t0": 0.0, "dur": 1.0, "host": "router"},
             {"name": "execute", "trace_id": "t", "span_id": "b",
              "parent_id": "a", "t0": 0.2, "dur": 0.5, "host": "1"}]
    doc = chrome_trace(dicts)
    assert {e["pid"] for e in doc["traceEvents"]} \
        == {"host-router", "host-1"}
    assert json.dumps(doc)                          # serializable as-is


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_merge_is_bin_exact_and_gauge_mode_aware():
    a, b = Registry(), Registry()
    for reg, vals in ((a, (0.001, 0.01)), (b, (0.1, 1.0))):
        for v in vals:
            reg.observe("session/query_s", v)
        reg.inc("serving/batches", 2)
    a.set("ingest/staged_bytes", 100, merge="sum")
    b.set("ingest/staged_bytes", 50, merge="sum")
    a.set("ingest/ring_occupancy", 0.2, merge="max")
    b.set("ingest/ring_occupancy", 0.7, merge="max")
    fleet = Registry.merge_states([a.state(), b.state()])
    snap = fleet.snapshot()
    assert snap["counters"]["serving/batches"] == 4
    assert snap["gauges"]["ingest/staged_bytes"] == 150
    assert snap["gauges"]["ingest/ring_occupancy"] == pytest.approx(0.7)
    h = snap["histograms"]["session/query_s"]
    # bin-exact: identical to one histogram fed all four observations
    one = Registry()
    for v in (0.001, 0.01, 0.1, 1.0):
        one.observe("session/query_s", v)
    assert h == one.snapshot()["histograms"]["session/query_s"]


def test_registry_prometheus_text_naming_scheme():
    reg = Registry()
    reg.observe("serving/queue_wait_s", 0.004)
    reg.inc("serving/batches")
    reg.set("ingest/ring_occupancy", 0.5)
    text = reg.prometheus_text()
    assert "# TYPE aidw_serving_batches_total counter" in text
    assert "aidw_serving_batches_total 1" in text
    assert "# TYPE aidw_ingest_ring_occupancy gauge" in text
    assert "# TYPE aidw_serving_queue_wait_s summary" in text
    assert 'aidw_serving_queue_wait_s{quantile="0.99"}' in text
    assert "aidw_serving_queue_wait_s_count 1" in text


def test_reset_histogram_keeps_registration_and_binning():
    reg = Registry()
    reg.histogram("x", lo=1e-3, hi=1e2, bins_per_decade=5).record(0.5)
    h = reg.reset_histogram("x")
    assert h.count == 0 and (h.lo, h.hi, h.bins_per_decade) == (1e-3, 1e2, 5)
    reg.observe("x", 0.1)
    assert reg.snapshot()["histograms"]["x"]["count"] == 1


# ---------------------------------------------------------------------------
# fleet QPS: union wall window (satellite b)
# ---------------------------------------------------------------------------


class _Req:
    queries_xy = np.zeros((100, 2), np.float32)
    overflow = 0
    t_submit, t_dispatch, t_done = 1.0, 1.5, 2.0


def _host_report(wall_at: float, host_id: int) -> dict:
    t = Telemetry(clock=FakeClock(10.0), wall=FakeClock(wall_at))
    t.record_batch([_Req()], 0.5)
    return {"merge": t.state(), "epoch": 0, "host_id": host_id}


def test_fleet_qps_uses_union_wall_window_not_summed_rates():
    # two hosts each serve 100 queries over a 1s window, but the windows
    # are DISJOINT in wall time: true fleet throughput is 200/2s = 100 q/s,
    # while the pre-PR-8 summed rate over-reports 200 q/s
    reports = [_host_report(1000.0, 0), _host_report(1001.0, 1)]
    fleet = merge_reports(reports)
    assert fleet["queries_per_s"] == pytest.approx(100.0)
    assert fleet["queries_per_s_summed"] == pytest.approx(200.0)


def test_fleet_qps_identical_windows_match_summed():
    reports = [_host_report(1000.0, 0), _host_report(1000.0, 1)]
    fleet = merge_reports(reports)
    assert fleet["queries_per_s"] == pytest.approx(200.0)
    assert fleet["queries_per_s_summed"] == pytest.approx(200.0)


def test_fleet_qps_falls_back_to_summed_without_windows():
    reports = [_host_report(1000.0, 0), _host_report(1001.0, 1)]
    for r in reports:                       # legacy per-host state shape
        del r["merge"]["window"]
    fleet = merge_reports(reports)
    assert fleet["queries_per_s"] == pytest.approx(200.0)


# ---------------------------------------------------------------------------
# server report schema + serving spans (needs jax; small shapes)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def traced_server_report():
    pts = spatial_points(2048, seed=0)
    with AsyncAidwServer(pts, max_batch=512, trace_sample_rate=1.0,
                         query_domain=spatial_queries(256, seed=1)) as srv:
        reqs = [srv.submit(spatial_queries(32 + i, seed=2 + i), block=False)
                for i in range(4)]
        srv.update_dataset(inserts=spatial_points(8, seed=9),
                           deletes=np.arange(8), timeout=300)
        srv.flush(timeout=300)
        yield srv.report(), srv.spans(), reqs, srv.metrics_text()


def test_server_report_schema_regression(traced_server_report):
    rep, _, reqs, _ = traced_server_report
    assert all(r.status == "done" for r in reqs)
    # the stable top-level surface: telemetry counters + rate + latency,
    # server attribution, and (PR 8) the stages/registry blocks
    for key in ("submitted", "completed", "shed", "rejected_full",
                "batches", "queries", "overflow_queries", "dataset_updates",
                "queries_per_s", "latency", "epoch", "admission",
                "queue_depth", "session", "merge", "stages", "registry",
                "slo", "recorder"):
        assert key in rep, f"report() lost key {key!r}"
    # the PR 9 blocks: SLO evaluation + flight-recorder counters
    assert {"targets", "windows_s", "rates", "gauges", "events"} \
        <= set(rep["slo"])
    assert {"requests", "retained", "dropped", "events",
            "events_dropped", "anomalies"} <= set(rep["recorder"])
    assert rep["recorder"]["requests"] == len(reqs)
    for axis in ("queue", "execute", "total", "shed"):
        snap = rep["latency"][axis]
        assert {"count", "mean_s", "p50_s", "p95_s", "p99_s",
                "max_s"} <= set(snap)
    # the mergeable block: counters + rate + wall window + full hist states
    assert {"counters", "queries_per_s", "window", "hists"} \
        <= set(rep["merge"])
    assert {"t0_wall", "t1_wall", "queries"} == set(rep["merge"]["window"])
    assert rep["merge"]["window"]["queries"] == rep["queries"]
    # the stage block: serving + session walls from ONE registry
    hists = rep["stages"]["histograms"]
    for name in ("serving/queue_wait_s", "serving/execute_s",
                 "serving/total_s", "serving/coalesce_s",
                 "serving/scatter_s", "session/plan_s",
                 "serving/epoch_barrier_s"):
        assert name in hists, f"stages block lost {name!r}"
    assert hists["serving/queue_wait_s"]["count"] == len(reqs)
    # the update_dataset barrier in the fixture observed its FIFO hold
    assert hists["serving/epoch_barrier_s"]["count"] == 1
    json.dumps(rep)                             # stays JSON-serializable


def test_serving_spans_cover_every_traced_request(traced_server_report):
    _, spans, reqs, _ = traced_server_report
    by_trace: dict = {}
    for s in spans:
        by_trace.setdefault(s["trace_id"], []).append(s)
    req_traces = [by_trace[r.trace_id] for r in reqs]
    for trace in req_traces:
        names = sorted(s["name"] for s in trace)
        assert names == ["coalesce", "execute", "queue_wait", "scatter"]
        assert all(s["parent_id"] == trace[0]["parent_id"] for s in trace)
    # the epoch barrier got its own trace with an apply_epoch span
    assert any(any(s["name"] == "apply_epoch" for s in t)
               for t in by_trace.values())


def test_server_prometheus_endpoint(traced_server_report):
    _, _, _, text = traced_server_report
    assert "# TYPE aidw_serving_queue_wait_s summary" in text
    assert "aidw_serving_coalesce_s" in text
    assert "aidw_session_plan_s" in text


def test_server_without_tracer_serves_and_reports_no_spans():
    pts = spatial_points(2048, seed=0)
    with AsyncAidwServer(pts, max_batch=512,
                         query_domain=spatial_queries(256, seed=1)) as srv:
        r = srv.submit(spatial_queries(32, seed=2))
        srv.flush(timeout=300)
        assert r.status == "done" and r.trace_id is None
        assert srv.spans() == []
        assert srv.report()["stages"]["histograms"][
            "serving/queue_wait_s"]["count"] == 1


# ---------------------------------------------------------------------------
# session timing aliases (satellite a)
# ---------------------------------------------------------------------------


def test_session_timing_aliases_mirror_registry():
    from repro.core import AidwConfig, InterpolationSession

    pts = spatial_points(2048, seed=0)
    qs = spatial_queries(256, seed=1)
    sess = InterpolationSession(pts, AidwConfig(), query_domain=qs)
    # stats["last_plan_s"] is the documented alias of the newest
    # session/plan_s observation
    snap = sess.registry.snapshot()["histograms"]
    assert snap["session/plan_s"]["count"] == 1
    assert snap["session/plan_s"]["mean_s"] \
        == pytest.approx(sess.stats["last_plan_s"])

    sess.query(qs)                                  # compile the bucket
    sess.registry.reset_histogram("session/query_s")
    res = sess.query(qs, timings=True)
    h = sess.registry.snapshot()["histograms"]["session/query_s"]
    assert h["count"] == 1
    # res.timings["query"] is the alias of the same wall
    assert h["mean_s"] == pytest.approx(res.timings["query"])

    prof = sess.query(qs, profile=True)
    assert prof.timings["stage1"] + prof.timings["stage2"] \
        == pytest.approx(prof.timings["query"])
    h = sess.registry.snapshot()["histograms"]
    assert h["session/stage1_s"]["count"] == 1
    assert h["session/stage2_s"]["count"] == 1
    # profiled split is bit-identical to the fused path
    assert np.array_equal(np.asarray(prof.values), np.asarray(res.values))


def test_session_spans_nest_plan_and_profiled_query():
    from repro.core import AidwConfig, InterpolationSession

    pts = spatial_points(2048, seed=0)
    qs = spatial_queries(256, seed=1)
    tr = Tracer(sample_rate=1.0, host="s")
    sess = InterpolationSession(pts, AidwConfig(), query_domain=qs,
                                tracer=tr)
    sess.query(qs, profile=True)
    spans = tr.spans()
    names = {s["name"] for s in spans}
    assert {"plan", "bin", "query", "stage1", "stage2"} <= names
    plan = next(s for s in spans if s["name"] == "plan")
    binsp = next(s for s in spans if s["name"] == "bin")
    assert binsp["parent_id"] == plan["span_id"]
    assert binsp["dur"] <= plan["dur"]
    query = next(s for s in spans if s["name"] == "query")
    for st in ("stage1", "stage2"):
        sp = next(s for s in spans if s["name"] == st)
        assert sp["parent_id"] == query["span_id"]
        assert sp["trace_id"] == query["trace_id"]


# ---------------------------------------------------------------------------
# flight recorder: deterministic tail-sampling retention (ISSUE 9)
# ---------------------------------------------------------------------------


class _RecReq:
    """Minimal request stub for the recorder: stamped timestamps only."""

    def __init__(self, uid, *, deadline=None, overflow=0, zero_weight=0,
                 t_submit=0.0, t_dispatch=None, t_done=None,
                 trace_id=None, epoch=None):
        self.uid = uid
        self.deadline = deadline
        self.overflow = overflow
        self.zero_weight = zero_weight
        self.t_submit = t_submit
        self.t_dispatch = t_dispatch
        self.t_done = t_done
        self.trace_id = trace_id
        self.epoch = epoch


def _observe_fast(rec, uid, *, total=0.01, **kw):
    """One in-SLO request: queue_wait 10% of total, execute the rest."""
    qw = 0.1 * total
    req = _RecReq(uid, t_submit=0.0, t_dispatch=qw, t_done=total, **kw)
    return rec.observe_request(req, t0=qw, t1=total, t2=total,
                               last_submit=0.0)


def test_recorder_in_slo_requests_leave_no_trace():
    rec = FlightRecorder(clock=FakeClock(), wall=None, host="h",
                         top_percentile=None)
    assert _observe_fast(rec, 1) is None
    assert rec.retained() == []
    snap = rec.snapshot()
    assert snap["requests"] == 1 and snap["retained"] == 0
    assert all(v == 0 for v in snap["anomalies"].values())
    # the coarse breakdown still folded into the running histograms
    assert rec.state()["hists"]["total"]["count"] == 1


def test_recorder_classifies_and_retains_each_anomaly_class():
    rec = FlightRecorder(clock=FakeClock(), wall=None, host="h",
                         top_percentile=None)
    # served past its deadline, plus overflow + zero-weight queries
    req = _RecReq(7, deadline=0.02, overflow=2, zero_weight=1,
                  t_submit=0.0, t_dispatch=0.01, t_done=0.04)
    rid = rec.observe_request(req, t0=0.01, t1=0.04, t2=0.05,
                              last_submit=0.0)
    assert rid == "req-7"
    (rec_record,) = rec.retained()
    assert rec_record["anomalies"] == ["deadline_miss", "overflow",
                                       "zero_weight"]
    bd = rec_record["breakdown"]
    assert bd["queue_wait"] == pytest.approx(0.01)
    assert bd["execute"] == pytest.approx(0.03)
    assert bd["scatter"] == pytest.approx(0.01)
    assert bd["total"] == pytest.approx(0.04)
    # additive identity: queue_wait + execute == total (scatter lands
    # after t_done; coalesce overlaps queue_wait)
    assert bd["queue_wait"] + bd["execute"] == pytest.approx(bd["total"])
    names = sorted(s["name"] for s in rec_record["spans"])
    assert names == ["coalesce", "execute", "queue_wait", "request",
                     "scatter"]
    # deterministic span ids: derived from the uid, never uuid4
    assert {s["span_id"] for s in rec_record["spans"]} \
        == {"req-7/r", "req-7/queue_wait", "req-7/coalesce",
            "req-7/execute", "req-7/scatter"}
    assert rec.snapshot()["anomalies"]["deadline_miss"] == 1


def test_recorder_retention_is_bitwise_deterministic():
    def run():
        rec = FlightRecorder(clock=FakeClock(), wall=None, host="h",
                             top_percentile=None)
        _observe_fast(rec, 1)
        req = _RecReq(2, deadline=0.01, t_submit=0.0, t_dispatch=0.005,
                      t_done=0.03)
        rec.observe_request(req, t0=0.005, t1=0.02, t2=0.03,
                            last_submit=0.0)
        rec.observe_shed(_RecReq(3, deadline=0.001, t_submit=0.0,
                                 t_done=0.002))
        return rec.state()

    assert run() == run()                   # replays bit-identically


def test_recorder_shed_retained_but_censored_from_histograms():
    rec = FlightRecorder(clock=FakeClock(5.0), wall=None, host="h",
                         top_percentile=None)
    rec.observe_shed(_RecReq(4, deadline=0.01, t_submit=0.0, t_done=0.02))
    (r,) = rec.retained()
    assert r["anomalies"] == ["shed", "deadline_miss"]
    assert r["breakdown"]["queue_wait"] == pytest.approx(0.02)
    # censoring: folding time-to-shed into the total histogram would
    # IMPROVE percentiles as traffic is dropped
    assert rec.state()["hists"]["total"]["count"] == 0
    assert rec.snapshot()["anomalies"]["shed"] == 1


def test_recorder_ring_evicts_fifo_and_counts_drops():
    rec = FlightRecorder(clock=FakeClock(), wall=None, host="h",
                         ring=2, top_percentile=None)
    for uid in (1, 2, 3):
        rec.observe_shed(_RecReq(uid, deadline=0.01, t_submit=0.0,
                                 t_done=0.02))
    assert [r["id"] for r in rec.retained()] == ["req-2", "req-3"]
    assert rec.dropped == 1                  # explicit, not silent
    assert rec.snapshot()["dropped"] == 1


def test_recorder_slow_class_reads_the_prior_window():
    rec = FlightRecorder(clock=FakeClock(), wall=None, host="h",
                         top_percentile=50.0, min_window=2)
    # below min_window the class is unarmed, however slow the request
    assert _observe_fast(rec, 1, total=5.0) is None
    assert _observe_fast(rec, 2, total=0.01) is None
    # armed: 5ms is below the prior-window p50 (~10ms) -> not slow
    assert _observe_fast(rec, 3, total=0.005) is None
    # 10x the prior-window p50 -> slow, retained
    rid = _observe_fast(rec, 4, total=6.0)
    assert rid == "req-4"
    assert rec.snapshot()["anomalies"]["slow"] == 1
    # top_percentile=None disables the class entirely
    off = FlightRecorder(clock=FakeClock(), wall=None, min_window=0,
                         top_percentile=None)
    for uid in range(8):
        assert _observe_fast(off, uid, total=float(uid + 1)) is None


def test_recorder_event_ring_bounded_with_drop_counter():
    rec = FlightRecorder(clock=FakeClock(1.0), wall=None, host="h",
                         event_ring=2)
    for i in range(3):
        rec.event(f"e{i}", severity="warning", data={"i": i})
    evs = rec.events()
    assert [e["kind"] for e in evs] == ["e1", "e2"]
    assert rec.events_dropped == 1


# ---------------------------------------------------------------------------
# SLO monitor: burn-rate arithmetic + edge-triggered breaches (ISSUE 9)
# ---------------------------------------------------------------------------


def _slo(clk, rec=None, windows=(10.0,), miss_target=0.05):
    return SloMonitor(clock=clk, windows=windows, recorder=rec,
                      targets={"deadline_miss_rate": miss_target,
                               "shed_rate": None,
                               "queue_depth_frac": None,
                               "ring_occupancy": None})


def test_slo_burn_rate_matches_hand_computed_rates():
    clk = FakeClock(0.0)
    mon = _slo(clk)
    mon.sample({"requests": 0, "deadline_miss": 0})
    clk.t = 10.0
    mon.sample({"requests": 200, "deadline_miss": 20})
    ev = mon.evaluate()
    w = ev["rates"]["deadline_miss_rate"]["10"]
    # hand-computed: 20 bad / 200 total = 10% observed, target 5% -> burn 2
    assert w["rate"] == pytest.approx(0.1)
    assert w["burn"] == pytest.approx(2.0)
    assert (w["bad"], w["total"]) == (20, 200)
    assert w["span_s"] == pytest.approx(10.0)
    assert ev["rates"]["deadline_miss_rate"]["windows_evaluated"] == 1
    (breach,) = ev["events"]
    assert breach["slo"] == "deadline_miss_rate" and breach["burn"] == 2.0


def test_slo_breach_events_are_edge_triggered():
    clk = FakeClock(0.0)
    rec = FlightRecorder(clock=clk, wall=None)
    mon = _slo(clk, rec)
    mon.sample({"requests": 0, "deadline_miss": 0})
    clk.t = 10.0
    mon.sample({"requests": 100, "deadline_miss": 50})
    assert len(mon.evaluate()["events"]) == 1     # crossing emits once
    assert mon.evaluate()["events"] == []         # sustained: no re-emit
    (ev,) = rec.events()
    assert ev["kind"] == "slo_breach" and ev["severity"] == "critical"
    # recovery clears the latch; a NEW burn re-emits
    clk.t = 20.0
    mon.sample({"requests": 300, "deadline_miss": 50})
    assert mon.evaluate()["events"] == []         # window rate back to 0
    clk.t = 30.0
    mon.sample({"requests": 500, "deadline_miss": 150})
    assert len(mon.evaluate()["events"]) == 1


def test_slo_needs_two_samples_spanning_a_window():
    clk = FakeClock(0.0)
    mon = _slo(clk)
    assert mon.evaluate()["rates"] == {}          # no samples at all
    mon.sample({"requests": 100, "deadline_miss": 100})
    assert mon.evaluate()["rates"] == {}          # one sample: no window


def test_slo_gauge_thresholds_and_events():
    clk = FakeClock(0.0)
    mon = SloMonitor(clock=clk, windows=(10.0,),
                     targets={"deadline_miss_rate": None, "shed_rate": None,
                              "queue_depth_frac": 0.9,
                              "ring_occupancy": 0.8})
    mon.sample({}, gauges={"queue_depth_frac": 0.95, "ring_occupancy": 0.5})
    ev = mon.evaluate()
    assert ev["gauges"]["queue_depth_frac"]["breaching"] is True
    assert ev["gauges"]["ring_occupancy"]["breaching"] is False
    assert [e["slo"] for e in ev["events"]] == ["queue_depth_frac"]


def test_fleet_epoch_staleness_derived_at_the_merge_point():
    assert fleet_epoch_events({"a": {"epoch": 3}, "b": {"epoch": 4}}) == []
    (ev,) = fleet_epoch_events({"a": {"epoch": 3}, "b": {"epoch": 5}})
    assert ev["slo"] == "epoch_staleness" and ev["window"] == "fleet"
    assert (ev["min_epoch"], ev["max_epoch"], ev["lag"]) == (3, 5, 2)
    assert ev["stale_hosts"] == ["a"]
    assert fleet_epoch_events({"a": {"epoch": 1}}) == []   # 1 host: no view


# ---------------------------------------------------------------------------
# tail-latency attribution: the decomposition identity (ISSUE 9)
# ---------------------------------------------------------------------------


def _fed_recorder(host="0", n_fast=100, n_slow=2):
    """A recorder fed ``n_fast`` 10ms in-SLO requests and ``n_slow``
    1s deadline-missers whose excess is ALL queue_wait."""
    rec = FlightRecorder(clock=FakeClock(), wall=None, host=host,
                         top_percentile=None)
    for uid in range(n_fast):
        _observe_fast(rec, uid, total=0.01)
    for uid in range(n_fast, n_fast + n_slow):
        req = _RecReq(uid, deadline=0.5, t_submit=0.0, t_dispatch=0.99,
                      t_done=1.0)
        rec.observe_request(req, t0=0.99, t1=1.0, t2=1.0, last_submit=0.0)
    return rec


def test_attribution_identity_decomposes_the_gap():
    attr = tail_attribution([_fed_recorder().state()])
    assert attr["n_total"] == 102 and attr["tail_n"] == 2
    assert not attr["tail_is_fallback"]
    gap = attr["gap_s"]
    assert gap > 0
    # THE acceptance identity: per-stage contributions sum to the gap
    # (well within the 15% bar — exact by construction with excess > 0)
    assert attr["attributed_s"] == pytest.approx(gap)
    assert attr["unattributed_s"] == pytest.approx(0.0)
    assert attr["share_basis"] == "excess"
    st = attr["stages"]
    # the tail's excess is queue_wait by construction
    assert st["queue_wait"]["share"] > 0.95
    assert st["queue_wait"]["attributed_s"] == pytest.approx(
        gap * st["queue_wait"]["share"])
    assert sum(s["share"] for n, s in st.items() if s["additive"]) \
        == pytest.approx(1.0)
    # overlay stages are reported but never attributed (they overlap)
    assert st["coalesce"]["attributed_s"] is None
    assert st["scatter"]["share"] is None


def test_attribution_fleet_merge_and_stall_block():
    reg = Registry()
    reg.observe("session/compact_stall_s", 0.25, exemplar="upd-1")
    reg.observe("serving/epoch_barrier_s", 0.1)
    attr = tail_attribution(
        [_fed_recorder("0").state(), _fed_recorder("1").state()],
        registry_state=reg.state())
    # two hosts merged bin-exactly: counts double, identity still exact
    assert attr["n_total"] == 204 and attr["tail_n"] == 4
    assert attr["attributed_s"] == pytest.approx(attr["gap_s"])
    # the stall block reads Registry.state()'s "hists" key
    stalls = attr["stalls"]
    assert stalls["session/compact_stall_s"]["count"] == 1
    assert stalls["session/compact_stall_s"]["max_s"] \
        == pytest.approx(0.25)
    assert stalls["serving/epoch_barrier_s"]["p99_s"] > 0


def test_attribution_tail_mean_basis_when_no_stage_exceeds_baseline():
    # bimodal population with NO retained record above the baselines:
    # excess-based shares would attribute nothing; the report degrades to
    # tail-mean mass so a positive gap still decomposes
    rec = FlightRecorder(clock=FakeClock(), wall=None,
                         top_percentile=None)
    for uid in range(60):
        _observe_fast(rec, uid, total=0.01)
    for uid in range(60, 100):
        _observe_fast(rec, uid, total=1.0)     # slow but in-SLO: not kept
    req = _RecReq(100, overflow=1, t_submit=0.0, t_dispatch=1e-4,
                  t_done=1.0)
    rec.observe_request(req, t0=1e-4, t1=3e-4, t2=3e-4, last_submit=0.0)
    attr = tail_attribution([rec.state()])
    assert attr["gap_s"] > 0 and attr["tail_n"] == 1
    assert attr["share_basis"] == "tail_mean"
    assert attr["attributed_s"] == pytest.approx(attr["gap_s"])


def test_attribution_empty_states_are_harmless():
    attr = tail_attribution([])
    assert attr["n_total"] == 0 and attr["gap_s"] == 0.0
    assert attr["attributed_s"] == 0.0 and attr["stalls"] == {}


# ---------------------------------------------------------------------------
# histogram exemplars: bucket -> trace links (ISSUE 9)
# ---------------------------------------------------------------------------


def test_histogram_exemplars_latest_wins_and_merge_is_bin_exact():
    a, b = Histogram(), Histogram()
    a.record(0.004, exemplar="t-old")
    a.record(0.0042, exemplar="t-new")      # same log bin: latest wins
    a.record(0.5, exemplar="t-big")
    b.record(0.0041, exemplar="t-peer")     # same bin as t-new, other host
    b.record(20.0, exemplar="t-huge")
    st = a.state()
    assert set(st["exemplars"].values()) == {"t-new", "t-big"}
    merged = Histogram.from_states([st, b.state()])
    ex = merged.state()["exemplars"]
    # bin-exact: the shared bin took the LAST-merged host's exemplar, the
    # disjoint bins kept their own
    assert set(ex.values()) == {"t-peer", "t-big", "t-huge"}
    # snapshot keys by upper bin edge (human-facing latency bound)
    snap_ex = merged.snapshot()["exemplars"]
    assert all(float(k) > 0 for k in snap_ex)
    # a pre-exemplar peer state (no "exemplars" key) still merges
    legacy = Histogram()
    legacy.record(1.0)
    merged.merge_state(legacy.state())
    assert merged.count == 6


def test_exemplars_absent_when_unused_and_not_in_prometheus_text():
    h = Histogram()
    h.record(0.01)
    assert "exemplars" not in h.state()
    assert "exemplars" not in h.snapshot()
    reg = Registry()
    reg.observe("serving/total_s", 0.01, exemplar="trace-xyz")
    text = reg.prometheus_text()
    # the 0.0.4 text format has no exemplar syntax: exposition unchanged
    assert "trace-xyz" not in text and "exemplar" not in text


def test_telemetry_exemplars_link_buckets_to_request_traces(
        traced_server_report):
    rep, _, reqs, _ = traced_server_report
    ex = rep["merge"]["hists"]["total"].get("exemplars", {})
    assert ex, "total-latency histogram lost its exemplars"
    assert set(ex.values()) <= {r.trace_id for r in reqs}


# ---------------------------------------------------------------------------
# Prometheus exposition: exact-format regression (ISSUE 9)
# ---------------------------------------------------------------------------


def test_prometheus_exposition_exact_format():
    reg = Registry()
    reg.inc("serving/batches", 3)
    reg.set("ingest/ring_occupancy", 0.5)
    reg.observe("serving/queue_wait_s", 0.004)
    assert reg.prometheus_text() == (
        "# HELP aidw_serving_batches_total cumulative count of "
        "serving/batches\n"
        "# TYPE aidw_serving_batches_total counter\n"
        "aidw_serving_batches_total 3\n"
        "# HELP aidw_ingest_ring_occupancy gauge ingest/ring_occupancy\n"
        "# TYPE aidw_ingest_ring_occupancy gauge\n"
        "aidw_ingest_ring_occupancy 0.5\n"
        "# HELP aidw_serving_queue_wait_s summary of serving/queue_wait_s "
        "in seconds\n"
        "# TYPE aidw_serving_queue_wait_s summary\n"
        'aidw_serving_queue_wait_s{quantile="0.5"} 0.004\n'
        'aidw_serving_queue_wait_s{quantile="0.95"} 0.004\n'
        'aidw_serving_queue_wait_s{quantile="0.99"} 0.004\n'
        "aidw_serving_queue_wait_s_sum 0.004\n"
        "aidw_serving_queue_wait_s_count 1\n"
        "aidw_serving_queue_wait_s_max 0.004\n")


def test_every_prometheus_family_has_help_and_type(traced_server_report):
    _, _, _, text = traced_server_report
    lines = text.splitlines()
    families = {ln.split()[0].split("{")[0]
                for ln in lines if ln and not ln.startswith("#")}
    helped = {ln.split()[2] for ln in lines if ln.startswith("# HELP")}
    typed = {ln.split()[2] for ln in lines if ln.startswith("# TYPE")}
    for fam in families:
        base = fam
        for suffix in ("_sum", "_count", "_max"):
            if base.endswith(suffix) and base.removesuffix(suffix) in typed:
                base = base.removesuffix(suffix)
                break
        assert base in typed, f"{fam} has no # TYPE"
        assert base in helped, f"{fam} has no # HELP"


# ---------------------------------------------------------------------------
# compaction-stall histogram: the FIFO-barrier hold (ISSUE 9 satellite)
# ---------------------------------------------------------------------------


def test_compact_stall_histogram_covers_the_fifo_hold():
    pts = spatial_points(2048, seed=0)
    with AsyncAidwServer(pts, max_batch=512,
                         query_domain=spatial_queries(256, seed=1)) as srv:
        srv.submit(spatial_queries(32, seed=2))
        srv.compact(timeout=300)
        srv.flush(timeout=300)
        hists = srv.report()["stages"]["histograms"]
        stall = hists["session/compact_stall_s"]
        assert stall["count"] == 1
        # the stall covers the WHOLE hold (enqueue -> applied), so it can
        # never undershoot the device fold wall the session records
        if "session/compact_s" in hists and hists["session/compact_s"][
                "count"]:
            assert stall["max_s"] >= hists["session/compact_s"]["max_s"] \
                - 1e-6
