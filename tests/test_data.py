"""Data pipeline: determinism, host sharding, learnability, prefetch."""

from __future__ import annotations

import numpy as np

from repro.data.pipeline import (LMStreamConfig, Prefetcher, lm_batch,
                                 spatial_points, spatial_queries)


CFG = LMStreamConfig(vocab=97, seq_len=16, global_batch=8, seed=3)


def test_lm_batch_deterministic():
    a = lm_batch(CFG, step=5)
    b = lm_batch(CFG, step=5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = lm_batch(CFG, step=6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_lm_batch_host_sharding_partitions_global_batch():
    full = lm_batch(CFG, step=2, host_index=0, n_hosts=1)
    shards = [lm_batch(CFG, step=2, host_index=h, n_hosts=4) for h in range(4)]
    assert all(s["tokens"].shape == (2, 16) for s in shards)
    # shards are mutually distinct and deterministic
    again = lm_batch(CFG, step=2, host_index=2, n_hosts=4)
    np.testing.assert_array_equal(shards[2]["tokens"], again["tokens"])


def test_lm_batch_is_learnable_pattern():
    b = lm_batch(CFG, step=0)
    toks, labs = b["tokens"], b["labels"]
    np.testing.assert_array_equal(toks[:, 1:], labs[:, :-1])  # shifted
    stride = (labs[:, 0] - toks[:, 0]) % CFG.vocab
    for i in range(CFG.seq_len - 1):
        np.testing.assert_array_equal((toks[:, i] + stride) % CFG.vocab,
                                      toks[:, i + 1])


def test_spatial_generators():
    pts = spatial_points(500, seed=1)
    assert pts.shape == (500, 3)
    assert (pts[:, :2] >= 0).all() and (pts[:, :2] <= 1).all()
    cl = spatial_points(500, seed=1, clustered=True)
    # clustered data has lower spread of pairwise NN distances
    assert cl[:, :2].std() < pts[:, :2].std()
    qs = spatial_queries(100)
    assert qs.shape == (100, 2)


def test_prefetcher_orders_steps():
    seen = []
    f = Prefetcher(lambda s: {"step": s}, start_step=4, depth=2)
    for _ in range(5):
        seen.append(f.next()["step"])
    f.close()
    assert seen == [4, 5, 6, 7, 8]
