"""AIDW math (Eqs. 2-6) + end-to-end pipeline properties vs the serial oracle."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
from hypcompat import given, settings, st  # guarded: skips, never dies, without hypothesis

from repro.core import (AidwConfig, InterpolationSession, adaptive_alpha,
                        aidw_improved, aidw_original, alpha_from_membership,
                        fuzzy_membership, idw_standard, weighted_interpolate)

import sys
from pathlib import Path
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
from benchmarks.serial_ref import serial_aidw  # noqa: E402


def test_fuzzy_membership_endpoints():
    assert float(fuzzy_membership(jnp.float32(-1.0))) == 0.0
    assert float(fuzzy_membership(jnp.float32(0.0))) == 0.0
    assert float(fuzzy_membership(jnp.float32(2.0))) == 1.0
    assert float(fuzzy_membership(jnp.float32(5.0))) == 1.0
    assert float(fuzzy_membership(jnp.float32(1.0))) == pytest.approx(0.5)


@settings(max_examples=50, deadline=None)
@given(st.floats(-1.0, 3.0), st.floats(-1.0, 3.0))
def test_fuzzy_membership_monotone(a, b):
    lo, hi = min(a, b), max(a, b)
    assert float(fuzzy_membership(jnp.float32(lo))) <= \
        float(fuzzy_membership(jnp.float32(hi))) + 1e-6


def test_alpha_triangular_breakpoints():
    alphas = (0.5, 1.0, 2.0, 3.0, 4.0)
    for mu, expect in [(0.0, 0.5), (0.1, 0.5), (0.2, 0.75), (0.3, 1.0),
                       (0.4, 1.5), (0.5, 2.0), (0.6, 2.5), (0.7, 3.0),
                       (0.8, 3.5), (0.9, 4.0), (1.0, 4.0)]:
        got = float(alpha_from_membership(jnp.float32(mu), alphas))
        assert got == pytest.approx(expect, abs=1e-5), mu


@settings(max_examples=50, deadline=None)
@given(st.floats(0.0, 1.0))
def test_alpha_within_levels(mu):
    a = float(alpha_from_membership(jnp.float32(mu)))
    assert 0.5 - 1e-6 <= a <= 4.0 + 1e-6


def test_adaptive_alpha_clustered_vs_sparse():
    # dense neighborhoods (small r_obs) -> small R -> small alpha;
    # sparse neighborhoods -> large R -> alpha saturates high.
    a_dense = float(adaptive_alpha(jnp.float32(0.001), 1000.0, 1.0))
    a_sparse = float(adaptive_alpha(jnp.float32(0.2), 1000.0, 1.0))
    assert a_dense < a_sparse
    assert a_sparse == pytest.approx(4.0)


def test_pipelines_agree(spatial_data):
    pts, qs = spatial_data
    r_impr = aidw_improved(pts, qs)
    r_orig = aidw_original(pts, qs)
    np.testing.assert_allclose(np.asarray(r_impr.values),
                               np.asarray(r_orig.values), rtol=1e-4, atol=1e-5)
    assert r_impr.overflow == 0


def test_matches_serial_oracle(spatial_data):
    pts, qs = spatial_data
    got = np.asarray(aidw_improved(pts, qs[:128]).values)
    want = serial_aidw(pts.astype(np.float64), qs[:128].astype(np.float64))
    np.testing.assert_allclose(got, want, atol=5e-4)


def test_prediction_bounded_by_data(spatial_data):
    pts, qs = spatial_data
    vals = np.asarray(aidw_improved(pts, qs).values)
    assert vals.min() >= pts[:, 2].min() - 1e-4   # convex combination
    assert vals.max() <= pts[:, 2].max() + 1e-4


def test_exact_hit_returns_data_value(spatial_data):
    pts, _ = spatial_data
    qs_on = pts[:50, :2].copy()
    vals = np.asarray(aidw_improved(pts, qs_on).values)
    np.testing.assert_allclose(vals, pts[:50, 2], atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(st.integers(50, 400), st.integers(0, 99))
def test_bounds_property(n, seed):
    rng = np.random.default_rng(seed)
    pts = rng.random((n, 3)).astype(np.float32)
    qs = rng.random((32, 2)).astype(np.float32)
    vals = np.asarray(aidw_improved(pts, qs, AidwConfig(k=min(10, n))).values)
    assert np.isfinite(vals).all()
    assert (vals >= pts[:, 2].min() - 1e-4).all()
    assert (vals <= pts[:, 2].max() + 1e-4).all()


def test_idw_standard_constant_alpha(spatial_data):
    pts, qs = spatial_data
    v2 = np.asarray(idw_standard(pts, qs[:64], alpha=2.0))
    v4 = np.asarray(idw_standard(pts, qs[:64], alpha=4.0))
    assert np.isfinite(v2).all() and np.isfinite(v4).all()
    assert not np.allclose(v2, v4)


def test_aidw_more_accurate_than_idw():
    """The paper's motivation (via Lu & Wong): adaptive alpha beats fixed."""
    from repro.data.pipeline import spatial_points, spatial_queries, spatial_surface

    pts = spatial_points(4096, seed=5)
    qs = spatial_queries(1024, seed=6)
    truth = spatial_surface(qs[:, 0], qs[:, 1])
    aidw = np.asarray(aidw_improved(pts, qs).values)
    idw = np.asarray(idw_standard(pts, qs, alpha=2.0))
    rmse = lambda a: float(np.sqrt(np.mean((a - truth) ** 2)))
    assert rmse(aidw) < rmse(idw)


# ---------------------------------------------------------------------------
# zero-weight guard (the PR 6 bugfix): a query so far from all data that
# every f32 weight underflows to zero must yield the 0.0 sentinel + mask,
# never NaN — in the jnp path, the Pallas path, and the session end to end.
# ---------------------------------------------------------------------------


def _far_batch(qs, n_near=7):
    far = np.array([[1e18, 1e18]], np.float32)
    return np.concatenate([np.asarray(qs[:n_near]), far]).astype(np.float32)


def test_weighted_interpolate_far_query_no_nan(spatial_data):
    """Direct Eq. (1): the guarded division never emits NaN, and guarded
    results stay bitwise the unguarded ones wherever the sum is nonzero."""
    from repro.core import aidw as A

    pts, qs = spatial_data
    batch = jnp.asarray(_far_batch(qs))
    p, z = jnp.asarray(pts[:, :2]), jnp.asarray(pts[:, 2])
    out = weighted_interpolate(batch, p, z, 4.0)
    assert not np.isnan(np.asarray(out)).any()
    assert np.asarray(out)[-1] == A.ZERO_WEIGHT_SENTINEL
    swz, sw = A.weighted_partial_sums(batch, p, z, jnp.full((8,), 4.0))
    vals, mask = A.guarded_values(swz, sw)
    assert np.asarray(mask)[-1] and not np.asarray(mask)[:-1].any()
    near = ~np.asarray(mask)
    assert np.array_equal(np.asarray(vals)[near],
                          np.asarray(swz / sw)[near])   # guard is a no-op


@pytest.mark.parametrize("stage2,fused", [("naive", False), ("tiled", False),
                                          ("tiled", True)])
def test_session_far_query_no_nan(spatial_data, stage2, fused):
    """End to end through every global Stage-2 route (jnp, Pallas tiled,
    fused alpha-in-kernel): sentinel value + raised zero_weight_mask."""
    pts, qs = spatial_data
    cfg = AidwConfig(stage2=stage2, fused=fused, interpret=True,
                     tile_q=128, tile_d=256)
    sess = InterpolationSession(pts, cfg, query_domain=qs)
    res = sess.query(_far_batch(qs))
    vals = np.asarray(res.values)
    mask = np.asarray(res.zero_weight_mask)
    assert not np.isnan(vals).any()
    assert mask[-1] and vals[-1] == 0.0
    assert not mask[:-1].any()
