"""Optional-hypothesis guard shared by the test modules.

Minimal containers ship without dev dependencies; a bare
``from hypothesis import given`` at module scope then kills pytest at
COLLECTION time, taking every non-property test in the module down with it.
Importing the three names from here instead gives:

* hypothesis installed (CI, ``pip install -r requirements-dev.txt``):
  the real ``given``/``settings``/``st`` — property tests run normally.
* hypothesis missing: stand-ins that turn each ``@given`` test into an
  individual runtime skip (the per-test equivalent of
  ``pytest.importorskip("hypothesis")``), while plain tests in the same
  module still collect and run.
"""

from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # minimal container — see requirements-dev.txt
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            # plain *args signature: pytest must not mistake the wrapped
            # test's parameters for fixtures (so no functools.wraps)
            def skipper(*args, **kwargs):
                pytest.skip(
                    "hypothesis not installed "
                    "(pip install -r requirements-dev.txt)")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _AnyStrategy:
        """st.floats/st.integers/... placeholders; args are never drawn."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()
