"""nn substrate oracles: attention chunking, MoE dispatch, SSD scan."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.nn.attention import gqa_attention
from repro.nn.moe import moe_apply, moe_capacity
from repro.nn.ssm import SsmDims, causal_conv, ssd_chunked, ssd_decode_step


def test_attention_chunked_equals_unchunked(rng):
    B, S, Hq, Hkv, dh = 2, 48, 8, 2, 16
    q = jnp.asarray(rng.normal(0, 1, (B, S, Hq, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, dh)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    full = gqa_attention(q, k, v, q_pos=pos, k_pos=pos, causal=True, q_chunk=10**9)
    for chunk in (8, 16, 17):  # incl. non-dividing chunk (padding path)
        out = gqa_attention(q, k, v, q_pos=pos, k_pos=pos, causal=True,
                            q_chunk=chunk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(full), atol=2e-6)


def test_attention_causality(rng):
    B, S, H, dh = 1, 16, 2, 8
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, H, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, H, dh)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    base = gqa_attention(q, k, v, q_pos=pos, k_pos=pos, causal=True)
    k2 = k.at[:, 8:].set(jnp.asarray(rng.normal(0, 1, (B, 8, H, dh)), jnp.float32))
    v2 = v.at[:, 8:].set(jnp.asarray(rng.normal(0, 1, (B, 8, H, dh)), jnp.float32))
    out = gqa_attention(q, k2, v2, q_pos=pos, k_pos=pos, causal=True)
    np.testing.assert_allclose(np.asarray(out[:, :8]), np.asarray(base[:, :8]),
                               atol=1e-6)  # prefix unaffected by future keys
    assert not np.allclose(np.asarray(out[:, 9:]), np.asarray(base[:, 9:]))


def test_attention_kv_validity_mask(rng):
    B, S, H, dh = 2, 12, 2, 8
    q = jnp.asarray(rng.normal(0, 1, (B, 1, H, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, H, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, H, dh)), jnp.float32)
    qp = jnp.full((B, 1), S - 1, jnp.int32)
    kp = jnp.broadcast_to(jnp.arange(S), (B, S))
    mask = jnp.asarray(np.random.default_rng(1).random((B, S)) > 0.5)
    mask = mask.at[:, -1].set(True)
    out_masked = gqa_attention(q, k, v, q_pos=qp, k_pos=kp, k_valid=mask,
                               causal=True)
    # equivalent: physically remove masked keys (per batch row)
    for b in range(B):
        sel = np.asarray(mask[b])
        ref = gqa_attention(q[b:b+1], k[b:b+1, sel], v[b:b+1, sel],
                            q_pos=qp[b:b+1], k_pos=kp[b:b+1, sel], causal=True)
        np.testing.assert_allclose(np.asarray(out_masked[b]),
                                   np.asarray(ref[0]), atol=1e-6)


def test_moe_matches_dense_reference(rng):
    T, Dm, E, F, topk = 64, 16, 4, 32, 2
    x = jnp.asarray(rng.normal(0, 1, (2, 32, Dm)), jnp.float32)
    wr = jnp.asarray(rng.normal(0, 0.5, (Dm, E)), jnp.float32)
    wg = jnp.asarray(rng.normal(0, 0.1, (E, Dm, F)), jnp.float32)
    wu = jnp.asarray(rng.normal(0, 0.1, (E, Dm, F)), jnp.float32)
    wd = jnp.asarray(rng.normal(0, 0.1, (E, F, Dm)), jnp.float32)
    out = moe_apply(x, wr, wg, wu, wd, top_k=topk, capacity_factor=16.0)

    xt = np.asarray(x).reshape(T, Dm)
    pr = jax.nn.softmax(jnp.asarray(xt @ np.asarray(wr)), -1)
    w, eidx = jax.lax.top_k(pr, topk)
    w = np.asarray(w / w.sum(-1, keepdims=True))
    eidx = np.asarray(eidx)
    ref = np.zeros((T, Dm), np.float32)
    for t in range(T):
        for j in range(topk):
            e = eidx[t, j]
            g = xt[t] @ np.asarray(wg)[e]
            u = xt[t] @ np.asarray(wu)[e]
            ref[t] += w[t, j] * ((g / (1 + np.exp(-g))) * u) @ np.asarray(wd)[e]
    np.testing.assert_allclose(np.asarray(out).reshape(T, Dm), ref, atol=2e-5)


def test_moe_capacity_dropping(rng):
    """With capacity_factor << 1 most assignments drop -> output shrinks."""
    x = jnp.asarray(rng.normal(0, 1, (2, 32, 16)), jnp.float32)
    wr = jnp.asarray(rng.normal(0, 0.5, (16, 4)), jnp.float32)
    we = [jnp.asarray(rng.normal(0, 0.1, s), jnp.float32)
          for s in [(4, 16, 32), (4, 16, 32), (4, 32, 16)]]
    full = moe_apply(x, wr, *we, top_k=2, capacity_factor=16.0)
    tight = moe_apply(x, wr, *we, top_k=2, capacity_factor=0.25)
    assert float(jnp.abs(tight).sum()) < float(jnp.abs(full).sum())


def test_moe_capacity_rounding():
    assert moe_capacity(1024, 8, 2, 1.25) % 8 == 0
    assert moe_capacity(10, 128, 8, 1.0) >= 8


def _ssd_seq_ref(xh, Bg, Cg, dt, A, D, dims):
    B, S, H, P = xh.shape
    N = dims.d_state
    Bh = np.repeat(np.asarray(Bg), H // dims.n_groups, 2)
    Ch = np.repeat(np.asarray(Cg), H // dims.n_groups, 2)
    h = np.zeros((B, H, N, P))
    ys = []
    for t in range(S):
        a = np.exp(np.asarray(dt)[:, t] * np.asarray(A))
        h = a[:, :, None, None] * h + np.einsum(
            "bh,bhn,bhp->bhnp", np.asarray(dt)[:, t], Bh[:, t], np.asarray(xh)[:, t])
        ys.append(np.einsum("bhn,bhnp->bhp", Ch[:, t], h)
                  + np.asarray(D)[None, :, None] * np.asarray(xh)[:, t])
    return np.stack(ys, 1), h


@pytest.mark.parametrize("chunk", [8, 16, 64, 60])
def test_ssd_chunked_vs_sequential(rng, chunk):
    B, S, H, P, N, G = 2, 60, 4, 8, 16, 1
    dims = SsmDims(32, H * P, H, P, N, G, 4)
    xh = jnp.asarray(rng.normal(0, 1, (B, S, H, P)), jnp.float32)
    Bg = jnp.asarray(rng.normal(0, 1, (B, S, G, N)), jnp.float32)
    Cg = jnp.asarray(rng.normal(0, 1, (B, S, G, N)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2, (H,)), jnp.float32)
    D = jnp.asarray(rng.normal(0, 1, (H,)), jnp.float32)
    y, hf = ssd_chunked(xh, Bg, Cg, dt, A, D, dims, chunk=chunk)
    yr, hr = _ssd_seq_ref(xh, Bg, Cg, dt, A, D, dims)
    np.testing.assert_allclose(np.asarray(y), yr, atol=5e-5)
    np.testing.assert_allclose(np.asarray(hf), hr, atol=5e-5)


def test_ssd_decode_continues_prefill(rng):
    B, S, H, P, N, G = 2, 33, 4, 8, 16, 1
    dims = SsmDims(32, H * P, H, P, N, G, 4)
    mk = lambda s: jnp.asarray(rng.normal(0, 1, s), jnp.float32)
    xh, Bg, Cg = mk((B, S, H, P)), mk((B, S, G, N)), mk((B, S, G, N))
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2, (H,)), jnp.float32)
    D = mk((H,))
    _, h1 = ssd_chunked(xh[:, :-1], Bg[:, :-1], Cg[:, :-1], dt[:, :-1],
                        A, D, dims, chunk=16)
    yd, hd = ssd_decode_step(xh[:, -1:], Bg[:, -1:], Cg[:, -1:], dt[:, -1:],
                             A, D, h1, dims)
    yf, hf = ssd_chunked(xh, Bg, Cg, dt, A, D, dims, chunk=16)
    np.testing.assert_allclose(np.asarray(yd[:, 0]), np.asarray(yf[:, -1]), atol=5e-5)
    np.testing.assert_allclose(np.asarray(hd), np.asarray(hf), atol=5e-5)


def test_causal_conv_decode_matches_full(rng):
    B, S, C, K = 2, 20, 6, 4
    x = jnp.asarray(rng.normal(0, 1, (B, S, C)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 1, (K, C)), jnp.float32)
    b = jnp.asarray(rng.normal(0, 1, (C,)), jnp.float32)
    full, _ = causal_conv(x, w, b)
    y1, st = causal_conv(x[:, :-1], w, b)
    y2, _ = causal_conv(x[:, -1:], w, b, state=st)
    np.testing.assert_allclose(np.asarray(y2[:, 0]), np.asarray(full[:, -1]),
                               atol=1e-5)
