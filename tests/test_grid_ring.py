"""Grid-aware sharded Stage 1 (PR 5): slab/halo correctness, the k-way
merge's equivalence with the replicated grid search (the halo's whole job,
exercised hardest by queries NEAR slab boundaries), delta updates staying
element-identical to a fresh plan, the analytic candidate census, and the
8-device grid-ring session (slow, subprocess — the CI mesh-suite gate).
"""

from __future__ import annotations

import numpy as np
import pytest

import jax.numpy as jnp

from conftest import run_multidevice
from hypcompat import given, settings, st
from repro.core import grid as G
from repro.core import knn as K
from repro.core.slab import SlabPartition
from repro.data.pipeline import spatial_points, spatial_queries


def _merge_slabs(spec, part, queries, k, max_level, window=256):
    """Host-side mirror of the ring step: per-slab slab_knn + running
    top-k merge + the overflow-excuse certificate."""
    dev = part.device_tables()
    n = queries.shape[0]
    topk = np.full((n, k), np.inf, np.float32)
    excuse = np.full(n, np.inf, np.float32)
    cand = np.zeros(n, np.int64)
    for s in range(part.p):
        res = K.slab_knn(
            spec, part.rps, part.halo, jnp.asarray(dev["cell_start"][s]),
            jnp.asarray(dev["sx"][s]), jnp.asarray(dev["sy"][s]),
            jnp.zeros(dev["sx"].shape[1], jnp.int32),
            jnp.int32(dev["row_lo"][s]), jnp.asarray(queries), k, max_level,
            window, 4096)
        topk = np.sort(np.concatenate([topk, np.asarray(res.d2)], 1), 1)[:, :k]
        excuse = np.minimum(excuse, np.asarray(res.excuse))
        cand += np.asarray(res.n_candidates)
    overflow = np.sqrt(np.maximum(topk[:, -1], 0.0)) > excuse
    return topk, overflow, cand


def _boundary_queries(spec, p, n, rng):
    """Queries concentrated within a couple of cells of slab boundaries."""
    from repro.core.slab import slab_rows

    rps = slab_rows(spec, p)
    cw = spec.cell_width
    edges = [spec.min_y + s * rps * cw for s in range(1, p)]
    ys = rng.choice(edges, n) + rng.uniform(-2 * cw, 2 * cw, n)
    xs = spec.min_x + rng.uniform(0, spec.n_cols * cw, n)
    return np.stack([xs, ys], 1).astype(np.float32)


def test_slab_merge_matches_grid_knn_fixed():
    """Fixed-seed exactness: merged per-slab top-k == replicated grid_knn
    d2 VALUES on every certified query, incl. boundary-hugging queries."""
    rng = np.random.default_rng(0)
    pts = spatial_points(4096, seed=0)
    qs = np.concatenate([spatial_queries(256, seed=1),
                         _boundary_queries(
                             G.plan_grid(pts[:, :2]), 4, 256, rng)])
    spec = G.plan_grid(pts[:, :2], qs)
    table = G.bin_points(spec, jnp.array(pts[:, 0]), jnp.array(pts[:, 1]),
                         jnp.array(pts[:, 2]))
    k = 15
    max_level = K.auto_max_level(spec, pts.shape[0], k)
    ref = K.grid_knn(spec, table, jnp.array(qs), k, max_level, 256, 4096,
                     True)
    part = SlabPartition.build(spec, pts, 4, halo=max_level)
    topk, overflow, cand = _merge_slabs(spec, part, qs, k, max_level)
    ok = ~np.asarray(ref.overflow) & ~overflow
    assert ok.mean() > 0.95                       # window generous here
    assert np.array_equal(np.sort(np.asarray(ref.d2), 1)[ok], topk[ok])
    # the O(window) claim: way fewer candidate distances than brute m
    assert cand.mean() < pts.shape[0] / 10


@settings(max_examples=25, deadline=None)
@given(st.integers(100, 900), st.integers(2, 6), st.integers(0, 10_000),
       st.integers(1, 20))
def test_slab_merge_matches_brute_near_boundaries(m, p, seed, k):
    """Property: for boundary-hugging queries, the merged slab search
    equals brute-force kNN wherever the merge certifies exactness."""
    rng = np.random.default_rng(seed)
    xy = rng.random((m, 2)).astype(np.float32)
    pts = np.concatenate([xy, rng.random((m, 1))], 1).astype(np.float32)
    spec = G.plan_grid(xy)
    qs = _boundary_queries(spec, p, 24, rng)
    max_level = K.auto_max_level(spec, m, k)
    part = SlabPartition.build(spec, pts, p, halo=max_level)
    topk, overflow, _ = _merge_slabs(spec, part, qs, k, max_level,
                                     window=512)
    bd2, _ = K.brute_knn(jnp.array(xy), jnp.array(qs), k)
    want = np.sort(np.asarray(bd2), 1)
    certified = ~overflow
    assert certified.any()
    np.testing.assert_allclose(topk[certified],
                               want[certified][:, :topk.shape[1]],
                               atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(200, 1200), st.integers(2, 5), st.integers(0, 10_000))
def test_slab_partition_delta_element_identical(m, p, seed):
    """apply_delta + compact == fresh build of the reconstructed dataset,
    every array of every slab table (the grid-ring delta-update contract:
    deltas tier through the hot rings / tombstones, and compaction folds
    them back to exactly the fresh-build arrays)."""
    rng = np.random.default_rng(seed)
    pts = np.concatenate([rng.random((m, 2)), rng.random((m, 1))],
                         1).astype(np.float32)
    spec = G.plan_grid(pts[:, :2])
    part = SlabPartition.build(spec, pts, p, halo=3)
    cur = pts.copy()
    for it in range(2):
        n_del = rng.integers(0, max(cur.shape[0] // 5, 1))
        dels = rng.choice(cur.shape[0], n_del, replace=False)
        ins = np.concatenate([rng.random((7, 2)), rng.random((7, 1))],
                             1).astype(np.float32)
        part.apply_delta(inserts=ins, deletes=dels)
        keep = np.ones(cur.shape[0], bool)
        keep[dels] = False
        cur = np.concatenate([cur[keep], ins], 0)
    part.compact()                      # fold rings + purge tombstones
    assert part.ring_size() == 0 and part.tombstone_frac() == 0.0
    fresh = SlabPartition.build(spec, cur, p, halo=3)
    assert part.m == fresh.m == cur.shape[0]
    for s in range(p):
        for name in ("sx", "sy", "sz", "cell_start", "order"):
            a = np.asarray(getattr(part.tables[s], name))
            b = np.asarray(getattr(fresh.tables[s], name))
            assert a.shape == b.shape and np.array_equal(a, b), (s, name)
        assert np.array_equal(part.members[s], fresh.members[s])


def test_ring_stage1_census_reduction():
    """The analytic census confirms the candidate-count drop at fixed
    (m, P): O(window) grid candidates vs O(m) brute."""
    from repro.launch.analytic import aidw_ring_stage1_census

    c = aidw_ring_stage1_census(100_000, 8)
    assert c.brute_candidates == 100_000
    assert c.grid_candidates <= 256                # bounded by the window
    assert c.reduction > 100                       # >= two orders at 100k
    small = aidw_ring_stage1_census(4096, 8)
    assert small.reduction > 10


def test_grid_ring_session_single_device_mesh():
    """A 1-device mesh degenerates to one slab covering the whole grid —
    the grid-ring session must still serve, delta-update incrementally,
    and stay element-identical to a fresh plan after churn."""
    import jax

    from repro.core import InterpolationSession
    from repro.core.jax_compat import make_auto_mesh

    mesh = make_auto_mesh((len(jax.devices()),), ("q",))
    pts = spatial_points(2048, seed=0)
    qs = spatial_queries(333, seed=1)
    sess = InterpolationSession(pts, query_domain=qs, mesh=mesh,
                                layout="grid_ring")
    single = InterpolationSession(pts, query_domain=qs)
    a, b = single.query(qs), sess.query(qs)
    assert np.array_equal(np.asarray(a.r_obs), np.asarray(b.r_obs))
    assert np.array_equal(np.asarray(a.alpha), np.asarray(b.alpha))
    assert np.abs(np.asarray(a.values) - np.asarray(b.values)).max() < 1e-4

    rng = np.random.default_rng(3)
    dels = rng.choice(2048, 40, replace=False)
    ins = spatial_points(40, seed=9)
    sess.update(inserts=ins, deletes=dels)
    assert sess.stats["delta_updates"] == 1
    assert sess.stats["stage1_builds"] == 1        # executor survived
    assert sess.stats["ring_points"] == 40         # inserts tiered in-ring
    assert sess.stats["staged_bytes"] > 0
    keep = np.ones(2048, bool)
    keep[dels] = False
    fresh = InterpolationSession(
        np.concatenate([pts[keep], ins.astype(pts.dtype)], 0),
        query_domain=qs, mesh=mesh, layout="grid_ring")
    # ring-resident: within the documented 1-ulp FMA caveat of fresh
    np.testing.assert_allclose(np.asarray(sess.query(qs).values),
                               np.asarray(fresh.query(qs).values),
                               rtol=1e-6, atol=1e-6)
    # post-compaction: BITWISE a fresh plan's (same m -> same GridSpec)
    sess.compact()
    assert sess.stats["ring_points"] == 0
    assert sess.stats["compactions"] == 1
    assert np.array_equal(np.asarray(sess.query(qs).values),
                          np.asarray(fresh.query(qs).values))


# ---------------------------------------------------------------------------
# multi-device (slow: subprocess with 8 forced host devices)
# ---------------------------------------------------------------------------

pytestmark_slow = pytest.mark.slow


@pytest.mark.slow
def test_grid_ring_session_matches_replicated_8dev():
    """Acceptance: on an 8-device mesh the grid-ring session serves within
    documented tolerance of the replicated layout — bit-identical
    r_obs/alpha on certified queries, ~1e-5 values — at O(window)
    candidates per query, and an incremental delta stays element-identical
    to a fresh plan."""
    out = run_multidevice("""
import numpy as np, jax
from repro.core import InterpolationSession
from repro.core.jax_compat import make_auto_mesh
from repro.data.pipeline import spatial_points, spatial_queries

pts = spatial_points(16384, seed=0)
qs = spatial_queries(1000, seed=1)       # odd size: padded buckets
mesh = make_auto_mesh((8,), ("q",))
single = InterpolationSession(pts, query_domain=qs)
sess = InterpolationSession(pts, query_domain=qs, mesh=mesh,
                            layout="grid_ring")
assert sess.sharded_plan.layout == "grid_ring"
a, b = single.query(qs), sess.query(qs)
assert np.array_equal(np.asarray(a.r_obs), np.asarray(b.r_obs))
assert np.array_equal(np.asarray(a.alpha), np.asarray(b.alpha))
err = np.abs(np.asarray(a.values) - np.asarray(b.values)).max()
assert err < 1e-4, err
cand = np.asarray(sess.last_stage1_candidates)
assert cand.mean() < pts.shape[0] / 20, cand.mean()   # O(window) not O(m)

# brute ring on the same mesh: tolerance only (never bitwise)
ring = InterpolationSession(pts, query_domain=qs, mesh=mesh, layout="ring")
rerr = np.abs(np.asarray(ring.query(qs).values)
              - np.asarray(a.values)).max()
assert rerr < 1e-4, rerr

# incremental delta: inserts tier through the hot rings (O(Delta) staging),
# deletes tombstone in place; ring-resident answers stay within 1 ulp of
# the physically-rebinned single session, and COMPACTION restores
# element-identity with a fresh plan (bitwise values, same m -> same spec)
dels = np.random.default_rng(3).choice(16384, 160, replace=False)
ins = spatial_points(160, seed=9)
for s in (single, sess):
    s.update(inserts=ins, deletes=dels)
assert sess.stats["delta_updates"] == 1 and sess.stats["stage1_builds"] == 1
assert sess.stats["ring_points"] == 160
a2, b2 = single.query(qs), sess.query(qs)
np.testing.assert_allclose(np.asarray(a2.r_obs), np.asarray(b2.r_obs),
                           rtol=1e-6, atol=1e-6)
sess.compact()
assert sess.stats["ring_points"] == 0 and sess.stats["compactions"] == 1
b2 = sess.query(qs)
assert np.array_equal(np.asarray(a2.r_obs), np.asarray(b2.r_obs))
keep = np.ones(16384, bool); keep[dels] = False
fresh = InterpolationSession(
    np.concatenate([pts[keep], ins.astype(pts.dtype)], 0),
    query_domain=qs, mesh=mesh, layout="grid_ring")
assert np.array_equal(np.asarray(b2.values), np.asarray(fresh.query(qs).values))
print("grid-ring-8dev-ok", float(cand.mean()))
""")
    assert "grid-ring-8dev-ok" in out


@pytest.mark.slow
def test_grid_ring_async_serving_8dev():
    """The async server can run the grid-ring layout: same results as the
    synchronous grid-ring session, churn serialized through the FIFO."""
    out = run_multidevice("""
import numpy as np, jax
from repro.core import InterpolationSession
from repro.core.jax_compat import make_auto_mesh
from repro.data.pipeline import spatial_points, spatial_queries
from repro.serving import AsyncAidwServer

pts = spatial_points(8192, seed=0)
qd = spatial_queries(1024, seed=1)
mesh = make_auto_mesh((8,), ("q",))
qs = [spatial_queries(96, seed=10 + i) for i in range(6)]
sess = InterpolationSession(pts, query_domain=qd, mesh=mesh,
                            layout="grid_ring")
with AsyncAidwServer(pts, query_domain=qd, mesh=mesh,
                     layout="grid_ring") as srv:
    reqs = [srv.submit(q) for q in qs[:3]]
    srv.update_dataset(inserts=spatial_points(50, seed=99),
                       deletes=np.arange(50), timeout=300)
    reqs += [srv.submit(q) for q in qs[3:]]
    srv.flush(timeout=600)
# values: allclose, not bitwise — the worker may coalesce the requests
# into one batch, and the ring Stage-2 tile shape (hence XLA's f32
# reduction strategy) varies with the padded bucket (~1 ulp)
for i, r in enumerate(reqs[:3]):
    assert r.status == "done" and r.epoch == 0
    ref = np.asarray(sess.query(qs[i]).values)
    assert np.abs(r.values - ref).max() < 1e-5
sess.update(inserts=spatial_points(50, seed=99), deletes=np.arange(50))
for i, r in enumerate(reqs[3:]):
    assert r.status == "done" and r.epoch == 1
    ref = np.asarray(sess.query(qs[3 + i]).values)
    assert np.abs(r.values - ref).max() < 1e-5
print("grid-ring-async-ok")
""")
    assert "grid-ring-async-ok" in out

@pytest.mark.slow
def test_grid_ring_local_stage2_8dev():
    """Exact-k local Stage 2 on the real 8-device grid-ring mesh: bit-identical
    r_obs/alpha to the global grid-ring session (Stage 1 untouched), values
    within the truncation tolerance, and the fused Pallas gather+weighting
    path agrees with the unfused local path within the documented 5e-7
    (bitwise stats; XLA FMA contraction under jit shifts jnp values ~1 ulp)."""
    out = run_multidevice("""
import numpy as np, jax
from repro.core import AidwConfig, InterpolationSession
from repro.core.jax_compat import make_auto_mesh
from repro.data.pipeline import spatial_points, spatial_queries

pts = spatial_points(16384, seed=0)
qs = spatial_queries(1000, seed=1)       # odd size: padded buckets
mesh = make_auto_mesh((8,), ("q",))
kw = dict(query_domain=qs, mesh=mesh, layout="grid_ring")
glob = InterpolationSession(pts, **kw)
loc = InterpolationSession(pts, AidwConfig(stage2="local"), **kw)
fused = InterpolationSession(
    pts, AidwConfig(stage2="local", fused=True, interpret=True), **kw)

g, l, f = glob.query(qs), loc.query(qs), fused.query(qs)
assert np.array_equal(np.asarray(g.r_obs), np.asarray(l.r_obs))
assert np.array_equal(np.asarray(g.alpha), np.asarray(l.alpha))
err = np.abs(np.asarray(g.values) - np.asarray(l.values)).max()
assert err < 5e-2, err                   # truncated far-field tail
assert not np.isnan(np.asarray(l.values)).any()

assert np.array_equal(np.asarray(f.alpha), np.asarray(l.alpha))
np.testing.assert_allclose(np.asarray(f.values), np.asarray(l.values),
                           rtol=5e-7, atol=5e-7)
print("grid-ring-local-8dev-ok", float(err))
""")
    assert "grid-ring-local-8dev-ok" in out
