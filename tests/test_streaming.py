"""Streaming ingest: LSM hot-ring interleaving, O(Δ) staging, zero-retrace.

ISSUE 7 test gates for the per-slab donation-aliased delta staging + hot
append ring:

* interleaving property — any mix of appends, deletes and COMPACTION
  epochs on a :class:`~repro.core.slab.SlabPartition`, compacted at the
  end, is element-identical to a fresh build of the surviving dataset
  (hypothesis-driven, with a fixed-seed variant that runs on minimal
  containers too);
* zero-retrace regression — in-ring churn on a ``grid_ring`` session must
  reuse BOTH the one compiled executor signature AND the cached staging
  fns (``SlabStaging._fns``): a retrace or a fresh jit per update would
  hide O(compile) work inside the O(Δ) ingest path;
* staged-bytes reduction — the unit-sized mirror of the
  ``ingest/staged_reduction`` benchmark gate: a 1% balanced delta must
  stage >= 10x fewer bytes than the construction-time full packet.
"""

from __future__ import annotations

import numpy as np
import pytest

import jax

from hypcompat import given, settings, st
from repro.core import grid as G
from repro.core.slab import SlabPartition
from repro.data.pipeline import spatial_points, spatial_queries


def _apply_interleaved(part, cur, rng, ops):
    """Apply (kind, payload) ops to ``part`` and the numpy shadow ``cur``."""
    for kind in ops:
        if kind == "compact":
            part.compact()                       # mid-stream compaction epoch
            continue
        n_del = int(rng.integers(0, max(cur.shape[0] // 6, 1)))
        dels = rng.choice(cur.shape[0], n_del, replace=False)
        n_ins = int(rng.integers(1, 9))
        ins = np.concatenate([rng.random((n_ins, 2)),
                              rng.random((n_ins, 1))], 1).astype(np.float32)
        part.apply_delta(inserts=ins, deletes=dels)
        keep = np.ones(cur.shape[0], bool)
        keep[dels] = False
        cur = np.concatenate([cur[keep], ins], 0)
    return cur


def _assert_element_identical(part, fresh, p):
    assert part.m == fresh.m
    for s in range(p):
        for name in ("sx", "sy", "sz", "cell_start", "order"):
            a = np.asarray(getattr(part.tables[s], name))
            b = np.asarray(getattr(fresh.tables[s], name))
            assert a.shape == b.shape and np.array_equal(a, b), (s, name)
        assert np.array_equal(part.members[s], fresh.members[s])


@settings(max_examples=15, deadline=None)
@given(st.integers(200, 1200), st.integers(2, 5), st.integers(0, 10_000),
       st.lists(st.sampled_from(["delta", "compact"]), min_size=1,
                max_size=6))
def test_interleaved_deltas_and_compactions_element_identical(
        m, p, seed, ops):
    """Property: ANY interleaving of delta updates and compaction epochs,
    followed by a final compact, leaves every slab table array and member
    list element-identical to a fresh build of the surviving dataset —
    compaction is a pure tier move, never a reorder the fresh build would
    not produce."""
    rng = np.random.default_rng(seed)
    pts = np.concatenate([rng.random((m, 2)), rng.random((m, 1))],
                         1).astype(np.float32)
    spec = G.plan_grid(pts[:, :2])
    part = SlabPartition.build(spec, pts, p, halo=3)
    cur = _apply_interleaved(part, pts.copy(), rng, ops)
    part.compact()
    assert part.ring_size() == 0 and part.tombstone_frac() == 0.0
    _assert_element_identical(part, SlabPartition.build(spec, cur, p,
                                                        halo=3), p)


@pytest.mark.parametrize("seed,ops", [
    (0, ["delta", "compact", "delta"]),
    (7, ["compact", "delta", "delta", "compact", "delta"]),
    (42, ["delta", "delta", "compact"]),
])
def test_interleaved_deltas_and_compactions_fixed_seeds(seed, ops):
    """Fixed-seed interleavings of the property above (runs on minimal
    containers where hypothesis is absent)."""
    rng = np.random.default_rng(seed)
    m, p = 700, 3
    pts = np.concatenate([rng.random((m, 2)), rng.random((m, 1))],
                         1).astype(np.float32)
    spec = G.plan_grid(pts[:, :2])
    part = SlabPartition.build(spec, pts, p, halo=3)
    cur = _apply_interleaved(part, pts.copy(), rng, ops)
    part.compact()
    assert part.ring_size() == 0 and part.tombstone_frac() == 0.0
    _assert_element_identical(part, SlabPartition.build(spec, cur, p,
                                                        halo=3), p)


def _grid_ring_session(m, *, ring_cap=512, seed=3):
    from repro.core import InterpolationSession
    from repro.core.jax_compat import make_auto_mesh

    mesh = make_auto_mesh((len(jax.devices()),), ("q",))
    pts = spatial_points(m, seed=seed)
    qd = spatial_queries(256, seed=seed + 1)
    sess = InterpolationSession(pts, query_domain=qd, mesh=mesh,
                                layout="grid_ring", ring_cap=ring_cap)
    sess.query(qd)
    return sess, pts, qd


def test_in_ring_churn_zero_retrace_and_stable_staging_fns():
    """Zero-retrace regression (ISSUE 7): while churn stays inside the
    ring capacity, every delta reuses (a) the ONE compiled grid-ring
    executor signature and (b) the cached donation-aliased staging fns —
    after the first delta has populated the scatter-fn cache, further
    same-bucket deltas add ZERO new jitted signatures of either kind."""
    from repro.core import pipeline as P

    sess, pts, qd = _grid_ring_session(3301)          # size unique to test
    lo, hi = pts[:, :2].min(axis=0), pts[:, :2].max(axis=0)
    sp = sess.sharded_plan
    fn = P.grid_ring_session_execute(
        sp.mesh, sp.ring_axis, sess.plan.cfg, sess.plan.spec, sp.rps,
        sp.halo, sp.max_level)
    n_exec = fn._cache_size()
    assert n_exec >= 1

    rng = np.random.default_rng(11)

    def delta(i):
        ins = spatial_points(16, seed=70 + i)
        ins[:, :2] = np.clip(ins[:, :2], lo, hi)
        # delete only from the CSR-resident head so every insert stays
        # ring-resident (a ring delete would be exact, but the 64-point
        # occupancy assertion below wants all inserts alive)
        sess.update(inserts=ins, deletes=rng.choice(3000, 16, replace=False))
        sess.query(qd)

    delta(0)                        # populates the scatter-side fn cache
    n_fns = len(sess.sharded_plan.staging._fns)
    for i in range(1, 4):
        delta(i)
    assert fn._cache_size() == n_exec            # zero executor retraces
    assert len(sess.sharded_plan.staging._fns) == n_fns   # zero staging fns
    assert sess.stats["delta_updates"] == 4
    assert sess.stats["full_restages"] == 1      # construction only
    assert sess.stats["spilled_updates"] == 0
    assert sess.stats["ring_points"] == 64       # all churn stayed in-ring
    # a compaction epoch may compile its own one-time staging signatures
    # (full-row folds at slab capacity) — but the EXECUTOR never retraces,
    # and a second churn+compact round adds zero new signatures of any kind
    sess.compact()
    sess.query(qd)
    assert sess.stats["ring_points"] == 0
    assert fn._cache_size() == n_exec
    n_post = len(sess.sharded_plan.staging._fns)
    delta(4)
    sess.compact()
    sess.query(qd)
    assert fn._cache_size() == n_exec
    assert len(sess.sharded_plan.staging._fns) == n_post


def test_delta_staging_bytes_reduction_unit():
    """Unit-sized mirror of the ``ingest/staged_reduction`` benchmark
    gate: at 1% balanced churn a grid-ring delta stages >= 10x fewer
    bytes than the construction-time full-packet upload, touching only
    the slabs the delta landed in."""
    m = 8192
    sess, pts, qd = _grid_ring_session(m)
    full_bytes = sess.stats["staged_bytes"]       # construction upload
    assert full_bytes > 0
    lo, hi = pts[:, :2].min(axis=0), pts[:, :2].max(axis=0)
    d = m // 100
    rng = np.random.default_rng(13)
    staged = []
    for i in range(2):
        ins = spatial_points(d, seed=80 + i)
        ins[:, :2] = np.clip(ins[:, :2], lo, hi)
        sess.update(inserts=ins, deletes=rng.choice(m, d, replace=False))
        sess.query(qd)
        staged.append(sess.stats["staged_bytes"])
    assert sess.stats["delta_updates"] == 2
    assert sess.stats["full_restages"] == 1
    assert sess.stats["spilled_updates"] == 0
    reduction = full_bytes / max(float(np.mean(staged)), 1.0)
    assert reduction >= 10.0, (reduction, staged, full_bytes)
    assert sess.stats["staged_bytes_total"] >= full_bytes + sum(staged)
    assert 1 <= sess.stats["slabs_touched"] <= len(jax.devices())
