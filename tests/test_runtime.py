"""Fault-tolerance policies: stragglers, heartbeats, elastic plans, spikes."""

from __future__ import annotations

import pytest

from repro.runtime import (ElasticPlanner, HeartbeatMonitor, SpikeGuard,
                           StragglerDetector)


def test_straggler_detection():
    det = StragglerDetector(["w0", "w1", "w2", "w3"], threshold=1.5, patience=2)
    for step in range(5):
        for w in ("w0", "w1", "w2"):
            det.observe(w, 1.0)
        det.observe("w3", 3.0)  # persistent straggler
        flagged = det.end_step()
    assert flagged == ["w3"]


def test_straggler_recovers():
    det = StragglerDetector(["w0", "w1"], threshold=1.5, patience=3)
    for _ in range(3):
        det.observe("w0", 1.0)
        det.observe("w1", 5.0)
        det.end_step()
    for _ in range(12):
        det.observe("w0", 1.0)
        det.observe("w1", 1.0)   # back to normal -> strikes reset
        flagged = det.end_step()
    assert flagged == []


def test_heartbeat_monitor():
    t = [0.0]
    mon = HeartbeatMonitor(["h0", "h1"], timeout_s=10, clock=lambda: t[0])
    t[0] = 5.0
    mon.beat("h0")
    t[0] = 12.0
    assert mon.dead_hosts() == ["h1"]
    assert mon.alive_hosts() == ["h0"]


def test_elastic_planner_keeps_model_axis():
    pl = ElasticPlanner(model_parallel=16)
    plan = pl.plan(surviving_chips=512 - 16)   # lost one model group
    assert plan.mesh_shape == (31, 16)
    assert plan.n_chips == 496 and plan.dropped_chips == 0
    plan = pl.plan(surviving_chips=250)        # ragged survivors
    assert plan.mesh_shape == (15, 16)
    assert plan.n_chips == 240 and plan.dropped_chips == 10
    with pytest.raises(RuntimeError):
        pl.plan(surviving_chips=7)


def test_spike_guard():
    g = SpikeGuard(window=10, factor=10.0)
    for _ in range(10):
        assert not g.observe(1.0)
    assert g.observe(50.0)          # 50x the median
    assert g.observe(float("nan"))  # non-finite always trips
    assert not g.observe(1.2)
