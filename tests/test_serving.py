"""Serving engine: slot-based continuous batching correctness."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.models import api
from repro.nn.param import init_params
from repro.serving.engine import Request, ServingEngine


def _engine(arch="llama3.2-3b", batch=3, max_len=48):
    cfg = reduced(get_config(arch))
    params = init_params(api.param_defs(cfg), jax.random.PRNGKey(0))
    return cfg, params, ServingEngine(cfg, params, batch_size=batch,
                                      max_len=max_len)


def _reqs(cfg, n, plen, max_new, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i, prompt=rng.integers(0, cfg.vocab, plen).astype(np.int32),
                    max_new_tokens=max_new) for i in range(n)]


def test_all_requests_served():
    cfg, params, eng = _engine()
    reqs = _reqs(cfg, 7, 16, 6)
    eng.run(reqs)
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == 6 for r in reqs)


def test_batched_output_matches_single_sequence_greedy():
    """Every request's tokens must equal unbatched greedy decoding."""
    cfg, params, eng = _engine(batch=2, max_len=40)
    reqs = _reqs(cfg, 4, 12, 5, seed=3)
    eng.run(reqs)

    prefill = jax.jit(api.prefill_fn(cfg))
    decode = jax.jit(api.decode_fn(cfg))
    for r in reqs:
        logits, cache = prefill(params, {"tokens": jnp.asarray(r.prompt[None, :])})
        cache = dict(cache)
        for kk in ("k", "v"):
            if kk in cache:
                pad = [(0, 0)] * cache[kk].ndim
                pad[2] = (0, 40 - len(r.prompt))
                cache[kk] = jnp.pad(cache[kk], pad)
        toks = [int(jnp.argmax(logits, -1)[0])]
        pos = len(r.prompt)
        while len(toks) < r.max_new_tokens:
            lg, cache = decode(params, cache,
                               {"tokens": jnp.asarray([[toks[-1]]], jnp.int32),
                                "pos": jnp.int32(pos)})
            toks.append(int(jnp.argmax(lg, -1)[0]))
            pos += 1
        assert r.out_tokens == toks, (r.uid, r.out_tokens, toks)


@pytest.mark.parametrize("arch", ["mamba2-130m", "zamba2-2.7b"])
def test_ssm_and_hybrid_serving(arch):
    cfg, params, eng = _engine(arch, batch=2, max_len=40)
    reqs = _reqs(cfg, 4, 12, 4)
    stats = eng.run(reqs)
    assert all(r.done for r in reqs)
    assert stats["tokens"] >= 16
