"""Even-grid construction: CSR cell table vs direct numpy binning, plus the
incremental rebinning (insert/delete delta) path."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
from hypcompat import given, settings, st  # guarded: skips, never dies, without hypothesis

from repro.core import bin_points, cell_ids, plan_grid, rebin_delta


def _np_points(seed, n):
    r = np.random.default_rng(seed)
    return r.random((n, 3)).astype(np.float32)


def _bin(spec, pts):
    return bin_points(spec, jnp.array(pts[:, 0]), jnp.array(pts[:, 1]),
                      jnp.array(pts[:, 2]))


def _assert_tables_equal(got, want):
    """Element-identity on every CellTable field (stable-sort equivalence)."""
    for name in ("sx", "sy", "sz", "cell_start", "order"):
        a, b = np.asarray(getattr(got, name)), np.asarray(getattr(want, name))
        assert np.array_equal(a, b), name


def test_plan_grid_covers_all_points():
    pts = _np_points(0, 500)
    qs = np.random.default_rng(1).random((100, 2)).astype(np.float32) * 2 - 0.5
    spec = plan_grid(pts[:, :2], qs)
    allx = np.concatenate([pts[:, 0], qs[:, 0]])
    ally = np.concatenate([pts[:, 1], qs[:, 1]])
    assert spec.min_x <= allx.min() and spec.min_y <= ally.min()
    assert spec.min_x + spec.n_cols * spec.cell_width >= allx.max()
    assert spec.min_y + spec.n_rows * spec.cell_width >= ally.max()


def test_cell_table_matches_numpy_bincount():
    pts = _np_points(2, 1000)
    spec = plan_grid(pts[:, :2])
    table = bin_points(spec, jnp.array(pts[:, 0]), jnp.array(pts[:, 1]),
                       jnp.array(pts[:, 2]))
    ids = np.asarray(cell_ids(spec, jnp.array(pts[:, 0]), jnp.array(pts[:, 1])))
    counts = np.bincount(ids, minlength=spec.n_cells)
    cs = np.asarray(table.cell_start)
    assert cs.shape == (spec.n_cells + 1,)
    assert (np.diff(cs) == counts).all()
    assert cs[0] == 0 and cs[-1] == len(pts)
    # sorted coordinates really belong to their cells
    sx, sy = np.asarray(table.sx), np.asarray(table.sy)
    sorted_ids = np.asarray(cell_ids(spec, jnp.array(sx), jnp.array(sy)))
    assert (np.diff(sorted_ids) >= 0).all()
    # order is a permutation mapping back to originals
    order = np.asarray(table.order)
    assert sorted(order.tolist()) == list(range(len(pts)))
    assert np.allclose(sx, pts[order, 0])


@settings(max_examples=25, deadline=None)
@given(st.integers(10, 400), st.integers(0, 10_000),
       st.floats(0.3, 4.0))
def test_cell_table_properties(n, seed, cell_factor):
    pts = _np_points(seed, n)
    spec = plan_grid(pts[:, :2], cell_factor=cell_factor)
    table = bin_points(spec, jnp.array(pts[:, 0]), jnp.array(pts[:, 1]),
                       jnp.array(pts[:, 2]))
    cs = np.asarray(table.cell_start)
    assert (np.diff(cs) >= 0).all()          # monotone CSR
    assert cs[-1] == n                        # every point binned exactly once
    assert float(jnp.sum(table.sz)) == pytest.approx(float(pts[:, 2].sum()), rel=1e-4)


def test_rebin_delta_matches_full_bin_randomized():
    """rebin_delta == full bin_points of the updated dataset (same spec),
    element-identical including ``order``, over randomized delta streams."""
    m = 2000
    pts = _np_points(3, m)
    spec = plan_grid(pts[:, :2])
    table = _bin(spec, pts)
    for trial in range(4):
        r = np.random.default_rng(trial)
        dels = r.choice(pts.shape[0], int(r.integers(0, m // 5)), replace=False)
        ins = _np_points(100 + trial, int(r.integers(0, m // 5)))
        got = rebin_delta(spec, table, inserts=ins, deletes=dels)
        keep = np.ones(pts.shape[0], bool)
        keep[dels] = False
        pts = np.concatenate([pts[keep], ins], axis=0)   # stream: accumulate
        table = got
        _assert_tables_equal(got, _bin(spec, pts))


def test_rebin_delta_noop_and_pure_cases():
    pts = _np_points(4, 500)
    spec = plan_grid(pts[:, :2])
    table = _bin(spec, pts)
    _assert_tables_equal(rebin_delta(spec, table), table)       # no-op
    ins = _np_points(5, 50)
    _assert_tables_equal(                                        # pure insert
        rebin_delta(spec, table, inserts=ins),
        _bin(spec, np.concatenate([pts, ins])))
    _assert_tables_equal(                                        # pure delete
        rebin_delta(spec, table, deletes=np.arange(0, 500, 7)),
        _bin(spec, np.delete(pts, np.arange(0, 500, 7), axis=0)))
    with pytest.raises(IndexError):
        rebin_delta(spec, table, deletes=[500])


@settings(max_examples=25, deadline=None)
@given(st.integers(20, 300), st.integers(0, 10_000),
       st.integers(0, 60), st.integers(0, 60))
def test_rebin_delta_properties(n, seed, n_del, n_ins):
    """Hypothesis: arbitrary insert/delete deltas reproduce a full re-bin."""
    n_del = min(n_del, n - 1)                    # never delete everything
    pts = _np_points(seed, n)
    spec = plan_grid(pts[:, :2])
    table = _bin(spec, pts)
    r = np.random.default_rng(seed + 1)
    dels = r.choice(n, n_del, replace=False)
    ins = _np_points(seed + 2, n_ins)
    got = rebin_delta(spec, table, inserts=ins, deletes=dels)
    keep = np.ones(n, bool)
    keep[dels] = False
    upd = np.concatenate([pts[keep], ins], axis=0)
    _assert_tables_equal(got, _bin(spec, upd))
    # CSR invariants survive the incremental path
    cs = np.asarray(got.cell_start)
    assert (np.diff(cs) >= 0).all() and cs[0] == 0 and cs[-1] == upd.shape[0]
    assert sorted(np.asarray(got.order).tolist()) == list(range(upd.shape[0]))


def test_paper_cell_width_formula():
    # cellWidth from Eq.(2): 1 / (2 sqrt(m / A))
    pts = _np_points(1, 4096)
    spec = plan_grid(pts[:, :2])
    area = (spec.n_cols * spec.cell_width) * (spec.n_rows * spec.cell_width)
    ppc = 4096 / spec.n_cells
    assert 0.15 < ppc < 0.40  # Eq.(2) width -> ~1/4 point per cell
