"""Even-grid construction: CSR cell table vs direct numpy binning."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
from hypcompat import given, settings, st  # guarded: skips, never dies, without hypothesis

from repro.core import bin_points, cell_ids, plan_grid


def _np_points(seed, n):
    r = np.random.default_rng(seed)
    return r.random((n, 3)).astype(np.float32)


def test_plan_grid_covers_all_points():
    pts = _np_points(0, 500)
    qs = np.random.default_rng(1).random((100, 2)).astype(np.float32) * 2 - 0.5
    spec = plan_grid(pts[:, :2], qs)
    allx = np.concatenate([pts[:, 0], qs[:, 0]])
    ally = np.concatenate([pts[:, 1], qs[:, 1]])
    assert spec.min_x <= allx.min() and spec.min_y <= ally.min()
    assert spec.min_x + spec.n_cols * spec.cell_width >= allx.max()
    assert spec.min_y + spec.n_rows * spec.cell_width >= ally.max()


def test_cell_table_matches_numpy_bincount():
    pts = _np_points(2, 1000)
    spec = plan_grid(pts[:, :2])
    table = bin_points(spec, jnp.array(pts[:, 0]), jnp.array(pts[:, 1]),
                       jnp.array(pts[:, 2]))
    ids = np.asarray(cell_ids(spec, jnp.array(pts[:, 0]), jnp.array(pts[:, 1])))
    counts = np.bincount(ids, minlength=spec.n_cells)
    cs = np.asarray(table.cell_start)
    assert cs.shape == (spec.n_cells + 1,)
    assert (np.diff(cs) == counts).all()
    assert cs[0] == 0 and cs[-1] == len(pts)
    # sorted coordinates really belong to their cells
    sx, sy = np.asarray(table.sx), np.asarray(table.sy)
    sorted_ids = np.asarray(cell_ids(spec, jnp.array(sx), jnp.array(sy)))
    assert (np.diff(sorted_ids) >= 0).all()
    # order is a permutation mapping back to originals
    order = np.asarray(table.order)
    assert sorted(order.tolist()) == list(range(len(pts)))
    assert np.allclose(sx, pts[order, 0])


@settings(max_examples=25, deadline=None)
@given(st.integers(10, 400), st.integers(0, 10_000),
       st.floats(0.3, 4.0))
def test_cell_table_properties(n, seed, cell_factor):
    pts = _np_points(seed, n)
    spec = plan_grid(pts[:, :2], cell_factor=cell_factor)
    table = bin_points(spec, jnp.array(pts[:, 0]), jnp.array(pts[:, 1]),
                       jnp.array(pts[:, 2]))
    cs = np.asarray(table.cell_start)
    assert (np.diff(cs) >= 0).all()          # monotone CSR
    assert cs[-1] == n                        # every point binned exactly once
    assert float(jnp.sum(table.sz)) == pytest.approx(float(pts[:, 2].sum()), rel=1e-4)


def test_paper_cell_width_formula():
    # cellWidth from Eq.(2): 1 / (2 sqrt(m / A))
    pts = _np_points(1, 4096)
    spec = plan_grid(pts[:, :2])
    area = (spec.n_cols * spec.cell_width) * (spec.n_rows * spec.cell_width)
    ppc = 4096 / spec.n_cells
    assert 0.15 < ppc < 0.40  # Eq.(2) width -> ~1/4 point per cell
