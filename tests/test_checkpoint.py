"""Checkpoint manager: atomic commit, async, retention, restore semantics."""

from __future__ import annotations

import json
from pathlib import Path

import numpy as np
import jax.numpy as jnp
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree(seed=0):
    r = np.random.default_rng(seed)
    return {"layers": {"w": jnp.asarray(r.normal(0, 1, (8, 4)), jnp.float32),
                       "b": jnp.asarray(r.normal(0, 1, (4,)), jnp.bfloat16)},
            "step_scale": jnp.float32(2.5)}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    t = _tree()
    mgr.save(7, t)
    restored, step = mgr.restore(jax.tree.map(jnp.zeros_like, t) if False else t)
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))
    mgr.close()


import jax  # noqa: E402  (used in test above)


def test_async_and_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    futs = [mgr.save_async(s, _tree(s)) for s in (1, 2, 3, 4)]
    for f in futs:
        f.result()
    assert mgr.complete_steps() == [3, 4]
    restored, step = mgr.restore(_tree())
    assert step == 4
    np.testing.assert_allclose(
        np.asarray(restored["layers"]["w"]), np.asarray(_tree(4)["layers"]["w"]))
    mgr.close()


def test_tmp_dirs_are_not_checkpoints(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(5, _tree())
    # simulate a crash mid-write: orphaned .tmp directory
    orphan = tmp_path / "step_000000009.tmp"
    orphan.mkdir()
    (orphan / "garbage.npy").write_bytes(b"xx")
    assert mgr.latest_step() == 5
    mgr.cleanup_tmp()
    assert not orphan.exists()
    mgr.close()


def test_restore_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError, match="shape mismatch"):
        mgr.restore({"w": jnp.zeros((5,))})
    mgr.close()


def test_restore_missing_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    with pytest.raises(FileNotFoundError):
        mgr.restore({"w": jnp.zeros(2)})
    mgr.close()


def test_cross_mesh_restore_subprocess(tmp_path):
    """Save on a (4,2) mesh, restore with (2,4)-mesh shardings (elastic)."""
    from conftest import run_multidevice

    code = f"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.manager import CheckpointManager

tree = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
mesh_a = jax.make_mesh((4, 2), ("data", "model"))
sh_a = {{"w": NamedSharding(mesh_a, P("data", "model"))}}
t_a = jax.device_put(tree, sh_a)
mgr = CheckpointManager(r"{tmp_path}")
mgr.save(3, t_a)

mesh_b = jax.make_mesh((2, 4), ("data", "model"))
sh_b = {{"w": NamedSharding(mesh_b, P("model", "data"))}}
restored, step = mgr.restore(tree, shardings=sh_b)
assert step == 3
np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
assert restored["w"].sharding == sh_b["w"]
print("cross-mesh-ok")
mgr.close()
"""
    out = run_multidevice(code, n_devices=8)
    assert "cross-mesh-ok" in out
