"""Optimizer + gradient compression: convergence and invariants."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.optim import adamw, compression


def _fit(opt_cfg, steps=200, compress=False):
    """Fit y = Xw on a fixed problem; returns final loss."""
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(0, 1, (64, 8)), jnp.float32)
    w_true = jnp.asarray(rng.normal(0, 1, (8,)), jnp.float32)
    y = X @ w_true
    params = {"w": jnp.zeros((8,), jnp.float32)}
    state = adamw.init_state(opt_cfg, params)
    err = compression.init_error(params) if compress else None

    def loss_fn(p):
        return jnp.mean((X @ p["w"] - y) ** 2)

    @jax.jit
    def step(p, s, e):
        l, g = jax.value_and_grad(loss_fn)(p)
        if compress:
            g, e = compression.compress_with_feedback(g, e)
        p, s, _ = adamw.apply_updates(opt_cfg, p, s, g)
        return p, s, e, l

    for _ in range(steps):
        params, state, err, l = step(params, state, err)
    return float(l)


def test_adamw_converges():
    assert _fit(adamw.AdamWConfig(lr=0.05, weight_decay=0.0,
                                  warmup_steps=5, total_steps=200)) < 1e-3


def test_compressed_grads_converge():
    """Error feedback keeps int8-quantized gradients unbiased over time."""
    assert _fit(adamw.AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=5,
                                  total_steps=200), compress=True) < 1e-2


def test_no_master_weights_mode():
    cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0, master_weights=False,
                            warmup_steps=5, total_steps=200)
    assert "master" not in adamw.init_state(cfg, {"w": jnp.zeros(3)})
    assert _fit(cfg) < 1e-3


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0), "b": jnp.full((3,), -10.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(700), rel=1e-5)
    assert float(adamw.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    # below threshold: untouched
    same, _ = adamw.clip_by_global_norm(g, 1e9)
    np.testing.assert_allclose(np.asarray(same["a"]), np.asarray(g["a"]))


def test_lr_schedule_shape():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    lrs = [float(adamw.lr_schedule(cfg, jnp.int32(s))) for s in range(101)]
    assert lrs[0] == 0.0
    assert lrs[10] == pytest.approx(1.0, rel=1e-3)
    assert max(lrs) <= 1.0 + 1e-6
    assert lrs[100] == pytest.approx(0.1, rel=1e-3)
    assert all(b <= a + 1e-6 for a, b in zip(lrs[10:], lrs[11:]))  # decays


def test_quantize_roundtrip_error_bounded(rng):
    g = jnp.asarray(rng.normal(0, 3, (1000,)), jnp.float32)
    q, s = compression._quantize(g)
    dq = compression._dequantize(q, s)
    assert float(jnp.abs(g - dq).max()) <= float(s) * 0.5 + 1e-6


def test_error_feedback_accumulates():
    g = {"w": jnp.asarray([1e-4] * 8, jnp.float32)}  # below 1-step resolution
    err = compression.init_error(g)
    total = jnp.zeros(8)
    for _ in range(50):
        dq, err = compression.compress_with_feedback(g, err)
        total = total + dq["w"]
    # over many steps the quantized stream must deliver the true mass
    np.testing.assert_allclose(np.asarray(total), 50 * 1e-4, rtol=0.2)


def test_compressed_psum_shardmap(rng):
    """int8-quantize -> psum -> dequantize inside shard_map (1 device)."""
    from repro.core.jax_compat import shard_map

    mesh = jax.make_mesh((1,), ("d",))
    g = jnp.asarray(rng.normal(0, 1, (16,)), jnp.float32)

    fn = shard_map(lambda x: compression.compressed_psum(x, "d"),
                   mesh=mesh, in_specs=jax.sharding.PartitionSpec("d"),
                   out_specs=jax.sharding.PartitionSpec("d"))
    out = fn(g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(g), atol=0.05)
