"""Local (exact-k) Stage-2 error contract vs the global Eq. (1) path.

The contract (``repro.core.aidw`` module docstring): with
``AidwConfig(stage2='local')`` Stage 1 is untouched, so ``r_obs``/``alpha``
are BIT-IDENTICAL to global mode by construction; the predicted values
differ exactly by the truncated far-field tail, which is bounded by the
tail's weight-mass fraction, shrinks as k grows, and vanishes (to f32
accumulation tolerance) at k = m.  Tightest on clustered data, loosest on
uniform data — both regimes are pinned here, plus the fused Pallas kernel's
bitwise equivalence, the zero-weight sentinel, and the fleet's single-phase
local merge.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest

from hypcompat import given, settings, st
from repro.core import (AidwConfig, InterpolationSession, aidw_improved,
                        brute_knn)
from repro.core import aidw as A
from repro.data.pipeline import spatial_points, spatial_queries


def _pair(pts, qs, **cfg_kw):
    """(global, local) results for the same dataset/queries."""
    g = aidw_improved(pts, qs, AidwConfig(**cfg_kw))
    l = aidw_improved(pts, qs, AidwConfig(stage2="local", **cfg_kw))
    return g, l


def _tail_bound(pts, qs, k, alpha):
    """f64 oracle: the far-field tail's weight-mass error bound per query.

    |Z_local - Z_global| <= (tail_w / total_w) * spread(z): dropping the
    tail moves the weighted average by at most the dropped mass times the
    data's value range.
    """
    d2 = ((qs[:, None, :] - pts[None, :, :2]) ** 2).sum(-1).astype(np.float64)
    w = np.maximum(d2, A.EPS_D2) ** (-0.5 * alpha[:, None].astype(np.float64))
    order = np.argsort(d2, axis=1, kind="stable")
    wsorted = np.take_along_axis(w, order, axis=1)
    tail = wsorted[:, k:].sum(axis=1)
    spread = pts[:, 2].max() - pts[:, 2].min()
    return tail / wsorted.sum(axis=1) * spread


def test_local_stats_bitwise_and_values_within_tail_bound():
    """Acceptance: r_obs/alpha bitwise vs global; |values delta| within the
    analytic truncated-tail bound (+ f32 accumulation slack)."""
    pts = spatial_points(4096, seed=0)
    qs = spatial_queries(512, seed=1)
    g, l = _pair(pts, qs, k=15)
    assert np.array_equal(np.asarray(g.r_obs), np.asarray(l.r_obs))
    assert np.array_equal(np.asarray(g.alpha), np.asarray(l.alpha))
    err = np.abs(np.asarray(g.values) - np.asarray(l.values))
    bound = _tail_bound(pts, qs, 15, np.asarray(g.alpha))
    assert (err <= bound + 1e-4).all(), float((err - bound).max())
    assert not np.asarray(l.zero_weight_mask).any()


@pytest.mark.parametrize("clustered", [False, True])
def test_local_converges_to_global_as_k_grows(clustered):
    """k -> m convergence: the tail error shrinks with k and reaches f32
    accumulation tolerance at k = m (the whole dataset is "local")."""
    m = 512
    pts = spatial_points(m, seed=2, clustered=clustered)
    qs = spatial_queries(128, seed=3)
    errs = []
    for k in (4, 16, 64, m):
        g, l = _pair(pts, qs, k=k, window=4 * m)
        assert np.array_equal(np.asarray(g.alpha), np.asarray(l.alpha)), k
        errs.append(np.abs(np.asarray(g.values) - np.asarray(l.values)).max())
    assert errs[-1] < 1e-5, errs            # k = m: only accumulation order
    assert errs[-1] <= errs[0] + 1e-7, errs  # tail error really shrank


def test_local_tolerance_uniform_tighter_than_clustered():
    """The documented regime split (``repro.core.aidw``): the tail mass is
    set by the alpha Eq. (6) picks, so UNIFORM patterns (alpha >= 2, fast
    decay) truncate tightly while CLUSTERED patterns (alpha ~ 0.5 near the
    clusters) carry a heavy far-field tail — local mode is loosest there."""
    rng = np.random.default_rng(4)

    def stats(clustered):
        pts = spatial_points(4096, seed=5, clustered=clustered)
        # queries co-located with the data: jittered data sites
        qs = (pts[rng.integers(0, 4096, 256), :2]
              + rng.normal(0, 0.005, (256, 2))).astype(np.float32)
        g, l = _pair(pts, qs, k=15)
        err = float(np.median(np.abs(np.asarray(g.values)
                                     - np.asarray(l.values))))
        return err, float(np.median(np.asarray(g.alpha)))

    uni_err, uni_alpha = stats(False)
    clu_err, clu_alpha = stats(True)
    assert clu_alpha < uni_alpha        # Eq. (6): clustered -> small alpha
    assert uni_err < clu_err            # ... hence the heavier tail


def test_session_local_fused_vs_unfused(spatial_data):
    """AidwConfig(stage2='local', fused=True) — the Pallas gather+weighting
    kernel — matches the unfused jnp top-k path end to end: Stage-1 stats
    and masks bitwise, values within 1 ulp (XLA contracts the compiled jnp
    path's mul+add into an FMA the interpreter doesn't use; the eager
    bitwise contract is pinned in tests/test_kernels.py)."""
    pts, qs = spatial_data
    unf = InterpolationSession(pts, AidwConfig(stage2="local"),
                               query_domain=qs).query(qs)
    fus = InterpolationSession(
        pts, AidwConfig(stage2="local", fused=True, interpret=True),
        query_domain=qs).query(qs)
    vu, vf = np.asarray(unf.values), np.asarray(fus.values)
    np.testing.assert_allclose(vf, vu, rtol=5e-7, atol=5e-7)
    assert np.array_equal(np.asarray(unf.alpha), np.asarray(fus.alpha))
    assert np.array_equal(np.asarray(unf.r_obs), np.asarray(fus.r_obs))
    assert np.array_equal(np.asarray(unf.zero_weight_mask),
                          np.asarray(fus.zero_weight_mask))


def test_session_local_matches_global_stats(spatial_data):
    """Session-level contract: local sessions report bitwise-identical
    Stage-1 stats (r_obs/alpha/overflow) to the global session."""
    pts, qs = spatial_data
    g = InterpolationSession(pts, query_domain=qs).query(qs)
    l = InterpolationSession(pts, AidwConfig(stage2="local"),
                             query_domain=qs).query(qs)
    assert np.array_equal(np.asarray(g.r_obs), np.asarray(l.r_obs))
    assert np.array_equal(np.asarray(g.alpha), np.asarray(l.alpha))
    assert np.array_equal(np.asarray(g.overflow_mask),
                          np.asarray(l.overflow_mask))
    assert np.abs(np.asarray(g.values) - np.asarray(l.values)).max() < 0.2


@settings(max_examples=20, deadline=None)
@given(st.integers(100, 600), st.integers(1, 30), st.integers(0, 10_000),
       st.booleans())
def test_local_error_contract_property(m, k, seed, clustered):
    """Property (hypothesis): for any cloud/k, the top-k truncation of
    Eq. (1) stays within the f64 tail bound and keeps alpha bitwise."""
    pts = spatial_points(m, seed=seed, clustered=clustered)
    qs = spatial_queries(32, seed=seed + 1)
    g, l = _pair(pts, qs, k=k, window=4 * m)
    assert np.array_equal(np.asarray(g.alpha), np.asarray(l.alpha))
    err = np.abs(np.asarray(g.values) - np.asarray(l.values))
    bound = _tail_bound(pts, qs, k, np.asarray(g.alpha))
    assert (err <= bound + 1e-3).all(), float((err - bound).max())


def test_topk_partial_sums_pad_invariance():
    """Appending inf-distance slots to the k axis is a bitwise no-op — the
    sequential accumulation contract the Pallas lane padding relies on."""
    rng = np.random.default_rng(7)
    d2 = jnp.asarray(np.sort(rng.random((64, 9)), axis=1), jnp.float32)
    z = jnp.asarray(rng.normal(0, 1, (64, 9)), jnp.float32)
    a = jnp.asarray(rng.uniform(0.5, 4.0, 64), jnp.float32)
    swz, sw = A.topk_weighted_partial_sums(d2, z, a)
    d2p = jnp.pad(d2, ((0, 0), (0, 7)), constant_values=jnp.inf)
    zp = jnp.pad(z, ((0, 0), (0, 7)))
    swzp, swp = A.topk_weighted_partial_sums(d2p, zp, a)
    assert np.array_equal(np.asarray(swz), np.asarray(swzp))
    assert np.array_equal(np.asarray(sw), np.asarray(swp))


def test_local_zero_weight_far_query(spatial_data):
    """A query so far that every neighbour weight underflows: 0.0 sentinel +
    raised mask, never NaN — through the full local session path."""
    pts, qs = spatial_data
    far = np.array([[1e18, 1e18]], np.float32)
    batch = np.concatenate([qs[:7], far]).astype(np.float32)
    for fused in (False, True):
        sess = InterpolationSession(
            pts, AidwConfig(stage2="local", fused=fused, interpret=True),
            query_domain=qs)
        res = sess.query(batch)
        vals = np.asarray(res.values)
        mask = np.asarray(res.zero_weight_mask)
        assert not np.isnan(vals).any()
        assert mask[-1] and vals[-1] == 0.0
        assert not mask[:-1].any()


def test_fleet_local_single_phase_matches_replica():
    """ShardedAidwCluster(stage2='local'): the merged (d2, z) heap finishes
    the query client-side (no phase-2 fan-out) and matches a full-replica
    local session within merge-order tolerance, with bitwise alpha."""
    from repro.serving.cluster import ShardedAidwCluster

    pts = spatial_points(4096, seed=0)
    qd = spatial_queries(512, seed=1)
    qs = spatial_queries(300, seed=2)
    cfg = AidwConfig(stage2="local")
    replica = InterpolationSession(pts, cfg, query_domain=qd)
    want = replica.query(qs)
    with ShardedAidwCluster(pts, n_hosts=2, cfg=cfg,
                            query_domain=qd) as fleet:
        got = fleet.query(qs, timeout=300)
        assert got.epoch == 0
        assert np.array_equal(got.alpha.astype(np.float32),
                              np.asarray(want.alpha))
        err = np.abs(got.values - np.asarray(want.values)).max()
        assert err < 1e-5, err
        assert not got.zero_weight_mask.any()


def test_grid_ring_local_matches_global_one_device():
    """grid_ring + stage2='local' on a 1-device mesh: bitwise Stage-1 stats
    vs the global grid-ring session, values within the tail tolerance, and
    no Stage-2 rotation needed to serve."""
    import jax

    from repro.core.jax_compat import make_auto_mesh

    mesh = make_auto_mesh((len(jax.devices()),), ("q",))
    pts = spatial_points(2048, seed=0)
    qs = spatial_queries(256, seed=1)
    g = InterpolationSession(pts, query_domain=qs, mesh=mesh,
                             layout="grid_ring").query(qs)
    l = InterpolationSession(pts, AidwConfig(stage2="local"),
                             query_domain=qs, mesh=mesh,
                             layout="grid_ring").query(qs)
    assert np.array_equal(np.asarray(g.r_obs), np.asarray(l.r_obs))
    assert np.array_equal(np.asarray(g.alpha), np.asarray(l.alpha))
    assert np.array_equal(np.asarray(g.overflow_mask),
                          np.asarray(l.overflow_mask))
    bound = _tail_bound(pts, qs, 15, np.asarray(g.alpha))
    err = np.abs(np.asarray(g.values) - np.asarray(l.values))
    assert (err <= bound + 1e-4).all()


def test_ring_local_matches_global_one_device():
    """ring + stage2='local' on a 1-device mesh: same contract through the
    brute-force ring executor (co-merged (d2, z) carry)."""
    import jax

    from repro.core.jax_compat import make_auto_mesh

    mesh = make_auto_mesh((len(jax.devices()),), ("q",))
    pts = spatial_points(1024, seed=0)
    qs = spatial_queries(256, seed=1)
    g = InterpolationSession(pts, query_domain=qs, mesh=mesh,
                             layout="ring").query(qs)
    l = InterpolationSession(pts, AidwConfig(stage2="local"),
                             query_domain=qs, mesh=mesh,
                             layout="ring").query(qs)
    assert np.array_equal(np.asarray(g.r_obs), np.asarray(l.r_obs))
    assert np.array_equal(np.asarray(g.alpha), np.asarray(l.alpha))
    bound = _tail_bound(pts, qs, 15, np.asarray(g.alpha))
    err = np.abs(np.asarray(g.values) - np.asarray(l.values))
    assert (err <= bound + 1e-4).all()
