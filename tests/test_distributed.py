"""Multi-device tests (subprocess with forced host devices): ring AIDW,
sharded train step, production-mesh construction."""

from __future__ import annotations

import pytest

from conftest import run_multidevice

# subprocess-spawning (8 forced host devices per test); moe-ep additionally
# needs the explicit-mesh API (ROADMAP 'Open items')
pytestmark = pytest.mark.slow


def test_ring_aidw_matches_single_device():
    out = run_multidevice("""
import numpy as np, jax
from repro.core import aidw_improved
from repro.core.distributed import ring_aidw, query_sharded_aidw

rng = np.random.default_rng(0)
pts = rng.random((1024, 3)).astype(np.float32)
q = rng.random((512, 2)).astype(np.float32)
mesh = jax.make_mesh((4, 2), ("data", "model"))
ref = np.asarray(aidw_improved(pts, q).values)
ring = np.asarray(ring_aidw(mesh, "data", pts, q))
qsh = np.asarray(query_sharded_aidw(mesh, pts, q))
assert np.abs(ring - ref).max() < 1e-5, np.abs(ring - ref).max()
assert np.abs(qsh - ref).max() < 1e-6, np.abs(qsh - ref).max()
print("ring-ok")
""")
    assert "ring-ok" in out


def test_ring_aidw_unpadded_sizes():
    out = run_multidevice("""
import numpy as np, jax
from repro.core import aidw_improved
from repro.core.distributed import ring_aidw

rng = np.random.default_rng(1)
pts = rng.random((1000, 3)).astype(np.float32)   # not divisible by 8
q = rng.random((300, 2)).astype(np.float32)
mesh = jax.make_mesh((8,), ("data",))
ref = np.asarray(aidw_improved(pts, q).values)
ring = np.asarray(ring_aidw(mesh, "data", pts, q))
assert ring.shape == (300,)
assert np.abs(ring - ref).max() < 1e-5
print("pad-ok")
""")
    assert "pad-ok" in out


def test_sharded_train_step_runs_and_matches_single():
    out = run_multidevice("""
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config, reduced
from repro.models import api, sharding
from repro.nn.param import init_params, make_shardings
from repro.optim import adamw
from repro.training import trainer
from repro.data.pipeline import LMStreamConfig, lm_batch

cfg = reduced(get_config("deepseek-7b"))
ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
step = trainer.make_train_step(cfg, ocfg)
stream = LMStreamConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
batch = {k: jnp.asarray(v) for k, v in lm_batch(stream, 0).items()}

# single-device reference
params = init_params(api.param_defs(cfg), jax.random.PRNGKey(0))
opt = trainer.init_opt_state(ocfg, params)
p_ref, _, m_ref = jax.jit(step)(params, opt, batch)

# sharded on a (4,2) mesh
mesh = jax.make_mesh((4, 2), ("data", "model"))
defs = api.param_defs(cfg)
psh = make_shardings(defs, mesh, sharding.param_rules(mesh))
with mesh:
    params2 = jax.device_put(init_params(defs, jax.random.PRNGKey(0)), psh)
    opt2 = trainer.init_opt_state(ocfg, params2)
    p_sh, _, m_sh = jax.jit(step)(params2, opt2, batch)
assert abs(float(m_ref["loss"]) - float(m_sh["loss"])) < 1e-4
diff = jax.tree.reduce(max, jax.tree.map(
    lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()),
    p_ref, p_sh))
assert diff < 1e-3, diff
print("shard-ok", diff)
""")
    assert "shard-ok" in out


def test_production_mesh_shapes():
    out = run_multidevice("""
import jax
from repro.launch.mesh import make_production_mesh, make_ring_mesh
m = make_production_mesh()
assert m.devices.shape == (16, 16) and m.axis_names == ("data", "model")
mp = make_production_mesh(multi_pod=True)
assert mp.devices.shape == (2, 16, 16)
assert mp.axis_names == ("pod", "data", "model")
r = make_ring_mesh(512)
assert r.devices.shape == (512,)
print("mesh-ok")
""", n_devices=512)
    assert "mesh-ok" in out


def test_expert_parallel_moe_matches_pjit_dispatch():
    out = run_multidevice("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.jax_compat import make_auto_mesh
from repro.nn.moe import moe_apply, moe_apply_ep

rng = np.random.default_rng(0)
E, D, F, topk = 8, 16, 32, 2
x = jnp.asarray(rng.normal(0,1,(4,16,D)), jnp.float32)
wr = jnp.asarray(rng.normal(0,0.5,(D,E)), jnp.float32)
wg = jnp.asarray(rng.normal(0,0.1,(E,D,F)), jnp.float32)
wu = jnp.asarray(rng.normal(0,0.1,(E,D,F)), jnp.float32)
wd = jnp.asarray(rng.normal(0,0.1,(E,F,D)), jnp.float32)
mesh = make_auto_mesh((2,4), ("data","model"))
ref = moe_apply(x, wr, wg, wu, wd, top_k=topk, capacity_factor=8.0)
with mesh:
    sh = lambda a: jax.device_put(a, NamedSharding(mesh, P("model")))
    out = jax.jit(lambda *a: moe_apply_ep(*a, top_k=topk, capacity_factor=8.0))(
        x, wr, sh(wg), sh(wu), sh(wd))
    g = jax.grad(lambda w: moe_apply_ep(x, wr, w, sh(wu), sh(wd), top_k=topk,
                                        capacity_factor=8.0).astype(jnp.float32).sum())(sh(wg))
g_ref = jax.grad(lambda w: moe_apply(x, wr, w, wu, wd, top_k=topk,
                                     capacity_factor=8.0).astype(jnp.float32).sum())(wg)
assert float(jnp.abs(out - ref).max()) < 1e-6
assert float(jnp.abs(g - g_ref).max()) < 1e-5
print("ep-ok")
""")
    assert "ep-ok" in out


def test_sharded_session_matches_single_device():
    """Acceptance: on an 8-device host-platform mesh, sharded session.query
    is bit-identical per query to the single-device session on the same
    plan, across mesh shapes and odd (bucketed) batch sizes."""
    out = run_multidevice("""
import numpy as np, jax
from repro.core import InterpolationSession
from repro.core.jax_compat import make_auto_mesh
from repro.data.pipeline import spatial_points, spatial_queries

pts = spatial_points(4096, seed=0)
qs = spatial_queries(1000, seed=1)       # odd size: exercises padded buckets
single = InterpolationSession(pts, query_domain=qs)
for shape, axes in (((8,), ("q",)), ((4, 2), ("data", "model"))):
    mesh = make_auto_mesh(shape, axes)
    sess = InterpolationSession(pts, query_domain=qs, mesh=mesh)
    assert sess.stats["devices"] == 8
    a, b = single.query(qs), sess.query(qs)
    assert np.array_equal(np.asarray(a.values), np.asarray(b.values)), shape
    assert np.array_equal(np.asarray(a.alpha), np.asarray(b.alpha))
    assert np.array_equal(np.asarray(a.r_obs), np.asarray(b.r_obs))
    assert a.overflow == b.overflow
    q2 = spatial_queries(997, seed=2)    # same bucket -> compile-cache hit
    assert np.array_equal(np.asarray(single.query(q2).values),
                          np.asarray(sess.query(q2).values))
    assert sess.stats["bucket_misses"] == 1 and sess.stats["bucket_hits"] == 1
print("sharded-session-ok")
""")
    assert "sharded-session-ok" in out


def test_sharded_session_delta_and_ring():
    """Delta updates re-place the sharded plan (still bit-identical), and the
    ring layout serves within brute-force-accumulation tolerance."""
    out = run_multidevice("""
import numpy as np, jax
from repro.core import InterpolationSession
from repro.core.jax_compat import make_auto_mesh
from repro.data.pipeline import spatial_points, spatial_queries

pts = spatial_points(4096, seed=0)
qs = spatial_queries(512, seed=1)
mesh = make_auto_mesh((8,), ("q",))
single = InterpolationSession(pts, query_domain=qs)
sess = InterpolationSession(pts, query_domain=qs, mesh=mesh)
dels = np.random.default_rng(3).choice(4096, 40, replace=False)
ins = spatial_points(40, seed=9)
for s in (single, sess):
    s.update(inserts=ins, deletes=dels)
assert sess.stats["delta_updates"] == 1 and sess.stats["stage1_builds"] == 1
a, b = single.query(qs), sess.query(qs)
assert np.array_equal(np.asarray(a.values), np.asarray(b.values))

ring = InterpolationSession(pts, query_domain=qs, mesh=mesh, layout="ring")
assert ring.sharded_plan.layout == "ring"
err = np.abs(np.asarray(ring.query(qs).values)
             - np.asarray(InterpolationSession(pts, query_domain=qs)
                          .query(qs).values)).max()
assert err < 1e-4, err
print("delta-ring-ok", err)
""")
    assert "delta-ring-ok" in out


def test_ring_aidw_query_blocking():
    out = run_multidevice("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import aidw_improved
from repro.core.distributed import make_ring_aidw
from repro.core.jax_compat import make_auto_mesh
rng = np.random.default_rng(0)
pts = rng.random((1024, 3)).astype(np.float32)
q = rng.random((512, 2)).astype(np.float32)
mesh = make_auto_mesh((8,), ("ring",))
ref = np.asarray(aidw_improved(pts, q).values)
for qb in (0, 17, 64):
    fn = make_ring_aidw(mesh, "ring", q_block=qb)
    out = fn(jnp.asarray(pts), jnp.asarray(q), jnp.float32(1024), jnp.float32(1.0))
    assert np.abs(np.asarray(out) - ref).max() < 1e-5, qb
print("qblock-ok")
""")
    assert "qblock-ok" in out


def test_slab_aidw_matches_single_device():
    out = run_multidevice("""
import numpy as np, jax
from repro.core import aidw_improved, AidwConfig
from repro.core.jax_compat import make_auto_mesh
from repro.core.slab import slab_aidw

rng = np.random.default_rng(3)
pts = rng.random((8192, 3)).astype(np.float32)
q = rng.random((2048, 2)).astype(np.float32)
mesh = make_auto_mesh((8,), ("ring",))
ref = np.asarray(aidw_improved(pts, q, AidwConfig(k=15, cell_factor=4.0)).values)
out, ovf = slab_aidw(mesh, "ring", pts, q, k=15, cell_factor=4.0, window=512)
assert ovf == 0
assert np.abs(out - ref).max() < 1e-5
print("slab-ok")
""")
    assert "slab-ok" in out
