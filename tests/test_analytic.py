"""Analytic FLOPs model validation vs exact (unrolled, single-device)
HLO cost analysis — the §Roofline compute-term source."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro.launch.analytic import cell_cost
from repro.models import api
from repro.models.api import ShapeSpec

# timing/HLO-census sensitive; broken on jax 0.4.x (ROADMAP 'Open items')
pytestmark = pytest.mark.slow
from repro.models.config import ModelConfig
from repro.nn.param import abstract_params
from repro.optim import adamw
from repro.training import trainer


def _exact_flops(cfg, shape):
    pa = abstract_params(api.param_defs(cfg))
    if shape.kind == "train":
        step = trainer.make_train_step(cfg, adamw.AdamWConfig())
        oa = jax.eval_shape(
            lambda p: trainer.init_opt_state(adamw.AdamWConfig(), p), pa)
        c = jax.jit(step).lower(pa, oa, api.input_specs(cfg, shape)).compile()
    elif shape.kind == "prefill":
        c = jax.jit(api.prefill_fn(cfg)).lower(
            pa, api.input_specs(cfg, shape)).compile()
    else:
        c = jax.jit(api.decode_fn(cfg)).lower(
            pa, api.cache_specs(cfg, shape), api.input_specs(cfg, shape)).compile()
    # jax 0.4.x returns a one-dict-per-module LIST; newer jax a flat dict
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return ca["flops"]


DENSE = ModelConfig(name="d", family="dense", n_layers=3, d_model=256,
                    vocab=1024, n_heads=8, n_kv_heads=4, d_ff=512,
                    dtype=jnp.bfloat16, remat=True, q_chunk=10**9,
                    unroll_layers=True)
MOE = ModelConfig(name="m", family="moe", n_layers=2, d_model=128, vocab=512,
                  n_heads=4, n_kv_heads=4, d_ff=256, n_experts=8, top_k=2,
                  moe_d_ff=64, dtype=jnp.bfloat16, remat=True, q_chunk=10**9,
                  unroll_layers=True)
SSM = ModelConfig(name="s", family="ssm", n_layers=3, d_model=128, vocab=512,
                  ssm_state=32, ssm_head_dim=32, ssm_chunk=64,
                  dtype=jnp.bfloat16, remat=True, unroll_layers=True)


@pytest.mark.parametrize("cfg,kind,lo,hi", [
    (DENSE, "train", 0.95, 1.10),     # matmul-exact; tiny elementwise slack
    (DENSE, "prefill", 0.90, 1.10),
    # decode: tiny absolute FLOPs, elementwise cache plumbing dominates the
    # residual — and decode cells are memory-bound, so the compute term's
    # precision is immaterial to the roofline verdict.
    (DENSE, "decode", 0.50, 1.30),
    (MOE, "train", 0.85, 1.10),       # router/scatter elementwise uncounted
    (SSM, "train", 0.60, 1.10),       # SSD fusion elementwise (VPU) uncounted
])
def test_analytic_within_band_of_exact(cfg, kind, lo, hi):
    shape = ShapeSpec("t", kind, 256, 8)
    exact = _exact_flops(cfg, shape)
    analytic = cell_cost(cfg, shape, n_chips=1, tensor_parallel=1).flops_global
    assert lo <= analytic / exact <= hi, (analytic, exact, analytic / exact)


def test_dot_census_matches_analytic_exactly():
    """Dot-only census of the compiled HLO == analytic matmul accounting."""
    import re

    cfg, kind = DENSE, "train"
    shape = ShapeSpec("t", kind, 256, 8)
    pa = abstract_params(api.param_defs(cfg))
    step = trainer.make_train_step(cfg, adamw.AdamWConfig())
    oa = jax.eval_shape(lambda p: trainer.init_opt_state(adamw.AdamWConfig(), p), pa)
    c = jax.jit(step).lower(pa, oa, api.input_specs(cfg, shape)).compile()
    text = c.as_text()
    # symbol table: instruction name -> dims (some printers omit operand types)
    shape_of = {}
    for line in text.splitlines():
        m = re.match(r"\s*(%[\w.\-]+) = \S*?\[([\d,]*)\]", line)
        if m:
            shape_of[m.group(1)] = [int(d) for d in m.group(2).split(",")] \
                if m.group(2) else []
    total = 0.0
    for line in text.splitlines():
        if " dot(" not in line:
            continue
        m = re.search(r"= \S*?\[([\d,]*)\]", line)
        out_elems = 1
        for d in (m.group(1).split(",") if m.group(1) else []):
            out_elems *= int(d)
        # lhs dims: 0.4.x prints operands WITH their types inline
        # (`dot(f32[2048,256]{1,0} %call.351, ...)`), newer jax without
        # (`dot(%call.351, ...)`) — read the inline shape when present,
        # fall back to the symbol table otherwise
        mt = re.search(r" dot\(\s*[\w!]+\[([\d,]*)\]", line)
        if mt:
            lhs = [int(d) for d in mt.group(1).split(",")] \
                if mt.group(1) else []
        else:
            ops = re.search(r" dot\((%[\w.\-]+), ", line)
            lhs = shape_of.get(ops.group(1), []) if ops else []
        mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
        kdims = [int(i) for i in mc.group(1).split(",")] if mc and mc.group(1) else []
        ksize = 1
        for i in kdims:
            if i < len(lhs):
                ksize *= lhs[i]
        total += 2.0 * out_elems * ksize
    analytic = cell_cost(cfg, shape, n_chips=1, tensor_parallel=1).flops_global
    # census excludes the ~10 flops/param optimizer elementwise
    assert 0.9 <= analytic / total <= 1.1, (analytic, total)
