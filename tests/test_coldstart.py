"""Cold-start subsystem: persistent compilation cache, AOT bucket-ladder
precompile, server/fleet prewarm, and the zero-compile-after-prewarm
invariants.

Every zero-compile assertion uses a dataset size unique within the test
process (distinct 64-multiple capacity buckets), so the in-memory jit cache
cannot pre-satisfy the shapes under test and ``precompile`` provably does
the compiling.  Zero-compile is asserted on EXACT ladder-bucket query
sizes — odd sizes additionally pay tiny one-off pad/sum helper compiles by
design (see the AOT contract in ``core/pipeline.py``).
"""

from __future__ import annotations

import os
import subprocess
import sys
import threading
from pathlib import Path

import numpy as np
import pytest

from repro.core import AidwConfig, InterpolationSession
from repro.core import pipeline as P
from repro.data.pipeline import spatial_points, spatial_queries
from repro.runtime import compile_cache

REPO = Path(__file__).resolve().parents[1]


def _selftest(cache_dir, *extra) -> dict:
    """Run the compile_cache selftest CLI in a fresh interpreter."""
    import json

    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.runtime.compile_cache",
         "--cache-dir", str(cache_dir), *extra],
        env=env, capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, f"{out.stdout}\n{out.stderr}"
    return json.loads(out.stdout)


def test_persistent_cache_second_process_hits(tmp_path):
    """The restart story end to end: a second process compiling the same
    canonical signature against the same cache directory deserializes
    instead of compiling (the CI cluster-suite assertion)."""
    first = _selftest(tmp_path / "cache")
    assert first["cache_dir"] == str(tmp_path / "cache")
    assert first["backend_compiles"] >= 1
    second = _selftest(tmp_path / "cache", "--min-hits", "1")
    assert second["persistent_cache_hits"] >= 1
    assert second["probe_s"] < first["probe_s"]


def test_enable_resolves_env_and_arg(tmp_path, monkeypatch):
    monkeypatch.delenv("AIDW_CACHE_DIR", raising=False)
    assert compile_cache.enable(None) is None      # listeners only
    monkeypatch.setenv("AIDW_CACHE_DIR", str(tmp_path / "env"))
    assert compile_cache.enable(None) == str(tmp_path / "env")
    assert (tmp_path / "env").is_dir()
    # explicit argument wins over the env var
    assert compile_cache.enable(str(tmp_path / "arg")) \
        == str(tmp_path / "arg")
    # leave the test process cache-less again
    import jax

    jax.config.update("jax_compilation_cache_dir", None)


def test_sync_registry_folds_deltas_not_totals():
    """Counters fold as per-registry DELTAS: syncing twice adds only what
    happened in between, so fleet merge_states stays additive."""
    from repro.obs import Registry

    compile_cache.install_listeners()
    reg = Registry()
    compile_cache.sync_registry(reg)              # baseline fold
    h0 = reg.counter("compile_cache_hits").value
    b0 = reg.counter("backend_compiles").value
    with compile_cache._LOCK:
        compile_cache._COUNTS["persistent_cache_hits"] += 3
        compile_cache._COUNTS["cache_requests"] += 5
        compile_cache._COUNTS["backend_compiles"] += 2
    delta = compile_cache.sync_registry(reg)
    assert delta["persistent_cache_hits"] == 3
    assert reg.counter("compile_cache_hits").value == h0 + 3
    assert reg.counter("compile_cache_misses").value >= 2
    assert reg.counter("backend_compiles").value == b0 + 2
    # nothing new happened: a second sync folds zero
    delta2 = compile_cache.sync_registry(reg)
    assert delta2["backend_compiles"] == 0
    assert reg.counter("compile_cache_hits").value == h0 + 3


def _zero_compile_ladder(sess, buckets):
    """First post-prewarm query of every ladder bucket: no new execute
    trace, no dispatch reaching the XLA compile layer."""
    anchor = np.asarray(sess._host_pts[0, :2], dtype=np.float32)
    t0, c0 = P.execute_traces(), compile_cache.backend_compiles()
    for b in buckets:
        r = sess.query(np.tile(anchor, (b, 1)))
        assert np.asarray(r.values).shape == (b,)
    return P.execute_traces() - t0, compile_cache.backend_compiles() - c0


@pytest.mark.parametrize("layout,points", [
    ("single", 2243), ("replicated", 2371),
    ("ring", 2503), ("grid_ring", 2633),
])
def test_precompile_ladder_zero_compile_all_layouts(layout, points):
    from repro.core.jax_compat import make_auto_mesh

    compile_cache.install_listeners()
    mesh = None if layout == "single" else make_auto_mesh((1,), ("q",))
    kw = {} if layout == "single" else {"layout": layout}
    sess = InterpolationSession(spatial_points(points, seed=0), AidwConfig(),
                                mesh=mesh,
                                query_domain=spatial_queries(512, seed=1),
                                **kw)
    buckets = sess.precompile(max_queries=256, warm=True)
    assert buckets == [64, 128, 256]
    assert sess.stats["aot_buckets"] == len(buckets)
    assert sess.registry.counter is not None     # registry wired
    dt, dc = _zero_compile_ladder(sess, buckets)
    assert dt == 0, f"{layout}: {dt} new execute traces post-prewarm"
    assert dc == 0, f"{layout}: {dc} backend compiles post-prewarm"
    # compile observability landed: one wall per compiled executable
    hist = sess.registry.snapshot()["histograms"]["session/compile_s"]
    assert hist["count"] >= len(buckets)


def test_precompile_results_match_lazy_session():
    """The AOT executables are the SAME computation: bit-identical values
    against a fresh lazily-compiled session on the same data."""
    pts = spatial_points(2767, seed=0)
    qs = spatial_queries(128, seed=2)             # exact bucket size
    qd = spatial_queries(512, seed=1)
    aot = InterpolationSession(pts, AidwConfig(), query_domain=qd)
    aot.precompile(buckets=[128], warm=True)
    lazy = InterpolationSession(pts, AidwConfig(), query_domain=qd)
    np.testing.assert_array_equal(np.asarray(aot.query(qs).values),
                                  np.asarray(lazy.query(qs).values))


def test_delta_update_keeps_aot_full_refresh_invalidates():
    compile_cache.install_listeners()
    pts = spatial_points(2129, seed=0)
    sess = InterpolationSession(pts, AidwConfig(),
                                query_domain=spatial_queries(512, seed=1))
    buckets = sess.precompile(max_queries=128, warm=True)
    lo, hi = pts[:, :2].min(axis=0), pts[:, :2].max(axis=0)
    ins = spatial_points(16, seed=3)
    ins[:, :2] = np.clip(ins[:, :2], lo, hi)      # stay inside the bbox
    sess.update(inserts=ins,
                deletes=np.arange(16))            # balanced: same capacity
    assert sess.stats["aot_buckets"] == len(buckets)
    dt, dc = _zero_compile_ladder(sess, buckets)
    assert (dt, dc) == (0, 0), "delta update must keep the AOT ladder live"
    # a full dataset refresh replans: the ladder is stale and must drop
    sess.update(points_xyz=spatial_points(4201, seed=4))
    assert sess.stats["aot_buckets"] == 0


def test_server_sync_prewarm_zero_postwarm_compiles():
    from repro.serving import AsyncAidwServer

    pts = spatial_points(2113, seed=0)
    with AsyncAidwServer(pts, max_batch=256, prewarm="sync",
                         query_domain=spatial_queries(512, seed=1)) as srv:
        st = srv.prewarm(wait=True, timeout=600)
        assert st["prewarmed"] and st["mode"] == "sync"
        assert st["aot_buckets"] == 3             # ladder 64/128/256
        anchor = np.asarray(pts[0, :2], dtype=np.float32)
        for b in (64, 128, 256):
            srv.result(srv.submit(np.tile(anchor, (b, 1))), timeout=600)
        rep = srv.report()
        assert rep["compile"]["post_warmup_compiles"] == 0
        assert rep["compile"]["prewarmed"] is True
        gauges = srv.debugz()["slo"]["gauges"]
        assert gauges["post_warmup_compiles"]["breaching"] is False


def test_server_background_prewarm_serves_while_compiling():
    from repro.serving import AsyncAidwServer

    pts = spatial_points(2179, seed=0)
    with AsyncAidwServer(pts, max_batch=256, prewarm="background",
                         query_domain=spatial_queries(512, seed=1)) as srv:
        # serving works immediately — lazily while the ladder compiles
        r = srv.result(srv.submit(spatial_queries(64, seed=2)), timeout=600)
        assert r.status == "done"
        st = srv.prewarm(wait=True, timeout=600)
        assert st["prewarmed"] and st["mode"] == "background"
        assert srv.report()["compile"]["aot_buckets"] == 3


def test_hot_path_compile_after_prewarm_is_flagged():
    """A compile reaching the worker AFTER prewarm is an anomaly: counter,
    SLO gauge, and flight-recorder event all fire.  Odd-size queries pay
    eager pad/sum helper compiles on first sight, which makes a convenient
    trigger."""
    from repro.serving import AsyncAidwServer

    pts = spatial_points(2339, seed=0)
    with AsyncAidwServer(pts, max_batch=256, prewarm="sync",
                         query_domain=spatial_queries(512, seed=1)) as srv:
        srv.result(srv.submit(spatial_queries(61, seed=2)), timeout=600)
        rep = srv.report()
        assert rep["compile"]["post_warmup_compiles"] > 0
        bundle = srv.debugz()
        assert bundle["slo"]["gauges"]["post_warmup_compiles"]["breaching"]
        kinds = [e["kind"] for e in bundle["recorder"]["events"]]
        assert "hot_path_compile" in kinds


def test_fleet_prewarm_then_first_batch_no_compile():
    from repro.serving.cluster import AidwCluster

    pts = spatial_points(1907, seed=0)
    with AidwCluster(pts, n_hosts=2, max_batch=256,
                     query_domain=spatial_queries(512, seed=1)) as cl:
        statuses = cl.prewarm(timeout=600)
        assert sorted(statuses) == [0, 1]
        assert all(s["prewarmed"] for s in statuses.values())
        anchor = np.asarray(pts[0, :2], dtype=np.float32)
        for _ in range(4):                        # round-robin hits both
            req = cl.submit(np.tile(anchor, (64, 1)))
            assert cl.result(req, timeout=600).status == "done"
        for h in cl.report()["hosts"]:
            assert h["compile"]["post_warmup_compiles"] == 0
            assert h["compile"]["prewarmed"] is True


def test_rpc_prewarm_wire():
    """The fleet control-plane prewarm op over the socket transport: a
    joining (remote) host compiles its ladder before entering rotation and
    serves its first routed batch without a hot-path compile."""
    from repro.serving.cluster.host import HostServer
    from repro.serving.cluster.rpc import (RemoteHost, free_port_base,
                                           serve_host)

    pts = spatial_points(1733, seed=0)
    host = HostServer(0, pts, max_batch=256,
                      query_domain=spatial_queries(512, seed=1))
    port = free_port_base(1)
    ready = threading.Event()
    t = threading.Thread(target=serve_host,
                         args=(host, ("127.0.0.1", port)),
                         kwargs={"ready_event": ready}, daemon=True)
    t.start()
    assert ready.wait(30)
    rh = RemoteHost(0, ("127.0.0.1", port))
    try:
        st = rh.prewarm(wait=True, timeout=600)
        assert st["prewarmed"] and st["aot_buckets"] == 3
        req = rh.submit(np.tile(np.asarray(pts[0, :2], dtype=np.float32),
                                (64, 1)))
        rh.wait(req, timeout=600)
        assert rh.report()["compile"]["post_warmup_compiles"] == 0
    finally:
        rh.close()
        t.join(30)


def test_cluster_config_cache_dir_from_env(monkeypatch, tmp_path):
    from repro.serving.cluster.bootstrap import ClusterConfig

    monkeypatch.setenv("AIDW_CACHE_DIR", str(tmp_path / "fleet"))
    cfg = ClusterConfig.from_env()
    assert cfg.cache_dir == str(tmp_path / "fleet")
    monkeypatch.delenv("AIDW_CACHE_DIR")
    assert ClusterConfig.from_env().cache_dir is None
    assert ClusterConfig.from_env(cache_dir="/x").cache_dir == "/x"
