"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp oracles (interpret)."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.aidw import ops as aidw_ops, ref as aidw_ref
from repro.kernels.knn import ops as knn_ops, ref as knn_ref


def _data(n, m, dtype, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.random((n, 2)), dtype)
    p = jnp.asarray(rng.random((m, 2)), dtype)
    z = jnp.asarray(np.sin(rng.random(m) * 7), dtype)
    a = jnp.asarray(rng.uniform(0.5, 4.0, n), dtype)
    return q, p, z, a


@pytest.mark.parametrize("n,m,tq,td", [
    (256, 512, 256, 512),     # exact tile fit
    (700, 1300, 256, 512),    # ragged both axes
    (64, 100, 8, 128),        # tiny tiles
    (1024, 256, 512, 128),    # more queries than data
    (1, 1, 8, 128),           # degenerate
])
def test_aidw_kernel_shapes_f32(n, m, tq, td):
    q, p, z, a = _data(n, m, jnp.float32)
    out, zero = aidw_ops.tiled_interpolate(q, p, z, a, tile_q=tq, tile_d=td,
                                           interpret=True)
    want = aidw_ref.interpolate_ref(q, p, z, a)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    assert not np.asarray(zero).any()


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 5e-2)])
def test_aidw_kernel_dtypes(dtype, tol):
    q, p, z, a = _data(300, 600, dtype)
    out, _ = aidw_ops.tiled_interpolate(q, p, z, a, tile_q=128, tile_d=256,
                                        interpret=True)
    want = aidw_ref.interpolate_ref(q, p, z, a)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_aidw_fused_alpha_kernel():
    q, p, z, _ = _data(300, 600, jnp.float32, seed=3)
    r_obs = jnp.asarray(np.random.default_rng(4).uniform(0, 0.1, 300), jnp.float32)
    out, _ = aidw_ops.fused_stage2(q, p, z, r_obs, n_points=600, area=1.0,
                                   tile_q=128, tile_d=256, interpret=True)
    want = aidw_ref.fused_stage2_ref(q, p, z, r_obs, n_points=600, area=1.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("n,m,k", [
    (256, 512, 15), (300, 600, 7), (33, 90, 15), (1, 64, 3),
])
def test_local_kernel_bitwise_vs_jnp_topk(n, m, k):
    """The local gather+weighting kernel is BITWISE the jnp top-k path
    (sequential k-axis accumulation makes lane padding a no-op)."""
    from repro.core import aidw as A, brute_knn

    q, p, z, a = _data(n, m, jnp.float32, seed=5)
    d2, idx = brute_knn(p, q, k)
    out, zero = aidw_ops.local_interpolate(d2, idx, z, a, tile_q=64,
                                           interpret=True)
    swz, sw = A.topk_weighted_partial_sums(d2, z[idx], a)
    want, wzero = A.guarded_values(swz, sw)
    assert (np.asarray(out) == np.asarray(want)).all()
    assert (np.asarray(zero) == np.asarray(wzero)).all()
    assert not np.isnan(np.asarray(out)).any()


def test_fused_local_kernel_bitwise_vs_unfused():
    """In-kernel alpha (Eqs. 2/4/5/6 from the SMEM stats block) is bitwise
    the host-side adaptive_alpha -> unfused local kernel chain."""
    from repro.core import aidw as A, brute_knn

    q, p, z, _ = _data(300, 600, jnp.float32, seed=6)
    d2, idx = brute_knn(p, q, 15)
    r_obs = jnp.sqrt(jnp.maximum(d2, 0.0)).mean(axis=1)
    alpha = A.adaptive_alpha(r_obs, jnp.float32(600), jnp.float32(1.0))
    fused, fzero = aidw_ops.fused_local_stage2(
        d2, idx, z, r_obs, n_points=jnp.float32(600), area=jnp.float32(1.0),
        tile_q=128, interpret=True)
    unf, uzero = aidw_ops.local_interpolate(d2, idx, z, alpha, tile_q=128,
                                            interpret=True)
    assert (np.asarray(fused) == np.asarray(unf)).all()
    assert (np.asarray(fzero) == np.asarray(uzero)).all()


def test_tiled_kernel_zero_weight_sentinel():
    """Global Pallas path: a query beyond f32 range from all data underflows
    every weight — 0.0 sentinel + raised mask bit, never NaN."""
    q = jnp.array([[1e18, 1e18], [0.5, 0.5]], jnp.float32)
    p = jnp.asarray(np.random.default_rng(8).random((64, 2)), jnp.float32)
    z = jnp.ones((64,), jnp.float32)
    out, zero = aidw_ops.tiled_interpolate(q, p, z, 4.0, tile_q=8,
                                           tile_d=128, interpret=True)
    assert not np.isnan(np.asarray(out)).any()
    assert np.asarray(zero)[0] and np.asarray(out)[0] == 0.0
    assert not np.asarray(zero)[1]


def test_local_kernel_zero_weight_sentinel():
    """All-inf neighbour distances (every weight underflows) must yield the
    0.0 sentinel + raised mask bit — never NaN."""
    d2 = jnp.full((4, 8), jnp.inf, jnp.float32)
    idx = jnp.zeros((4, 8), jnp.int32)
    z = jnp.ones((16,), jnp.float32)
    out, zero = aidw_ops.local_interpolate(d2, idx, z, 2.0, tile_q=8,
                                           interpret=True)
    assert np.asarray(zero).all()
    assert (np.asarray(out) == 0.0).all()


@pytest.mark.parametrize("n,m,k", [
    (256, 512, 15), (100, 300, 1), (70, 40, 8), (128, 128, 32), (33, 9, 15),
])
def test_knn_kernel_shapes(n, m, k):
    q, p, _, _ = _data(n, m, jnp.float32, seed=k)
    out = knn_ops.knn_d2(p, q, k=k, tile_q=64, tile_d=128, interpret=True)
    want = knn_ref.knn_d2_ref(p, q, k=k)
    fin = np.isfinite(np.asarray(want))
    np.testing.assert_allclose(np.asarray(out)[fin], np.asarray(want)[fin],
                               rtol=1e-5, atol=1e-7)
    assert (np.isfinite(np.asarray(out)) == fin).all()


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5), (jnp.bfloat16, 5e-2)])
def test_knn_kernel_dtypes(dtype, tol):
    q, p, _, _ = _data(200, 400, dtype, seed=9)
    out = knn_ops.knn_d2(p, q, k=10, tile_q=64, tile_d=128, interpret=True)
    want = knn_ref.knn_d2_ref(p, q, k=10)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_knn_kernel_duplicate_points():
    """k-pass masked-min must handle exact duplicate distances."""
    p = jnp.array([[0.5, 0.5]] * 20 + [[0.1, 0.1]] * 5, jnp.float32)
    q = jnp.array([[0.5, 0.5]], jnp.float32)
    out = knn_ops.knn_d2(p, q, k=21, tile_q=8, tile_d=128, interpret=True)
    want = knn_ref.knn_d2_ref(p, q, k=21)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-7)


def test_kernel_mean_distance_matches_core():
    from repro.core import brute_knn

    q, p, _, _ = _data(150, 350, jnp.float32, seed=11)
    d2k = knn_ops.knn_d2(p, q, k=15, interpret=True)
    d2c, _ = brute_knn(p, q, 15)
    np.testing.assert_allclose(np.asarray(knn_ops.mean_nn_distance(d2k)),
                               np.asarray(knn_ops.mean_nn_distance(d2c)),
                               rtol=1e-5)
