"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp oracles (interpret)."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.aidw import ops as aidw_ops, ref as aidw_ref
from repro.kernels.knn import ops as knn_ops, ref as knn_ref


def _data(n, m, dtype, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.random((n, 2)), dtype)
    p = jnp.asarray(rng.random((m, 2)), dtype)
    z = jnp.asarray(np.sin(rng.random(m) * 7), dtype)
    a = jnp.asarray(rng.uniform(0.5, 4.0, n), dtype)
    return q, p, z, a


@pytest.mark.parametrize("n,m,tq,td", [
    (256, 512, 256, 512),     # exact tile fit
    (700, 1300, 256, 512),    # ragged both axes
    (64, 100, 8, 128),        # tiny tiles
    (1024, 256, 512, 128),    # more queries than data
    (1, 1, 8, 128),           # degenerate
])
def test_aidw_kernel_shapes_f32(n, m, tq, td):
    q, p, z, a = _data(n, m, jnp.float32)
    out = aidw_ops.tiled_interpolate(q, p, z, a, tile_q=tq, tile_d=td,
                                     interpret=True)
    want = aidw_ref.interpolate_ref(q, p, z, a)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 5e-2)])
def test_aidw_kernel_dtypes(dtype, tol):
    q, p, z, a = _data(300, 600, dtype)
    out = aidw_ops.tiled_interpolate(q, p, z, a, tile_q=128, tile_d=256,
                                     interpret=True)
    want = aidw_ref.interpolate_ref(q, p, z, a)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_aidw_fused_alpha_kernel():
    q, p, z, _ = _data(300, 600, jnp.float32, seed=3)
    r_obs = jnp.asarray(np.random.default_rng(4).uniform(0, 0.1, 300), jnp.float32)
    out = aidw_ops.fused_stage2(q, p, z, r_obs, n_points=600, area=1.0,
                                tile_q=128, tile_d=256, interpret=True)
    want = aidw_ref.fused_stage2_ref(q, p, z, r_obs, n_points=600, area=1.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("n,m,k", [
    (256, 512, 15), (100, 300, 1), (70, 40, 8), (128, 128, 32), (33, 9, 15),
])
def test_knn_kernel_shapes(n, m, k):
    q, p, _, _ = _data(n, m, jnp.float32, seed=k)
    out = knn_ops.knn_d2(p, q, k=k, tile_q=64, tile_d=128, interpret=True)
    want = knn_ref.knn_d2_ref(p, q, k=k)
    fin = np.isfinite(np.asarray(want))
    np.testing.assert_allclose(np.asarray(out)[fin], np.asarray(want)[fin],
                               rtol=1e-5, atol=1e-7)
    assert (np.isfinite(np.asarray(out)) == fin).all()


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5), (jnp.bfloat16, 5e-2)])
def test_knn_kernel_dtypes(dtype, tol):
    q, p, _, _ = _data(200, 400, dtype, seed=9)
    out = knn_ops.knn_d2(p, q, k=10, tile_q=64, tile_d=128, interpret=True)
    want = knn_ref.knn_d2_ref(p, q, k=10)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_knn_kernel_duplicate_points():
    """k-pass masked-min must handle exact duplicate distances."""
    p = jnp.array([[0.5, 0.5]] * 20 + [[0.1, 0.1]] * 5, jnp.float32)
    q = jnp.array([[0.5, 0.5]], jnp.float32)
    out = knn_ops.knn_d2(p, q, k=21, tile_q=8, tile_d=128, interpret=True)
    want = knn_ref.knn_d2_ref(p, q, k=21)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-7)


def test_kernel_mean_distance_matches_core():
    from repro.core import brute_knn

    q, p, _, _ = _data(150, 350, jnp.float32, seed=11)
    d2k = knn_ops.knn_d2(p, q, k=15, interpret=True)
    d2c, _ = brute_knn(p, q, 15)
    np.testing.assert_allclose(np.asarray(knn_ops.mean_nn_distance(d2k)),
                               np.asarray(knn_ops.mean_nn_distance(d2c)),
                               rtol=1e-5)
