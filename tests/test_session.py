"""InterpolationSession: amortization counters, bucketing, bit-identity,
dataset refresh, fused Stage-2, and the session-backed serving engine."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AidwConfig, InterpolationSession, aidw_improved,
                        bucket_size, execute)
from repro.core import grid as G
from repro.core import pipeline as P
from repro.data.pipeline import spatial_points, spatial_queries


def test_bucket_size_powers_of_two():
    assert bucket_size(1) == 64          # floor
    assert bucket_size(63) == 64
    assert bucket_size(64) == 64
    assert bucket_size(65) == 128
    assert bucket_size(2048) == 2048
    assert bucket_size(2049) == 4096
    assert bucket_size(5, min_bucket=8) == 8
    with pytest.raises(ValueError):
        bucket_size(0)


def test_bucket_size_non_pow2_min_bucket():
    """Regression: a non-power-of-two ``min_bucket`` must round UP to a power
    of two, not seed a 48 -> 96 -> 192 doubling chain."""
    assert bucket_size(5, min_bucket=48) == 64
    assert bucket_size(100, min_bucket=48) == 128
    assert bucket_size(1, min_bucket=1) == 1
    assert bucket_size(3, min_bucket=3) == 4
    for mb in (1, 3, 7, 48, 100, 64):
        for n in (1, 5, 97, 1000):
            b = bucket_size(n, min_bucket=mb)
            assert b >= n and (b & (b - 1)) == 0, (n, mb, b)


def test_warm_query_bit_identical_to_cold(spatial_data):
    """Core acceptance: session.query == one-shot aidw_improved, bitwise."""
    pts, qs = spatial_data
    cold = aidw_improved(pts, qs)
    sess = InterpolationSession(pts, query_domain=qs)
    warm = sess.query(qs)
    assert np.array_equal(np.asarray(cold.values), np.asarray(warm.values))
    assert np.array_equal(np.asarray(cold.alpha), np.asarray(warm.alpha))
    assert np.array_equal(np.asarray(cold.r_obs), np.asarray(warm.r_obs))
    assert cold.overflow == warm.overflow == 0


def test_no_rebuild_or_retrace_across_same_bucket_queries(spatial_data):
    """Repeated odd-sized batches in one bucket: the jit cache is hit and
    Stage-1 (bin_points) is neither re-traced nor re-run."""
    pts, _ = spatial_data
    sess = InterpolationSession(pts, min_bucket=64)
    sess.query(spatial_queries(512, seed=2))        # compile the 512 bucket
    traces0, bins0 = P.execute_traces(), G.bin_traces()
    builds0 = sess.stats["stage1_builds"]
    for i in range(5):
        n = 512 - 3 * i
        res = sess.query(spatial_queries(n, seed=10 + i))
        assert res.values.shape == (n,)
    assert P.execute_traces() == traces0            # zero execute retraces
    assert G.bin_traces() == bins0                  # zero Stage-1 rebinning
    assert sess.stats["stage1_builds"] == builds0 == 1
    assert sess.stats["bucket_misses"] == 1
    assert sess.stats["bucket_hits"] == 5


def test_new_bucket_traces_exactly_once():
    # a dataset size unique to THIS test: n_points is a static jit arg, so no
    # other test file can have pre-compiled these signatures (the trace-delta
    # assertions below are only valid against a cold compile cache)
    pts = spatial_points(2051, seed=12)
    sess = InterpolationSession(pts, min_bucket=64)
    sess.query(spatial_queries(100, seed=0))        # 128 bucket
    t0 = P.execute_traces()
    sess.query(spatial_queries(200, seed=1))        # 256 bucket: one trace
    assert P.execute_traces() == t0 + 1
    sess.query(spatial_queries(255, seed=2))        # 256 again: cache hit
    assert P.execute_traces() == t0 + 1


def test_bucket_boundary_shapes_roundtrip(spatial_data):
    """n in {1, block-1, block, block+1} all pad, execute, and un-pad to
    results bit-identical to an unpadded execute on the same plan."""
    pts, qs = spatial_data
    block = 64
    sess = InterpolationSession(pts, min_bucket=block, query_domain=qs)
    for n in (1, block - 1, block, block + 1):
        warm = sess.query(qs[:n])
        want = execute(sess.plan, qs[:n])
        assert warm.values.shape == (n,)
        assert np.array_equal(np.asarray(warm.values), np.asarray(want.values))
        assert np.array_equal(np.asarray(warm.alpha), np.asarray(want.alpha))
        assert warm.overflow == want.overflow


def test_update_refreshes_dataset(spatial_data):
    pts, qs = spatial_data
    sess = InterpolationSession(pts, query_domain=qs)
    v_old = np.asarray(sess.query(qs).values)
    pts2 = spatial_points(pts.shape[0], seed=9)
    sess.update(pts2)
    v_new = np.asarray(sess.query(qs).values)
    cold2 = np.asarray(aidw_improved(pts2, qs).values)
    assert np.array_equal(v_new, cold2)             # serving == one-shot
    assert not np.array_equal(v_new, v_old)         # dataset really changed
    assert sess.stats["stage1_builds"] == 2


def _fixed_spec_plan(sess, pts_updated):
    """A plan from a FULL re-bin on the session's retained spec (the
    incremental path's equivalence reference)."""
    spec = sess.plan.spec
    table = G.bin_points(spec, jnp.asarray(pts_updated[:, 0]),
                         jnp.asarray(pts_updated[:, 1]),
                         jnp.asarray(pts_updated[:, 2]))
    return P.pad_plan(P.AidwPlan(spec=spec, table=table,
                                 points_xy=jnp.asarray(pts_updated[:, :2]),
                                 values=jnp.asarray(pts_updated[:, 2]),
                                 n_points=pts_updated.shape[0],
                                 area=sess.plan.area, cfg=sess.cfg))


def test_delta_update_matches_full_rebin(spatial_data):
    """update(inserts/deletes) == full re-bin at the retained spec, bitwise;
    Stage-1 is never rebuilt (delta_updates counts instead)."""
    pts, qs = spatial_data
    m = pts.shape[0]
    sess = InterpolationSession(pts, query_domain=qs)
    sess.query(qs)
    bins0 = G.bin_traces()
    dels = np.random.default_rng(0).choice(m, 25, replace=False)
    ins = spatial_points(30, seed=21)
    sess.update(inserts=ins, deletes=dels)
    assert sess.stats["delta_updates"] == 1
    assert sess.stats["stage1_builds"] == 1          # no full rebuild
    assert G.bin_traces() == bins0                   # sort core untouched

    keep = np.ones(m, bool)
    keep[dels] = False
    upd = np.concatenate([pts[keep], ins], axis=0)
    warm = sess.query(qs)
    want = execute(_fixed_spec_plan(sess, upd), qs)
    assert np.array_equal(np.asarray(warm.values), np.asarray(want.values))
    assert np.array_equal(np.asarray(warm.alpha), np.asarray(want.alpha))


def test_delta_update_deltas_tuple_and_engine(spatial_data):
    """The ``deltas=(inserts, deletes)`` spelling and the engine passthrough."""
    from repro.serving import AidwEngine

    pts, qs = spatial_data
    sess = InterpolationSession(pts, query_domain=qs)
    sess.update(deltas=(spatial_points(10, seed=3),
                        np.arange(10)))
    assert sess.stats["delta_updates"] == 1

    eng = AidwEngine(pts, query_domain=qs)
    eng.update_dataset(inserts=spatial_points(10, seed=4), deletes=[0, 1])
    assert eng.session.stats["delta_updates"] == 1
    assert eng.session.stats["stage1_builds"] == 1


def test_update_argument_validation(spatial_data):
    """Bad update() spellings fail loudly instead of silently diverging."""
    from repro.core.jax_compat import make_auto_mesh

    pts, qs = spatial_data
    sess = InterpolationSession(pts, query_domain=qs)
    with pytest.raises(ValueError):
        sess.update()                                # nothing to update
    with pytest.raises(ValueError):
        sess.update(pts, inserts=pts[:1])            # full AND delta
    with pytest.raises(IndexError):
        sess.update(deletes=[-1])                    # would wrap silently
    with pytest.raises(IndexError):
        sess.update(deletes=[pts.shape[0]])
    with pytest.raises(ValueError):                  # layout typo
        InterpolationSession(pts, mesh=make_auto_mesh((1,), ("q",)),
                             layout="auto")


def test_delta_update_fallback_paths(spatial_data):
    """Oversized deltas and out-of-bbox inserts fall back to a full re-plan."""
    pts, qs = spatial_data
    m = pts.shape[0]
    sess = InterpolationSession(pts, query_domain=qs)
    sess.update(inserts=spatial_points(m, seed=7))   # > max_delta_frac * m
    assert sess.stats["stage1_builds"] == 2
    assert sess.stats["delta_updates"] == 0

    out = np.array([[50.0, 50.0, 1.0]], np.float32)  # far outside the grid
    sess.update(inserts=out)
    assert sess.stats["stage1_builds"] == 3          # bbox fallback
    assert sess.stats["delta_updates"] == 0
    # ... and the re-planned session still answers (the degenerate geometry
    # overflows the candidate window, where only tolerance — not bitwise —
    # equality is contractual)
    want = execute(sess.plan, qs)
    got = sess.query(qs)
    assert got.overflow == want.overflow
    np.testing.assert_allclose(np.asarray(got.values),
                               np.asarray(want.values), rtol=1e-5, atol=1e-6)


def test_sharded_session_single_device_mesh(spatial_data):
    """mesh= on a 1-device mesh: same API, bit-identical results, shard-aware
    stats.  (The real 8-lane partition runs in tests/test_distributed.py.)"""
    from repro.core.jax_compat import make_auto_mesh

    pts, qs = spatial_data
    mesh = make_auto_mesh((1,), ("q",))
    single = InterpolationSession(pts, query_domain=qs)
    sharded = InterpolationSession(pts, query_domain=qs, mesh=mesh)
    assert sharded.stats["devices"] == 1
    assert sharded.sharded_plan.layout == "replicated"
    a, b = single.query(qs), sharded.query(qs)
    assert np.array_equal(np.asarray(a.values), np.asarray(b.values))
    assert np.array_equal(np.asarray(a.r_obs), np.asarray(b.r_obs))
    assert a.overflow == b.overflow
    # delta update keeps working through the sharded placement
    sharded.update(inserts=spatial_points(8, seed=5), deletes=[0, 1, 2])
    single.update(inserts=spatial_points(8, seed=5), deletes=[0, 1, 2])
    a, b = single.query(qs), sharded.query(qs)
    assert np.array_equal(np.asarray(a.values), np.asarray(b.values))
    assert sharded.stats["delta_updates"] == 1


def test_fused_session_matches_unfused(spatial_data):
    """AidwConfig(fused=True) routes Stage 2 through the alpha-in-kernel
    Pallas path; predictions agree with the two-launch path within 1e-5."""
    pts, qs = spatial_data
    unfused = InterpolationSession(pts, query_domain=qs)
    fused_cfg = AidwConfig(stage2="tiled", fused=True, interpret=True,
                           tile_q=128, tile_d=256)
    fused = InterpolationSession(pts, fused_cfg, query_domain=qs)
    ref = np.asarray(unfused.query(qs).values)
    got = np.asarray(fused.query(qs).values)
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


def test_aidw_engine_coalesces_and_matches(spatial_data):
    from repro.serving import AidwEngine, InterpolationRequest

    pts, qs = spatial_data
    eng = AidwEngine(pts, max_batch=256, query_domain=qs)
    reqs = [InterpolationRequest(uid=i, queries_xy=qs[64 * i:64 * (i + 1)])
            for i in range(6)]
    stats = eng.run(reqs)
    assert all(r.done for r in reqs)
    got = np.concatenate([r.values for r in reqs])
    want = np.asarray(execute(eng.session.plan, qs[:384]).values)
    assert np.array_equal(got, want)
    assert stats["batches"] < len(reqs)             # FIFO coalescing happened
    assert stats["queries"] == 384
    assert eng.session.stats["stage1_builds"] == 1  # zero per-request rebuilds


def test_aidw_engine_dataset_refresh(spatial_data):
    from repro.serving import AidwEngine, InterpolationRequest

    pts, qs = spatial_data
    eng = AidwEngine(pts, query_domain=qs)
    r1 = InterpolationRequest(uid=0, queries_xy=qs[:128])
    eng.run([r1])
    eng.update_dataset(spatial_points(pts.shape[0], seed=11))
    r2 = InterpolationRequest(uid=1, queries_xy=qs[:128])
    eng.run([r2])
    assert eng.session.stats["stage1_builds"] == 2
    assert not np.array_equal(r1.values, r2.values)


# ---------------------------------------------------------------------------
# n_points-churn retrace regression (the PR 6 bugfix): n_points is a TRACED
# scalar and plan arrays are capacity-padded, so dataset-RESIZING deltas that
# stay inside one 64-row capacity bucket must never retrace any executor.
# ---------------------------------------------------------------------------


def _churn(sess, sizes=(10, -5, 20, -25)):
    """Apply resizing deltas (net n_points change each step)."""
    from repro.data.pipeline import spatial_points

    for i, d in enumerate(sizes):
        if d > 0:
            sess.update(inserts=spatial_points(d, seed=50 + i))
        else:
            sess.update(deletes=np.arange(-d))


def test_churn_within_capacity_bucket_never_retraces():
    """Single layout: +10/-5/+20/-25 point churn (all inside the 3072-row
    capacity bucket) keeps the execute trace count frozen while the served
    values actually change."""
    from repro.data.pipeline import spatial_points, spatial_queries

    # dataset size unique to THIS test (see test_new_bucket_traces_exactly_once)
    pts = spatial_points(3037, seed=30)
    qs = spatial_queries(256, seed=31)
    sess = InterpolationSession(pts, query_domain=qs)
    v0 = np.asarray(sess.query(qs).values)
    t0, b0 = P.execute_traces(), G.bin_traces()
    _churn(sess)
    assert sess.plan.points_xy.shape[0] == 3072     # capacity bucket held
    v1 = np.asarray(sess.query(qs).values)
    assert P.execute_traces() == t0                 # ZERO retraces on churn
    assert G.bin_traces() == b0                     # delta path, no re-bin
    assert sess.stats["delta_updates"] == 4
    assert not np.array_equal(v0, v1)               # dataset really changed


def test_churn_replicated_mesh_never_retraces():
    """Replicated mesh layout: the shard_map body is _execute_core, so the
    same counter proves the mesh executor survived resizing churn."""
    from repro.core.jax_compat import make_auto_mesh
    from repro.data.pipeline import spatial_points, spatial_queries

    pts = spatial_points(3101, seed=32)             # unique size
    qs = spatial_queries(256, seed=33)
    sess = InterpolationSession(pts, query_domain=qs,
                                mesh=make_auto_mesh((1,), ("q",)))
    sess.query(qs)
    t0 = P.execute_traces()
    _churn(sess)
    sess.query(qs)
    assert P.execute_traces() == t0
    assert sess.stats["delta_updates"] == 4


@pytest.mark.parametrize("layout", ["ring", "grid_ring"])
def test_churn_ring_layouts_never_retrace(layout):
    """Ring layouts: n_points rides through the ring executors as a traced
    scalar and the packet arrays are capacity-padded, so resizing churn
    reuses the ONE compiled signature (jit cache size stays 1)."""
    from repro.core.jax_compat import make_auto_mesh
    from repro.data.pipeline import spatial_points, spatial_queries

    pts = spatial_points(3163 if layout == "ring" else 3217, seed=34)
    qs = spatial_queries(256, seed=35)
    mesh = make_auto_mesh((1,), ("q",))
    sess = InterpolationSession(pts, query_domain=qs, mesh=mesh,
                                layout=layout)
    sess.query(qs)
    sp = sess.sharded_plan
    if layout == "ring":
        fn = P.ring_session_execute(sp.mesh, sp.ring_axis, sess.plan.cfg)
    else:
        fn = P.grid_ring_session_execute(
            sp.mesh, sp.ring_axis, sess.plan.cfg, sess.plan.spec, sp.rps,
            sp.halo, sp.max_level)
    # the cached executor is shared process-wide (keyed by mesh/cfg), so
    # other suites may have compiled other shapes already — the invariant
    # is that churn adds ZERO new signatures, not an absolute count
    n0 = fn._cache_size()
    assert n0 >= 1
    _churn(sess)
    sess.query(qs)
    assert fn._cache_size() == n0                   # zero retraces on churn
    assert sess.stats["delta_updates"] == 4
