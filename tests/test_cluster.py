"""Multi-host AIDW serving cluster: epoch protocol, routing + draining,
fleet telemetry merge, and multi-process jax.distributed fleets.

Acceptance criteria covered here (ISSUE 4):
(a) a 2-host cluster serving an interleaved query+churn workload (3
    CONCURRENT ``update_dataset`` calls) returns results bit-identical to a
    single ``AsyncAidwServer`` applying the same epochs sequentially;
(b) a host dying mid-stream is drained by the router with no lost or
    duplicated request;
(c) per-host telemetry merges into fleet p50/p95/p99 + QPS;
plus the slow-marked 2-process x 4-forced-host-device test that runs the
whole stack — ``jax.distributed`` bootstrap, socket control plane, epoch
broadcast — across REAL process boundaries (the CI cluster-suite job).
"""

from __future__ import annotations

import threading

import numpy as np
import pytest

from conftest import REPO
from repro.data.pipeline import spatial_points, spatial_queries
from repro.serving import (AdmissionQueueFull, AsyncAidwServer,
                           LatencyHistogram, Telemetry)
from repro.serving.cluster import (AidwCluster, EpochApplier,
                                   EpochCoordinator, EpochUpdate,
                                   NoLiveHosts, Router, merge_reports)


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t


# ---------------------------------------------------------------------------
# epoch protocol
# ---------------------------------------------------------------------------


def test_epoch_coordinator_monotonic_under_concurrency():
    coord = EpochCoordinator()
    got: list[int] = []
    lock = threading.Lock()

    def assign(k):
        for _ in range(50):
            e = coord.assign(inserts=k).epoch
            with lock:
                got.append(e)

    ts = [threading.Thread(target=assign, args=(k,)) for k in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    # 200 assignments -> epochs 1..200, each exactly once, log in order
    assert sorted(got) == list(range(1, 201))
    assert [u.epoch for u in coord.log] == list(range(1, 201))
    assert coord.epoch == 200
    assert [u.epoch for u in coord.since(197)] == [198, 199, 200]


def test_epoch_applier_orders_buffers_and_dedups():
    applied: list[int] = []

    def enqueue(upd):
        applied.append(upd.epoch)
        return object()

    ap = EpochApplier(enqueue)
    h2 = ap.offer(EpochUpdate(epoch=2))          # early: buffered
    assert applied == [] and not h2.wait_bound(0)
    h1 = ap.offer(EpochUpdate(epoch=1))          # fills the gap: 1 then 2
    assert applied == [1, 2]
    assert h1.wait_bound(0) and h2.wait_bound(0)
    dup = ap.offer(EpochUpdate(epoch=1))         # stale: idempotent drop
    assert dup.duplicate and applied == [1, 2]
    assert ap.counters == {"enqueued": 2, "buffered": 1, "duplicates": 1}
    ap.offer(EpochUpdate(epoch=3))
    assert applied == [1, 2, 3] and ap.next_epoch == 4


def test_server_epoch_stamping_and_order_guard(spatial_data):
    """Server hooks: requests are stamped with the epoch they were served
    under; explicit (cluster) epochs pin the counter and must increase."""
    pts, qs = spatial_data
    with AsyncAidwServer(pts, max_batch=256, query_domain=qs) as srv:
        r0 = srv.submit(qs[:32])
        srv.flush(timeout=120)
        assert srv.epoch == 0 and r0.epoch == 0
        srv.update_dataset(inserts=spatial_points(8, seed=3), timeout=120)
        r1 = srv.submit(qs[:32])
        srv.flush(timeout=120)
        assert srv.epoch == 1 and r1.epoch == 1
        srv.update_dataset(inserts=spatial_points(8, seed=4), epoch=7,
                           timeout=120)
        assert srv.epoch == 7
        with pytest.raises(RuntimeError, match="epoch"):
            srv.update_dataset(inserts=spatial_points(8, seed=5), epoch=7,
                               timeout=120)
        r2 = srv.submit(qs[:32])                 # worker survived the guard
        assert srv.result(r2, timeout=120).epoch == 7


def test_withdrawn_epoch_update_leaves_detectable_gap(spatial_data):
    """Review regression: a withdrawn (timed-out) explicit-epoch barrier is
    a HOLE in the host's update order — later deltas must refuse (the
    monotonicity guard alone cannot see the gap), a full refresh heals it,
    and a retried wait on the skipped op must not read as success."""
    from repro.serving.server import _UpdateOp

    pts, qs = spatial_data
    with AsyncAidwServer(pts, max_batch=256, query_domain=qs) as srv:
        op = _UpdateOp(inserts=spatial_points(8, seed=3), epoch=1,
                       cancelled=True)     # withdrawn before the worker ran
        srv._apply_update(op)              # worker skip path, deterministic
        assert op.skipped and op.applied.is_set()
        with pytest.raises(TimeoutError, match="withdrawn"):
            srv.wait_update(op, timeout=1.0)
        with pytest.raises(RuntimeError, match="missed epoch 1"):
            srv.update_dataset(inserts=spatial_points(8, seed=4), epoch=2,
                               timeout=120)
        srv.update_dataset(pts, epoch=3, timeout=120)   # full re-sync heals
        r = srv.submit(qs[:16])
        assert srv.result(r, timeout=120).epoch == 3
        srv.update_dataset(inserts=spatial_points(8, seed=5), epoch=4,
                           timeout=120)    # deltas flow again post-heal
        assert srv.epoch == 4


# ---------------------------------------------------------------------------
# fleet telemetry merge
# ---------------------------------------------------------------------------


def test_histogram_merge_matches_single_histogram():
    rng = np.random.default_rng(0)
    samples = rng.exponential(0.05, 400)
    one = LatencyHistogram()
    parts = [LatencyHistogram() for _ in range(3)]
    for i, s in enumerate(samples):
        one.record(s)
        parts[i % 3].record(s)
    merged = LatencyHistogram.from_states(p.state() for p in parts)
    got, want = merged.snapshot(), one.snapshot()
    # mean sums floats in a different order; everything else is exact
    assert got["mean_s"] == pytest.approx(want["mean_s"])
    for k in ("count", "p50_s", "p95_s", "p99_s", "max_s"):
        assert got[k] == want[k], k
    with pytest.raises(ValueError):              # mismatched bins are loud
        one.merge_state(LatencyHistogram(bins_per_decade=5).state())


def test_merge_reports_sums_counters_and_rates():
    class _R:
        queries_xy = np.zeros((4, 2), np.float32)
        overflow = 1
        t_submit, t_dispatch, t_done = 1.0, 2.0, 3.0

    reports = []
    for host_id in range(2):
        t = Telemetry()
        t.record_submit(_R())
        t.record_batch([_R()], 0.5)
        reports.append({"merge": t.state(), "epoch": 2 + host_id,
                        "host_id": host_id, "admission": {"admitted": 3}})
    fleet = merge_reports(reports)
    assert fleet["hosts"] == 2 and fleet["host_ids"] == [0, 1]
    assert fleet["completed"] == 2 and fleet["queries"] == 8
    assert fleet["overflow_queries"] == 2
    assert fleet["admission"] == {"admitted": 6}
    assert fleet["epoch_min"] == 2 and fleet["epoch_max"] == 3
    # fleet QPS = sum(queries) over the UNION wall window (PR 8); the
    # legacy summed rate stays observable as queries_per_s_summed
    ws = [r["merge"]["window"] for r in reports]
    t0 = min(w["t0_wall"] for w in ws)
    t1 = max(w["t1_wall"] for w in ws)
    assert fleet["queries_per_s"] == pytest.approx(
        sum(w["queries"] for w in ws) / (t1 - t0))
    assert fleet["queries_per_s_summed"] == pytest.approx(
        sum(r["merge"]["queries_per_s"] for r in reports))
    assert fleet["latency"]["total"]["count"] == 2


# ---------------------------------------------------------------------------
# router (stub hosts: policy + heartbeat draining without jax in the loop)
# ---------------------------------------------------------------------------


class StubRequest:
    def __init__(self, queries_xy, deadline_s):
        self.queries_xy = queries_xy
        self.deadline_s = deadline_s
        self.done = False
        self.status = "queued"
        self.values = None
        self.overflow = 0
        self.epoch = 0


class StubHost:
    """Scriptable host: instant serve unless ``hold`` / ``dead`` /
    ``full`` (backpressure: submit raises AdmissionQueueFull)."""

    def __init__(self, host_id, depth=0):
        self.host_id = host_id
        self.depth = depth
        self.dead = False
        self.hold = False
        self.full = False
        self.submitted: list[StubRequest] = []

    def submit(self, queries_xy, *, deadline_s=None, uid=None, timeout=None):
        if self.dead:
            raise RuntimeError("stub host is dead")
        if self.full:
            raise AdmissionQueueFull("stub queue full")
        req = StubRequest(queries_xy, deadline_s)
        self.submitted.append(req)
        if not self.hold:
            req.done, req.status = True, "done"
            req.values = np.zeros(len(queries_xy), np.float32)
        return req

    def wait(self, req, timeout=None):
        if self.dead:
            raise RuntimeError("stub host is dead")
        if not req.done:
            raise TimeoutError("stub pending")
        return req

    def queue_depth(self):
        if self.dead:
            raise RuntimeError("stub host is dead")
        return self.depth

    def probe(self):
        return self.queue_depth()


def _q(n=4):
    return np.zeros((n, 2), np.float32)


def test_router_round_robin_alternates_and_least_loaded_prefers_shallow():
    a, b = StubHost(0), StubHost(1)
    rr = Router([a, b], clock=FakeClock())
    for _ in range(4):
        rr.route(_q())
    assert [len(a.submitted), len(b.submitted)] == [2, 2]

    a2, b2 = StubHost(0, depth=5), StubHost(1, depth=0)
    ll = Router([a2, b2], policy="least_loaded", clock=FakeClock())
    for _ in range(4):
        ll.route(_q())
    assert len(b2.submitted) == 4 and len(a2.submitted) == 0
    with pytest.raises(ValueError):
        Router([a, b], policy="random")


def test_router_least_loaded_drains_host_that_fails_depth_probe():
    """Review regression: a dead host raising from its queue_depth() probe
    is drained inside host selection, not allowed to wedge every route."""
    a, b = StubHost(0), StubHost(1)
    r = Router([a, b], policy="least_loaded", clock=FakeClock())
    a.dead = True
    req = r.route(_q())
    assert r.live_hosts() == [1] and r.counters["drained_hosts"] == 1
    assert req.status == "done" and req.attempts[0][0] == 1


def test_router_validates_queries_without_draining():
    a, b = StubHost(0), StubHost(1)
    r = Router([a, b], clock=FakeClock())
    for bad in (np.zeros((4, 3), np.float32), np.zeros((0, 2), np.float32),
                np.zeros((4, 2), np.int32)):
        with pytest.raises(ValueError):
            r.route(bad)
    assert r.live_hosts() == [0, 1]              # malformed input != death


def test_router_heartbeat_timeout_probes_then_drains_and_resubmits():
    clock = FakeClock()
    a, b = StubHost(0), StubHost(1)
    a.hold = True                                # a accepts but never serves
    r = Router([a, b], heartbeat_timeout_s=10.0, clock=clock)
    stuck = r.route(_q())                        # round-robin -> host 0
    assert stuck.attempts[0][0] == 0 and not stuck.done
    clock.t = 11.0
    r.beat(1)                                    # b is alive, a went silent
    # stale heartbeat alone is NOT death: a still answers its probe
    assert r.check() == [] and r.live_hosts() == [0, 1]
    clock.t = 23.0
    a.dead = True                                # now the probe fails too
    assert r.check() == [0]
    assert r.live_hosts() == [1]
    # the stuck request was resubmitted to b, which serves instantly
    assert stuck.attempts[-1][0] == 1
    assert r.wait(stuck, timeout=5.0).status == "done"
    assert r.counters["resubmitted"] == 1 and r.counters["drained_hosts"] == 1


def test_router_idle_fleet_not_drained_by_quiet_period():
    """Review regression: hosts untouched for > heartbeat_timeout_s pass
    their probe and stay in rotation — an idle fleet must not silently
    collapse (there is no re-admission path yet)."""
    clock = FakeClock()
    a, b = StubHost(0), StubHost(1)
    r = Router([a, b], heartbeat_timeout_s=10.0, clock=clock)
    clock.t = 120.0                              # long quiet period
    assert r.check() == [] and r.live_hosts() == [0, 1]
    req = r.route(_q())                          # still serves normally
    assert r.wait(req, timeout=5.0).status == "done"


def test_router_backpressure_is_not_death():
    """Review regression: AdmissionQueueFull routes around the full host
    without draining it; an all-full fleet surfaces backpressure to the
    caller like a single server would."""
    a, b = StubHost(0), StubHost(1)
    r = Router([a, b], clock=FakeClock())
    a.full = True
    for _ in range(3):
        assert r.wait(r.route(_q()), timeout=5.0).status == "done"
    assert len(b.submitted) == 3 and len(a.submitted) == 0
    assert r.live_hosts() == [0, 1]              # a stayed in rotation
    b.full = True
    with pytest.raises(AdmissionQueueFull):
        r.route(_q())
    assert r.live_hosts() == [0, 1]


def test_router_fleet_wide_death_fails_requests_not_hangs():
    a, b = StubHost(0), StubHost(1)
    a.hold = b.hold = True
    r = Router([a, b], clock=FakeClock())
    req = r.route(_q())
    a.dead = b.dead = True
    r.drain(0)                                   # cascade: resubmit hits b,
    assert req.status == "failed" and req.done   # b dead too -> failed, not
    assert r.live_hosts() == []                  # an exception or a hang
    with pytest.raises(NoLiveHosts):
        r.route(_q())


# ---------------------------------------------------------------------------
# 2-host cluster: bit-identity + host death (in-process, CI-fast)
# ---------------------------------------------------------------------------


def _replay_reference(pts, qd, log, pre, post, max_batch=256):
    """Single AsyncAidwServer applying the coordinator's epoch log between
    the same two query waves; returns (pre_results, post_results)."""
    with AsyncAidwServer(pts, max_batch=max_batch, query_domain=qd) as ref:
        r_pre = [ref.submit(q) for q in pre]
        ref.flush(timeout=300)
        for u in log:
            ref.update_dataset(u.points_xyz, inserts=u.inserts,
                               deletes=u.deletes, timeout=300)
        r_post = [ref.submit(q) for q in post]
        ref.flush(timeout=300)
    return r_pre, r_post


def test_cluster_bit_identical_to_single_server_across_concurrent_updates(
        spatial_data):
    """Acceptance (a): interleaved queries + 3 CONCURRENT update_dataset
    calls; every result bit-identical to one server applying the same
    epochs sequentially, on both waves and on every host."""
    pts, qs = spatial_data
    qd = spatial_queries(1024, seed=1)
    pre = [qs[64 * i:64 * (i + 1)] for i in range(4)]
    post = [qs[64 * i:64 * (i + 1)] for i in range(4, 8)]
    with AidwCluster(pts, n_hosts=2, max_batch=256, query_domain=qd) as cl:
        w0 = [cl.submit(q) for q in pre]

        def upd(k):
            cl.update_dataset(
                inserts=spatial_points(16, seed=40 + k),
                deletes=np.arange(k * 16, (k + 1) * 16), timeout=300)

        ts = [threading.Thread(target=upd, args=(k,)) for k in range(3)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        w1 = [cl.submit(q) for q in post]
        cl.flush(timeout=300)
        log = list(cl.coordinator.log)
        rep = cl.report()
    assert len(log) == 3 and [u.epoch for u in log] == [1, 2, 3]
    # both hosts applied all three epochs (fleet-wide consistency)
    assert rep["fleet"]["epoch_min"] == rep["fleet"]["epoch_max"] == 3
    # queries were actually spread over both hosts
    assert sorted({r.host_id for r in w0 + w1}) == [0, 1]
    # epoch stamps witness the contract: wave 0 pre-churn, wave 1 post
    assert all(r.epoch == 0 for r in w0)
    assert all(r.epoch == 3 for r in w1)

    r0, r1 = _replay_reference(pts, qd, log, pre, post)
    for got, want in zip(w0 + w1, r0 + r1):
        assert got.status == "done"
        assert np.array_equal(np.asarray(got.values),
                              np.asarray(want.values))
    # exactly-once: every uid distinct, every request terminal
    assert len({r.uid for r in w0 + w1}) == 8


def test_cluster_host_death_mid_stream_no_lost_or_duplicated(spatial_data):
    """Acceptance (b): a host dies mid-stream; the router drains it,
    resubmits its unserved requests, and results still match the
    single-server reference (same epochs)."""
    pts, qs = spatial_data
    qd = spatial_queries(1024, seed=1)
    batches = [qs[32 * i:32 * (i + 1)] for i in range(8)]
    with AidwCluster(pts, n_hosts=2, max_batch=256, query_domain=qd) as cl:
        warm = [cl.submit(q) for q in batches[:2]]
        cl.flush(timeout=300)
        epoch = cl.update_dataset(inserts=spatial_points(16, seed=9),
                                  deletes=np.arange(16), timeout=300)
        assert epoch == 1

        def boom(*a, **k):
            raise RuntimeError("injected host fault")

        cl.hosts[1].server.session.query = boom   # dies on next dispatch
        reqs = [cl.submit(q) for q in batches]
        cl.flush(timeout=300)
        rep = cl.report()
        assert rep["routing"]["live_hosts"] == [0]
        assert rep["routing"]["drained_hosts"] == 1
        assert rep["routing"]["resubmitted"] >= 1
        # no lost (all terminal, served), no duplicated (distinct uids,
        # resolved exactly once)
        assert all(r.status == "done" and r.values is not None
                   for r in warm + reqs)
        assert len({r.uid for r in warm + reqs}) == 10
        log = list(cl.coordinator.log)
    with AsyncAidwServer(pts, max_batch=256, query_domain=qd) as ref:
        for u in log:
            ref.update_dataset(u.points_xyz, inserts=u.inserts,
                               deletes=u.deletes, timeout=300)
        want = [ref.submit(q) for q in batches]
        ref.flush(timeout=300)
    for got, w in zip(reqs, want):
        assert np.array_equal(np.asarray(got.values), np.asarray(w.values))


def test_cluster_kill_mid_batch_keeps_one_connected_trace(spatial_data):
    """ISSUE 8 acceptance: a host killed mid-batch with tracing on.  The
    drain-resubmission records a ``resubmit`` span as a CHILD of the
    original request's route root on the SAME trace — one connected trace
    per request, zero lost spans (every done request has exactly one
    serving span set) and zero duplicated ones (the dead host never
    scattered, so it contributed none)."""
    pts, qs = spatial_data
    qd = spatial_queries(1024, seed=1)
    batches = [qs[32 * i:32 * (i + 1)] for i in range(6)]
    with AidwCluster(pts, n_hosts=2, max_batch=256, query_domain=qd,
                     trace_sample_rate=1.0) as cl:
        warm = [cl.submit(q) for q in batches[:2]]
        cl.flush(timeout=300)
        cl.collect_spans()                     # drop the warmup spans

        def boom(*a, **k):
            raise RuntimeError("injected host fault")

        cl.hosts[1].server.session.query = boom   # dies on next dispatch
        reqs = [cl.submit(q) for q in batches]
        cl.flush(timeout=300)
        spans = cl.collect_spans()
        rep = cl.report()
    assert rep["routing"]["resubmitted"] >= 1
    assert all(r.status == "done" for r in warm + reqs)

    by_trace: dict = {}
    for s in spans:
        by_trace.setdefault(s["trace_id"], []).append(s)
    # every request kept ONE trace end to end: its root route span, its
    # serving spans, and (for drained requests) the resubmit child
    assert all(r.trace_id in by_trace for r in reqs)
    assert len({r.trace_id for r in reqs}) == len(reqs)
    resubmits = [s for s in spans if s["name"] == "resubmit"]
    assert resubmits, "drain-resubmission recorded no spans"
    for trace_id, trace in by_trace.items():
        roots = [s for s in trace if s["name"] == "route"]
        assert len(roots) == 1, f"trace {trace_id} has {len(roots)} roots"
        root = roots[0]
        for s in trace:
            if s["name"] == "resubmit":
                # the resubmission is a child of the ORIGINAL route span —
                # the kill shows up inside the request's trace, not as a
                # disconnected second trace
                assert s["parent_id"] == root["span_id"]
                assert s["args"]["attempt"] >= 1
        # zero lost / zero duplicated serving spans: exactly one full
        # queue_wait/coalesce/execute/scatter set per completed request
        for name in ("queue_wait", "coalesce", "execute", "scatter"):
            got = [s for s in trace if s["name"] == name]
            assert len(got) == 1, \
                f"trace {trace_id}: {len(got)} {name} spans"
            assert got[0]["parent_id"] == root["span_id"]
    # the dead host contributed no serving spans (it never scattered) —
    # all serving-side spans come from the surviving host or the router
    serving = [s for s in spans if s["name"] in
               ("queue_wait", "coalesce", "execute", "scatter")]
    assert {s["host"] for s in serving} == {"0"}


def test_cluster_least_loaded_policy_serves_all(spatial_data):
    pts, qs = spatial_data
    qd = spatial_queries(1024, seed=1)
    with AidwCluster(pts, n_hosts=2, max_batch=256, query_domain=qd,
                     policy="least_loaded") as cl:
        reqs = [cl.submit(qs[32 * i:32 * (i + 1)]) for i in range(8)]
        cl.flush(timeout=300)
        assert all(r.status == "done" for r in reqs)
        assert cl.report()["fleet"]["completed"] == 8


# ---------------------------------------------------------------------------
# multi-process fleets (slow: subprocess spawning; the CI cluster-suite gate)
# ---------------------------------------------------------------------------


def _free_port() -> int:
    import socket

    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


@pytest.mark.slow
def test_cluster_two_process_jax_distributed_bit_identical():
    """The acceptance workload across REAL process boundaries: 2 processes
    x 4 forced host devices each, jax.distributed initialized on both, the
    socket control plane carrying routed queries + 3 epoch-broadcast
    updates, and results bit-identical to a single in-process server
    replaying the coordinator's epoch log."""
    import os
    import subprocess
    import sys

    jax_port, ctrl_port = _free_port(), _free_port()
    code = f"""
import os, numpy as np
from repro.data.pipeline import spatial_points, spatial_queries
from repro.serving import AsyncAidwServer
from repro.serving.cluster import (AidwCluster, ClusterConfig, HostServer,
                                   RemoteHost, bootstrap)
from repro.serving.cluster.rpc import spawn_worker

import jax
# spawn the worker FIRST: jax.distributed.initialize barriers until every
# fleet process registers with the coordination service
env = dict(os.environ)
proc = spawn_worker(1, 2, points=2048, seed=0, control_port={ctrl_port},
                    max_batch=256,
                    jax_coordinator="127.0.0.1:{jax_port}", env=env)
ctx = bootstrap(ClusterConfig(
    n_hosts=2, host_id=0, jax_coordinator="127.0.0.1:{jax_port}",
    control_port={ctrl_port}))
assert ctx.jax_distributed and jax.process_count() == 2
assert len(jax.local_devices()) == 4 and len(jax.devices()) == 8
assert ctx.mesh is not None and ctx.mesh.devices.size == 4

pts = spatial_points(2048, seed=0)
qs = spatial_queries(512, seed=1)
qd = spatial_queries(1024, seed=1)
local = HostServer(0, pts, max_batch=256, query_domain=qd, mesh=ctx.mesh)
remote = RemoteHost(1, ("127.0.0.1", {ctrl_port} + 1), connect_timeout_s=300)

pre = [qs[64*i:64*(i+1)] for i in range(4)]
post = [qs[64*i:64*(i+1)] for i in range(4, 8)]
with AidwCluster(hosts=[local, remote]) as cl:
    w0 = [cl.submit(q) for q in pre]
    for k in range(3):
        cl.update_dataset(inserts=spatial_points(16, seed=40 + k),
                          deletes=np.arange(k*16, (k+1)*16), timeout=300)
    w1 = [cl.submit(q) for q in post]
    cl.flush(timeout=600)
    rep = cl.report()
    log = list(cl.coordinator.log)
ctx.shutdown()       # join the fleet shutdown barrier with the worker
proc.wait(timeout=120)
assert proc.returncode == 0, proc.returncode
assert rep["fleet"]["hosts"] == 2
assert rep["fleet"]["epoch_min"] == rep["fleet"]["epoch_max"] == 3
assert rep["fleet"]["latency"]["total"]["p99_s"] > 0
assert sorted({{r.host_id for r in w0 + w1}}) == [0, 1]
assert local.server.session.stats["devices"] == 4

with AsyncAidwServer(pts, max_batch=256, query_domain=qd) as ref:
    r0 = [ref.submit(q) for q in pre]
    ref.flush(timeout=300)
    for u in log:
        ref.update_dataset(inserts=u.inserts, deletes=u.deletes, timeout=300)
    r1 = [ref.submit(q) for q in post]
    ref.flush(timeout=300)
for got, want in zip(w0 + w1, r0 + r1):
    assert got.status == "done"
    assert np.array_equal(np.asarray(got.values), np.asarray(want.values))
print("2proc cluster ok", rep["fleet"]["completed"])
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = str(REPO / "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", code], env=env, timeout=600,
                         capture_output=True, text=True)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "2proc cluster ok 8" in out.stdout


@pytest.mark.slow
def test_load_gen_cluster_procs_merged_report():
    """The CI fleet-latency artifact path: load_gen --cluster 2
    --cluster-procs --json produces a merged report with summed counters
    and fleet percentiles, and loses nothing."""
    import json
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, str(REPO / "benchmarks" / "load_gen.py"),
         "--cluster", "2", "--cluster-procs", "--json", "--requests", "24",
         "--rate", "150", "--points", "4096"],
        env=env, timeout=600, capture_output=True, text=True)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    rep = json.loads(out.stdout)
    assert rep["lost"] == 0 and rep["duplicated"] == 0
    fleet = rep["report"]
    assert fleet["hosts"] == 2 and len(rep["hosts"]) == 2
    assert fleet["completed"] == sum(h["completed"] for h in rep["hosts"])
    assert fleet["latency"]["total"]["p99_s"] > 0
    assert fleet["queries_per_s"] > 0
    # per-host histograms really merged: fleet count = sum of host counts
    assert fleet["latency"]["total"]["count"] == sum(
        h["latency"]["total"]["count"] for h in rep["hosts"])


# ---------------------------------------------------------------------------
# fleet data partitioning (ShardedAidwCluster; PR 5 acceptance gate)
# ---------------------------------------------------------------------------


def test_sharded_cluster_matches_replica():
    """Acceptance: a 2-shard cluster (points PARTITIONED, not replicated)
    answers a query batch within f32 accumulation tolerance of a 1-host
    full-replica server — the client-side k-way merge over per-shard grid
    kNN + Eq. (1) partial sums."""
    from repro.serving.cluster import ShardedAidwCluster

    pts = spatial_points(8192, seed=0)
    qd = spatial_queries(1024, seed=1)
    qs = spatial_queries(500, seed=2)
    with AsyncAidwServer(pts, query_domain=qd) as replica, \
            ShardedAidwCluster(pts, n_hosts=2, query_domain=qd) as fleet:
        want = replica.result(replica.submit(qs))
        got = fleet.query(qs, timeout=300)
        assert got.epoch == 0
        err = np.abs(np.asarray(want.values) - got.values).max()
        assert err < 1e-4, err
        rep = fleet.report()
        assert rep["n_points"] == pts.shape[0]
        assert sum(rep["shard_sizes"]) == pts.shape[0]
        assert min(rep["shard_sizes"]) > 0       # really partitioned


def test_sharded_cluster_delta_routing_and_epochs():
    """Deltas split by owning shard under one epoch (empty pieces keep the
    per-host epoch streams dense); post-delta results still match the
    replica applying the same global delta; concurrent churn retries keep
    every merged batch on ONE epoch."""
    from repro.serving.cluster import ShardedAidwCluster

    pts = spatial_points(8192, seed=0)
    qd = spatial_queries(1024, seed=1)
    qs = spatial_queries(300, seed=2)
    rng = np.random.default_rng(5)
    with AsyncAidwServer(pts, query_domain=qd) as replica, \
            ShardedAidwCluster(pts, n_hosts=2, query_domain=qd) as fleet:
        dels = rng.choice(pts.shape[0], 120, replace=False)
        ins = spatial_points(100, seed=9)
        replica.update_dataset(inserts=ins, deletes=dels)
        assert fleet.update_dataset(inserts=ins, deletes=dels,
                                    timeout=300) == 1
        assert fleet.m == pts.shape[0] - 120 + 100
        # every host saw epoch 1 (even if its piece was small/empty)
        assert all(h.epoch == 1 for h in fleet.hosts)
        want = replica.result(replica.submit(qs))
        got = fleet.query(qs, timeout=300)
        assert got.epoch == 1
        err = np.abs(np.asarray(want.values) - got.values).max()
        assert err < 1e-4, err

        # interleave queries with churn: merged batches stay epoch-pure
        done = []

        def churn():
            for i in range(3):
                fleet.update_dataset(
                    inserts=spatial_points(40, seed=20 + i),
                    deletes=np.arange(40) * 2, timeout=300)

        t = threading.Thread(target=churn)
        t.start()
        for i in range(6):
            out = fleet.query(spatial_queries(80, seed=40 + i), timeout=300)
            assert np.isfinite(out.values).all()
            done.append(out.epoch)
        t.join()
        assert fleet.epoch == 4
        assert all(e in range(0, 5) for e in done)


def test_sharded_cluster_validates_queries_like_the_router():
    """The shard fan-out shares validate_queries with the server/router
    admission surfaces: malformed arrays bounce at the boundary instead of
    reaching (and killing) shard workers."""
    from repro.serving.cluster import ShardedAidwCluster

    pts = spatial_points(2048, seed=0)
    with ShardedAidwCluster(pts, n_hosts=2,
                            query_domain=spatial_queries(256, seed=1)) as fl:
        for bad in (np.zeros((0, 2), np.float32),
                    np.zeros((4, 3), np.float32),
                    np.zeros((4, 2), np.int32)):
            with pytest.raises(ValueError):
                fl.query(bad)
        # a shard op reaching the server directly hits the same check
        with pytest.raises(ValueError):
            fl.hosts[0].shard_knn(np.zeros((4, 3), np.float32))


@pytest.mark.slow
def test_sharded_cluster_subprocess_shard_worker():
    """The fleet-partitioned deployment shape across a REAL process
    boundary: host 1 is a subprocess serving shard 1 of the deterministic
    fleet_partition (rpc --shard-of), shard ops travel the socket control
    plane, and the merged results still match the full-replica server."""
    import os

    from repro.serving.cluster import (HostServer as HS, RemoteHost,
                                       ShardedAidwCluster, fleet_partition)
    from repro.serving.cluster.rpc import free_port_base, spawn_worker

    n_pts, seed = 4096, 0
    pts = spatial_points(n_pts, seed=seed)
    qd = spatial_queries(1024, seed=1)
    qs = spatial_queries(300, seed=2)
    _, _, members = fleet_partition(pts, 2, query_domain=qd)
    base = free_port_base(2)
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    worker = spawn_worker(1, 2, points=n_pts, seed=seed, control_port=base,
                          shard_of=2, env=env)
    try:
        hosts = [HS(0, pts[members[0]], query_domain=qd),
                 RemoteHost(1, ("127.0.0.1", base + 1),
                            connect_timeout_s=300)]
        with AsyncAidwServer(pts, query_domain=qd) as replica, \
                ShardedAidwCluster(pts, n_hosts=2, hosts=hosts,
                                   query_domain=qd) as fleet:
            want = replica.result(replica.submit(qs))
            got = fleet.query(qs, timeout=300)
            err = np.abs(np.asarray(want.values) - got.values).max()
            assert err < 1e-4, err
            # delta routed across the process boundary under one epoch
            dels = np.arange(0, 200, 2)
            ins = spatial_points(64, seed=9)
            replica.update_dataset(inserts=ins, deletes=dels)
            assert fleet.update_dataset(inserts=ins, deletes=dels,
                                        timeout=300) == 1
            want2 = replica.result(replica.submit(qs))
            got2 = fleet.query(qs, timeout=300)
            assert got2.epoch == 1
            err2 = np.abs(np.asarray(want2.values) - got2.values).max()
            assert err2 < 1e-4, err2
    finally:
        try:
            worker.wait(timeout=60)
        except Exception:
            worker.kill()


def test_sharded_cluster_rejected_update_consumes_no_epoch():
    """Review-driven regression: a REJECTED update (bad delete index /
    empty-shard full refresh) must not consume an epoch — a gap would
    wedge every host's EpochApplier forever.  Validation runs before
    assignment, so the fleet stays fully usable."""
    from repro.serving.cluster import ShardedAidwCluster

    pts = spatial_points(4096, seed=0)
    with ShardedAidwCluster(pts, n_hosts=2,
                            query_domain=spatial_queries(256, seed=1)) as fl:
        with pytest.raises(IndexError):
            fl.update_dataset(deletes=[10**6])
        with pytest.raises(ValueError):      # all points into one shard
            fl.update_dataset(points_xyz=np.concatenate(
                [np.zeros((64, 2), np.float32) + 0.01,
                 np.ones((64, 1), np.float32)], axis=1))
        assert fl.epoch == 0                 # nothing consumed
        assert fl.update_dataset(inserts=spatial_points(32, seed=5),
                                 deletes=np.arange(32), timeout=300) == 1
        out = fl.query(spatial_queries(64, seed=2), timeout=300)
        assert out.epoch == 1
        assert np.isfinite(out.values).all()


def test_sharded_cluster_full_refresh_replans_and_bbox_guard():
    """Review-driven regression: a FULL refresh re-plans the fleet grid
    (study area + shard routing track the new data like a full-replica
    re-plan), while an out-of-bbox DELTA insert is rejected without
    consuming an epoch (the fleet spec is frozen across deltas, like
    plan_delta's bbox fallback)."""
    from repro.serving.cluster import ShardedAidwCluster

    pts = spatial_points(8192, seed=0)
    qd = spatial_queries(512, seed=1)
    with AsyncAidwServer(pts, query_domain=qd) as rep, \
            ShardedAidwCluster(pts, n_hosts=2, query_domain=qd) as fl:
        with pytest.raises(ValueError):
            fl.update_dataset(
                inserts=np.array([[9.0, 9.0, 1.0]], np.float32))
        assert fl.epoch == 0
        old_area = fl.area
        pts2 = spatial_points(8192, seed=7) \
            * np.array([2.0, 2.0, 1.0], np.float32)
        rep.update_dataset(points_xyz=pts2)
        assert fl.update_dataset(points_xyz=pts2, timeout=300) == 1
        assert fl.area > 2 * old_area        # spec really re-planned
        qs2 = (spatial_queries(200, seed=8) * 2.0).astype(np.float32)
        want = rep.result(rep.submit(qs2))
        got = fl.query(qs2, timeout=300)
        assert got.epoch == 1
        err = np.abs(np.asarray(want.values) - got.values).max()
        assert err < 1e-4, err


def test_sharded_cluster_churn_with_compaction_matches_replay():
    """ISSUE 7 acceptance: a sharded fleet under CONCURRENT writer churn
    plus a fleet-wide COMPACTION epoch matches a single grid_ring server
    replaying the coordinator's epoch log — compaction epochs replayed AS
    compactions (they carry no delta payload; replaying them through
    update_dataset would corrupt the replay), everything else in epoch
    order."""
    from repro.core.jax_compat import make_auto_mesh
    from repro.serving.cluster import ShardedAidwCluster

    pts = spatial_points(8192, seed=0)
    qd = spatial_queries(1024, seed=1)
    qs = spatial_queries(300, seed=2)
    lo, hi = pts[:, :2].min(axis=0), pts[:, :2].max(axis=0)

    def _ins(seed, n=32):
        # clip into the frozen bbox: both the fleet spec and the replay
        # server's plan_delta freeze the grid across deltas
        ins = spatial_points(n, seed=seed)
        ins[:, :2] = np.clip(ins[:, :2], lo, hi)
        return ins

    with ShardedAidwCluster(pts, n_hosts=2, query_domain=qd) as fleet:

        def churn(k):
            fleet.update_dataset(inserts=_ins(60 + k),
                                 deletes=np.arange(k * 32, (k + 1) * 32),
                                 timeout=300)

        ts = [threading.Thread(target=churn, args=(k,)) for k in range(3)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert fleet.compact(timeout=300) == 4   # fleet-wide ring fold
        fleet.update_dataset(inserts=_ins(99), deletes=np.arange(16),
                             timeout=300)
        got = fleet.query(qs, timeout=300)
        assert got.epoch == 5
        log = list(fleet.coordinator.log)
    assert [u.compact for u in log] == [False, False, False, True, False]
    assert log[3].points_xyz is None and log[3].inserts is None \
        and log[3].deletes is None               # compact carries no delta
    # the replay reference runs the grid_ring layout so the compaction
    # epoch really folds hot rings into the slab CSR mid-log
    mesh = make_auto_mesh((1,), ("q",))
    with AsyncAidwServer(pts, query_domain=qd, mesh=mesh,
                         layout="grid_ring", ring_cap=512) as ref:
        for u in log:
            if u.compact:
                ref.compact(timeout=300)
            else:
                ref.update_dataset(u.points_xyz, inserts=u.inserts,
                                   deletes=u.deletes, timeout=300)
        assert ref.session.stats["compactions"] >= 1
        assert ref.session.stats["ring_points"] == 32   # post-compact delta
        want = ref.result(ref.submit(qs))
    # sharded merge is f32-accumulation tolerant of a replica (1e-4) and
    # the grid_ring layout adds its own documented 1-ulp Stage-2 caveat
    err = np.abs(np.asarray(want.values) - got.values).max()
    assert err < 5e-4, err


# ---------------------------------------------------------------------------
# ISSUE 9: fleet-wide debugz bundles
# ---------------------------------------------------------------------------


class _AnomalyReq:
    """Stamped-timestamp stub for injecting deterministic anomalies into a
    host's live flight recorder (the debugz merge is what's under test,
    not the serving path that normally feeds it)."""

    def __init__(self, uid, *, deadline=None, t_submit=0.0,
                 t_dispatch=None, t_done=None):
        self.uid = uid
        self.deadline = deadline
        self.overflow = 0
        self.zero_weight = 0
        self.t_submit = t_submit
        self.t_dispatch = t_dispatch
        self.t_done = t_done
        self.trace_id = None
        self.epoch = None


def _inject_tail(rec, base_uid):
    """50 in-SLO 10ms requests + one 1s deadline-misser whose excess is
    all queue_wait — a deterministic p99-p50 gap with a retained tail."""
    for i in range(50):
        r = _AnomalyReq(base_uid + i, t_submit=0.0, t_dispatch=0.001,
                        t_done=0.01)
        rec.observe_request(r, t0=0.001, t1=0.01, t2=0.01, last_submit=0.0)
    slow = _AnomalyReq(base_uid + 50, deadline=0.5, t_submit=0.0,
                       t_dispatch=0.99, t_done=1.0)
    rec.observe_request(slow, t0=0.99, t1=1.0, t2=1.0, last_submit=0.0)


def test_cluster_debugz_merged_bundle_schema_and_attribution(spatial_data):
    """ISSUE 9 acceptance: ``AidwCluster.debugz()`` on a 2-host fleet
    returns ONE merged bundle — per-host sections, bin-exact fleet stage
    registry, fleet SLO events, and a tail-latency attribution whose
    per-stage contributions sum within 15% of the p99-p50 gap."""
    import json

    pts, qs = spatial_data
    qd = spatial_queries(1024, seed=1)
    with AidwCluster(pts, n_hosts=2, max_batch=256, query_domain=qd) as cl:
        reqs = [cl.submit(qs[32 * i:32 * (i + 1)]) for i in range(4)]
        cl.update_dataset(inserts=spatial_points(16, seed=9),
                          deletes=np.arange(16), timeout=300)
        cl.flush(timeout=300)
        assert all(r.status == "done" for r in reqs)
        # deterministic anomaly injection into the LIVE recorders: each
        # host retains one queue_wait-dominated deadline-misser
        for k, host in enumerate(cl.hosts):
            _inject_tail(host.server.recorder, base_uid=1000 * (k + 1))
        bundle = cl.debugz()

    assert set(bundle) == {"epoch", "hosts", "unreachable", "routing",
                           "fleet", "slo", "attribution"}
    assert sorted(bundle["hosts"]) == ["0", "1"] \
        and bundle["unreachable"] == []
    assert bundle["epoch"] == 1
    for hid, hb in bundle["hosts"].items():
        assert hb["host_id"] == int(hid) and hb["alive"]
        assert hb["recorder"]["requests"] >= 51
        assert {"targets", "rates", "gauges", "events"} <= set(hb["slo"])
    fleet = bundle["fleet"]
    assert fleet["epochs"] == {"min": 1, "max": 1,
                               "by_host": {"0": 1, "1": 1}}
    # bin-exact fleet merge: both hosts' serving walls in one histogram
    served = sum(b["recorder"]["anomalies"]["deadline_miss"]
                 for b in bundle["hosts"].values())
    assert served == 2
    assert "serving/queue_wait_s" in fleet["stages"]["histograms"]

    # THE acceptance identity, on the merged fleet attribution
    attr = bundle["attribution"]
    # 102 injected + the real served traffic also folded by the recorder
    assert attr["n_total"] >= 102 and attr["tail_n"] >= 2
    gap = attr["gap_s"]
    assert gap > 0
    assert abs(attr["attributed_s"] - gap) <= 0.15 * gap
    assert attr["stages"]["queue_wait"]["share"] > 0.9
    json.dumps(bundle)                       # one JSON artifact, as shipped


def test_cluster_debugz_partial_bundle_when_host_unreachable(spatial_data):
    """Diagnostics must never drain a host: a host whose debugz pull
    FAILS lands in ``unreachable`` — it is not drained, the other host's
    bundle and the fleet merge still come back whole (the bundle stays
    useful mid-incident, which is exactly when it is pulled)."""
    import json

    pts, qs = spatial_data
    qd = spatial_queries(1024, seed=1)
    with AidwCluster(pts, n_hosts=2, max_batch=256, query_domain=qd) as cl:
        reqs = [cl.submit(qs[32 * i:32 * (i + 1)]) for i in range(4)]
        cl.flush(timeout=300)

        def boom(*a, **k):
            raise RuntimeError("injected debugz fault")

        cl.hosts[1].server.debugz = boom
        bundle = cl.debugz()
        # the pull failure did NOT drain the host: it still serves
        assert cl.router.live_hosts() == [0, 1]
        after = cl.submit(qs[:16])
        cl.flush(timeout=300)
        assert after.status == "done"

    assert sorted(bundle["hosts"]) == ["0"]
    assert bundle["unreachable"] == ["1"]
    assert bundle["hosts"]["0"]["alive"]
    assert bundle["fleet"]["epochs"]["by_host"] == {"0": 0}
    assert bundle["attribution"]["n_total"] >= 0
    assert all(r.status == "done" for r in reqs)
    json.dumps(bundle)
