"""End-to-end behaviour + dry-run artifact validation.

The dry-run itself (512 forced host devices) runs via
``python -m repro.launch.dryrun``; these tests validate the committed
artifacts cover the full matrix and that every cell compiled.
"""

from __future__ import annotations

import json
from pathlib import Path

import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import api

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"

_have_artifacts = ART.exists() and len(list(ART.glob("*.json"))) > 0


@pytest.mark.skipif(not _have_artifacts, reason="run repro.launch.dryrun first")
@pytest.mark.parametrize("mesh", ["pod", "multipod"])
def test_dryrun_matrix_complete_and_green(mesh):
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape_name, shape in api.SHAPES.items():
            p = ART / f"{arch}__{shape_name}__{mesh}.json"
            assert p.exists(), f"missing dry-run cell {p.name}"
            rec = json.loads(p.read_text())
            ok, _ = api.applicable(cfg, shape)
            if not ok:
                assert rec["status"] == "skipped", p.name
            else:
                assert rec["status"] == "ok", (p.name, rec.get("error"))
                assert rec["n_chips"] == (512 if mesh == "multipod" else 256)
                assert rec["memory"]["peak_bytes_per_device"] > 0
                assert rec["per_chip"]["flops"] > 0


@pytest.mark.skipif(not _have_artifacts, reason="run repro.launch.dryrun first")
def test_dryrun_records_collective_schedule():
    rec = json.loads((ART / "command-r-plus-104b__train_4k__pod.json").read_text())
    colls = rec["collectives"]
    assert set(colls) == {"all-reduce", "all-gather", "reduce-scatter",
                          "all-to-all", "collective-permute"}
    assert sum(c["count"] for c in colls.values()) > 0


def test_quickstart_example_runs():
    import subprocess
    import sys
    import os

    repo = Path(__file__).resolve().parents[1]
    env = dict(os.environ, PYTHONPATH=str(repo / "src"))
    r = subprocess.run([sys.executable, str(repo / "examples" / "quickstart.py")],
                       env=env, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr
    assert "prediction" in r.stdout.lower() or "aidw" in r.stdout.lower()
