"""Grid kNN: exactness vs brute force (the paper's central data structure)."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from hypcompat import given, settings, st  # guarded: skips, never dies, without hypothesis

from repro.core import bin_points, brute_knn, grid_knn, mean_nn_distance, plan_grid


def _setup(pts, qs):
    spec = plan_grid(pts[:, :2], qs)
    table = bin_points(spec, jnp.array(pts[:, 0]), jnp.array(pts[:, 1]),
                       jnp.array(pts[:, 2]))
    return spec, table


def test_grid_knn_exact_matches_brute():
    rng = np.random.default_rng(0)
    pts = rng.random((3000, 3)).astype(np.float32)
    qs = rng.random((700, 2)).astype(np.float32)
    spec, table = _setup(pts, qs)
    res = grid_knn(spec, table, jnp.array(qs), 15, None, 1024, 512, True)
    bd2, _ = brute_knn(jnp.array(pts[:, :2]), jnp.array(qs), 15)
    assert int(res.overflow.sum()) == 0
    np.testing.assert_allclose(np.sort(np.asarray(res.d2), 1),
                               np.sort(np.asarray(bd2), 1), atol=1e-6)


def test_paper_heuristic_mode_close_but_flagged():
    """exact=False is the paper's +1-ring heuristic: nearly exact on uniform
    data (the paper's own test protocol) — mismatches are rare and small."""
    rng = np.random.default_rng(1)
    pts = rng.random((3000, 3)).astype(np.float32)
    qs = rng.random((1000, 2)).astype(np.float32)
    spec, table = _setup(pts, qs)
    res = grid_knn(spec, table, jnp.array(qs), 15, None, 1024, 512, False)
    bd2, _ = brute_knn(jnp.array(pts[:, :2]), jnp.array(qs), 15)
    bad = (np.abs(np.sort(np.asarray(res.d2), 1)
                  - np.sort(np.asarray(bd2), 1)).max(1) > 1e-6).sum()
    assert bad <= 20  # < 2% of queries on uniform data


@settings(max_examples=20, deadline=None)
@given(st.integers(30, 500), st.integers(1, 25), st.integers(0, 10_000),
       st.booleans())
def test_grid_knn_exactness_property(m, k, seed, clustered):
    rng = np.random.default_rng(seed)
    if clustered:
        centers = rng.random((3, 2))
        xy = np.clip(centers[rng.integers(0, 3, m)]
                     + rng.normal(0, 0.05, (m, 2)), 0, 1)
    else:
        xy = rng.random((m, 2))
    pts = np.concatenate([xy, rng.random((m, 1))], 1).astype(np.float32)
    qs = rng.random((64, 2)).astype(np.float32)
    spec, table = _setup(pts, qs)
    res = grid_knn(spec, table, jnp.array(qs), k, None, 4096, 64, True)
    bd2, _ = brute_knn(jnp.array(pts[:, :2]), jnp.array(qs), k)
    no_ovf = ~np.asarray(res.overflow)
    got = np.sort(np.asarray(res.d2), 1)[no_ovf]
    want = np.sort(np.asarray(bd2), 1)[no_ovf]
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_k_larger_than_m():
    rng = np.random.default_rng(3)
    pts = rng.random((8, 3)).astype(np.float32)
    qs = rng.random((5, 2)).astype(np.float32)
    spec, table = _setup(pts, qs)
    res = grid_knn(spec, table, jnp.array(qs), 15, None, 64, 8, True)
    # first 8 finite, rest inf
    d2 = np.sort(np.asarray(res.d2), 1)
    assert np.isfinite(d2[:, :8]).all()
    assert np.isinf(d2[:, 8:]).all()


def test_mean_nn_distance_defers_sqrt():
    d2 = jnp.array([[4.0, 9.0, 16.0]])
    assert float(mean_nn_distance(d2)[0]) == (2 + 3 + 4) / 3


def test_knn_indices_point_to_true_neighbors():
    rng = np.random.default_rng(4)
    pts = rng.random((500, 3)).astype(np.float32)
    qs = rng.random((50, 2)).astype(np.float32)
    spec, table = _setup(pts, qs)
    res = grid_knn(spec, table, jnp.array(qs), 5, None, 512, 64, True)
    idx = np.asarray(res.idx)
    d2 = np.asarray(res.d2)
    for i in range(len(qs)):
        d = (pts[idx[i], 0] - qs[i, 0]) ** 2 + (pts[idx[i], 1] - qs[i, 1]) ** 2
        np.testing.assert_allclose(np.sort(d), np.sort(d2[i]), rtol=1e-5)
