"""Domain-decomposed (ring) AIDW across devices — the paper at pod scale.

Shards the DATA POINTS across a device ring and the queries across the whole
mesh, rotating data blocks with collective-permute so no chip ever holds the
full dataset (DESIGN.md §2 'ring AIDW').  Run with forced host devices to
simulate a pod slice on CPU:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python examples/distributed_aidw.py
"""

import numpy as np
import jax

from repro.core import aidw_improved
from repro.core.distributed import query_sharded_aidw, ring_aidw
from repro.data.pipeline import spatial_points, spatial_queries


def main() -> None:
    n_dev = len(jax.devices())
    print(f"devices: {n_dev}")
    pts = spatial_points(4096, seed=0)
    qs = spatial_queries(2048, seed=1)

    ref = np.asarray(aidw_improved(pts, qs).values)

    if n_dev >= 2:
        axes = (n_dev // 2, 2)
        mesh = jax.make_mesh(axes, ("data", "model"))
        ring = np.asarray(ring_aidw(mesh, "data", pts, qs))
        qsh = np.asarray(query_sharded_aidw(mesh, pts, qs))
        print(f"mesh {axes}: ring-AIDW max|err| vs single-device "
              f"= {np.abs(ring - ref).max():.2e}")
        print(f"mesh {axes}: query-sharded max|err| = {np.abs(qsh - ref).max():.2e}")
        print(f"per-device data-point shard: {pts.shape[0] // axes[0]} of "
              f"{pts.shape[0]} (O(m/P) memory)")
    else:
        print("single device: ring reduces to the local pipeline")
        print(f"AIDW values[:4] = {ref[:4]}")
    print("aidw distributed demo complete")


if __name__ == "__main__":
    main()
