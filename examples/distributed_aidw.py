"""Domain-decomposed (ring) AIDW across devices — the paper at pod scale.

Shards the DATA POINTS across a device ring and the queries across the whole
mesh, rotating data blocks with collective-permute so no chip ever holds the
full dataset (DESIGN.md §2 'ring AIDW').  The single-device reference runs
through :class:`repro.core.InterpolationSession` — the grid build happens
once and every query batch reuses it.  Run with forced host devices to
simulate a pod slice on CPU:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python examples/distributed_aidw.py
"""

import numpy as np
import jax

from repro.core import InterpolationSession
from repro.core.distributed import query_sharded_aidw, ring_aidw
from repro.core.jax_compat import make_auto_mesh
from repro.data.pipeline import spatial_points, spatial_queries


def main() -> None:
    n_dev = len(jax.devices())
    print(f"devices: {n_dev}")
    pts = spatial_points(4096, seed=0)
    qs = spatial_queries(2048, seed=1)

    # plan once; every batch below is a warm session query (no grid rebuild)
    sess = InterpolationSession(pts, query_domain=qs)
    ref = np.asarray(sess.query(qs).values)
    for seed in (2, 3, 4):          # repeated odd-sized traffic, one executable
        sess.query(spatial_queries(2048 - seed * 7, seed=seed))
    print(f"session: {sess.stats['batches']} batches / "
          f"{sess.stats['queries']} queries on "
          f"{sess.stats['stage1_builds']} Stage-1 build(s), "
          f"{sess.stats['bucket_misses']} compiled bucket(s)")

    # incremental churn: replace ~1% of the dataset without a Stage-1 rebuild
    n_delta = pts.shape[0] // 100
    sess.update(inserts=spatial_points(n_delta, seed=5),
                deletes=np.random.default_rng(6).choice(
                    pts.shape[0], n_delta, replace=False))
    sess.query(qs)
    print(f"delta update: {sess.stats['delta_updates']} incremental / "
          f"{sess.stats['stage1_builds']} full Stage-1 build(s)")

    if n_dev >= 2:
        # ONE session serving the whole mesh: queries sharded over all axes,
        # plan replicated — results bit-identical to the single-device path
        smesh = make_auto_mesh((n_dev,), ("q",))
        ssess = InterpolationSession(pts, query_domain=qs, mesh=smesh)
        sharded = np.asarray(ssess.query(qs).values)
        print(f"sharded session ({n_dev} devices): bit-identical to "
              f"single-device = {np.array_equal(sharded, ref)}")

        axes = (n_dev // 2, 2)
        mesh = jax.make_mesh(axes, ("data", "model"))
        ring = np.asarray(ring_aidw(mesh, "data", pts, qs))
        qsh = np.asarray(query_sharded_aidw(mesh, pts, qs))
        print(f"mesh {axes}: ring-AIDW max|err| vs warm session "
              f"= {np.abs(ring - ref).max():.2e}")
        print(f"mesh {axes}: query-sharded max|err| = {np.abs(qsh - ref).max():.2e}")
        print(f"per-device data-point shard: {pts.shape[0] // axes[0]} of "
              f"{pts.shape[0]} (O(m/P) memory)")
    else:
        print("single device: ring reduces to the local session pipeline")
        print(f"AIDW values[:4] = {ref[:4]}")
    print("aidw distributed demo complete")


if __name__ == "__main__":
    main()
