"""Multi-host AIDW serving fleet — the cluster subsystem end to end.

Client threads submit interpolation requests to a 2-host
:class:`repro.serving.cluster.AidwCluster` while the dataset churns
underneath via CONCURRENT epoch-ordered updates (the coordinator totally
orders them, every host applies them in the same order between the same
batches), and one host dies mid-stream: the router drains it, resubmits
its unserved requests to the survivor, and every client still gets exactly
one result.  Prints the merged fleet telemetry at the end.

Run in-process, or back host 1 with a real subprocess over the socket
control plane:

  PYTHONPATH=src python examples/cluster_aidw.py
  PYTHONPATH=src python examples/cluster_aidw.py --procs
"""

from __future__ import annotations

import argparse
import threading

import numpy as np

from repro.data.pipeline import spatial_points, spatial_queries
from repro.serving.cluster import AidwCluster


def client(cl: AidwCluster, cid: int, n_requests: int, results: list):
    """One client: odd-sized requests, every third deadline-bound."""
    reqs = []
    for i in range(n_requests):
        qs = spatial_queries(97 + 13 * ((cid + i) % 5), seed=cid * 100 + i)
        reqs.append(cl.submit(qs, deadline_s=10.0 if i % 3 == 0 else None))
    for r in reqs:
        cl.result(r, timeout=300)
    results.append(reqs)


def build_hosts(args):
    """None for an in-process fleet, or [local host 0, RPC proxy to a
    subprocess host 1] for the process-backed shape."""
    if not args.procs:
        return None, []
    import os
    import socket

    from repro.serving.cluster import HostServer, RemoteHost
    from repro.serving.cluster.rpc import spawn_worker

    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    base = s.getsockname()[1]
    s.close()
    env = dict(os.environ)
    env.setdefault("PYTHONPATH", "src")
    worker = spawn_worker(1, 2, points=args.points, seed=0,
                          control_port=base, env=env)
    hosts = [HostServer(0, spatial_points(args.points, seed=0),
                        query_domain=spatial_queries(1024, seed=1)),
             RemoteHost(1, ("127.0.0.1", base + 1), connect_timeout_s=300)]
    return hosts, [worker]


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--points", type=int, default=16384)
    p.add_argument("--clients", type=int, default=3)
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--updates", type=int, default=3)
    p.add_argument("--procs", action="store_true",
                   help="host 1 in a real subprocess (socket control plane)")
    p.add_argument("--kill-host", action="store_true",
                   help="crash a host mid-stream to show router draining "
                        "(in-process fleets only)")
    args = p.parse_args()

    pts = spatial_points(args.points, seed=0)
    hosts, workers = build_hosts(args)
    with AidwCluster(pts if hosts is None else None, n_hosts=2, hosts=hosts,
                     query_domain=spatial_queries(1024, seed=1)) as cl:
        results: list = []
        threads = [threading.Thread(target=client,
                                    args=(cl, c, args.requests, results))
                   for c in range(args.clients)]
        for t in threads:
            t.start()
        # CONCURRENT churn: each update gets an epoch from the coordinator
        # and lands in every host's FIFO in that order, so the fleet stays
        # consistent no matter how these threads interleave
        n_delta = max(args.points // 100, 1)

        def churn(k: int):
            cl.update_dataset(
                inserts=spatial_points(n_delta, seed=2 + k),
                deletes=np.random.default_rng(3 + k).choice(
                    args.points - n_delta, n_delta, replace=False),
                timeout=600)

        upd_threads = [threading.Thread(target=churn, args=(k,))
                       for k in range(args.updates)]
        for t in upd_threads:
            t.start()
        if args.kill_host and hosts is None:
            # simulate host death: the router drains it on the first error
            # and resubmits its unserved requests to the survivor
            def boom(*a, **k):
                raise RuntimeError("injected host fault")

            cl.hosts[1].server.session.query = boom
        for t in upd_threads + threads:
            t.join()
        cl.flush(timeout=600)

        served = sum(r.status == "done" for reqs in results for r in reqs)
        total = sum(len(reqs) for reqs in results)
        rep = cl.report()
        fleet, routing = rep["fleet"], rep["routing"]
        lat = fleet["latency"]["total"]
        print(f"served {served}/{total} requests from {args.clients} "
              f"client threads over {fleet['hosts']} hosts "
              f"({fleet['shed']} shed, epochs "
              f"{fleet['epoch_min']}..{fleet['epoch_max']})")
        print(f"fleet: {fleet['queries_per_s']:.0f} q/s, total-latency "
              f"p50 {lat['p50_s'] * 1e3:.1f}ms / "
              f"p99 {lat['p99_s'] * 1e3:.1f}ms")
        print(f"routing: policy={routing['policy']} "
              f"live={routing['live_hosts']} "
              f"drained={routing['drained_hosts']} "
              f"resubmitted={routing['resubmitted']}")
    for w in workers:
        w.wait(timeout=60)


if __name__ == "__main__":
    main()
