"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps.

Uses the full substrate: deterministic sharded data stream, jit'd train step
(AdamW, clipping, cosine schedule), async atomic checkpointing, spike guard.
On the CPU container this runs a reduced-width model by default; pass
--full-100m for the real ~100M config (slow on CPU).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--full-100m]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import LMStreamConfig, lm_batch
from repro.models import api
from repro.models.config import ModelConfig
from repro.nn.param import init_params
from repro.optim import adamw
from repro.runtime.fault_tolerance import SpikeGuard
from repro.training import trainer


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--full-100m", action="store_true")
    p.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = p.parse_args()

    if args.full_100m:
        cfg = ModelConfig(name="lm-100m", family="dense", n_layers=12,
                          d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
                          vocab=32000, dtype=jnp.float32, remat=False)
    else:
        cfg = ModelConfig(name="lm-tiny", family="dense", n_layers=4,
                          d_model=128, n_heads=4, n_kv_heads=4, d_ff=512,
                          vocab=4096, dtype=jnp.float32, remat=False,
                          q_chunk=128)
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f}M params)")

    ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps,
                             weight_decay=0.01)
    params = init_params(api.param_defs(cfg), jax.random.PRNGKey(0))
    opt = trainer.init_opt_state(ocfg, params)
    step_fn = jax.jit(trainer.make_train_step(cfg, ocfg), donate_argnums=(0, 1))

    stream = LMStreamConfig(vocab=cfg.vocab, seq_len=args.seq,
                            global_batch=args.batch)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    guard = SpikeGuard()

    t0 = time.perf_counter()
    for s in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in lm_batch(stream, s).items()}
        params, opt, m = step_fn(params, opt, batch)
        loss = float(m["loss"])
        assert not guard.observe(loss), f"loss spike at step {s}: {loss}"
        if s % 25 == 0 or s == args.steps - 1:
            tok_s = (s + 1) * args.batch * args.seq / (time.perf_counter() - t0)
            print(f"step {s:4d}  loss {loss:.4f}  lr {float(m['lr']):.2e}  "
                  f"{tok_s:,.0f} tok/s")
        if (s + 1) % 100 == 0:
            mgr.save_async(s + 1, (params, opt))
    mgr.save(args.steps, (params, opt))
    mgr.close()
    print("done; final checkpoint at", args.ckpt_dir)


if __name__ == "__main__":
    main()
