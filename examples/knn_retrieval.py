"""Grid kNN as a retrieval primitive beyond interpolation.

The paper's even-grid kNN is a general spatial index.  Here it serves
nearest-neighbour retrieval over a 2-D projection of learned embeddings
(e.g. for approximate semantic lookup), using exactly the same
bin->CSR->expand->top-k machinery as the interpolation pipeline, and
cross-checked against brute force.

Run:  PYTHONPATH=src python examples/knn_retrieval.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import bin_points, brute_knn, grid_knn, plan_grid


def main() -> None:
    rng = np.random.default_rng(0)
    # "embeddings": clustered 2-D projections (e.g. PCA of doc vectors)
    centers = rng.random((32, 2)).astype(np.float32)
    docs = (centers[rng.integers(0, 32, 20000)]
            + rng.normal(0, 0.01, (20000, 2))).astype(np.float32)
    queries = docs[rng.integers(0, len(docs), 256)] \
        + rng.normal(0, 0.005, (256, 2)).astype(np.float32)

    spec = plan_grid(docs, queries)
    table = bin_points(spec, jnp.asarray(docs[:, 0]), jnp.asarray(docs[:, 1]),
                       jnp.zeros(len(docs)))
    res = grid_knn(spec, table, jnp.asarray(queries), 10, None, 2048, 256, True)
    bd2, bidx = brute_knn(jnp.asarray(docs), jnp.asarray(queries), 10)

    agree = np.mean(np.sort(np.asarray(res.d2), 1)
                    == np.sort(np.asarray(bd2), 1))
    print(f"indexed {len(docs)} docs in a {spec.n_rows}x{spec.n_cols} grid")
    print(f"top-10 retrieval for {len(queries)} queries: "
          f"{agree * 100:.1f}% exact agreement with brute force")
    print(f"candidate windows examined: mean={float(res.n_candidates.mean()):.0f} "
          f"points/query (vs {len(docs)} brute-force)")
    print(f"overflowed windows: {int(res.overflow.sum())}")


if __name__ == "__main__":
    main()
