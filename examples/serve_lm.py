"""Batched serving example: continuous batching over a reduced llama3.2-3b.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import numpy as np
import jax

from repro.configs import get_config, reduced
from repro.models import api
from repro.nn.param import init_params
from repro.serving.engine import Request, ServingEngine


def main() -> None:
    cfg = reduced(get_config("llama3.2-3b"))
    params = init_params(api.param_defs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab, 24).astype(np.int32),
                    max_new_tokens=12) for i in range(9)]
    engine = ServingEngine(cfg, params, batch_size=3, max_len=64)
    stats = engine.run(reqs)
    print(f"served {sum(r.done for r in reqs)}/{len(reqs)} requests | "
          f"{stats['tokens']} tokens | {stats['tokens_per_s']:.1f} tok/s | "
          f"{stats['prefills']} prefills, {stats['decode_steps']} decode steps")
    for r in reqs[:3]:
        print(f"  req {r.uid}: prompt[:4]={r.prompt[:4].tolist()} -> "
              f"out={r.out_tokens}")


if __name__ == "__main__":
    main()
