"""Quickstart: AIDW spatial interpolation with grid-accelerated kNN.

Reproduces the paper's pipeline end to end on synthetic terrain:
build data -> improved AIDW (grid kNN + adaptive alpha + Eq.1 weighting)
-> compare against standard IDW and the brute-force 'original' algorithm.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import AidwConfig, aidw_improved, aidw_original, idw_standard
from repro.data.pipeline import spatial_points, spatial_queries, spatial_surface


def main() -> None:
    m, n = 8192, 2048
    pts = spatial_points(m, seed=0, noise=0.02)
    qs = spatial_queries(n, seed=1)
    truth = spatial_surface(qs[:, 0], qs[:, 1])

    cfg = AidwConfig(k=15)
    improved = aidw_improved(pts, qs, cfg, timings=True)
    original = aidw_original(pts, qs, cfg, timings=True)
    idw = np.asarray(idw_standard(pts, qs, alpha=2.0))

    rmse = lambda v: float(np.sqrt(np.mean((np.asarray(v) - truth) ** 2)))
    agree = float(np.abs(np.asarray(improved.values)
                         - np.asarray(original.values)).max())

    print(f"data points          : {m},  interpolated points: {n}")
    print(f"adaptive alpha range : [{float(improved.alpha.min()):.2f}, "
          f"{float(improved.alpha.max()):.2f}]")
    print(f"AIDW prediction RMSE : {rmse(improved.values):.4f}")
    print(f"IDW(a=2) RMSE        : {rmse(idw):.4f}")
    print(f"improved vs original : max |diff| = {agree:.2e} (same math)")
    print(f"stage times (s)      : kNN={improved.timings['knn']:.3f} "
          f"interp={improved.timings['interp']:.3f}  "
          f"(original kNN={original.timings['knn']:.3f})")
    print(f"window overflow      : {improved.overflow} queries")


if __name__ == "__main__":
    main()
