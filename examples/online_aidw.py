"""Online AIDW serving — the async subsystem end to end.

Multiple client threads submit interpolation requests (some deadline-bound)
to one :class:`repro.serving.AsyncAidwServer` while the dataset churns
underneath via incremental delta updates; the admission queue serializes
churn against query batches, the deadline-aware coalescer forms microbatches
on the resident session's compiled executables, and telemetry reports the
latency distribution at the end.

Run single-device, or simulate a pod slice on CPU:

  PYTHONPATH=src python examples/online_aidw.py
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python examples/online_aidw.py --mesh
"""

from __future__ import annotations

import argparse
import threading

import numpy as np

from repro.data.pipeline import spatial_points, spatial_queries
from repro.serving import AsyncAidwServer


def client(srv: AsyncAidwServer, cid: int, n_requests: int, results: list):
    """One client: a stream of odd-sized requests, every third with an SLO."""
    reqs = []
    for i in range(n_requests):
        qs = spatial_queries(97 + 13 * ((cid + i) % 5), seed=cid * 100 + i)
        deadline_s = 10.0 if i % 3 == 0 else None
        reqs.append(srv.submit(qs, deadline_s=deadline_s))
    for r in reqs:
        srv.result(r, timeout=300)
    results.append(reqs)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--points", type=int, default=16384)
    p.add_argument("--clients", type=int, default=3)
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--mesh", action="store_true")
    args = p.parse_args()

    mesh = None
    if args.mesh:
        import jax

        from repro.core.jax_compat import make_auto_mesh

        mesh = make_auto_mesh((len(jax.devices()),), ("q",))

    pts = spatial_points(args.points, seed=0)
    with AsyncAidwServer(pts, max_batch=4096, mesh=mesh,
                         query_domain=spatial_queries(1024, seed=1)) as srv:
        results: list = []
        threads = [threading.Thread(target=client,
                                    args=(srv, c, args.requests, results))
                   for c in range(args.clients)]
        for t in threads:
            t.start()
        # churn the dataset WHILE clients are in flight: the update is a FIFO
        # barrier on the worker, so it never races a query batch
        n_delta = max(args.points // 100, 1)
        srv.update_dataset(
            inserts=spatial_points(n_delta, seed=2),
            deletes=np.random.default_rng(3).choice(
                args.points, n_delta, replace=False))
        for t in threads:
            t.join()
        srv.flush(timeout=300)

        served = sum(r.status == "done" for reqs in results for r in reqs)
        total = sum(len(reqs) for reqs in results)
        rep = srv.report()
        lat = rep["latency"]["total"]
        print(f"served {served}/{total} requests from {args.clients} "
              f"client threads ({rep['shed']} shed, "
              f"{rep['dataset_updates']} dataset update mid-stream)")
        print(f"batches {rep['batches']}, {rep['queries_per_s']:.0f} q/s, "
              f"total-latency p50 {lat['p50_s'] * 1e3:.1f}ms / "
              f"p99 {lat['p99_s'] * 1e3:.1f}ms")
        s = srv.session.stats
        print(f"session: devices={s['devices']} "
              f"stage1_builds={s['stage1_builds']} "
              f"delta_updates={s['delta_updates']} "
              f"buckets={s['bucket_misses']}")


if __name__ == "__main__":
    main()
