"""Paper-table benchmarks (Mei, Xu & Xu 2016) — one function per table/figure.

The paper's protocol: n data points == n interpolated points, random in a
square; five sizes 10K..1000K on a GT730M GPU.  This container is CPU-only,
so sizes scale down (default 1K/4K/16K; --full adds 64K) and absolute times
are CPU times — the REPORTED quantities are the paper's own derived ratios
(stage splits, improved-vs-original speedups), which are hardware-relative.

CSV schema: name,us_per_call,derived
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import AidwConfig, aidw_improved, aidw_original, idw_standard
from repro.data.pipeline import spatial_points, spatial_queries

from .serial_ref import serial_aidw

SIZES = (1024, 4096, 16384)
FULL_SIZES = SIZES + (65536,)
K = 15


def _data(n, seed=0):
    return spatial_points(n, seed=seed), spatial_queries(n, seed=seed + 1)


def _time(fn, *args, reps=3, **kw):
    fn(*args, **kw)  # warmup / compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args, **kw)
        ts.append(time.perf_counter() - t0)
    return min(ts) * 1e6  # us


def table1_exec_time(sizes=SIZES, serial_cap: int = 8192) -> list[tuple]:
    """Table 1: execution time of serial / original / improved algorithms.

    'tiled' on this CPU container = the same Stage-2 math through the Pallas
    kernel in interpret mode at the SMALLEST size only (interpret mode is a
    correctness vehicle, not a performance one — see EXPERIMENTS.md).
    """
    rows = []
    for n in sizes:
        pts, qs = _data(n)
        cfg = AidwConfig(k=K)
        if n <= serial_cap:
            t_serial = _time(serial_aidw, pts, qs, k=K, reps=1)
            rows.append((f"table1/serial/{n}", t_serial, ""))
        t_orig = _time(lambda: aidw_original(pts, qs, cfg).values.block_until_ready())
        rows.append((f"table1/original_naive/{n}", t_orig, ""))
        t_impr = _time(lambda: aidw_improved(pts, qs, cfg).values.block_until_ready())
        rows.append((f"table1/improved_naive/{n}", t_impr, ""))
        if n <= serial_cap:
            rows.append((f"table1/speedup_improved_vs_serial/{n}", 0.0,
                         f"{t_serial / t_impr:.1f}x"))
        rows.append((f"table1/speedup_improved_vs_original/{n}", 0.0,
                     f"{t_orig / t_impr:.2f}x"))
    # tiled (Pallas interpret) at smallest size: structural + numerical check
    n = sizes[0]
    pts, qs = _data(n)
    cfg_t = AidwConfig(k=K, stage2="tiled", interpret=True)
    t_tiled = _time(lambda: aidw_improved(pts, qs, cfg_t).values.block_until_ready(),
                    reps=1)
    rows.append((f"table1/improved_tiled_interpret/{n}", t_tiled,
                 "pallas-interpret (correctness mode)"))
    return rows


def table2_stage_split(sizes=SIZES) -> list[tuple]:
    """Table 2 / Fig 7: kNN-search vs weighted-interpolation stage split."""
    rows = []
    for n in sizes:
        pts, qs = _data(n)
        res = aidw_improved(pts, qs, AidwConfig(k=K), timings=True)
        res = aidw_improved(pts, qs, AidwConfig(k=K), timings=True)  # warm
        knn_us = res.timings["knn"] * 1e6
        int_us = res.timings["interp"] * 1e6
        share = knn_us / (knn_us + int_us) * 100
        rows.append((f"table2/knn_stage/{n}", knn_us, f"{share:.1f}% of total"))
        rows.append((f"table2/interp_stage/{n}", int_us,
                     f"{100 - share:.1f}% of total"))
    return rows


def table3_knn_compare(sizes=SIZES) -> list[tuple]:
    """Table 3 / Fig 9: kNN stage, improved (grid) vs original (brute)."""
    rows = []
    for n in sizes:
        pts, qs = _data(n)
        t_impr = aidw_improved(pts, qs, AidwConfig(k=K), timings=True)
        t_impr = aidw_improved(pts, qs, AidwConfig(k=K), timings=True)
        t_orig = aidw_original(pts, qs, AidwConfig(k=K), timings=True)
        t_orig = aidw_original(pts, qs, AidwConfig(k=K), timings=True)
        g = t_impr.timings["knn"] * 1e6
        b = t_orig.timings["knn"] * 1e6
        rows.append((f"table3/grid_knn/{n}", g, ""))
        rows.append((f"table3/brute_knn/{n}", b, ""))
        rows.append((f"table3/knn_pct_of_original/{n}", 0.0,
                     f"{g / b * 100:.1f}%"))
    return rows


def accuracy_check(n: int = 4096) -> list[tuple]:
    """Beyond-paper: AIDW vs standard IDW prediction error on an analytic
    surface (the paper's own accuracy motivation, Lu & Wong 2008)."""
    from repro.data.pipeline import spatial_surface

    pts, qs = _data(n)
    truth = spatial_surface(qs[:, 0], qs[:, 1])
    aidw = np.asarray(aidw_improved(pts, qs, AidwConfig(k=K)).values)
    idw2 = np.asarray(idw_standard(pts, qs, alpha=2.0))
    serial = serial_aidw(pts, qs, k=K)
    rows = [
        ("accuracy/aidw_rmse", 0.0, f"{np.sqrt(np.mean((aidw - truth) ** 2)):.5f}"),
        ("accuracy/idw2_rmse", 0.0, f"{np.sqrt(np.mean((idw2 - truth) ** 2)):.5f}"),
        ("accuracy/aidw_vs_serial_maxerr", 0.0,
         f"{np.abs(aidw - serial).max():.2e}"),
    ]
    return rows
