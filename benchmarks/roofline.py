"""Roofline table assembly: dry-run artifacts + analytic model -> §Roofline.

Per (arch x shape) on the single-pod 16x16 mesh:

  compute term    = analytic MXU dot FLOPs / chip / 197e12       [s]
  memory term     = analytic HBM traffic / chip / 819e9          [s]
  collective term = probe-corrected wire bytes / chip / 50e9     [s]
  + peak bytes/device from the compiled memory_analysis (fits-HBM check)
  + MODEL_FLOPS / HLO(analytic-executed) usefulness ratio

Sources of each column and their caveats are documented in
EXPERIMENTS.md §Roofline-methodology.
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.configs import ARCH_IDS, get_config
from repro.launch.analytic import cell_cost
from repro.launch.dryrun import model_flops  # pure helpers (no jax device init)
from repro.models import api

ART = Path(__file__).resolve().parents[1] / "artifacts"

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
HBM_PER_CHIP = 16e9  # v5e


def _load(d: Path, arch: str, shape: str, mesh: str) -> dict | None:
    p = d / f"{arch}__{shape}__{mesh}.json"
    return json.loads(p.read_text()) if p.exists() else None


def cell_row(arch: str, shape_name: str, mesh: str = "pod") -> dict | None:
    cfg = get_config(arch)
    shape = api.SHAPES[shape_name]
    ok, reason = api.applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": reason}
    dr = _load(ART / "dryrun", arch, shape_name, mesh)
    pr = _load(ART / "probe", arch, shape_name, mesh)
    if dr is None or dr.get("status") != "ok":
        return {"arch": arch, "shape": shape_name, "status": "missing-dryrun"}

    n_chips = dr["n_chips"]
    cost = cell_cost(cfg, shape, n_chips)
    compute_s = cost.flops_chip / PEAK_FLOPS
    memory_s = cost.hbm_bytes_chip / HBM_BW
    if pr is not None and pr.get("status") == "ok":
        wire = pr["per_chip"]["wire_bytes"]
        wire_src = "probe"
    else:
        wire = dr["per_chip"]["collective_wire_bytes"]
        wire_src = "hlo-raw(undercount)"
    coll_s = wire / LINK_BW

    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops(cfg, shape)
    peak_mem = dr["memory"]["peak_bytes_per_device"]
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh, "status": "ok",
        "n_chips": n_chips,
        "compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s,
        "dominant": dominant, "step_lower_bound_s": bound,
        "wire_source": wire_src,
        "model_flops": mf,
        "useful_ratio": mf / cost.flops_global if cost.flops_global else None,
        "roofline_fraction": (mf / n_chips / PEAK_FLOPS) / bound if bound else None,
        "peak_bytes_per_device": peak_mem,
        "fits_hbm": bool(peak_mem is not None and peak_mem <= HBM_PER_CHIP),
        "notes": cost.notes,
    }


def full_table(mesh: str = "pod") -> list[dict]:
    rows = []
    for arch in ARCH_IDS:
        for shape_name in api.SHAPES:
            r = cell_row(arch, shape_name, mesh)
            if r is not None:
                rows.append(r)
    return rows


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | dominant | "
           "MF/HLO | roofline-frac | peak GB/dev | fits |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"{r['status']} | — | — | — | — |\n")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | {r['dominant']} | "
            f"{(r['useful_ratio'] or 0):.2f} | {(r['roofline_fraction'] or 0):.3f} | "
            f"{(r['peak_bytes_per_device'] or 0) / 1e9:.1f} | "
            f"{'Y' if r['fits_hbm'] else 'N'} |\n")
    return "".join(out)


def rows_csv(rows: list[dict]) -> list[tuple]:
    out = []
    for r in rows:
        if r["status"] != "ok":
            continue
        name = f"roofline/{r['arch']}/{r['shape']}"
        out.append((name, r["step_lower_bound_s"] * 1e6,
                    f"dom={r['dominant']};frac={(r['roofline_fraction'] or 0):.3f}"))
    return out
