"""Cold-plan vs warm-session AIDW throughput (the serving amortization story).

Workload model: heavy repeated query traffic over a mostly-static dataset.
Real traffic arrives in odd-sized batches, which is the worst case for the
one-shot pipeline: every distinct batch shape retraces + recompiles Stage-1
and Stage-2, and every call re-plans and re-bins the even grid.  The
InterpolationSession amortizes both — the grid build runs once and
power-of-two query bucketing keeps all batches on one compiled executable.

Reported rows (CSV schema name,us_per_call,derived):

* ``session/plan_build``        — one-time Stage-1 build (grid + CSR binning)
* ``session/cold_per_batch``    — ``aidw_improved`` per odd-sized batch
                                  (re-plan + re-bin + retrace per shape)
* ``session/warm_per_batch``    — ``session.query`` per batch, Stage-1 rebuild
                                  EXCLUDED by construction (plan is resident)
* ``session/warm_speedup``      — cold / warm throughput ratio
* ``session/fused_maxerr``      — fused (alpha-in-kernel) vs unfused Stage-2
* ``session/sharded_per_batch`` — warm ``session.query`` on a mesh over every
                                  visible device (run under
                                  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
                                  to exercise a real mesh on CPU); verified
                                  bit-identical to the single-device session
* ``session/update_full``       — full ``session.update`` (re-plan + re-bin)
* ``session/update_delta``      — incremental ``update(deltas=...)`` for a
                                  1% churn (rebin_delta, spec + executables
                                  kept) + the full/delta speedup ratio
* ``ring/stage1_brute``         — warm ``layout='ring'`` query throughput at
                                  >= 100k points (brute-force Stage 1: O(m)
                                  candidate distances per query)
* ``ring/stage1_grid``          — same mesh/points/queries with
                                  ``layout='grid_ring'`` (slab CSR + halo:
                                  O(window) candidates; measured per-query
                                  candidate count reported, checked against
                                  the analytic census), verified within
                                  tolerance of the replicated session
* ``ring/stage1_speedup``       — brute / grid-aware throughput ratio (the
                                  paper's grid-vs-brute headline, re-measured
                                  for the sharded layouts)
* ``ring/stage2_local``         — same grid-aware mesh with ``stage2='local'``
                                  (exact-k Stage 2 over the merged Stage-1
                                  window — the O(m)-per-query weighting
                                  rotation disappears); r_obs/alpha verified
                                  bit-identical to the global-Stage-2 ring
                                  session, values within the truncation
                                  tolerance
* ``ring/stage2_local_speedup`` — global / local Stage-2 throughput ratio;
                                  the run RAISES if this lands below 5x on
                                  the 8-device mesh (the PR 6 acceptance row)
* ``ingest/update_delta``       — warm ``grid_ring`` 1% churn through the
                                  per-slab donation-aliased delta staging +
                                  hot append rings (O(Δ + touched-slab)
                                  bytes to device)
* ``ingest/staged_reduction``   — staged bytes per delta vs the full-packet
                                  re-stage the same update used to upload;
                                  the run RAISES below 10x (the PR 7
                                  acceptance row)

Paper-table conventions apply (benchmarks/paper_tables.py): this container is
CPU-only, so the default sizes scale down; ``--full`` restores the paper-scale
serving shape (1M data points, 64K-query batches).

Standalone: ``python benchmarks/session_bench.py [--full] [--json]`` (the CI
mesh job uploads the ``--json`` output as the perf-trajectory artifact).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import AidwConfig, InterpolationSession, aidw_improved
from repro.data.pipeline import spatial_points, spatial_queries

# (m data points, base batch, number of traffic batches)
SIZES = (16384, 2048, 3)
FULL_SIZES = (1_048_576, 65536, 3)


def _batches(base: int, n_batches: int):
    """Odd-sized batches around ``base`` — realistic (non-padded) traffic."""
    return [spatial_queries(base - 17 * i - 1, seed=100 + i)
            for i in range(n_batches)]


def session_rows(sizes=SIZES) -> list[tuple]:
    m, base, n_batches = sizes
    pts = spatial_points(m, seed=0)
    traffic = _batches(base, n_batches)
    cfg = AidwConfig()
    rows: list[tuple] = []

    # -- cold: one-shot pipeline per batch (re-plan/re-bin/retrace each) -----
    aidw_improved(pts, traffic[0], cfg).values.block_until_ready()  # warm libs
    cold = []
    for qs in traffic:
        t0 = time.perf_counter()
        aidw_improved(pts, qs, cfg).values.block_until_ready()
        cold.append(time.perf_counter() - t0)
    cold_us = float(np.mean(cold)) * 1e6

    # -- warm: session with resident plan + bucketed executables -------------
    sess = InterpolationSession(pts, cfg, query_domain=traffic[0])
    plan_us = sess.stats["last_plan_s"] * 1e6
    sess.query(traffic[0]).values.block_until_ready()   # compile the bucket
    warm = []
    for qs in traffic:
        t0 = time.perf_counter()
        sess.query(qs).values.block_until_ready()
        warm.append(time.perf_counter() - t0)
    warm_us = float(np.mean(warm)) * 1e6

    qps_cold = base / (cold_us / 1e6)
    qps_warm = base / (warm_us / 1e6)
    rows.append((f"session/plan_build/{m}", plan_us, "one-time Stage-1 build"))
    rows.append((f"session/cold_per_batch/{m}x{base}", cold_us,
                 f"{qps_cold:.0f} q/s (re-plan+retrace per odd batch)"))
    rows.append((f"session/warm_per_batch/{m}x{base}", warm_us,
                 f"{qps_warm:.0f} q/s (Stage-1 rebuild excluded)"))
    rows.append((f"session/warm_speedup/{m}x{base}", 0.0,
                 f"{cold_us / warm_us:.1f}x warm-vs-cold throughput"))
    if sess.stats["stage1_builds"] != 1:   # bench invariant, not a debug check
        raise RuntimeError(f"warm session rebuilt Stage 1: {sess.stats}")
    return rows


def fused_rows(m: int = 4096, n: int = 1024) -> list[tuple]:
    """Exercise the fused alpha-in-kernel Stage-2 path and bound its error.

    Pallas interpret mode on CPU (correctness vehicle); on a TPU the fused
    path is one kernel launch for the whole Stage 2.
    """
    pts = spatial_points(m, seed=7)
    qs = spatial_queries(n, seed=8)
    kw = dict(tile_q=256, tile_d=512, interpret=True)
    unfused = InterpolationSession(pts, AidwConfig(), query_domain=qs)
    fused = InterpolationSession(
        pts, AidwConfig(stage2="tiled", fused=True, **kw), query_domain=qs)

    ref = np.asarray(unfused.query(qs).values)
    t0 = time.perf_counter()
    got = np.asarray(fused.query(qs).values)
    fused_us = (time.perf_counter() - t0) * 1e6
    err = float(np.abs(got - ref).max())
    if err >= 1e-5:
        raise RuntimeError(f"fused Stage-2 diverged from unfused: {err}")
    return [(f"session/fused_stage2_interpret/{m}x{n}", fused_us,
             f"maxerr={err:.1e} vs unfused (tol 1e-5)")]


def sharded_rows(sizes=SIZES) -> list[tuple]:
    """Warm SHARDED session throughput over a mesh of every visible device.

    On a 1-device host this degenerates to the shard_map-wrapped single-device
    path (still a correctness check); under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` it exercises the
    real 8-lane mesh partition.  Results are asserted bit-identical to the
    single-device session on the same dataset.
    """
    import jax

    from repro.core.jax_compat import make_auto_mesh

    m, base, n_batches = sizes
    n_dev = len(jax.devices())
    mesh = make_auto_mesh((n_dev,), ("q",))
    pts = spatial_points(m, seed=0)
    traffic = _batches(base, n_batches)

    single = InterpolationSession(pts, query_domain=traffic[0])
    sess = InterpolationSession(pts, query_domain=traffic[0], mesh=mesh)
    ref = np.asarray(single.query(traffic[0]).values)
    got = np.asarray(sess.query(traffic[0]).values)   # also compiles bucket
    assert np.array_equal(got, ref), \
        f"sharded != single-device: {np.abs(got - ref).max()}"
    warm = []
    for qs in traffic:
        t0 = time.perf_counter()
        sess.query(qs).values.block_until_ready()
        warm.append(time.perf_counter() - t0)
    warm_us = float(np.mean(warm)) * 1e6
    qps = base / (warm_us / 1e6)
    return [(f"session/sharded_per_batch/{m}x{base}", warm_us,
             f"{qps:.0f} q/s on {n_dev} device(s), bit-identical")]


def delta_rows(m: int = 100_000, churn: float = 0.01) -> list[tuple]:
    """Incremental ``update(deltas=...)`` vs full re-plan on a 100k dataset.

    A balanced 1% churn (equal inserts and deletes, so ``n_points`` and every
    compiled executable survive unchanged) through ``rebin_delta`` vs the
    full grid re-plan + re-bin the same refresh would otherwise cost.
    """
    d = max(int(m * churn), 1)
    pts = spatial_points(m, seed=3)
    sess = InterpolationSession(pts, query_domain=spatial_queries(256, seed=4))
    rng = np.random.default_rng(5)

    refreshes = [spatial_points(m, seed=10 + i) for i in range(3)]
    full = []
    for new_pts in refreshes:                # full re-plan of the same m
        t0 = time.perf_counter()
        sess.update(new_pts)
        full.append(time.perf_counter() - t0)
    full_us = float(np.mean(full)) * 1e6

    n_now = sess.plan.n_points
    churns = [(spatial_points(d, seed=20 + i),
               rng.choice(n_now, d, replace=False)) for i in range(3)]
    delta = []
    for ins, dels in churns:                 # balanced churn: delete d, add d
        t0 = time.perf_counter()
        sess.update(inserts=ins, deletes=dels)
        delta.append(time.perf_counter() - t0)
    delta_us = float(np.mean(delta)) * 1e6
    if sess.stats["delta_updates"] != 3:
        raise RuntimeError(
            f"update(deltas=...) fell back to a full re-plan: {sess.stats}")
    return [
        (f"session/update_full/{m}", full_us, "re-plan + full re-bin"),
        (f"session/update_delta/{m}x{d}", delta_us,
         f"{full_us / delta_us:.1f}x vs full re-plan ({churn:.0%} churn, "
         "spec + executables kept)"),
    ]


def ingest_rows(m: int = 120_000, churn: float = 0.01,
                ring_cap: int | None = None,
                n_updates: int = 3) -> list[tuple]:
    """O(Δ) device-side ingest: per-slab delta staging vs full re-stage.

    A balanced ``churn`` delta (equal inserts and deletes at 120k points)
    against a warm ``grid_ring`` session whose ring capacity holds the
    whole run: inserts land in the per-slab hot append rings and deletes
    tombstone in place, so each update stages O(Δ + touched-slab) bytes —
    the donation-aliased row patches — instead of re-uploading the O(m)
    stacked packet.  The acceptance gate RAISES if the measured staged
    bytes per update are not at least 10x below the full-packet re-stage
    (the construction-time upload of the same session), or if any update
    fell back to a full re-stage / spilled past the ring.
    """
    import jax

    from repro.core.jax_compat import make_auto_mesh

    n_dev = len(jax.devices())
    mesh = make_auto_mesh((n_dev,), ("q",))
    d = max(int(m * churn), 1)
    if ring_cap is None:
        # hold the whole run in-ring (2x slab-imbalance headroom): a fold
        # mid-run would stage the full packet and poison the average
        ring_cap = max(256, 2 * n_updates * d // n_dev)
    pts = spatial_points(m, seed=3)
    qd = spatial_queries(256, seed=4)
    sess = InterpolationSession(pts, query_domain=qd, mesh=mesh,
                                layout="grid_ring", ring_cap=ring_cap)
    sess.query(qd).values.block_until_ready()           # compile the bucket
    full_bytes = sess.stats["staged_bytes"]             # construction upload
    rng = np.random.default_rng(5)
    # inserts must stay inside the FROZEN grid bbox: plan_delta's bbox
    # fallback turns an out-of-bounds insert into a full re-plan, which is
    # exactly the path this row exists to avoid measuring
    lo, hi = pts[:, :2].min(axis=0), pts[:, :2].max(axis=0)

    staged, times = [], []
    for i in range(n_updates):
        ins = spatial_points(d, seed=40 + i)
        ins[:, :2] = np.clip(ins[:, :2], lo, hi)
        dels = rng.choice(m, d, replace=False)
        t0 = time.perf_counter()
        sess.update(inserts=ins, deletes=dels)
        sess.query(qd).values.block_until_ready()       # warm-path serve
        times.append(time.perf_counter() - t0)
        staged.append(sess.stats["staged_bytes"])
    if sess.stats["delta_updates"] != n_updates \
            or sess.stats["full_restages"] != 1 \
            or sess.stats["spilled_updates"]:
        raise RuntimeError(
            f"delta ingest fell off the O(Delta) path: {sess.stats}")
    delta_bytes = float(np.mean(staged))
    reduction = full_bytes / max(delta_bytes, 1.0)
    if reduction < 10.0:
        raise RuntimeError(
            f"ingest acceptance gate: staged-bytes reduction "
            f"{reduction:.1f}x < 10x at {m}x{d} ({delta_bytes:.0f} B/update "
            f"vs {full_bytes} B full packet)")
    delta_us = float(np.mean(times)) * 1e6
    occ = sess.stats["ring_occupancy"]
    return [
        (f"ingest/update_delta/{m}x{d}x{n_dev}dev", delta_us,
         f"{delta_bytes:.0f} B staged/update, {sess.stats['slabs_touched']} "
         f"slab(s) touched, ring {occ:.0%} full, tombstones "
         f"{sess.stats['tombstone_frac']:.2%}"),
        (f"ingest/staged_reduction/{m}x{d}x{n_dev}dev", 0.0,
         f"{reduction:.0f}x fewer staged bytes vs full {full_bytes} B "
         f"packet re-stage ({churn:.0%} churn; >=10x required)"),
    ]


def ring_rows(m: int = 120_000, nq: int = 1024, n_batches: int = 3,
              tol: float = 1e-4, local_tol: float = 5e-2) -> list[tuple]:
    """Brute-force ring vs grid-aware ring Stage 1 at >= 100k points.

    Both layouts run warm on a mesh over every visible device (the CI mesh
    suite forces 8 host devices) with identical points/queries/config; the
    grid-aware session is additionally checked within ``tol`` of the
    REPLICATED session (the halo/merge correctness witness) and its
    measured per-query Stage-1 candidate count is reported next to the
    analytic census's prediction — the paper's grid-vs-brute claim,
    re-measured for the sharded serving layouts.

    The ``ring/stage2_local*`` rows then re-run the grid-aware layout with
    ``stage2='local'``: Stage 2 interpolates each query from only its k
    merged Stage-1 neighbours, so the per-query O(m) weighting rotation
    disappears.  r_obs/alpha must be BIT-identical to the global session
    (same Stage-1 window by construction) and values within ``local_tol``
    (the truncated far-field tail: the uniform pattern draws alpha ~ 2 from
    Eq. (6), whose 1/d^2 tail mass shrinks only logarithmically with radius,
    so a few-1e-3 drift at k=15 is the expected truncation cost — the
    analytic f64 tail bound is pinned per regime in
    ``tests/test_local_stage2.py``; clustered data, alpha ~ 0.5, is looser
    still).  On a mesh of >= 8 devices a speedup below 5x RAISES — the
    acceptance gate for the exact-k local mode.
    """
    import jax

    from repro.core.jax_compat import make_auto_mesh
    from repro.launch.analytic import aidw_ring_stage1_census

    n_dev = len(jax.devices())
    mesh = make_auto_mesh((n_dev,), ("q",))
    pts = spatial_points(m, seed=0)
    traffic = [spatial_queries(nq - 17 * i, seed=300 + i)
               for i in range(n_batches)]

    def warm_and_time(layout, cfg=AidwConfig()):
        sess = InterpolationSession(pts, cfg, query_domain=traffic[0],
                                    mesh=mesh, layout=layout)
        sess.query(traffic[0]).values.block_until_ready()   # compile bucket
        times = []
        for qs in traffic:
            t0 = time.perf_counter()
            sess.query(qs).values.block_until_ready()
            times.append(time.perf_counter() - t0)
        return sess, float(np.mean(times)) * 1e6

    brute_sess, brute_us = warm_and_time("ring")
    grid_sess, grid_us = warm_and_time("grid_ring")
    local_sess, local_us = warm_and_time("grid_ring", AidwConfig(stage2="local"))

    ref = InterpolationSession(pts, query_domain=traffic[0])
    want = np.asarray(ref.query(traffic[-1]).values)
    got = np.asarray(grid_sess.query(traffic[-1]).values)
    err = float(np.abs(got - want).max())
    if err >= tol:
        raise RuntimeError(f"grid-aware ring diverged from replicated "
                           f"session: maxerr {err} >= {tol}")
    cand = float(np.asarray(grid_sess.last_stage1_candidates).mean())
    census = aidw_ring_stage1_census(m, n_dev)
    qps_b = nq / (brute_us / 1e6)
    qps_g = nq / (grid_us / 1e6)

    # -- exact-k local Stage 2: same Stage-1 window, no weighting rotation ---
    g_res = grid_sess.query(traffic[-1])
    l_res = local_sess.query(traffic[-1])
    for field in ("r_obs", "alpha"):
        if not np.array_equal(np.asarray(getattr(l_res, field)),
                              np.asarray(getattr(g_res, field))):
            raise RuntimeError(
                f"stage2='local' {field} not bit-identical to global ring")
    lerr = float(np.abs(np.asarray(l_res.values)
                        - np.asarray(g_res.values)).max())
    if lerr >= local_tol:
        raise RuntimeError(f"stage2='local' values diverged from global "
                           f"beyond the truncation tolerance: {lerr} >= "
                           f"{local_tol}")
    local_speedup = grid_us / local_us
    if n_dev >= 8 and local_speedup < 5.0:
        raise RuntimeError(
            f"stage2='local' acceptance gate: {local_speedup:.1f}x < 5x over "
            f"the global ring Stage 2 at {m}x{nq}x{n_dev}dev")
    qps_l = nq / (local_us / 1e6)

    return [
        (f"ring/stage1_brute/{m}x{nq}x{n_dev}dev", brute_us,
         f"{qps_b:.0f} q/s (O(m): {m} candidate dists/query)"),
        (f"ring/stage1_grid/{m}x{nq}x{n_dev}dev", grid_us,
         f"{qps_g:.0f} q/s, measured {cand:.0f} candidates/query "
         f"(census {census.grid_candidates:.0f}), maxerr {err:.1e} vs "
         f"replicated"),
        (f"ring/stage1_speedup/{m}x{nq}x{n_dev}dev", 0.0,
         f"{brute_us / grid_us:.1f}x grid-aware vs brute ring "
         f"(census candidate reduction {census.reduction:.0f}x)"),
        (f"ring/stage2_local/{m}x{nq}x{n_dev}dev", local_us,
         f"{qps_l:.0f} q/s exact-k local Stage 2, r_obs/alpha bitwise vs "
         f"global, value maxerr {lerr:.1e} (tol {local_tol:.0e})"),
        (f"ring/stage2_local_speedup/{m}x{nq}x{n_dev}dev", 0.0,
         f"{local_speedup:.1f}x local vs global Stage 2 on the grid-aware "
         f"ring (>=5x required on the 8-device mesh)"),
    ]


def main() -> None:
    import argparse
    import json

    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true")
    p.add_argument("--json", action="store_true",
                   help="emit a JSON array instead of CSV (CI artifact)")
    p.add_argument("--skip-ring", action="store_true",
                   help="skip the brute-vs-grid ring Stage-1 rows")
    p.add_argument("--skip-ingest", action="store_true",
                   help="skip the O(Delta) delta-staging ingest rows")
    args = p.parse_args()

    sizes = FULL_SIZES if args.full else SIZES
    rows = session_rows(sizes) + fused_rows() + sharded_rows(sizes) \
        + delta_rows()
    if not args.skip_ring:
        rows += ring_rows()
    if not args.skip_ingest:
        rows += ingest_rows()
    if args.json:
        print(json.dumps([{"name": n, "us_per_call": us, "derived": d}
                          for n, us, d in rows], indent=2))
    else:
        print("name,us_per_call,derived")
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
