"""Open-loop load generator for the async AIDW serving subsystem.

Drives :class:`repro.serving.AsyncAidwServer` with OPEN-LOOP Poisson
arrivals — requests are submitted at exponentially-spaced instants from a
pre-drawn trace, regardless of completions, so queueing delay under
overload is measured instead of hidden (a closed-loop generator would
self-throttle and report flattering latencies).

The trace mixes deadline classes (``--deadline-frac`` of requests carry a
deadline drawn from ``--deadline-ms``; the rest are best-effort) and
odd-sized request bodies, which exercises the deadline-aware coalescer and
the session's power-of-two bucketing together.

Output: CSV rows via :func:`load_rows` (wired into ``benchmarks/run.py``)
or a JSON latency report with ``--json`` (the CI serving-suite job uploads
it as the latency-trajectory artifact next to the session benchmark):

    {"config": {...}, "report": {submitted, completed, shed, queries_per_s,
                                 latency: {queue, execute, total:
                                           {p50_s, p95_s, p99_s, ...}}},
     "lost": 0, "duplicated": 0}

``--mesh`` serves the load over every visible device (run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to simulate a pod
slice on CPU).  ``--cluster N`` replays the SAME open-loop trace against an
N-host :class:`repro.serving.cluster.AidwCluster` fleet — queries routed
across hosts, updates broadcast as epoch-ordered barriers — and reports the
MERGED fleet telemetry (per-host histograms merged bin-exactly into fleet
p50/p95/p99, QPS and shed counters summed; per-host reports attached).
``--cluster-procs`` backs every host but the coordinator's with a real
subprocess over the socket control plane.

Tracing (PR 8): ``--trace-sample-rate P`` turns on end-to-end spans —
single-server mode builds the server's tracer at rate ``P``; cluster mode
samples at the ROUTER (rate ``P``) and runs every host's tracer at rate 0
so propagated contexts are recorded but no fleet-invisible roots start.
``--trace-out PATH`` writes the collected spans as Chrome ``trace_event``
JSON (loads in ``chrome://tracing``/Perfetto; the CI cluster-suite uploads
it as the sample-trace artifact).  ``--trace-overhead-gate`` runs the
observability overhead acceptance check instead of a plain load run: two
identical loads, one with no tracer and no flight recorder, one with a
sample-rate-0 tracer plus the always-on recorder (the production
configuration), and RAISES when the instrumented p99 exceeds
``TRACE_OVERHEAD_LIMIT`` (2%) over baseline — best of 3 attempts, since
open-loop p99 on a shared CPU box is noisy and the gate exists to catch
hot-path instrumentation cost, not scheduler jitter.  The same flag then
runs the tail-sampling retention gate (``recorder_retention_rows``): a
deadline-heavy trace where >= 95% of missed-deadline requests must retain
full span trees, zero in-SLO requests may be retained, and the tail
attribution must decompose the p99-p50 gap within 15%.  ``--debugz-out
PATH`` (PR 9) writes the diagnostics bundle — fleet-merged under
``--cluster`` — as the CI debugz artifact.  Standalone:

    PYTHONPATH=src python benchmarks/load_gen.py [--json] [--mesh]
        [--requests N] [--rate QPS] [--updates K]
        [--cluster N [--cluster-procs]] [--policy least_loaded]
        [--trace-sample-rate P] [--trace-out trace.json]
        [--trace-overhead-gate] [--debugz-out debugz.json]
        [--no-warmup] [--prewarm sync|background]
        [--compilation-cache-dir DIR]
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from repro.data.pipeline import spatial_points, spatial_queries
from repro.serving import AsyncAidwServer


def make_trace(n_requests: int, rate_rps: float, req_queries: int,
               deadline_frac: float, deadline_ms: tuple, seed: int = 0):
    """Pre-draw the open-loop arrival trace.

    Returns a list of ``(t_arrival_s, n_queries, deadline_s_or_None)``:
    exponential inter-arrivals at ``rate_rps`` requests/s, odd-ish request
    sizes around ``req_queries``, and a ``deadline_frac`` mix of
    deadline-bound requests with deadlines drawn uniformly from
    ``deadline_ms`` (milliseconds, relative to arrival).
    """
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_rps, n_requests)
    arrivals = np.cumsum(gaps)
    trace = []
    for i in range(n_requests):
        n = max(1, req_queries - int(rng.integers(0, max(req_queries // 3,
                                                         2))))
        deadline = None
        if rng.random() < deadline_frac:
            deadline = float(rng.uniform(*deadline_ms)) / 1e3
        trace.append((float(arrivals[i]), n, deadline))
    return trace


def run_load(server, trace, *, updates: int = 0,
             points: int = 0, seed: int = 0,
             write_rate_rps: float = 0.0, write_batch: int = 32,
             write_bbox=None) -> dict:
    """Replay ``trace`` against ``server`` (open loop), optionally weaving
    ``updates`` incremental dataset deltas through the admission stream at
    even intervals.  Returns the JSON report body.

    ``write_rate_rps > 0`` turns on the MIXED read/write mode: writer
    arrivals are drawn from their own open-loop Poisson process over the
    trace horizon, and each due write submits one balanced
    ``write_batch``-point delta NON-BLOCKING (``submit_update``) — a FIFO
    barrier in the admission stream, never a stop-the-world wait — with all
    write handles awaited at flush time.  ``write_bbox`` (lo, hi) clips
    insert coordinates into the frozen grid bbox so the O(Δ) delta path is
    what gets measured, not the out-of-bbox full-re-plan fallback.
    Single-server mode only (the fleet's epoch-ordered writes go through
    ``update_dataset``/``compact``).

    ``server`` is anything with the submit/update_dataset/flush/report
    surface: an :class:`AsyncAidwServer` or a multi-host
    :class:`repro.serving.cluster.AidwCluster` (whose ``report()`` nests
    the merged fleet view — ``drive_cluster`` flattens it)."""
    rng = np.random.default_rng(seed + 1)
    update_every = len(trace) // (updates + 1) if updates else None
    write_arrivals = []
    if write_rate_rps > 0:
        wr = np.random.default_rng(seed + 7)
        t = wr.exponential(1.0 / write_rate_rps)
        while t < trace[-1][0]:
            write_arrivals.append(t)
            t += wr.exponential(1.0 / write_rate_rps)
    reqs, write_ops, wi = [], [], 0
    t0 = time.monotonic()
    for i, (t_arrival, n, deadline_s) in enumerate(trace):
        if update_every and i and i % update_every == 0 \
                and len(reqs) // update_every <= updates:
            d = max(points // 100, 1)
            server.update_dataset(
                inserts=spatial_points(d, seed=seed + 50 + i),
                deletes=rng.choice(max(points - d, 1), d, replace=False))
        while wi < len(write_arrivals) and write_arrivals[wi] <= t_arrival:
            ins = spatial_points(write_batch, seed=seed + 5000 + wi)
            if write_bbox is not None:
                ins[:, :2] = np.clip(ins[:, :2], *write_bbox)
            write_ops.append(server.submit_update(
                inserts=ins,
                deletes=rng.choice(max(points, 1), write_batch,
                                   replace=False),
                timeout=60))
            wi += 1
        now = time.monotonic() - t0
        if t_arrival > now:                  # open loop: wait for the slot,
            time.sleep(t_arrival - now)      # never for completions
            now = t_arrival
        # deadlines are anchored at the TRACE arrival, not at submit: when
        # submission falls behind (update barrier blocking, backpressure),
        # a delayed request must NOT gain deadline budget — that is exactly
        # the overload signal an open-loop harness exists to report
        reqs.append(server.submit(
            spatial_queries(n, seed=seed + 1000 + i),
            deadline_s=None if deadline_s is None
            else t_arrival + deadline_s - now))
    wall_submit = time.monotonic() - t0
    for op in write_ops:
        server.wait_update(op, timeout=600)
    server.flush(timeout=600)
    wall_total = time.monotonic() - t0

    terminal = [r for r in reqs if r.status in ("done", "shed")]
    report = server.report()
    return {
        "report": report,
        "offered_rps": len(trace) / max(wall_submit, 1e-9),
        "wall_s": wall_total,
        "writes": len(write_ops),
        "lost": len(reqs) - len(terminal),
        "duplicated": len(reqs) - len({r.uid for r in reqs}),
        # the request objects themselves (NOT JSON: the CLI pops this
        # before serializing) — the recorder retention gate needs per-
        # request terminal state to cross-check against retained traces
        "_reqs": reqs,
    }


def drive(points: int, trace, *, max_batch: int = 4096, mesh=None,
          updates: int = 3, req_queries: int = 96, seed: int = 0,
          pipeline_depth: int = 0, layout: str = "replicated",
          ring_cap: int = 1024, write_rate_rps: float = 0.0,
          write_batch: int = 32,
          trace_sample_rate: float | None = None,
          record_tail: bool = True, recorder_opts: dict | None = None,
          debugz: bool = False, warmup: bool = True,
          prewarm: str | None = None) -> dict:
    """Build a server, warm it, and replay ``trace`` (shared by the CSV rows
    and the JSON CLI so both measure the same configuration).

    Warmup primes the executables + the scheduler's execute-time model,
    then telemetry is RESET so the reported window reflects steady state,
    not first-bucket compiles.  ``warmup=False`` (``--no-warmup``) skips
    both, so the replay measures the COLD trajectory — first-bucket
    compiles land inside the reported latencies (the cold-start rows; pair
    with a persistent compilation cache to measure the restart path).
    ``prewarm`` passes through to :class:`AsyncAidwServer` (AOT-compile
    the whole bucket ladder at construction).  ``pipeline_depth`` turns on the worker's
    launch-ahead pipelining (``--pipeline``; a measured experiment — see
    ROADMAP's post-PR-5 re-triage for the CPU result).  ``write_rate_rps``
    turns on the mixed read/write open-loop mode (:func:`run_load`);
    ``layout='grid_ring'`` (+ ``mesh``) serves writes through the O(Δ)
    per-slab delta staging instead of a full re-stage per delta.
    ``trace_sample_rate`` builds the server's tracer at that rate (``None``
    = no tracer at all — the overhead-gate baseline); collected spans ride
    out under ``"spans"``.  ``record_tail=False`` drops the always-on
    flight recorder too (the PR-9 overhead-gate baseline: no observability
    objects at all on the hot path); ``recorder_opts`` pass through to
    :class:`repro.obs.FlightRecorder` (the retention gate pins
    ``top_percentile=None`` so retention is a pure function of the trace);
    ``debugz=True`` attaches the server's diagnostics bundle under
    ``"debugz"``.
    """
    pts = spatial_points(points, seed=seed)
    with AsyncAidwServer(pts, max_batch=max_batch, mesh=mesh, layout=layout,
                         ring_cap=ring_cap, pipeline_depth=pipeline_depth,
                         trace_sample_rate=trace_sample_rate,
                         record_tail=record_tail, recorder_opts=recorder_opts,
                         prewarm=prewarm,
                         query_domain=spatial_queries(1024, seed=1)) as srv:
        if warmup:
            for _ in range(3):
                srv.submit(spatial_queries(req_queries, seed=2))
            srv.flush(timeout=600)
            srv.telemetry.reset()
            srv.spans()                 # drop warmup spans ([] if no tracer)
            for k in srv.queue.counters:
                srv.queue.counters[k] = 0
        out = run_load(srv, trace, updates=updates, points=points,
                       seed=seed, write_rate_rps=write_rate_rps,
                       write_batch=write_batch,
                       write_bbox=(pts[:, :2].min(axis=0),
                                   pts[:, :2].max(axis=0)))
        if trace_sample_rate:
            out["spans"] = srv.spans()
        if debugz:
            out["debugz"] = srv.debugz()
        return out


def drive_cluster(points: int, trace, *, n_hosts: int, procs: bool = False,
                  max_batch: int = 4096, updates: int = 3,
                  req_queries: int = 96, seed: int = 0,
                  policy: str = "round_robin", mesh=None,
                  trace_sample_rate: float | None = None,
                  debugz: bool = False, warmup: bool = True) -> dict:
    """Replay ``trace`` against an ``n_hosts`` fleet; returns the merged
    fleet report (flattened: ``report`` = fleet view, ``hosts``/``routing``
    attached).

    ``procs=True`` runs every host except host 0 as a REAL subprocess
    behind the socket control plane (``repro.serving.cluster.rpc``) — the
    multi-host deployment shape, minus the machines.  ``mesh`` applies to
    IN-PROCESS hosts only (they share this process's devices); subprocess
    hosts build their own local mesh from their own visible devices.
    ``trace_sample_rate`` samples at the ROUTER; hosts (subprocess ones
    included) run their tracers at rate 0 so they record propagated
    contexts without starting fleet-invisible roots; spans collected from
    every live host ride out under ``"spans"``.  ``debugz=True`` attaches
    the MERGED fleet diagnostics bundle (per-host debugz + fleet-level
    SLO events + tail-latency attribution) under ``"debugz"``.
    """
    import os

    from repro.serving.cluster import AidwCluster, HostServer, RemoteHost
    from repro.serving.cluster.rpc import free_port_base, spawn_worker

    pts = spatial_points(points, seed=seed)
    qd = spatial_queries(1024, seed=1)
    workers, hosts = [], None
    host_rate = 0.0 if trace_sample_rate is not None else None
    if procs and n_hosts > 1:
        base = free_port_base(n_hosts)
        env = dict(os.environ)
        env.setdefault("PYTHONPATH", "src")
        workers = [spawn_worker(i, n_hosts, points=points, seed=seed,
                                control_port=base, max_batch=max_batch,
                                trace_sample_rate=host_rate, env=env)
                   for i in range(1, n_hosts)]
        hosts = [HostServer(0, pts, max_batch=max_batch, query_domain=qd,
                            trace_sample_rate=host_rate)] \
            + [RemoteHost(i, ("127.0.0.1", base + i), connect_timeout_s=300)
               for i in range(1, n_hosts)]
    try:
        with AidwCluster(None if hosts else pts, n_hosts=n_hosts,
                         hosts=hosts, policy=policy,
                         trace_sample_rate=trace_sample_rate,
                         **({} if hosts else
                            {"max_batch": max_batch,
                             "query_domain": qd, "mesh": mesh})) as cl:
            if warmup:
                # parallel warmup: every host compiles its executables
                # CONCURRENTLY under one fleet deadline (cold-start used to
                # be per-host sequential and dominated the 2-host CPU bench
                # rows)
                cl.warmup(spatial_queries(req_queries, seed=2),
                          batches_per_host=3, timeout=600)
                cl.reset_telemetry()
            out = run_load(cl, trace, updates=updates, points=points,
                           seed=seed)
            rep = out["report"]              # AidwCluster.report(): nested
            out["report"] = rep["fleet"]
            out["hosts"] = rep["hosts"]
            out["routing"] = rep["routing"]
            out["epoch"] = rep["epoch"]
            if trace_sample_rate:
                out["spans"] = cl.collect_spans()
            if debugz:
                out["debugz"] = cl.debugz()
    finally:
        for w in workers:
            try:
                w.wait(timeout=60)
            except Exception:
                w.kill()
    return out


def load_rows(n_requests: int = 96, rate_rps: float = 400.0,
              req_queries: int = 96, points: int = 16384,
              deadline_frac: float = 0.25,
              deadline_ms: tuple = (20.0, 200.0), updates: int = 3,
              seed: int = 0, mesh=None) -> list[tuple]:
    """CSV rows for benchmarks/run.py (schema name,us_per_call,derived)."""
    trace = make_trace(n_requests, rate_rps, req_queries, deadline_frac,
                       deadline_ms, seed=seed)
    out = drive(points, trace, mesh=mesh, updates=updates,
                req_queries=req_queries, seed=seed)
    rep = out["report"]
    lat = rep["latency"]
    if out["lost"] or out["duplicated"]:
        raise RuntimeError(f"load run lost/duplicated requests: "
                           f"{out['lost']}/{out['duplicated']}")
    tag = f"{points}x{req_queries}@{rate_rps:.0f}rps"
    return [
        (f"serving/load_total_p50/{tag}", lat["total"]["p50_s"] * 1e6,
         f"{rep['queries_per_s']:.0f} q/s served, "
         f"{out['offered_rps']:.0f} req/s offered"),
        (f"serving/load_total_p99/{tag}", lat["total"]["p99_s"] * 1e6,
         f"queue p99 {lat['queue']['p99_s'] * 1e3:.1f}ms, "
         f"execute p99 {lat['execute']['p99_s'] * 1e3:.1f}ms"),
        (f"serving/load_shed/{tag}", 0.0,
         f"{rep['shed']} shed / {rep['completed']} completed "
         f"({updates} delta updates interleaved)"),
    ]


def mixed_rows(n_requests: int = 96, rate_rps: float = 400.0,
               req_queries: int = 96, points: int = 16384,
               write_rate_rps: float = 25.0, write_batch: int = 32,
               seed: int = 0, p99_ratio_limit: float = 1.5) -> list[tuple]:
    """Sustained-churn rows: read-only vs mixed read/write p99 at the SAME
    offered read load, served from a ``grid_ring`` session whose writes ride
    the O(Δ) per-slab delta staging + hot append rings.

    The acceptance gate RAISES when the mixed-workload p99 exceeds
    ``p99_ratio_limit`` x the read-only p99 (best of two attempts — open-
    loop p99 on a shared CPU CI box is noisy, and the gate exists to catch
    systematic write-path stalls, not scheduler jitter), or when any
    request is lost/duplicated under churn (the mixed-workload invariant).

    The offered load is CALIBRATED to the box before the comparison: at
    oversaturation an open-loop p99 measures queue depth, which grows with
    ANY extra work — the ratio would trip on healthy write paths on slow
    machines and hide real stalls on fast ones.  A short saturating burst
    measures read capacity; both runs then offer ~40% of it (capped at
    ``rate_rps``), with the writer rate capped at a 1:4 write:read ratio."""
    import jax

    from repro.core.jax_compat import make_auto_mesh

    mesh = make_auto_mesh((len(jax.devices()),), ("q",))
    kw = dict(mesh=mesh, layout="grid_ring", updates=0,
              req_queries=req_queries, seed=seed)
    cal = drive(points, make_trace(12, 1000.0, req_queries, 0.0,
                                   (0.0, 0.0), seed=seed), **kw)
    cap_rps = cal["report"]["queries_per_s"] / req_queries
    rate_rps = max(min(rate_rps, 0.4 * cap_rps), 2.0)
    write_rate_rps = max(min(write_rate_rps, rate_rps / 4), 1.0)
    # deadline-free trace: a shed tail would censor exactly the p99 this
    # row compares across the two runs
    trace = make_trace(n_requests, rate_rps, req_queries,
                       deadline_frac=0.0, deadline_ms=(0.0, 0.0), seed=seed)
    for attempt in (1, 2):
        ro = drive(points, trace, **kw)
        mixed = drive(points, trace, write_rate_rps=write_rate_rps,
                      write_batch=write_batch, **kw)
        for out in (ro, mixed):
            if out["lost"] or out["duplicated"]:
                raise RuntimeError(
                    f"mixed-workload run lost/duplicated requests: "
                    f"{out['lost']}/{out['duplicated']}")
        ro_p99 = ro["report"]["latency"]["total"]["p99_s"]
        mx_p99 = mixed["report"]["latency"]["total"]["p99_s"]
        ratio = mx_p99 / max(ro_p99, 1e-9)
        if ratio <= p99_ratio_limit:
            break
    if ratio > p99_ratio_limit:
        raise RuntimeError(
            f"mixed-workload acceptance gate: p99 ratio {ratio:.2f}x > "
            f"{p99_ratio_limit}x at {write_rate_rps:.0f} writes/s "
            f"(read-only {ro_p99 * 1e3:.1f}ms, mixed {mx_p99 * 1e3:.1f}ms)")
    sess = mixed["report"]["session"]
    tag = f"{points}x{req_queries}@{rate_rps:.0f}r+{write_rate_rps:.0f}w"
    return [
        (f"serving/churn_read_p99/{tag}", ro_p99 * 1e6,
         f"read-only baseline, {ro['report']['queries_per_s']:.0f} q/s"),
        (f"serving/churn_mixed_p99/{tag}", mx_p99 * 1e6,
         f"{ratio:.2f}x read-only p99 (limit {p99_ratio_limit}x), "
         f"{mixed['writes']} writes of {write_batch} pts applied"),
        (f"serving/churn_staged_bytes/{tag}",
         sess.get("staged_bytes", 0),
         f"last delta staged {sess.get('staged_bytes', 0)} B, ring "
         f"{sess.get('ring_occupancy', 0.0):.0%} full, "
         f"{sess.get('compactions', 0)} compactions, "
         f"{sess.get('spilled_updates', 0)} spills"),
    ]


TRACE_OVERHEAD_LIMIT = 1.02     # traced/baseline p99 ceiling (the <2% story)


def trace_overhead_rows(n_requests: int = 64, rate_rps: float = 200.0,
                        req_queries: int = 96, points: int = 16384,
                        seed: int = 0, attempts: int = 3) -> list[tuple]:
    """The always-on observability overhead acceptance gate.

    Replays one open-loop trace twice — baseline with NO observability
    objects anywhere on the hot path (``trace_sample_rate=None`` +
    ``record_tail=False``: the pre-PR-8 configuration) vs the full
    production configuration (``trace_sample_rate=0.0``: tracer built,
    sampler never admits; flight recorder ON, classifying and recording
    every request) — and RAISES when the instrumented p99 exceeds
    ``TRACE_OVERHEAD_LIMIT`` x baseline on the best of ``attempts`` runs.
    Deadline-free trace (a shed tail would censor the very p99 under
    comparison) at a sub-saturation rate (at oversaturation p99 measures
    queue depth, which amplifies any jitter into false trips)."""
    trace = make_trace(n_requests, rate_rps, req_queries,
                       deadline_frac=0.0, deadline_ms=(0.0, 0.0), seed=seed)
    kw = dict(updates=0, req_queries=req_queries, seed=seed)
    best = float("inf")
    for _ in range(attempts):
        base = drive(points, trace, trace_sample_rate=None,
                     record_tail=False, **kw)
        traced = drive(points, trace, trace_sample_rate=0.0,
                       record_tail=True, **kw)
        for out in (base, traced):
            if out["lost"] or out["duplicated"]:
                raise RuntimeError(
                    f"trace-overhead run lost/duplicated requests: "
                    f"{out['lost']}/{out['duplicated']}")
        b99 = base["report"]["latency"]["total"]["p99_s"]
        t99 = traced["report"]["latency"]["total"]["p99_s"]
        ratio = t99 / max(b99, 1e-12)
        best = min(best, ratio)
        if best <= TRACE_OVERHEAD_LIMIT:
            break
    if best > TRACE_OVERHEAD_LIMIT:
        raise RuntimeError(
            f"trace overhead gate: rate-0 tracing + flight recorder p99 is "
            f"{best:.3f}x baseline (> {TRACE_OVERHEAD_LIMIT}x) over "
            f"{attempts} attempts "
            f"(baseline {b99 * 1e3:.2f}ms, instrumented {t99 * 1e3:.2f}ms)")
    tag = f"{points}x{req_queries}@{rate_rps:.0f}rps"
    return [
        (f"serving/trace_overhead_p99_ratio/{tag}", 0.0,
         f"rate-0 tracing + recorder p99 {best:.3f}x baseline "
         f"(limit {TRACE_OVERHEAD_LIMIT}x, best of {attempts})"),
    ]


def recorder_retention_rows(n_requests: int = 48, rate_rps: float = 300.0,
                            req_queries: int = 96, points: int = 16384,
                            seed: int = 0) -> list[tuple]:
    """The tail-sampling retention acceptance gate.

    Replays a deadline-heavy open-loop trace (half the requests carry
    deadlines drawn from 0.5–10ms — tight enough that some MUST miss under
    real dispatch latency) against a recorder with the noise classes off
    (``top_percentile=None``: no 'slow' class, so retention is a pure
    function of each request's own outcome) and a ring large enough that
    nothing evicts.  Asserts the ISSUE-9 acceptance bars:

    - >= 95% of requests that MISSED their deadline (shed at admission/
      dispatch, or served past it) have a full span tree retained;
    - ZERO in-SLO requests (served in time, no overflow, no zero-weight
      neighborhoods) retained — tail sampling, not head sampling;
    - the tail-latency attribution built from the recorder's state
      decomposes the p99-p50 gap into per-stage contributions whose sum
      lands within 15% of the gap (exact by construction when any additive
      stage shows positive excess — the row records the residual).
    """
    from repro.obs import tail_attribution

    trace = make_trace(n_requests, rate_rps, req_queries,
                       deadline_frac=0.5, deadline_ms=(0.5, 10.0), seed=seed)
    out = drive(points, trace, updates=0, req_queries=req_queries, seed=seed,
                trace_sample_rate=0.0, record_tail=True,
                recorder_opts={"top_percentile": None,
                               "ring": 4 * n_requests},
                debugz=True)
    if out["lost"] or out["duplicated"]:
        raise RuntimeError(f"retention run lost/duplicated requests: "
                           f"{out['lost']}/{out['duplicated']}")
    reqs = out["_reqs"]
    rec = out["debugz"]["recorder"]
    retained = {t["id"] for t in rec["traces"]}

    def rec_id(r):
        return getattr(r, "trace_id", None) or f"req-{r.uid}"

    missed = [r for r in reqs
              if r.status == "shed"
              or (r.deadline is not None and r.status == "done"
                  and r.t_done is not None and r.t_done > r.deadline)]
    in_slo = [r for r in reqs
              if r.status == "done" and not r.overflow
              and not getattr(r, "zero_weight", 0)
              and (r.deadline is None
                   or (r.t_done is not None and r.t_done <= r.deadline))]
    miss_kept = sum(rec_id(r) in retained for r in missed)
    slo_kept = [rec_id(r) for r in in_slo if rec_id(r) in retained]
    if missed and miss_kept < 0.95 * len(missed):
        raise RuntimeError(
            f"retention gate: only {miss_kept}/{len(missed)} missed-deadline "
            f"requests have retained span trees (need >= 95%; recorder "
            f"dropped={rec['dropped']})")
    if slo_kept:
        raise RuntimeError(
            f"retention gate: {len(slo_kept)} in-SLO requests retained "
            f"(tail sampling must retain zero): {slo_kept[:5]}")

    attr = tail_attribution([rec],
                            registry_state=out["debugz"].get("registry"))
    gap, attributed = attr["gap_s"], attr["attributed_s"]
    residual = abs(attributed - gap) / max(gap, 1e-12)
    if gap > 0 and any(s["tail_mean_s"] > 0
                       for s in attr["stages"].values()
                       if s.get("additive")) and residual > 0.15:
        raise RuntimeError(
            f"attribution identity: per-stage contributions sum to "
            f"{attributed * 1e3:.2f}ms vs p99-p50 gap {gap * 1e3:.2f}ms "
            f"({residual:.0%} residual > 15%)")
    tag = f"{points}x{req_queries}@{rate_rps:.0f}rps"
    return [
        (f"serving/recorder_retention/{tag}", 0.0,
         f"{miss_kept}/{len(missed)} missed-deadline requests retained, "
         f"0/{len(in_slo)} in-SLO retained, "
         f"attribution residual {residual:.1%} of "
         f"{gap * 1e3:.2f}ms gap"),
    ]


def cluster_rows(n_requests: int = 64, rate_rps: float = 300.0,
                 req_queries: int = 96, points: int = 16384,
                 updates: int = 2, seed: int = 0,
                 policy: str = "round_robin") -> list[tuple]:
    """1-host vs 2-host fleet at the SAME offered load: the scale-out
    trajectory rows for benchmarks/run.py (QPS + p99 per width, plus the
    2-host scale-out efficiency = qps2 / (2 * qps1))."""
    trace = make_trace(n_requests, rate_rps, req_queries,
                       deadline_frac=0.25, deadline_ms=(20.0, 200.0),
                       seed=seed)
    rows = []
    qps = {}
    for n_hosts in (1, 2):
        out = drive_cluster(points, trace, n_hosts=n_hosts, updates=updates,
                            req_queries=req_queries, seed=seed,
                            policy=policy)
        rep = out["report"]
        if out["lost"] or out["duplicated"]:
            # explicit raise, not assert: python -O must not turn a lost/
            # duplicated request into a silently wrong scale-out row
            raise RuntimeError(f"cluster load run lost/duplicated requests: "
                               f"{out['lost']}/{out['duplicated']}")
        qps[n_hosts] = rep["queries_per_s"]
        tag = f"{points}x{req_queries}@{rate_rps:.0f}rps/{n_hosts}host"
        rows.append(
            (f"cluster/load_total_p99/{tag}",
             rep["latency"]["total"]["p99_s"] * 1e6,
             f"{rep['queries_per_s']:.0f} q/s fleet, {rep['shed']} shed, "
             f"epochs {rep['epoch_min']}..{rep['epoch_max']}"))
    rows.append(
        (f"cluster/scaleout_eff/{points}x{req_queries}@{rate_rps:.0f}rps",
         0.0,
         f"2-host efficiency {qps[2] / max(2 * qps[1], 1e-9):.2f} "
         f"({qps[1]:.0f} -> {qps[2]:.0f} q/s)"))
    return rows


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--points", type=int, default=16384)
    p.add_argument("--requests", type=int, default=96)
    p.add_argument("--rate", type=float, default=400.0,
                   help="offered load, requests/s (open loop)")
    p.add_argument("--req-queries", type=int, default=96)
    p.add_argument("--max-batch", type=int, default=4096)
    p.add_argument("--deadline-frac", type=float, default=0.25,
                   help="fraction of requests carrying a deadline")
    p.add_argument("--deadline-ms", type=float, nargs=2,
                   default=(20.0, 200.0))
    p.add_argument("--updates", type=int, default=3,
                   help="incremental dataset updates woven into the stream")
    p.add_argument("--write-rate", type=float, default=0.0, metavar="WPS",
                   help="mixed read/write mode: open-loop Poisson writer "
                        "arrivals/s, each a balanced --write-batch delta "
                        "submitted non-blocking (single-server mode only)")
    p.add_argument("--write-batch", type=int, default=32)
    p.add_argument("--layout", default="replicated",
                   choices=("replicated", "ring", "grid_ring"),
                   help="session layout (grid_ring = O(Delta) ingest path; "
                        "needs --mesh)")
    p.add_argument("--pipeline", type=int, default=0, metavar="DEPTH",
                   help="worker launch-ahead pipelining depth (0 = off; "
                        "single-server mode only)")
    p.add_argument("--mesh", action="store_true",
                   help="serve across every visible device")
    p.add_argument("--cluster", type=int, default=0, metavar="N",
                   help="serve from an N-host fleet and report MERGED "
                        "fleet telemetry")
    p.add_argument("--cluster-procs", action="store_true",
                   help="back fleet hosts 1..N-1 with real subprocesses "
                        "(socket control plane)")
    p.add_argument("--policy", default="round_robin",
                   choices=("round_robin", "least_loaded"))
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--no-warmup", action="store_true",
                   help="skip the warmup batches + telemetry reset, so the "
                        "replay measures the COLD trajectory (first-bucket "
                        "compiles land inside the reported latencies)")
    p.add_argument("--prewarm", choices=("background", "sync"), default=None,
                   help="AOT-compile + warm the whole bucket ladder at "
                        "server construction (single-server mode; 'sync' "
                        "blocks, 'background' compiles off the worker "
                        "thread)")
    p.add_argument("--compilation-cache-dir", metavar="DIR", default=None,
                   help="persistent XLA compilation cache directory "
                        "(default: AIDW_CACHE_DIR env; a restart with the "
                        "same directory deserializes instead of "
                        "recompiling)")
    p.add_argument("--trace-sample-rate", type=float, default=None,
                   metavar="P",
                   help="end-to-end tracing: root sample rate (cluster "
                        "mode samples at the router; hosts record at rate "
                        "0). 0.0 = tracer on, sampler off (the overhead-"
                        "gate configuration)")
    p.add_argument("--trace-out", default=None, metavar="PATH",
                   help="write collected spans as Chrome trace_event JSON "
                        "(needs --trace-sample-rate > 0; CI uploads it as "
                        "the sample-trace artifact)")
    p.add_argument("--trace-overhead-gate", action="store_true",
                   help="run the observability overhead acceptance gate "
                        "(<2% p99 with rate-0 tracing + flight recorder ON "
                        "over a bare baseline, best of 3) plus the tail-"
                        "sampling retention gate instead of a plain load "
                        "run; raises on failure")
    p.add_argument("--debugz-out", default=None, metavar="PATH",
                   help="write the diagnostics bundle (queue/epoch state, "
                        "SLO events, flight-recorder traces, tail-latency "
                        "attribution; fleet-merged in --cluster mode) as "
                        "JSON to PATH after the run")
    p.add_argument("--json", action="store_true",
                   help="emit the full JSON latency report (CI artifact)")
    args = p.parse_args()

    # before any compile: flag > AIDW_CACHE_DIR env > disabled
    from repro.runtime import compile_cache
    compile_cache.enable(args.compilation_cache_dir)

    if args.trace_overhead_gate:
        rows = trace_overhead_rows(n_requests=args.requests,
                                   req_queries=args.req_queries,
                                   points=args.points, seed=args.seed)
        rows += recorder_retention_rows(req_queries=args.req_queries,
                                        points=args.points, seed=args.seed)
        print("name,us_per_call,derived")
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        return

    mesh = None
    if args.mesh and not (args.cluster and args.cluster_procs):
        # in-process fleets can share this process's mesh; subprocess
        # hosts build their own from their own visible devices
        import jax

        from repro.core.jax_compat import make_auto_mesh

        mesh = make_auto_mesh((len(jax.devices()),), ("q",))

    trace = make_trace(args.requests, args.rate, args.req_queries,
                       args.deadline_frac, tuple(args.deadline_ms),
                       seed=args.seed)
    if args.cluster:
        out = drive_cluster(args.points, trace, n_hosts=args.cluster,
                            procs=args.cluster_procs,
                            max_batch=args.max_batch, updates=args.updates,
                            req_queries=args.req_queries, seed=args.seed,
                            policy=args.policy, mesh=mesh,
                            trace_sample_rate=args.trace_sample_rate,
                            debugz=bool(args.debugz_out),
                            warmup=not args.no_warmup)
    else:
        out = drive(args.points, trace, max_batch=args.max_batch, mesh=mesh,
                    updates=args.updates, req_queries=args.req_queries,
                    seed=args.seed, pipeline_depth=args.pipeline,
                    layout=args.layout, write_rate_rps=args.write_rate,
                    write_batch=args.write_batch,
                    trace_sample_rate=args.trace_sample_rate,
                    debugz=bool(args.debugz_out),
                    warmup=not args.no_warmup, prewarm=args.prewarm)

    out.pop("_reqs", None)               # request objects are not JSON
    spans = out.pop("spans", [])
    if args.debugz_out:
        with open(args.debugz_out, "w") as f:
            json.dump(out.pop("debugz"), f, indent=1)
        print(f"# wrote debugz bundle to {args.debugz_out}",
              file=sys.stderr)
    if args.trace_out:
        from repro.obs import chrome_trace

        with open(args.trace_out, "w") as f:
            json.dump(chrome_trace(spans), f)
        out["trace_events"] = len(spans)
        print(f"# wrote {len(spans)} spans to {args.trace_out}",
              file=sys.stderr)

    if out["lost"] or out["duplicated"]:
        # CLI invariant gate (CI churn step): a lost or duplicated request
        # under mixed read/write load must fail the job, json mode included
        raise SystemExit(f"load run lost/duplicated requests: "
                         f"{out['lost']}/{out['duplicated']}")
    if args.json:
        out["config"] = {k: (list(v) if isinstance(v, tuple) else v)
                         for k, v in vars(args).items()}
        print(json.dumps(out, indent=2))
        return
    rep = out["report"]
    lat = rep["latency"]
    print(f"offered {out['offered_rps']:.0f} req/s | served "
          f"{rep['queries_per_s']:.0f} q/s | completed {rep['completed']} "
          f"shed {rep['shed']} lost {out['lost']} dup {out['duplicated']}")
    for axis in ("queue", "execute", "total", "shed"):
        s = lat[axis]
        print(f"  {axis:8s} p50 {s['p50_s'] * 1e3:8.2f}ms  "
              f"p95 {s['p95_s'] * 1e3:8.2f}ms  p99 {s['p99_s'] * 1e3:8.2f}ms"
              f"  max {s['max_s'] * 1e3:8.2f}ms  (n={s['count']})")


if __name__ == "__main__":
    main()
