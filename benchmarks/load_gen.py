"""Open-loop load generator for the async AIDW serving subsystem.

Drives :class:`repro.serving.AsyncAidwServer` with OPEN-LOOP Poisson
arrivals — requests are submitted at exponentially-spaced instants from a
pre-drawn trace, regardless of completions, so queueing delay under
overload is measured instead of hidden (a closed-loop generator would
self-throttle and report flattering latencies).

The trace mixes deadline classes (``--deadline-frac`` of requests carry a
deadline drawn from ``--deadline-ms``; the rest are best-effort) and
odd-sized request bodies, which exercises the deadline-aware coalescer and
the session's power-of-two bucketing together.

Output: CSV rows via :func:`load_rows` (wired into ``benchmarks/run.py``)
or a JSON latency report with ``--json`` (the CI serving-suite job uploads
it as the latency-trajectory artifact next to the session benchmark):

    {"config": {...}, "report": {submitted, completed, shed, queries_per_s,
                                 latency: {queue, execute, total:
                                           {p50_s, p95_s, p99_s, ...}}},
     "lost": 0, "duplicated": 0}

``--mesh`` serves the load over every visible device (run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to simulate a pod
slice on CPU).  Standalone:

    PYTHONPATH=src python benchmarks/load_gen.py [--json] [--mesh]
        [--requests N] [--rate QPS] [--updates K]
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.data.pipeline import spatial_points, spatial_queries
from repro.serving import AsyncAidwServer


def make_trace(n_requests: int, rate_rps: float, req_queries: int,
               deadline_frac: float, deadline_ms: tuple, seed: int = 0):
    """Pre-draw the open-loop arrival trace.

    Returns a list of ``(t_arrival_s, n_queries, deadline_s_or_None)``:
    exponential inter-arrivals at ``rate_rps`` requests/s, odd-ish request
    sizes around ``req_queries``, and a ``deadline_frac`` mix of
    deadline-bound requests with deadlines drawn uniformly from
    ``deadline_ms`` (milliseconds, relative to arrival).
    """
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_rps, n_requests)
    arrivals = np.cumsum(gaps)
    trace = []
    for i in range(n_requests):
        n = max(1, req_queries - int(rng.integers(0, max(req_queries // 3,
                                                         2))))
        deadline = None
        if rng.random() < deadline_frac:
            deadline = float(rng.uniform(*deadline_ms)) / 1e3
        trace.append((float(arrivals[i]), n, deadline))
    return trace


def run_load(server: AsyncAidwServer, trace, *, updates: int = 0,
             points: int = 0, seed: int = 0) -> dict:
    """Replay ``trace`` against ``server`` (open loop), optionally weaving
    ``updates`` incremental dataset deltas through the admission stream at
    even intervals.  Returns the JSON report body."""
    rng = np.random.default_rng(seed + 1)
    update_every = len(trace) // (updates + 1) if updates else None
    reqs = []
    t0 = time.monotonic()
    for i, (t_arrival, n, deadline_s) in enumerate(trace):
        if update_every and i and i % update_every == 0 \
                and len(reqs) // update_every <= updates:
            d = max(points // 100, 1)
            server.update_dataset(
                inserts=spatial_points(d, seed=seed + 50 + i),
                deletes=rng.choice(max(points - d, 1), d, replace=False))
        now = time.monotonic() - t0
        if t_arrival > now:                  # open loop: wait for the slot,
            time.sleep(t_arrival - now)      # never for completions
            now = t_arrival
        # deadlines are anchored at the TRACE arrival, not at submit: when
        # submission falls behind (update barrier blocking, backpressure),
        # a delayed request must NOT gain deadline budget — that is exactly
        # the overload signal an open-loop harness exists to report
        reqs.append(server.submit(
            spatial_queries(n, seed=seed + 1000 + i),
            deadline_s=None if deadline_s is None
            else t_arrival + deadline_s - now))
    wall_submit = time.monotonic() - t0
    server.flush(timeout=600)
    wall_total = time.monotonic() - t0

    terminal = [r for r in reqs if r.status in ("done", "shed")]
    report = server.report()
    return {
        "report": report,
        "offered_rps": len(trace) / max(wall_submit, 1e-9),
        "wall_s": wall_total,
        "lost": len(reqs) - len(terminal),
        "duplicated": len(reqs) - len({r.uid for r in reqs}),
    }


def drive(points: int, trace, *, max_batch: int = 4096, mesh=None,
          updates: int = 3, req_queries: int = 96, seed: int = 0) -> dict:
    """Build a server, warm it, and replay ``trace`` (shared by the CSV rows
    and the JSON CLI so both measure the same configuration).

    Warmup primes the executables + the scheduler's execute-time model,
    then telemetry is RESET so the reported window reflects steady state,
    not first-bucket compiles.
    """
    pts = spatial_points(points, seed=seed)
    with AsyncAidwServer(pts, max_batch=max_batch, mesh=mesh,
                         query_domain=spatial_queries(1024, seed=1)) as srv:
        for _ in range(3):
            srv.submit(spatial_queries(req_queries, seed=2))
        srv.flush(timeout=600)
        srv.telemetry.reset()
        for k in srv.queue.counters:
            srv.queue.counters[k] = 0
        return run_load(srv, trace, updates=updates, points=points,
                        seed=seed)


def load_rows(n_requests: int = 96, rate_rps: float = 400.0,
              req_queries: int = 96, points: int = 16384,
              deadline_frac: float = 0.25,
              deadline_ms: tuple = (20.0, 200.0), updates: int = 3,
              seed: int = 0, mesh=None) -> list[tuple]:
    """CSV rows for benchmarks/run.py (schema name,us_per_call,derived)."""
    trace = make_trace(n_requests, rate_rps, req_queries, deadline_frac,
                       deadline_ms, seed=seed)
    out = drive(points, trace, mesh=mesh, updates=updates,
                req_queries=req_queries, seed=seed)
    rep = out["report"]
    lat = rep["latency"]
    assert out["lost"] == 0 and out["duplicated"] == 0, out
    tag = f"{points}x{req_queries}@{rate_rps:.0f}rps"
    return [
        (f"serving/load_total_p50/{tag}", lat["total"]["p50_s"] * 1e6,
         f"{rep['queries_per_s']:.0f} q/s served, "
         f"{out['offered_rps']:.0f} req/s offered"),
        (f"serving/load_total_p99/{tag}", lat["total"]["p99_s"] * 1e6,
         f"queue p99 {lat['queue']['p99_s'] * 1e3:.1f}ms, "
         f"execute p99 {lat['execute']['p99_s'] * 1e3:.1f}ms"),
        (f"serving/load_shed/{tag}", 0.0,
         f"{rep['shed']} shed / {rep['completed']} completed "
         f"({updates} delta updates interleaved)"),
    ]


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--points", type=int, default=16384)
    p.add_argument("--requests", type=int, default=96)
    p.add_argument("--rate", type=float, default=400.0,
                   help="offered load, requests/s (open loop)")
    p.add_argument("--req-queries", type=int, default=96)
    p.add_argument("--max-batch", type=int, default=4096)
    p.add_argument("--deadline-frac", type=float, default=0.25,
                   help="fraction of requests carrying a deadline")
    p.add_argument("--deadline-ms", type=float, nargs=2,
                   default=(20.0, 200.0))
    p.add_argument("--updates", type=int, default=3,
                   help="incremental dataset updates woven into the stream")
    p.add_argument("--mesh", action="store_true",
                   help="serve across every visible device")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--json", action="store_true",
                   help="emit the full JSON latency report (CI artifact)")
    args = p.parse_args()

    mesh = None
    if args.mesh:
        import jax

        from repro.core.jax_compat import make_auto_mesh

        mesh = make_auto_mesh((len(jax.devices()),), ("q",))

    trace = make_trace(args.requests, args.rate, args.req_queries,
                       args.deadline_frac, tuple(args.deadline_ms),
                       seed=args.seed)
    out = drive(args.points, trace, max_batch=args.max_batch, mesh=mesh,
                updates=args.updates, req_queries=args.req_queries,
                seed=args.seed)

    if args.json:
        out["config"] = {k: (list(v) if isinstance(v, tuple) else v)
                         for k, v in vars(args).items()}
        print(json.dumps(out, indent=2))
        return
    rep = out["report"]
    lat = rep["latency"]
    print(f"offered {out['offered_rps']:.0f} req/s | served "
          f"{rep['queries_per_s']:.0f} q/s | completed {rep['completed']} "
          f"shed {rep['shed']} lost {out['lost']} dup {out['duplicated']}")
    for axis in ("queue", "execute", "total", "shed"):
        s = lat[axis]
        print(f"  {axis:8s} p50 {s['p50_s'] * 1e3:8.2f}ms  "
              f"p95 {s['p95_s'] * 1e3:8.2f}ms  p99 {s['p99_s'] * 1e3:8.2f}ms"
              f"  max {s['max_s'] * 1e3:8.2f}ms  (n={s['count']})")


if __name__ == "__main__":
    main()
