"""Benchmark harness — one function per paper table/figure + roofline readers.

``PYTHONPATH=src python -m benchmarks.run [--full] [--skip-paper]
[--skip-roofline] [--skip-session] [--skip-ring] [--skip-ingest]
[--skip-load] [--skip-churn] [--skip-cluster] [--skip-stages]
[--json [PATH]]``

Prints ``name,us_per_call,derived`` CSV rows.  The ``session/*`` rows compare
cold one-shot ``aidw_improved`` against warm ``InterpolationSession.query``
throughput (Stage-1 rebuild excluded), verify the fused Stage-2 path, report
warm SHARDED-session throughput on a mesh over every visible device
(bit-identity checked), and time incremental ``update(deltas=...)`` against
the full re-plan it replaces.  The ``ring/*`` rows measure brute-force ring
Stage 1 against the grid-aware ring (slab CSR + halo) at >= 100k points —
the paper's grid-vs-brute headline re-measured for the sharded layouts,
with the measured per-query candidate count checked against the analytic
census.  The ``serving/*`` rows put the ASYNC serving subsystem under
open-loop Poisson load (deadline mix + interleaved delta updates) and
report end-to-end p50/p99 latency and shed counts — the whole speedup
story, traffic included, in one command.  The ``cluster/*`` rows replay the
same offered load against 1-host and 2-host serving fleets
(``repro.serving.cluster``) so the trajectory starts capturing scale-out
efficiency alongside single-host latency.  The ``ingest/*`` rows measure
the O(Δ) per-slab donation-aliased delta staging against the full-packet
re-stage (>= 10x fewer staged bytes required at 1% churn), and the
``serving/churn_*`` rows put a grid_ring server under a sustained mixed
read/write open-loop load (mixed p99 must stay within 1.5x of read-only at
the same offered load).  The ``stage/*`` rows (benchmarks/stage_bench.py)
read per-stage walls — stage1/stage2/staging/compact/queue_wait/coalesce —
out of the SAME ``repro.obs.Registry`` histograms the production paths
populate, each with a raising gate (fence honesty, span nesting, count
exactness, the queue+execute==total identity, span/metric agreement) plus a
profiled-sum vs end-to-end reconciliation band.

``--json`` additionally writes the rows (plus environment metadata) to a
repo-root perf-trajectory artifact.  The artifact name is derived per PR —
``BENCH_<tag>.json`` where ``<tag>`` comes from ``--artifact-tag`` or the
``BENCH_ARTIFACT_TAG`` env var (so CI never re-overwrites an earlier PR's
trajectory file the way a hardcoded name would) — and the CI mesh-suite job
regenerates and uploads it per PR.  An explicit ``--json PATH`` still wins.
"""

from __future__ import annotations

import argparse
import os
import sys

DEFAULT_TAG = os.environ.get("BENCH_ARTIFACT_TAG", "PR8")


def default_artifact(tag: str = DEFAULT_TAG) -> str:
    return f"BENCH_{tag}.json"


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true",
                   help="add the 64K size; serving shape becomes 1Mx64K")
    p.add_argument("--skip-paper", action="store_true")
    p.add_argument("--skip-roofline", action="store_true")
    p.add_argument("--skip-session", action="store_true")
    p.add_argument("--skip-ring", action="store_true",
                   help="skip the brute-vs-grid-aware ring Stage-1 rows")
    p.add_argument("--skip-load", action="store_true",
                   help="skip the async-serving load-generator rows")
    p.add_argument("--skip-cluster", action="store_true",
                   help="skip the 1-host-vs-2-host fleet scale-out rows")
    p.add_argument("--skip-ingest", action="store_true",
                   help="skip the O(Delta) delta-staging ingest rows")
    p.add_argument("--skip-churn", action="store_true",
                   help="skip the sustained-churn mixed read/write rows")
    p.add_argument("--skip-stages", action="store_true",
                   help="skip the per-stage observability rows + gates")
    p.add_argument("--artifact-tag", default=DEFAULT_TAG, metavar="TAG",
                   help="perf-trajectory artifact tag: --json with no PATH "
                        "writes BENCH_<TAG>.json (env BENCH_ARTIFACT_TAG "
                        f"overrides the default, currently {DEFAULT_TAG})")
    p.add_argument("--json", nargs="?", const="", default=None,
                   metavar="PATH",
                   help="also write the rows as a JSON perf-trajectory "
                        "artifact at the repo root (default "
                        "BENCH_<artifact-tag>.json)")
    args = p.parse_args()

    rows: list[tuple] = []

    if not args.skip_paper:
        from . import paper_tables as T

        sizes = T.FULL_SIZES if args.full else T.SIZES
        rows += T.table1_exec_time(sizes)
        rows += T.table2_stage_split(sizes)
        rows += T.table3_knn_compare(sizes)
        rows += T.accuracy_check()

    if not args.skip_session:
        from . import session_bench as S

        sizes = S.FULL_SIZES if args.full else S.SIZES
        rows += S.session_rows(sizes)
        rows += S.fused_rows()
        rows += S.sharded_rows(sizes)   # mesh over every visible device
        rows += S.delta_rows()          # incremental vs full dataset refresh

    if not args.skip_ring:
        from . import session_bench as S

        rows += S.ring_rows()           # brute vs grid-aware ring Stage 1

    if not args.skip_ingest:
        from . import session_bench as S

        rows += S.ingest_rows()         # O(Delta) per-slab delta staging

    if not args.skip_load:
        from . import load_gen as L

        rows += L.load_rows()           # async server under Poisson load
        rows += L.trace_overhead_rows()  # rate-0 tracing <2% p99 gate

    if not args.skip_churn:
        from . import load_gen as L

        rows += L.mixed_rows()          # sustained-churn mixed read/write

    if not args.skip_cluster:
        from . import load_gen as L

        rows += L.cluster_rows()        # 1-host vs 2-host fleet scale-out

    if not args.skip_stages:
        from . import stage_bench as ST

        rows += ST.stage_rows()         # per-stage walls from the registry

    if not args.skip_roofline:
        from . import roofline as R

        rows += R.rows_csv(R.full_table())

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    if args.json is not None:
        import json
        import platform
        from pathlib import Path

        import jax

        out = Path(args.json or default_artifact(args.artifact_tag))
        if not out.is_absolute():
            out = Path(__file__).resolve().parents[1] / out
        out.write_text(json.dumps({
            "env": {"devices": len(jax.devices()),
                    "backend": jax.default_backend(),
                    "jax": jax.__version__,
                    "python": platform.python_version(),
                    "argv": sys.argv[1:]},
            "rows": [{"name": n, "us_per_call": us, "derived": d}
                     for n, us, d in rows],
        }, indent=1) + "\n")
        print(f"# wrote {out}", file=sys.stderr)


if __name__ == "__main__":
    main()
