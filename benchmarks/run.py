"""Benchmark harness — one function per paper table/figure + roofline readers.

``PYTHONPATH=src python -m benchmarks.run [--full] [--skip-paper]
[--skip-roofline] [--skip-session] [--skip-ring] [--skip-ingest]
[--skip-load] [--skip-churn] [--skip-cluster] [--skip-stages]
[--skip-coldstart] [--json [PATH]]``

Prints ``name,us_per_call,derived`` CSV rows.  The ``session/*`` rows compare
cold one-shot ``aidw_improved`` against warm ``InterpolationSession.query``
throughput (Stage-1 rebuild excluded), verify the fused Stage-2 path, report
warm SHARDED-session throughput on a mesh over every visible device
(bit-identity checked), and time incremental ``update(deltas=...)`` against
the full re-plan it replaces.  The ``ring/*`` rows measure brute-force ring
Stage 1 against the grid-aware ring (slab CSR + halo) at >= 100k points —
the paper's grid-vs-brute headline re-measured for the sharded layouts,
with the measured per-query candidate count checked against the analytic
census.  The ``serving/*`` rows put the ASYNC serving subsystem under
open-loop Poisson load (deadline mix + interleaved delta updates) and
report end-to-end p50/p99 latency and shed counts — the whole speedup
story, traffic included, in one command.  The ``cluster/*`` rows replay the
same offered load against 1-host and 2-host serving fleets
(``repro.serving.cluster``) so the trajectory starts capturing scale-out
efficiency alongside single-host latency.  The ``ingest/*`` rows measure
the O(Δ) per-slab donation-aliased delta staging against the full-packet
re-stage (>= 10x fewer staged bytes required at 1% churn), and the
``serving/churn_*`` rows put a grid_ring server under a sustained mixed
read/write open-loop load (mixed p99 must stay within 1.5x of read-only at
the same offered load).  The ``stage/*`` rows (benchmarks/stage_bench.py)
read per-stage walls — stage1/stage2/staging/compact/queue_wait/coalesce —
out of the SAME ``repro.obs.Registry`` histograms the production paths
populate, each with a raising gate (fence honesty, span nesting, count
exactness, the queue+execute==total identity, span/metric agreement) plus a
profiled-sum vs end-to-end reconciliation band.  The ``coldstart/*`` rows
(benchmarks/coldstart_bench.py) measure first-query latency cold (fresh
subprocess, no cache), after a persistent-compilation-cache restart
(RAISING gate: >= 2x faster than cold), warm, and AOT-prewarmed — with the
zero-compile gate (no backend compile serving any ladder bucket after
``precompile(warm=True)``) and the prewarm-off-hot-path p99 gate (serving
p99 during background prewarm <= 1.1x steady state).  Rows stamped
``includes_compile`` (first-observation walls: staging, compact, the cold/
restart rows) are excluded from the regression gate — a compile-
contaminated wall regressing says nothing about the production path.

``--json`` additionally writes the rows (plus environment metadata) to a
repo-root perf-trajectory artifact.  The artifact name is derived per PR —
``BENCH_<tag>.json`` where ``<tag>`` comes from ``--artifact-tag`` or the
``BENCH_ARTIFACT_TAG`` env var (so CI never re-overwrites an earlier PR's
trajectory file the way a hardcoded name would) — and the CI mesh-suite job
regenerates and uploads it per PR.  An explicit ``--json PATH`` still wins.
After writing, the PERF-TRAJECTORY REGRESSION GATE compares this run's
``stage/*`` rows against the most recent prior ``BENCH_*.json`` carrying
each row and fails the run when any per-stage wall regressed by more than
``REGRESSION_LIMIT`` (25%); rows with no prior measurement are
grandfathered in, so adding a stage never blocks the PR that adds it.
"""

from __future__ import annotations

import argparse
import os
import sys

DEFAULT_TAG = os.environ.get("BENCH_ARTIFACT_TAG", "PR10")

# perf-trajectory regression guard: a stage/* row that got > this much
# slower than the most recent prior BENCH_*.json carrying the same row
# fails the run (absent-before rows are grandfathered — new stages enter
# the trajectory without blocking the PR that adds them)
REGRESSION_LIMIT = 1.25

# ...but a RATIO is meaningless below the scheduler-noise band: microsecond
# walls (coalesce ~60-100us) bounce 1.5x run to run on a busy CI core, so a
# row participates in the ratio gate only once at least one of its two
# measurements escapes this floor.  Both below => skipped (invisible inside
# the band); either above => gated (a genuine 67us -> 10ms blowup still
# fails; a 97us-vs-67us bounce no longer does).
NOISE_FLOOR_US = 250.0


def default_artifact(tag: str = DEFAULT_TAG) -> str:
    return f"BENCH_{tag}.json"


def _prior_artifacts(root, current) -> list:
    """Prior BENCH_*.json artifacts at the repo root, NEWEST first (PR tag
    order: BENCH_PR8 before BENCH_PR5), excluding the one being written."""
    import re

    def key(p):
        m = re.search(r"BENCH_PR(\d+)", p.name)
        return int(m.group(1)) if m else -1

    return sorted((p for p in root.glob("BENCH_*.json")
                   if p.resolve() != current.resolve()),
                  key=key, reverse=True)


def check_regressions(rows, out_path, limit: float = REGRESSION_LIMIT,
                      prefix: str = "stage/") -> list[str]:
    """Compare this run's ``prefix`` rows against the most recent prior
    artifact that carries each row; return the list of violation strings
    (callers raise).  Rows with no prior measurement, with a prior/
    current value of ~0 (gate rows report 0.0 us), or with both walls
    inside the :data:`NOISE_FLOOR_US` band, are skipped."""
    import json

    priors: dict[str, tuple[float, str]] = {}
    for p in _prior_artifacts(out_path.parent, out_path):
        try:
            data = json.loads(p.read_text())
        except (OSError, ValueError):
            continue
        for r in data.get("rows", []):
            n = r.get("name", "")
            if n.startswith(prefix) and n not in priors:
                priors[n] = (float(r.get("us_per_call", 0.0)), p.name)
    bad = []
    for row in rows:
        name, us = row[0], row[1]
        if len(row) > 3 and row[3]:
            # includes_compile rows are excluded: a compile-contaminated
            # wall regressing says nothing about the production path (and
            # a persistent-cache hit would "improve" it 10x for free)
            continue
        if not name.startswith(prefix) or name not in priors:
            continue                     # grandfather rows absent before
        prior_us, src = priors[name]
        if prior_us <= 1e-9 or us <= 1e-9:
            continue
        if prior_us < NOISE_FLOOR_US and us < NOISE_FLOOR_US:
            continue                     # both inside the noise band
        if us > limit * prior_us:
            bad.append(f"{name}: {us:.1f}us vs {prior_us:.1f}us in {src} "
                       f"({us / prior_us:.2f}x > {limit}x)")
    return bad


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true",
                   help="add the 64K size; serving shape becomes 1Mx64K")
    p.add_argument("--skip-paper", action="store_true")
    p.add_argument("--skip-roofline", action="store_true")
    p.add_argument("--skip-session", action="store_true")
    p.add_argument("--skip-ring", action="store_true",
                   help="skip the brute-vs-grid-aware ring Stage-1 rows")
    p.add_argument("--skip-load", action="store_true",
                   help="skip the async-serving load-generator rows")
    p.add_argument("--skip-cluster", action="store_true",
                   help="skip the 1-host-vs-2-host fleet scale-out rows")
    p.add_argument("--skip-ingest", action="store_true",
                   help="skip the O(Delta) delta-staging ingest rows")
    p.add_argument("--skip-churn", action="store_true",
                   help="skip the sustained-churn mixed read/write rows")
    p.add_argument("--skip-stages", action="store_true",
                   help="skip the per-stage observability rows + gates")
    p.add_argument("--skip-coldstart", action="store_true",
                   help="skip the cold-start rows + gates (restart-speedup "
                        "floor, postwarm zero-compile, prewarm-offpath p99)")
    p.add_argument("--artifact-tag", default=DEFAULT_TAG, metavar="TAG",
                   help="perf-trajectory artifact tag: --json with no PATH "
                        "writes BENCH_<TAG>.json (env BENCH_ARTIFACT_TAG "
                        f"overrides the default, currently {DEFAULT_TAG})")
    p.add_argument("--json", nargs="?", const="", default=None,
                   metavar="PATH",
                   help="also write the rows as a JSON perf-trajectory "
                        "artifact at the repo root (default "
                        "BENCH_<artifact-tag>.json)")
    args = p.parse_args()

    rows: list[tuple] = []

    if not args.skip_paper:
        from . import paper_tables as T

        sizes = T.FULL_SIZES if args.full else T.SIZES
        rows += T.table1_exec_time(sizes)
        rows += T.table2_stage_split(sizes)
        rows += T.table3_knn_compare(sizes)
        rows += T.accuracy_check()

    if not args.skip_session:
        from . import session_bench as S

        sizes = S.FULL_SIZES if args.full else S.SIZES
        rows += S.session_rows(sizes)
        rows += S.fused_rows()
        rows += S.sharded_rows(sizes)   # mesh over every visible device
        rows += S.delta_rows()          # incremental vs full dataset refresh

    if not args.skip_ring:
        from . import session_bench as S

        rows += S.ring_rows()           # brute vs grid-aware ring Stage 1

    if not args.skip_ingest:
        from . import session_bench as S

        rows += S.ingest_rows()         # O(Delta) per-slab delta staging

    if not args.skip_load:
        from . import load_gen as L

        rows += L.load_rows()           # async server under Poisson load
        rows += L.trace_overhead_rows()  # rate-0 tracing <2% p99 gate

    if not args.skip_churn:
        from . import load_gen as L

        rows += L.mixed_rows()          # sustained-churn mixed read/write

    if not args.skip_cluster:
        from . import load_gen as L

        rows += L.cluster_rows()        # 1-host vs 2-host fleet scale-out

    if not args.skip_stages:
        from . import stage_bench as ST

        rows += ST.stage_rows()         # per-stage walls from the registry

    if not args.skip_coldstart:
        from . import coldstart_bench as C

        rows += C.coldstart_rows()      # cold/restart/AOT-prewarmed + gates

    if not args.skip_roofline:
        from . import roofline as R

        rows += R.rows_csv(R.full_table())

    print("name,us_per_call,derived")
    for row in rows:
        print(f"{row[0]},{row[1]:.1f},{row[2]}")

    if args.json is not None:
        import json
        import platform
        from pathlib import Path

        import jax

        out = Path(args.json or default_artifact(args.artifact_tag))
        if not out.is_absolute():
            out = Path(__file__).resolve().parents[1] / out
        out.write_text(json.dumps({
            "env": {"devices": len(jax.devices()),
                    "backend": jax.default_backend(),
                    "jax": jax.__version__,
                    "python": platform.python_version(),
                    "argv": sys.argv[1:]},
            "rows": [{"name": r[0], "us_per_call": r[1], "derived": r[2],
                      "includes_compile": bool(r[3]) if len(r) > 3
                      else False}
                     for r in rows],
        }, indent=1) + "\n")
        print(f"# wrote {out}", file=sys.stderr)

        bad = check_regressions(rows, out)
        if bad:
            raise SystemExit(
                "perf-trajectory regression gate "
                f"(> {REGRESSION_LIMIT}x vs prior artifact):\n  "
                + "\n  ".join(bad))


if __name__ == "__main__":
    main()
