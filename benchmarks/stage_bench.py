"""Stage-attributed benchmark: per-stage walls from the obs registry.

Every row here is read out of the SAME :class:`repro.obs.Registry`
histograms the production paths populate — not from bench-local stopwatch
code — so the bench doubles as an end-to-end check that the instrumentation
itself is honest.  Two workloads drive the stack:

* a **session** workload on a mesh over every visible device: a
  ``replicated`` session's profiled queries split the warm wall into
  ``session/stage1_s`` / ``session/stage2_s`` (separately-jitted, fenced
  halves — ``profile=True`` needs the binned plan that layout carries),
  its construction and 1%-churn incremental updates populate
  ``session/plan_s`` / ``session/bin_s`` / ``session/staging_s``, and a
  ``grid_ring`` session's explicit compactions populate
  ``session/compact_s`` (the LSM fold only exists on that layout);
* a **serving** workload (``AsyncAidwServer`` with tracing at sample rate
  1.0): a burst of odd-sized requests populates ``serving/queue_wait_s`` /
  ``serving/coalesce_s`` / ``serving/execute_s`` / ``serving/total_s`` /
  ``serving/scatter_s``, and the tracer's spans give a second,
  independently-recorded view of the same intervals.

Rows (CSV schema ``name,us_per_call,derived`` plus an
``includes_compile`` stamp — ``staging``/``compact`` hold first-and-only
observations so XLA compile time is inside them, and benchmarks/run.py
excludes stamped rows from the regression gate): ``stage/stage1``,
``stage/stage2``, ``stage/staging``, ``stage/compact``,
``stage/queue_wait``, ``stage/coalesce`` — each with at least one RAISING
acceptance gate:

* **stage1/stage2 — fence honesty + e2e reconciliation.**  Each profiled
  stage must carry >= 2% of the profiled query wall (an unfenced stage
  would report only its ~µs dispatch cost), and the profiled sum
  (stage1 + stage2) must reconcile with the separately measured UNPROFILED
  warm query wall within ``E2E_TOL`` = 3x either way.  The tolerance is
  deliberately wide — the profiled path pays an extra dispatch + fence
  between the halves and CPU CI boxes are noisy — but it still catches
  gross misattribution (a missing fence puts ~100% of the wall on one
  stage and ~0% on the other, which the 2%-floor gate trips first).
* **staging — span nesting.**  ``bin + staging <= plan`` per the span
  taxonomy (both are sub-spans of the plan/update wall), checked on the
  construction update where all three histograms hold exactly one
  observation of the SAME update; a sub-wall exceeding its parent means
  the clock domains diverged.  The row itself reports the delta-path
  staging mean (the wall serving updates actually pay).
* **compact — count exactness.**  ``session/compact_s`` must hold exactly
  as many observations as ``compact()`` calls issued.
* **queue_wait — telemetry identity.**  ``mean(queue) + mean(execute)``
  must equal ``mean(total)`` within 1% (the three are stamped from the
  same request timestamps; drift means a recording path diverged).
* **coalesce — span/metric agreement.**  Every completed traced request
  must have produced exactly one ``coalesce`` span, and the mean of the
  ``execute`` SPANS must agree with the ``serving/execute_s`` histogram
  mean within 10% (spans and metrics are two views of one measurement).

Standalone: ``PYTHONPATH=src python benchmarks/stage_bench.py [--json]``
(CI runs it via ``benchmarks/run.py --json`` so the rows land in
``BENCH_<tag>.json``).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import AidwConfig, InterpolationSession
from repro.data.pipeline import spatial_points, spatial_queries
from repro.serving import AsyncAidwServer

# (m data points, query batch, profiled repetitions)
SIZES = (16384, 1024, 5)
E2E_TOL = 3.0          # profiled-sum vs unprofiled-wall reconciliation band
STAGE_FLOOR = 0.02     # min fraction of the profiled wall per fenced stage
SPAN_METRIC_TOL = 0.10  # tracer-span mean vs registry-histogram mean


def _hist(reg_snapshot: dict, name: str) -> dict:
    h = reg_snapshot["histograms"].get(name)
    if h is None or not h["count"]:
        raise RuntimeError(f"stage bench: no observations under {name!r} — "
                           f"the instrumentation path did not run")
    return h


def session_stage_rows(sizes=SIZES) -> list[tuple]:
    """``stage/stage1`` / ``stage/stage2`` / ``stage/staging`` /
    ``stage/compact`` rows + their gates (see module docstring)."""
    import jax

    from repro.core.jax_compat import make_auto_mesh

    m, base, reps = sizes
    pts = spatial_points(m, seed=0)
    mesh = make_auto_mesh((len(jax.devices()),), ("q",))
    sess = InterpolationSession(pts, AidwConfig(), mesh=mesh,
                                layout="replicated",
                                query_domain=spatial_queries(base, seed=1))
    # gate: sub-spans nest inside their parent wall — checked on the
    # construction update, where plan/bin/staging are exactly one
    # observation each of the SAME update
    ctor = sess.registry.snapshot()
    plan = _hist(ctor, "session/plan_s")
    binh = _hist(ctor, "session/bin_s")
    stg0 = _hist(ctor, "session/staging_s")
    if binh["mean_s"] + stg0["mean_s"] > plan["mean_s"] * 1.01:
        raise RuntimeError(
            f"stage bench gate: bin {binh['mean_s'] * 1e6:.1f}us + staging "
            f"{stg0['mean_s'] * 1e6:.1f}us exceeds their parent plan wall "
            f"{plan['mean_s'] * 1e6:.1f}us — clock domains diverged?")

    qs = spatial_queries(base, seed=2)
    sess.query(qs).values.block_until_ready()        # compile both paths
    sess.query(qs, profile=True)
    for name in ("session/query_s", "session/stage1_s", "session/stage2_s",
                 "session/staging_s"):
        sess.registry.reset_histogram(name)

    # unprofiled end-to-end warm wall (the reconciliation target)
    e2e = []
    for _ in range(reps):
        t0 = time.perf_counter()
        sess.query(qs).values.block_until_ready()
        e2e.append(time.perf_counter() - t0)
    e2e_s = float(np.mean(e2e))

    for _ in range(reps):
        sess.query(qs, profile=True)

    # incremental churn -> the delta-path staging wall (CSR patch + mesh
    # re-place, fenced)
    d = max(m // 100, 1)
    rng = np.random.default_rng(3)
    sess.update(inserts=spatial_points(d, seed=4),
                deletes=rng.choice(m, d, replace=False))

    # compaction only exists on the grid_ring LSM layout
    ring = InterpolationSession(pts, AidwConfig(), mesh=mesh,
                                layout="grid_ring",
                                query_domain=spatial_queries(base, seed=1))
    ring.update(inserts=spatial_points(d, seed=5),
                deletes=rng.choice(m, d, replace=False))
    n_compacts = 2
    for _ in range(n_compacts):
        ring.compact()

    snap = sess.registry.snapshot()
    s1 = _hist(snap, "session/stage1_s")
    s2 = _hist(snap, "session/stage2_s")
    prof = s1["mean_s"] + s2["mean_s"]

    # gate: fence honesty — each separately-jitted half carries real work
    for name, h in (("stage1", s1), ("stage2", s2)):
        if h["mean_s"] < STAGE_FLOOR * prof:
            raise RuntimeError(
                f"stage bench gate: {name} mean {h['mean_s'] * 1e6:.1f}us is "
                f"< {STAGE_FLOOR:.0%} of the profiled query wall "
                f"{prof * 1e6:.1f}us — stage output not fenced?")
    # gate: profiled split reconciles with the unprofiled end-to-end wall
    ratio = prof / max(e2e_s, 1e-12)
    if not (1.0 / E2E_TOL <= ratio <= E2E_TOL):
        raise RuntimeError(
            f"stage bench gate: profiled stage1+stage2 "
            f"{prof * 1e6:.1f}us vs unprofiled query {e2e_s * 1e6:.1f}us "
            f"({ratio:.2f}x) outside the {E2E_TOL}x reconciliation band")

    stg = _hist(snap, "session/staging_s")
    cmp_h = _hist(ring.registry.snapshot(), "session/compact_s")
    # gate: every compact() call produced exactly one observation
    if cmp_h["count"] != n_compacts:
        raise RuntimeError(
            f"stage bench gate: {n_compacts} compact() calls but "
            f"{cmp_h['count']} session/compact_s observations")

    # 4th element: includes_compile — stage1/stage2 walls are measured on
    # warmed executables; the staging and compact walls each hold their
    # FIRST (and only) observations, so XLA trace+compile time is inside
    # them.  run.py excludes stamped rows from the regression gate: a
    # compile-contaminated wall regressing 1.25x says nothing about the
    # production path (and a persistent-cache hit would "improve" it 10x).
    tag = f"{m}x{base}"
    return [
        (f"stage/stage1/{tag}", s1["mean_s"] * 1e6,
         f"{s1['mean_s'] / prof:.0%} of profiled query "
         f"({prof * 1e6:.0f}us; e2e {e2e_s * 1e6:.0f}us, "
         f"{ratio:.2f}x within {E2E_TOL}x band)", False),
        (f"stage/stage2/{tag}", s2["mean_s"] * 1e6,
         f"{s2['mean_s'] / prof:.0%} of profiled query, n={s2['count']}",
         False),
        (f"stage/staging/{tag}", stg["mean_s"] * 1e6,
         f"delta-path staging, n={stg['count']}; construction nesting "
         f"bin {binh['mean_s'] * 1e6:.0f}us + staging "
         f"{stg0['mean_s'] * 1e6:.0f}us <= plan {plan['mean_s'] * 1e6:.0f}us",
         True),
        (f"stage/compact/{tag}", cmp_h["mean_s"] * 1e6,
         f"{cmp_h['count']} grid_ring compactions observed "
         f"(count gate exact)", True),
    ]


def serving_stage_rows(points: int = 16384, req_queries: int = 96,
                       n_requests: int = 24) -> list[tuple]:
    """``stage/queue_wait`` / ``stage/coalesce`` rows + the telemetry
    identity and span/metric-agreement gates (see module docstring)."""
    pts = spatial_points(points, seed=0)
    with AsyncAidwServer(pts, max_batch=4096, trace_sample_rate=1.0,
                         query_domain=spatial_queries(1024, seed=1)) as srv:
        srv.submit(spatial_queries(req_queries, seed=2))
        srv.flush(timeout=600)
        srv.telemetry.reset()
        srv.spans()                       # drop warmup spans
        reqs = [srv.submit(spatial_queries(req_queries - (i % 7), seed=3 + i),
                           block=False)
                for i in range(n_requests)]
        srv.flush(timeout=600)
        snap = srv.metrics_snapshot()
        spans = srv.spans()
        done = sum(r.status == "done" for r in reqs)

    qw = _hist(snap, "serving/queue_wait_s")
    ex = _hist(snap, "serving/execute_s")
    tot = _hist(snap, "serving/total_s")
    co = _hist(snap, "serving/coalesce_s")
    # gate: the telemetry identity queue + execute == total (same stamps)
    drift = abs(qw["mean_s"] + ex["mean_s"] - tot["mean_s"])
    if drift > 0.01 * max(tot["mean_s"], 1e-12):
        raise RuntimeError(
            f"stage bench gate: mean(queue_wait)+mean(execute) drifts "
            f"{drift * 1e6:.1f}us from mean(total) "
            f"{tot['mean_s'] * 1e6:.1f}us (> 1%)")
    # gate: one coalesce span per completed traced request, none lost
    co_spans = [s for s in spans if s["name"] == "coalesce"]
    if len(co_spans) != done:
        raise RuntimeError(
            f"stage bench gate: {done} completed traced requests but "
            f"{len(co_spans)} coalesce spans")
    # gate: spans and histograms are two views of ONE measurement
    ex_spans = [s["dur"] for s in spans if s["name"] == "execute"]
    span_mean = float(np.mean(ex_spans)) if ex_spans else 0.0
    if abs(span_mean - ex["mean_s"]) > SPAN_METRIC_TOL * ex["mean_s"]:
        raise RuntimeError(
            f"stage bench gate: execute span mean {span_mean * 1e6:.1f}us vs "
            f"serving/execute_s mean {ex['mean_s'] * 1e6:.1f}us differ by "
            f"> {SPAN_METRIC_TOL:.0%}")

    tag = f"{points}x{req_queries}"
    return [
        (f"stage/queue_wait/{tag}", qw["mean_s"] * 1e6,
         f"queue+execute-total drift {drift * 1e6:.2f}us (<1% gate), "
         f"n={qw['count']}", False),
        (f"stage/coalesce/{tag}", co["mean_s"] * 1e6,
         f"{len(co_spans)} spans == {done} completed requests; execute "
         f"span/metric agree within {SPAN_METRIC_TOL:.0%}", False),
    ]


def stage_rows() -> list[tuple]:
    """All stage-attributed rows (wired into benchmarks/run.py)."""
    return session_stage_rows() + serving_stage_rows()


def main() -> None:
    import argparse
    import json

    p = argparse.ArgumentParser()
    p.add_argument("--json", action="store_true")
    args = p.parse_args()
    rows = stage_rows()
    if args.json:
        print(json.dumps([{"name": r[0], "us_per_call": r[1],
                           "derived": r[2],
                           "includes_compile": bool(r[3])
                           if len(r) > 3 else False}
                          for r in rows], indent=1))
        return
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r[0]},{r[1]:.1f},{r[2]}")


if __name__ == "__main__":
    main()
