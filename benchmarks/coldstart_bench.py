"""Cold-start benchmark: first-query latency across the warmup spectrum.

Four points on the cold-start trajectory, measured as ``coldstart/*`` rows
(wired into benchmarks/run.py):

* **cold** — a FRESH subprocess with no compilation cache builds a session
  and serves its first query; the wall includes construction, tracing, and
  every XLA compile on the path.  Subprocess, not in-process: jax's
  in-memory jit cache would hide the cost from any second measurement in
  the same interpreter.
* **restart** — the same subprocess workload with a PERSISTENT compilation
  cache directory a prior process already populated: compiles deserialize
  instead of running.  RAISING GATE: the restart first-query wall must be
  <= ``1/RESTART_SPEEDUP_FLOOR`` of cold (i.e. the cache must buy >= 2x),
  and the child must report actual persistent-cache hits (a silently
  disabled cache would otherwise pass on noise).
* **warm / AOT-prewarmed** — in-process: the steady-state query wall, and
  the first query after ``InterpolationSession.precompile(warm=True)``
  (the AOT bucket-ladder path a prewarmed serving host takes).  RAISING
  GATE: after ``precompile(warm=True)``, serving one exact-bucket-sized
  batch of EVERY ladder bucket triggers ZERO new backend compiles
  (``coldstart/postwarm_compiles`` == 0).
* **prewarm-offpath** — an ``AsyncAidwServer(prewarm='background')``
  serves a warmed bucket WHILE the background thread compiles the rest of
  the ladder.  RAISING GATE: p99 during prewarm must stay <=
  ``OFFPATH_P99_LIMIT`` (1.1x) of the same server's post-prewarm
  steady-state p99, best of ``attempts`` (shared CPU boxes are noisy; the
  gate exists to catch prewarm work leaking onto the worker thread, not
  scheduler jitter).

Exact-bucket measurement semantics: the zero-compile gates query at
power-of-two ladder sizes.  Odd-sized batches additionally pay tiny
one-off pad/sum helper compiles on first sight of each new size — inherent
to eager-op shape specialization, documented in ``core/pipeline.py``, and
deliberately out of scope for the gates.

Standalone: ``PYTHONPATH=src python -m benchmarks.coldstart_bench``
(``--child`` is the subprocess entry the parent spawns; not for direct
use).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time

RESTART_SPEEDUP_FLOOR = 2.0   # restart first query must be >= 2x faster
OFFPATH_P99_LIMIT = 1.1       # serving p99 during background prewarm
# distinct dataset sizes (distinct 64-multiple capacity buckets) so every
# in-process phase compiles fresh shapes instead of reusing the jit cache
_COLD_POINTS = 8192
_WARM_POINTS = 2903
_OFFPATH_POINTS = (2963, 3023, 3089)


def _run_child(points: int, queries: int,
               cache_dir: str | None) -> dict:
    """One cold-start sample in a FRESH interpreter; returns its JSON."""
    cmd = [sys.executable, "-m", "benchmarks.coldstart_bench", "--child",
           "--points", str(points), "--queries", str(queries)]
    if cache_dir:
        cmd += ["--cache-dir", cache_dir]
    env = dict(os.environ)
    env.setdefault("PYTHONPATH", "src")
    if not cache_dir:
        # a truly cold child: a job-level AIDW_CACHE_DIR (CI sets one for
        # the test suites) must not warm the measurement through enable()'s
        # env fallback
        env.pop("AIDW_CACHE_DIR", None)
    out = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         timeout=900, check=False)
    if out.returncode != 0:
        raise RuntimeError(f"coldstart child failed:\n{out.stderr[-2000:]}")
    return json.loads(out.stdout.splitlines()[-1])


def _child(args) -> None:
    """Subprocess body: enable the cache, build a session, time the first
    query (construction included — that IS the cold path a restarted host
    pays), report compile-cache stats as JSON on stdout."""
    from repro.runtime import compile_cache

    compile_cache.enable(args.cache_dir)

    import numpy as np

    from repro.core import AidwConfig, InterpolationSession
    from repro.data.pipeline import spatial_points, spatial_queries

    pts = spatial_points(args.points, seed=0)
    qs = spatial_queries(args.queries, seed=2)
    t0 = time.perf_counter()
    sess = InterpolationSession(pts, AidwConfig(),
                                query_domain=spatial_queries(1024, seed=1))
    np.asarray(sess.query(qs).values)
    first_s = time.perf_counter() - t0
    print(json.dumps({"first_query_s": first_s,
                      "backend_compiles": compile_cache.backend_compiles(),
                      "cache": compile_cache.cache_stats()}))


def subprocess_rows(points: int = _COLD_POINTS, queries: int = 256,
                    attempts: int = 2) -> list[tuple]:
    """``coldstart/cold_first_query`` + ``coldstart/restart_first_query``
    and the raising restart-speedup gate (best of ``attempts`` — each
    attempt is 3 fresh interpreters, and a loaded CI box can smear any
    single cold/restart pair)."""
    best = None
    for _ in range(attempts):
        cold = _run_child(points, queries, cache_dir=None)
        with tempfile.TemporaryDirectory(prefix="aidw-cache-") as d:
            _run_child(points, queries, cache_dir=d)   # populate the cache
            restart = _run_child(points, queries, cache_dir=d)
        hits = restart["cache"]["persistent_cache_hits"]
        if hits <= 0:
            raise RuntimeError(
                "coldstart gate: restart child reported zero persistent-"
                f"cache hits — the compilation cache is not engaged "
                f"({restart})")
        speedup = cold["first_query_s"] / max(restart["first_query_s"],
                                              1e-9)
        if best is None or speedup > best[0]:
            best = (speedup, cold, restart, hits)
        if speedup >= RESTART_SPEEDUP_FLOOR:
            break
    speedup, cold, restart, hits = best
    if speedup < RESTART_SPEEDUP_FLOOR:
        raise RuntimeError(
            f"coldstart gate: restart first query "
            f"{restart['first_query_s']:.2f}s is only {speedup:.2f}x faster "
            f"than cold {cold['first_query_s']:.2f}s "
            f"(floor {RESTART_SPEEDUP_FLOOR}x over {attempts} attempts; "
            f"{hits} cache hits)")
    tag = f"{points}x{queries}"
    return [
        (f"coldstart/cold_first_query/{tag}",
         cold["first_query_s"] * 1e6,
         f"fresh process, no cache: {cold['backend_compiles']} backend "
         f"compiles inside the wall", True),
        (f"coldstart/restart_first_query/{tag}",
         restart["first_query_s"] * 1e6,
         f"{speedup:.2f}x faster than cold (gate >= "
         f"{RESTART_SPEEDUP_FLOOR}x), {hits} persistent-cache hits", True),
    ]


def inprocess_rows(points: int = _WARM_POINTS,
                   queries: int = 256) -> list[tuple]:
    """``coldstart/warm_query`` + ``coldstart/aot_prewarmed_first_query``
    + the raising ``coldstart/postwarm_compiles`` == 0 gate."""
    import numpy as np

    from repro.core import AidwConfig, InterpolationSession
    from repro.data.pipeline import spatial_points, spatial_queries
    from repro.runtime import compile_cache

    compile_cache.install_listeners()
    pts = spatial_points(points, seed=0)
    sess = InterpolationSession(pts, AidwConfig(),
                                query_domain=spatial_queries(1024, seed=1))
    buckets = sess.precompile(max_queries=queries, warm=True)
    # first post-prewarm query of EVERY ladder bucket: zero new compiles
    anchor = np.asarray(pts[0, :2], dtype=np.float32)
    c0 = compile_cache.backend_compiles()
    t0 = time.perf_counter()
    np.asarray(sess.query(np.tile(anchor, (buckets[-1], 1))).values)
    aot_first_s = time.perf_counter() - t0
    for b in buckets:
        np.asarray(sess.query(np.tile(anchor, (b, 1))).values)
    dc = compile_cache.backend_compiles() - c0
    if dc != 0:
        raise RuntimeError(
            f"coldstart gate: {dc} backend compiles after "
            f"precompile(warm=True) across ladder {buckets} (gate == 0)")
    qs = np.tile(anchor, (queries, 1))
    walls = []
    for _ in range(5):
        t0 = time.perf_counter()
        np.asarray(sess.query(qs).values)
        walls.append(time.perf_counter() - t0)
    warm_s = float(np.mean(walls))
    tag = f"{points}x{queries}"
    return [
        (f"coldstart/warm_query/{tag}", warm_s * 1e6,
         f"steady-state exact-bucket query, mean of {len(walls)}", False),
        (f"coldstart/aot_prewarmed_first_query/{tag}", aot_first_s * 1e6,
         f"first query after precompile(warm=True) over ladder "
         f"{buckets}", False),
        (f"coldstart/postwarm_compiles/{tag}", 0.0,
         f"{dc} backend compiles serving every ladder bucket post-prewarm "
         f"(gate == 0)", False),
    ]


def prewarm_offpath_rows(queries: int = 64,
                         attempts: int = len(_OFFPATH_POINTS)) -> list[tuple]:
    """The prewarm-off-hot-path acceptance gate: serving p99 during
    background prewarm <= ``OFFPATH_P99_LIMIT`` x steady-state p99."""
    import numpy as np

    from repro.data.pipeline import spatial_points, spatial_queries
    from repro.serving import AsyncAidwServer

    best, best_stats = float("inf"), None
    for attempt in range(attempts):
        # fresh dataset size per attempt: fresh capacity-bucket shapes, so
        # the background thread has REAL compiles to do
        points = _OFFPATH_POINTS[attempt % len(_OFFPATH_POINTS)]
        pts = spatial_points(points, seed=0)
        qs = spatial_queries(queries, seed=2)
        with AsyncAidwServer(pts, max_batch=1024, prewarm="background",
                             query_domain=spatial_queries(1024,
                                                          seed=1)) as srv:
            during, steady = [], []
            # closed loop against the worker while the prewarm thread
            # COMPILES (the seconds-long phase the gate is about; past
            # _prewarm_compiled the remaining warm batches are ordinary
            # worker-queue items and a foreground request queueing behind
            # one is FIFO head-of-line blocking, not compile leakage).
            # The first samples carry this bucket's own lazy compile and
            # are dropped below.
            while not srv._prewarm_compiled.is_set() and len(during) < 200:
                t0 = time.perf_counter()
                srv.result(srv.submit(qs), timeout=600)
                during.append(time.perf_counter() - t0)
            srv.prewarm(wait=True, timeout=600)
            for _ in range(max(len(during), 20)):
                t0 = time.perf_counter()
                srv.result(srv.submit(qs), timeout=600)
                steady.append(time.perf_counter() - t0)
        during = during[5:]             # drop the lazy-compile head
        if len(during) < 8:
            continue                    # prewarm outran the sampler
        d99 = float(np.percentile(during, 99))
        s99 = float(np.percentile(steady, 99))
        ratio = d99 / max(s99, 1e-12)
        if ratio < best:
            best, best_stats = ratio, (d99, s99, len(during), points)
        if best <= OFFPATH_P99_LIMIT:
            break
    if best_stats is None:
        # prewarm completed before enough contended samples existed on
        # every attempt — nothing measurable leaked onto the hot path
        return [("coldstart/prewarm_offpath_p99/uncontended", 0.0,
                 f"background prewarm finished before {8} post-head "
                 f"samples on all {attempts} attempts (no contention "
                 f"window to measure)", False)]
    d99, s99, n, points = best_stats
    if best > OFFPATH_P99_LIMIT:
        raise RuntimeError(
            f"coldstart gate: p99 during background prewarm "
            f"{d99 * 1e3:.1f}ms is {best:.2f}x steady-state "
            f"{s99 * 1e3:.1f}ms (> {OFFPATH_P99_LIMIT}x over {attempts} "
            f"attempts) — prewarm work is leaking onto the worker thread")
    return [
        (f"coldstart/prewarm_offpath_p99/{points}x{queries}", d99 * 1e6,
         f"{best:.2f}x steady-state p99 {s99 * 1e3:.1f}ms "
         f"(gate <= {OFFPATH_P99_LIMIT}x, n={n} contended samples)",
         False),
    ]


def coldstart_rows() -> list[tuple]:
    """All ``coldstart/*`` rows (wired into benchmarks/run.py)."""
    return subprocess_rows() + inprocess_rows() + prewarm_offpath_rows()


def main() -> None:
    import argparse

    p = argparse.ArgumentParser()
    p.add_argument("--child", action="store_true",
                   help="subprocess entry: one cold-start sample as JSON")
    p.add_argument("--points", type=int, default=_COLD_POINTS)
    p.add_argument("--queries", type=int, default=256)
    p.add_argument("--cache-dir", default=None)
    p.add_argument("--json", action="store_true")
    args = p.parse_args()
    if args.child:
        _child(args)
        return
    rows = coldstart_rows()
    if args.json:
        print(json.dumps([{"name": r[0], "us_per_call": r[1],
                           "derived": r[2],
                           "includes_compile": bool(r[3])}
                          for r in rows], indent=1))
        return
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r[0]},{r[1]:.1f},{r[2]}")


if __name__ == "__main__":
    main()
