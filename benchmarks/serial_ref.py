"""Serial CPU AIDW — the paper's baseline (Table 1, 'CPU/Serial', double).

Faithful to Mei et al. (2015)'s serial algorithm: per interpolated point, a
full kNN pass over all data points, then adaptive alpha, then the weighted
average over ALL data points.  NumPy float64, per-query loop (the inner loop
over data points is vectorized — a literal scalar loop would only scale the
constant, not the O(n*m) shape of the baseline).
"""

from __future__ import annotations

import numpy as np

ALPHAS = (0.5, 1.0, 2.0, 3.0, 4.0)


def serial_aidw(points_xyz: np.ndarray, queries_xy: np.ndarray, *, k: int = 15,
                alphas=ALPHAS, r_min: float = 0.0, r_max: float = 2.0,
                area: float | None = None) -> np.ndarray:
    pts = points_xyz.astype(np.float64)
    qs = queries_xy.astype(np.float64)
    m = len(pts)
    if area is None:
        xs = np.concatenate([pts[:, 0], qs[:, 0]])
        ys = np.concatenate([pts[:, 1], qs[:, 1]])
        area = (xs.max() - xs.min()) * (ys.max() - ys.min())
    r_exp = 1.0 / (2.0 * np.sqrt(m / area))

    a1, a2, a3, a4, a5 = alphas
    out = np.empty(len(qs))
    for i, (x, y) in enumerate(qs):
        d2 = (pts[:, 0] - x) ** 2 + (pts[:, 1] - y) ** 2
        knn = np.sort(d2)[: min(k, m)]
        r_obs = np.sqrt(knn).mean()
        r = r_obs / r_exp
        if r <= r_min:
            mu = 0.0
        elif r >= r_max:
            mu = 1.0
        else:
            mu = 0.5 - 0.5 * np.cos(np.pi / r_max * (r - r_min))
        if mu <= 0.1:
            al = a1
        elif mu <= 0.3:
            al = a1 * (1 - 5 * (mu - 0.1)) + 5 * a2 * (mu - 0.1)
        elif mu <= 0.5:
            al = 5 * a3 * (mu - 0.3) + a2 * (1 - 5 * (mu - 0.3))
        elif mu <= 0.7:
            al = a3 * (1 - 5 * (mu - 0.5)) + 5 * a4 * (mu - 0.5)
        elif mu <= 0.9:
            al = 5 * a5 * (mu - 0.7) + a4 * (1 - 5 * (mu - 0.7))
        else:
            al = a5
        w = np.maximum(d2, 1e-12) ** (-al / 2.0)
        out[i] = (w * pts[:, 2]).sum() / w.sum()
    return out
