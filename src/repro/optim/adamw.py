"""AdamW with f32 moments/master weights, global-norm clipping, LR schedules.

Pure-pytree implementation (no optax): the optimizer state is
``{"step", "mu", "nu", ["master"]}`` with moments sharded exactly like their
parameters (tree-mapped PartitionSpecs), which is what lets the dry-run lower
a realistic memory footprint: bf16 params + f32 mu/nu (+ optional f32 master)
= 10 (14) bytes/param before activations.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    master_weights: bool = True


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_ratio * lr."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps) /
                 max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio * cfg.lr + (1 - cfg.min_lr_ratio) * cfg.lr * \
        0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(cfg: AdamWConfig, params) -> dict:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(zeros32, params),
        "nu": jax.tree.map(zeros32, params),
    }
    if cfg.master_weights:
        # copy=True: a same-dtype astype would alias the param buffer and
        # break donation (donate(params) + donate(master) -> same buffer).
        state["master"] = jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)
    return state


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def apply_updates(cfg: AdamWConfig, params, state, grads):
    """One AdamW step.  grads may be bf16; all math in f32."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["mu"], grads)
    nu = jax.tree.map(lambda n, g: b2 * n + (1 - b2) * g * g, state["nu"], grads)

    base = state.get("master", params)

    def upd(p, m, n):
        pf = p.astype(jnp.float32)
        u = (m / bc1) / (jnp.sqrt(n / bc2) + cfg.eps) + cfg.weight_decay * pf
        return pf - lr * u

    new_master = jax.tree.map(upd, base, mu, nu)
    new_params = jax.tree.map(
        lambda nm, p: nm.astype(p.dtype), new_master, params)
    new_state = {"step": step, "mu": mu, "nu": nu}
    if cfg.master_weights:
        new_state["master"] = new_master
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def state_specs(cfg: AdamWConfig, param_spec_tree):
    """PartitionSpec tree for the optimizer state, mirroring the params."""
    from jax.sharding import PartitionSpec as P

    specs = {
        "step": P(),
        "mu": param_spec_tree,
        "nu": param_spec_tree,
    }
    if cfg.master_weights:
        specs["master"] = param_spec_tree
    return specs
