"""Optimizers + distributed-optimization transforms."""
from . import adamw, compression
