"""Error-feedback gradient compression (int8) for cross-pod reduction.

At 1000+ nodes the cross-pod (DCN-class) gradient all-reduce dominates the
step budget for pure-DP pods.  Classic EF-SGD/1-bit-Adam style compression:

    c_t   = Q(g_t + e_{t-1})        (int8 symmetric per-tensor quantization)
    e_t   = (g_t + e_{t-1}) - DQ(c_t)   (error memory, carried in opt state)
    update uses DQ(c_t)

Quantizing BEFORE the pod all-reduce cuts cross-pod bytes 4x (f32->i8) /
2x (bf16->i8); the error memory keeps the optimizer unbiased over time
(convergence validated in tests/test_optim.py on a real regression task).

Under pjit auto-sharding the reduction itself is XLA-inserted, so this module
exposes the transform as local math on the already-summed gradient; the
shard_map variant that places Q/DQ around an explicit cross-pod psum is the
``compressed_psum`` helper below.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _quantize(g: jax.Array):
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_feedback(grads, error):
    """Returns (dequantized grads, new error memory)."""

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = _quantize(gf)
        dq = _dequantize(q, s)
        return dq, gf - dq

    flat = jax.tree.map(one, grads, error)
    dq = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    return dq, err


def compressed_psum(g: jax.Array, axis_name: str):
    """shard_map building block: int8 quantize -> psum -> dequantize.

    The wire format crossing ``axis_name`` is int8 + one f32 scale, i.e. the
    collective moves ~1/4 of the f32 bytes.  (Sum of quantized values is
    exact in int32 accumulation; scales are combined via max.)
    """
    q, scale = _quantize(g.astype(jnp.float32))
    q32 = jax.lax.psum(q.astype(jnp.int32), axis_name)
    s = jax.lax.pmax(scale, axis_name)
    return q32.astype(jnp.float32) * s
