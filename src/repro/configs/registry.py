"""Architecture registry: ``--arch <id>`` -> ModelConfig.

Every assigned architecture is a selectable config; ``reduced()`` derives the
small same-family config used by the per-arch CPU smoke tests (the FULL
configs are exercised only via the dry-run's ShapeDtypeStructs).
"""

from __future__ import annotations

from importlib import import_module

import jax.numpy as jnp

from repro.models.config import ModelConfig

_MODULES = {
    "internvl2-76b": "internvl2_76b",
    "command-r-plus-104b": "command_r_plus_104b",
    "deepseek-7b": "deepseek_7b",
    "llama3.2-3b": "llama3_2_3b",
    "granite-3-2b": "granite_3_2b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "mamba2-130m": "mamba2_130m",
    "zamba2-2.7b": "zamba2_2_7b",
    "whisper-medium": "whisper_medium",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def reduced(cfg: ModelConfig, *, dtype=jnp.float32) -> ModelConfig:
    """Small same-family config for CPU smoke tests (one fwd/train step)."""
    kw: dict = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=64, vocab=128, dtype=dtype, remat=False,
        q_chunk=32, ssm_chunk=16,
    )
    if cfg.uses_attention:
        kw.update(n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 4) or 4, d_head=16)
    if cfg.is_moe:
        kw.update(n_experts=min(cfg.n_experts, 8),
                  top_k=min(cfg.top_k, 2), moe_d_ff=32)
    if cfg.d_ff:
        kw.update(d_ff=128)
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_head_dim=16)
    if cfg.family == "hybrid":
        kw.update(attn_every=2, n_layers=4)
    if cfg.enc_dec:
        kw.update(n_enc_layers=2, enc_len=24)
    if cfg.family == "vlm":
        kw.update(n_vis_tokens=8)
    return cfg.with_(**kw)
