"""whisper-medium [audio]: enc-dec transformer (arXiv:2212.04356).

The conv/log-mel frontend is a STUB: input_specs() supplies precomputed
frame embeddings (enc_len=1500 x d_model).  Sinusoidal positions substitute
the decoder's learned table so params stay independent of assigned shapes.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865,
    enc_dec=True, n_enc_layers=24, enc_len=1500,
    tie_embeddings=True,
)
