"""mamba2-130m [ssm]: SSD / state-space duality (arXiv:2405.21060).
Attention-free; runs the long_500k cell."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, vocab=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_groups=1, d_conv=4,
    tie_embeddings=True,
)
