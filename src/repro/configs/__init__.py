"""Architecture configs (one module per assigned arch) + registry."""

from .registry import ARCH_IDS, get_config, reduced
