"""zamba2-2.7b [hybrid]: Mamba2 backbone + ONE shared attention block applied
every 6 layers (arXiv:2411.15242).  Runs the long_500k cell.

Simplifications vs. the full Zamba2 recipe (recorded in DESIGN.md): the
shared block here takes the current hidden state (no concat-with-embedding
input, no per-invocation LoRA deltas).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_groups=1, d_conv=4,
    attn_every=6,
)
