"""command-r-plus-104b [dense]: Cohere GQA, no-bias, parallel residual blocks
(hf:CohereForAI/c4ai-command-r-v01 family)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8,
    d_ff=33792, vocab=256000,
    parallel_residual=True, tie_embeddings=True,
    notes="Cohere-style parallel attention+FFN block; tied embeddings.",
)
