"""internvl2-76b [vlm]: InternViT + InternLM2 backbone (arXiv:2404.16821).

Backbone only — the vision frontend is a STUB: input_specs() supplies
precomputed patch embeddings (n_vis_tokens x d_model) per the assignment.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, rope_theta=1_000_000.0,
    n_vis_tokens=256,
    notes="InternLM2-76B LM backbone; GQA kv=8; patch-embed frontend stubbed.",
)
