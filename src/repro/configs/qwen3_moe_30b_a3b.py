"""qwen3-moe-30b-a3b [moe]: 128 experts top-8, 768-wide experts
(hf:Qwen/Qwen3-30B-A3B)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=768, vocab=151936, rope_theta=1_000_000.0,
    n_experts=128, top_k=8, moe_d_ff=768,
)
