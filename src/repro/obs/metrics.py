"""One metrics registry: counters, gauges, and bin-exact mergeable histograms.

This module unifies what used to be three disjoint metric surfaces —
``InterpolationSession.stats`` dicts, ``serving.telemetry.Telemetry``'s
private histograms, and the ad-hoc ingest block in the cluster's
``merge_reports`` — behind one :class:`Registry`.  Everything here is
dependency-free host-side bookkeeping (no JAX, no device syncs): a
``record``/``inc``/``set`` costs a few dict updates, so hot serving paths
can call it per batch without perturbing the latencies it measures.

Design rules:

* **Histograms are bin-exact mergeable.**  :class:`Histogram` (the class
  previously published as ``serving.telemetry.LatencyHistogram``; that name
  is still exported there as an alias) snapshots its full bin counts in
  :meth:`Histogram.state`, so fleet-level percentiles are computed exactly
  from per-host bins instead of averaging per-host percentiles (which has
  no statistical meaning).  :meth:`Registry.merge_state` reuses the same
  merge for whole registries — the cluster rollup in
  ``serving/cluster/telemetry.py`` is built on it.
* **Gauges declare their merge mode.**  A fleet rollup must know whether a
  gauge is additive across hosts (``merge='sum'``: e.g. staged bytes), a
  high-water (``merge='max'``: e.g. ring occupancy), or host-local
  (``merge='last'``).
* **Prometheus naming scheme** (:meth:`Registry.prometheus_text`): metric
  names are slash-namespaced internally (``session/plan_s``,
  ``serving/queue_wait_s``); the exporter maps them to
  ``<prefix>_<name>`` with ``/``, ``.``, ``-`` and spaces folded to ``_``
  (default prefix ``aidw``).  Counters get the conventional ``_total``
  suffix; histograms are rendered summary-style as ``_count`` / ``_sum`` /
  ``_max`` plus ``{quantile="0.5|0.95|0.99"}`` samples.  Every family is
  preceded by its ``# HELP`` and ``# TYPE`` comment lines.
* **Exemplars link buckets to traces.**  ``record(s, exemplar=trace_id)``
  keeps ONE exemplar id per log bin (latest wins), merged bin-exactly in
  :meth:`Histogram.merge_state` and emitted in the JSON snapshot/state —
  so a fleet p99 bucket points straight at a flight-recorder trace.  The
  Prometheus text exposition is unchanged (exemplars are an OpenMetrics
  extension; the 0.0.4 text format has no syntax for them).
"""

from __future__ import annotations

import math
import threading
from bisect import bisect_left

__all__ = ["Counter", "Gauge", "Histogram", "Registry"]


class Histogram:
    """Log-spaced histogram with quantile estimation (seconds by default).

    Bins span ``lo``..``hi`` with ``bins_per_decade`` log10-spaced buckets
    (default: 1us..1000s, 10 buckets/decade => 91 bins, <1KB).
    ``percentile`` returns the upper edge of the bucket holding the
    requested rank, clamped to the exact observed max — a <=26%
    overestimate by construction, which is the right bias for latency SLO
    reporting.
    """

    def __init__(self, lo: float = 1e-6, hi: float = 1e3,
                 bins_per_decade: int = 10):
        self.lo = float(lo)
        self.hi = float(hi)
        self.bins_per_decade = int(bins_per_decade)
        decades = math.log10(hi / lo)
        n = int(round(decades * bins_per_decade))
        self._edges = [lo * 10.0 ** (i / bins_per_decade)
                       for i in range(1, n + 1)]
        self._counts = [0] * (n + 1)        # +1: overflow bucket above hi
        self._exemplars: dict[int, str] = {}   # bin index -> trace id
        self.count = 0
        self.sum = 0.0
        self.max = 0.0

    def record(self, seconds: float, exemplar: str | None = None) -> None:
        s = max(float(seconds), 0.0)
        i = bisect_left(self._edges, s)
        self._counts[i] += 1
        if exemplar is not None:
            self._exemplars[i] = exemplar     # one per bin, latest wins
        self.count += 1
        self.sum += s
        if s > self.max:
            self.max = s

    def percentile(self, p: float) -> float:
        """p in [0, 100] -> seconds (0.0 when empty)."""
        if self.count == 0:
            return 0.0
        rank = p / 100.0 * self.count
        seen = 0
        for i, c in enumerate(self._counts):
            seen += c
            if seen >= rank and c:
                edge = self._edges[i] if i < len(self._edges) else self.max
                return min(edge, self.max)
        return self.max

    def snapshot(self) -> dict:
        out = {
            "count": self.count,
            "mean_s": self.sum / self.count if self.count else 0.0,
            "p50_s": self.percentile(50),
            "p95_s": self.percentile(95),
            "p99_s": self.percentile(99),
            "max_s": self.max,
        }
        if self._exemplars:
            # upper bin edge -> exemplar id: the human-facing view keys by
            # latency bound, not bin index
            out["exemplars"] = {
                f"{self._edges[i] if i < len(self._edges) else self.hi:g}":
                    x for i, x in sorted(self._exemplars.items())}
        return out

    # -- cross-host merging --------------------------------------------------

    def state(self) -> dict:
        """Full mergeable state (JSON-serializable): bin counts plus the bin
        parameters, so fleet-level percentiles can be computed exactly from
        per-host histograms instead of averaging per-host percentiles (which
        has no statistical meaning)."""
        out = {"lo": self.lo, "hi": self.hi,
               "bins_per_decade": self.bins_per_decade,
               "counts": list(self._counts),
               "count": self.count, "sum": self.sum, "max": self.max}
        if self._exemplars:
            # JSON object keys must be strings; merge_state converts back
            out["exemplars"] = {str(i): x
                                for i, x in self._exemplars.items()}
        return out

    def merge_state(self, state: dict) -> None:
        """Fold another histogram's :meth:`state` into this one.  Bin layouts
        must match — merging histograms with different edges would silently
        misattribute counts, so mismatch raises."""
        if (state["lo"], state["hi"], state["bins_per_decade"]) != \
                (self.lo, self.hi, self.bins_per_decade) or \
                len(state["counts"]) != len(self._counts):
            raise ValueError("cannot merge histograms with different bins")
        for i, c in enumerate(state["counts"]):
            self._counts[i] += int(c)
        self.count += int(state["count"])
        self.sum += float(state["sum"])
        self.max = max(self.max, float(state["max"]))
        # bin-exact exemplar merge; .get guards pre-exemplar peer states
        for i, x in (state.get("exemplars") or {}).items():
            self._exemplars[int(i)] = x

    @classmethod
    def from_states(cls, states) -> "Histogram":
        """Merge per-host states into one fleet histogram."""
        states = list(states)
        if not states:
            return cls()
        h = cls(states[0]["lo"], states[0]["hi"],
                states[0]["bins_per_decade"])
        for s in states:
            h.merge_state(s)
        return h


class Counter:
    """Monotonically increasing count; fleet merge is always additive."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """A point-in-time value with a declared fleet merge mode.

    ``merge`` is one of ``'sum'`` (additive across hosts), ``'max'``
    (high-water), or ``'last'`` (host-local; the merged value is whichever
    state was folded in last).
    """

    __slots__ = ("value", "merge")

    def __init__(self, merge: str = "last"):
        if merge not in ("sum", "max", "last"):
            raise ValueError(f"unknown gauge merge mode: {merge!r}")
        self.value = 0.0
        self.merge = merge

    def set(self, v: float) -> None:
        self.value = float(v)


class Registry:
    """Named counters/gauges/histograms with snapshot, Prometheus text, and
    bin-exact cross-host merge.

    Metric names are slash-namespaced (``session/plan_s``); create-or-get
    accessors make wiring cheap::

        reg.observe("session/plan_s", 0.012)      # histogram
        reg.inc("serving/batches")                # counter
        reg.set("ingest/ring_occupancy", 17, merge="max")

    Thread-safe: one lock guards metric creation and mutation (a record is
    a few dict updates, contention is negligible at serving batch rates).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._hists: dict[str, Histogram] = {}

    # -- create-or-get -------------------------------------------------------

    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter()
            return c

    def gauge(self, name: str, merge: str = "last") -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge(merge)
            return g

    def histogram(self, name: str, lo: float = 1e-6, hi: float = 1e3,
                  bins_per_decade: int = 10) -> Histogram:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = Histogram(lo, hi, bins_per_decade)
            return h

    def reset_histogram(self, name: str) -> Histogram:
        """Replace ``name`` with a fresh histogram of the SAME binning and
        return it (load harnesses zero steady-state windows after warmup
        without losing the metric's registration)."""
        with self._lock:
            old = self._hists.get(name)
            h = Histogram(old.lo, old.hi, old.bins_per_decade) \
                if old is not None else Histogram()
            self._hists[name] = h
            return h

    # -- convenience recording ----------------------------------------------

    def inc(self, name: str, n: int = 1) -> None:
        self.counter(name).inc(n)

    def set(self, name: str, v: float, merge: str = "last") -> None:
        self.gauge(name, merge).set(v)

    def observe(self, name: str, seconds: float,
                exemplar: str | None = None) -> None:
        self.histogram(name).record(seconds, exemplar=exemplar)

    # -- reporting -----------------------------------------------------------

    def snapshot(self) -> dict:
        """Human-facing JSON snapshot: scalar values + histogram quantiles."""
        with self._lock:
            return {
                "counters": {k: c.value for k, c in self._counters.items()},
                "gauges": {k: g.value for k, g in self._gauges.items()},
                "histograms": {k: h.snapshot()
                               for k, h in self._hists.items()},
            }

    def state(self) -> dict:
        """Mergeable cross-host state: counters, gauges (with merge modes),
        and FULL histogram bin states."""
        with self._lock:
            return {
                "counters": {k: c.value for k, c in self._counters.items()},
                "gauges": {k: {"value": g.value, "merge": g.merge}
                           for k, g in self._gauges.items()},
                "hists": {k: h.state() for k, h in self._hists.items()},
            }

    def merge_state(self, state: dict) -> None:
        """Fold another registry's :meth:`state` in: counters add, gauges
        combine per their declared merge mode, histograms merge bin-exact."""
        for k, v in state.get("counters", {}).items():
            self.counter(k).inc(int(v))
        for k, gs in state.get("gauges", {}).items():
            g = self.gauge(k, gs.get("merge", "last"))
            v = float(gs["value"])
            if g.merge == "sum":
                g.value += v
            elif g.merge == "max":
                g.value = max(g.value, v)
            else:
                g.value = v
        for k, hs in state.get("hists", {}).items():
            with self._lock:
                h = self._hists.get(k)
                if h is None:
                    h = self._hists[k] = Histogram(
                        hs["lo"], hs["hi"], hs["bins_per_decade"])
            h.merge_state(hs)

    @classmethod
    def merge_states(cls, states) -> "Registry":
        """Merge per-host registry states into one fleet registry."""
        reg = cls()
        for s in states:
            reg.merge_state(s)
        return reg

    # -- Prometheus exposition ----------------------------------------------

    @staticmethod
    def _prom_name(prefix: str, name: str) -> str:
        out = []
        for ch in f"{prefix}_{name}" if prefix else name:
            out.append(ch if (ch.isalnum() or ch == "_") else "_")
        s = "".join(out)
        return "_" + s if s[:1].isdigit() else s

    def prometheus_text(self, prefix: str = "aidw") -> str:
        """Prometheus text exposition (version 0.0.4) of every metric.

        Counters render as ``<p>_<name>_total``; gauges as ``<p>_<name>``;
        histograms summary-style: ``_count``, ``_sum``, ``_max`` plus
        ``{quantile="0.5|0.95|0.99"}`` samples in seconds.  Each family is
        preceded by ``# HELP`` (the internal slash-namespaced name, so
        dashboards can map back to ``Registry`` keys) and ``# TYPE``.
        """
        with self._lock:
            counters = {k: c.value for k, c in self._counters.items()}
            gauges = {k: g.value for k, g in self._gauges.items()}
            hists = {k: h.snapshot() for k, h in self._hists.items()}
        lines = []
        for k in sorted(counters):
            n = self._prom_name(prefix, k) + "_total"
            lines.append(f"# HELP {n} cumulative count of {k}")
            lines.append(f"# TYPE {n} counter")
            lines.append(f"{n} {counters[k]}")
        for k in sorted(gauges):
            n = self._prom_name(prefix, k)
            lines.append(f"# HELP {n} gauge {k}")
            lines.append(f"# TYPE {n} gauge")
            lines.append(f"{n} {gauges[k]}")
        for k in sorted(hists):
            n = self._prom_name(prefix, k)
            s = hists[k]
            lines.append(f"# HELP {n} summary of {k} in seconds")
            lines.append(f"# TYPE {n} summary")
            for q, key in ((0.5, "p50_s"), (0.95, "p95_s"), (0.99, "p99_s")):
                lines.append(f'{n}{{quantile="{q}"}} {s[key]}')
            lines.append(f"{n}_sum {s['mean_s'] * s['count']}")
            lines.append(f"{n}_count {s['count']}")
            lines.append(f"{n}_max {s['max_s']}")
        return "\n".join(lines) + "\n"
