"""Tail-latency attribution: decompose the p99−p50 gap into per-stage
contributions from the flight recorder's retained outliers.

The question "why is p99 slow" is a question about the DIFFERENCE
between tail requests and typical requests, not about where time goes on
average — a stage can dominate the mean and contribute nothing to the
tail.  The decomposition here:

1. ``gap = p99(total) − p50(total)`` from the recorder's always-on
   total histogram (every request, not just retained ones);
2. the *tail set* is the retained SERVED outliers whose total is at/above
   the p99 threshold (sheds are excluded — they have no stage breakdown
   and their fast termination is censored from the histogram too);
   when retention classes caught outliers below p99 only, the single
   slowest retained record stands in;
3. per additive stage, ``excess = max(mean_tail(stage) − p50(stage), 0)``
   — how much more of that stage a tail request pays than the typical
   request;
4. the gap is attributed proportionally:
   ``attributed = gap * excess / sum(excess)`` — so the per-stage
   contributions sum to the measured gap EXACTLY whenever any stage shows
   excess (raw excesses are reported alongside; the proportional view is
   the headline because fence-grained stage walls overlap imperfectly).

Only ``queue_wait`` and ``execute`` are additive: by the scheduler's
timing identity ``total = queue_wait + execute`` exactly (coalesce
overlaps queue_wait inside the submit→dispatch window; scatter lands
after ``t_done``).  The overlapping stages are still reported — a tail
dominated by coalesce time is actionable (linger too long) even though
its wall is a subset of queue_wait's.

The report also carries a ``stalls`` block read from the registry state:
``session/compact_stall_s`` (the FIFO-barrier hold while the stop-the-
world compaction folds) and ``serving/epoch_barrier_s`` (dataset-update
holds) — the two serving-loop stalls that surface as queue_wait in the
per-request view; the block names the culprit behind a queue_wait-heavy
tail.
"""

from __future__ import annotations

from .metrics import Histogram

__all__ = ["tail_attribution", "render_attribution", "ADDITIVE_STAGES"]

# total == queue_wait + execute by the scheduler's timing identity
ADDITIVE_STAGES = ("queue_wait", "execute")
# reported but excluded from the additive decomposition (overlapping)
OVERLAY_STAGES = ("coalesce", "scatter")

# registry histograms surfaced as the stall block (name -> short label)
_STALL_HISTS = {
    "session/compact_stall_s": "compaction stall (FIFO barrier hold)",
    "serving/epoch_barrier_s": "epoch barrier (dataset update hold)",
    "session/compact_s": "compaction device fold",
}


def tail_attribution(recorder_states, *, registry_state=None,
                     p_tail: float = 99.0, p_base: float = 50.0) -> dict:
    """Build the attribution report from one or more
    ``FlightRecorder.state()`` dicts (a fleet merge is just the list of
    per-host states — histograms merge bin-exactly, trace lists
    concatenate).  ``registry_state`` (a ``Registry.state()`` dict,
    optionally fleet-merged) feeds the stall block."""
    if isinstance(recorder_states, dict):
        recorder_states = [recorder_states]
    states = [s for s in recorder_states if s]

    def merged(name):
        hs = [s["hists"][name] for s in states
              if s.get("hists", {}).get(name)]
        return Histogram.from_states(hs) if hs else Histogram()

    total = merged("total")
    p_lo = total.percentile(p_base)
    p_hi = total.percentile(p_tail)
    gap = max(p_hi - p_lo, 0.0)

    outliers = [t for s in states for t in s.get("traces", [])
                if "shed" not in t.get("anomalies", ())
                and t.get("breakdown", {}).get("total") is not None]
    tail = [t for t in outliers if t["breakdown"]["total"] >= p_hi]
    tail_is_fallback = False
    if not tail and outliers:
        tail = [max(outliers, key=lambda t: t["breakdown"]["total"])]
        tail_is_fallback = True

    def stage_row(name, additive):
        base = merged(name).percentile(p_base)
        walls = [t["breakdown"].get(name) for t in tail]
        walls = [w for w in walls if w is not None]
        mean = (sum(walls) / len(walls)) if walls else 0.0
        return {"p50_s": base, "tail_mean_s": mean,
                "excess_s": max(mean - base, 0.0),
                "additive": additive}

    stages = {n: stage_row(n, True) for n in ADDITIVE_STAGES}
    stages.update({n: stage_row(n, False) for n in OVERLAY_STAGES})

    # shares come from per-stage EXCESS over the p50 baseline; when no
    # additive stage exceeds its baseline (log-bin edge effects under
    # saturation: percentile() returns bin upper edges, which can
    # overshoot every observed wall) degrade to raw tail-mean mass so a
    # positive gap still decomposes instead of going unattributed
    excess_sum = sum(stages[n]["excess_s"] for n in ADDITIVE_STAGES)
    share_basis, basis_key = "excess", "excess_s"
    if excess_sum <= 0:
        excess_sum = sum(stages[n]["tail_mean_s"] for n in ADDITIVE_STAGES)
        share_basis, basis_key = "tail_mean", "tail_mean_s"
    for n in ADDITIVE_STAGES:
        share = (stages[n][basis_key] / excess_sum) if excess_sum > 0 \
            else 0.0
        stages[n]["share"] = share
        stages[n]["attributed_s"] = gap * share
    for n in OVERLAY_STAGES:
        stages[n]["share"] = None
        stages[n]["attributed_s"] = None

    attributed = sum(stages[n]["attributed_s"] for n in ADDITIVE_STAGES)

    stalls = {}
    if registry_state:
        # Registry.state() keys its mergeable bin states "hists" (the
        # snapshot() form, "histograms", holds percentiles, not bins)
        reg_hists = registry_state.get("hists", {})
        for hname, label in _STALL_HISTS.items():
            hs = reg_hists.get(hname)
            if not hs:
                continue
            h = Histogram.from_states([hs])
            stalls[hname] = {"label": label, "count": h.count,
                             "p50_s": h.percentile(50.0),
                             "p99_s": h.percentile(99.0),
                             "max_s": h.max, "sum_s": h.sum}

    return {"p_tail": p_tail, "p_base": p_base,
            "n_total": total.count,
            "p50_s": p_lo, "p99_s": p_hi, "gap_s": gap,
            "tail_n": len(tail), "tail_is_fallback": tail_is_fallback,
            "outliers_retained": len(outliers),
            "share_basis": share_basis,
            "stages": stages,
            "attributed_s": attributed,
            "unattributed_s": max(gap - attributed, 0.0),
            "stalls": stalls}


def render_attribution(report: dict) -> str:
    """Human-readable rendering of :func:`tail_attribution` output."""
    r = report
    lines = [
        f"tail-latency attribution (p{r['p_base']:g} -> p{r['p_tail']:g},"
        f" n={r['n_total']})",
        f"  p50 {r['p50_s'] * 1e3:9.3f} ms   p99 {r['p99_s'] * 1e3:9.3f}"
        f" ms   gap {r['gap_s'] * 1e3:9.3f} ms",
        f"  tail set: {r['tail_n']} retained outlier(s)"
        + (" [fallback: slowest retained]" if r["tail_is_fallback"]
           else ""),
    ]
    lines.append(f"  {'stage':<12} {'p50':>10} {'tail mean':>10}"
                 f" {'excess':>10} {'attributed':>11} {'share':>7}")
    for name, s in r["stages"].items():
        att = "" if s["attributed_s"] is None \
            else f"{s['attributed_s'] * 1e3:9.3f}ms"
        shr = "" if s["share"] is None else f"{s['share'] * 100:5.1f}%"
        tag = "" if s["additive"] else "  (overlaps)"
        lines.append(
            f"  {name:<12} {s['p50_s'] * 1e3:8.3f}ms"
            f" {s['tail_mean_s'] * 1e3:8.3f}ms"
            f" {s['excess_s'] * 1e3:8.3f}ms {att:>11} {shr:>7}{tag}")
    basis = "" if r.get("share_basis", "excess") == "excess" \
        else " [shares by tail-mean mass: no stage exceeded baseline]"
    lines.append(f"  attributed {r['attributed_s'] * 1e3:.3f} ms"
                 f" / gap {r['gap_s'] * 1e3:.3f} ms"
                 f" (unattributed {r['unattributed_s'] * 1e3:.3f} ms){basis}")
    if r["stalls"]:
        lines.append("  stalls:")
        for hname, st in r["stalls"].items():
            lines.append(
                f"    {hname:<28} n={st['count']:<5}"
                f" p50 {st['p50_s'] * 1e3:8.3f}ms"
                f" p99 {st['p99_s'] * 1e3:8.3f}ms"
                f" max {st['max_s'] * 1e3:8.3f}ms  ({st['label']})")
    return "\n".join(lines)
