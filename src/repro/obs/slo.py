"""SLO burn-rate monitor over the serving metrics ``Registry``.

An SLO is a target rate of *bad events* over total events (e.g. "at most
1% of requests miss their deadline").  The monitor keeps a bounded ring
of cumulative-counter samples and, on evaluation, computes the observed
bad-event rate over each configured trailing window; the **burn rate** is
``observed_rate / target_rate`` — burn 1.0 spends error budget exactly
as fast as the SLO allows, burn 14 on the short window is the classic
page-now threshold.  Multi-window evaluation (default 60 s and 300 s)
distinguishes a transient blip (short window hot, long window cold) from
a sustained burn (both hot).

The monitor is COLD-PATH ONLY: nothing on the request path touches it.
``sample()`` is called from ``report()`` / ``debugz()`` pulls with the
cumulative counters of the moment; ``evaluate()`` is pure arithmetic over
the retained samples.  Rate SLOs need at least two samples spanning a
window before they report — ``windows_evaluated`` says how many actually
had data.  Gauge SLOs (queue depth, ring occupancy vs the compaction
highwater) are instantaneous threshold checks on the latest sample.

Breaches emit structured events into the
:class:`~repro.obs.recorder.FlightRecorder` event ring (deduplicated per
(slo, window) while the breach persists) and appear in the ``slo`` block
of ``server.report()``.  Fleet-level epoch staleness cannot be seen from
any single host; :func:`fleet_epoch_events` derives it in
``AidwCluster.debugz()`` from the per-host bundle epochs.
"""

from __future__ import annotations

import time
from collections import deque

__all__ = ["SloMonitor", "fleet_epoch_events", "DEFAULT_TARGETS"]

# rate targets are bad/total fractions; gauge targets are absolute
# thresholds on the latest sampled value (None disables the check)
DEFAULT_TARGETS = {
    "deadline_miss_rate": 0.01,   # <=1% of requests may miss deadline
    "shed_rate": 0.01,            # <=1% of requests may be shed
    "queue_depth_frac": 0.9,      # admission queue nearly full
    "ring_occupancy": None,       # set from compact_highwater by server
    # ANY compile reaching the hot path after the bucket ladder was
    # prewarmed is an anomaly (the server's counter behind this gauge
    # only increments once prewarm completed — before that, lazy
    # compiles are expected and ignored)
    "post_warmup_compiles": 1.0,
}

# which cumulative counters feed each rate SLO: (bad, total)
_RATE_COUNTERS = {
    "deadline_miss_rate": ("deadline_miss", "requests"),
    "shed_rate": ("shed", "requests"),
}


class SloMonitor:
    """Burn-rate windows over cumulative counters + gauge thresholds.

    ``sample(counters, gauges)`` appends one cumulative snapshot;
    ``evaluate()`` returns the JSON ``slo`` block and pushes breach
    events into ``recorder`` (when given).  All timestamps come from the
    injected ``clock`` so the window math replays exactly under fake
    clocks.
    """

    def __init__(self, *, clock=time.monotonic,
                 windows=(60.0, 300.0), targets=None,
                 recorder=None, max_samples: int = 512):
        self.clock = clock
        self.windows = tuple(float(w) for w in windows)
        self.targets = dict(DEFAULT_TARGETS)
        if targets:
            self.targets.update(targets)
        self.recorder = recorder
        self.max_samples = int(max_samples)
        self._samples: deque = deque()
        # (slo, window) -> currently breaching?  Edge-triggered event
        # emission: one event when a burn crosses 1.0, not one per pull.
        self._breaching: dict = {}

    def sample(self, counters: dict, gauges: dict | None = None,
               now: float | None = None) -> None:
        """Record one cumulative snapshot.  ``counters`` must be
        monotonically non-decreasing across calls (restarts reset the
        window by clearing samples, not by going backwards)."""
        t = self.clock() if now is None else now
        self._samples.append((float(t), dict(counters),
                              dict(gauges or {})))
        while len(self._samples) > self.max_samples:
            self._samples.popleft()

    def evaluate(self, now: float | None = None) -> dict:
        """The ``slo`` report block: per-SLO per-window burn rates, gauge
        threshold checks, and the breach events newly emitted by this
        evaluation."""
        t = self.clock() if now is None else now
        out = {"targets": {k: v for k, v in self.targets.items()
                           if v is not None},
               "windows_s": list(self.windows),
               "rates": {}, "gauges": {}, "events": []}
        if not self._samples:
            return out
        latest_t, latest_c, latest_g = self._samples[-1]

        for slo, (bad_key, total_key) in _RATE_COUNTERS.items():
            target = self.targets.get(slo)
            if target is None:
                continue
            per_window = {}
            for w in self.windows:
                base = self._baseline(t - w)
                if base is None:
                    continue
                base_t, base_c, _ = base
                d_total = latest_c.get(total_key, 0) \
                    - base_c.get(total_key, 0)
                d_bad = latest_c.get(bad_key, 0) - base_c.get(bad_key, 0)
                rate = (d_bad / d_total) if d_total > 0 else 0.0
                burn = rate / target
                per_window[str(int(w))] = {
                    "rate": rate, "burn": burn,
                    "bad": int(d_bad), "total": int(d_total),
                    "span_s": latest_t - base_t,
                }
                self._edge(out, slo, str(int(w)), burn >= 1.0,
                           {"rate": rate, "burn": burn,
                            "target": target, "window_s": w})
            if per_window:
                per_window["windows_evaluated"] = len(
                    [k for k in per_window if k != "windows_evaluated"])
                out["rates"][slo] = per_window

        for slo in ("queue_depth_frac", "ring_occupancy",
                    "post_warmup_compiles"):
            target = self.targets.get(slo)
            if target is None or slo not in latest_g:
                continue
            val = float(latest_g[slo])
            out["gauges"][slo] = {"value": val, "target": float(target),
                                  "breaching": val >= target}
            self._edge(out, slo, "gauge", val >= target,
                       {"value": val, "target": float(target)})
        return out

    # -- internals -----------------------------------------------------------

    def _baseline(self, cutoff: float):
        """The newest sample at/before ``cutoff`` (the window's left
        edge), or the oldest retained sample if the ring already spans
        past it; ``None`` when fewer than two samples exist (no window to
        difference over)."""
        if len(self._samples) < 2:
            return None
        base = None
        for s in self._samples:
            if s[0] <= cutoff:
                base = s
            else:
                break
        if base is None:
            base = self._samples[0]
        if base is self._samples[-1]:
            return None
        return base

    def _edge(self, out: dict, slo: str, window: str, breaching: bool,
              data: dict) -> None:
        key = (slo, window)
        was = self._breaching.get(key, False)
        self._breaching[key] = breaching
        if breaching and not was:
            ev = {"kind": "slo_breach", "slo": slo, "window": window}
            ev.update(data)
            out["events"].append(ev)
            if self.recorder is not None:
                self.recorder.event("slo_breach", severity="critical",
                                    data={"slo": slo, "window": window,
                                          **data})


def fleet_epoch_events(host_bundles: dict, *, max_lag: int = 1) -> list:
    """Epoch-staleness check across a fleet's debugz bundles: no single
    host can see it, so the merge point derives it.  Returns breach
    events when ``max(epoch) - min(epoch)`` exceeds ``max_lag`` —
    stragglers are pinning the epoch barrier for everyone routed to
    them."""
    epochs = {hid: b.get("epoch") for hid, b in host_bundles.items()
              if b.get("epoch") is not None}
    if len(epochs) < 2:
        return []
    lo, hi = min(epochs.values()), max(epochs.values())
    if hi - lo <= max_lag:
        return []
    stale = sorted(h for h, e in epochs.items() if e < hi - max_lag)
    return [{"kind": "slo_breach", "slo": "epoch_staleness",
             "window": "fleet", "min_epoch": int(lo),
             "max_epoch": int(hi), "lag": int(hi - lo),
             "stale_hosts": stale}]
