"""Observability spine: end-to-end tracing + one metrics registry.

This package is the single instrumentation surface for the whole stack —
core session, async server, and the multi-host fleet all report through
it, so BENCH_*.json rows, ``server.report()``, the Prometheus endpoint,
and Chrome traces are different views of the SAME measurements.

Span taxonomy (names are stable API; tests and the stage bench key on
them):

* **session** — ``plan`` (one-time Stage-1 build) with sub-spans ``bin``
  (CSR binning) and ``staging`` (device upload of delta row patches);
  ``stage1`` (grid kNN search), ``stage2`` (weighted interpolation),
  ``compact`` (LSM ring fold-back).  ``query`` wraps a whole session
  query.
* **serving** (one per request, parented on the request's root span) —
  ``queue_wait`` (submit -> dispatch), ``coalesce`` (batch hold beyond
  the last member's arrival), ``execute`` (dispatch -> results
  materialized on host), ``scatter`` (slice results back per request).
* **fleet** — ``route`` (host pick + submit; a drain resubmission records
  a child ``resubmit`` span under the SAME trace), ``fanout`` (parallel
  shard rpc), ``phase1`` (shard kNN + k-way merge input), ``merge``
  (client-side k-way merge + alpha), ``phase2`` (partial-sum fan-out),
  and ``epoch_update``/``apply_epoch`` for the update barrier path.

Clock / fencing contract:

* Every :class:`~repro.obs.trace.Tracer` takes an **explicit clock** — the
  same clock its component stamps request timestamps with — so spans and
  latency histograms share an epoch, and fake-clock tests are exact.  A
  wall-clock anchor captured once at construction aligns exports across
  processes (pass ``wall=None`` under fake clocks).
* Spans that bracket device work close only after
  :func:`~repro.obs.trace.fence` (``jax.block_until_ready``) on the
  stage's outputs — stage walls stay honest on async dispatch backends.
* **Overhead budget**: with sampling off (``sample_rate=0``) the entire
  subsystem costs one ``None``-check per call site — enforced <2% on
  serving p99 by the ``serving/trace_overhead_p99_ratio`` load_gen gate.

Trace propagation: a sampled request carries ``trace_id``/``parent_span``
on ``InterpolationRequest``, across the JSON/TCP rpc control plane,
through ``EpochUpdate`` barriers and router drain-resubmission, so one
fleet query yields ONE connected cross-host trace.
:func:`~repro.obs.trace.chrome_trace` renders collected span dicts as
Chrome ``trace_event`` JSON (loads in ``chrome://tracing``/Perfetto).

Registry -> Prometheus naming: see :mod:`repro.obs.metrics` — internal
slash-namespaced names (``session/plan_s``) export as
``aidw_session_plan_s`` (counters ``_total``-suffixed, histograms
summary-style with ``quantile`` labels, ``# HELP``/``# TYPE`` per
family).  Histograms carry per-bin **exemplars**
(``observe(..., exemplar=trace_id)``): a p99 bucket links straight to a
flight-recorder trace.

Always-on vs sampled — the two tiers of the tail story:

* The **Tracer** is HEAD-sampled (root decides at submit); production
  runs it at ``sample_rate=0``, so it explains requests you chose in
  advance, never the stragglers.
* The :class:`~repro.obs.recorder.FlightRecorder` is ALWAYS-ON and
  TAIL-sampled: every request pays a fixed-size coarse breakdown
  (queue_wait/coalesce/execute/scatter floats off the existing fence
  points), and the full span tree is retained in a bounded ring only
  when the request is anomalous.  Anomaly classes (stable API):
  ``deadline_miss``, ``shed``, ``overflow``, ``zero_weight``, and
  ``slow`` (total at/above the recorder's own running
  ``top_percentile``, armed after ``min_window`` observations).
  Retention is deterministic under fake clocks; evictions are counted in
  ``dropped``.  Because the recorder is always-on it lives INSIDE the
  <2% p99 budget — the load_gen overhead gate re-verifies p99 <=1.02x
  with the recorder enabled.
* The :class:`~repro.obs.slo.SloMonitor` evaluates burn-rate windows
  over cumulative counters (deadline-miss rate, shed rate) plus gauge
  thresholds (queue depth, ring occupancy vs ``compact_highwater``) on
  the COLD path only (``report()``/``debugz()`` pulls); breaches emit
  edge-triggered events into the recorder's event ring.  Fleet epoch
  staleness is derived at the ``AidwCluster.debugz()`` merge point.
* :func:`~repro.obs.attribution.tail_attribution` decomposes the
  p99−p50 gap into per-stage contributions from the retained outliers
  (proportional to each additive stage's tail excess over its p50), with
  a stall block for ``session/compact_stall_s`` and
  ``serving/epoch_barrier_s`` — rendered as JSON and text
  (:func:`~repro.obs.attribution.render_attribution`).
"""

from .attribution import render_attribution, tail_attribution
from .metrics import Counter, Gauge, Histogram, Registry
from .recorder import FlightRecorder
from .slo import SloMonitor, fleet_epoch_events
from .trace import Span, Tracer, chrome_trace, fence, new_span_id

__all__ = ["Counter", "FlightRecorder", "Gauge", "Histogram", "Registry",
           "SloMonitor", "Span", "Tracer", "chrome_trace", "fence",
           "fleet_epoch_events", "new_span_id", "render_attribution",
           "tail_attribution"]
