"""Observability spine: end-to-end tracing + one metrics registry.

This package is the single instrumentation surface for the whole stack —
core session, async server, and the multi-host fleet all report through
it, so BENCH_*.json rows, ``server.report()``, the Prometheus endpoint,
and Chrome traces are different views of the SAME measurements.

Span taxonomy (names are stable API; tests and the stage bench key on
them):

* **session** — ``plan`` (one-time Stage-1 build) with sub-spans ``bin``
  (CSR binning) and ``staging`` (device upload of delta row patches);
  ``stage1`` (grid kNN search), ``stage2`` (weighted interpolation),
  ``compact`` (LSM ring fold-back).  ``query`` wraps a whole session
  query.
* **serving** (one per request, parented on the request's root span) —
  ``queue_wait`` (submit -> dispatch), ``coalesce`` (batch hold beyond
  the last member's arrival), ``execute`` (dispatch -> results
  materialized on host), ``scatter`` (slice results back per request).
* **fleet** — ``route`` (host pick + submit; a drain resubmission records
  a child ``resubmit`` span under the SAME trace), ``fanout`` (parallel
  shard rpc), ``phase1`` (shard kNN + k-way merge input), ``merge``
  (client-side k-way merge + alpha), ``phase2`` (partial-sum fan-out),
  and ``epoch_update``/``apply_epoch`` for the update barrier path.

Clock / fencing contract:

* Every :class:`~repro.obs.trace.Tracer` takes an **explicit clock** — the
  same clock its component stamps request timestamps with — so spans and
  latency histograms share an epoch, and fake-clock tests are exact.  A
  wall-clock anchor captured once at construction aligns exports across
  processes (pass ``wall=None`` under fake clocks).
* Spans that bracket device work close only after
  :func:`~repro.obs.trace.fence` (``jax.block_until_ready``) on the
  stage's outputs — stage walls stay honest on async dispatch backends.
* **Overhead budget**: with sampling off (``sample_rate=0``) the entire
  subsystem costs one ``None``-check per call site — enforced <2% on
  serving p99 by the ``serving/trace_overhead_p99_ratio`` load_gen gate.

Trace propagation: a sampled request carries ``trace_id``/``parent_span``
on ``InterpolationRequest``, across the JSON/TCP rpc control plane,
through ``EpochUpdate`` barriers and router drain-resubmission, so one
fleet query yields ONE connected cross-host trace.
:func:`~repro.obs.trace.chrome_trace` renders collected span dicts as
Chrome ``trace_event`` JSON (loads in ``chrome://tracing``/Perfetto).

Registry -> Prometheus naming: see :mod:`repro.obs.metrics` — internal
slash-namespaced names (``session/plan_s``) export as
``aidw_session_plan_s`` (counters ``_total``-suffixed, histograms
summary-style with ``quantile`` labels).
"""

from .metrics import Counter, Gauge, Histogram, Registry
from .trace import Span, Tracer, chrome_trace, fence, new_span_id

__all__ = ["Counter", "Gauge", "Histogram", "Registry",
           "Span", "Tracer", "chrome_trace", "fence", "new_span_id"]
