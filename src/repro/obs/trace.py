"""Low-overhead spans with explicit clocks, sampling, and Chrome export.

A :class:`Tracer` records :class:`Span` objects — named intervals tied to a
``trace_id`` (one request / one fleet query / one epoch update) and nested
via ``parent_id``.  The design rules that keep it honest and cheap:

* **Explicit clocks.**  Every tracer is constructed with the clock its
  component already uses (``time.monotonic`` for serving, ``perf_counter``
  for the session) so span timestamps share an epoch with the component's
  own latency bookkeeping, and tests can inject fake clocks.  A wall-clock
  anchor (``wall=time.time``, captured once at construction) shifts
  exported timestamps into a cross-process-comparable timebase so spans
  from different hosts line up in one Chrome trace; pass ``wall=None``
  under fake clocks to keep exports deterministic.
* **Retroactive recording.**  Hot paths that already stamp timestamps
  (``t_submit``/``t_dispatch``/``t_done`` on requests) call
  :meth:`Tracer.record` after the fact instead of holding a context
  manager open — tracing then adds zero work between the timestamps it
  reports.  :meth:`Tracer.span` is the context-manager form for
  code-bracketing spans (plan/compact/fleet phases).
* **Sampling decides at the root, once.**  :meth:`Tracer.new_trace`
  returns a fresh ``trace_id`` with probability ``sample_rate`` and
  ``None`` otherwise; every child call is a no-op when its ``trace_id`` is
  ``None``, so a disabled tracer (rate 0) costs one ``if`` per call site.
* **Device fencing.**  Spans that bracket device work must close only
  after the work is done: call :func:`fence` (``jax.block_until_ready``)
  on the stage's outputs before closing the span, otherwise async dispatch
  attributes a stage's cost to whoever synchronizes later.

Export formats: :meth:`Tracer.chrome_trace` emits Chrome ``trace_event``
JSON (complete ``"ph": "X"`` events, microsecond timestamps — loads in
``chrome://tracing`` and Perfetto); :meth:`Tracer.export_jsonl` writes one
span dict per line.  :func:`chrome_trace` converts span dicts collected
from many hosts into a single connected trace.
"""

from __future__ import annotations

import json
import random
import threading
import time
import uuid

__all__ = ["Span", "Tracer", "chrome_trace", "fence", "new_span_id"]


def fence(tree):
    """Block until every array in ``tree`` is computed; returns ``tree``.

    The stage-boundary fencing contract: a span that times device work
    closes after ``fence(outputs)`` so the wall covers the actual compute,
    not just dispatch.  Falls back to per-leaf ``block_until_ready`` when
    JAX is unavailable (the tracer itself never imports JAX at load time).
    """
    try:
        import jax
        return jax.block_until_ready(tree)
    except ImportError:                                   # pragma: no cover
        if hasattr(tree, "block_until_ready"):
            tree.block_until_ready()
        return tree


def _new_id() -> str:
    return uuid.uuid4().hex[:16]


def new_span_id() -> str:
    """A fresh span id, for callers that must hand a parent id to children
    BEFORE retroactively recording the parent itself (pass it back to
    :meth:`Tracer.record` via ``span_id=``) — e.g. the fleet router, whose
    root ``route`` span only closes after the host already holds the
    request."""
    return _new_id()


class Span:
    """One finished span: a named ``[t0, t0+dur]`` interval on a trace.

    ``t0`` is in the exporting tracer's (wall-anchored) clock, seconds;
    ``dur`` is seconds.  ``host`` labels the recording process (maps to
    the Chrome ``pid`` lane).
    """

    __slots__ = ("name", "trace_id", "span_id", "parent_id", "t0", "dur",
                 "host", "args")

    def __init__(self, name, trace_id, span_id, parent_id, t0, dur,
                 host="0", args=None):
        self.name = name
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.t0 = t0
        self.dur = dur
        self.host = host
        self.args = args

    def to_dict(self) -> dict:
        d = {"name": self.name, "trace_id": self.trace_id,
             "span_id": self.span_id, "parent_id": self.parent_id,
             "t0": self.t0, "dur": self.dur, "host": self.host}
        if self.args:
            d["args"] = self.args
        return d


class Tracer:
    """Thread-safe span recorder for ONE process/component.

    Parameters
    ----------
    clock: the component's monotonic clock (injectable for tests); all
        ``t0``/``t1`` arguments to :meth:`record` must be in this clock.
    wall: wall clock used ONCE at construction to anchor exports in a
        cross-process timebase (``None`` => no anchoring; exports stay in
        ``clock``'s epoch — use under fake clocks).
    sample_rate: probability that :meth:`new_trace` starts a sampled trace.
    host: process label for the Chrome ``pid`` lane (host id in a fleet).
    max_spans: retention cap; beyond it new spans are counted in
        ``dropped`` instead of stored (the trace log is a diagnostic ring,
        not an unbounded buffer).
    """

    def __init__(self, clock=time.monotonic, wall=time.time,
                 sample_rate: float = 1.0, host: str = "0",
                 max_spans: int = 100_000, seed=None):
        self.clock = clock
        self.sample_rate = float(sample_rate)
        self.host = str(host)
        self.max_spans = int(max_spans)
        self.dropped = 0
        self._offset = (wall() - clock()) if wall is not None else 0.0
        self._spans: list[Span] = []
        self._lock = threading.Lock()
        self._rng = random.Random(seed)

    # -- trace/span creation -------------------------------------------------

    def new_trace(self) -> str | None:
        """Sampling decision + root id: a fresh ``trace_id`` with
        probability ``sample_rate``, else ``None`` (the whole trace is
        then skipped at every layer for one ``if`` per call)."""
        if self.sample_rate <= 0.0:
            return None
        if self.sample_rate < 1.0 and self._rng.random() >= self.sample_rate:
            return None
        return _new_id()

    def record(self, name: str, t0: float, t1: float, *, trace_id,
               parent_id=None, span_id=None, args=None) -> str | None:
        """Retroactively record a finished span from ``clock``-domain
        timestamps.  No-op (returns ``None``) when ``trace_id`` is None —
        call sites need no sampling branch of their own."""
        if trace_id is None:
            return None
        sid = span_id or _new_id()
        span = Span(name, trace_id, sid, parent_id,
                    t0 + self._offset, max(t1 - t0, 0.0),
                    host=self.host, args=args)
        with self._lock:
            if len(self._spans) >= self.max_spans:
                self.dropped += 1
            else:
                self._spans.append(span)
        return sid

    def span(self, name: str, *, trace_id, parent_id=None, args=None):
        """Context manager bracketing a code span; yields a handle with
        ``trace_id``/``span_id`` for parenting children.  Device work
        inside must be fenced (:func:`fence`) before the block closes."""
        return _OpenSpan(self, name, trace_id, parent_id, args)

    # -- collection / export -------------------------------------------------

    def spans(self) -> list[dict]:
        """Copy of the recorded span dicts (oldest first)."""
        with self._lock:
            return [s.to_dict() for s in self._spans]

    def drain(self) -> list[dict]:
        """Return and clear the recorded spans (the rpc collection hook)."""
        with self._lock:
            out = [s.to_dict() for s in self._spans]
            self._spans.clear()
            return out

    def chrome_trace(self) -> dict:
        """This tracer's spans as a Chrome ``trace_event`` JSON object."""
        return chrome_trace(self.spans())

    def export_chrome(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)

    def export_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for d in self.spans():
                f.write(json.dumps(d) + "\n")


class _OpenSpan:
    """The object returned by :meth:`Tracer.span`.

    Usable as a context manager; when ``trace_id`` is None every method is
    a no-op and ``span_id`` stays None.
    """

    __slots__ = ("_tracer", "_name", "_parent", "_t0", "trace_id",
                 "span_id", "args")

    def __init__(self, tracer, name, trace_id, parent_id, args):
        self._tracer = tracer
        self._name = name
        self._parent = parent_id
        self._t0 = None
        self.trace_id = trace_id
        self.span_id = _new_id() if trace_id is not None else None
        self.args = dict(args) if args else None

    def __enter__(self):
        if self.trace_id is not None:
            self._t0 = self._tracer.clock()
        return self

    def __exit__(self, exc_type, exc, tb):
        if self.trace_id is not None:
            self._tracer.record(
                self._name, self._t0, self._tracer.clock(),
                trace_id=self.trace_id, parent_id=self._parent,
                span_id=self.span_id, args=self.args)
        return False


def chrome_trace(span_dicts) -> dict:
    """Convert span dicts (possibly gathered from many hosts) into one
    Chrome ``trace_event`` JSON object.

    Each span becomes a complete (``"ph": "X"``) event with microsecond
    ``ts``/``dur``; the recording host maps to ``pid`` so a fleet trace
    shows one lane per host, and trace/span/parent ids ride in ``args``
    for programmatic checks.  The result loads in ``chrome://tracing`` and
    Perfetto.
    """
    events = []
    for d in span_dicts:
        args = {"trace_id": d["trace_id"], "span_id": d["span_id"],
                "parent_span": d.get("parent_id")}
        if d.get("args"):
            args.update(d["args"])
        events.append({
            "name": d["name"], "cat": "aidw", "ph": "X",
            "ts": d["t0"] * 1e6, "dur": max(d["dur"], 0.0) * 1e6,
            "pid": f"host-{d.get('host', '0')}",
            "tid": f"host-{d.get('host', '0')}",
            "args": args,
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}
