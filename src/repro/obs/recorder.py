"""Always-on flight recorder: tail-sampling for the anomalies head-sampling
misses.

The :class:`~repro.obs.trace.Tracer` samples at the ROOT: with
``sample_rate=0`` (the production default, enforced by the <2% overhead
gate) the p99 stragglers, deadline misses, and sheds leave no trace at
all.  The :class:`FlightRecorder` inverts that: EVERY request records a
fixed-size coarse breakdown — queue_wait/coalesce/execute/scatter walls,
a handful of floats stamped from the timestamps the scheduler already
fenced — and the full span tree is retained only when the request turns
out to be *anomalous*:

* ``deadline_miss`` — served, but after its deadline;
* ``shed``          — deadline expired before dispatch (never served);
* ``overflow``      — >= 1 of its queries overflowed the kNN candidate
  window (Stage-1 certification);
* ``zero_weight``   — >= 1 of its queries hit the f32 weight-sum
  underflow sentinel;
* ``slow``          — total latency at/above the ``top_percentile`` of
  the recorder's OWN running histogram (armed only after ``min_window``
  observations; ``top_percentile=None`` disables the class).

Retention is deterministic under fake clocks: every decision is a pure
function of the injected clock and the request's stamped timestamps (span
ids derive from the request uid, never from ``uuid4``), so tests replay
bit-identical rings.  The ring is bounded (FIFO eviction, oldest record
first) with an explicit :attr:`dropped` counter — same honesty contract
as ``Tracer.max_spans``.

Overhead discipline mirrors the tracer's ``None``-check-when-off rule:
call sites guard with ``if recorder is not None``; when on, a per-request
observation costs five histogram records (a bisect each) plus a dict — no
allocation-heavy span objects unless the request is anomalous.
"""

from __future__ import annotations

import threading
import time
from collections import deque

from .metrics import Histogram

__all__ = ["FlightRecorder", "ANOMALY_CLASSES", "COARSE_STAGES"]

# classification vocabulary (stable API: slo/attribution/tests key on it)
ANOMALY_CLASSES = ("deadline_miss", "shed", "overflow", "zero_weight",
                   "slow")
# the additive coarse stages: queue_wait + execute == total by
# construction (coalesce overlaps queue_wait; scatter lands after t_done)
COARSE_STAGES = ("queue_wait", "coalesce", "execute", "scatter")


class FlightRecorder:
    """Per-request coarse accounting + anomaly-gated full-trace retention.

    ``clock`` is the SERVING clock the request timestamps are stamped with
    (the obs clock contract); ``wall`` anchors exported span timestamps
    across processes (pass ``wall=None`` under fake clocks — the anchor is
    captured ONCE at construction, exactly like ``Tracer``).  ``ring``
    bounds the retained-trace ring and ``event_ring`` the SLO-event ring;
    both evict FIFO and count evictions in :attr:`dropped` /
    :attr:`events_dropped`.
    """

    def __init__(self, *, clock=time.monotonic, wall=time.time,
                 host="0", ring: int = 256, event_ring: int = 256,
                 top_percentile: float | None = 99.0,
                 min_window: int = 64):
        self.clock = clock
        self._offset = (wall() - clock()) if wall is not None else 0.0
        self.host = str(host)
        self.ring = int(ring)
        self.event_ring = int(event_ring)
        self.top_percentile = top_percentile
        self.min_window = int(min_window)
        self.dropped = 0
        self.events_dropped = 0
        self.requests = 0
        self.anomalies = {c: 0 for c in ANOMALY_CLASSES}
        self._traces: deque = deque()
        self._events: deque = deque()
        # total + per-stage running histograms: the slow-class threshold
        # and the attribution report's p50 baselines both read these
        self._hists = {"total": Histogram()}
        for s in COARSE_STAGES:
            self._hists[s] = Histogram()
        # observe_request runs on the worker thread while observe_shed
        # arrives from client threads (shed-on-arrival) and state() from
        # diagnostics pullers
        self._lock = threading.Lock()

    # -- recording (hot path) ------------------------------------------------

    def observe_request(self, req, *, t0: float, t1: float, t2: float,
                        last_submit: float) -> str | None:
        """Fold one SERVED request in; returns the retained-record id when
        the request was anomalous (``None`` otherwise — the common case).

        Called from ``scheduler.scatter_batch`` after the execute fence,
        with the batch timestamps it already stamped: ``t0`` dispatch,
        ``t1`` results materialized on host, ``t2`` scatter done,
        ``last_submit`` the batch's newest member arrival.
        """
        t_sub = req.t_submit
        t_disp = req.t_dispatch if req.t_dispatch is not None else t0
        t_done = req.t_done if req.t_done is not None else t1
        if t_sub is None:
            t_sub = t_disp
        breakdown = {
            "queue_wait": max(t_disp - t_sub, 0.0),
            "coalesce": max(t0 - min(last_submit, t_disp), 0.0),
            "execute": max(t1 - t0, 0.0),
            "scatter": max(t2 - t1, 0.0),
            "total": max(t_done - t_sub, 0.0),
        }
        classes = []
        if req.deadline is not None and t_done > req.deadline:
            classes.append("deadline_miss")
        if req.overflow:
            classes.append("overflow")
        if getattr(req, "zero_weight", 0):
            classes.append("zero_weight")
        with self._lock:
            total_hist = self._hists["total"]
            # the slow decision reads the PRIOR window (this request's own
            # observation folds in below): deterministic, never
            # self-referential, armed only past min_window
            if self.top_percentile is not None \
                    and total_hist.count >= self.min_window \
                    and breakdown["total"] \
                    >= total_hist.percentile(self.top_percentile):
                classes.append("slow")
            self.requests += 1
            total_hist.record(breakdown["total"])
            for s in COARSE_STAGES:
                self._hists[s].record(breakdown[s])
            for c in classes:
                self.anomalies[c] += 1
            if not classes:
                return None
            return self._retain(req, classes, breakdown,
                                t_sub=t_sub, t_disp=t_disp, t0=t0, t1=t1,
                                t2=t2, last_submit=last_submit)

    def observe_shed(self, req) -> str | None:
        """Fold one SHED request in (terminal, never served).  Its
        time-to-shed is NOT recorded into the total histogram — shed
        requests terminate fast by construction, and folding them in would
        improve the percentile the more traffic is dropped (the same
        censoring rule ``serving.telemetry`` applies)."""
        t_sub = req.t_submit
        t_done = req.t_done if req.t_done is not None else self.clock()
        if t_sub is None:
            t_sub = t_done
        breakdown = {"queue_wait": max(t_done - t_sub, 0.0),
                     "total": max(t_done - t_sub, 0.0)}
        classes = ["shed"]
        if req.deadline is not None:     # a shed IS a missed deadline
            classes.append("deadline_miss")
        with self._lock:
            self.requests += 1
            for c in classes:
                self.anomalies[c] += 1
            return self._retain(req, classes, breakdown,
                                t_sub=t_sub, t_disp=None, t0=None, t1=None,
                                t2=None, last_submit=None)

    def event(self, kind: str, severity: str = "warning",
              data: dict | None = None) -> None:
        """Append one structured event (the SLO monitor's emission hook)
        to the bounded event ring."""
        ev = {"t_wall": self.clock() + self._offset, "kind": kind,
              "severity": severity, "host": self.host,
              "data": data or {}}
        with self._lock:
            self._events.append(ev)
            while len(self._events) > self.event_ring:
                self._events.popleft()
                self.events_dropped += 1

    # -- retention -----------------------------------------------------------

    def _retain(self, req, classes, breakdown, *, t_sub, t_disp, t0, t1,
                t2, last_submit) -> str:
        # lock already held.  Record id: join the request's sampled trace
        # when it has one (the histogram-exemplar link), else derive a
        # deterministic id from the uid
        rid = getattr(req, "trace_id", None) or f"req-{req.uid}"
        off = self._offset
        spans = [{"name": "request", "trace_id": rid,
                  "span_id": f"{rid}/r", "parent_id": None,
                  "t0": t_sub + off, "dur": breakdown["total"],
                  "host": self.host,
                  "args": {"uid": req.uid, "anomalies": list(classes)}}]

        def child(name, a, b, args=None):
            spans.append({"name": name, "trace_id": rid,
                          "span_id": f"{rid}/{name}",
                          "parent_id": f"{rid}/r", "t0": a + off,
                          "dur": max(b - a, 0.0), "host": self.host,
                          "args": args})

        if t_disp is not None:
            child("queue_wait", t_sub, t_disp)
            child("coalesce", min(last_submit, t_disp), t0)
            child("execute", t0, t1,
                  args={"overflow": int(req.overflow),
                        "zero_weight": int(getattr(req, "zero_weight", 0))})
            child("scatter", t1, t2)
        rec = {"id": rid, "uid": req.uid, "host": self.host,
               "anomalies": list(classes),
               "breakdown": {k: float(v) for k, v in breakdown.items()},
               "epoch": getattr(req, "epoch", None),
               "spans": spans}
        self._traces.append(rec)
        while len(self._traces) > self.ring:
            self._traces.popleft()           # FIFO: oldest record evicts
            self.dropped += 1
        return rid

    # -- reporting -----------------------------------------------------------

    def retained(self) -> list[dict]:
        """The retained anomaly records, oldest first (non-draining — a
        diagnostics pull must never mutate what the next pull sees)."""
        with self._lock:
            return list(self._traces)

    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def snapshot(self) -> dict:
        """Scalar counters (the ``report()['recorder']`` block)."""
        with self._lock:
            return {"requests": self.requests,
                    "retained": len(self._traces),
                    "dropped": self.dropped,
                    "events": len(self._events),
                    "events_dropped": self.events_dropped,
                    "anomalies": dict(self.anomalies)}

    def state(self) -> dict:
        """Full JSON-serializable state for the debugz bundle: counters,
        mergeable stage histograms, retained traces, and events —
        :func:`repro.obs.attribution.tail_attribution` consumes a list of
        these."""
        with self._lock:
            return {"host": self.host,
                    "requests": self.requests,
                    "dropped": self.dropped,
                    "events_dropped": self.events_dropped,
                    "anomalies": dict(self.anomalies),
                    "hists": {k: h.state() for k, h in self._hists.items()},
                    "traces": list(self._traces),
                    "events": list(self._events)}
