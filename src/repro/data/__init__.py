"""Deterministic sharded data pipelines (LM token streams + spatial points)."""
