"""Deterministic sharded synthetic data pipeline.

Every batch is a pure function of (step, host_index, n_hosts) — no files, no
coordination — which gives us exactly-once semantics across restarts and
elastic rescaling for free: after a failure, the restored step counter alone
reproduces the data stream, on any surviving topology.

The LM stream is a learnable arithmetic pattern (per-sequence random stride
and offset) so integration tests can assert the loss actually decreases; the
spatial generators reproduce the paper's testing protocol (uniform random
points in a square) plus a clustered variant for the kNN stress tests.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np


# ---------------------------------------------------------------------------
# LM token stream
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LMStreamConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


def lm_batch(cfg: LMStreamConfig, step: int, host_index: int = 0,
             n_hosts: int = 1) -> dict:
    """Host-local slice of the global batch for ``step``.

    tokens[i] = (offset + i * stride) % vocab — next-token-predictable from
    context, so training on it must drive the loss toward ~0.
    """
    assert cfg.global_batch % n_hosts == 0
    local_b = cfg.global_batch // n_hosts
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, host_index]))
    stride = rng.integers(1, 17, (local_b, 1))
    offset = rng.integers(0, cfg.vocab, (local_b, 1))
    idx = np.arange(cfg.seq_len + 1)[None, :]
    seq = (offset + idx * stride) % cfg.vocab
    return {
        "tokens": seq[:, :-1].astype(np.int32),
        "labels": seq[:, 1:].astype(np.int32),
    }


# ---------------------------------------------------------------------------
# spatial point streams (paper's testing data, §5.1)
# ---------------------------------------------------------------------------


def spatial_surface(x: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Smooth analytic terrain used as ground truth for accuracy checks."""
    return (np.sin(3.1 * x) * np.cos(2.3 * y)
            + 0.5 * np.sin(7.9 * x * y) + 0.1 * x - 0.2 * y)


def spatial_points(n: int, *, seed: int = 0, clustered: bool = False,
                   noise: float = 0.0) -> np.ndarray:
    """(n, 3) data points: x, y in the unit square (paper: random in a square),
    z from the analytic surface (+ optional noise)."""
    rng = np.random.default_rng(seed)
    if clustered:
        k = max(1, n // 500)
        centers = rng.random((k, 2))
        xy = centers[rng.integers(0, k, n)] + rng.normal(0, 0.02, (n, 2))
        xy = np.clip(xy, 0.0, 1.0)
    else:
        xy = rng.random((n, 2))
    z = spatial_surface(xy[:, 0], xy[:, 1])
    if noise:
        z = z + rng.normal(0, noise, n)
    return np.concatenate([xy, z[:, None]], axis=1).astype(np.float32)


def spatial_queries(n: int, *, seed: int = 1) -> np.ndarray:
    return np.random.default_rng(seed).random((n, 2)).astype(np.float32)


# ---------------------------------------------------------------------------
# prefetch
# ---------------------------------------------------------------------------


class Prefetcher:
    """Background-thread double buffering over any step->batch function."""

    def __init__(self, make_batch, start_step: int = 0, depth: int = 2):
        self._make = make_batch
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        while not self._stop.is_set():
            batch = self._make(self._step)
            self._step += 1
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def next(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
