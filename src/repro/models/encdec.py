"""Encoder-decoder LM (whisper-family backbone).

The audio frontend (log-mel + 2x conv downsample) is a STUB per the
assignment: ``enc_embeds`` arrive as precomputed frame embeddings
(B, enc_len, d_model).  Positions are sinusoidal (whisper's encoder scheme;
we substitute it for the decoder's learned embedding so parameters stay
independent of the assigned sequence shapes — recorded in DESIGN.md).

Decoder blocks: causal self-attention (KV-cached) + cross-attention over the
encoder output (cross-KV computed once at prefill) + MLP.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn import attention as attn_lib
from repro.nn import layers as L
from repro.nn.param import ParamDef

from .config import ModelConfig
from .lm import _attn_defs, _mlp_defs, _maybe_remat, _scan


def sinusoid_pos(s: int, d: int, offset=0) -> jax.Array:
    pos = (jnp.arange(s, dtype=jnp.float32) + offset)[:, None]
    half = d // 2
    freq = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (jnp.log(10000.0) / max(half - 1, 1)))
    ang = pos * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _dec_layer_defs(cfg: ModelConfig) -> dict:
    D, dt = cfg.d_model, cfg.dtype
    return {
        "ln1": ParamDef((D,), (None,), "ones", dt),
        "self_attn": _attn_defs(cfg),
        "lnx": ParamDef((D,), (None,), "ones", dt),
        "cross_attn": _attn_defs(cfg),
        "ln2": ParamDef((D,), (None,), "ones", dt),
        "mlp": _mlp_defs(cfg, cfg.d_ff),
    }


def _enc_layer_defs(cfg: ModelConfig) -> dict:
    D, dt = cfg.d_model, cfg.dtype
    return {
        "ln1": ParamDef((D,), (None,), "ones", dt),
        "attn": _attn_defs(cfg),
        "ln2": ParamDef((D,), (None,), "ones", dt),
        "mlp": _mlp_defs(cfg, cfg.d_ff),
    }


def param_defs(cfg: ModelConfig) -> dict:
    from .lm import _stack

    D, V, dt = cfg.d_model, cfg.vocab, cfg.dtype
    defs = {
        "embed": ParamDef((V, D), ("vocab", "embed"), "normal", dt),
        "enc_layers": _stack(_enc_layer_defs(cfg), cfg.n_enc_layers),
        "enc_norm": ParamDef((D,), (None,), "ones", dt),
        "dec_layers": _stack(_dec_layer_defs(cfg), cfg.n_layers),
        "final_norm": ParamDef((D,), (None,), "ones", dt),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((V, D), ("vocab", "embed"), "normal", dt)
    return defs


def _proj_kv(p, x):
    return L.dense(x, p["wk"]), L.dense(x, p["wv"])


def _attend(p, x, k, v, *, cfg, q_pos, k_pos, k_valid, causal):
    q = L.dense(x, p["wq"])
    out = attn_lib.gqa_attention(q, k, v, q_pos=q_pos, k_pos=k_pos,
                                 k_valid=k_valid, causal=causal,
                                 q_chunk=cfg.q_chunk)
    B, S = x.shape[:2]
    return L.dense(out.reshape(B, S, -1), p["wo"].reshape(-1, cfg.d_model))


def encode(params, cfg: ModelConfig, enc_embeds: jax.Array) -> jax.Array:
    B, S, D = enc_embeds.shape
    x = enc_embeds.astype(cfg.dtype) + sinusoid_pos(S, D).astype(cfg.dtype)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    valid = jnp.ones((B, S), bool)

    def body(h, p):
        n1 = L.rms_norm(h, p["ln1"], cfg.norm_eps)
        k, v = _proj_kv(p["attn"], n1)
        h = h + _attend(p["attn"], n1, k, v, cfg=cfg, q_pos=pos, k_pos=pos,
                        k_valid=valid, causal=False)
        n2 = L.rms_norm(h, p["ln2"], cfg.norm_eps)
        return h + L.swiglu(n2, p["mlp"]["wg"], p["mlp"]["wu"], p["mlp"]["wd"]), None

    x, _ = _scan(_maybe_remat(body, cfg), cfg, x, params["enc_layers"])
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _run_decoder(params, cfg, x, enc_out, *, q_pos, k_pos, k_valid, mode,
                 cache=None, write_pos=None):
    B = x.shape[0]
    Se = enc_out.shape[1]
    e_pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))
    e_valid = jnp.ones((B, Se), bool)

    def body(h, xs):
        p = xs["p"]
        n1 = L.rms_norm(h, p["ln1"], cfg.norm_eps)
        if mode == "decode":
            kn, vn = _proj_kv(p["self_attn"], n1)
            k, v = attn_lib.update_cache(xs["k"], xs["v"], kn, vn, write_pos)
            ck, cv = xs["ck"], xs["cv"]
            ys = {"k": k, "v": v, "ck": ck, "cv": cv}
        else:
            k, v = _proj_kv(p["self_attn"], n1)
            ck, cv = _proj_kv(p["cross_attn"], enc_out)
            ys = {"k": k, "v": v, "ck": ck, "cv": cv} if mode == "prefill" else None
        h = h + _attend(p["self_attn"], n1, k, v, cfg=cfg, q_pos=q_pos,
                        k_pos=k_pos, k_valid=k_valid, causal=True)
        nx = L.rms_norm(h, p["lnx"], cfg.norm_eps)
        h = h + _attend(p["cross_attn"], nx, ck, cv, cfg=cfg, q_pos=q_pos,
                        k_pos=e_pos, k_valid=e_valid, causal=False)
        n2 = L.rms_norm(h, p["ln2"], cfg.norm_eps)
        return h + L.swiglu(n2, p["mlp"]["wg"], p["mlp"]["wu"], p["mlp"]["wd"]), ys

    xs = {"p": params["dec_layers"]}
    if mode == "decode":
        xs.update(cache)
    x, ys = _scan(_maybe_remat(body, cfg), cfg, x, xs)
    return x, ys


def _dec_logits(params, cfg, x):
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return L.unembed(x, table)


def loss(params, cfg: ModelConfig, batch) -> jax.Array:
    enc_out = encode(params, cfg, batch["enc_embeds"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.embed(tokens, params["embed"]) + sinusoid_pos(S, cfg.d_model).astype(cfg.dtype)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    valid = jnp.ones((B, S), bool)
    x, _ = _run_decoder(params, cfg, x, enc_out, q_pos=pos, k_pos=pos,
                        k_valid=valid, mode="train")
    logits = _dec_logits(params, cfg, x)
    labels = batch["labels"]
    return L.softmax_cross_entropy(logits, jnp.maximum(labels, 0), labels >= 0)


def prefill(params, cfg: ModelConfig, batch):
    enc_out = encode(params, cfg, batch["enc_embeds"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.embed(tokens, params["embed"]) + sinusoid_pos(S, cfg.d_model).astype(cfg.dtype)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    valid = jnp.ones((B, S), bool)
    x, cache = _run_decoder(params, cfg, x, enc_out, q_pos=pos, k_pos=pos,
                            k_valid=valid, mode="prefill")
    return _dec_logits(params, cfg, x[:, -1:])[:, 0], cache


def decode_step(params, cfg: ModelConfig, cache, batch):
    tokens = batch["tokens"]                                   # (B, 1)
    B = tokens.shape[0]
    pos = batch["pos"].astype(jnp.int32)
    x = L.embed(tokens, params["embed"]) + \
        sinusoid_pos(1, cfg.d_model, offset=pos).astype(cfg.dtype)
    q_pos = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
    Smax = cache["k"].shape[2]
    k_pos = jnp.broadcast_to(jnp.arange(Smax, dtype=jnp.int32), (B, Smax))
    k_valid = k_pos <= pos
    enc_stub = cache["ck"][0]  # (B, Se, Hkv, dh) — only shape matters downstream
    x, new_cache = _run_decoder(
        params, cfg, x, jnp.zeros((B, enc_stub.shape[1], cfg.d_model), cfg.dtype),
        q_pos=q_pos, k_pos=k_pos, k_valid=k_valid, mode="decode",
        cache=cache, write_pos=pos)
    return _dec_logits(params, cfg, x)[:, 0], new_cache


def cache_defs(cfg: ModelConfig, batch: int, s_max: int) -> dict:
    dt = cfg.dtype
    kv = (cfg.n_layers, batch, s_max, cfg.n_kv_heads, cfg.head_dim)
    ckv = (cfg.n_layers, batch, cfg.enc_len, cfg.n_kv_heads, cfg.head_dim)
    ax = ("layers", "batch", "kv_seq", "kv_heads", None)
    return {
        "k": ParamDef(kv, ax, "zeros", dt),
        "v": ParamDef(kv, ax, "zeros", dt),
        "ck": ParamDef(ckv, ax, "zeros", dt),
        "cv": ParamDef(ckv, ax, "zeros", dt),
    }
