"""Logical-axis -> mesh-axis sharding rules (GSPMD via NamedSharding).

Scheme (MaxText-style FSDP + tensor parallelism):

* ``model`` mesh axis: tensor parallel — attention heads, FFN hidden, vocab,
  experts (expert parallelism), Mamba inner channels.
* ``data`` mesh axis: batch parallel AND fully-sharded parameters (the other
  dim of every weight matrix is sharded over ``data`` — ZeRO-3-like; XLA
  inserts the per-layer all-gathers).
* ``pod`` mesh axis (multi-pod): pure data parallelism — parameters are
  replicated across pods, so the only cross-pod (DCN-class) collective is the
  gradient all-reduce.  Batch shards over ``(pod, data)``.

Any mapping whose dimension does not divide the mesh-axis product is dropped
to replication by ``make_shardings`` (e.g. 8 KV heads over 16-way model
parallelism -> replicated KV projections, the standard GQA duplication).

For single-sample long-context decode (long_500k) the batch axis is
unshardable; rules shift the KV/SSM cache sequence axis onto ``data``
(context parallelism) instead.
"""

from __future__ import annotations

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .config import ModelConfig

TENSOR_AXIS = "model"
FSDP_AXIS = "data"


def batch_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a != TENSOR_AXIS)


def batch_size_divisor(mesh: Mesh) -> int:
    from math import prod
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return prod(sizes[a] for a in batch_axes(mesh))


def param_rules(mesh: Mesh) -> dict:
    """Logical axis -> mesh axis mapping for parameters."""
    return {
        "vocab": TENSOR_AXIS,
        "embed": FSDP_AXIS,
        "heads": TENSOR_AXIS,
        "kv_heads": TENSOR_AXIS,
        "ffn": TENSOR_AXIS,
        "expert": TENSOR_AXIS,
        "inner": TENSOR_AXIS,
        "ssm_heads": TENSOR_AXIS,
        "layers": None,
    }


def cache_rules(mesh: Mesh, cfg: ModelConfig, batch: int) -> dict:
    """Rules for decode caches; context-parallel fallback for tiny batches."""
    rules = dict(param_rules(mesh))
    b_axes = batch_axes(mesh)
    if batch % batch_size_divisor(mesh) == 0:
        rules.update({"batch": b_axes, "kv_seq": None})
    else:
        # long-context single-sample decode: shard the sequence instead
        rules.update({"batch": None, "kv_seq": FSDP_AXIS})
    return rules


def data_specs(mesh: Mesh, cfg: ModelConfig, batch_shapes: dict) -> dict:
    """PartitionSpec per input-batch entry (tokens/labels/vis_embeds/pos)."""
    b_axes = batch_axes(mesh)
    out = {}
    for name, sds in batch_shapes.items():
        if name == "pos":
            out[name] = P()
            continue
        b = sds.shape[0]
        lead = b_axes if b % batch_size_divisor(mesh) == 0 else None
        out[name] = P(lead, *([None] * (len(sds.shape) - 1)))
    return out


def shard_batch(mesh: Mesh, specs: dict):
    return {k: NamedSharding(mesh, v) for k, v in specs.items()}
