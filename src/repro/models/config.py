"""Unified model configuration covering all assigned architecture families."""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    vocab: int
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 0              # 0 -> d_model // n_heads
    rope_theta: float = 10000.0
    parallel_residual: bool = False   # command-r style fused attn+FFN block
    # dense MLP
    d_ff: int = 0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0            # per-expert hidden width
    n_shared_experts: int = 0    # llama4-scout shared expert
    capacity_factor: float = 1.25
    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    d_conv: int = 4
    ssm_chunk: int = 256
    # hybrid (zamba2): one SHARED attention block applied every attn_every layers
    attn_every: int = 0
    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_len: int = 1500          # whisper 30 s of frames (stubbed frontend)
    # vlm: prepended precomputed patch embeddings (stubbed frontend)
    n_vis_tokens: int = 0
    # numerics / compute shape
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: Any = jnp.bfloat16
    q_chunk: int = 512           # query chunking for exact blocked attention
    remat: bool = True           # checkpoint each layer under scan
    unroll_layers: bool = False  # fully unroll layer scans (dry-run probes)
    # --- distribution/perf knobs (§Perf hillclimb) ---
    act_spec: tuple | None = None   # PartitionSpec entries for the residual
                                    # stream, e.g. (("pod","data"),"model",None)
                                    # = Megatron-style sequence sharding
    loss_chunk: int = 0             # CE loss in sequence chunks (logit memory)
    moe_spec: tuple | None = None   # (E,C,D) dispatch-buffer constraint, e.g.
                                    # ("model", None, None) = expert parallel
    moe_impl: str = "pjit"          # "pjit" | "ep" (shard_map expert parallel)
    notes: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // max(self.n_heads, 1))

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def uses_attention(self) -> bool:
        return self.family not in ("ssm",)

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM state instead of full-attention prefill)."""
        return self.family in ("ssm", "hybrid")

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (for 6*N*D roofline bookkeeping)."""
        D, dh = self.d_model, self.head_dim
        emb = self.vocab * D * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "audio"):
            attn = D * (self.n_heads + 2 * self.n_kv_heads) * dh + self.n_heads * dh * D
            if self.is_moe:
                mlp = self.n_experts * 3 * D * self.moe_d_ff + D * self.n_experts
                mlp += self.n_shared_experts * 3 * D * self.d_ff
            else:
                mlp = 3 * D * self.d_ff
            per_layer = attn + mlp + (D if self.parallel_residual else 2 * D)
        elif self.family in ("ssm", "hybrid"):
            d_inner = self.ssm_expand * D
            n_h = d_inner // self.ssm_head_dim
            gn = self.ssm_groups * self.ssm_state
            d_in_proj = 2 * d_inner + 2 * gn + n_h
            conv_ch = d_inner + 2 * gn
            per_layer = D * d_in_proj + d_inner * D + d_inner + 3 * n_h \
                + (self.d_conv + 1) * conv_ch + D
        n = emb + self.n_layers * per_layer + D  # + final norm
        if self.family == "hybrid" and self.attn_every:
            attn = D * (self.n_heads + 2 * self.n_kv_heads) * dh + self.n_heads * dh * D
            n += attn + 3 * D * self.d_ff + 2 * D  # one shared block
        if self.enc_dec:
            attn = D * (self.n_heads + 2 * self.n_kv_heads) * dh + self.n_heads * dh * D
            enc_layer = attn + 3 * D * self.d_ff + 2 * D
            dec_extra = attn + D  # cross-attention + norm
            n += self.n_enc_layers * enc_layer + self.n_layers * dec_extra + D  # + enc_norm
        return n

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k of n_experts)."""
        if not self.is_moe:
            return self.param_count()
        D = self.d_model
        full = self.param_count()
        moe_all = self.n_layers * self.n_experts * 3 * D * self.moe_d_ff
        moe_active = self.n_layers * self.top_k * 3 * D * self.moe_d_ff
        return full - moe_all + moe_active
