"""Model zoo: decoder-only LMs (dense/MoE/SSM/hybrid/VLM) + whisper enc-dec."""
