"""Decoder-only language models: dense GQA, MoE, Mamba2/SSD, hybrid, VLM.

One implementation, configuration-selected blocks, three entry points:

* ``loss(params, batch)``            — training objective (next-token CE)
* ``prefill(params, batch)``         — build the KV/SSM cache, last logits
* ``decode_step(params, cache, batch)`` — one token with a full cache

Layers are stacked (leading ``L`` dim) and driven by ``lax.scan`` so the HLO
is O(1) in depth (compile time matters at 512 devices), with optional
``jax.checkpoint`` per layer.  Hybrid (zamba2-style) models scan groups of
``attn_every`` Mamba layers and interleave ONE shared attention block.
"""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.nn import attention as attn_lib
from repro.nn import layers as L
from repro.nn import moe as moe_lib
from repro.nn import ssm as ssm_lib
from repro.nn.param import ParamDef

from .config import ModelConfig

# ---------------------------------------------------------------------------
# parameter definitions
# ---------------------------------------------------------------------------


def _stack(defs, n: int, axis_name: str = "layers"):
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, (axis_name,) + d.logical, d.init, d.dtype),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def _attn_defs(cfg: ModelConfig) -> dict:
    D, dh = cfg.d_model, cfg.head_dim
    dt = cfg.dtype
    return {
        "wq": ParamDef((D, cfg.n_heads, dh), ("embed", "heads", None), "scaled", dt),
        "wk": ParamDef((D, cfg.n_kv_heads, dh), ("embed", "kv_heads", None), "scaled", dt),
        "wv": ParamDef((D, cfg.n_kv_heads, dh), ("embed", "kv_heads", None), "scaled", dt),
        "wo": ParamDef((cfg.n_heads, dh, D), ("heads", None, "embed"), "scaled", dt),
    }


def _mlp_defs(cfg: ModelConfig, d_ff: int) -> dict:
    D, dt = cfg.d_model, cfg.dtype
    return {
        "wg": ParamDef((D, d_ff), ("embed", "ffn"), "scaled", dt),
        "wu": ParamDef((D, d_ff), ("embed", "ffn"), "scaled", dt),
        "wd": ParamDef((d_ff, D), ("ffn", "embed"), "scaled", dt),
    }


def _moe_defs(cfg: ModelConfig) -> dict:
    D, E, Fe, dt = cfg.d_model, cfg.n_experts, cfg.moe_d_ff, cfg.dtype
    defs = {
        "wr": ParamDef((D, E), ("embed", None), "scaled", jnp.float32),
        "weg": ParamDef((E, D, Fe), ("expert", "embed", None), "scaled", dt),
        "weu": ParamDef((E, D, Fe), ("expert", "embed", None), "scaled", dt),
        "wed": ParamDef((E, Fe, D), ("expert", None, "embed"), "scaled", dt),
    }
    if cfg.n_shared_experts:
        defs["shared"] = _mlp_defs(cfg, cfg.d_ff)
    return defs


def _dense_layer_defs(cfg: ModelConfig) -> dict:
    D, dt = cfg.d_model, cfg.dtype
    defs = {"ln1": ParamDef((D,), (None,), "ones", dt), "attn": _attn_defs(cfg)}
    if not cfg.parallel_residual:
        defs["ln2"] = ParamDef((D,), (None,), "ones", dt)
    defs["mlp"] = _moe_defs(cfg) if cfg.is_moe else _mlp_defs(cfg, cfg.d_ff)
    return defs


def _mamba_layer_defs(cfg: ModelConfig) -> dict:
    D, dt = cfg.d_model, cfg.dtype
    dims = ssm_dims(cfg)
    return {
        "ln": ParamDef((D,), (None,), "ones", dt),
        "w_in": ParamDef((D, dims.d_in_proj), ("embed", "inner"), "scaled", dt),
        "conv_w": ParamDef((dims.d_conv, dims.conv_ch), (None, "inner"), "scaled", dt),
        "conv_b": ParamDef((dims.conv_ch,), ("inner",), "zeros", dt),
        "A_log": ParamDef((dims.n_heads,), (None,), "zeros", jnp.float32),
        "dt_bias": ParamDef((dims.n_heads,), (None,), "zeros", jnp.float32),
        "D": ParamDef((dims.n_heads,), (None,), "ones", jnp.float32),
        "norm": ParamDef((dims.d_inner,), ("inner",), "ones", dt),
        "w_out": ParamDef((dims.d_inner, D), ("inner", "embed"), "scaled", dt),
    }


def ssm_dims(cfg: ModelConfig) -> ssm_lib.SsmDims:
    d_inner = cfg.ssm_expand * cfg.d_model
    return ssm_lib.SsmDims(
        d_model=cfg.d_model, d_inner=d_inner,
        n_heads=d_inner // cfg.ssm_head_dim, head_dim=cfg.ssm_head_dim,
        d_state=cfg.ssm_state, n_groups=cfg.ssm_groups, d_conv=cfg.d_conv)


def param_defs(cfg: ModelConfig) -> dict:
    D, V, dt = cfg.d_model, cfg.vocab, cfg.dtype
    defs: dict[str, Any] = {
        "embed": ParamDef((V, D), ("vocab", "embed"), "normal", dt),
        "final_norm": ParamDef((D,), (None,), "ones", dt),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((V, D), ("vocab", "embed"), "normal", dt)

    if cfg.family in ("dense", "moe", "vlm"):
        defs["layers"] = _stack(_dense_layer_defs(cfg), cfg.n_layers)
    elif cfg.family == "ssm":
        defs["layers"] = _stack(_mamba_layer_defs(cfg), cfg.n_layers)
    elif cfg.family == "hybrid":
        defs["layers"] = _stack(_mamba_layer_defs(cfg), cfg.n_layers)
        defs["shared_attn"] = {
            "ln1": ParamDef((D,), (None,), "ones", dt),
            "attn": _attn_defs(cfg),
            "ln2": ParamDef((D,), (None,), "ones", dt),
            "mlp": _mlp_defs(cfg, cfg.d_ff),
        }
    else:
        raise ValueError(cfg.family)
    return defs


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def _attn_apply(p, x, *, cfg: ModelConfig, q_pos, k, v, k_pos, k_valid):
    """Project queries from x; attend over provided k/v (B, Sk, Hkv, dh)."""
    q = L.dense(x, p["wq"])                                  # (B,S,H,dh)
    q = L.apply_rope(q, q_pos, cfg.rope_theta)
    out = attn_lib.gqa_attention(
        q, k, v, q_pos=q_pos, k_pos=k_pos, k_valid=k_valid,
        causal=True, q_chunk=cfg.q_chunk)
    B, S = x.shape[:2]
    return L.dense(out.reshape(B, S, -1), p["wo"].reshape(-1, cfg.d_model))


def _project_kv(p, x, *, cfg: ModelConfig, pos):
    k = L.dense(x, p["wk"])                                  # (B,S,Hkv,dh)
    k = L.apply_rope(k, pos, cfg.rope_theta)
    v = L.dense(x, p["wv"])
    return k, v


def _mlp_apply(p, x):
    return L.swiglu(x, p["wg"], p["wu"], p["wd"])


def _moe_apply(p, x, cfg: ModelConfig):
    if cfg.moe_impl == "ep":
        out = moe_lib.moe_apply_ep(
            x, p["wr"], p["weg"], p["weu"], p["wed"],
            top_k=cfg.top_k, capacity_factor=cfg.capacity_factor)
    else:
        out = moe_lib.moe_apply(
            x, p["wr"], p["weg"], p["weu"], p["wed"],
            top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
            buf_spec=cfg.moe_spec)
    if cfg.n_shared_experts:
        out = out + _mlp_apply(p["shared"], x)
    return out


def _dense_block(p, x, *, cfg: ModelConfig, q_pos, kv, k_pos, k_valid,
                 new_kv=None):
    """One transformer block.  kv = (k_full, v_full) to attend over.

    Sub-block outputs are constrained to ``cfg.act_spec`` so tensor-parallel
    partial-sum reductions compile to reduce-scatters into the (sequence-)
    sharded residual layout instead of full all-reduces (§Perf iteration 4).
    """
    n1 = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    a = _constrain(_attn_apply(p["attn"], n1, cfg=cfg, q_pos=q_pos,
                               k=kv[0], v=kv[1], k_pos=k_pos, k_valid=k_valid),
                   cfg)
    if cfg.parallel_residual:
        m = _moe_apply(p["mlp"], n1, cfg) if cfg.is_moe else _mlp_apply(p["mlp"], n1)
        return x + a + _constrain(m, cfg)
    h = x + a
    n2 = L.rms_norm(h, p["ln2"], cfg.norm_eps)
    m = _moe_apply(p["mlp"], n2, cfg) if cfg.is_moe else _mlp_apply(p["mlp"], n2)
    return h + _constrain(m, cfg)


def _mamba_block(p, x, *, cfg: ModelConfig, conv_state=None, ssm_state=None,
                 decode=False):
    n = L.rms_norm(x, p["ln"], cfg.norm_eps)
    out, new_state = ssm_lib.mamba_block(
        p, n, ssm_dims(cfg), chunk=cfg.ssm_chunk,
        conv_state=conv_state, ssm_state=ssm_state, decode=decode)
    return x + out, new_state


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------


def _embed_inputs(params, cfg: ModelConfig, batch):
    """Token (+ stubbed vision) embeddings; returns (x, loss_mask_extra)."""
    x = L.embed(batch["tokens"], params["embed"])
    if cfg.family == "vlm":
        vis = batch["vis_embeds"].astype(x.dtype)            # (B, Nv, D) stub
        x = jnp.concatenate([vis, x], axis=1)
    return x


def _logits(params, cfg: ModelConfig, x):
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return L.unembed(x, table)                               # (B, S, V) f32


# ---------------------------------------------------------------------------
# layer-stack drivers (scan over stacked params)
# ---------------------------------------------------------------------------


def _constrain(x, cfg: ModelConfig):
    """Residual-stream sharding constraint (§Perf knob; no-op without a mesh
    context or when cfg.act_spec is None)."""
    if cfg.act_spec is None:
        return x
    from jax.sharding import PartitionSpec as P
    try:
        return jax.lax.with_sharding_constraint(x, P(*cfg.act_spec))
    except (ValueError, RuntimeError):  # no mesh (CPU unit tests)
        return x


def _maybe_remat(fn, cfg: ModelConfig):
    return jax.checkpoint(fn) if cfg.remat else fn


def _scan(fn, cfg: ModelConfig, init, xs):
    return jax.lax.scan(fn, init, xs, unroll=True if cfg.unroll_layers else 1)


def _run_dense_stack(params, cfg, x, *, q_pos, k_pos, k_valid, mode,
                     cache=None, write_pos=None):
    """mode: train | prefill | decode."""

    def body(h, xs):
        p = xs["p"]
        if mode == "train":
            k, v = _project_kv(p["attn"], L.rms_norm(h, p["ln1"], cfg.norm_eps),
                               cfg=cfg, pos=q_pos)
            h = _dense_block(p, h, cfg=cfg, q_pos=q_pos, kv=(k, v),
                             k_pos=k_pos, k_valid=k_valid)
            return _constrain(h, cfg), None
        if mode == "prefill":
            k, v = _project_kv(p["attn"], L.rms_norm(h, p["ln1"], cfg.norm_eps),
                               cfg=cfg, pos=q_pos)
            h = _dense_block(p, h, cfg=cfg, q_pos=q_pos, kv=(k, v),
                             k_pos=k_pos, k_valid=k_valid)
            return _constrain(h, cfg), {"k": k, "v": v}
        # decode: insert this token's k/v into the cache slice
        ck, cv = xs["k"], xs["v"]
        kn, vn = _project_kv(p["attn"], L.rms_norm(h, p["ln1"], cfg.norm_eps),
                             cfg=cfg, pos=q_pos)
        ck, cv = attn_lib.update_cache(ck, cv, kn, vn, write_pos)
        h = _dense_block(p, h, cfg=cfg, q_pos=q_pos, kv=(ck, cv),
                         k_pos=k_pos, k_valid=k_valid)
        return _constrain(h, cfg), {"k": ck, "v": cv}

    xs = {"p": params["layers"]}
    if mode == "decode":
        xs.update(cache)
    x, ys = _scan(_maybe_remat(body, cfg), cfg, x, xs)
    return x, ys


def _run_mamba_stack(params, cfg, x, *, mode, cache=None):
    def body(h, xs):
        p = xs["p"]
        if mode == "train":
            h, _ = _mamba_block(p, h, cfg=cfg)
            return h, None
        conv = xs["conv"] if mode == "decode" else None
        ssm = xs["ssm"] if mode == "decode" else None
        h, (conv_n, ssm_n) = _mamba_block(p, h, cfg=cfg, conv_state=conv,
                                          ssm_state=ssm, decode=(mode == "decode"))
        return _constrain(h, cfg), {"conv": conv_n, "ssm": ssm_n}

    xs = {"p": params["layers"]}
    if mode == "decode":
        xs.update(cache)
    x, ys = _scan(_maybe_remat(body, cfg), cfg, x, xs)
    return x, ys


def _run_hybrid_stack(params, cfg, x, *, q_pos, k_pos, k_valid, mode,
                      cache=None, write_pos=None):
    """Groups of ``attn_every`` mamba layers + ONE shared attention block."""
    every = cfg.attn_every
    n_groups = cfg.n_layers // every
    shared = params["shared_attn"]

    grouped_layers = jax.tree.map(
        lambda a: a.reshape((n_groups, every) + a.shape[1:]), params["layers"])

    def shared_block(h, kv_slice):
        n1 = L.rms_norm(h, shared["ln1"], cfg.norm_eps)
        if mode == "train":
            k, v = _project_kv(shared["attn"], n1, cfg=cfg, pos=q_pos)
            ys = None
        elif mode == "prefill":
            k, v = _project_kv(shared["attn"], n1, cfg=cfg, pos=q_pos)
            ys = {"k": k, "v": v}
        else:
            kn, vn = _project_kv(shared["attn"], n1, cfg=cfg, pos=q_pos)
            k, v = attn_lib.update_cache(kv_slice["k"], kv_slice["v"],
                                         kn, vn, write_pos)
            ys = {"k": k, "v": v}
        a = _attn_apply(shared["attn"], n1, cfg=cfg, q_pos=q_pos, k=k, v=v,
                        k_pos=k_pos, k_valid=k_valid)
        h = h + a
        n2 = L.rms_norm(h, shared["ln2"], cfg.norm_eps)
        return _constrain(h + _mlp_apply(shared["mlp"], n2), cfg), ys

    def group_body(h, xs):
        def inner(hh, xs_in):
            p = xs_in["p"]
            if mode == "decode":
                hh, (cn, sn) = _mamba_block(p, hh, cfg=cfg,
                                            conv_state=xs_in["conv"],
                                            ssm_state=xs_in["ssm"], decode=True)
                return hh, {"conv": cn, "ssm": sn}
            hh, st = _mamba_block(p, hh, cfg=cfg)
            hh = _constrain(hh, cfg)
            if mode == "prefill":
                return hh, {"conv": st[0], "ssm": st[1]}
            return hh, None

        inner_xs = {"p": xs["p"]}
        if mode == "decode":
            inner_xs.update({"conv": xs["conv"], "ssm": xs["ssm"]})
        h, inner_ys = _scan(_maybe_remat(inner, cfg), cfg, h, inner_xs)
        kv_slice = {"k": xs["k"], "v": xs["v"]} if mode == "decode" else None
        h, attn_ys = shared_block(h, kv_slice)
        return h, (inner_ys, attn_ys)

    xs = {"p": grouped_layers}
    if mode == "decode":
        xs["conv"] = cache["conv"].reshape((n_groups, every) + cache["conv"].shape[1:])
        xs["ssm"] = cache["ssm"].reshape((n_groups, every) + cache["ssm"].shape[1:])
        xs["k"], xs["v"] = cache["k"], cache["v"]
    x, (inner_ys, attn_ys) = _scan(group_body, cfg, x, xs)

    new_cache = None
    if mode != "train":
        flat = lambda a: a.reshape((cfg.n_layers,) + a.shape[2:])
        new_cache = {"conv": flat(inner_ys["conv"]), "ssm": flat(inner_ys["ssm"]),
                     "k": attn_ys["k"], "v": attn_ys["v"]}
    return x, new_cache


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


def forward(params, cfg: ModelConfig, batch) -> jax.Array:
    """Causal logits over the (vision+)token sequence — train-time path."""
    x = _embed_inputs(params, cfg, batch)
    B, S = x.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    valid = jnp.ones((B, S), bool)
    if cfg.family in ("dense", "moe", "vlm"):
        x, _ = _run_dense_stack(params, cfg, x, q_pos=pos, k_pos=pos,
                                k_valid=valid, mode="train")
    elif cfg.family == "ssm":
        x, _ = _run_mamba_stack(params, cfg, x, mode="train")
    else:
        x, _ = _run_hybrid_stack(params, cfg, x, q_pos=pos, k_pos=pos,
                                 k_valid=valid, mode="train")
    return _logits(params, cfg, x)


def _hidden(params, cfg: ModelConfig, batch) -> jax.Array:
    x = _embed_inputs(params, cfg, batch)
    B, S = x.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    valid = jnp.ones((B, S), bool)
    if cfg.family in ("dense", "moe", "vlm"):
        x, _ = _run_dense_stack(params, cfg, x, q_pos=pos, k_pos=pos,
                                k_valid=valid, mode="train")
    elif cfg.family == "ssm":
        x, _ = _run_mamba_stack(params, cfg, x, mode="train")
    else:
        x, _ = _run_hybrid_stack(params, cfg, x, q_pos=pos, k_pos=pos,
                                 k_valid=valid, mode="train")
    return x


def _chunked_ce(params, cfg: ModelConfig, x, labels, mask):
    """CE over sequence chunks: the (B, C, V) logits exist one chunk at a
    time and are rematerialized in backward (§Perf: logits memory knob)."""
    C = cfg.loss_chunk
    B, S = labels.shape
    pad = (-S) % C
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = (S + pad) // C
    xc = jnp.moveaxis(x.reshape(B, nc, C, -1), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, nc, C), 1, 0)
    mc = jnp.moveaxis(mask.reshape(B, nc, C), 1, 0)

    @jax.checkpoint
    def body(carry, xs):
        xb, lb, mb = xs
        logits = _logits(params, cfg, xb).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        nll_sum, n = carry
        mf = mb.astype(jnp.float32)
        return (nll_sum + ((lse - gold) * mf).sum(), n + mf.sum()), None

    (nll_sum, n), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                   (xc, lc, mc))
    return nll_sum / jnp.maximum(n, 1.0)


def loss(params, cfg: ModelConfig, batch) -> jax.Array:
    """Mean next-token cross-entropy.  labels < 0 are masked."""
    labels = batch["labels"]
    if cfg.family == "vlm":  # vision positions carry no next-token loss
        pad = jnp.full(batch["vis_embeds"].shape[:2], -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    mask = labels >= 0
    if cfg.loss_chunk:
        x = _hidden(params, cfg, batch)
        return _chunked_ce(params, cfg, x, jnp.maximum(labels, 0), mask)
    logits = forward(params, cfg, batch)
    return L.softmax_cross_entropy(logits, jnp.maximum(labels, 0), mask)


def prefill(params, cfg: ModelConfig, batch):
    """Process the full prompt; return (last-position logits, cache)."""
    x = _embed_inputs(params, cfg, batch)
    B, S = x.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    valid = jnp.ones((B, S), bool)
    if cfg.family in ("dense", "moe", "vlm"):
        x, cache = _run_dense_stack(params, cfg, x, q_pos=pos, k_pos=pos,
                                    k_valid=valid, mode="prefill")
    elif cfg.family == "ssm":
        x, cache = _run_mamba_stack(params, cfg, x, mode="prefill")
    else:
        x, cache = _run_hybrid_stack(params, cfg, x, q_pos=pos, k_pos=pos,
                                     k_valid=valid, mode="prefill")
    return _logits(params, cfg, x[:, -1:])[:, 0], cache


def decode_step(params, cfg: ModelConfig, cache, batch):
    """One new token.  batch: tokens (B,1), pos scalar (write slot & position).

    Attention caches have capacity Smax; the new token is written at ``pos``
    and attends to positions <= pos.
    """
    x = L.embed(batch["tokens"], params["embed"])
    B = x.shape[0]
    pos = batch["pos"].astype(jnp.int32)                      # scalar
    q_pos = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)

    if cfg.family == "ssm":
        x, new_cache = _run_mamba_stack(params, cfg, x, mode="decode",
                                        cache=cache)
        return _logits(params, cfg, x)[:, 0], new_cache

    Smax = cache["k"].shape[2]
    k_pos = jnp.broadcast_to(jnp.arange(Smax, dtype=jnp.int32), (B, Smax))
    # per-slot validity bitmask (continuous batching: swapped-in slots have
    # holes); falls back to the prefix mask for plain synchronized decode.
    valid = cache.get("valid")
    if valid is not None:
        valid = jax.lax.dynamic_update_slice(
            valid, jnp.ones((B, 1), valid.dtype), (0, pos))
        k_valid = valid
    else:
        k_valid = k_pos <= pos
    run = (_run_dense_stack if cfg.family in ("dense", "moe", "vlm")
           else _run_hybrid_stack)
    layer_cache = {k: v for k, v in cache.items() if k != "valid"}
    x, new_cache = run(params, cfg, x, q_pos=q_pos, k_pos=k_pos,
                       k_valid=k_valid, mode="decode", cache=layer_cache,
                       write_pos=pos)
    if valid is not None:
        new_cache["valid"] = valid
    return _logits(params, cfg, x)[:, 0], new_cache


# ---------------------------------------------------------------------------
# cache shape definitions (for dry-run input_specs)
# ---------------------------------------------------------------------------


def cache_defs(cfg: ModelConfig, batch: int, s_max: int) -> dict:
    """ShapeDtypeStruct-compatible ParamDef tree describing the decode cache."""
    dt = cfg.dtype
    Lc = cfg.n_layers
    out: dict[str, ParamDef] = {}
    if cfg.family in ("dense", "moe", "vlm"):
        kv = (Lc, batch, s_max, cfg.n_kv_heads, cfg.head_dim)
        out["k"] = ParamDef(kv, ("layers", "batch", "kv_seq", "kv_heads", None), "zeros", dt)
        out["v"] = ParamDef(kv, ("layers", "batch", "kv_seq", "kv_heads", None), "zeros", dt)
    if cfg.family in ("ssm", "hybrid"):
        dims = ssm_dims(cfg)
        out["conv"] = ParamDef((Lc, batch, dims.d_conv - 1, dims.conv_ch),
                               ("layers", "batch", None, "inner"), "zeros", dt)
        out["ssm"] = ParamDef(
            (Lc, batch, dims.n_heads, dims.d_state, dims.head_dim),
            ("layers", "batch", "ssm_heads", None, None), "zeros", jnp.float32)
    if cfg.family == "hybrid":
        g = cfg.n_layers // cfg.attn_every
        kv = (g, batch, s_max, cfg.n_kv_heads, cfg.head_dim)
        out["k"] = ParamDef(kv, ("layers", "batch", "kv_seq", "kv_heads", None), "zeros", dt)
        out["v"] = ParamDef(kv, ("layers", "batch", "kv_seq", "kv_heads", None), "zeros", dt)
    if cfg.family != "ssm":
        out["valid"] = ParamDef((batch, s_max), ("batch", "kv_seq"),
                                "zeros", jnp.bool_)
    return out
