"""Uniform model API: family dispatch + dry-run input specs.

``step_fn(cfg, kind)`` returns the function the launcher jits:
  * train  -> loss(params, batch)
  * prefill-> (last logits, cache)
  * decode -> (logits, new cache)

``input_specs(cfg, shape)`` returns ``jax.ShapeDtypeStruct`` stand-ins for
every model input of that (arch x shape) cell — weak-type-correct, shardable,
zero allocation (the dry-run contract).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from repro.nn.param import ParamDef, abstract_params

from . import encdec, lm
from .config import ModelConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason-if-not).  long_500k needs a sub-quadratic family."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: long_500k skipped (DESIGN.md)"
    return True, ""


def _mod(cfg: ModelConfig):
    return encdec if cfg.enc_dec else lm


def param_defs(cfg: ModelConfig):
    return _mod(cfg).param_defs(cfg)


def cache_defs(cfg: ModelConfig, batch: int, s_max: int):
    return _mod(cfg).cache_defs(cfg, batch, s_max)


def loss_fn(cfg: ModelConfig) -> Callable:
    mod = _mod(cfg)
    return lambda params, batch: mod.loss(params, cfg, batch)


def prefill_fn(cfg: ModelConfig) -> Callable:
    mod = _mod(cfg)
    return lambda params, batch: mod.prefill(params, cfg, batch)


def decode_fn(cfg: ModelConfig) -> Callable:
    mod = _mod(cfg)
    return lambda params, cache, batch: mod.decode_step(params, cfg, cache, batch)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Batch ShapeDtypeStructs for one (arch x shape) cell (no cache)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.enc_dec:
            specs = {
                "enc_embeds": jax.ShapeDtypeStruct((B, cfg.enc_len, cfg.d_model), cfg.dtype),
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
            }
        elif cfg.family == "vlm":
            s_txt = S - cfg.n_vis_tokens
            specs = {
                "tokens": jax.ShapeDtypeStruct((B, s_txt), i32),
                "vis_embeds": jax.ShapeDtypeStruct((B, cfg.n_vis_tokens, cfg.d_model), cfg.dtype),
            }
        else:
            specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if shape.kind == "train":
            lab_s = S - cfg.n_vis_tokens if cfg.family == "vlm" else S
            specs["labels"] = jax.ShapeDtypeStruct((B, lab_s), i32)
        return specs
    # decode: one new token against a seq_len-deep cache
    return {
        "tokens": jax.ShapeDtypeStruct((B, 1), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }


def cache_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStructs for the decode cache of one cell."""
    assert shape.kind == "decode"
    return abstract_params(cache_defs(cfg, shape.global_batch, shape.seq_len))


def make_step(cfg: ModelConfig, shape: ShapeSpec):
    """(fn, example_args_specs) for this cell — what the dry-run lowers.

    train  : fn(params, batch) -> loss                (grads+update added by trainer)
    prefill: fn(params, batch) -> (logits, cache)
    decode : fn(params, cache, batch) -> (logits, cache)
    """
    if shape.kind == "train":
        return loss_fn(cfg)
    if shape.kind == "prefill":
        return prefill_fn(cfg)
    return decode_fn(cfg)
