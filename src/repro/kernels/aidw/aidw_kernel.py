"""Pallas TPU kernel: tiled AIDW Stage-2 weighted interpolation.

TPU analogue of the paper's shared-memory "tiled version" (§3.3/§4.2.2):

* CUDA shared-memory tile of data-point coordinates  ->  a ``(1, TILE_D)``
  VMEM block per grid step along the data axis (BlockSpec-managed).
* per-thread register accumulators (sum of partial weights / weighted values)
  ->  ``(TILE_Q, 1)`` float32 VMEM scratch accumulators that persist across
  the ``arbitrary`` data-axis grid dimension.
* one thread per interpolated point  ->  one (8,128)-vectorized lane row per
  query inside a ``(TILE_Q, TILE_D)`` distance/weight tile (MXU/VPU shaped).

The kernel optionally FUSES the adaptive-alpha determination (Eqs. 2/4/5/6)
with the weighting pass: it takes the Stage-1 mean NN distance ``r_obs`` and
computes alpha in-kernel on the first data step — one kernel launch for the
whole Stage 2 instead of the paper's two (beyond-paper optimization,
DESIGN.md §2).

Layouts are SoA exactly as the paper prescribes (§4.2.1): queries arrive as
``(n, 1)`` column vectors (sublane-major), data points as ``(1, m)`` row
vectors (lane-major), so the broadcasted difference is a native outer
product on the VPU.

Padding contract: data sentinels at +1e30 make ``d2 = inf`` in f32, hence
``w = exp(-inf) = 0`` exactly — padded data points contribute nothing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import aidw as A
from repro.kernels.pallas_compat import CompilerParams

DEFAULT_TILE_Q = 256
DEFAULT_TILE_D = 512


def _alpha_from_r_obs(r_obs, n_points, area, alphas, r_min, r_max):
    """Eqs. (2)->(4)->(5)->(6) — jnp only, safe inside the kernel."""
    r_exp = 1.0 / (2.0 * jnp.sqrt(n_points / area))
    r_stat = r_obs / r_exp
    mu = 0.5 - 0.5 * jnp.cos(jnp.pi / r_max * (r_stat - r_min))
    mu = jnp.where(r_stat <= r_min, 0.0, jnp.where(r_stat >= r_max, 1.0, mu))
    return A.alpha_from_membership(mu, alphas)


def _interp_kernel(
    qx_ref, qy_ref, aux_ref,            # queries: (TQ, 1); aux = alpha or r_obs
    px_ref, py_ref, pz_ref,             # data:    (1, TD)
    out_ref,                            # output:  (TQ, 1)
    sum_w, sum_wz, alpha_s,             # scratch: (TQ, 1) f32
    *, n_dblocks: int, fused: bool,
    n_points: float, area: float, alphas, r_min: float, r_max: float,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        sum_w[...] = jnp.zeros_like(sum_w)
        sum_wz[...] = jnp.zeros_like(sum_wz)
        aux = aux_ref[...].astype(jnp.float32)
        if fused:
            alpha_s[...] = _alpha_from_r_obs(
                aux, jnp.float32(n_points), jnp.float32(area), alphas, r_min, r_max)
        else:
            alpha_s[...] = aux

    qx = qx_ref[...].astype(jnp.float32)          # (TQ, 1)
    qy = qy_ref[...].astype(jnp.float32)
    px = px_ref[...].astype(jnp.float32)          # (1, TD)
    py = py_ref[...].astype(jnp.float32)
    pz = pz_ref[...].astype(jnp.float32)
    alpha = alpha_s[...]                          # (TQ, 1)

    d2 = (qx - px) ** 2 + (qy - py) ** 2          # (TQ, TD) outer broadcast
    # w = d2 ** (-alpha/2), squared distances throughout (paper: sqrt deferred);
    # exp/log form feeds the VPU transcendental unit once each.
    w = jnp.exp(-0.5 * alpha * jnp.log(jnp.maximum(d2, A.EPS_D2)))
    sum_w[...] += w.sum(axis=1, keepdims=True)
    sum_wz[...] += (w * pz).sum(axis=1, keepdims=True)

    @pl.when(j == n_dblocks - 1)
    def _finish():
        denom = jnp.maximum(sum_w[...], jnp.float32(1e-30))
        out_ref[...] = (sum_wz[...] / denom).astype(out_ref.dtype)


def tiled_interpolate_kernel(
    qx, qy, aux, px, py, pz,
    *, tile_q: int = DEFAULT_TILE_Q, tile_d: int = DEFAULT_TILE_D,
    fused: bool = False, n_points: float = 1.0, area: float = 1.0,
    alphas=A.DEFAULT_ALPHAS, r_min: float = A.DEFAULT_R_MIN,
    r_max: float = A.DEFAULT_R_MAX, interpret: bool = False,
):
    """Raw pallas_call wrapper.  Shapes: qx/qy/aux (n,1); px/py/pz (1,m).

    n % tile_q == 0 and m % tile_d == 0 (ops.py pads).
    """
    n, m = qx.shape[0], px.shape[1]
    assert n % tile_q == 0 and m % tile_d == 0, (n, tile_q, m, tile_d)
    grid = (n // tile_q, m // tile_d)

    kernel = functools.partial(
        _interp_kernel, n_dblocks=grid[1], fused=fused,
        n_points=n_points, area=area, alphas=tuple(alphas),
        r_min=r_min, r_max=r_max,
    )
    q_spec = pl.BlockSpec((tile_q, 1), lambda i, j: (i, 0))
    d_spec = pl.BlockSpec((1, tile_d), lambda i, j: (0, j))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, q_spec, q_spec, d_spec, d_spec, d_spec],
        out_specs=pl.BlockSpec((tile_q, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), qx.dtype),
        scratch_shapes=[
            pltpu.VMEM((tile_q, 1), jnp.float32),
            pltpu.VMEM((tile_q, 1), jnp.float32),
            pltpu.VMEM((tile_q, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qx, qy, aux, px, py, pz)
