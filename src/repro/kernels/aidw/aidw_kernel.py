"""Pallas TPU kernel: tiled AIDW Stage-2 weighted interpolation.

TPU analogue of the paper's shared-memory "tiled version" (§3.3/§4.2.2):

* CUDA shared-memory tile of data-point coordinates  ->  a ``(1, TILE_D)``
  VMEM block per grid step along the data axis (BlockSpec-managed).
* per-thread register accumulators (sum of partial weights / weighted values)
  ->  ``(TILE_Q, 1)`` float32 VMEM scratch accumulators that persist across
  the ``arbitrary`` data-axis grid dimension.
* one thread per interpolated point  ->  one (8,128)-vectorized lane row per
  query inside a ``(TILE_Q, TILE_D)`` distance/weight tile (MXU/VPU shaped).

The kernel optionally FUSES the adaptive-alpha determination (Eqs. 2/4/5/6)
with the weighting pass: it takes the Stage-1 mean NN distance ``r_obs`` and
computes alpha in-kernel on the first data step — one kernel launch for the
whole Stage 2 instead of the paper's two (beyond-paper optimization,
DESIGN.md §2).

Layouts are SoA exactly as the paper prescribes (§4.2.1): queries arrive as
``(n, 1)`` column vectors (sublane-major), data points as ``(1, m)`` row
vectors (lane-major), so the broadcasted difference is a native outer
product on the VPU.

Padding contract: data sentinels at +1e30 make ``d2 = inf`` in f32, hence
``w = exp(-inf) = 0`` exactly — padded data points contribute nothing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import aidw as A
from repro.kernels.pallas_compat import CompilerParams

DEFAULT_TILE_Q = 256
DEFAULT_TILE_D = 512


def _alpha_from_r_obs(r_obs, n_points, area, alphas, r_min, r_max):
    """Eqs. (2)->(4)->(5)->(6) — delegates to the canonical jnp chain so the
    in-kernel alpha is bit-identical to the two-launch path's
    :func:`repro.core.aidw.adaptive_alpha` (jnp only, safe inside a kernel)."""
    return A.adaptive_alpha(r_obs, n_points, area, alphas=alphas,
                            r_min=r_min, r_max=r_max)


def _interp_kernel(
    qx_ref, qy_ref, aux_ref,            # queries: (TQ, 1); aux = alpha or r_obs
    stats_ref,                          # SMEM (1, 2): (n_points, area), traced
    px_ref, py_ref, pz_ref,             # data:    (1, TD)
    out_ref, sumw_ref,                  # outputs: (TQ, 1) values / weight sums
    sum_w, sum_wz, alpha_s,             # scratch: (TQ, 1) f32
    *, n_dblocks: int, fused: bool,
    alphas, r_min: float, r_max: float,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        sum_w[...] = jnp.zeros_like(sum_w)
        sum_wz[...] = jnp.zeros_like(sum_wz)
        aux = aux_ref[...].astype(jnp.float32)
        if fused:
            alpha_s[...] = _alpha_from_r_obs(
                aux, stats_ref[0, 0], stats_ref[0, 1], alphas, r_min, r_max)
        else:
            alpha_s[...] = aux

    qx = qx_ref[...].astype(jnp.float32)          # (TQ, 1)
    qy = qy_ref[...].astype(jnp.float32)
    px = px_ref[...].astype(jnp.float32)          # (1, TD)
    py = py_ref[...].astype(jnp.float32)
    pz = pz_ref[...].astype(jnp.float32)
    alpha = alpha_s[...]                          # (TQ, 1)

    d2 = (qx - px) ** 2 + (qy - py) ** 2          # (TQ, TD) outer broadcast
    # w = d2 ** (-alpha/2), squared distances throughout (paper: sqrt deferred);
    # exp/log form feeds the VPU transcendental unit once each.
    w = jnp.exp(-0.5 * alpha * jnp.log(jnp.maximum(d2, A.EPS_D2)))
    sum_w[...] += w.sum(axis=1, keepdims=True)
    sum_wz[...] += (w * pz).sum(axis=1, keepdims=True)

    @pl.when(j == n_dblocks - 1)
    def _finish():
        # zero-weight guard: a query whose every f32 weight underflowed gets
        # the 0.0 sentinel (sum_wz is then also 0), never NaN; the caller
        # derives the zero_weight_mask from the sumw output.
        denom = jnp.maximum(sum_w[...], jnp.float32(1e-30))
        out_ref[...] = (sum_wz[...] / denom).astype(out_ref.dtype)
        sumw_ref[...] = sum_w[...].astype(sumw_ref.dtype)


def tiled_interpolate_kernel(
    qx, qy, aux, stats, px, py, pz,
    *, tile_q: int = DEFAULT_TILE_Q, tile_d: int = DEFAULT_TILE_D,
    fused: bool = False,
    alphas=A.DEFAULT_ALPHAS, r_min: float = A.DEFAULT_R_MIN,
    r_max: float = A.DEFAULT_R_MAX, interpret: bool = False,
):
    """Raw pallas_call wrapper.  Shapes: qx/qy/aux (n,1); stats (1,2) f32
    (n_points, area — TRACED, so dataset churn never retraces); px/py/pz (1,m).

    Returns ``(values (n,1), sum_w (n,1))``.  n % tile_q == 0 and
    m % tile_d == 0 (ops.py pads).
    """
    n, m = qx.shape[0], px.shape[1]
    assert n % tile_q == 0 and m % tile_d == 0, (n, tile_q, m, tile_d)
    grid = (n // tile_q, m // tile_d)

    kernel = functools.partial(
        _interp_kernel, n_dblocks=grid[1], fused=fused,
        alphas=tuple(alphas), r_min=r_min, r_max=r_max,
    )
    q_spec = pl.BlockSpec((tile_q, 1), lambda i, j: (i, 0))
    d_spec = pl.BlockSpec((1, tile_d), lambda i, j: (0, j))
    s_spec = pl.BlockSpec((1, 2), lambda i, j: (0, 0),
                          memory_space=pltpu.SMEM)
    o_spec = pl.BlockSpec((tile_q, 1), lambda i, j: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, q_spec, q_spec, s_spec, d_spec, d_spec, d_spec],
        out_specs=(o_spec, o_spec),
        out_shape=(jax.ShapeDtypeStruct((n, 1), qx.dtype),
                   jax.ShapeDtypeStruct((n, 1), jnp.float32)),
        scratch_shapes=[
            pltpu.VMEM((tile_q, 1), jnp.float32),
            pltpu.VMEM((tile_q, 1), jnp.float32),
            pltpu.VMEM((tile_q, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qx, qy, aux, stats, px, py, pz)


def _local_kernel(
    d2_ref, idx_ref,                    # (TQ, KP): merged Stage-1 neighbours
    aux_ref,                            # (TQ, 1): alpha, or r_obs when fused
    stats_ref,                          # SMEM (1, 2): (n_points, area), traced
    pz_ref,                             # (1, M): full data-value row
    out_ref, sumw_ref,                  # outputs: (TQ, 1)
    *, fused: bool, alphas, r_min: float, r_max: float,
):
    aux = aux_ref[...].astype(jnp.float32)
    if fused:
        alpha = _alpha_from_r_obs(
            aux, stats_ref[0, 0], stats_ref[0, 1], alphas, r_min, r_max)
    else:
        alpha = aux                                   # (TQ, 1)

    d2 = d2_ref[...].astype(jnp.float32)              # (TQ, KP)
    # the fused gather: neighbour values pulled straight from the value row
    # by the Stage-1 indices, no (n, m) rotation ever materializes
    z = jnp.take(pz_ref[...][0], idx_ref[...], axis=0).astype(jnp.float32)
    w = A.idw_weights_sq(d2, alpha)                   # same op chain as jnp path
    wz = w * z
    # sequential k-axis accumulation — the SAME pinned order as
    # A.topk_weighted_partial_sums, so fused == unfused bitwise, and padded
    # k slots (d2 = inf -> w = 0 exactly) leave every partial sum unchanged
    swz, sw = wz[:, 0:1], w[:, 0:1]
    for i in range(1, d2.shape[1]):
        swz = swz + wz[:, i:i + 1]
        sw = sw + w[:, i:i + 1]
    zero = sw <= 0.0
    vals = jnp.where(zero, jnp.float32(A.ZERO_WEIGHT_SENTINEL),
                     swz / jnp.where(zero, 1.0, sw))
    out_ref[...] = vals.astype(out_ref.dtype)
    sumw_ref[...] = sw.astype(sumw_ref.dtype)


def local_interpolate_kernel(
    d2, idx, aux, stats, pz,
    *, tile_q: int = DEFAULT_TILE_Q, fused: bool = False,
    alphas=A.DEFAULT_ALPHAS, r_min: float = A.DEFAULT_R_MIN,
    r_max: float = A.DEFAULT_R_MAX, interpret: bool = False,
):
    """Raw pallas_call wrapper for the local (exact-k) Stage-2 kernel.

    Shapes: d2/idx (n, kp) — the k merged Stage-1 neighbours per query,
    k-padded with ``d2 = inf`` slots; aux (n, 1) alpha (or r_obs when
    ``fused``); stats (1, 2) f32 traced (n_points, area); pz (1, m) the full
    value row the in-kernel gather reads through ``idx``.

    One grid dimension over query tiles — each query touches only its k
    neighbours, O(k) work instead of the global kernel's O(m) data axis.
    Returns ``(values (n,1), sum_w (n,1))``.
    """
    n, kp = d2.shape
    assert n % tile_q == 0, (n, tile_q)
    grid = (n // tile_q,)

    kernel = functools.partial(
        _local_kernel, fused=fused, alphas=tuple(alphas),
        r_min=r_min, r_max=r_max,
    )
    k_spec = pl.BlockSpec((tile_q, kp), lambda i: (i, 0))
    q_spec = pl.BlockSpec((tile_q, 1), lambda i: (i, 0))
    s_spec = pl.BlockSpec((1, 2), lambda i: (0, 0), memory_space=pltpu.SMEM)
    z_spec = pl.BlockSpec((1, pz.shape[1]), lambda i: (0, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[k_spec, k_spec, q_spec, s_spec, z_spec],
        out_specs=(q_spec, q_spec),
        out_shape=(jax.ShapeDtypeStruct((n, 1), aux.dtype),
                   jax.ShapeDtypeStruct((n, 1), jnp.float32)),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(d2, idx, aux, stats, pz)
