"""jit'd public wrappers for the tiled AIDW Stage-2 Pallas kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import aidw as A

from .aidw_kernel import DEFAULT_TILE_D, DEFAULT_TILE_Q, tiled_interpolate_kernel

PAD_COORD = 1e30  # padded data points -> d2 = inf (f32) -> weight exactly 0


def _pad1(a, mult, value=0.0):
    pad = (-a.shape[0]) % mult
    return jnp.pad(a, (0, pad), constant_values=value) if pad else a


@partial(jax.jit, static_argnames=("tile_q", "tile_d", "interpret"))
def tiled_interpolate(
    queries_xy: jax.Array,   # (n, 2)
    points_xy: jax.Array,    # (m, 2)
    values: jax.Array,       # (m,)
    alpha: jax.Array,        # (n,) or scalar
    *, tile_q: int = DEFAULT_TILE_Q, tile_d: int = DEFAULT_TILE_D,
    interpret: bool = True,
) -> jax.Array:
    """Eq. (1) weighted average over all data points, per-query alpha.

    The TPU 'tiled version': drop-in replacement for
    ``repro.core.aidw.weighted_interpolate``.
    """
    n = queries_xy.shape[0]
    alpha = jnp.broadcast_to(jnp.asarray(alpha, queries_xy.dtype), (n,))
    qx = _pad1(queries_xy[:, 0], tile_q)[:, None]
    qy = _pad1(queries_xy[:, 1], tile_q)[:, None]
    aux = _pad1(alpha, tile_q, value=1.0)[:, None]
    px = _pad1(points_xy[:, 0], tile_d, PAD_COORD)[None, :]
    py = _pad1(points_xy[:, 1], tile_d, PAD_COORD)[None, :]
    pz = _pad1(values, tile_d)[None, :]
    out = tiled_interpolate_kernel(
        qx, qy, aux, px, py, pz,
        tile_q=tile_q, tile_d=tile_d, fused=False, interpret=interpret,
    )
    return out[:n, 0]


@partial(jax.jit, static_argnames=(
    "tile_q", "tile_d", "interpret", "alphas", "r_min", "r_max",
    "n_points", "area"))
def fused_stage2(
    queries_xy: jax.Array,   # (n, 2)
    points_xy: jax.Array,    # (m, 2)
    values: jax.Array,       # (m,)
    r_obs: jax.Array,        # (n,) Stage-1 mean NN distance
    *, n_points: float, area: float,
    alphas: tuple = A.DEFAULT_ALPHAS,
    r_min: float = A.DEFAULT_R_MIN, r_max: float = A.DEFAULT_R_MAX,
    tile_q: int = DEFAULT_TILE_Q, tile_d: int = DEFAULT_TILE_D,
    interpret: bool = True,
) -> jax.Array:
    """Beyond-paper fusion: alpha determination (Eqs. 2/4/5/6) + Eq. (1)
    weighting in ONE kernel launch (the paper launches two)."""
    n = queries_xy.shape[0]
    qx = _pad1(queries_xy[:, 0], tile_q)[:, None]
    qy = _pad1(queries_xy[:, 1], tile_q)[:, None]
    aux = _pad1(jnp.asarray(r_obs, queries_xy.dtype), tile_q, value=1.0)[:, None]
    px = _pad1(points_xy[:, 0], tile_d, PAD_COORD)[None, :]
    py = _pad1(points_xy[:, 1], tile_d, PAD_COORD)[None, :]
    pz = _pad1(values, tile_d)[None, :]
    out = tiled_interpolate_kernel(
        qx, qy, aux, px, py, pz,
        tile_q=tile_q, tile_d=tile_d, fused=True,
        n_points=float(n_points), area=float(area), alphas=tuple(alphas),
        r_min=r_min, r_max=r_max, interpret=interpret,
    )
    return out[:n, 0]
