"""jit'd public wrappers for the AIDW Stage-2 Pallas kernels.

Every wrapper returns ``(values, zero_weight_mask)``: the per-query mask is
True where the f32 weight sum underflowed to zero and the value is the 0.0
sentinel instead of NaN (see ``repro.core.aidw.guarded_values``).

``n_points``/``area`` ride through as TRACED scalars (an SMEM (1, 2) stats
block), so dataset churn never retraces the fused kernels.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import aidw as A

from .aidw_kernel import (DEFAULT_TILE_D, DEFAULT_TILE_Q,
                          local_interpolate_kernel, tiled_interpolate_kernel)

PAD_COORD = 1e30  # padded data points -> d2 = inf (f32) -> weight exactly 0
LANE = 128        # TPU lane width: the k axis pads to a multiple of this


def _pad1(a, mult, value=0.0):
    pad = (-a.shape[0]) % mult
    return jnp.pad(a, (0, pad), constant_values=value) if pad else a


def _stats(n_points, area):
    """The traced (1, 2) f32 (n_points, area) SMEM block."""
    return jnp.stack([jnp.asarray(n_points, jnp.float32).reshape(()),
                      jnp.asarray(area, jnp.float32).reshape(())]).reshape(1, 2)


@partial(jax.jit, static_argnames=("tile_q", "tile_d", "interpret"))
def tiled_interpolate(
    queries_xy: jax.Array,   # (n, 2)
    points_xy: jax.Array,    # (m, 2)
    values: jax.Array,       # (m,)
    alpha: jax.Array,        # (n,) or scalar
    *, tile_q: int = DEFAULT_TILE_Q, tile_d: int = DEFAULT_TILE_D,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Eq. (1) weighted average over all data points, per-query alpha.

    The TPU 'tiled version': drop-in replacement for
    ``repro.core.aidw.weighted_interpolate``.  Returns
    ``(values, zero_weight_mask)``.
    """
    n = queries_xy.shape[0]
    alpha = jnp.broadcast_to(jnp.asarray(alpha, queries_xy.dtype), (n,))
    qx = _pad1(queries_xy[:, 0], tile_q)[:, None]
    qy = _pad1(queries_xy[:, 1], tile_q)[:, None]
    aux = _pad1(alpha, tile_q, value=1.0)[:, None]
    px = _pad1(points_xy[:, 0], tile_d, PAD_COORD)[None, :]
    py = _pad1(points_xy[:, 1], tile_d, PAD_COORD)[None, :]
    pz = _pad1(values, tile_d)[None, :]
    out, sumw = tiled_interpolate_kernel(
        qx, qy, aux, _stats(1.0, 1.0), px, py, pz,
        tile_q=tile_q, tile_d=tile_d, fused=False, interpret=interpret,
    )
    return out[:n, 0], sumw[:n, 0] <= 0.0


@partial(jax.jit, static_argnames=(
    "tile_q", "tile_d", "interpret", "alphas", "r_min", "r_max"))
def fused_stage2(
    queries_xy: jax.Array,   # (n, 2)
    points_xy: jax.Array,    # (m, 2)
    values: jax.Array,       # (m,)
    r_obs: jax.Array,        # (n,) Stage-1 mean NN distance
    *, n_points, area,       # TRACED scalars (dataset churn never retraces)
    alphas: tuple = A.DEFAULT_ALPHAS,
    r_min: float = A.DEFAULT_R_MIN, r_max: float = A.DEFAULT_R_MAX,
    tile_q: int = DEFAULT_TILE_Q, tile_d: int = DEFAULT_TILE_D,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Beyond-paper fusion: alpha determination (Eqs. 2/4/5/6) + Eq. (1)
    weighting in ONE kernel launch (the paper launches two).  Returns
    ``(values, zero_weight_mask)``."""
    n = queries_xy.shape[0]
    qx = _pad1(queries_xy[:, 0], tile_q)[:, None]
    qy = _pad1(queries_xy[:, 1], tile_q)[:, None]
    aux = _pad1(jnp.asarray(r_obs, queries_xy.dtype), tile_q, value=1.0)[:, None]
    px = _pad1(points_xy[:, 0], tile_d, PAD_COORD)[None, :]
    py = _pad1(points_xy[:, 1], tile_d, PAD_COORD)[None, :]
    pz = _pad1(values, tile_d)[None, :]
    out, sumw = tiled_interpolate_kernel(
        qx, qy, aux, _stats(n_points, area), px, py, pz,
        tile_q=tile_q, tile_d=tile_d, fused=True, alphas=tuple(alphas),
        r_min=r_min, r_max=r_max, interpret=interpret,
    )
    return out[:n, 0], sumw[:n, 0] <= 0.0


def _local_call(d2, idx, aux, stats, values, *, tile_q, fused, alphas,
                r_min, r_max, interpret):
    """Shared padding + launch for the local (exact-k) kernel."""
    n, k = d2.shape
    qpad = (-n) % tile_q
    kpad = (-k) % LANE
    if qpad:
        d2 = jnp.pad(d2, ((0, qpad), (0, 0)), constant_values=jnp.inf)
        idx = jnp.pad(idx, ((0, qpad), (0, 0)))
        aux = jnp.pad(aux, (0, qpad), constant_values=1.0)
    if kpad:
        # padded neighbour slots: d2 = inf -> weight exactly 0 -> bitwise no-op
        d2 = jnp.pad(d2, ((0, 0), (0, kpad)), constant_values=jnp.inf)
        idx = jnp.pad(idx, ((0, 0), (0, kpad)))
    pz = _pad1(values, LANE)[None, :]
    out, sumw = local_interpolate_kernel(
        d2, idx.astype(jnp.int32), aux[:, None], stats, pz,
        tile_q=tile_q, fused=fused, alphas=tuple(alphas),
        r_min=r_min, r_max=r_max, interpret=interpret,
    )
    return out[:n, 0], sumw[:n, 0] <= 0.0


@partial(jax.jit, static_argnames=("tile_q", "interpret"))
def local_interpolate(
    d2: jax.Array,           # (n, k) merged Stage-1 neighbour distances^2
    idx: jax.Array,          # (n, k) neighbour indices into ``values``
    values: jax.Array,       # (m,) data values (gathered in-kernel)
    alpha: jax.Array,        # (n,) or scalar
    *, tile_q: int = DEFAULT_TILE_Q, interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Local (exact-k) Eq. (1): gather + weighting fused in one kernel.

    Bit-identical to ``repro.core.aidw.topk_weighted_partial_sums`` +
    ``guarded_values`` on the same (d2, values[idx], alpha) inputs.  Returns
    ``(values, zero_weight_mask)``.
    """
    n = d2.shape[0]
    alpha = jnp.broadcast_to(jnp.asarray(alpha, values.dtype), (n,))
    return _local_call(d2, idx, alpha, _stats(1.0, 1.0), values,
                       tile_q=tile_q, fused=False, alphas=A.DEFAULT_ALPHAS,
                       r_min=A.DEFAULT_R_MIN, r_max=A.DEFAULT_R_MAX,
                       interpret=interpret)


@partial(jax.jit, static_argnames=(
    "tile_q", "interpret", "alphas", "r_min", "r_max"))
def fused_local_stage2(
    d2: jax.Array,           # (n, k) merged Stage-1 neighbour distances^2
    idx: jax.Array,          # (n, k) neighbour indices into ``values``
    values: jax.Array,       # (m,) data values (gathered in-kernel)
    r_obs: jax.Array,        # (n,) Stage-1 mean NN distance
    *, n_points, area,       # TRACED scalars
    alphas: tuple = A.DEFAULT_ALPHAS,
    r_min: float = A.DEFAULT_R_MIN, r_max: float = A.DEFAULT_R_MAX,
    tile_q: int = DEFAULT_TILE_Q, interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """The tentpole kernel: adaptive alpha (Eqs. 2/4/5/6) + neighbour gather
    + local Eq. (1) weighting, one launch, O(k) per query.  Returns
    ``(values, zero_weight_mask)``."""
    aux = jnp.asarray(r_obs, values.dtype)
    return _local_call(d2, idx, aux, _stats(n_points, area), values,
                       tile_q=tile_q, fused=True, alphas=tuple(alphas),
                       r_min=r_min, r_max=r_max, interpret=interpret)
