"""Tiled AIDW Stage-2 Pallas kernel (VMEM analogue of the paper's shared-memory tiling)."""

from . import ops, ref
from .aidw_kernel import local_interpolate_kernel, tiled_interpolate_kernel
from .ops import (fused_local_stage2, fused_stage2, local_interpolate,
                  tiled_interpolate)
