"""Tiled AIDW Stage-2 Pallas kernel (VMEM analogue of the paper's shared-memory tiling)."""

from . import ops, ref
from .aidw_kernel import tiled_interpolate_kernel
from .ops import fused_stage2, tiled_interpolate
