"""Pure-jnp oracle for the tiled AIDW Stage-2 kernel (no Pallas, no blocking)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import aidw as A


def interpolate_ref(queries_xy, points_xy, values, alpha):
    """Dense Eq. (1): full (n, m) weight matrix in one shot, f32 accumulation."""
    n = queries_xy.shape[0]
    alpha = jnp.broadcast_to(jnp.asarray(alpha, jnp.float32), (n,))
    q = queries_xy.astype(jnp.float32)
    p = points_xy.astype(jnp.float32)
    z = values.astype(jnp.float32)
    d2 = (q[:, 0:1] - p[None, :, 0]) ** 2 + (q[:, 1:2] - p[None, :, 1]) ** 2
    w = jnp.power(jnp.maximum(d2, A.EPS_D2), -0.5 * alpha[:, None])
    return ((w * z[None, :]).sum(-1) / w.sum(-1)).astype(queries_xy.dtype)


def fused_stage2_ref(queries_xy, points_xy, values, r_obs, *, n_points, area,
                     alphas=A.DEFAULT_ALPHAS, r_min=A.DEFAULT_R_MIN,
                     r_max=A.DEFAULT_R_MAX):
    """Alpha determination + Eq. (1), unfused reference path."""
    alpha = A.adaptive_alpha(
        jnp.asarray(r_obs, jnp.float32), float(n_points), float(area),
        alphas=alphas, r_min=r_min, r_max=r_max)
    return interpolate_ref(queries_xy, points_xy, values, alpha)
