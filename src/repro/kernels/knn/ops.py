"""jit'd public wrappers for the blocked brute-force kNN Pallas kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .knn_kernel import DEFAULT_TILE_D, DEFAULT_TILE_Q, knn_kernel

PAD_COORD = 1e30


def _pad1(a, mult, value=0.0):
    pad = (-a.shape[0]) % mult
    return jnp.pad(a, (0, pad), constant_values=value) if pad else a


@partial(jax.jit, static_argnames=("k", "tile_q", "tile_d", "interpret"))
def knn_d2(
    points_xy: jax.Array,    # (m, 2)
    queries_xy: jax.Array,   # (n, 2)
    *, k: int = 15,
    tile_q: int = DEFAULT_TILE_Q, tile_d: int = DEFAULT_TILE_D,
    interpret: bool = True,
) -> jax.Array:
    """Squared distances (n, k), ascending, of each query's k nearest points."""
    n = queries_xy.shape[0]
    qx = _pad1(queries_xy[:, 0], tile_q)[:, None]
    qy = _pad1(queries_xy[:, 1], tile_q)[:, None]
    px = _pad1(points_xy[:, 0], tile_d, PAD_COORD)[None, :]
    py = _pad1(points_xy[:, 1], tile_d, PAD_COORD)[None, :]
    out = knn_kernel(qx, qy, px, py, k=k, tile_q=tile_q, tile_d=tile_d,
                     interpret=interpret)
    return out[:n]


@partial(jax.jit, static_argnames=("k", "tile_q", "tile_d", "interpret"))
def knn_d2_with_ring(
    points_xy: jax.Array,    # (m, 2)   CSR-resident (compacted) points
    ring_xy: jax.Array,      # (r, 2)   hot append ring; dead slots PAD_COORD
    queries_xy: jax.Array,   # (n, 2)
    *, k: int = 15,
    tile_q: int = DEFAULT_TILE_Q, tile_d: int = DEFAULT_TILE_D,
    interpret: bool = True,
) -> jax.Array:
    """:func:`knn_d2` over the compacted table PLUS the LSM hot append ring
    (``repro.core.slab`` module docstring): ring points join the brute-force
    candidate set directly, so freshly staged inserts are query-visible with
    no re-sort.  Empty/dead ring slots must carry ``PAD_COORD`` — their
    squared distance overflows f32 to inf and is never selected, exactly the
    tombstone convention of the grid path."""
    return knn_d2(jnp.concatenate([points_xy, ring_xy], axis=0), queries_xy,
                  k=k, tile_q=tile_q, tile_d=tile_d, interpret=interpret)


def mean_nn_distance(d2: jax.Array) -> jax.Array:
    """Eq. (3) r_obs from the kernel's squared distances (sqrt deferred here)."""
    return jnp.sqrt(jnp.maximum(d2, 0.0)).mean(axis=-1)
