"""Pure-jnp oracle for the blocked kNN kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def knn_d2_ref(points_xy, queries_xy, *, k: int = 15):
    """Full (n, m) distance matrix + lax.top_k; f32 accumulation."""
    q = queries_xy.astype(jnp.float32)
    p = points_xy.astype(jnp.float32)
    d2 = (q[:, 0:1] - p[None, :, 0]) ** 2 + (q[:, 1:2] - p[None, :, 1]) ** 2
    neg_top, _ = jax.lax.top_k(-d2, min(k, p.shape[0]))
    out = -neg_top
    if out.shape[1] < k:  # fewer points than k: pad with inf like the kernel
        out = jnp.pad(out, ((0, 0), (0, k - out.shape[1])),
                      constant_values=jnp.inf)
    return out.astype(queries_xy.dtype)
