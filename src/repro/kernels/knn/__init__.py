"""Blocked brute-force kNN Pallas kernel (k-pass masked-min selection)."""

from . import ops, ref
from .knn_kernel import knn_kernel
from .ops import knn_d2, knn_d2_with_ring, mean_nn_distance
