"""Pallas TPU kernel: blocked brute-force kNN (squared distances).

TPU adaptation of the 'original' algorithm's hot loop (Mei et al. 2015 /
paper §3.1) and of the final filter step of the improved grid search:

* CUDA: one thread per query walks all m data points, maintaining a length-k
  insertion-sorted buffer in registers — per-lane insertion sort does not
  vectorize on a TPU.
* Here: a ``(TILE_Q, TILE_D)`` distance tile is computed per grid step (outer
  broadcast, VPU-shaped); the per-query running top-k lives in a
  ``(TILE_Q, k)`` VMEM scratch carried across the ``arbitrary`` data-block
  dimension, and the merge is a **k-pass masked-min selection** over the
  concatenated ``(TILE_Q, k + TILE_D)`` tile: each pass extracts the row
  minimum and masks its first occurrence (duplicate-safe).  k passes of
  vectorized reductions replace m insertion-sort steps.

Squared distances throughout (sqrt deferred — paper §4.1.4).  Padding
contract: data sentinels at +1e30 give d2 = inf and never enter the top-k.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

DEFAULT_TILE_Q = 256
DEFAULT_TILE_D = 512


def _kpass_topk(cat: jax.Array, k: int) -> jax.Array:
    """k smallest per row of ``cat`` (ascending) by masked-min extraction."""
    outs = []
    for _ in range(k):
        v = jnp.min(cat, axis=1, keepdims=True)            # (TQ, 1)
        is_min = cat == v
        first = is_min & (jnp.cumsum(is_min.astype(jnp.int32), axis=1) == 1)
        cat = jnp.where(first, jnp.inf, cat)
        outs.append(v)
    return jnp.concatenate(outs, axis=1)                   # (TQ, k)


def _knn_kernel(
    qx_ref, qy_ref,          # queries: (TQ, 1)
    px_ref, py_ref,          # data:    (1, TD)
    out_ref,                 # output:  (TQ, k) squared distances ascending
    topk_s,                  # scratch: (TQ, k) f32
    *, k: int, n_dblocks: int,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        topk_s[...] = jnp.full_like(topk_s, jnp.inf)

    qx = qx_ref[...].astype(jnp.float32)
    qy = qy_ref[...].astype(jnp.float32)
    px = px_ref[...].astype(jnp.float32)
    py = py_ref[...].astype(jnp.float32)

    d2 = (qx - px) ** 2 + (qy - py) ** 2                   # (TQ, TD)
    cat = jnp.concatenate([topk_s[...], d2], axis=1)       # (TQ, k + TD)
    topk_s[...] = _kpass_topk(cat, k)

    @pl.when(j == n_dblocks - 1)
    def _finish():
        out_ref[...] = topk_s[...].astype(out_ref.dtype)


def knn_kernel(
    qx, qy, px, py, *, k: int,
    tile_q: int = DEFAULT_TILE_Q, tile_d: int = DEFAULT_TILE_D,
    interpret: bool = False,
):
    """Raw pallas_call wrapper.  qx/qy (n,1); px/py (1,m); returns (n,k) d2."""
    n, m = qx.shape[0], px.shape[1]
    assert n % tile_q == 0 and m % tile_d == 0, (n, tile_q, m, tile_d)
    grid = (n // tile_q, m // tile_d)

    kernel = functools.partial(_knn_kernel, k=k, n_dblocks=grid[1])
    q_spec = pl.BlockSpec((tile_q, 1), lambda i, j: (i, 0))
    d_spec = pl.BlockSpec((1, tile_d), lambda i, j: (0, j))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, q_spec, d_spec, d_spec],
        out_specs=pl.BlockSpec((tile_q, k), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), qx.dtype),
        scratch_shapes=[pltpu.VMEM((tile_q, k), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qx, qy, px, py)
