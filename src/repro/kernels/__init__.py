"""Pallas TPU kernels for the compute hot-spots the paper optimizes:

* ``aidw``  — Stage-2 tiled weighted interpolation (paper's shared-memory tiling)
* ``knn``   — blocked brute-force kNN (the 'original' baseline's hot loop)
"""
