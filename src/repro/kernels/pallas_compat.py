"""Version-compat shims over ``jax.experimental.pallas.tpu``.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (and kept
the old name as a deprecated alias for a while).  Kernels import the symbol
from here so they run unmodified on both sides of the rename:

* jax >= 0.5.x : ``pltpu.CompilerParams``
* jax  0.4.x  : ``pltpu.TPUCompilerParams``

Both accept the same ``dimension_semantics=...`` constructor arguments used by
this repo's kernels.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
