"""Core library: the paper's contribution (grid kNN + AIDW) as composable JAX."""

from .aidw import (
    DEFAULT_ALPHAS,
    adaptive_alpha,
    alpha_from_membership,
    expected_nn_distance,
    fuzzy_membership,
    idw_weights_sq,
    nn_statistic,
    weighted_interpolate,
    weighted_partial_sums,
)
from .grid import (
    CellTable,
    GridSpec,
    bin_points,
    cell_ids,
    plan_grid,
    rebin_delta,
)
from .knn import KnnResult, brute_knn, grid_knn, mean_nn_distance
from .pipeline import (
    AidwConfig,
    AidwPlan,
    AidwResult,
    ShardedAidwPlan,
    aidw_improved,
    aidw_original,
    execute,
    idw_standard,
    plan,
    plan_delta,
    shard_plan,
)
from .session import InterpolationSession, bucket_size

__all__ = [
    "DEFAULT_ALPHAS", "adaptive_alpha", "alpha_from_membership",
    "expected_nn_distance", "fuzzy_membership", "idw_weights_sq",
    "nn_statistic", "weighted_interpolate", "weighted_partial_sums",
    "CellTable", "GridSpec", "bin_points", "cell_ids", "plan_grid",
    "rebin_delta",
    "KnnResult", "brute_knn", "grid_knn", "mean_nn_distance",
    "AidwConfig", "AidwPlan", "AidwResult", "ShardedAidwPlan",
    "aidw_improved", "aidw_original", "execute", "idw_standard", "plan",
    "plan_delta", "shard_plan",
    "InterpolationSession", "bucket_size",
]
