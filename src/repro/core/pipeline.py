"""End-to-end AIDW pipelines — the paper's Figure 1 as composable JAX.

Variants (all numerically equivalent modulo accumulation order):

* :func:`aidw_improved`  — grid-based fast kNN (Stage 1) + weighted
  interpolation (Stage 2).  ``stage2='naive'`` uses the blocked pure-jnp
  path; ``stage2='tiled'`` uses the Pallas VMEM-tiled kernel (the TPU
  analogue of the paper's shared-memory tiled version).
* :func:`aidw_original`  — the authors' previous algorithm (Mei et al. 2015):
  brute-force global kNN + the same Stage 2.  This is the paper's baseline.
* :func:`idw_standard`   — Shepard (1968) constant-alpha IDW.

Plan/execute contract (serving-scale API; see also ``repro.core.session``):

The paper splits the improved algorithm into a one-time grid build and a
per-query pass, but a naive ``aidw_improved`` call re-plans and re-bins on
every invocation.  For repeated queries over a mostly-static dataset the
pipeline is therefore factored into:

* :func:`plan` — HOST-side grid planning (static ``GridSpec``) plus the
  device-resident CSR cell table (:class:`repro.core.grid.CellTable`), the
  study-area constants for Eq. (2), and the pipeline config, bundled into an
  :class:`AidwPlan`.  Runs once per dataset (or per ``update``).  Because the
  grid spec determines downstream array SHAPES, ``plan`` must run eagerly;
  everything after it is shape-static and jit-safe.
* :func:`execute` — the per-query Stage-1 (grid kNN + mean NN distance) and
  Stage-2 (adaptive alpha + Eq. (1) weighting) over a prebuilt plan.  Pure in
  the plan arrays and queries: safe to wrap in ``jax.jit`` with the plan's
  static fields (``spec``, ``cfg``, ``n_points``, ``area``) as static args —
  :data:`_session_execute` below is exactly that jit, shared by every
  :class:`repro.core.session.InterpolationSession`.

Padding rules: callers may pad the query batch to a bucketed shape (power of
two) so repeated odd-sized batches reuse one compiled executable.  Padded
queries are ordinary coordinates (pad with an EDGE query, not zeros, so the
padded lanes stay in a dense, cheap-to-search cell); all per-query outputs
are independent, so slicing ``[:n]`` recovers results bit-identical to an
unpadded call.  Per-query reductions never cross the query axis, which is
what makes bucketed results match unbucketed ones bitwise.

Donation rules: the padded query buffer is created by the caller expressly
for one ``execute`` call, so sessions donate it (``donate_argnums``) on
backends that support buffer donation (not CPU); plan arrays are long-lived
and must NEVER be donated — they are reused by every subsequent query.

AOT / ladder rules (``InterpolationSession.precompile``; PR 10):

Because of the padding rules above, a session's entire steady-state compile
surface is finite and known at plan time: one executable per (query-bucket,
capacity-bucket) pair, where query buckets are the power-of-two ladder up to
``max_batch`` and the capacity bucket is fixed by the plan.  ``precompile``
walks that ladder through ``jax.jit(...).lower().compile()`` and installs
the resulting ``Compiled`` objects ahead of any traffic, so the first query
of every bucket size dispatches a prebuilt executable — zero traces, zero
backend compiles (the invariant tests/test_coldstart.py pins per layout).
The contract has three edges to know about:

* AOT covers the EXECUTE jit only.  The session's eager helper ops (query
  padding, result slicing, the warm-path reductions) still compile lazily
  per novel batch size; ``precompile(warm=True)`` — and the server prewarm,
  which submits one warm batch per bucket — flushes those for exact bucket
  sizes.  An odd-sized batch therefore pays a tiny one-off pad/sum compile
  on first sight even on a fully prewarmed server; the post-warmup compile
  counter treats any such hot-path compile as an anomaly worth flagging,
  not an error.
* The ladder survives delta updates by construction: ``plan_delta`` freezes
  the GridSpec and capacity bucket (incremental-binning rules below), so
  the AOT signature stays valid.  A full re-plan (fresh spec or capacity
  crossing) invalidates every installed executable; the session drops them
  and ``stats['aot_buckets']`` falls to 0 rather than serve a stale shape.
* Compiled-ladder entries are written through the persistent compilation
  cache when ``repro.runtime.compile_cache.enable`` ran first, so a
  restarted process — or a fleet host sharing ``AIDW_CACHE_DIR`` —
  deserializes the ladder instead of recompiling it.  Background prewarm
  additionally compiles under
  ``compile_cache.background_compile_options()`` (single-split CPU
  codegen) on a thread niced to the scheduler floor, keeping the
  seconds-long compile phase off the serving hot path; the server flips an
  internal event (``_prewarm_compiled``) at the compile→warm phase
  boundary so observers can tell expensive compilation apart from the
  ordinary queued warm batches that follow it.

Sharding rules (mesh-parallel serving; see :func:`shard_plan`):

The per-query pass is embarrassingly parallel, so one plan can serve a whole
mesh.  A :class:`ShardedAidwPlan` places the plan for a mesh in one of two
layouts:

* ``replicated`` (default) — the CSR :class:`~repro.core.grid.CellTable`,
  ``points_xy`` and ``values`` are REPLICATED on every device; queries are
  partitioned over ALL mesh axes and each device runs :func:`_execute_core`
  on its local shard inside ``shard_map``.  Because no per-query reduction
  crosses the query axis, each lane computes exactly what the single-device
  path computes for its queries: warm sharded results are BIT-IDENTICAL per
  query to the single-device session on the same plan.  The bucketed-padding
  and donation contracts above apply unchanged — the global bucket must be
  divisible by the query-axis device product (the session rounds per-device).
* ``ring`` — for datasets too large to replicate, data points are sharded
  into blocks along a ring axis and both stages rotate the blocks via
  collective-permute (:func:`repro.core.distributed.make_ring_aidw`).  The
  ring path does brute-force kNN over rotating blocks, so results match the
  grid path only to accumulation-order tolerance (~1e-5 f32), never bitwise
  — and Stage 1 costs O(m) candidate distances per query, the exact
  brute-force pattern the paper's grid search exists to beat.
* ``grid_ring`` — the grid-AWARE ring (PR 5; the default for
  ``layout='auto'`` at ring scale): the same O(m/P)-per-device data
  decomposition, but the even grid itself is partitioned into per-device
  row slabs (:class:`repro.core.slab.SlabPartition`: per-slab CSR
  ``CellTable`` + a halo ring of boundary cells) and the rotating block
  ships its slab's cell table, so Stage 1 evaluates only O(window)
  candidates per query from the expanding search window
  (:func:`repro.core.distributed.make_grid_ring_aidw`).  Per-slab top-k
  results k-way merge into the running neighbour heap; results carry the
  grid path's certification story: d2/r_obs/alpha BIT-IDENTICAL to the
  replicated layout for queries whose certified window closes inside one
  slab (incl. its halo), interpolated values within ~1e-5 f32 accumulation
  tolerance (Stage 2 sums slab partials in rotation order; the Stage-2
  tile shape follows the padded query bucket, so values may additionally
  vary ~1 ulp across batch compositions — Stage-1 outputs never do).

Stage-2 mode rules (``AidwConfig.stage2``; see ``repro.core.aidw``):

``'naive'``/``'tiled'`` (alias ``'global'``) evaluate Eq. (1) over ALL data
points — jnp-blocked or Pallas-tiled.  ``'local'`` truncates Eq. (1) to the
k merged Stage-1 neighbours: ``r_obs``/``alpha`` are bit-identical to global
mode by construction (Stage 1 is untouched), values differ by the truncated
far-field tail, and per-query work drops from O(m) to O(k)
(``fused=True`` routes through the Pallas gather+weighting kernel —
bit-identical to the unfused jnp top-k path eagerly, within 1 ulp under
jit where XLA contracts the jnp path's mul+add).  In the ``grid_ring`` layout
local mode also drops the whole Stage-2 ring rotation — O(window + k) per
query end-to-end.

Incremental-binning rules (:func:`plan_delta` / ``session.update(deltas=...)``):

A delta update (inserts + deletes) reuses the existing ``GridSpec`` — cell
width, rows and cols are FROZEN so array shapes, the compiled executables and
Eq. (2)'s study area all survive — and patches the CSR table in
O(Δ log Δ + m memcpy + n_cells) via :func:`repro.core.grid.rebin_delta`
instead of the full O(m log m) re-sort.  A delta update falls back to a full
re-plan (fresh spec, full :func:`~repro.core.grid.bin_points`) when the
incremental result would be invalid or degraded: any insert landing outside
the planned grid's bounding box (it would be clamped to a border cell), or a
delta larger than ``max_delta_frac`` of the dataset (grid density drifts off
Eq. (2)).  ``n_points`` is TRACED in every layout, and :func:`plan` /
:func:`plan_delta` capacity-pad the plan arrays to
:data:`PLAN_PAD_MULTIPLE`-sized buckets (sentinel coordinates contribute
exactly zero weight), so dataset-resizing churn retraces NOTHING while the
point count stays inside one capacity bucket; crossing a bucket boundary
retraces once per new capacity, not once per new count.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from . import aidw as A
from . import grid as G
from . import knn as K
from .jax_compat import shard_map


@dataclass(frozen=True)
class AidwConfig:
    k: int = 15
    alphas: tuple = A.DEFAULT_ALPHAS
    r_min: float = A.DEFAULT_R_MIN
    r_max: float = A.DEFAULT_R_MAX
    cell_factor: float = 1.0       # scales Eq.(2) cell width (1.0 = paper)
    max_level: int | None = None   # None = auto from density (knn.auto_max_level)
    window: int = 256
    exact: bool = True             # certified 2-pass kNN (False = paper heuristic)
    knn_block: int = 4096
    interp_block: int = 1024
    interp_data_block: int = 0     # chunk Stage-2 data axis (0 = whole dataset)
    stage2: Literal["naive", "tiled", "local", "global"] = "naive"
    fused: bool = False            # tiled/local: alpha-in-kernel single launch
    tile_q: int = 256              # Pallas query-block
    tile_d: int = 512              # Pallas data-block
    interpret: bool = True         # CPU container: run Pallas in interpret mode

    def __post_init__(self):
        # 'global' is the documented alias for the default all-points Eq. (1)
        # path; normalize it at construction so config-keyed executor caches
        # (and jit static args) see ONE canonical spelling.
        if self.stage2 == "global":
            object.__setattr__(self, "stage2", "naive")


@dataclass
class AidwResult:
    values: jax.Array              # (n,) predictions
    alpha: jax.Array               # (n,) adaptive power parameter
    r_obs: jax.Array               # (n,) observed mean NN distance
    overflow: int = 0              # queries whose candidate window overflowed
    timings: dict = field(default_factory=dict)   # stage -> seconds
    overflow_mask: jax.Array | None = None        # (n,) bool per-query flag
    # overflow_mask lets batch owners (the serving coalescer) attribute
    # overflowed queries to the request that contributed them; ``overflow``
    # stays the batch-level sum for one-shot callers.
    zero_weight_mask: jax.Array | None = None     # (n,) bool: sum(w) underflow
    # zero_weight_mask flags queries whose every f32 weight underflowed to
    # zero; their ``values`` entry is the 0.0 sentinel, never NaN (see
    # repro.core.aidw.guarded_values).


@dataclass(frozen=True)
class AidwPlan:
    """Reusable Stage-1 build: everything that depends only on the dataset.

    ``spec``/``cfg``/``area`` are static (hashable) and safe as jit static
    args; ``n_points`` is the TRUE point count and rides through the
    executors as a traced scalar (churn never retraces);
    ``table``/``points_xy``/``values`` are device-resident arrays reused —
    never donated — across queries, capacity-padded to
    :data:`PLAN_PAD_MULTIPLE` buckets by :func:`pad_plan` (rows beyond
    ``n_points`` hold sentinel coordinates whose Stage-2 weight is exactly
    zero and which no CSR cell range ever addresses).
    """

    spec: G.GridSpec
    table: G.CellTable | None      # None only for unbinned (ring-only) plans
    points_xy: jax.Array           # (cap, 2); rows [n_points:] are sentinels
    values: jax.Array              # (cap,)
    n_points: int
    area: float
    cfg: AidwConfig


# Plan arrays pad to this capacity multiple: small dataset churn keeps every
# array shape (and therefore every compiled executable) stable.  Matches the
# grid_ring slab packet's pad multiple (repro.core.slab.device_tables).
PLAN_PAD_MULTIPLE = 64


def pad_plan(pln: AidwPlan, multiple: int = PLAN_PAD_MULTIPLE) -> AidwPlan:
    """Capacity-pad a plan's point arrays to a ``multiple``-sized bucket.

    Padded point rows carry :data:`repro.core.aidw.PAD_SENTINEL` coordinates:
    their squared distance to any real query overflows f32 to inf, so their
    Eq. (1) weight is exactly 0.0 and no result bit changes.  Padded CSR tail
    slots sit beyond ``cell_start[-1]`` and are never addressed by a cell
    range.  ``n_points`` keeps the TRUE count (Eq. (2) and the kNN count
    floor read it, not the array shape).
    """
    m = pln.n_points
    cap = -(-max(m, 1) // multiple) * multiple
    pad = cap - pln.points_xy.shape[0]
    if pad == 0:
        return pln
    if pad < 0:
        raise ValueError(f"plan arrays ({pln.points_xy.shape[0]}) exceed "
                         f"capacity bucket {cap} for n_points={m}")
    big = jnp.float32(A.PAD_SENTINEL)
    points_xy = jnp.pad(pln.points_xy, ((0, pad), (0, 0)),
                        constant_values=big)
    values = jnp.pad(pln.values, (0, pad))
    table = pln.table
    if table is not None:
        tpad = cap - table.sx.shape[0]
        table = G.CellTable(
            sx=jnp.pad(table.sx, (0, tpad), constant_values=big),
            sy=jnp.pad(table.sy, (0, tpad), constant_values=big),
            sz=jnp.pad(table.sz, (0, tpad)),
            cell_start=table.cell_start,
            order=jnp.pad(table.order, (0, tpad)),
        )
    return AidwPlan(spec=pln.spec, table=table, points_xy=points_xy,
                    values=values, n_points=m, area=pln.area, cfg=pln.cfg)


def plan_host_points(pln: AidwPlan) -> np.ndarray:
    """The TRUE (n_points, 3) dataset from a (possibly capacity-padded) plan."""
    return np.concatenate(
        [np.asarray(pln.points_xy)[:pln.n_points],
         np.asarray(pln.values)[:pln.n_points, None]], axis=1)


@dataclass(frozen=True)
class ShardedAidwPlan:
    """An :class:`AidwPlan` placed on a mesh (module docstring, 'Sharding
    rules').  ``replicated``: plan arrays replicated, queries partitioned over
    all mesh axes, per-lane bit-identity with the single-device path.
    ``ring``: ``ring_points`` holds the (padded, (m_pad, 3)) dataset sharded
    along ``ring_axis``; execution rotates blocks via collective-permute.
    ``grid_ring``: ``slab_part`` holds the host-side
    :class:`repro.core.slab.SlabPartition` (per-slab CSR tables + delta
    bookkeeping) and ``slab_arrays`` its device placement (stacked packet
    sharded along ``ring_axis``, kept resident and delta-PATCHED by
    ``staging`` — a :class:`SlabStaging`); ``rps``/``halo``/``max_level``
    are the static slab geometry the executor is compiled against.
    """

    base: AidwPlan
    mesh: Mesh
    layout: Literal["replicated", "ring", "grid_ring"] = "replicated"
    ring_axis: str | None = None
    ring_points: jax.Array | None = None
    slab_part: object | None = None
    slab_arrays: dict | None = None
    rps: int | None = None
    halo: int | None = None
    max_level: int | None = None
    staging: object | None = None   # SlabStaging (grid_ring layout only)

    @property
    def n_devices(self) -> int:
        return int(self.mesh.devices.size)


class SlabStaging:
    """Per-slab donation-aliased device staging for the grid_ring packet.

    The device-side half of the LSM ingest tier (``repro.core.slab`` module
    docstring): where the old path re-uploaded the whole stacked packet on
    every delta (O(m) memcpy + transfer), this keeps the packet resident
    and patches ONLY what a :class:`repro.core.slab.DeltaReport` names —

    * ``csr_rows``  -> one padded slab row per array
      (``lax.dynamic_update_slice`` at the slab index, old buffer DONATED
      so XLA aliases the update in place; O(touched-slab rows) bytes);
    * ``dead``      -> an O(Δ) element scatter of tombstone sentinels into
      the slab's sorted coordinates (and the matching owned-block slots) —
      the CSR offsets are byte-stable under tombstone deletes;
    * ``ring_rows`` -> one ``ring_cap``-slot hot-ring row per touched slab.

    Capacities are STICKY (grow-only): a delta that would overflow the
    current caps falls back to :meth:`full_stage` once, establishing new
    caps that every later delta patches against — so sustained churn
    converges to pure O(Δ + touched-slab) staging.  Scatter index vectors
    are bucketed to powers of two (duplicating the first index, which
    rewrites the same sentinel — a no-op) so the patch executables retrace
    per bucket, not per delta size.  Donation is disabled on CPU (no
    buffer aliasing there; XLA would warn on every patch).

    Telemetry (read by ``session.stats``): ``staged_bytes`` (host bytes
    shipped by the LAST stage call), ``staged_bytes_total``,
    ``slabs_touched`` (last call), ``full_restages``.
    """

    def __init__(self, mesh: Mesh, ring_axis: str):
        self.mesh = mesh
        self.ring_axis = ring_axis
        self.arrays: dict = {}
        self.cap = 0
        self.cap2 = 0
        self.staged_bytes = 0
        self.staged_bytes_total = 0
        self.slabs_touched = 0
        self.full_restages = 0
        self._donate = jax.default_backend() != "cpu"
        self._fns: dict = {}

    def _sharding(self, ndim: int) -> NamedSharding:
        spec = PartitionSpec(self.ring_axis) if ndim == 1 \
            else PartitionSpec(self.ring_axis, None)
        return NamedSharding(self.mesh, spec)

    def _row_fn(self, shape, dtype):
        """Jitted single-row patcher for a (P, width) packet array."""
        key = ("row", shape, dtype)
        fn = self._fns.get(key)
        if fn is None:
            def patch(dst, row, s):
                return jax.lax.dynamic_update_slice(
                    dst, row[None], (s, jnp.int32(0)))
            fn = jax.jit(patch, out_shardings=self._sharding(2),
                         donate_argnums=(0,) if self._donate else ())
            self._fns[key] = fn
        return fn

    def _scatter_fn(self, shape, dtype, n_idx):
        """Jitted element scatter into row ``s`` of a (P, width) array."""
        key = ("scatter", shape, dtype, n_idx)
        fn = self._fns.get(key)
        if fn is None:
            def patch(dst, s, idx, val):
                return dst.at[s, idx].set(val)
            fn = jax.jit(patch, out_shardings=self._sharding(2),
                         donate_argnums=(0,) if self._donate else ())
            self._fns[key] = fn
        return fn

    def _patch_row(self, name: str, s: int, row: np.ndarray) -> int:
        dst = self.arrays[name]
        fn = self._row_fn(dst.shape, dst.dtype)
        self.arrays[name] = fn(dst, jnp.asarray(row), jnp.int32(s))
        return row.nbytes

    def _patch_slots(self, name: str, s: int, idx: np.ndarray,
                     val: float) -> int:
        dst = self.arrays[name]
        # power-of-two index bucket: duplicates rewrite the same sentinel
        n = int(idx.size)
        bucket = 1 << max(n - 1, 0).bit_length()
        padded = np.empty(bucket, np.int32)
        padded[:n] = idx
        padded[n:] = idx[0]
        fn = self._scatter_fn(dst.shape, dst.dtype, bucket)
        self.arrays[name] = fn(dst, jnp.int32(s), jnp.asarray(padded),
                               jnp.asarray(val, dst.dtype))
        return padded.nbytes + np.dtype(dst.dtype).itemsize

    def full_stage(self, part) -> dict:
        """Upload the whole stacked packet (build / cap-overflow path)."""
        host = part.device_tables(PLAN_PAD_MULTIPLE, cap_floor=self.cap,
                                  cap2_floor=self.cap2)
        self.cap = host["sx"].shape[1]
        self.cap2 = host["bx"].shape[1]
        nbytes = 0
        out = {}
        for name, arr in host.items():
            out[name] = jax.device_put(jnp.asarray(arr),
                                       self._sharding(arr.ndim))
            nbytes += arr.nbytes
        self.arrays = out
        self.full_restages += 1
        self.staged_bytes = nbytes
        self.staged_bytes_total += nbytes
        self.slabs_touched = part.p
        return out

    def delta_stage(self, part, rep) -> dict:
        """Patch the resident packet per a DeltaReport (O(Δ + touched)).

        Falls back to one :meth:`full_stage` when a restaged slab no
        longer fits the sticky capacities.  Fills ``rep.staged_bytes``.
        """
        if not self.arrays:
            out = self.full_stage(part)
            rep.staged_bytes = self.staged_bytes
            return out
        rows = {}
        for s in sorted(rep.csr_rows):
            row = part.slab_host_rows(s, self.cap, self.cap2)
            if row is None:                      # sticky caps overflowed
                out = self.full_stage(part)
                rep.staged_bytes = self.staged_bytes
                return out
            rows[s] = row
        nbytes = 0
        for s, row in rows.items():
            for name in ("sx", "sy", "sz", "cell_start", "bx", "by", "bz"):
                nbytes += self._patch_row(name, s, row[name])
        tomb = np.float32(G.TOMBSTONE_COORD)
        for s, slots in rep.dead.items():
            if s in rows:
                continue                         # full-row restage covers it
            slots = np.asarray(slots, np.int32)
            if not slots.size:
                continue
            nbytes += self._patch_slots("sx", s, slots, tomb)
            nbytes += self._patch_slots("sy", s, slots, tomb)
            nbytes += self._patch_slots("sz", s, slots, np.float32(0.0))
            bpos = np.asarray(part.owned_positions(s, slots), np.int32)
            if bpos.size:
                nbytes += self._patch_slots("bx", s, bpos, tomb)
                nbytes += self._patch_slots("by", s, bpos, tomb)
                nbytes += self._patch_slots("bz", s, bpos, np.float32(0.0))
        for s in sorted(rep.ring_rows):
            row = part.ring_host_row(s)
            for name in ("rx", "ry", "rz"):
                nbytes += self._patch_row(name, s, row[name])
        self.staged_bytes = nbytes
        self.staged_bytes_total += nbytes
        self.slabs_touched = len(
            set(rep.csr_rows) | set(rep.ring_rows) | set(rep.dead))
        rep.staged_bytes = nbytes
        return dict(self.arrays)


def shard_plan(pln: AidwPlan, mesh: Mesh,
               layout: Literal["auto", "replicated", "ring",
                               "grid_ring"] = "auto",
               *, ring_axis: str | None = None,
               ring_threshold: int = 4_000_000,
               ring_cap: int = 256,
               host_points=None) -> ShardedAidwPlan:
    """Place a plan on ``mesh``: replicate the CSR table + point arrays, or
    slab-shard the points when ``m`` is large (``layout='auto'`` picks
    ``grid_ring`` at ``n_points >= ring_threshold`` — the grid-aware ring
    dominates the brute-force ``ring``, which is kept as the merge
    baseline).  ``host_points`` optionally supplies the (m, 3) dataset as a
    host array for the slab partitioner, avoiding a device pull."""
    if layout == "auto":
        layout = "grid_ring" if pln.n_points >= ring_threshold \
            else "replicated"
    if layout == "replicated":
        rep = NamedSharding(mesh, PartitionSpec())
        pln = AidwPlan(
            spec=pln.spec, table=jax.device_put(pln.table, rep),
            points_xy=jax.device_put(pln.points_xy, rep),
            values=jax.device_put(pln.values, rep),
            n_points=pln.n_points, area=pln.area, cfg=pln.cfg)
        return ShardedAidwPlan(base=pln, mesh=mesh, layout="replicated")
    ring_axis = ring_axis or mesh.axis_names[0]
    if layout == "grid_ring":
        from . import knn as K
        from .slab import SlabPartition

        cfg = pln.cfg
        max_level = cfg.max_level if cfg.max_level is not None \
            else K.auto_max_level(pln.spec, pln.n_points, cfg.k)
        if host_points is None:
            host_points = plan_host_points(pln)
        part = SlabPartition.build(pln.spec, host_points,
                                   int(mesh.shape[ring_axis]),
                                   halo=max_level, ring_cap=ring_cap)
        staging = SlabStaging(mesh, ring_axis)
        return ShardedAidwPlan(
            base=pln, mesh=mesh, layout="grid_ring", ring_axis=ring_axis,
            slab_part=part, slab_arrays=staging.full_stage(part),
            rps=part.rps, halo=part.halo, max_level=max_level,
            staging=staging)
    from .distributed import pad_to_multiple

    # pad to a CAPACITY bucket (64 rows per ring device), not just to the
    # device count: like the other layouts, churn that stays inside the
    # bucket keeps the ring executor's shapes (and its compiled trace) stable
    pts = pad_to_multiple(
        jnp.concatenate([pln.points_xy[:pln.n_points],
                         pln.values[:pln.n_points, None]], axis=1),
        PLAN_PAD_MULTIPLE * int(mesh.shape[ring_axis]))
    pts = jax.device_put(
        pts, NamedSharding(mesh, PartitionSpec(ring_axis, None)))
    return ShardedAidwPlan(base=pln, mesh=mesh, layout="ring",
                           ring_axis=ring_axis, ring_points=pts)


def grid_ring_plan_delta(splan: ShardedAidwPlan, new_base: AidwPlan,
                         inserts=None, deletes=None):
    """Incrementally re-place a ``grid_ring`` plan after a dataset delta.

    The shard-aware half of the session's incremental update: the delta is
    routed to the OWNING slabs' host state only
    (:meth:`repro.core.slab.SlabPartition.apply_delta` — LSM-tiered:
    inserts land in hot rings, CSR deletes tombstone in place; untouched
    slabs keep their host arrays and cached ownership masks), and the
    resident device packet is PATCHED per the returned
    :class:`~repro.core.slab.DeltaReport` by :class:`SlabStaging` —
    O(Δ + touched-slab) staged bytes instead of the former O(m) whole-
    packet re-upload.  The grid spec / slab geometry / compiled executor
    all survive.  ``new_base`` is the updated base plan from
    :func:`plan_delta` (same spec by construction).

    Returns ``(new_splan, delta_report)``; the report carries the ingest
    telemetry (``staged_bytes``, spill/compaction flags) the session
    surfaces through ``stats``.
    """
    if splan.layout != "grid_ring" or splan.slab_part is None:
        raise ValueError("grid_ring_plan_delta needs a grid_ring plan")
    if new_base.spec != splan.base.spec:
        raise ValueError("delta re-placement requires an unchanged GridSpec")
    rep = splan.slab_part.apply_delta(inserts=inserts, deletes=deletes)
    staging = splan.staging or SlabStaging(splan.mesh, splan.ring_axis)
    arrays = staging.delta_stage(splan.slab_part, rep)
    return ShardedAidwPlan(
        base=new_base, mesh=splan.mesh, layout="grid_ring",
        ring_axis=splan.ring_axis, slab_part=splan.slab_part,
        slab_arrays=arrays, rps=splan.rps, halo=splan.halo,
        max_level=splan.max_level, staging=staging), rep


def grid_ring_plan_compact(splan: ShardedAidwPlan):
    """Fold every hot ring into its slab CSRs (the background compaction
    epoch) and patch the device packet.  The logical dataset is unchanged
    (``base`` survives) — only WHERE points are searched moves, after
    which the partition is element-identical to a fresh build and warm
    queries are bitwise a fresh session's.  Returns
    ``(new_splan, delta_report)``."""
    if splan.layout != "grid_ring" or splan.slab_part is None:
        raise ValueError("grid_ring_plan_compact needs a grid_ring plan")
    rep = splan.slab_part.compact()
    staging = splan.staging or SlabStaging(splan.mesh, splan.ring_axis)
    arrays = staging.delta_stage(splan.slab_part, rep)
    return ShardedAidwPlan(
        base=splan.base, mesh=splan.mesh, layout="grid_ring",
        ring_axis=splan.ring_axis, slab_part=splan.slab_part,
        slab_arrays=arrays, rps=splan.rps, halo=splan.halo,
        max_level=splan.max_level, staging=staging), rep


def _study_area(spec: G.GridSpec) -> float:
    return (spec.n_cols * spec.cell_width) * (spec.n_rows * spec.cell_width)


# Python-invocation counter for the execute body: under jit this increments at
# TRACE time only, so a stable count across repeated calls proves the
# compilation cache was hit (see tests/test_session.py).
_EXECUTE_TRACES = [0]


def execute_traces() -> int:
    """How many times the execute body has been (re)traced or run eagerly."""
    return _EXECUTE_TRACES[0]


def plan(points_xyz, cfg: AidwConfig = AidwConfig(), *,
         query_domain=None, bin: bool = True,
         timings: dict | None = None) -> AidwPlan:
    """One-time Stage-1 build: grid planning + CSR binning for a dataset.

    ``query_domain`` optionally extends the grid's bounding box to cover
    queries that lie outside the data points' hull (pass the query array, or
    any (n, 2) sample of the expected query region).  Queries outside the
    planned grid are clamped to the border cells; their kNN is still correct
    whenever the expansion level covers the true neighbours, and the
    per-query ``overflow`` flag reports when it could not be certified.

    ``bin=False`` skips the CSR build (``table=None``) for consumers that
    only need the spec/area/point arrays — the ring layout's brute-force
    executor never reads the table, and for the dataset sizes ring targets
    the full sort is exactly the cost to avoid.

    ``timings`` (optional dict) receives ``bin_s`` — the fenced wall of the
    CSR build alone — so the session's ``plan`` span can attribute its
    ``bin`` sub-span honestly (the fence costs one device sync on a path
    that is already eager and host-dominated).
    """
    points_xyz = jnp.asarray(points_xyz)
    px, py, pz = points_xyz[:, 0], points_xyz[:, 1], points_xyz[:, 2]
    qd = None if query_domain is None else np.asarray(query_domain)
    spec = G.plan_grid(np.asarray(points_xyz[:, :2]), qd,
                       cell_factor=cfg.cell_factor)
    if bin:
        tb = time.perf_counter()
        table = G.bin_points(spec, px, py, pz)
        if timings is not None:
            jax.block_until_ready(table)
            timings["bin_s"] = time.perf_counter() - tb
    else:
        table = None
    return pad_plan(AidwPlan(
        spec=spec, table=table, points_xy=points_xyz[:, :2],
        values=pz, n_points=points_xyz.shape[0],
        area=_study_area(spec), cfg=cfg))


def _stage1(spec: G.GridSpec, cfg: AidwConfig, table: G.CellTable, queries_xy):
    block = min(cfg.knn_block, max(queries_xy.shape[0], 1))
    res = K.grid_knn(spec, table, queries_xy, cfg.k, cfg.max_level,
                     cfg.window, block, cfg.exact)
    return res, K.mean_nn_distance(res.d2)


def _stage2(queries_xy, points_xy, values, alpha, cfg: AidwConfig):
    """Global Eq. (1): returns ``(values, zero_weight_mask)``."""
    if cfg.stage2 == "tiled":
        from repro.kernels.aidw import ops as aidw_ops

        return aidw_ops.tiled_interpolate(
            queries_xy, points_xy, values, alpha,
            tile_q=cfg.tile_q, tile_d=cfg.tile_d, interpret=cfg.interpret,
        )
    swz, sw = A.weighted_partial_sums(queries_xy, points_xy, values, alpha,
                                      cfg.interp_block, cfg.interp_data_block)
    return A.guarded_values(swz, sw)


def _stage2_fused(queries_xy, points_xy, values, r_obs, n_points, area,
                  cfg: AidwConfig):
    """Alpha-in-kernel Stage 2: Eqs. (2)/(4)/(5)/(6) + Eq. (1) in ONE launch.

    Returns ``(values, zero_weight_mask)``; ``n_points``/``area`` ride
    through as traced scalars."""
    from repro.kernels.aidw import ops as aidw_ops

    return aidw_ops.fused_stage2(
        queries_xy, points_xy, values, r_obs,
        n_points=jnp.float32(n_points), area=jnp.float32(area),
        alphas=tuple(cfg.alphas), r_min=cfg.r_min, r_max=cfg.r_max,
        tile_q=cfg.tile_q, tile_d=cfg.tile_d, interpret=cfg.interpret,
    )


def _stage2_local(knn_res: K.KnnResult, values, r_obs, alpha, n_points, area,
                  cfg: AidwConfig):
    """Local (exact-k) Eq. (1) over the merged Stage-1 neighbours.

    ``fused=True`` launches the Pallas gather+weighting kernel at the
    session's alpha (neighbour gather + sequential weighting in ONE
    launch); otherwise the jnp top-k path gathers ``values[idx]`` and runs
    :func:`repro.core.aidw.topk_weighted_partial_sums`.  Both return
    ``(values, zero_weight_mask)``; eagerly they are bit-identical
    (sequential k-axis accumulation; the kernel's lane padding is a no-op —
    tests/test_kernels.py), under jit XLA's FMA contraction on the jnp
    path can shift values by 1 ulp.
    The alpha-in-kernel variant
    (:func:`repro.kernels.aidw.ops.fused_local_stage2`) stays kernel-layer
    only: recomputing Eqs. (2)-(6) inside the interpreter and outside jit
    can differ from the compiled host chain by ~1 ulp, which would break
    the session's fused==unfused bitwise contract.
    """
    if cfg.fused:
        from repro.kernels.aidw import ops as aidw_ops

        return aidw_ops.local_interpolate(
            knn_res.d2, knn_res.idx, values, alpha,
            tile_q=cfg.tile_q, interpret=cfg.interpret,
        )
    z = values[knn_res.idx]
    swz, sw = A.topk_weighted_partial_sums(knn_res.d2, z, alpha)
    return A.guarded_values(swz, sw)


def _execute_core(spec: G.GridSpec, cfg: AidwConfig, area: float,
                  table: G.CellTable, points_xy, values, queries_xy,
                  n_points):
    """Stage 1 + Stage 2 over a prebuilt plan (jit-safe; spec/cfg/area
    static, ``n_points`` TRACED so churn never retraces).  Returns
    ``(values, alpha, r_obs, overflow_mask, zero_weight_mask)``."""
    _EXECUTE_TRACES[0] += 1
    n_points = jnp.float32(n_points)
    res, r_obs = _stage1(spec, cfg, table, queries_xy)
    alpha = A.adaptive_alpha(r_obs, n_points, area, alphas=cfg.alphas,
                             r_min=cfg.r_min, r_max=cfg.r_max)
    if cfg.stage2 == "local":
        out, zero = _stage2_local(res, values, r_obs, alpha, n_points, area,
                                  cfg)
    elif cfg.fused and cfg.stage2 == "tiled":
        out, zero = _stage2_fused(queries_xy, points_xy, values, r_obs,
                                  n_points, area, cfg)
    else:
        out, zero = _stage2(queries_xy, points_xy, values, alpha, cfg)
    return out, alpha, r_obs, res.overflow, zero


# The session entry points: one compiled executable per (spec, cfg, area,
# array shapes) — n_points is traced (argnum 7), so dataset churn inside one
# capacity bucket reuses the executable.  Bucketed query padding makes the
# shape key coarse, so repeated odd-sized batches all hit the same
# executable.  The donating variant gives up the padded query buffer
# (argnums 6) — see the module docstring's donation rules.
_session_execute = jax.jit(_execute_core, static_argnums=(0, 1, 2))
_session_execute_donate = jax.jit(_execute_core, static_argnums=(0, 1, 2),
                                  donate_argnums=(6,))


# Mesh-parallel session entry points: one jitted shard_map wrapper per
# (mesh, donate).  Queries are partitioned over ALL mesh axes; the plan
# arrays are replicated (in_specs P()); every per-query output shards back
# over the same axes.  Per-lane the body IS _execute_core, so warm sharded
# queries are bit-identical per query to the single-device path (module
# docstring, 'Sharding rules').
_SHARDED_EXECUTE_CACHE: dict = {}


def sharded_session_execute(mesh: Mesh, donate: bool = False):
    """The ``shard_map``-wrapped :data:`_session_execute` for ``mesh``."""
    key = (mesh, bool(donate))
    fn = _SHARDED_EXECUTE_CACHE.get(key)
    if fn is None:
        axes = tuple(mesh.axis_names)

        def run(spec, cfg, area, table, points_xy, values, queries_xy,
                n_points):
            body = shard_map(
                partial(_execute_core, spec, cfg, area),
                mesh=mesh,
                in_specs=(PartitionSpec(), PartitionSpec(), PartitionSpec(),
                          PartitionSpec(axes, None), PartitionSpec()),
                out_specs=PartitionSpec(axes),
            )
            return body(table, points_xy, values, queries_xy,
                        jnp.float32(n_points))

        fn = jax.jit(run, static_argnums=(0, 1, 2),
                     donate_argnums=(6,) if donate else ())
        _SHARDED_EXECUTE_CACHE[key] = fn
    return fn


_RING_EXECUTE_CACHE: dict = {}


def ring_session_execute(mesh: Mesh, ring_axis: str, cfg: AidwConfig):
    """The ring-rotation executor for a ``layout='ring'`` sharded plan.

    Returns ``fn(points_xyz_padded, queries_xy, n_points, area) ->
    (values, alpha, r_obs, zero_weight_mask)``; brute-force ring kNN, so
    ~1e-5 of the grid path, never bitwise (module docstring, 'Sharding
    rules').  ``cfg.stage2='local'`` skips the Stage-2 interpolation
    rotation and weights the k merged neighbours directly."""
    from .distributed import make_ring_aidw

    key = (mesh, ring_axis, cfg.k, tuple(cfg.alphas), cfg.r_min, cfg.r_max,
           cfg.stage2 == "local")
    fn = _RING_EXECUTE_CACHE.get(key)
    if fn is None:
        fn = make_ring_aidw(mesh, ring_axis, k=cfg.k, alphas=cfg.alphas,
                            r_min=cfg.r_min, r_max=cfg.r_max,
                            stage2_local=cfg.stage2 == "local",
                            return_stats=True)
        _RING_EXECUTE_CACHE[key] = fn
    return fn


_GRID_RING_EXECUTE_CACHE: dict = {}


def grid_ring_session_execute(mesh: Mesh, ring_axis: str, cfg: AidwConfig,
                              spec: G.GridSpec, rps: int, halo: int,
                              max_level: int):
    """The grid-aware ring executor for a ``layout='grid_ring'`` plan.

    Returns ``fn(sx, sy, sz, cell_start, row_lo, bx, by, bz, rx, ry, rz,
    queries, n_points, area) -> (values, alpha, r_obs, overflow,
    n_candidates, zero_weight_mask)`` — see
    :func:`repro.core.distributed.make_grid_ring_aidw`.  Cached per
    (mesh, ring_axis, cfg, slab geometry): a delta update that keeps the
    spec reuses the compiled executable, and because ``n_points`` is traced
    a delta that RESIZES the dataset reuses it too.
    ``cfg.stage2='local'`` drops the Stage-2 block rotation entirely —
    values come straight from the merged (d2, z) neighbour carry.
    """
    key = (mesh, ring_axis, cfg, spec, rps, halo, max_level)
    fn = _GRID_RING_EXECUTE_CACHE.get(key)
    if fn is None:
        from .distributed import make_grid_ring_aidw

        fn = make_grid_ring_aidw(
            mesh, ring_axis, spec=spec, rps=rps, halo=halo,
            max_level=max_level, k=cfg.k, window=cfg.window,
            knn_block=cfg.knn_block, alphas=cfg.alphas, r_min=cfg.r_min,
            r_max=cfg.r_max, stage2_local=cfg.stage2 == "local",
            return_stats=True)
        _GRID_RING_EXECUTE_CACHE[key] = fn
    return fn


# Fleet-partitioning shard executes (repro.serving.cluster.fleet): a shard
# host answers Stage 1 (its shard's kNN distances AND neighbour values — the
# per-shard top-k heap the client k-way merges) and Stage 2 partial sums (at
# the client-merged alpha) as two separate passes over ITS plan — never a
# full interpolation.  In local Stage-2 mode the merged (d2, z) heap alone
# finishes the query client-side and the partial-sum pass is skipped.


def _shard_knn_core(spec: G.GridSpec, cfg: AidwConfig, table: G.CellTable,
                    values, queries_xy):
    res, _ = _stage1(spec, cfg, table, queries_xy)
    return res.d2, values[res.idx], res.overflow


def _shard_partial_core(cfg: AidwConfig, points_xy, values, queries_xy,
                        alpha):
    return A.weighted_partial_sums(queries_xy, points_xy, values, alpha,
                                   cfg.interp_block, cfg.interp_data_block)


_shard_knn_execute = jax.jit(_shard_knn_core, static_argnums=(0, 1))
_shard_partial_execute = jax.jit(_shard_partial_core, static_argnums=(0,))


# Profiled per-stage entry points (``InterpolationSession.query(profile=True)``
# and benchmarks/stage_bench.py): Stage 1 and Stage 2 as two separately-jitted
# launches so each stage can be fenced (``block_until_ready``) and timed on
# its own.  The fused single-jit :data:`_session_execute` lets XLA fuse across
# the stage boundary, so profiled values may differ from it by accumulation
# order only; the profiled path exists for honest stage walls, not serving.


def _stage1_profile_core(spec: G.GridSpec, cfg: AidwConfig,
                         table: G.CellTable, queries_xy):
    res, r_obs = _stage1(spec, cfg, table, queries_xy)
    return res.d2, res.idx, res.n_candidates, res.overflow, r_obs


def _stage2_profile_core(cfg: AidwConfig, points_xy, values, queries_xy,
                         d2, idx, n_cand, overflow, r_obs, n_points, area):
    n_points = jnp.float32(n_points)
    area = jnp.float32(area)
    alpha = A.adaptive_alpha(r_obs, n_points, area, alphas=cfg.alphas,
                             r_min=cfg.r_min, r_max=cfg.r_max)
    if cfg.stage2 == "local":
        res = K.KnnResult(d2=d2, idx=idx, n_candidates=n_cand,
                          overflow=overflow)
        out, zero = _stage2_local(res, values, r_obs, alpha, n_points, area,
                                  cfg)
    elif cfg.fused and cfg.stage2 == "tiled":
        out, zero = _stage2_fused(queries_xy, points_xy, values, r_obs,
                                  n_points, area, cfg)
    else:
        out, zero = _stage2(queries_xy, points_xy, values, alpha, cfg)
    return out, alpha, r_obs, overflow, zero


_stage1_profile_execute = jax.jit(_stage1_profile_core, static_argnums=(0, 1))
_stage2_profile_execute = jax.jit(_stage2_profile_core, static_argnums=(0,))


def plan_delta(pln: AidwPlan, inserts=None, deletes=None, *,
               max_delta_frac: float = 0.25, host_points=None):
    """Incrementally apply an (inserts, deletes) delta to a plan.

    Returns ``(new_plan, updated_points_xyz)``.  ``new_plan`` keeps the
    existing ``GridSpec`` and patches the CSR table via
    :func:`repro.core.grid.rebin_delta`; it is ``None`` when the delta must
    fall back to a full re-plan (out-of-bbox insert, or
    ``len(delta) > max_delta_frac * m`` — module docstring,
    'Incremental-binning rules'), in which case the caller re-plans from the
    returned updated dataset.

    ``host_points`` optionally supplies the current (m, 3) dataset as a host
    array (the session keeps one as a mirror), avoiding the full
    device-to-host pull of ``points_xy``/``values`` that the reconstruction
    otherwise costs on accelerator backends.
    """
    ins = None if inserts is None else np.asarray(inserts)
    dels = None if deletes is None else np.asarray(deletes, dtype=np.int64)
    n_ins = 0 if ins is None else ins.shape[0]
    n_del = 0 if dels is None else dels.shape[0]
    if n_del and (dels.min() < 0 or dels.max() >= pln.n_points):
        # reject before any fancy indexing: negative indices would silently
        # wrap on the unbinned (ring) path that never reaches rebin_delta
        raise IndexError(f"delete index out of range [0, {pln.n_points})")

    # reconstruct the updated dataset in original order (kept + appended)
    if host_points is not None:
        old = np.asarray(host_points)
    else:
        old = plan_host_points(pln)
    keep = np.ones(pln.n_points, bool)
    if n_del:
        keep[dels] = False
    parts = [old[keep]]
    if n_ins:
        parts.append(ins.astype(old.dtype, copy=False))
    new_pts = np.concatenate(parts, axis=0) if len(parts) > 1 else parts[0]

    spec = pln.spec
    in_bbox = n_ins == 0 or bool(
        (ins[:, 0] >= spec.min_x).all() and
        (ins[:, 1] >= spec.min_y).all() and
        (ins[:, 0] <= spec.min_x + spec.n_cols * spec.cell_width).all() and
        (ins[:, 1] <= spec.min_y + spec.n_rows * spec.cell_width).all())
    if not in_bbox or n_ins + n_del > max_delta_frac * max(pln.n_points, 1):
        return None, new_pts

    # unbinned (ring-layout) plans skip the CSR patch — nothing reads it
    table = None if pln.table is None else \
        G.rebin_delta(spec, pln.table, inserts=ins, deletes=dels)
    new_plan = pad_plan(AidwPlan(
        spec=spec, table=table,
        points_xy=jnp.asarray(new_pts[:, :2]),
        values=jnp.asarray(new_pts[:, 2]),
        n_points=new_pts.shape[0], area=pln.area, cfg=pln.cfg))
    return new_plan, new_pts


def execute(pln: AidwPlan, queries_xy, *, timings: bool = False) -> AidwResult:
    """Per-query pass over a prebuilt :class:`AidwPlan` (eager staging).

    For the jitted, shape-bucketed, donation-aware path use
    :class:`repro.core.session.InterpolationSession`.
    """
    queries_xy = jnp.asarray(queries_xy)
    cfg = pln.cfg
    n_points = jnp.float32(pln.n_points)  # same op chain as the traced path

    t0 = time.perf_counter()
    res, r_obs = _stage1(pln.spec, cfg, pln.table, queries_xy)
    if timings:
        r_obs.block_until_ready()
    t1 = time.perf_counter()

    alpha = A.adaptive_alpha(r_obs, n_points, pln.area, alphas=cfg.alphas,
                             r_min=cfg.r_min, r_max=cfg.r_max)
    if cfg.stage2 == "local":
        values, zero = _stage2_local(res, pln.values, r_obs, alpha,
                                     n_points, pln.area, cfg)
    elif cfg.fused and cfg.stage2 == "tiled":
        values, zero = _stage2_fused(queries_xy, pln.points_xy, pln.values,
                                     r_obs, n_points, pln.area, cfg)
    else:
        values, zero = _stage2(queries_xy, pln.points_xy, pln.values, alpha,
                               cfg)
    if timings:
        values.block_until_ready()
    t2 = time.perf_counter()

    return AidwResult(
        values=values, alpha=alpha, r_obs=r_obs,
        overflow=int(jnp.sum(res.overflow)),
        timings={"knn": t1 - t0, "interp": t2 - t1} if timings else {},
        overflow_mask=res.overflow,
        zero_weight_mask=zero,
    )


def aidw_improved(points_xyz, queries_xy, cfg: AidwConfig = AidwConfig(),
                  *, timings: bool = False) -> AidwResult:
    """The paper's improved algorithm: grid kNN -> adaptive alpha -> Eq. (1).

    One-shot convenience: plans (grid build + binning) on EVERY call.  For
    repeated queries over a static dataset build the plan once — see
    :func:`plan`/:func:`execute` and ``repro.core.session``.
    """
    t0 = time.perf_counter()
    pln = plan(points_xyz, cfg, query_domain=np.asarray(queries_xy))
    res = execute(pln, queries_xy, timings=timings)
    if timings:
        # keep the historical split: 'knn' covers plan+bin+Stage-1
        res.timings["plan"] = time.perf_counter() - t0 \
            - res.timings["knn"] - res.timings["interp"]
        res.timings["knn"] += res.timings["plan"]
    return res


def aidw_original(points_xyz, queries_xy, cfg: AidwConfig = AidwConfig(),
                  *, timings: bool = False) -> AidwResult:
    """The Mei et al. (2015) baseline: brute-force global kNN + same Stage 2."""
    points_xyz = jnp.asarray(points_xyz)
    queries_xy = jnp.asarray(queries_xy)

    t0 = time.perf_counter()
    d2, _ = K.brute_knn(points_xyz[:, :2], queries_xy, cfg.k, cfg.knn_block)
    r_obs = K.mean_nn_distance(d2)
    if timings:
        r_obs.block_until_ready()
    t1 = time.perf_counter()

    spec = G.plan_grid(np.asarray(points_xyz[:, :2]), np.asarray(queries_xy),
                       cell_factor=cfg.cell_factor)
    alpha = A.adaptive_alpha(r_obs, points_xyz.shape[0], _study_area(spec),
                             alphas=cfg.alphas, r_min=cfg.r_min, r_max=cfg.r_max)
    values, zero = _stage2(queries_xy, points_xyz[:, :2], points_xyz[:, 2],
                           alpha, cfg)
    if timings:
        values.block_until_ready()
    t2 = time.perf_counter()

    return AidwResult(
        values=values, alpha=alpha, r_obs=r_obs,
        timings={"knn": t1 - t0, "interp": t2 - t1} if timings else {},
        zero_weight_mask=zero,
    )


def idw_standard(points_xyz, queries_xy, alpha: float = 2.0,
                 cfg: AidwConfig = AidwConfig()) -> jax.Array:
    """Shepard (1968): constant user-specified power parameter."""
    points_xyz = jnp.asarray(points_xyz)
    queries_xy = jnp.asarray(queries_xy)
    return _stage2(queries_xy, points_xyz[:, :2], points_xyz[:, 2],
                   jnp.full((queries_xy.shape[0],), alpha,
                            points_xyz.dtype), cfg)[0]
