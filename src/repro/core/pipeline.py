"""End-to-end AIDW pipelines — the paper's Figure 1 as composable JAX.

Variants (all numerically equivalent modulo accumulation order):

* :func:`aidw_improved`  — grid-based fast kNN (Stage 1) + weighted
  interpolation (Stage 2).  ``stage2='naive'`` uses the blocked pure-jnp
  path; ``stage2='tiled'`` uses the Pallas VMEM-tiled kernel (the TPU
  analogue of the paper's shared-memory tiled version).
* :func:`aidw_original`  — the authors' previous algorithm (Mei et al. 2015):
  brute-force global kNN + the same Stage 2.  This is the paper's baseline.
* :func:`idw_standard`   — Shepard (1968) constant-alpha IDW.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from . import aidw as A
from . import grid as G
from . import knn as K


@dataclass(frozen=True)
class AidwConfig:
    k: int = 15
    alphas: tuple = A.DEFAULT_ALPHAS
    r_min: float = A.DEFAULT_R_MIN
    r_max: float = A.DEFAULT_R_MAX
    cell_factor: float = 1.0       # scales Eq.(2) cell width (1.0 = paper)
    max_level: int | None = None   # None = auto from density (knn.auto_max_level)
    window: int = 256
    exact: bool = True             # certified 2-pass kNN (False = paper heuristic)
    knn_block: int = 4096
    interp_block: int = 1024
    stage2: Literal["naive", "tiled"] = "naive"
    tile_q: int = 256              # Pallas query-block
    tile_d: int = 512              # Pallas data-block
    interpret: bool = True         # CPU container: run Pallas in interpret mode


@dataclass
class AidwResult:
    values: jax.Array              # (n,) predictions
    alpha: jax.Array               # (n,) adaptive power parameter
    r_obs: jax.Array               # (n,) observed mean NN distance
    overflow: int = 0              # queries whose candidate window overflowed
    timings: dict = field(default_factory=dict)   # stage -> seconds


def _study_area(spec: G.GridSpec) -> float:
    return (spec.n_cols * spec.cell_width) * (spec.n_rows * spec.cell_width)


def _stage2(queries_xy, points_xy, values, alpha, cfg: AidwConfig):
    if cfg.stage2 == "tiled":
        from repro.kernels.aidw import ops as aidw_ops

        return aidw_ops.tiled_interpolate(
            queries_xy, points_xy, values, alpha,
            tile_q=cfg.tile_q, tile_d=cfg.tile_d, interpret=cfg.interpret,
        )
    return A.weighted_interpolate(queries_xy, points_xy, values, alpha,
                                  cfg.interp_block)


def aidw_improved(points_xyz, queries_xy, cfg: AidwConfig = AidwConfig(),
                  *, timings: bool = False) -> AidwResult:
    """The paper's improved algorithm: grid kNN -> adaptive alpha -> Eq. (1)."""
    points_xyz = jnp.asarray(points_xyz)
    queries_xy = jnp.asarray(queries_xy)
    px, py, pz = points_xyz[:, 0], points_xyz[:, 1], points_xyz[:, 2]

    t0 = time.perf_counter()
    spec = G.plan_grid(np.asarray(points_xyz[:, :2]), np.asarray(queries_xy),
                       cell_factor=cfg.cell_factor)
    table = G.bin_points(spec, px, py, pz)
    res = K.grid_knn(spec, table, queries_xy, cfg.k, cfg.max_level,
                     cfg.window, cfg.knn_block, cfg.exact)
    r_obs = K.mean_nn_distance(res.d2)
    if timings:
        r_obs.block_until_ready()
    t1 = time.perf_counter()

    alpha = A.adaptive_alpha(r_obs, points_xyz.shape[0], _study_area(spec),
                             alphas=cfg.alphas, r_min=cfg.r_min, r_max=cfg.r_max)
    values = _stage2(queries_xy, points_xyz[:, :2], pz, alpha, cfg)
    if timings:
        values.block_until_ready()
    t2 = time.perf_counter()

    return AidwResult(
        values=values, alpha=alpha, r_obs=r_obs,
        overflow=int(jnp.sum(res.overflow)),
        timings={"knn": t1 - t0, "interp": t2 - t1} if timings else {},
    )


def aidw_original(points_xyz, queries_xy, cfg: AidwConfig = AidwConfig(),
                  *, timings: bool = False) -> AidwResult:
    """The Mei et al. (2015) baseline: brute-force global kNN + same Stage 2."""
    points_xyz = jnp.asarray(points_xyz)
    queries_xy = jnp.asarray(queries_xy)

    t0 = time.perf_counter()
    d2, _ = K.brute_knn(points_xyz[:, :2], queries_xy, cfg.k, cfg.knn_block)
    r_obs = K.mean_nn_distance(d2)
    if timings:
        r_obs.block_until_ready()
    t1 = time.perf_counter()

    spec = G.plan_grid(np.asarray(points_xyz[:, :2]), np.asarray(queries_xy),
                       cell_factor=cfg.cell_factor)
    alpha = A.adaptive_alpha(r_obs, points_xyz.shape[0], _study_area(spec),
                             alphas=cfg.alphas, r_min=cfg.r_min, r_max=cfg.r_max)
    values = _stage2(queries_xy, points_xyz[:, :2], points_xyz[:, 2], alpha, cfg)
    if timings:
        values.block_until_ready()
    t2 = time.perf_counter()

    return AidwResult(
        values=values, alpha=alpha, r_obs=r_obs,
        timings={"knn": t1 - t0, "interp": t2 - t1} if timings else {},
    )


def idw_standard(points_xyz, queries_xy, alpha: float = 2.0,
                 cfg: AidwConfig = AidwConfig()) -> jax.Array:
    """Shepard (1968): constant user-specified power parameter."""
    points_xyz = jnp.asarray(points_xyz)
    queries_xy = jnp.asarray(queries_xy)
    return _stage2(queries_xy, points_xyz[:, :2], points_xyz[:, 2],
                   jnp.full((queries_xy.shape[0],), alpha, points_xyz.dtype), cfg)
