"""Even-grid construction — Stage 1 substrate of the improved AIDW algorithm.

Paper mapping (Mei, Xu & Xu 2016, §3.2.1-§3.2.3):

* "Creating an even grid"        -> :func:`plan_grid`   (host-side, static shapes)
* "Distributing points into cells" -> cell-id computation in :func:`bin_points`
* "Determining data points in each cell" (thrust sort_by_key +
  reduce_by_key/unique_by_key)   -> argsort + searchsorted CSR in
  :func:`bin_points`.  The paper's two segmented primitives (per-cell count and
  head index) collapse into one ``cell_start`` array: ``count[c] =
  cell_start[c+1] - cell_start[c]`` and ``head[c] = cell_start[c]``.

TPU adaptation: the CSR table is built with XLA's variadic sort and a
vectorized binary search instead of thrust segmented primitives — no atomics,
no dynamic allocation, identical result (see DESIGN.md §2).

Incremental rebinning (serving-scale extension): a mostly-static dataset under
high churn should not pay the full O(m log m) re-sort for a small delta.
:func:`bin_points` is therefore factored into the id computation plus a
reusable sort core (:func:`sort_core`), and :func:`rebin_delta` applies an
(inserts, deletes) delta directly to an existing :class:`CellTable`: the Δ
inserts are sorted alone (O(Δ log Δ)), merged into the sorted CSR arrays with
one vectorized insert (O(m) memcpy, no comparison sort), deleted rows are
tombstoned out, and the CSR offsets are rebuilt from per-cell delta counts
(O(n_cells + Δ)).  The result is ELEMENT-IDENTICAL to a full
:func:`bin_points` of the updated dataset on the same :class:`GridSpec`
(both sorts are stable, so per-cell point order matches too).

Tombstone deletes (``rebin_delta(..., tombstone=True)``): instead of
physically compacting the sorted arrays (an O(m) memcpy whose result must be
re-staged wholesale), a delete overwrites just the dead slots in place —
coords become :data:`TOMBSTONE_COORD` (squared distances overflow f32 to
``inf``, so Stage-1 top-k never selects them and Stage-2 IDW weights are an
exact ``0.0``), ``order`` becomes ``-1``, and ``cell_start`` is left
untouched.  The table's shape and every live slot's position are preserved,
which is what makes device-side delta staging O(Δ): only the dead slots
changed.  Dead slots keep their cell identity (they still occupy CSR range),
so later inserts land after them and :func:`purge_tombstones` — compaction,
triggered once :func:`tombstone_frac` crosses a threshold — recovers a table
element-identical to a fresh :func:`bin_points` of the live dataset.
"""

from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class GridSpec(NamedTuple):
    """Static description of the even grid (hashable: safe as a jit-static arg).

    The flattened cell id of cell (row, col) is ``row * n_cols + col`` — the
    1-D key transformation the paper argues for (single-key sorts are faster
    and need one array instead of two).
    """

    min_x: float
    min_y: float
    cell_width: float
    n_rows: int
    n_cols: int

    @property
    def n_cells(self) -> int:
        return self.n_rows * self.n_cols


class CellTable(NamedTuple):
    """CSR layout over grid cells: the paper's Figure 3 in two arrays.

    ``sx/sy/sz`` are the data points sorted by flattened cell id; the points of
    cell ``c`` occupy ``sx[cell_start[c]:cell_start[c + 1]]``.
    """

    sx: jax.Array          # (m,) sorted x coordinates
    sy: jax.Array          # (m,) sorted y coordinates
    sz: jax.Array          # (m,) sorted values
    cell_start: jax.Array  # (n_cells + 1,) int32 CSR offsets
    order: jax.Array       # (m,) int32: original index of each sorted point


def expected_nn_distance(n_points: float, area: float) -> float:
    """Eq. (2): expected nearest-neighbour distance of a random pattern."""
    return 1.0 / (2.0 * math.sqrt(n_points / area))


def plan_grid(
    points_xy: np.ndarray,
    queries_xy: np.ndarray | None = None,
    *,
    cell_width: float | None = None,
    cell_factor: float = 1.0,
    pad: float = 1e-6,
) -> GridSpec:
    """Host-side grid planning: bounding box + static row/col counts.

    The paper derives ``cellWidth`` from Eq. (2) (the expected NN distance);
    ``cell_factor`` scales it (1.0 = paper-faithful).  Runs eagerly because the
    grid dimensions determine downstream array shapes.
    """
    pts = np.asarray(points_xy, dtype=np.float64)
    if queries_xy is not None:
        pts = np.concatenate([pts, np.asarray(queries_xy, dtype=np.float64)], axis=0)
    min_x = float(pts[:, 0].min()) - pad
    max_x = float(pts[:, 0].max()) + pad
    min_y = float(pts[:, 1].min()) - pad
    max_y = float(pts[:, 1].max()) + pad
    area = max(max_x - min_x, 1e-30) * max(max_y - min_y, 1e-30)
    m = points_xy.shape[0]
    if cell_width is None:
        cell_width = cell_factor * expected_nn_distance(m, area)
    # int nCol = (maxX - minX + cellWidth) / cellWidth;   (paper §4.1.1)
    n_cols = int((max_x - min_x + cell_width) / cell_width)
    n_rows = int((max_y - min_y + cell_width) / cell_width)
    return GridSpec(min_x, min_y, float(cell_width), max(n_rows, 1), max(n_cols, 1))


def cell_ids(spec: GridSpec, x: jax.Array, y: jax.Array) -> jax.Array:
    """Flattened cell id per point (paper §4.1.2's col_idx/row_idx kernels)."""
    col = jnp.clip(((x - spec.min_x) / spec.cell_width).astype(jnp.int32), 0, spec.n_cols - 1)
    row = jnp.clip(((y - spec.min_y) / spec.cell_width).astype(jnp.int32), 0, spec.n_rows - 1)
    return row * spec.n_cols + col


# Trace-time counter: bin_points is jitted, so this increments only when the
# binning computation is (re)traced — a stable count across repeated session
# queries proves Stage-1 is never rebuilt (see tests/test_session.py).
_BIN_TRACES = [0]


def bin_traces() -> int:
    """How many times :func:`bin_points` has been (re)traced."""
    return _BIN_TRACES[0]


def sort_core(n_cells: int, ids: jax.Array, x: jax.Array, y: jax.Array,
              z: jax.Array) -> CellTable:
    """Stable sort by cell id + CSR offsets: the reusable heart of binning.

    Stability matters beyond determinism: it is what lets
    :func:`rebin_delta` reproduce a full re-sort with a merge (points of one
    cell keep their original relative order).
    """
    order = jnp.argsort(ids).astype(jnp.int32)
    sorted_ids = ids[order]
    # Vectorized binary search replaces segmented reduction/scan (Fig. 3).
    cell_start = jnp.searchsorted(
        sorted_ids, jnp.arange(n_cells + 1, dtype=jnp.int32), side="left"
    ).astype(jnp.int32)
    return CellTable(x[order], y[order], z[order], cell_start, order)


@partial(jax.jit, static_argnums=0)
def bin_points(spec: GridSpec, x: jax.Array, y: jax.Array, z: jax.Array) -> CellTable:
    """Sort points by cell id and build the CSR cell table.

    thrust::sort_by_key           -> argsort + take
    thrust::reduce_by_key (count) -> cell_start[c+1] - cell_start[c]
    thrust::unique_by_key (head)  -> cell_start[c]
    """
    _BIN_TRACES[0] += 1
    return sort_core(spec.n_cells, cell_ids(spec, x, y), x, y, z)


def cell_ids_host(spec: GridSpec, x: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Numpy mirror of :func:`cell_ids` (same f32 ops -> same ids bitwise)."""
    x = np.asarray(x)
    col = np.clip(((x - x.dtype.type(spec.min_x)) /
                   x.dtype.type(spec.cell_width)).astype(np.int32),
                  0, spec.n_cols - 1)
    row = np.clip(((np.asarray(y) - x.dtype.type(spec.min_y)) /
                   x.dtype.type(spec.cell_width)).astype(np.int32),
                  0, spec.n_rows - 1)
    return row.astype(np.int64) * spec.n_cols + col


def sorted_cell_ids(table: CellTable) -> np.ndarray:
    """Recover the sorted flattened cell ids from the CSR offsets (exact)."""
    cs = np.asarray(table.cell_start, dtype=np.int64)
    return np.repeat(np.arange(cs.shape[0] - 1, dtype=np.int64), np.diff(cs))


# Invocation counter for the incremental path (sibling of bin_traces: the
# session tests assert delta updates never touch the full sort core).
_DELTA_REBINS = [0]


def delta_rebins() -> int:
    """How many times :func:`rebin_delta` has run."""
    return _DELTA_REBINS[0]


# Dead-slot coordinate sentinel: (q - 1e30)^2 overflows float32 to +inf, so a
# tombstoned slot's d2 is inf — never in any top-k — and its IDW weight
# power(inf, -alpha/2) is an exact 0.0 (adding it to a partial sum is a
# bitwise no-op).  Matches the padding sentinel used by the sharded layouts.
TOMBSTONE_COORD = 1e30


def live_count(table: CellTable) -> int:
    """Number of live (non-tombstoned) points in a table."""
    order = np.asarray(table.order)
    m = int(np.asarray(table.cell_start)[-1])
    return int((order[:m] >= 0).sum())


def tombstone_frac(table: CellTable) -> float:
    """Fraction of table slots that are tombstones (0.0 for a fresh table)."""
    m = int(np.asarray(table.cell_start)[-1])
    return 1.0 - live_count(table) / m if m else 0.0


def purge_tombstones(spec: GridSpec, table: CellTable) -> CellTable:
    """Physically compact a tombstoned table.

    Element-identical to ``bin_points(spec, *live_dataset)``: a tombstone
    never reorders the surviving slots, so dropping the dead ones recovers
    exactly the stable-sorted fresh layout (``order`` is already remapped to
    the live dataset indexing by :func:`rebin_delta`).
    """
    m = int(np.asarray(table.cell_start)[-1])
    order = np.asarray(table.order)[:m]
    keep = order >= 0
    if keep.all():
        return table
    ids_sorted = sorted_cell_ids(table)
    counts = np.diff(np.asarray(table.cell_start, dtype=np.int64))
    counts = counts - np.bincount(ids_sorted[~keep], minlength=spec.n_cells)
    cell_start = np.concatenate(
        [np.zeros(1, np.int64), np.cumsum(counts)]).astype(np.int32)
    return CellTable(jnp.asarray(np.asarray(table.sx)[:m][keep]),
                     jnp.asarray(np.asarray(table.sy)[:m][keep]),
                     jnp.asarray(np.asarray(table.sz)[:m][keep]),
                     jnp.asarray(cell_start),
                     jnp.asarray(order[keep], jnp.int32))


def rebin_delta(spec: GridSpec, table: CellTable, inserts=None,
                deletes=None, *, insert_ids=None,
                tombstone: bool = False) -> CellTable:
    """Apply an (inserts, deletes) delta to an existing CSR cell table.

    ``inserts`` is an (Δ, 3) xyz array appended to the dataset; ``deletes``
    is a list of ORIGINAL dataset indices (values of ``table.order``) to
    remove.  Returns a table element-identical to
    ``bin_points(spec, *updated_dataset)`` where the updated dataset is the
    kept points in their original order followed by the inserts — including
    ``order``, which is remapped to index that updated dataset.

    ``insert_ids`` optionally supplies the inserts' flattened cell ids,
    bypassing :func:`cell_ids_host`.  The slab layer uses this to bin into
    a slab-LOCAL table with ids derived from the GLOBAL spec (global id
    minus the slab's row offset): recomputing them against a shifted local
    ``min_y`` would not be bitwise the same arithmetic, and a point on a
    cell boundary could land one row off from where the global binning put
    it.

    ``tombstone=True`` switches the delete path to in-place tombstones (see
    module docstring): dead slots get :data:`TOMBSTONE_COORD` coords,
    ``order == -1``, and ``cell_start`` is untouched, so only O(Δ) slots of
    the table change.  Delete indices always refer to the LIVE dataset
    indexing (tombstones are invisible), and the surviving ``order`` values
    are remapped exactly as in the physical path — so
    :func:`purge_tombstones` later recovers the fresh-bin layout bitwise.

    Cost: O(Δ log Δ) insert sort + O(m) tombstone/merge memcpy +
    O(n_cells + Δ) offset rebuild — no O(m log m) comparison sort.  Runs on
    the host (numpy): binning is already a host-side planning step, and a
    delta's data movement is memcpy-bound, not compute-bound.
    """
    _DELTA_REBINS[0] += 1
    counts = np.diff(np.asarray(table.cell_start, dtype=np.int64))
    # capacity-padded tables (repro.core.pipeline.pad_plan) carry sentinel
    # tail slots beyond the true point count cell_start[-1]; the delta
    # machinery operates on the EXACT arrays (array length must equal the
    # sum of cell counts) and the caller re-pads the result
    m = int(np.asarray(table.cell_start)[-1])
    sx = np.asarray(table.sx)[:m]
    sy = np.asarray(table.sy)[:m]
    sz = np.asarray(table.sz)[:m]
    order = np.asarray(table.order)[:m].astype(np.int64)

    # -- deletes: tombstone in place, or compact out of the sorted arrays ----
    if deletes is not None and np.size(deletes):
        dels = np.unique(np.asarray(deletes, dtype=np.int64))
        live = int((order >= 0).sum())
        if dels[0] < 0 or dels[-1] >= live:
            raise IndexError(f"delete index out of range [0, {live})")
        drop = np.isin(order, dels)          # order==-1 (dead) never matches
        if tombstone:
            # O(Δ) in-place: shapes, offsets and live positions all survive
            sx, sy, sz = sx.copy(), sy.copy(), sz.copy()
            sx[drop] = sy[drop] = np.float32(TOMBSTONE_COORD)
            sz[drop] = 0.0
            order = order.copy()
            order[drop] = -1
            alive = order >= 0
            order[alive] -= np.searchsorted(dels, order[alive])
            ids_sorted = None
        else:
            ids_sorted = sorted_cell_ids(table)
            counts = counts - np.bincount(ids_sorted[drop],
                                          minlength=spec.n_cells)
            keep = ~drop
            sx, sy, sz = sx[keep], sy[keep], sz[keep]
            ids_sorted = ids_sorted[keep]
            # original index -> index in the compacted (post-delete) dataset
            order = order[keep]
            order -= np.searchsorted(dels, order)
        m_kept = live - dels.size
    else:
        ids_sorted = None   # computed lazily; unneeded for pure appends
        m_kept = int((order >= 0).sum())

    # -- merge the sorted inserts --------------------------------------------
    if inserts is not None and np.size(inserts):
        ins = np.asarray(inserts)
        ix = ins[:, 0].astype(sx.dtype)
        iy = ins[:, 1].astype(sy.dtype)
        iz = ins[:, 2].astype(sz.dtype)
        iid = cell_ids_host(spec, ix, iy) if insert_ids is None \
            else np.asarray(insert_ids, dtype=np.int64)
        iorder = np.argsort(iid, kind="stable")
        ix, iy, iz, iid = ix[iorder], iy[iorder], iz[iorder], iid[iorder]
        if ids_sorted is None:
            ids_sorted = sorted_cell_ids(table)
        # side='right': within a cell, kept points (stable-sorted in original
        # order) come first, inserts after — exactly a stable full re-sort.
        pos = np.searchsorted(ids_sorted, iid, side="right")
        sx = np.insert(sx, pos, ix)
        sy = np.insert(sy, pos, iy)
        sz = np.insert(sz, pos, iz)
        order = np.insert(order, pos, m_kept + iorder)
        counts = counts + np.bincount(iid, minlength=spec.n_cells)

    cell_start = np.concatenate(
        [np.zeros(1, np.int64), np.cumsum(counts)]).astype(np.int32)
    return CellTable(jnp.asarray(sx), jnp.asarray(sy), jnp.asarray(sz),
                     jnp.asarray(cell_start), jnp.asarray(order, jnp.int32))
