"""InterpolationSession — amortized AIDW serving over a static dataset.

The paper's improved algorithm already factors into a one-time grid build
(Stage 1 substrate) and a per-query kNN + weighting pass, but the one-shot
:func:`repro.core.pipeline.aidw_improved` pays the build on every call.  For
the serving workload (heavy repeated query traffic, mostly-static data) this
session keeps the build resident and makes the per-query path cheap:

* ``plan once``   — grid planning + CSR binning run at construction (and on
  :meth:`update`), never per query.  The plan's arrays stay device-resident.
* ``bucketed jit`` — query batches are padded to power-of-two buckets, so a
  stream of odd-sized batches compiles ONE executable per bucket instead of
  one per distinct size.  Padding uses the batch's last query (edge mode):
  per-query results are independent, so the slice ``[:n]`` is bit-identical
  to an unpadded call (pipeline module docstring, 'Padding rules').
* ``donation``    — the padded query buffer is donated to the executable on
  backends that support it (not CPU), saving one allocation per batch.
  Plan arrays are never donated ('Donation rules').
* ``fused Stage 2`` — with ``AidwConfig(stage2='tiled', fused=True)`` the
  adaptive-alpha determination runs inside the Pallas weighting kernel: one
  launch for the whole Stage 2.

``stats`` exposes the amortization counters the tests assert on:
``stage1_builds`` (plan/update invocations), ``batches``/``queries`` served,
and ``bucket_hits``/``bucket_misses`` (compile-cache behaviour).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from . import pipeline as P

__all__ = ["InterpolationSession", "bucket_size"]


def bucket_size(n: int, min_bucket: int = 64) -> int:
    """Smallest power-of-two >= n, floored at ``min_bucket``."""
    if n <= 0:
        raise ValueError(f"query batch must be non-empty, got n={n}")
    b = min_bucket
    while b < n:
        b *= 2
    return b


class InterpolationSession:
    """Reusable AIDW query session over one (mostly static) dataset.

    >>> sess = InterpolationSession(points_xyz)
    >>> out = sess.query(queries_xy)          # jitted Stage-1 + Stage-2
    >>> out2 = sess.query(more_queries_xy)    # same bucket -> zero retrace
    >>> sess.update(new_points_xyz)           # re-bin once, keep executables
    """

    def __init__(self, points_xyz, cfg: P.AidwConfig = P.AidwConfig(), *,
                 query_domain=None, min_bucket: int = 64,
                 donate: bool | None = None):
        self.cfg = cfg
        self.min_bucket = int(min_bucket)
        self._query_domain = query_domain
        # CPU XLA cannot donate buffers; donating there only emits warnings.
        self._donate = (jax.default_backend() != "cpu") if donate is None \
            else bool(donate)
        self.stats = {"stage1_builds": 0, "batches": 0, "queries": 0,
                      "bucket_hits": 0, "bucket_misses": 0,
                      "last_plan_s": 0.0}
        self._seen_buckets: set[int] = set()
        self._plan: P.AidwPlan | None = None
        self.update(points_xyz)

    # -- dataset lifecycle ---------------------------------------------------

    @property
    def plan(self) -> P.AidwPlan:
        return self._plan

    def update(self, points_xyz) -> None:
        """Dataset refresh: re-plan + re-bin once; compiled executables are
        keyed on (GridSpec, cfg, shapes) and survive whenever those match."""
        t0 = time.perf_counter()
        self._plan = P.plan(points_xyz, self.cfg,
                            query_domain=self._query_domain)
        self.stats["stage1_builds"] += 1
        self.stats["last_plan_s"] = time.perf_counter() - t0

    # -- query path ----------------------------------------------------------

    def _bucket(self, n: int) -> int:
        b = bucket_size(n, self.min_bucket)
        if b in self._seen_buckets:
            self.stats["bucket_hits"] += 1
        else:
            self._seen_buckets.add(b)
            self.stats["bucket_misses"] += 1
        return b

    def query(self, queries_xy, *, timings: bool = False) -> P.AidwResult:
        """Interpolate one query batch; results are bit-identical to a cold
        :func:`repro.core.pipeline.execute` on the same plan."""
        q = jnp.asarray(queries_xy)
        n = q.shape[0]
        b = self._bucket(n)
        t0 = time.perf_counter()
        qp = jnp.pad(q, ((0, b - n), (0, 0)), mode="edge") if b != n else q
        pln = self._plan
        # donate only the padded copy we created — never the caller's array
        # (donation rules in the pipeline module docstring)
        fn = P._session_execute_donate if self._donate and qp is not q \
            else P._session_execute
        values, alpha, r_obs, overflow = fn(
            pln.spec, pln.cfg, pln.n_points, pln.area,
            pln.table, pln.points_xy, pln.values, qp)
        res = P.AidwResult(
            values=values[:n], alpha=alpha[:n], r_obs=r_obs[:n],
            overflow=int(jnp.sum(overflow[:n])),
        )
        if timings:
            res.values.block_until_ready()
            res.timings = {"query": time.perf_counter() - t0, "bucket": b}
        self.stats["batches"] += 1
        self.stats["queries"] += n
        return res
