"""InterpolationSession — amortized AIDW serving over a static dataset.

The paper's improved algorithm already factors into a one-time grid build
(Stage 1 substrate) and a per-query kNN + weighting pass, but the one-shot
:func:`repro.core.pipeline.aidw_improved` pays the build on every call.  For
the serving workload (heavy repeated query traffic, mostly-static data) this
session keeps the build resident and makes the per-query path cheap:

* ``plan once``   — grid planning + CSR binning run at construction (and on
  :meth:`update`), never per query.  The plan's arrays stay device-resident.
* ``bucketed jit`` — query batches are padded to power-of-two buckets, so a
  stream of odd-sized batches compiles ONE executable per bucket instead of
  one per distinct size.  Padding uses the batch's last query (edge mode):
  per-query results are independent, so the slice ``[:n]`` is bit-identical
  to an unpadded call (pipeline module docstring, 'Padding rules').
* ``donation``    — the padded query buffer is donated to the executable on
  backends that support it (not CPU), saving one allocation per batch.
  Plan arrays are never donated ('Donation rules').
* ``fused Stage 2`` — with ``AidwConfig(stage2='tiled', fused=True)`` the
  adaptive-alpha determination runs inside the Pallas weighting kernel: one
  launch for the whole Stage 2.  ``stage2='local'`` instead truncates
  Eq. (1) to the k merged Stage-1 neighbours (O(k) per query, identical
  r_obs/alpha, values within the documented far-field-tail tolerance;
  ``fused=True`` routes the neighbour gather + weighting through one
  Pallas launch).  Every layout supports it; ``grid_ring`` additionally
  drops its whole Stage-2 ring rotation.
* ``mesh``        — with ``mesh=``, one session serves queries across every
  device of the mesh ('Sharding rules'): the plan is placed once via
  :func:`repro.core.pipeline.shard_plan` (CSR table + points replicated;
  ``layout='ring'`` brute-force ring-shards the points when the dataset is
  too large to replicate; ``layout='grid_ring'`` ring-shards them behind
  per-slab CSR tables with a boundary-cell halo, keeping the paper's
  O(window) Stage-1 cost at O(m/P) memory) and each query batch is
  partitioned over all mesh axes.  Buckets are rounded per-device
  (power-of-two PER LANE times the device product), and replicated-layout
  results stay bit-identical per query to the single-device session on
  the same plan.
* ``AOT ladder``  — :meth:`precompile` lowers + compiles the whole
  power-of-two bucket ladder ahead of time via
  ``jax.jit(...).lower().compile()`` and stores the resulting ``Compiled``
  executables; :meth:`_run` dispatches to them directly, bypassing jit
  tracing AND the XLA compile layer entirely, so the first query of every
  precompiled bucket is a warm query.  ``warm=True`` additionally executes
  each ladder bucket once (exact bucket size) to warm the tiny eager
  helper ops around the executable (pad/slice/sum).  Stored executables
  carry a staleness signature (spec, cfg, shapes); a full re-plan clears
  them and falls back to the lazy jit path until the next
  :meth:`precompile`.
* ``delta update`` — ``update(inserts=..., deletes=...)`` (or
  ``deltas=(inserts, deletes)``) patches the resident CSR table in
  O(Δ log Δ + memcpy) via :func:`repro.core.grid.rebin_delta` instead of
  re-binning from scratch, keeping the grid spec and every compiled
  executable alive ('Incremental-binning rules'; falls back to a full
  re-plan on out-of-bbox inserts or oversized deltas).

``stats`` exposes the amortization counters the tests assert on:
``stage1_builds`` (full plan/update invocations), ``delta_updates``
(incremental updates that did NOT rebuild Stage 1), ``batches``/``queries``
served, ``bucket_hits``/``bucket_misses`` (compile-cache behaviour),
``devices`` (mesh width; 1 for a single-device session), and ``n_points``
(current dataset size — the serving scheduler keys its execute-time model
on it, and cluster telemetry reports it per host).

Observability (``repro.obs``): the session records its stage walls into a
:class:`repro.obs.Registry` (``session/plan_s`` with ``session/bin_s`` and
``session/staging_s`` sub-parts, ``session/compact_s``, and — when timing
or profiling a query — ``session/query_s`` / ``session/stage1_s`` /
``session/stage2_s``), and, when constructed with a ``tracer``, emits the
matching ``plan``/``bin``/``staging``/``compact``/``query``/``stage1``/
``stage2`` spans.  ``stats["last_plan_s"]`` and
``res.timings["query"]`` are kept as documented ALIASES of the newest
registry observation so pre-PR-8 consumers keep working.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import pipeline as P
from ..obs import Registry

__all__ = ["InterpolationSession", "bucket_size"]


def bucket_size(n: int, min_bucket: int = 64) -> int:
    """Smallest power-of-two >= n, floored at ``min_bucket``.

    ``min_bucket`` is rounded UP to a power of two first, so every returned
    bucket is a true power of two even for e.g. ``min_bucket=48`` (doubling
    from a non-power floor would yield 96, 192, ... and silently break the
    one-executable-per-bucket compile-cache story).
    """
    if n <= 0:
        raise ValueError(f"query batch must be non-empty, got n={n}")
    b = 1
    while b < min_bucket:
        b *= 2
    while b < n:
        b *= 2
    return b


class InterpolationSession:
    """Reusable AIDW query session over one (mostly static) dataset.

    >>> sess = InterpolationSession(points_xyz)
    >>> out = sess.query(queries_xy)          # jitted Stage-1 + Stage-2
    >>> out2 = sess.query(more_queries_xy)    # same bucket -> zero retrace
    >>> sess.update(new_points_xyz)           # re-bin once, keep executables
    >>> sess.update(inserts=new_rows, deletes=[3, 17])   # incremental re-bin

    With ``mesh=`` the same API serves the whole mesh: queries are sharded
    over every mesh axis and (replicated layout) results are bit-identical
    per query to the single-device session.
    """

    def __init__(self, points_xyz, cfg: P.AidwConfig = P.AidwConfig(), *,
                 query_domain=None, min_bucket: int = 64,
                 donate: bool | None = None, mesh=None,
                 layout: str = "replicated", ring_axis: str | None = None,
                 max_delta_frac: float = 0.25, ring_cap: int = 256,
                 tracer=None, registry: Registry | None = None):
        self.cfg = cfg
        # observability: the registry is always on (a record is a few dict
        # updates); spans only when a tracer is injected AND its sampler
        # admits the operation's trace
        self.tracer = tracer
        self.registry = registry if registry is not None else Registry()
        self.min_bucket = int(min_bucket)
        self._query_domain = query_domain
        self._mesh = mesh
        self._ring_cap = int(ring_cap)
        if mesh is not None and layout not in ("replicated", "ring",
                                               "grid_ring"):
            # no 'auto' here: the query path dispatches on the layout, so it
            # must be pinned before the first plan is placed
            raise ValueError(f"layout must be 'replicated', 'ring' or "
                             f"'grid_ring', got {layout!r}")
        self._layout = layout if mesh is not None else "single"
        self._ring_axis = ring_axis
        self._n_dev = int(mesh.devices.size) if mesh is not None else 1
        self.max_delta_frac = float(max_delta_frac)
        # CPU XLA cannot donate buffers; donating there only emits warnings.
        self._donate = (jax.default_backend() != "cpu") if donate is None \
            else bool(donate)
        self.stats = {"stage1_builds": 0, "delta_updates": 0, "batches": 0,
                      "queries": 0, "bucket_hits": 0, "bucket_misses": 0,
                      "last_plan_s": 0.0, "devices": self._n_dev,
                      "n_points": 0,
                      # ingest telemetry (flat int/float keys so the serving
                      # report's scalar filter forwards them; grid_ring
                      # fills them from SlabStaging/SlabPartition, other
                      # layouts report their honest full-restage bytes)
                      "staged_bytes": 0, "staged_bytes_total": 0,
                      "slabs_touched": 0, "full_restages": 0,
                      "ring_occupancy": 0.0, "ring_points": 0,
                      "tombstone_frac": 0.0, "compactions": 0,
                      "spilled_updates": 0,
                      # cold-start telemetry: distinct buckets with a live
                      # AOT executable (precompile) — 0 on lazy sessions
                      "aot_buckets": 0}
        self._seen_buckets: set[int] = set()
        # AOT bucket ladder: (bucket, donate) -> (Compiled, signature).
        # Entries whose signature no longer matches the resident plan are
        # ignored by _run (and cleared wholesale on full re-plans).
        self._aot: dict[tuple[int, bool], tuple] = {}
        self._plan: P.AidwPlan | None = None
        self._splan: P.ShardedAidwPlan | None = None
        # grid_ring only: per-query Stage-1 candidate counts of the LAST
        # batch (device array) — the measured O(window) evidence the ring
        # benchmark / analytic census read
        self.last_stage1_candidates = None
        # host-side (m, 3) mirror of the dataset: delta updates reconstruct
        # from it instead of pulling the plan arrays off the device
        self._host_pts = None
        self.update(points_xyz)

    # -- dataset lifecycle ---------------------------------------------------

    @property
    def plan(self) -> P.AidwPlan:
        return self._plan

    @property
    def sharded_plan(self) -> P.ShardedAidwPlan | None:
        return self._splan

    def _place(self) -> None:
        """(Re)place the current plan on the mesh (no-op single-device)."""
        if self._mesh is None:
            return
        self._splan = P.shard_plan(self._plan, self._mesh, self._layout,
                                   ring_axis=self._ring_axis,
                                   ring_cap=self._ring_cap,
                                   host_points=self._host_pts)
        if self._splan.layout == "replicated":
            self._plan = self._splan.base   # replicated arrays serve both
        self._refresh_ingest_stats()

    def _refresh_ingest_stats(self, rep=None) -> None:
        """Pull the ingest-path counters into the flat ``stats`` dict."""
        sp = self._splan
        if sp is None or sp.layout != "grid_ring" or sp.staging is None:
            return
        st, part = sp.staging, sp.slab_part
        self.stats["staged_bytes"] = int(st.staged_bytes)
        self.stats["staged_bytes_total"] = int(st.staged_bytes_total)
        self.stats["slabs_touched"] = int(st.slabs_touched)
        self.stats["full_restages"] = int(st.full_restages)
        self.stats["ring_occupancy"] = float(part.ring_occupancy())
        self.stats["ring_points"] = int(part.ring_size())
        self.stats["tombstone_frac"] = float(part.tombstone_frac())
        self.stats["compactions"] = int(part.compactions)
        if rep is not None and rep.spilled:
            self.stats["spilled_updates"] += 1
        # registry mirror (fleet merge modes match cluster/telemetry.py:
        # byte/point totals are additive across hosts, occupancy/tombstone
        # ratios are high-waters)
        reg = self.registry
        reg.set("ingest/staged_bytes", self.stats["staged_bytes"],
                merge="sum")
        reg.set("ingest/staged_bytes_total",
                self.stats["staged_bytes_total"], merge="sum")
        reg.set("ingest/ring_points", self.stats["ring_points"], merge="sum")
        reg.set("ingest/compactions", self.stats["compactions"], merge="sum")
        reg.set("ingest/ring_occupancy", self.stats["ring_occupancy"],
                merge="max")
        reg.set("ingest/tombstone_frac", self.stats["tombstone_frac"],
                merge="max")

    def compact(self) -> None:
        """Background compaction epoch: fold every hot ring into the slab
        CSRs and purge tombstones (``repro.core.slab`` LSM contract).  The
        logical dataset is unchanged; after this, warm grid_ring queries
        are bitwise a fresh session's.  No-op on other layouts (their
        updates restage eagerly — there is nothing to fold)."""
        if self._layout != "grid_ring" or self._splan is None:
            return
        clk = self.tracer.clock if self.tracer is not None \
            else time.perf_counter
        tid = self.tracer.new_trace() if self.tracer is not None else None
        t0 = clk()
        self._splan, rep = P.grid_ring_plan_compact(self._splan)
        # fence: the compaction wall covers the restage, not its dispatch
        jax.block_until_ready(self._splan.slab_arrays)
        # compaction may regrow slab capacities; stale AOT executables are
        # shape-specialized, so drop them (signature check would skip them
        # anyway — clearing keeps the compiled_buckets gauge honest)
        self._aot_invalidate()
        t1 = clk()
        self.registry.observe("session/compact_s", t1 - t0)
        if tid is not None:
            self.tracer.record("compact", t0, t1, trace_id=tid)
        self._refresh_ingest_stats(rep)

    def update(self, points_xyz=None, *, inserts=None, deletes=None,
               deltas=None) -> None:
        """Dataset refresh.

        Full (``points_xyz``): re-plan + re-bin once; compiled executables
        are keyed on (GridSpec, cfg, shapes) and survive whenever those
        match.  Incremental (``inserts``/``deletes``/``deltas``): patch the
        CSR table in place, keeping the grid spec and ALL executables; falls
        back to a full re-plan per the pipeline's incremental-binning rules.
        """
        if deltas is not None:
            inserts, deletes = deltas
        has_delta = inserts is not None or deletes is not None
        if points_xyz is not None and has_delta:
            raise ValueError(
                "pass either a full dataset or inserts/deletes, not both")
        if points_xyz is None and not has_delta:
            raise ValueError(
                "update() needs a full dataset or inserts/deletes")
        clk = self.tracer.clock if self.tracer is not None \
            else time.perf_counter
        tid = self.tracer.new_trace() if self.tracer is not None else None
        t0 = clk()
        bin_t: dict = {}        # pipeline fills 'bin_s' on full re-plans
        t_stage = None          # (start, end) of the device staging sub-span
        if points_xyz is None and self._plan is not None:
            new_plan, new_pts = P.plan_delta(
                self._plan, inserts, deletes,
                max_delta_frac=self.max_delta_frac,
                host_points=self._host_pts)
            self._host_pts = new_pts
            if new_plan is not None:
                self._plan = new_plan
                ts0 = clk()
                if self._layout == "grid_ring" and self._splan is not None:
                    # shard-aware LSM delta: inserts land in the owning
                    # slabs' hot rings, deletes tombstone CSR slots in
                    # place, and the resident device packet is PATCHED
                    # per the delta report (O(Δ + touched-slab) staged
                    # bytes) — spec, slab geometry and compiled executor
                    # all survive
                    self._splan, rep = P.grid_ring_plan_delta(
                        self._splan, new_plan, inserts, deletes)
                    # fence: the staging wall must cover the upload, not
                    # just its dispatch (obs clock/fencing contract)
                    jax.block_until_ready(self._splan.slab_arrays)
                    t_stage = (ts0, clk())
                    self._refresh_ingest_stats(rep)
                else:
                    self._place()
                    t_stage = (ts0, clk())
                    nb = int(new_plan.points_xy.nbytes
                             + new_plan.values.nbytes)
                    if new_plan.table is not None:
                        nb += sum(int(np.asarray(a).nbytes)
                                  for a in new_plan.table)
                    # honest O(m) restage accounting for non-LSM layouts
                    self.stats["staged_bytes"] = nb
                    self.stats["staged_bytes_total"] += nb
                self.stats["delta_updates"] += 1
                self.stats["n_points"] = int(new_plan.n_points)
                self._finish_update(t0, clk, tid, bin_t, t_stage)
                return
            points_xyz = new_pts        # fallback: full re-plan below
        elif points_xyz is None:
            raise ValueError("first update needs the full dataset")
        else:
            self._host_pts = np.asarray(points_xyz)
        # the ring executors never read the global CSR table; skip the full
        # sort (grid_ring builds PER-SLAB tables in shard_plan instead)
        self._plan = P.plan(points_xyz, self.cfg,
                            query_domain=self._query_domain,
                            bin=self._layout in ("single", "replicated"),
                            timings=bin_t)
        if self._mesh is not None:
            ts0 = clk()
            self._place()
            t_stage = (ts0, clk())
        else:
            self._place()
        self.stats["stage1_builds"] += 1
        self.stats["n_points"] = int(self._plan.n_points)
        # full re-plan: spec/area/capacity may all have moved — every AOT
        # executable is specialized on them, so the ladder must recompile
        self._aot_invalidate()
        self._finish_update(t0, clk, tid, bin_t, t_stage)

    def _finish_update(self, t0, clk, tid, bin_t, t_stage) -> None:
        """Close out one :meth:`update`: registry stage walls, the
        ``stats["last_plan_s"]`` alias, and (sampled) plan/bin/staging
        spans."""
        t1 = clk()
        dur = t1 - t0
        self.registry.observe("session/plan_s", dur)
        if bin_t.get("bin_s"):
            self.registry.observe("session/bin_s", bin_t["bin_s"])
        if t_stage is not None:
            self.registry.observe("session/staging_s",
                                  t_stage[1] - t_stage[0])
        # documented alias of the newest session/plan_s observation
        self.stats["last_plan_s"] = dur
        if tid is not None:
            root = self.tracer.record("plan", t0, t1, trace_id=tid)
            if bin_t.get("bin_s"):
                # the CSR build runs at the head of plan(); anchor it there
                self.tracer.record("bin", t0, t0 + bin_t["bin_s"],
                                   trace_id=tid, parent_id=root)
            if t_stage is not None:
                self.tracer.record("staging", t_stage[0], t_stage[1],
                                   trace_id=tid, parent_id=root)

    # -- query path ----------------------------------------------------------

    def _bucket_for(self, n: int) -> int:
        """Pure bucket math (no hit/miss accounting): the padded batch size
        a batch of ``n`` queries dispatches at under this session's mesh."""
        if self._n_dev == 1:
            return bucket_size(n, self.min_bucket)
        # power-of-two per lane, divisible by the device product globally
        per = -(-n // self._n_dev)
        return bucket_size(per, max(1, self.min_bucket // self._n_dev)) \
            * self._n_dev

    def _bucket(self, n: int) -> int:
        b = self._bucket_for(n)
        if b in self._seen_buckets:
            self.stats["bucket_hits"] += 1
        else:
            self._seen_buckets.add(b)
            self.stats["bucket_misses"] += 1
        return b

    # -- AOT bucket ladder ---------------------------------------------------

    def bucket_ladder(self, max_queries: int) -> list[int]:
        """Every bucket the session can dispatch for batches up to
        ``max_queries``: doubling powers of two (times the device product on
        a mesh) from the minimum bucket up to ``_bucket_for(max_queries)``."""
        top = self._bucket_for(int(max_queries))
        b = self._bucket_for(1)
        out = [b]
        while b < top:
            b *= 2
            out.append(b)
        return out

    def _aot_signature(self) -> tuple:
        """Staleness witness for stored ``Compiled`` executables: the static
        jit arguments plus the shapes/dtypes of every captured plan array.
        An AOT entry is only dispatched while its signature matches the
        resident plan — delta updates preserve it (n_points is traced),
        full re-plans and capacity-bucket moves change it."""
        pln = self._plan
        if self._layout == "grid_ring":
            sp = self._splan
            arr = sp.slab_arrays
            return ("grid_ring", pln.spec, pln.cfg, sp.rps, sp.halo,
                    sp.max_level,
                    tuple((k, arr[k].shape, str(arr[k].dtype))
                          for k in sorted(arr)))
        if self._layout == "ring":
            sp = self._splan
            return ("ring", pln.cfg, tuple(sp.ring_points.shape))
        table_sig = tuple((tuple(a.shape), str(a.dtype))
                          for a in jax.tree_util.tree_leaves(pln.table))
        return (self._layout, pln.spec, pln.cfg, pln.area, table_sig,
                tuple(pln.points_xy.shape), tuple(pln.values.shape))

    def _aot_invalidate(self) -> None:
        self._aot.clear()
        self.stats["aot_buckets"] = 0
        self.registry.set("compiled_buckets", 0, merge="max")

    def _lower(self, qp, donate: bool):
        """Lower the active layout's executor for one padded bucket; the
        caller ``.compile()``s the result.  Static arguments are baked into
        the lowering — the stored ``Compiled`` is called with the DYNAMIC
        arguments only (mirrors the jit call in :meth:`_run`)."""
        pln = self._plan
        if self._layout == "grid_ring":
            sp = self._splan
            fn = P.grid_ring_session_execute(
                sp.mesh, sp.ring_axis, pln.cfg, pln.spec, sp.rps, sp.halo,
                sp.max_level)
            arr = sp.slab_arrays
            return fn.lower(
                arr["sx"], arr["sy"], arr["sz"], arr["cell_start"],
                arr["row_lo"], arr["bx"], arr["by"], arr["bz"],
                arr["rx"], arr["ry"], arr["rz"], qp,
                jnp.float32(pln.n_points), jnp.float32(pln.area))
        if self._layout == "ring":
            sp = self._splan
            fn = P.ring_session_execute(sp.mesh, sp.ring_axis, pln.cfg)
            return fn.lower(sp.ring_points, qp, jnp.float32(pln.n_points),
                            jnp.float32(pln.area))
        if self._mesh is not None:
            fn = P.sharded_session_execute(self._mesh, donate)
        else:
            fn = P._session_execute_donate if donate else P._session_execute
        return fn.lower(pln.spec, pln.cfg, pln.area,
                        pln.table, pln.points_xy, pln.values, qp,
                        pln.n_points)

    def precompile(self, max_queries: int | None = None, buckets=None,
                   warm: bool = False,
                   compiler_options: dict | None = None) -> list[int]:
        """Ahead-of-time compile the bucket ladder for the ACTIVE layout.

        Lowers + compiles every (query-bucket × current-capacity-bucket)
        executable via ``jit(...).lower().compile()`` and stores the
        ``Compiled`` objects; subsequent :meth:`query` calls of those
        buckets dispatch straight to them — no trace, no XLA compile, warm
        from the first hit.  Pass ``max_queries=`` to cover the doubling
        ladder up to that batch size (:meth:`bucket_ladder`) or an explicit
        ``buckets=`` iterable (each entry is rounded to its bucket).  Donate
        variants are compiled alongside when the backend donates.

        ``warm=True`` additionally EXECUTES each bucket once on dummy
        queries (exact bucket size, results discarded) so the tiny eager
        helper ops around the executable — pad/slice/sum — are compiled
        too; leave it False when another thread owns device execution (the
        async server routes its warm batches through the worker instead).
        ``compiler_options`` pass through to ``Lowered.compile`` — the
        server's background prewarm uses
        :func:`repro.runtime.compile_cache.background_compile_options` to
        keep CPU codegen off the serving cores (options are part of the
        persistent-cache key; see that function's docstring).

        Each compile wall lands in the ``session/compile_s`` histogram; the
        ``compiled_buckets`` gauge and ``stats["aot_buckets"]`` track the
        distinct buckets with a live executable.  Returns the sorted bucket
        list covered by this call."""
        if buckets is None:
            if max_queries is None:
                raise ValueError(
                    "precompile() needs max_queries= or buckets=")
            buckets = self.bucket_ladder(max_queries)
        buckets = sorted({self._bucket_for(int(b)) for b in buckets})
        sig = self._aot_signature()
        donates = (False, True) \
            if (self._donate and self._layout in ("single", "replicated")) \
            else (False,)
        for b in buckets:
            qp = jnp.zeros((b, 2), jnp.float32)
            for dn in donates:
                ent = self._aot.get((b, dn))
                if ent is not None and ent[1] == sig:
                    continue
                t0 = time.perf_counter()
                self._aot[(b, dn)] = (
                    self._lower(qp, dn).compile(
                        compiler_options=compiler_options), sig)
                self.registry.observe("session/compile_s",
                                      time.perf_counter() - t0)
            # precompiled buckets are warm by construction, not misses
            self._seen_buckets.add(b)
        live = {b for (b, _d), (_c, s) in self._aot.items() if s == sig}
        self.stats["aot_buckets"] = len(live)
        self.registry.set("compiled_buckets", len(live), merge="max")
        if warm:
            for b in buckets:
                self.query(np.tile(np.asarray(self._host_pts[0, :2],
                                              dtype=np.float32), (b, 1)))
        return buckets

    def _run(self, qp, donate: bool):
        """Dispatch one padded bucket to the right executable.

        An AOT entry from :meth:`precompile` whose staleness signature still
        matches the resident plan wins (no trace, no compile layer); every
        other case falls back to the lazy jit path.  Every branch returns
        the same 5-tuple:
        ``(values, alpha, r_obs, overflow_mask, zero_weight_mask)``."""
        pln = self._plan
        dn = bool(donate) if self._layout in ("single", "replicated") \
            else False
        ent = self._aot.get((int(qp.shape[0]), dn))
        aot = ent[0] if ent is not None \
            and ent[1] == self._aot_signature() else None
        if self._layout == "grid_ring":
            sp = self._splan
            fn = aot if aot is not None else P.grid_ring_session_execute(
                sp.mesh, sp.ring_axis, pln.cfg, pln.spec, sp.rps, sp.halo,
                sp.max_level)
            arr = sp.slab_arrays
            values, alpha, r_obs, overflow, cand, zero = fn(
                arr["sx"], arr["sy"], arr["sz"], arr["cell_start"],
                arr["row_lo"], arr["bx"], arr["by"], arr["bz"],
                arr["rx"], arr["ry"], arr["rz"], qp,
                jnp.float32(pln.n_points), jnp.float32(pln.area))
            # Stage-1 candidate counts (device array; no sync here — the
            # benchmark census reads it after the batch materializes)
            self.last_stage1_candidates = cand
            return values, alpha, r_obs, overflow, zero
        if self._layout == "ring":
            sp = self._splan
            fn = aot if aot is not None \
                else P.ring_session_execute(sp.mesh, sp.ring_axis, pln.cfg)
            values, alpha, r_obs, zero = fn(
                sp.ring_points, qp, jnp.float32(pln.n_points),
                jnp.float32(pln.area))
            return values, alpha, r_obs, jnp.zeros(qp.shape[0], bool), zero
        if aot is not None:
            # statics (spec, cfg, area) were baked in at lower time
            return aot(pln.table, pln.points_xy, pln.values, qp,
                       pln.n_points)
        if self._mesh is not None:
            fn = P.sharded_session_execute(self._mesh, donate)
        else:
            fn = P._session_execute_donate if donate else P._session_execute
        return fn(pln.spec, pln.cfg, pln.area,
                  pln.table, pln.points_xy, pln.values, qp, pln.n_points)

    def knn(self, queries_xy):
        """Stage 1 only: (d2 (n, k) ascending, neighbour VALUES z (n, k),
        overflow mask) against THIS session's dataset — a shard host's
        local top-k heap for the serving fleet's client-side k-way merge
        (``repro.serving.cluster.fleet.ShardedAidwCluster``; local Stage-2
        mode finishes the query from the merged (d2, z) heap alone).
        Needs a binned plan (single-device or replicated layout)."""
        if self._plan.table is None:
            raise ValueError(
                "shard kNN needs a binned plan (single/replicated layout)")
        q = jnp.asarray(queries_xy)
        n = q.shape[0]
        b = self._bucket(n)
        qp = jnp.pad(q, ((0, b - n), (0, 0)), mode="edge") if b != n else q
        d2, z, ovf = P._shard_knn_execute(
            self._plan.spec, self._plan.cfg, self._plan.table,
            self._plan.values, qp)
        return d2[:n], z[:n], ovf[:n]

    def partial_interpolate(self, queries_xy, alpha):
        """Stage-2 partial sums (sum w*z, sum w) of Eq. (1) over THIS
        session's dataset at a caller-supplied per-query ``alpha`` — the
        fleet sums these across shards before the one global division."""
        q = jnp.asarray(queries_xy)
        a = jnp.asarray(alpha)
        n = q.shape[0]
        b = self._bucket(n)
        if b != n:
            q = jnp.pad(q, ((0, b - n), (0, 0)), mode="edge")
            a = jnp.pad(a, (0, b - n), mode="edge")
        swz, sw = P._shard_partial_execute(
            self._plan.cfg, self._plan.points_xy, self._plan.values, q, a)
        return swz[:n], sw[:n]

    def query(self, queries_xy, *, timings: bool = False,
              profile: bool = False) -> P.AidwResult:
        """Interpolate one query batch; (single-device and replicated-mesh
        layouts) results are bit-identical to a cold
        :func:`repro.core.pipeline.execute` on the same plan.

        ``timings=True`` fences the result and reports
        ``res.timings={"query": wall_s, "bucket": b}`` (the ``query`` key
        is the documented alias of the ``session/query_s`` registry
        histogram, which records the same wall).  ``profile=True`` instead
        runs Stage 1 and Stage 2 as two separately-jitted, individually
        FENCED launches and adds ``stage1``/``stage2`` walls to
        ``res.timings`` (recorded into ``session/stage1_s`` /
        ``session/stage2_s``) — honest per-stage attribution at the cost
        of losing cross-stage XLA fusion, so ``stage1 + stage2`` may
        exceed the fused path's ``query`` wall; needs a binned plan
        (single/replicated layout).
        """
        q = jnp.asarray(queries_xy)
        n = q.shape[0]
        b = self._bucket(n)
        clk = self.tracer.clock if self.tracer is not None \
            else time.perf_counter
        t0 = clk()
        qp = jnp.pad(q, ((0, b - n), (0, 0)), mode="edge") if b != n else q
        if profile:
            res = self._query_profiled(qp, n, b, clk, t0)
        else:
            # donate only the padded copy we created — never the caller's
            # array (donation rules in the pipeline module docstring)
            values, alpha, r_obs, overflow, zero = self._run(
                qp, self._donate and qp is not q)
            res = P.AidwResult(
                values=values[:n], alpha=alpha[:n], r_obs=r_obs[:n],
                overflow=int(jnp.sum(overflow[:n])),
                overflow_mask=overflow[:n],
                zero_weight_mask=zero[:n],
            )
            if timings:
                res.values.block_until_ready()
                dur = clk() - t0
                self.registry.observe("session/query_s", dur)
                res.timings = {"query": dur, "bucket": b}
        self.stats["batches"] += 1
        self.stats["queries"] += n
        return res

    def _query_profiled(self, qp, n: int, b: int, clk, t0) -> P.AidwResult:
        """Stage-split query: two jitted launches, each fenced, so the
        per-stage walls are honest (obs fencing contract); emits
        stage1/stage2 spans under one sampled ``query`` root."""
        pln = self._plan
        if pln.table is None:
            raise ValueError(
                "profile=True needs a binned plan (single/replicated "
                "layout)")
        d2, idx, cand, ovf, r_obs = P._stage1_profile_execute(
            pln.spec, pln.cfg, pln.table, qp)
        jax.block_until_ready((d2, idx, cand, ovf, r_obs))
        t1 = clk()
        values, alpha, r_obs, overflow, zero = P._stage2_profile_execute(
            pln.cfg, pln.points_xy, pln.values, qp, d2, idx, cand, ovf,
            r_obs, jnp.float32(pln.n_points), jnp.float32(pln.area))
        jax.block_until_ready(values)
        t2 = clk()
        res = P.AidwResult(
            values=values[:n], alpha=alpha[:n], r_obs=r_obs[:n],
            overflow=int(jnp.sum(overflow[:n])),
            overflow_mask=overflow[:n],
            zero_weight_mask=zero[:n],
        )
        self.registry.observe("session/stage1_s", t1 - t0)
        self.registry.observe("session/stage2_s", t2 - t1)
        self.registry.observe("session/query_s", t2 - t0)
        res.timings = {"query": t2 - t0, "stage1": t1 - t0,
                       "stage2": t2 - t1, "bucket": b}
        if self.tracer is not None:
            tid = self.tracer.new_trace()
            if tid is not None:
                root = self.tracer.record("query", t0, t2, trace_id=tid)
                self.tracer.record("stage1", t0, t1, trace_id=tid,
                                   parent_id=root)
                self.tracer.record("stage2", t1, t2, trace_id=tid,
                                   parent_id=root)
        return res
