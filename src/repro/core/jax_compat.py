"""Version-compat shims over multi-device jax APIs (0.4.x <-> 0.5+).

Sibling of ``repro.kernels.pallas_compat``: the distributed layer imports
these symbols from here so it runs unmodified on both sides of the API moves.

* ``shard_map`` — promoted from ``jax.experimental.shard_map`` to a top-level
  ``jax.shard_map`` after 0.4.x.
* ``pvary``     — introduced alongside the varying-manual-axes (check_vma)
  rework; on 0.4.x shard_map there is no varying-axes tracking to annotate,
  so the shim is the identity.
"""

from __future__ import annotations

import jax

try:
    shard_map = jax.shard_map
except AttributeError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map


def pvary(x, axis_name):
    """``jax.lax.pvary`` where it exists; identity on 0.4.x."""
    fn = getattr(jax.lax, "pvary", None)
    return fn(x, axis_name) if fn is not None else x


def make_auto_mesh(shape, axes):
    """``jax.make_mesh`` with explicit Auto axis types where the
    ``jax.sharding.AxisType`` enum exists; 0.4.x meshes are always Auto."""
    try:
        from jax.sharding import AxisType
    except ImportError:  # jax 0.4.x
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def set_mesh(mesh):
    """Ambient-mesh context manager: ``jax.set_mesh`` where it exists; on
    0.4.x the classic ``with mesh:`` enters the same thread-local context."""
    fn = getattr(jax, "set_mesh", None)
    return fn(mesh) if fn is not None else mesh


def get_ambient_mesh():
    """The mesh installed by :func:`set_mesh`, or None/empty outside one.

    ``jax.sharding.get_abstract_mesh`` where it exists; the thread-local
    physical mesh on 0.4.x (same emptiness/axis_names surface).
    """
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        return fn()
    from jax._src import mesh as mesh_lib

    return mesh_lib.thread_resources.env.physical_mesh


__all__ = ["shard_map", "pvary", "make_auto_mesh", "set_mesh",
           "get_ambient_mesh"]
