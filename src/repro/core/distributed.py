"""Distributed AIDW — the paper's algorithm at pod scale.

The paper parallelizes over queries on ONE GPU (one thread per interpolated
point) and replicates all data points.  At 1000+-chip scale neither the data
points nor the queries fit (or should sit) on one chip.  Two schemes:

* :func:`query_sharded_aidw` — queries sharded over the whole mesh, data
  points replicated.  Zero communication (embarrassingly parallel, the
  paper's own structure); right when m is small and n is huge.

* :func:`make_ring_aidw` — **domain-decomposed / ring AIDW** (beyond-paper,
  DESIGN.md §2): data points are sharded into P blocks along a ring axis;
  queries are sharded over the remaining mesh axes (and the ring axis).  Both
  stages then rotate the data blocks around the ring with
  ``lax.ppermute``:

    - Stage 1 (kNN): each device keeps a running top-k of squared distances
      between its local queries and the rotating data block — after P steps
      every query has seen every data point.  (Same merge pattern as the
      in-kernel k-selection.)
    - Stage 2 (Eq. 1): each device accumulates partial (sum w*z, sum w)
      against the rotating block — the numerator/denominator accumulation of
      ring attention, applied to inverse-distance weights.

  Per-chip memory is O(m/P + n/(P*Q)); the collective is a neighbour
  permute (contention-free on a TPU torus), and XLA overlaps the permute
  with the local distance/weight compute.  Padding points are placed at
  +PAD_COORD so they contribute inf distance / zero weight to both stages.

* :func:`make_grid_ring_aidw` — **grid-aware ring AIDW** (PR 5): same data
  decomposition and rotation as the ring scheme, but Stage 1 keeps the
  paper's GRID search.  Each rotating block ships its slab's CSR cell
  table (built by :class:`repro.core.slab.SlabPartition`: the global even
  grid cut into row slabs with a halo ring of boundary cells), and the
  ring step only evaluates candidates from the query's expanding search
  window instead of the whole block — O(window) candidate distances per
  query instead of O(m), restoring the paper's headline Stage-1 cost at
  O(m/P + boundary-halo) memory per device.  Per-slab top-k results are
  k-way merged into the running neighbour heap (the same
  concatenate-and-top-k merge as the brute step), with an exactly-once
  contribution contract and an overflow-excuse certificate
  (:func:`repro.core.knn._slab_query_knn`) so merged results match the
  replicated layout within the SAME certification story — bit-identical
  d2/r_obs/alpha for queries whose certified window closes inside one
  slab (incl. its halo), ~1e-5 f32 accumulation tolerance on the
  interpolated values (Stage 2 sums slab partials in rotation order).
  Comms per step: one neighbour permute of the slab packet — points, CSR
  offsets, row offset — O(m/P + boundary) bytes, same wire profile as the
  brute ring plus the O(n_cells/P) offset array.

Both ring builders accept ``stage2_local=True`` (the session's
``AidwConfig(stage2='local')``): Stage 1 co-merges the rotating blocks' data
VALUES alongside the distances through the same ``top_k`` selection, and
Eq. (1) is evaluated over just those k merged neighbours after the scan —
the Stage-2 rotation disappears entirely (O(window + k) per query in the
grid-aware ring).  r_obs/alpha are bit-identical to global mode by
construction; the interpolated values differ by the truncated far-field
tail (see ``repro.core.aidw``).
"""

from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import aidw as A
from .jax_compat import pvary, shard_map

PAD_COORD = 1e30


def pad_to_multiple(arr: jax.Array, multiple: int, axis: int = 0,
                    value: float = PAD_COORD) -> jax.Array:
    """Pad ``axis`` up to a multiple; AIDW-safe sentinel coordinates."""
    pad = (-arr.shape[axis]) % multiple
    if pad == 0:
        return arr
    widths = [(0, 0)] * arr.ndim
    widths[axis] = (0, pad)
    return jnp.pad(arr, widths, constant_values=value)


def query_sharded_aidw(mesh: Mesh, points_xyz, queries_xy, *, k: int = 15,
                       alphas=A.DEFAULT_ALPHAS, cfg=None):
    """Queries sharded over every mesh axis; data replicated (paper's scheme)."""
    from .pipeline import AidwConfig, aidw_improved

    cfg = cfg or AidwConfig(k=k, alphas=alphas)
    axes = tuple(mesh.axis_names)
    n_dev = mesh.devices.size
    qs = pad_to_multiple(jnp.asarray(queries_xy), n_dev)
    qs = jax.device_put(qs, NamedSharding(mesh, P(axes, None)))
    pts = jax.device_put(jnp.asarray(points_xyz), NamedSharding(mesh, P(None, None)))
    res = aidw_improved(pts, qs, cfg)
    return res.values[: queries_xy.shape[0]]


def _blocked_map(fn, qxy, block: int):
    """lax.map over query chunks of ``block`` (bounds the (q, m_loc) tiles)."""
    n = qxy[0].shape[0]
    if block <= 0 or block >= n:
        return fn(qxy)
    pad = (-n) % block
    padded = tuple(jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
                   for a in qxy)
    nb = (n + pad) // block
    chunked = tuple(a.reshape((nb, block) + a.shape[1:]) for a in padded)
    out = jax.lax.map(fn, chunked)
    return jax.tree.map(
        lambda a: a.reshape((nb * block,) + a.shape[2:])[:n], out)


def _ring_knn_step(ring_axis: str, perm, qx, qy, carry_d2, blk,
                   q_block: int = 0, carry_z=None):
    """Merge the rotating data block into the running top-k, then rotate.

    ``q_block`` chunks the queries so the (q, m_loc) distance tile stays
    VMEM/HBM-bounded (§Perf AIDW iteration: baseline materializes the full
    tile; blocked version fits at 1B-point scale).

    With ``carry_z`` (local Stage-2 mode) the block's data VALUES co-merge
    through the SAME ``top_k`` call — the selected distances (and hence
    r_obs/alpha) are bitwise what the distance-only merge selects."""
    bx, by = blk[:, 0], blk[:, 1]
    k = carry_d2.shape[1]

    if carry_z is None:
        def merge(args):
            cqx, cqy, ctop = args
            d2 = (cqx[:, None] - bx[None, :]) ** 2 + (cqy[:, None] - by[None, :]) ** 2
            cat = jnp.concatenate([ctop, d2], axis=1)
            neg_top, _ = jax.lax.top_k(-cat, k)
            return -neg_top

        carry_d2 = _blocked_map(merge, (qx, qy, carry_d2), q_block)
        blk = jax.lax.ppermute(blk, ring_axis, perm)
        return carry_d2, blk

    bz = blk[:, 2]

    def merge_z(args):
        cqx, cqy, ctop, ctz = args
        d2 = (cqx[:, None] - bx[None, :]) ** 2 + (cqy[:, None] - by[None, :]) ** 2
        cat = jnp.concatenate([ctop, d2], axis=1)
        catz = jnp.concatenate(
            [ctz, jnp.broadcast_to(bz[None, :], d2.shape)], axis=1)
        neg_top, sel = jax.lax.top_k(-cat, k)
        return -neg_top, jnp.take_along_axis(catz, sel, axis=1)

    carry_d2, carry_z = _blocked_map(
        merge_z, (qx, qy, carry_d2, carry_z), q_block)
    blk = jax.lax.ppermute(blk, ring_axis, perm)
    return (carry_d2, carry_z), blk


def _ring_interp_step(ring_axis: str, perm, qx, qy, alpha, carry, blk,
                      q_block: int = 0):
    """Accumulate partial (sum w*z, sum w) against the rotating block."""
    sum_wz, sum_w = carry
    bx, by, bz = blk[:, 0], blk[:, 1], blk[:, 2]

    def accum(args):
        cqx, cqy, calpha, cwz, cw = args
        d2 = (cqx[:, None] - bx[None, :]) ** 2 + (cqy[:, None] - by[None, :]) ** 2
        w = A.idw_weights_sq(d2, calpha[:, None])
        # padding sentinels: d2 = inf -> w = 0 exactly
        return cwz + (w * bz[None, :]).sum(axis=1), cw + w.sum(axis=1)

    sum_wz, sum_w = _blocked_map(accum, (qx, qy, alpha, sum_wz, sum_w), q_block)
    blk = jax.lax.ppermute(blk, ring_axis, perm)
    return (sum_wz, sum_w), blk


def make_ring_aidw(
    mesh: Mesh,
    ring_axis: str,
    *,
    k: int = 15,
    alphas=A.DEFAULT_ALPHAS,
    r_min: float = A.DEFAULT_R_MIN,
    r_max: float = A.DEFAULT_R_MAX,
    q_block: int = 0,
    stage2_local: bool = False,
    return_stats: bool = False,
):
    """Build the domain-decomposed AIDW step for ``mesh``.

    Returns ``fn(points_xyz, queries_xy, n_points, area) -> values`` operating
    on GLOBAL arrays whose leading dims are divisible by the mesh factors:
    data sharded along ``ring_axis`` only; queries sharded along every axis.
    ``n_points``/``area`` are the true (unpadded) study statistics for Eq.(2).
    With ``return_stats=True`` the step returns ``(values, alpha, r_obs,
    zero_weight_mask)`` instead — the per-query stats the sharded ring-layout
    session reports.

    ``stage2_local=True`` drops the Stage-2 rotation entirely: the Stage-1
    scan co-merges the blocks' data VALUES alongside the distances (same
    ``top_k`` selection — r_obs/alpha stay bitwise what global mode
    computes) and Eq. (1) is evaluated over just those k neighbours after
    the scan, O(k) per query instead of a second O(m) sweep.
    """
    all_axes = tuple(mesh.axis_names)
    p_ring = mesh.shape[ring_axis]
    perm = [(i, (i + 1) % p_ring) for i in range(p_ring)]

    def local_fn(points, queries, n_points, area):
        qx, qy = queries[:, 0], queries[:, 1]
        n_q = queries.shape[0]

        # ---- Stage 1: ring kNN (lax.scan: HLO is O(1) in ring size) ----
        def knn_step(carry, _):
            topk, blk = carry
            topk, blk = _ring_knn_step(ring_axis, perm, qx, qy, topk, blk,
                                       q_block)
            return (topk, blk), None

        def knn_z_step(carry, _):
            (topk, tz), blk = carry
            (topk, tz), blk = _ring_knn_step(ring_axis, perm, qx, qy, topk,
                                             blk, q_block, carry_z=tz)
            return ((topk, tz), blk), None

        topk0 = pvary(
            jnp.full((n_q, k), jnp.inf, points.dtype),
            all_axes)  # carry inherits the queries' full varying-axes set
        if stage2_local:
            tz0 = pvary(jnp.zeros((n_q, k), points.dtype), all_axes)
            ((topk, topk_z), _), _ = jax.lax.scan(
                knn_z_step, ((topk0, tz0), points), None, length=p_ring)
        else:
            (topk, _), _ = jax.lax.scan(knn_step, (topk0, points), None,
                                        length=p_ring)
        r_obs = jnp.sqrt(jnp.maximum(topk, 0.0)).mean(axis=1)
        alpha = A.adaptive_alpha(r_obs, n_points, area,
                                 alphas=alphas, r_min=r_min, r_max=r_max)

        if stage2_local:
            # ---- Stage 2 (local): Eq. (1) over the merged k neighbours ----
            swz, sw = A.topk_weighted_partial_sums(topk, topk_z, alpha)
            vals, zero = A.guarded_values(swz, sw)
            return (vals, alpha, r_obs, zero) if return_stats else vals

        # ---- Stage 2 (global): ring weighted interpolation ----
        def interp_step(carry, _):
            acc, blk = carry
            acc, blk = _ring_interp_step(ring_axis, perm, qx, qy, alpha, acc,
                                         blk, q_block)
            return (acc, blk), None

        acc0 = (jnp.zeros_like(qx), jnp.zeros_like(qx))
        ((sum_wz, sum_w), _), _ = jax.lax.scan(
            interp_step, (acc0, points), None, length=p_ring)
        vals, zero = A.guarded_values(sum_wz, sum_w)
        return (vals, alpha, r_obs, zero) if return_stats else vals

    data_spec = P(ring_axis, None)
    query_spec = P(all_axes, None)
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(data_spec, query_spec, P(), P()),
        out_specs=P(all_axes),
    )
    return jax.jit(fn)


def make_grid_ring_aidw(
    mesh: Mesh,
    ring_axis: str,
    *,
    spec,
    rps: int,
    halo: int,
    max_level: int,
    k: int = 15,
    window: int = 256,
    knn_block: int = 4096,
    alphas=A.DEFAULT_ALPHAS,
    r_min: float = A.DEFAULT_R_MIN,
    r_max: float = A.DEFAULT_R_MAX,
    q_block: int = 0,
    stage2_local: bool = False,
    return_stats: bool = False,
):
    """Build the grid-aware ring AIDW step for ``mesh`` (module docstring).

    Returns ``fn(sx, sy, sz, cell_start, row_lo, bx, by, bz, rx, ry, rz,
    queries, n_points, area)`` where the first eleven arguments are the
    stacked packets from :meth:`repro.core.slab.SlabPartition.device_tables`
    — the halo'd slab CSR tables Stage 1 rotates, the owned-only point
    blocks Stage 2 rotates, and the per-slab HOT APPEND RINGS (the LSM
    ingest tier, ``repro.core.slab`` module docstring) — all sharded along
    ``ring_axis``; queries are sharded over EVERY mesh axis.  ``spec`` is
    the GLOBAL grid spec and ``rps``/``halo``/``max_level`` the slab
    geometry — all static.

    Hot-ring search: each rotating packet's ring is scanned EXHAUSTIVELY
    (:func:`repro.core.knn.ring_candidate_d2`) and its candidates co-merge
    into the same per-step ``top_k`` as the slab's CSR result, so freshly
    staged inserts are query-visible without touching the CSR arrays.  A
    ring point lives ONLY in its owning slab's packet (never in a halo
    copy), so the exhaustive scan preserves the exactly-once contribution
    contract, needs no certification (it cannot overflow), and its d2
    arithmetic is bitwise the CSR gather's.  Stage 2 (global mode) rotates
    the ring points concatenated onto the owned block; empty ring slots
    carry ``PAD_COORD`` and contribute inf distance / zero weight.

    With ``return_stats=True`` the step returns ``(values, alpha, r_obs,
    overflow, n_candidates, zero_weight_mask)``: per-query overflow is the
    merged certification flag (kth merged distance vs the worst un-excused
    slab overflow), and ``n_candidates`` counts Stage-1 candidate distance
    evaluations per query summed over all slabs — the measured O(window)
    quantity the analytic census cross-checks against brute force's O(m).

    ``stage2_local=True`` drops the Stage-2 block rotation entirely: the
    Stage-1 packet additionally rotates the slab's sorted VALUES (``sz``),
    each slab's top-k indices gather them, and the (d2, z) pairs co-merge
    through the SAME ``top_k`` call — so r_obs/alpha (and the whole
    certification story) stay bitwise what global mode computes while
    per-query Stage-2 work drops from O(m) to O(k): O(window + k) total.
    """
    from . import knn as K

    all_axes = tuple(mesh.axis_names)
    p_ring = mesh.shape[ring_axis]
    perm = [(i, (i + 1) % p_ring) for i in range(p_ring)]

    def local_fn(sx, sy, sz, cell_start, row_lo, bx, by, bz, rx, ry, rz,
                 queries, n_points, area):
        qx, qy = queries[:, 0], queries[:, 1]
        n_q = queries.shape[0]

        # ---- Stage 1: grid-aware ring kNN -----------------------------
        # the rotating packet carries the slab's sorted points + CSR
        # offsets + row offset + hot append ring; `own` is consumed
        # locally by Stage 2 only.  Local mode rotates sz/rz too and
        # co-merges the gathered values.
        def knn_step(carry, _):
            if stage2_local:
                topk, topk_z, excuse, cand, pk = carry
                psx, psy, psz, pcs, prl, prx, pry, prz = pk
            else:
                topk, excuse, cand, pk = carry
                psx, psy, pcs, prl, prx, pry = pk
            # `order` = iota: res.idx indexes the slab's SORTED arrays,
            # which is exactly what the in-scan value gather wants (global
            # mode never reads idx, so zeros vs iota is indifferent there)
            res = K.slab_knn(spec, rps, halo, pcs[0], psx[0], psy[0],
                             jax.lax.iota(jnp.int32, psx.shape[1]), prl[0],
                             queries, k, max_level, window, knn_block)
            # hot ring: exhaustive scan of this slab's staged inserts
            # (tiny, exact, overflow-free — see make_grid_ring_aidw doc)
            rd2 = K.ring_candidate_d2(prx[0], pry[0], qx, qy)
            cat = jnp.concatenate([topk, res.d2, rd2], axis=1)
            neg, sel = jax.lax.top_k(-cat, k)
            ring_live = (prx[0] < PAD_COORD).sum().astype(jnp.int32)
            pk = jax.tree.map(
                lambda a: jax.lax.ppermute(a, ring_axis, perm), pk)
            if stage2_local:
                catz = jnp.concatenate(
                    [topk_z, psz[0][res.idx],
                     jnp.broadcast_to(prz[0][None, :], rd2.shape)], axis=1)
                topk_z = jnp.take_along_axis(catz, sel, axis=1)
                return (-neg, topk_z, jnp.minimum(excuse, res.excuse),
                        cand + res.n_candidates + ring_live, pk), None
            return (-neg, jnp.minimum(excuse, res.excuse),
                    cand + res.n_candidates + ring_live, pk), None

        topk0 = pvary(jnp.full((n_q, k), jnp.inf, queries.dtype), all_axes)
        excuse0 = pvary(jnp.full((n_q,), jnp.inf, queries.dtype), all_axes)
        cand0 = pvary(jnp.zeros((n_q,), jnp.int32), all_axes)
        if stage2_local:
            tz0 = pvary(jnp.zeros((n_q, k), sz.dtype), all_axes)
            packet0 = (sx, sy, sz, cell_start, row_lo, rx, ry, rz)
            (topk, topk_z, excuse, cand, _), _ = jax.lax.scan(
                knn_step, (topk0, tz0, excuse0, cand0, packet0), None,
                length=p_ring)
        else:
            packet0 = (sx, sy, cell_start, row_lo, rx, ry)
            (topk, excuse, cand, _), _ = jax.lax.scan(
                knn_step, (topk0, excuse0, cand0, packet0), None,
                length=p_ring)

        r_obs = jnp.sqrt(jnp.maximum(topk, 0.0)).mean(axis=1)
        overflow = jnp.sqrt(jnp.maximum(topk[:, -1], 0.0)) > excuse
        alpha = A.adaptive_alpha(r_obs, n_points, area, alphas=alphas,
                                 r_min=r_min, r_max=r_max)

        if stage2_local:
            # ---- Stage 2 (local): no rotation — the merged neighbour
            # carry already holds everything Eq. (1) needs ---------------
            swz, sw = A.topk_weighted_partial_sums(topk, topk_z, alpha)
            vals, zero = A.guarded_values(swz, sw)
            return (vals, alpha, r_obs, overflow, cand, zero) \
                if return_stats else vals

        # ---- Stage 2 (global): ring rotation over OWNED blocks plus the
        # slab's hot ring (ring points live only in their owner's packet,
        # so concatenating them keeps Eq. (1) exactly-once; halo copies
        # never enter: they would double-count, and their dead lanes
        # would widen every Stage-2 tile) ------------------------------
        blk0 = jnp.concatenate([
            jnp.stack([bx[0], by[0], bz[0]], axis=1),
            jnp.stack([rx[0], ry[0], rz[0]], axis=1),
        ], axis=0)

        def interp_step(carry, _):
            acc, blk = carry
            acc, blk = _ring_interp_step(ring_axis, perm, qx, qy, alpha,
                                         acc, blk, q_block)
            return (acc, blk), None

        acc0 = (jnp.zeros_like(qx), jnp.zeros_like(qx))
        ((swz, sw), _), _ = jax.lax.scan(interp_step, (acc0, blk0), None,
                                         length=p_ring)
        vals, zero = A.guarded_values(swz, sw)
        return (vals, alpha, r_obs, overflow, cand, zero) if return_stats \
            else vals

    data2 = P(ring_axis, None)
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(data2, data2, data2, data2, P(ring_axis), data2, data2,
                  data2, data2, data2, data2, P(all_axes, None), P(), P()),
        out_specs=tuple(P(all_axes) for _ in range(6)) if return_stats
        else P(all_axes),
    )
    return jax.jit(fn)


def ring_aidw(mesh: Mesh, ring_axis: str, points_xyz, queries_xy, *,
              k: int = 15, alphas=A.DEFAULT_ALPHAS):
    """Convenience wrapper: pads, runs :func:`make_ring_aidw`, unpads."""
    points_xyz = jnp.asarray(points_xyz)
    queries_xy = jnp.asarray(queries_xy)
    n, m = queries_xy.shape[0], points_xyz.shape[0]
    # true study-area statistics from the unpadded data
    xs = jnp.concatenate([points_xyz[:, 0], queries_xy[:, 0]])
    ys = jnp.concatenate([points_xyz[:, 1], queries_xy[:, 1]])
    area = (xs.max() - xs.min()) * (ys.max() - ys.min())

    p_ring = mesh.shape[ring_axis]
    n_dev = mesh.devices.size
    pts = pad_to_multiple(points_xyz, p_ring)
    qs = pad_to_multiple(queries_xy, n_dev)
    fn = make_ring_aidw(mesh, ring_axis, k=k, alphas=alphas)
    return fn(pts, qs, jnp.float32(m), area.astype(jnp.float32))[:n]
