"""Fast kNN search over the even grid — Stage 1 of the improved AIDW algorithm.

Paper mapping (§3.2.4 / Fig. 5): per interpolated point,
  Step 1 locate the query in the grid          -> row/col computation
  Step 2 determine the level of cell expanding -> closed-form from ring counts
  Step 3 find neighbours within the local cells-> ragged window gather + top-k
  Step 4 average distance                      -> mean of k sqrt'd squared dists

TPU adaptation (DESIGN.md §2): the paper expands rings in a per-thread loop,
counting points until >= k are covered, then adds ONE safety ring (the Remark /
Fig. 4 exactness argument).  A per-lane data-dependent loop would serialize on
a TPU's (8, 128) vector unit, so we restructure it:

* Because cells of one grid row are contiguous in the flattened id, the points
  of a (2L+1)x(2L+1) block are, per row, ONE contiguous slice of the sorted
  point array.  Ring counts for ALL levels come from 2x(2L+1) gathers of the
  CSR ``cell_start`` array — no loop over points.
* The expansion level is then ``first L with count(L) >= k``, computed with a
  vectorized argmax over a static number of levels, + 1 safety ring (paper).
* Candidate gathering is a ragged->dense window gather: row slices are packed
  into a fixed-size window of ``window`` slots with masking, and the exact kNN
  are selected with a masked top-k.  Squared distances throughout; the sqrt is
  deferred to the final averaging step exactly as the paper prescribes.
"""

from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .grid import CellTable, GridSpec, cell_ids


class KnnResult(NamedTuple):
    d2: jax.Array        # (n, k) squared distances, ascending
    idx: jax.Array       # (n, k) indices into the ORIGINAL point array
    n_candidates: jax.Array  # (n,) candidates examined per query
    overflow: jax.Array  # (n,) bool: window too small (result approximate)


def _gather_topk(spec, k, max_level, window, cell_start, sx, sy, order,
                 qx, qy, col0, row0, dr, row_ok, row_base, lvl):
    """Gather the level-``lvl`` block's row slices and select the k nearest."""
    n_cols = spec.n_cols
    n_band = 2 * max_level + 1
    flo = jnp.clip(col0 - lvl, 0, n_cols - 1)
    fhi = jnp.clip(col0 + lvl, 0, n_cols - 1)
    active = (jnp.abs(dr) <= lvl) & row_ok                            # (n_band,)
    r_start = cell_start[row_base + flo]
    r_len = jnp.where(active, cell_start[row_base + fhi + 1] - r_start, 0)
    offsets = jnp.cumsum(r_len)                                       # (n_band,)
    total = offsets[-1]

    slots = jnp.arange(window, dtype=jnp.int32)
    row_of = jnp.searchsorted(offsets, slots, side="right").astype(jnp.int32)
    row_of = jnp.minimum(row_of, n_band - 1)
    prev = jnp.where(row_of > 0, offsets[jnp.maximum(row_of - 1, 0)], 0)
    src = r_start[row_of] + (slots - prev)
    valid = slots < jnp.minimum(total, window)
    src = jnp.clip(src, 0, sx.shape[0] - 1)

    # exact kNN among candidates (squared distances; sqrt deferred)
    d2 = (sx[src] - qx) ** 2 + (sy[src] - qy) ** 2
    d2 = jnp.where(valid, d2, jnp.inf)
    neg_top, top_i = jax.lax.top_k(-d2, k)
    return -neg_top, order[src[top_i]], total


def _query_knn(
    spec: GridSpec,
    k: int,
    max_level: int,
    window: int,
    exact: bool,
    cell_start: jax.Array,
    sx: jax.Array,
    sy: jax.Array,
    order: jax.Array,
    qx: jax.Array,
    qy: jax.Array,
):
    """kNN for a single query point (vmapped by :func:`grid_knn`)."""
    n_cols, n_rows = spec.n_cols, spec.n_rows
    col0 = jnp.clip(((qx - spec.min_x) / spec.cell_width).astype(jnp.int32), 0, n_cols - 1)
    row0 = jnp.clip(((qy - spec.min_y) / spec.cell_width).astype(jnp.int32), 0, n_rows - 1)

    n_band = 2 * max_level + 1
    dr = jnp.arange(-max_level, max_level + 1, dtype=jnp.int32)      # (n_band,)
    rows = row0 + dr
    row_ok = (rows >= 0) & (rows < n_rows)
    rows_c = jnp.clip(rows, 0, n_rows - 1)
    row_base = rows_c * n_cols                                        # (n_band,)

    # --- Step 2: ring counts for every level L in [0, max_level] ------------
    # count(L) = sum over rows |dr|<=L of points in columns [col0-L, col0+L].
    levels = jnp.arange(max_level + 1, dtype=jnp.int32)               # (n_lvl,)
    clo = jnp.clip(col0 - levels, 0, n_cols - 1)                      # (n_lvl,)
    chi = jnp.clip(col0 + levels, 0, n_cols - 1)
    # starts[l, r] = cell_start[row_base[r] + clo[l]]   (gather, no loops)
    start_idx = row_base[None, :] + clo[:, None]                      # (n_lvl, n_band)
    end_idx = row_base[None, :] + chi[:, None] + 1
    row_cnt = cell_start[end_idx] - cell_start[start_idx]             # (n_lvl, n_band)
    in_band = jnp.abs(dr)[None, :] <= levels[:, None]
    row_cnt = jnp.where(in_band & row_ok[None, :], row_cnt, 0)
    counts = row_cnt.sum(axis=1)                                      # (n_lvl,)

    # first level with >= k candidates; paper's Remark: expand one extra ring.
    # The true point count is cell_start[-1], NOT sx.shape[0]: capacity-padded
    # tables (pipeline plan padding) carry sentinel tail slots outside every
    # CSR range, and the count floor must ignore them.
    enough = counts >= jnp.minimum(k, jnp.maximum(cell_start[-1], 1))
    first = jnp.where(jnp.any(enough), jnp.argmax(enough), max_level)
    lvl = jnp.minimum(first.astype(jnp.int32) + 1, max_level)

    args = (spec, k, max_level, window, cell_start, sx, sy, order,
            qx, qy, col0, row0, dr, row_ok, row_base)
    d2, idx, total = _gather_topk(*args, lvl)
    not_exact = total > window

    if exact:
        # Beyond-paper exactness pass (DESIGN.md §2): the paper's +1 ring is a
        # heuristic — the true kth NN can sit outside it (~0.5% of queries on
        # uniform data).  A level-L block centred on the query's cell is
        # GUARANTEED to cover radius L*cw, and pass-1's kth distance upper-
        # bounds the true kth distance, so re-gathering at ceil(d_k/cw)
        # certifies exactness.
        d_k = jnp.sqrt(jnp.maximum(d2[-1], 0.0))
        lvl2 = jnp.ceil(d_k / spec.cell_width).astype(jnp.int32)
        clamped = lvl2 > max_level
        lvl2 = jnp.clip(lvl2, lvl, max_level)
        d2b, idxb, totalb = _gather_topk(*args, lvl2)
        redo = lvl2 > lvl
        d2 = jnp.where(redo, d2b, d2)
        idx = jnp.where(redo, idxb, idx)
        total = jnp.where(redo, totalb, total)
        not_exact = (total > window) | clamped

    return KnnResult(d2=d2, idx=idx, n_candidates=total, overflow=not_exact)


class SlabKnnResult(NamedTuple):
    d2: jax.Array        # (n, k) squared distances to THIS slab's contribution
    idx: jax.Array       # (n, k) indices into the slab's original point order
    n_candidates: jax.Array  # (n,) candidates examined against this slab
    overflow: jax.Array  # (n,) bool: this slab's search was not certified
    excuse: jax.Array    # (n,) f32: radius within which an overflow is
    #                        irrelevant — any point this slab FAILED to
    #                        examine is farther than ``excuse`` from the
    #                        query, so a merged kth distance <= excuse keeps
    #                        the merged result exact despite the flag


def _slab_query_knn(
    spec: GridSpec,
    k: int,
    max_level: int,
    window: int,
    rps: int,
    halo: int,
    cell_start: jax.Array,
    sx: jax.Array,
    sy: jax.Array,
    order: jax.Array,
    row_lo: jax.Array,
    qx: jax.Array,
    qy: jax.Array,
):
    """kNN for one query against ONE slab of the global grid.

    The slab owns global rows ``[row_lo, row_lo + rps)`` and its CSR table
    additionally carries ``halo`` rows of boundary cells on each side
    (local row ``r`` is global row ``row_lo - halo + r``; the table has
    ``rps + 2*halo`` rows x ``spec.n_cols`` cells).  ``spec`` is the GLOBAL
    grid — column/row indices are computed exactly as the replicated search
    computes them, and ``sx``/``sy`` hold TRUE (unshifted) coordinates, so
    every distance is bitwise what the replicated path computes for the
    same (query, point) pair.  ``row_lo`` is dynamic: the slab rotates
    around a ring, so nothing about it may be baked into the trace.

    Ownership contract (the halo-width invariant; see ``repro.core.slab``):
    merging per-slab results must count every data point EXACTLY once, so
    each (query, point) pair is assigned to one slab —

    * the slab OWNING the query's row contributes its own rows plus halo
      rows within ``halo`` grid rows of the query (the halo exists so a
      query near a slab boundary finds its whole expanding search window
      in the owning slab's table: for certified levels <= halo the owner's
      result alone is the exact global answer, bit-identical to the
      replicated layout's candidate sequence);
    * every other slab contributes only rows it OWNS that lie MORE than
      ``halo`` rows from the query (outside the owner's covered band).

    Certification: the exact second gather pass re-runs at
    ``ceil(d_k / cell_width)`` like :func:`_query_knn`; clamping only moves
    the search centre CLOSER to any in-table cell, so the coverage argument
    survives queries whose row lies outside this slab.  When the pass
    cannot be certified (window overflow or level clamp) the result is
    flagged, and ``excuse`` reports the radius under which the flag cannot
    affect a MERGED top-k: every point this slab failed to examine is
    farther than ``excuse`` (its contributed rows start ``max(gap, halo+1)``
    rows away for non-owners; 0 for the owner, whose overflow is never
    excused).
    """
    n_cols, n_rows_g = spec.n_cols, spec.n_rows
    n_rows_local = rps + 2 * halo
    col0 = jnp.clip(((qx - spec.min_x) / spec.cell_width).astype(jnp.int32),
                    0, n_cols - 1)
    row_g = jnp.clip(((qy - spec.min_y) / spec.cell_width).astype(jnp.int32),
                     0, n_rows_g - 1)
    rr = row_g - row_lo                       # own-row-relative query row
    gap = jnp.maximum(0, jnp.maximum(-rr, rr - (rps - 1)))
    is_owner = gap == 0
    row0 = jnp.clip(rr + halo, 0, n_rows_local - 1)   # clamped local centre

    n_band = 2 * max_level + 1
    dr = jnp.arange(-max_level, max_level + 1, dtype=jnp.int32)
    rows_l = row0 + dr                                 # local band rows
    rows_global = rows_l + (row_lo - halo)
    owned = (rows_l >= halo) & (rows_l < halo + rps)
    in_band = jnp.abs(rows_global - row_g) <= halo
    contrib = jnp.where(is_owner, owned | in_band, owned & ~in_band)
    row_ok = (rows_l >= 0) & (rows_l < n_rows_local) \
        & (rows_global < n_rows_g) & contrib
    rows_c = jnp.clip(rows_l, 0, n_rows_local - 1)
    row_base = rows_c * n_cols

    # ring counts for every level (same gather pattern as _query_knn, with
    # the ownership mask folded into row validity)
    levels = jnp.arange(max_level + 1, dtype=jnp.int32)
    clo = jnp.clip(col0 - levels, 0, n_cols - 1)
    chi = jnp.clip(col0 + levels, 0, n_cols - 1)
    start_idx = row_base[None, :] + clo[:, None]
    end_idx = row_base[None, :] + chi[:, None] + 1
    row_cnt = cell_start[end_idx] - cell_start[start_idx]
    band_ok = jnp.abs(dr)[None, :] <= levels[:, None]
    row_cnt = jnp.where(band_ok & row_ok[None, :], row_cnt, 0)
    counts = row_cnt.sum(axis=1)

    n_slab = cell_start[-1]
    enough = counts >= jnp.minimum(k, jnp.maximum(n_slab, 1))
    first = jnp.where(jnp.any(enough), jnp.argmax(enough), max_level)
    lvl = jnp.minimum(first.astype(jnp.int32) + 1, max_level)

    args = (spec, k, max_level, window, cell_start, sx, sy, order,
            qx, qy, col0, row0, dr, row_ok, row_base)
    d2, idx, total = _gather_topk(*args, lvl)

    # certified second pass (cap inf d_k BEFORE the int cast: a slab with
    # fewer than k contributed points yields d2[-1] = inf)
    d_k = jnp.sqrt(jnp.maximum(d2[-1], 0.0))
    d_cap = jnp.minimum(d_k, (max_level + 2.0) * spec.cell_width)
    lvl2 = jnp.ceil(d_cap / spec.cell_width).astype(jnp.int32)
    clamped = (lvl2 > max_level) | ~jnp.isfinite(d_k)
    lvl2 = jnp.clip(lvl2, lvl, max_level)
    d2b, idxb, totalb = _gather_topk(*args, lvl2)
    redo = lvl2 > lvl
    d2 = jnp.where(redo, d2b, d2)
    idx = jnp.where(redo, idxb, idx)
    total = jnp.where(redo, totalb, total)
    # a slab whose whole contributed point set fit in the gather window is
    # exact no matter what the level heuristics concluded
    exhausted = (total <= window) & (total >= n_slab)
    not_exact = ((total > window) | clamped) & ~exhausted

    # overflow excuse: non-owner slabs contribute nothing nearer than
    # max(gap, halo+1) rows, so their un-certified searches cannot corrupt
    # a merged top-k whose kth distance stays below (that - 1) cell widths.
    gap_eff = jnp.where(is_owner, 0, jnp.maximum(gap, halo + 1))
    excuse = jnp.where(
        not_exact,
        (gap_eff.astype(d_k.dtype) - 1.0) * spec.cell_width,
        jnp.inf)
    return SlabKnnResult(d2=d2, idx=idx, n_candidates=total,
                         overflow=not_exact, excuse=excuse)


def slab_knn(
    spec: GridSpec,
    rps: int,
    halo: int,
    cell_start: jax.Array,
    sx: jax.Array,
    sy: jax.Array,
    order: jax.Array,
    row_lo: jax.Array,
    queries_xy: jax.Array,
    k: int = 15,
    max_level: int | None = None,
    window: int = 256,
    block: int = 4096,
) -> SlabKnnResult:
    """Vectorized :func:`_slab_query_knn` over a query batch (the grid-aware
    ring step's Stage-1 kernel; NOT jitted here — it runs inside the traced
    ring rotation of :func:`repro.core.distributed.make_grid_ring_aidw`,
    and standalone callers wrap it themselves)."""
    n = queries_xy.shape[0]
    if max_level is None:
        max_level = auto_max_level(spec, max(int(sx.shape[0]), 1), k)
    block = min(block, max(n, 1))   # never pad a small shard up to a block
    qx, qy = queries_xy[:, 0], queries_xy[:, 1]
    f = partial(_slab_query_knn, spec, k, max_level, window, rps, halo,
                cell_start, sx, sy, order, row_lo)
    pad = (-n) % block
    qxp = jnp.pad(qx, (0, pad))
    qyp = jnp.pad(qy, (0, pad))
    nb = (n + pad) // block
    out = jax.lax.map(
        lambda ab: jax.vmap(f)(ab[0], ab[1]),
        (qxp.reshape(nb, block), qyp.reshape(nb, block)),
    )
    flat = jax.tree.map(lambda a: a.reshape((nb * block,) + a.shape[2:])[:n],
                        out)
    return SlabKnnResult(*flat)


def ring_candidate_d2(rx: jax.Array, ry: jax.Array,
                      qx: jax.Array, qy: jax.Array) -> jax.Array:
    """Exhaustive squared distances from a query batch to a slab's hot ring.

    The hot append ring (``repro.core.slab`` LSM ingest contract) is a tiny
    fixed-capacity buffer of freshly inserted points that have not yet been
    folded into the slab's CSR table.  It is searched EXHAUSTIVELY — every
    query against every slot — because its capacity is a few hundred slots,
    far below the CSR gather window, and an exhaustive scan needs no level
    heuristic, no certification pass, and cannot overflow.

    The arithmetic is element-for-element the CSR path's
    ``(sx[src] - qx)**2 + (sy[src] - qy)**2`` (squaring makes the operand
    order bitwise-irrelevant: ``x*x`` and ``(-x)*(-x)`` are identical
    floats), so merging ring candidates into a slab top-k preserves the
    bitwise Stage-1 contract.  Empty slots carry the ``PAD_COORD`` sentinel
    (1e30): their d2 overflows f32 to +inf and is never selected.

    Shapes: ``rx``/``ry`` are (ring_cap,); ``qx``/``qy`` are (nq,); the
    result is (nq, ring_cap).
    """
    return ((qx[:, None] - rx[None, :]) ** 2
            + (qy[:, None] - ry[None, :]) ** 2)


def auto_max_level(spec: GridSpec, m: int, k: int) -> int:
    """Expansion-level bound from expected point density (points/cell).

    Need (2L+1)^2 * ppc >= k at the count level, plus the safety ring and
    certified-pass headroom; clamped to the grid radius.
    """
    ppc = max(m / spec.n_cells, 1e-3)
    lvl = int(math.ceil(0.5 * (math.sqrt(4.0 * k / ppc) - 1.0))) + 3
    return max(2, min(lvl, max(spec.n_rows, spec.n_cols)))


@partial(jax.jit, static_argnums=(0, 3, 4, 5, 6, 7))
def grid_knn(
    spec: GridSpec,
    table: CellTable,
    queries_xy: jax.Array,
    k: int = 15,
    max_level: int | None = None,
    window: int = 256,
    block: int = 4096,
    exact: bool = True,
) -> KnnResult:
    """kNN for every query via local grid search (paper Stage 1).

    ``exact=False`` is the paper-faithful heuristic (count-based level + one
    safety ring); ``exact=True`` (default) adds the certified second gather
    pass (see ``_query_knn``).  ``window`` bounds the candidate set per query;
    with the paper's Eq.(2) cell width the expected candidate count at the
    safety level is ~(2L+3)^2 / 4 << 256, so the default is generous for
    near-uniform data.  ``overflow`` reports queries whose window overflowed
    or whose certified level exceeded ``max_level`` (result approximate).
    ``block`` chunks queries through ``lax.map`` to bound peak memory.
    """
    n = queries_xy.shape[0]
    if max_level is None:
        max_level = auto_max_level(spec, table.sx.shape[0], k)
    qx, qy = queries_xy[:, 0], queries_xy[:, 1]
    f = partial(
        _query_knn, spec, k, max_level, window, exact,
        table.cell_start, table.sx, table.sy, table.order,
    )
    pad = (-n) % block
    qxp = jnp.pad(qx, (0, pad))
    qyp = jnp.pad(qy, (0, pad))
    nb = (n + pad) // block
    out = jax.lax.map(
        lambda ab: jax.vmap(f)(ab[0], ab[1]),
        (qxp.reshape(nb, block), qyp.reshape(nb, block)),
    )
    flat = jax.tree.map(lambda a: a.reshape((nb * block,) + a.shape[2:])[:n], out)
    return KnnResult(*flat)


@partial(jax.jit, static_argnums=(2, 3))
def brute_knn(points_xy: jax.Array, queries_xy: jax.Array, k: int = 15,
              block: int = 1024) -> tuple[jax.Array, jax.Array]:
    """Brute-force kNN (the 'original' algorithm's global search, §3.1).

    Returns (d2, idx) with d2 ascending.  Blocked over queries so the (n, m)
    distance matrix never materializes in full.
    """
    n = queries_xy.shape[0]
    px, py = points_xy[:, 0], points_xy[:, 1]
    k = min(k, points_xy.shape[0])

    def one_block(qb):
        d2 = (qb[:, 0:1] - px[None, :]) ** 2 + (qb[:, 1:2] - py[None, :]) ** 2
        neg_top, idx = jax.lax.top_k(-d2, k)
        return -neg_top, idx

    pad = (-n) % block
    qp = jnp.pad(queries_xy, ((0, pad), (0, 0)))
    nb = (n + pad) // block
    d2, idx = jax.lax.map(one_block, qp.reshape(nb, block, 2))
    return d2.reshape(-1, k)[:n], idx.reshape(-1, k)[:n]


def mean_nn_distance(d2: jax.Array) -> jax.Array:
    """Eq. (3): r_obs = mean of the k NN distances (sqrt deferred until here)."""
    return jnp.sqrt(jnp.maximum(d2, 0.0)).mean(axis=-1)
