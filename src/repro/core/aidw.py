"""AIDW mathematics — Eqs. (1)-(6) of Mei, Xu & Xu (2016) / Lu & Wong (2008).

Stage 2 of the improved algorithm: given the observed mean nearest-neighbour
distance ``r_obs`` per interpolated point (from Stage 1 / kNN), adaptively
determine the distance-decay parameter ``alpha`` and take the inverse-distance
weighted average over data points (Eq. 1).

Stage-2 mode contract (``AidwConfig.stage2``):

* **global** (``'naive'``/``'tiled'``) — Eq. (1) exactly as written: the
  weighted average runs over ALL m data points.
* **local** — Eq. (1) truncated to the k merged nearest neighbours that
  Stage 1 already produced (:func:`topk_weighted_partial_sums`).  Because
  Stage 1 is untouched, ``r_obs`` and therefore ``alpha`` are **bit-identical**
  to global mode by construction; only the predicted values differ, and they
  differ exactly by the truncated far-field tail
  ``sum_{i>k} w_i (z_i - Z_local) / sum_{i<=k} w_i`` — a relative error that
  shrinks like the tail weight mass ``O(k^(1-alpha/2))`` for alpha > 2 and
  vanishes as k -> n.  Because the tail mass is set by the alpha that
  Eq. (6) itself picks, the regimes split the opposite way from naive
  intuition: UNIFORM patterns (R-statistic near 1) get alpha >= 2 — fast
  decay, tight bound — while CLUSTERED patterns get alpha ~ 0.5 near the
  clusters, whose heavy far-field tail makes local mode loosest exactly
  there; ``tests/test_local_stage2.py`` pins both regimes against the
  analytic f64 tail bound.

Zero-weight contract: every division by ``sum_i w_i`` in this module is
guarded (:func:`guarded_values`).  A query so far from all data that every
f32 weight underflows to zero yields the sentinel value 0.0 and a raised bit
in the per-query ``zero_weight_mask`` — never NaN.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

# Five distance-decay levels alpha_1..alpha_5 (Eq. 6).  The paper inherits the
# triangular-membership levels from Lu & Wong (2008); these are configurable.
DEFAULT_ALPHAS = (0.5, 1.0, 2.0, 3.0, 4.0)
DEFAULT_R_MIN = 0.0
DEFAULT_R_MAX = 2.0
EPS_D2 = 1e-12
PAD_SENTINEL = 1e30  # padded points -> d2 = inf (f32) -> weight exactly 0


def expected_nn_distance(n_points, area):
    """Eq. (2): r_exp = 1 / (2 sqrt(n / A)) for a random point pattern."""
    return 1.0 / (2.0 * jnp.sqrt(n_points / area))


def nn_statistic(r_obs, r_exp):
    """Eq. (4): R(S0) = r_obs / r_exp."""
    return r_obs / r_exp


def fuzzy_membership(r_stat, r_min: float = DEFAULT_R_MIN, r_max: float = DEFAULT_R_MAX):
    """Eq. (5): normalize R(S0) to mu_R in [0, 1] by a cosine fuzzy membership."""
    mu = 0.5 - 0.5 * jnp.cos(jnp.pi / r_max * (r_stat - r_min))
    return jnp.where(r_stat <= r_min, 0.0, jnp.where(r_stat >= r_max, 1.0, mu))


def alpha_from_membership(mu, alphas=DEFAULT_ALPHAS):
    """Eq. (6): map mu_R to a distance-decay alpha by triangular membership.

    Piecewise-linear interpolation through the five levels: constant a1 on
    [0, .1], linear a1->a2 on [.1, .3], a2->a3 on [.3, .5], a3->a4 on [.5, .7],
    a4->a5 on [.7, .9], constant a5 on [.9, 1].
    """
    a1, a2, a3, a4, a5 = [jnp.asarray(a, dtype=jnp.result_type(mu, 1.0)) for a in alphas]
    mu = jnp.asarray(mu)
    out = jnp.where(mu <= 0.1, a1, 0.0)
    segs = ((0.1, a1, a2), (0.3, a2, a3), (0.5, a3, a4), (0.7, a4, a5))
    for lo, alo, ahi in segs:
        t = 5.0 * (mu - lo)
        out = jnp.where((mu > lo) & (mu <= lo + 0.2), alo * (1.0 - t) + ahi * t, out)
    return jnp.where(mu > 0.9, a5, out)


def adaptive_alpha(r_obs, n_points, area, *, alphas=DEFAULT_ALPHAS,
                   r_min: float = DEFAULT_R_MIN, r_max: float = DEFAULT_R_MAX):
    """Full Stage-2 alpha determination: Eqs. (2) -> (4) -> (5) -> (6)."""
    r_exp = expected_nn_distance(n_points, area)
    return alpha_from_membership(
        fuzzy_membership(nn_statistic(r_obs, r_exp), r_min, r_max), alphas
    )


def idw_weights_sq(d2, alpha):
    """w_i = 1/d^alpha computed from SQUARED distances: (d^2)^(-alpha/2).

    The paper defers sqrt everywhere; a zero distance (query == data point)
    is clamped so the weight saturates and the prediction converges to the
    exact data value.
    """
    return jnp.power(jnp.maximum(d2, EPS_D2), -0.5 * alpha)


@partial(jax.jit, static_argnums=(4, 5))
def weighted_partial_sums(queries_xy, points_xy, values, alpha,
                          block: int = 1024, data_block: int = 0):
    """Eq. (1) numerator/denominator: (sum_i w_i z_i, sum_i w_i) per query.

    The reusable heart of :func:`weighted_interpolate` — exposed separately
    because a data-partitioned deployment (the serving fleet's shard hosts,
    ``repro.serving.cluster.fleet``) sums these partials ACROSS shards
    before the one global division.  Blocking as in
    :func:`weighted_interpolate`.
    """
    n = queries_xy.shape[0]
    m = points_xy.shape[0]
    alpha = jnp.broadcast_to(jnp.asarray(alpha, values.dtype), (n,))
    px, py = points_xy[:, 0], points_xy[:, 1]

    def tile(qb, ab, dx, dy, dz):
        d2 = (qb[:, 0:1] - dx[None, :]) ** 2 + (qb[:, 1:2] - dy[None, :]) ** 2
        w = idw_weights_sq(d2, ab[:, None])
        return (w * dz[None, :]).sum(-1), w.sum(-1)

    if data_block and data_block < m:
        dpad = (-m) % data_block
        big = jnp.float32(PAD_SENTINEL)
        dxc = jnp.pad(px, (0, dpad), constant_values=big)
        dyc = jnp.pad(py, (0, dpad), constant_values=big)
        dzc = jnp.pad(values, (0, dpad))
        nd = (m + dpad) // data_block
        chunks = (dxc.reshape(nd, data_block), dyc.reshape(nd, data_block),
                  dzc.reshape(nd, data_block))

        def one_block(args):
            qb, ab = args

            def dstep(acc, dchunk):
                wz, wsum = tile(qb, ab, *dchunk)
                return (acc[0] + wz, acc[1] + wsum), None

            zero = jnp.zeros((qb.shape[0],), jnp.float32)
            (swz, sw), _ = jax.lax.scan(dstep, (zero, zero), chunks)
            return swz, sw
    else:
        def one_block(args):
            qb, ab = args
            return tile(qb, ab, px, py, values)

    pad = (-n) % block
    qp = jnp.pad(queries_xy, ((0, pad), (0, 0)))
    ap = jnp.pad(alpha, (0, pad))
    nb = (n + pad) // block
    swz, sw = jax.lax.map(one_block,
                          (qp.reshape(nb, block, 2), ap.reshape(nb, block)))
    return swz.reshape(-1)[:n], sw.reshape(-1)[:n]


ZERO_WEIGHT_SENTINEL = 0.0  # value reported where sum(w) underflowed to zero


def guarded_values(swz, sw):
    """Eq. (1) final division with the zero-denominator guard.

    Returns ``(values, zero_weight_mask)``.  Where the f32 weight sum
    underflowed to exactly zero (query far from all data with large alpha),
    the value is the explicit sentinel ``ZERO_WEIGHT_SENTINEL`` (0.0) and the
    mask bit is set — the NaN that plain ``swz / sw`` would emit never
    escapes.  Everywhere else the division is performed verbatim, keeping
    guarded results bit-identical to the unguarded ones.
    """
    zero = sw <= 0.0
    vals = jnp.where(zero, ZERO_WEIGHT_SENTINEL,
                     swz / jnp.where(zero, 1.0, sw))
    return vals, zero


def topk_weighted_partial_sums(d2, z, alpha):
    """Local-mode Eq. (1) partials over the k merged Stage-1 neighbours.

    ``d2``: (n, k) squared distances to the k nearest neighbours,
    ``z``: (n, k) the neighbours' data values (gathered via the kNN indices),
    ``alpha``: per-query (n,) or scalar decay.  Padded / missing neighbour
    slots carry ``d2 = inf``, whose weight is exactly 0.0 for every
    alpha > 0 — padding the k axis never perturbs the sums bitwise.

    Accumulation over the k axis is SEQUENTIAL (pinned left-to-right order)
    rather than ``jnp.sum``'s shape-dependent reduction tree: appending
    zero-weight slots then changes nothing bitwise, which is what lets the
    Pallas local kernel (lane-padded k) reproduce this path bit-for-bit.
    """
    alpha = jnp.asarray(alpha, z.dtype)
    if alpha.ndim == 1:
        alpha = alpha[:, None]
    w = idw_weights_sq(d2, alpha)
    wz = w * z
    swz, sw = wz[..., 0], w[..., 0]
    for i in range(1, d2.shape[-1]):
        swz = swz + wz[..., i]
        sw = sw + w[..., i]
    return swz, sw


@partial(jax.jit, static_argnums=(4, 5))
def weighted_interpolate(queries_xy, points_xy, values, alpha,
                         block: int = 1024, data_block: int = 0):
    """Eq. (1): Z(x) = sum_i w_i z_i / sum_i w_i over ALL data points.

    ``alpha`` is per-query (AIDW) or scalar (standard IDW).  Blocked over
    queries; ``data_block`` additionally chunks the data axis with running
    (sum w*z, sum w) accumulators, bounding the tile at
    (block x data_block) for billion-point datasets — the pure-jnp analogue
    of the Pallas kernel's accumulate-over-data-blocks grid dimension.

    The division is guarded: zero-weight queries produce the 0.0 sentinel,
    never NaN (see :func:`guarded_values`; callers needing the mask use
    ``guarded_values(*weighted_partial_sums(...))`` directly).
    """
    swz, sw = weighted_partial_sums(queries_xy, points_xy, values, alpha,
                                    block, data_block)
    return guarded_values(swz, sw)[0]
