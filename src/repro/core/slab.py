"""Slab-decomposed AIDW: grid kNN with halo exchange + ring Stage 2.

The final §Perf iteration for the paper's technique at pod scale
(EXPERIMENTS.md cell 3): the ring variant's brute-force kNN doubles the step
FLOPs.  Here Stage 1 keeps the paper's GRID search, domain-decomposed:

* the study area is cut into P horizontal **slabs** of whole grid rows
  (slab s owns rows [s*rps, (s+1)*rps) of the global grid); data points and
  queries arrive pre-partitioned by slab (the natural layout of tiled
  geospatial ingestion);
* each shard receives its two neighbour slabs via collective-permute (the
  halo — one ring hop each way) and builds a LOCAL grid over
  [prev | own | next] with static dims (3*rps rows x global cols); the only
  dynamic quantity is the slab's y-offset, folded into the point/query
  coordinates, so the existing static-spec `bin_points`/`grid_knn` machinery
  applies unchanged;
* kNN is exact while the certified expansion level stays within one slab
  (max_level <= rps; overflow flags report violations — with Eq.(2)x4 cells
  and k=15 the certified level is ~5 vs rps=32 at 1B points / 512 chips);
* Stage 2 is the ring rotation from `distributed.make_ring_aidw` (the global
  Eq.(1) sum needs every data block regardless of where kNN happened).

Per-chip cost at m=n=2^30, P=512: kNN drops from O(n_loc * m) ~ 1.7e16 FLOPs
(ring brute force) to O(n_loc * window) ~ 4e9 — the step becomes one
Stage-2 sweep, halving total FLOPs vs ring AIDW.

Grid-aware ring (PR 5; :class:`SlabPartition` below + ``make_grid_ring_aidw``
in ``repro.core.distributed``): the serving session's ``layout='grid_ring'``
uses the SAME slab decomposition but rotates the slab CSR tables around the
ring instead of pre-partitioning queries, so it composes with the session's
query-sharded-over-all-axes layout.  Contracts:

* **Halo-width invariant** — slab ``s`` owns global grid rows
  ``[s*rps, (s+1)*rps)`` and its CSR table carries ``halo`` extra rows of
  boundary cells on each side (points REPLICATED from the neighbouring
  slabs).  With ``halo >= max_level`` (the search's level bound, the
  default), a query landing in slab ``s`` finds its ENTIRE expanding search
  window — every cell a certified level-``L <= halo`` expansion can touch —
  inside ``s``'s table, so the owner's result alone is the exact global
  answer for such queries and the candidate sequence is identical to the
  replicated layout's (bit-identical d2/r_obs/alpha).  Queries whose
  certified window exceeds the halo fall back to the cross-slab k-way
  merge, which is still exact: contributions are partitioned so every data
  point is counted exactly once (owner takes its rows plus in-halo-band
  halo rows; non-owners take only rows they own outside that band — see
  ``repro.core.knn._slab_query_knn``), and un-certified slab searches carry
  an ``excuse`` radius that keeps the merged overflow flag honest.
* **Memory model** — each device holds O(m/P) owned points + O(boundary)
  halo copies (``2 * halo`` rows of points) + the slab's CSR offsets
  ((rps + 2*halo) * n_cols + 1 int32), NEVER the O(m) dataset or the
  O(n_cells) global table.
* **Comms model** — one neighbour ``ppermute`` of the slab packet (points +
  CSR offsets, O(m/P + boundary) bytes) per ring step per stage; no
  all-gather, no per-query traffic.  Stage 2 rotates the same point blocks
  (the global Eq. (1) sum needs every block regardless of where kNN
  happened).
* **Hot-ring (LSM) ingest contract** — every slab carries a small
  fixed-capacity APPEND RING next to its CSR table (``ring_cap`` slots).
  An insert lands ONLY in its owning slab's ring (never halo-replicated:
  every rotating packet's ring is searched exhaustively by every query, so
  a ring point is globally visible the moment it is staged — no halo copy
  needed) and a CSR delete becomes an in-place TOMBSTONE
  (:func:`repro.core.grid.rebin_delta` ``tombstone=True``), so a delta
  changes O(Δ) ring slots + O(Δ) dead slots and the CSR arrays/offsets are
  otherwise untouched — the device staging cost drops from O(m) to
  O(Δ + touched-slab rows).  **Visibility**: a write is query-visible at
  the epoch whose update staged it (the next executed batch), exactly like
  a CSR write — Stage 1 k-way-merges the ring candidates with the CSR
  candidates with element-identical d2 arithmetic, so while a point sits
  in the ring the merged Stage-1 outputs equal a fresh build's within
  1 ulp (the ring scan is a separate XLA subgraph, so FMA contraction may
  round its d2 differently than the CSR gather's) and the GLOBAL Stage-2
  f32 summation order differs (values ~1 ulp); after :meth:`compact`
  every output is BITWISE a fresh build's again.  **Compaction**:
  :meth:`compact`
  (triggered when a ring cannot absorb an insert batch, when the tombstone
  fraction crosses ``tombstone_threshold``, or explicitly as a background
  FIFO-barrier epoch by the serving layer) folds every ring into the slab
  CSRs — halo replication happens HERE, via the standard insert routing —
  and purges tombstones, after which every table is element-identical to a
  fresh :meth:`build` of the same logical dataset.  Each point is counted
  exactly once across the move (ring ids are always strictly greater than
  every CSR member id, so the fold is a pure sorted append; a point is
  never in a ring and a CSR table at the same time): compaction changes
  WHERE a point is searched, never whether or how often it contributes.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from . import aidw as A
from . import grid as G
from . import knn as K
from .jax_compat import shard_map
from .distributed import PAD_COORD, _ring_interp_step


def slab_plan(m_global: int, p: int, *, bounds=(0.0, 1.0, 0.0, 1.0),
              cell_factor: float = 4.0) -> tuple[G.GridSpec, int]:
    """(local GridSpec with 3*rps rows, rows-per-slab) for a P-way split.

    ``bounds`` = (min_x, max_x, min_y, max_y) must be known statically (the
    ingestion contract for tiled spatial data)."""
    min_x, max_x, min_y, max_y = bounds
    area = (max_x - min_x) * (max_y - min_y)
    cw = cell_factor * (1.0 / (2.0 * math.sqrt(m_global / area)))
    cols = max(int((max_x - min_x + cw) / cw), 1)
    rows_g = max(int((max_y - min_y + cw) / cw), 1)
    rps = -(-rows_g // p)                      # rows per slab (ceil)
    local = G.GridSpec(min_x, 0.0, cw, 3 * rps, cols)
    return local, rps


def partition_by_slab(points: np.ndarray, p: int, rps: int, cw: float,
                      min_y: float = 0.0):
    """Host-side: group rows into slabs, pad to equal size with sentinels.

    Returns (slabbed (p, cap, d), original_index (p, cap) with -1 padding).
    """
    rows = np.clip(((points[:, 1] - min_y) / cw).astype(np.int64), 0,
                   p * rps - 1)
    slab = np.minimum(rows // rps, p - 1)
    cap = int(np.bincount(slab, minlength=p).max())
    d = points.shape[1]
    out = np.full((p, cap, d), PAD_COORD, dtype=points.dtype)
    idx = np.full((p, cap), -1, dtype=np.int64)
    for s in range(p):
        sel = np.nonzero(slab == s)[0]
        out[s, : len(sel)] = points[sel]
        idx[s, : len(sel)] = sel
    return out, idx


def slab_rows(spec: G.GridSpec, p: int) -> int:
    """Rows per slab (ceil) for a P-way split of ``spec``'s rows."""
    return -(-spec.n_rows // p)


def member_delta(mem: np.ndarray, dels, m_kept: int, ins_idx):
    """Apply one (deletes, inserts) delta to a SORTED member-index array.

    The shared bookkeeping for every slab-style partition (the grid-ring
    layout's :class:`SlabPartition` tables and the serving fleet's
    per-shard membership — one implementation, so delete routing can never
    drift between them).  ``mem`` holds indices into the CURRENT dataset
    order; ``dels`` is the sorted unique global delete set (or None);
    ``m_kept`` the post-delete dataset size; ``ins_idx`` the positions of
    this member set's inserts within the global insert batch (or None).
    Returns ``(dels_local, new_mem)`` where ``dels_local`` are the deleted
    entries' positions WITHIN ``mem`` (what ``rebin_delta`` wants) and
    ``new_mem`` is remapped to the reconstructed kept-plus-appended order
    (still sorted: appends index past every kept entry).
    """
    dels_local = None
    if dels is not None and mem.size:
        pos = np.searchsorted(mem, dels)
        hit = pos < mem.size
        hit[hit] &= mem[pos[hit]] == dels[hit]
        dels_local = pos[hit]
        keep = np.ones(mem.size, bool)
        keep[dels_local] = False
        mem = mem[keep]
    if dels is not None:
        mem = mem - np.searchsorted(dels, mem)
    if ins_idx is not None and np.size(ins_idx):
        mem = np.concatenate([mem, m_kept + np.asarray(ins_idx)])
    return dels_local, mem


class DeltaReport:
    """What one :meth:`SlabPartition.apply_delta`/:meth:`compact` touched.

    The device-staging worklist: ``csr_rows`` are slabs whose CSR arrays
    changed wholesale (insert spill / compaction — restage those rows),
    ``dead`` maps a slab to the sorted-array slot positions tombstoned this
    delta (an O(Δ) scatter patch, the CSR arrays are otherwise byte-stable),
    ``ring_rows`` are slabs whose hot ring changed (restage one
    ``ring_cap``-slot row).  ``staged_bytes`` is filled in by the staging
    layer that consumes the report.
    """

    def __init__(self):
        self.csr_rows: set = set()
        self.dead: dict = {}
        self.ring_rows: set = set()
        self.compactions = 0
        self.n_inserts = 0
        self.n_deletes = 0
        self.spilled = False
        self.staged_bytes = 0


class SlabPartition:
    """Host-side slab decomposition of a dataset over a GLOBAL grid spec.

    The device-facing half of the grid-aware ring layout (module docstring,
    'Grid-aware ring'): slab ``s`` owns global rows ``[s*rps, (s+1)*rps)``
    and its CSR :class:`~repro.core.grid.CellTable` covers
    ``rps + 2*halo`` rows (its own plus ``halo`` boundary rows replicated
    from each neighbour).  All binning is done with ids derived from the
    GLOBAL spec (global id minus the slab's row offset), so per-row CSR
    content is bitwise what the replicated global table holds for the same
    rows — the root of the grid-ring layout's bit-identity story.

    Incremental updates: :meth:`apply_delta` is LSM-tiered (module
    docstring, 'Hot-ring (LSM) ingest contract').  Inserts append to the
    owning slab's fixed-capacity hot ring; CSR deletes tombstone dead slots
    in place; ring deletes compact the tiny ring host-side.  The CSR tables
    change only when a ring cannot absorb its insert batch or the tombstone
    fraction crosses ``tombstone_threshold`` — then :meth:`compact` folds
    every ring into the slab CSRs (halo replication happens at the fold)
    and purges tombstones, recovering a partition element-identical to a
    fresh :meth:`build` of the updated dataset.  Every call returns a
    :class:`DeltaReport` naming exactly which device rows/slots changed.

    ``members[s]`` holds each table's points as indices into the CURRENT
    dataset order (the session's kept-in-original-order-plus-appends
    order), always ascending — the delta router's join key.  Ring members
    (``ring_mem[s]``) are kept separately and are always strictly greater
    than every CSR member id (inserts take the top of the index space and
    CSR tables gain ids only at compaction, which empties the rings) — the
    invariant that makes the compaction fold a pure sorted append.
    """

    def __init__(self, spec: G.GridSpec, p: int, rps: int, halo: int,
                 tables: list, members: list, m: int, *,
                 ring_cap: int = 256):
        self.spec = spec
        self.p = p
        self.rps = rps
        self.halo = halo
        self.tables = tables          # per-slab CellTable of numpy arrays
        self.members = members        # per-slab sorted global indices
        self.m = m
        # per-slab Stage-2 ownership masks over the sorted table entries,
        # cached so a delta recomputes them for TOUCHED slabs only
        self._owned: list = [None] * p
        # hot append rings: freshly inserted points, owner slab only
        self.ring_cap = int(ring_cap)
        self.ring_pts = [np.zeros((0, 3), np.float32) for _ in range(p)]
        self.ring_ids = [np.zeros(0, np.int64) for _ in range(p)]
        self.ring_mem = [np.zeros(0, np.int64) for _ in range(p)]
        self.tombstone_threshold = 0.25
        self.compactions = 0

    @property
    def local_spec(self) -> G.GridSpec:
        """Static spec of one slab table: rps + 2*halo rows, global cols.
        (min_x/min_y are the GLOBAL origin — ids are always computed
        globally and offset, never re-derived from a shifted origin.)"""
        return G.GridSpec(self.spec.min_x, self.spec.min_y,
                          self.spec.cell_width,
                          self.rps + 2 * self.halo, self.spec.n_cols)

    @classmethod
    def build(cls, spec: G.GridSpec, points_xyz, p: int, halo: int,
              ring_cap: int = 256) -> "SlabPartition":
        pts = np.asarray(points_xyz)
        x, y, z = pts[:, 0], pts[:, 1], pts[:, 2]
        rps = slab_rows(spec, p)
        ids = G.cell_ids_host(spec, x, y)
        row = ids // spec.n_cols
        n_local = (rps + 2 * halo) * spec.n_cols
        tables, members = [], []
        for s in range(p):
            lo = s * rps
            mem = np.nonzero((row >= lo - halo)
                             & (row < lo + rps + halo))[0]
            lids = ids[mem] - (lo - halo) * spec.n_cols
            ordr = np.argsort(lids, kind="stable").astype(np.int32)
            cell_start = np.searchsorted(
                lids[ordr], np.arange(n_local + 1, dtype=np.int64),
                side="left").astype(np.int32)
            tables.append(G.CellTable(
                x[mem][ordr], y[mem][ordr], z[mem][ordr], cell_start, ordr))
            members.append(mem.astype(np.int64))
        return cls(spec, p, rps, halo, tables, members, pts.shape[0],
                   ring_cap=ring_cap)

    def apply_delta(self, inserts=None, deletes=None) -> DeltaReport:
        """LSM-tiered delta: rings absorb inserts, tombstones absorb deletes.

        ``deletes`` are indices into the CURRENT dataset order; ``inserts``
        append after compaction, exactly like
        :func:`repro.core.pipeline.plan_delta`'s dataset reconstruction —
        so ``compact()`` always recovers a partition element-identical to a
        fresh build of that reconstructed dataset (and queries see the
        same candidate multiset at every intermediate state).  Returns a
        :class:`DeltaReport` naming the touched device rows/slots.
        """
        spec = self.spec
        rep = DeltaReport()
        dels = np.unique(np.asarray(deletes, dtype=np.int64)) \
            if deletes is not None and np.size(deletes) else None
        if dels is not None and (dels[0] < 0 or dels[-1] >= self.m):
            raise IndexError(f"delete index out of range [0, {self.m})")
        ins = np.asarray(inserts) if inserts is not None \
            and np.size(inserts) else None
        m_kept = self.m - (0 if dels is None else dels.size)
        rep.n_deletes = 0 if dels is None else int(dels.size)
        rep.n_inserts = 0 if ins is None else int(ins.shape[0])
        lspec = self.local_spec

        # --- phase 1: hot-ring deletes (exact removal; rings stay tiny) ----
        if dels is not None:
            for s in range(self.p):
                rmem = self.ring_mem[s]
                if rmem.size:
                    hit = np.isin(rmem, dels)
                    if hit.any():
                        keep = ~hit
                        self.ring_pts[s] = self.ring_pts[s][keep]
                        self.ring_ids[s] = self.ring_ids[s][keep]
                        rmem = rmem[keep]
                        rep.ring_rows.add(s)
                self.ring_mem[s] = rmem - np.searchsorted(dels, rmem)

        # --- phase 2: CSR deletes -> tombstones (O(Δ) slots change) --------
        if dels is not None:
            for s in range(self.p):
                # membership always shifts: deletes ANYWHERE compact the
                # global order that members indexes into
                dels_local, self.members[s] = member_delta(
                    self.members[s], dels, m_kept, None)
                if dels_local is not None and dels_local.size:
                    old_order = np.asarray(self.tables[s].order)
                    t = G.rebin_delta(lspec, self.tables[s],
                                      deletes=dels_local, tombstone=True)
                    self.tables[s] = G.CellTable(
                        *(np.asarray(a) for a in t))
                    rep.dead[s] = np.nonzero(
                        (np.asarray(t.order) == -1) & (old_order != -1))[0]

        # --- phase 3: tombstone-threshold compaction -----------------------
        compacted = False
        if dels is not None \
                and self.tombstone_frac() > self.tombstone_threshold:
            self._compact_into(rep)
            compacted = True

        # --- phase 4: inserts -> hot rings (CSR spill only after a
        #     compaction has emptied every ring, preserving the id order
        #     invariant the fold depends on) ---------------------------------
        if ins is not None:
            ins_ids = G.cell_ids_host(spec, ins[:, 0], ins[:, 1])
            ins_row = ins_ids // spec.n_cols
            owner = np.minimum(ins_row // self.rps, self.p - 1)
            needed = np.bincount(owner, minlength=self.p)
            occ = np.array([self.ring_ids[s].size for s in range(self.p)])
            if not compacted and np.any(occ + needed > self.ring_cap):
                self._compact_into(rep)
                compacted = True
            if np.any(needed > self.ring_cap):
                rep.spilled = True
                for s in range(self.p):
                    lo = s * self.rps
                    mask = (ins_row >= lo - self.halo) \
                        & (ins_row < lo + self.rps + self.halo)
                    if not mask.any():
                        continue
                    base = (lo - self.halo) * spec.n_cols
                    t = G.rebin_delta(lspec, self.tables[s],
                                      inserts=ins[mask],
                                      insert_ids=ins_ids[mask] - base)
                    self.tables[s] = G.CellTable(
                        *(np.asarray(a) for a in t))
                    self.members[s] = np.concatenate(
                        [self.members[s], m_kept + np.nonzero(mask)[0]])
                    self._owned[s] = None
                    rep.csr_rows.add(s)
            else:
                for s in np.unique(owner):
                    s = int(s)
                    sel = owner == s
                    self.ring_pts[s] = np.concatenate(
                        [self.ring_pts[s], ins[sel]]) \
                        if self.ring_pts[s].size else np.array(ins[sel])
                    self.ring_ids[s] = np.concatenate(
                        [self.ring_ids[s], ins_ids[sel]])
                    self.ring_mem[s] = np.concatenate(
                        [self.ring_mem[s], m_kept + np.nonzero(sel)[0]])
                    rep.ring_rows.add(s)
        self.m = m_kept + rep.n_inserts
        return rep

    def compact(self) -> DeltaReport:
        """Fold every hot ring into its slab CSRs and purge tombstones.

        After this the partition is element-identical to a fresh
        :meth:`build` of the current logical dataset (module docstring
        contract).  Returns the staging worklist."""
        rep = DeltaReport()
        self._compact_into(rep)
        return rep

    def _compact_into(self, rep: DeltaReport) -> None:
        spec = self.spec
        lspec = self.local_spec
        all_mem = np.concatenate(self.ring_mem) if self.p else \
            np.zeros(0, np.int64)
        o = np.argsort(all_mem, kind="stable")
        all_mem = all_mem[o]
        all_ids = np.concatenate(self.ring_ids)[o]
        all_pts = np.concatenate(
            [p for p in self.ring_pts] or [np.zeros((0, 3), np.float32)],
            axis=0)[o]
        rows = all_ids // spec.n_cols
        for s in range(self.p):
            lo = s * self.rps
            purged = G.purge_tombstones(lspec, self.tables[s])
            changed = purged is not self.tables[s]
            mask = (rows >= lo - self.halo) \
                & (rows < lo + self.rps + self.halo)
            if mask.any():
                base = (lo - self.halo) * spec.n_cols
                purged = G.rebin_delta(lspec, purged, inserts=all_pts[mask],
                                       insert_ids=all_ids[mask] - base)
                self.members[s] = np.concatenate(
                    [self.members[s], all_mem[mask]])
                changed = True
            if changed:
                self.tables[s] = G.CellTable(
                    *(np.asarray(a) for a in purged))
                self._owned[s] = None
                rep.csr_rows.add(s)
                rep.dead.pop(s, None)   # the full-row restage covers it
            if self.ring_ids[s].size:
                rep.ring_rows.add(s)
        self.ring_pts = [np.zeros((0, 3), np.float32)
                         for _ in range(self.p)]
        self.ring_ids = [np.zeros(0, np.int64) for _ in range(self.p)]
        self.ring_mem = [np.zeros(0, np.int64) for _ in range(self.p)]
        self.compactions += 1
        rep.compactions += 1

    # -- ingest telemetry ----------------------------------------------------

    def tombstone_frac(self) -> float:
        """Max per-slab tombstone fraction (compaction trigger + stat)."""
        return max((G.tombstone_frac(t) for t in self.tables), default=0.0)

    def ring_occupancy(self) -> float:
        """Max per-slab hot-ring fill fraction."""
        if not self.p:
            return 0.0
        return max(self.ring_ids[s].size for s in range(self.p)) \
            / self.ring_cap

    def ring_size(self) -> int:
        """Total points currently resident in hot rings."""
        return int(sum(self.ring_ids[s].size for s in range(self.p)))

    # -- per-slab device staging helpers ------------------------------------

    def owned_mask(self, s: int) -> np.ndarray:
        """Stage-2 ownership mask over slab ``s``'s sorted table entries
        (cached; invalidated only when the slab's CSR layout changes —
        tombstones keep it valid since dead slots keep their position)."""
        if self._owned[s] is None:
            cs = np.asarray(self.tables[s].cell_start, np.int64)
            rows = np.repeat(np.arange(cs.size - 1, dtype=np.int64),
                             np.diff(cs)) // self.spec.n_cols
            self._owned[s] = (rows >= self.halo) \
                & (rows < self.halo + self.rps)
        return self._owned[s]

    def owned_positions(self, s: int, slots: np.ndarray) -> np.ndarray:
        """Owned-block (bx/by/bz) positions of the given sorted-array slots
        (only the owned ones; halo copies have no Stage-2 block slot)."""
        o = self.owned_mask(s)
        brank = np.cumsum(o) - 1
        owned = slots[o[slots]]
        return brank[owned]

    def slab_host_rows(self, s: int, cap: int, cap2: int) -> dict | None:
        """One slab's padded device rows (the delta-staging unit), or
        ``None`` if the slab no longer fits the given capacities."""
        t = self.tables[s]
        o = self.owned_mask(s)
        n_s = t.sx.shape[0]
        n_o = int(o.sum())
        if n_s > cap or n_o > cap2:
            return None
        dt, zt = t.sx.dtype, t.sz.dtype
        row = {"sx": np.full(cap, PAD_COORD, dt),
               "sy": np.full(cap, PAD_COORD, dt),
               "sz": np.zeros(cap, zt),
               "cell_start": np.asarray(t.cell_start, np.int32),
               "bx": np.full(cap2, PAD_COORD, dt),
               "by": np.full(cap2, PAD_COORD, dt),
               "bz": np.zeros(cap2, zt)}
        row["sx"][:n_s] = t.sx
        row["sy"][:n_s] = t.sy
        row["sz"][:n_s] = t.sz
        row["bx"][:n_o] = t.sx[o]
        row["by"][:n_o] = t.sy[o]
        row["bz"][:n_o] = t.sz[o]
        return row

    def ring_host_row(self, s: int) -> dict:
        """One slab's padded hot-ring device row (``ring_cap`` slots)."""
        dt = self.tables[s].sx.dtype if self.tables else np.float32
        zt = self.tables[s].sz.dtype if self.tables else np.float32
        row = {"rx": np.full(self.ring_cap, PAD_COORD, dt),
               "ry": np.full(self.ring_cap, PAD_COORD, dt),
               "rz": np.zeros(self.ring_cap, zt)}
        pts = self.ring_pts[s]
        r = pts.shape[0]
        if r:
            row["rx"][:r] = pts[:, 0]
            row["ry"][:r] = pts[:, 1]
            row["rz"][:r] = pts[:, 2]
        return row

    def device_tables(self, pad_multiple: int = 64, *, cap_floor: int = 0,
                      cap2_floor: int = 0) -> dict:
        """Stacked (P, ...) numpy arrays for the ring executor's rotating
        packets; point arrays padded to common caps (multiples of
        ``pad_multiple``, so balanced churn rarely changes array shapes
        and the compiled executables survive).  ``cap_floor``/``cap2_floor``
        let the staging layer keep caps sticky (grow-only) across deltas.

        Stage 1 rotates the halo'd slab tables (``sx``/``sy``/``sz``/
        ``cell_start``/``row_lo``; ``sz`` rides along for LOCAL Stage-2
        mode, whose in-scan gather gathers values by slab-sorted index)
        plus the hot-ring block (``rx``/``ry``/``rz``, ``ring_cap`` slots
        per slab — searched exhaustively, so padded slots with inf d2 are
        inert).  Stage 2 rotates SEPARATE owned-only blocks
        (``bx``/``by``/``bz``) — halo copies must not contribute to the
        global Eq. (1) sum twice, and carrying them as dead padded lanes
        would widen every Stage-2 tile by the boundary size, eating the
        Stage-1 win — and the ring block rides along (every ring point is
        owned by construction).  Padded slots hold ``PAD_COORD`` (Stage-2
        weight exactly 0) and are NEVER addressed by Stage 1
        (``cell_start[-1]`` stops short of them)."""
        def rounded(n):
            return max(pad_multiple, -(-n // pad_multiple) * pad_multiple)

        caps = [t.sx.shape[0] for t in self.tables]
        cap = max(rounded(max(caps + [1])), cap_floor)
        dt = self.tables[0].sx.dtype if self.tables else np.float32
        zt = self.tables[0].sz.dtype if self.tables else np.float32
        sx = np.full((self.p, cap), PAD_COORD, dt)
        sy = np.full((self.p, cap), PAD_COORD, dt)
        sz = np.zeros((self.p, cap), zt)
        cell_start = np.stack([np.asarray(t.cell_start, np.int32)
                               for t in self.tables])
        owned_sel = [self.owned_mask(s) for s in range(self.p)]
        for s, t in enumerate(self.tables):
            n_s = t.sx.shape[0]
            sx[s, :n_s] = t.sx
            sy[s, :n_s] = t.sy
            sz[s, :n_s] = t.sz
        cap2 = max(rounded(max([int(o.sum()) for o in owned_sel] + [1])),
                   cap2_floor)
        bx = np.full((self.p, cap2), PAD_COORD, dt)
        by = np.full((self.p, cap2), PAD_COORD, dt)
        bz = np.zeros((self.p, cap2), zt)
        for s, (t, o) in enumerate(zip(self.tables, owned_sel)):
            n_o = int(o.sum())
            bx[s, :n_o] = t.sx[o]
            by[s, :n_o] = t.sy[o]
            bz[s, :n_o] = t.sz[o]
        rx = np.full((self.p, self.ring_cap), PAD_COORD, dt)
        ry = np.full((self.p, self.ring_cap), PAD_COORD, dt)
        rz = np.zeros((self.p, self.ring_cap), zt)
        for s in range(self.p):
            pts = self.ring_pts[s]
            if pts.shape[0]:
                rx[s, :pts.shape[0]] = pts[:, 0]
                ry[s, :pts.shape[0]] = pts[:, 1]
                rz[s, :pts.shape[0]] = pts[:, 2]
        return {"sx": sx, "sy": sy, "sz": sz, "cell_start": cell_start,
                "row_lo": (np.arange(self.p) * self.rps).astype(np.int32),
                "bx": bx, "by": by, "bz": bz,
                "rx": rx, "ry": ry, "rz": rz}


def make_slab_aidw(
    mesh: Mesh,
    ring_axis: str,
    *,
    m_global: int,
    k: int = 15,
    cell_factor: float = 4.0,
    bounds=(0.0, 1.0, 0.0, 1.0),
    window: int = 256,
    q_block: int = 0,
    alphas=A.DEFAULT_ALPHAS,
    r_min: float = A.DEFAULT_R_MIN,
    r_max: float = A.DEFAULT_R_MAX,
):
    """fn(points (P*cap, 3), queries (P*qcap, 2), n_points, area) -> values.

    Inputs arrive slab-partitioned (see :func:`partition_by_slab`) and sharded
    over ``ring_axis``; sentinel-padded rows yield NaN outputs (dropped by the
    caller via the index map).
    """
    p_ring = mesh.shape[ring_axis]
    spec, rps = slab_plan(m_global, p_ring, bounds=bounds,
                          cell_factor=cell_factor)
    min_y = bounds[2]
    cw = spec.cell_width
    max_level = min(K.auto_max_level(spec, max(m_global // p_ring, 1), k) + 1,
                    rps)
    fwd = [(i, (i + 1) % p_ring) for i in range(p_ring)]
    bwd = [(i, (i - 1) % p_ring) for i in range(p_ring)]

    def local_fn(points, queries, n_points, area):
        s = jax.lax.axis_index(ring_axis)
        # --- halo exchange: whole neighbour slabs, one hop each way --------
        prev_blk = jax.lax.ppermute(points, ring_axis, fwd)   # from s-1
        next_blk = jax.lax.ppermute(points, ring_axis, bwd)   # from s+1
        pts = jnp.concatenate([prev_blk, points, next_blk], axis=0)

        # --- shift into the local 3*rps-row frame --------------------------
        y_base = min_y + (s.astype(jnp.float32) - 1.0) * (rps * cw)
        ys = pts[:, 1] - y_base
        # wraparound halos (slab 0's 'prev' etc.) land outside -> sentinel
        ok = (ys >= 0.0) & (ys < spec.n_rows * cw) & (pts[:, 0] < PAD_COORD / 2)
        xs = jnp.where(ok, pts[:, 0], PAD_COORD)
        ys = jnp.where(ok, ys, PAD_COORD)
        table = G.bin_points(spec, xs, ys, pts[:, 2])

        qy = queries[:, 1] - y_base
        q_ok = queries[:, 0] < PAD_COORD / 2
        q_local = jnp.stack(
            [jnp.where(q_ok, queries[:, 0], PAD_COORD),
             jnp.where(q_ok, qy, PAD_COORD)], axis=1)

        # --- paper Stage 1 on the local grid --------------------------------
        res = K.grid_knn(spec, table, q_local, k, max_level, window,
                         min(4096, queries.shape[0]), True)
        r_obs = K.mean_nn_distance(res.d2)
        alpha = A.adaptive_alpha(r_obs, n_points, area, alphas=alphas,
                                 r_min=r_min, r_max=r_max)

        # --- Stage 2: ring rotation (global Eq. 1 sum) ----------------------
        qx = queries[:, 0]
        qy_g = queries[:, 1]

        def interp_step(carry, _):
            acc, blk = carry
            acc, blk = _ring_interp_step(ring_axis, fwd, qx, qy_g, alpha,
                                         acc, blk, q_block)
            return (acc, blk), None

        acc0 = (jnp.zeros_like(qx), jnp.zeros_like(qx))
        ((swz, sw), _), _ = jax.lax.scan(interp_step, (acc0, points), None,
                                         length=p_ring)
        return swz / sw, res.overflow

    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(ring_axis, None), P(ring_axis, None), P(), P()),
        out_specs=(P(ring_axis), P(ring_axis)),
    )
    return jax.jit(fn), spec, rps


def slab_aidw(mesh: Mesh, ring_axis: str, points_xyz, queries_xy, *,
              k: int = 15, cell_factor: float = 4.0,
              bounds=(0.0, 1.0, 0.0, 1.0), window: int = 256,
              q_block: int = 0):
    """Convenience wrapper: host-side slab partition, run, un-permute."""
    p = mesh.shape[ring_axis]
    pts = np.asarray(points_xyz)
    qs = np.asarray(queries_xy)
    m, n = len(pts), len(qs)
    fn, spec, rps = make_slab_aidw(
        mesh, ring_axis, m_global=m, k=k, cell_factor=cell_factor,
        bounds=bounds, window=window, q_block=q_block)
    cw = spec.cell_width
    pts_s, _ = partition_by_slab(pts, p, rps, cw, bounds[2])
    qs_s, q_idx = partition_by_slab(qs, p, rps, cw, bounds[2])
    area = (bounds[1] - bounds[0]) * (bounds[3] - bounds[2])
    vals, overflow = fn(
        jnp.asarray(pts_s.reshape(-1, 3)), jnp.asarray(qs_s.reshape(-1, 2)),
        jnp.float32(m), jnp.float32(area))
    vals = np.asarray(vals).reshape(p, -1)
    out = np.empty(n, np.float32)
    flat_idx = q_idx.reshape(-1)
    keep = flat_idx >= 0
    out[flat_idx[keep]] = vals.reshape(-1)[keep]
    return out, int(np.asarray(overflow).reshape(-1)[keep].sum())
