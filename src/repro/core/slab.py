"""Slab-decomposed AIDW: grid kNN with halo exchange + ring Stage 2.

The final §Perf iteration for the paper's technique at pod scale
(EXPERIMENTS.md cell 3): the ring variant's brute-force kNN doubles the step
FLOPs.  Here Stage 1 keeps the paper's GRID search, domain-decomposed:

* the study area is cut into P horizontal **slabs** of whole grid rows
  (slab s owns rows [s*rps, (s+1)*rps) of the global grid); data points and
  queries arrive pre-partitioned by slab (the natural layout of tiled
  geospatial ingestion);
* each shard receives its two neighbour slabs via collective-permute (the
  halo — one ring hop each way) and builds a LOCAL grid over
  [prev | own | next] with static dims (3*rps rows x global cols); the only
  dynamic quantity is the slab's y-offset, folded into the point/query
  coordinates, so the existing static-spec `bin_points`/`grid_knn` machinery
  applies unchanged;
* kNN is exact while the certified expansion level stays within one slab
  (max_level <= rps; overflow flags report violations — with Eq.(2)x4 cells
  and k=15 the certified level is ~5 vs rps=32 at 1B points / 512 chips);
* Stage 2 is the ring rotation from `distributed.make_ring_aidw` (the global
  Eq.(1) sum needs every data block regardless of where kNN happened).

Per-chip cost at m=n=2^30, P=512: kNN drops from O(n_loc * m) ~ 1.7e16 FLOPs
(ring brute force) to O(n_loc * window) ~ 4e9 — the step becomes one
Stage-2 sweep, halving total FLOPs vs ring AIDW.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from . import aidw as A
from . import grid as G
from . import knn as K
from .jax_compat import shard_map
from .distributed import PAD_COORD, _ring_interp_step


def slab_plan(m_global: int, p: int, *, bounds=(0.0, 1.0, 0.0, 1.0),
              cell_factor: float = 4.0) -> tuple[G.GridSpec, int]:
    """(local GridSpec with 3*rps rows, rows-per-slab) for a P-way split.

    ``bounds`` = (min_x, max_x, min_y, max_y) must be known statically (the
    ingestion contract for tiled spatial data)."""
    min_x, max_x, min_y, max_y = bounds
    area = (max_x - min_x) * (max_y - min_y)
    cw = cell_factor * (1.0 / (2.0 * math.sqrt(m_global / area)))
    cols = max(int((max_x - min_x + cw) / cw), 1)
    rows_g = max(int((max_y - min_y + cw) / cw), 1)
    rps = -(-rows_g // p)                      # rows per slab (ceil)
    local = G.GridSpec(min_x, 0.0, cw, 3 * rps, cols)
    return local, rps


def partition_by_slab(points: np.ndarray, p: int, rps: int, cw: float,
                      min_y: float = 0.0):
    """Host-side: group rows into slabs, pad to equal size with sentinels.

    Returns (slabbed (p, cap, d), original_index (p, cap) with -1 padding).
    """
    rows = np.clip(((points[:, 1] - min_y) / cw).astype(np.int64), 0,
                   p * rps - 1)
    slab = np.minimum(rows // rps, p - 1)
    cap = int(np.bincount(slab, minlength=p).max())
    d = points.shape[1]
    out = np.full((p, cap, d), PAD_COORD, dtype=points.dtype)
    idx = np.full((p, cap), -1, dtype=np.int64)
    for s in range(p):
        sel = np.nonzero(slab == s)[0]
        out[s, : len(sel)] = points[sel]
        idx[s, : len(sel)] = sel
    return out, idx


def make_slab_aidw(
    mesh: Mesh,
    ring_axis: str,
    *,
    m_global: int,
    k: int = 15,
    cell_factor: float = 4.0,
    bounds=(0.0, 1.0, 0.0, 1.0),
    window: int = 256,
    q_block: int = 0,
    alphas=A.DEFAULT_ALPHAS,
    r_min: float = A.DEFAULT_R_MIN,
    r_max: float = A.DEFAULT_R_MAX,
):
    """fn(points (P*cap, 3), queries (P*qcap, 2), n_points, area) -> values.

    Inputs arrive slab-partitioned (see :func:`partition_by_slab`) and sharded
    over ``ring_axis``; sentinel-padded rows yield NaN outputs (dropped by the
    caller via the index map).
    """
    p_ring = mesh.shape[ring_axis]
    spec, rps = slab_plan(m_global, p_ring, bounds=bounds,
                          cell_factor=cell_factor)
    min_y = bounds[2]
    cw = spec.cell_width
    max_level = min(K.auto_max_level(spec, max(m_global // p_ring, 1), k) + 1,
                    rps)
    fwd = [(i, (i + 1) % p_ring) for i in range(p_ring)]
    bwd = [(i, (i - 1) % p_ring) for i in range(p_ring)]

    def local_fn(points, queries, n_points, area):
        s = jax.lax.axis_index(ring_axis)
        # --- halo exchange: whole neighbour slabs, one hop each way --------
        prev_blk = jax.lax.ppermute(points, ring_axis, fwd)   # from s-1
        next_blk = jax.lax.ppermute(points, ring_axis, bwd)   # from s+1
        pts = jnp.concatenate([prev_blk, points, next_blk], axis=0)

        # --- shift into the local 3*rps-row frame --------------------------
        y_base = min_y + (s.astype(jnp.float32) - 1.0) * (rps * cw)
        ys = pts[:, 1] - y_base
        # wraparound halos (slab 0's 'prev' etc.) land outside -> sentinel
        ok = (ys >= 0.0) & (ys < spec.n_rows * cw) & (pts[:, 0] < PAD_COORD / 2)
        xs = jnp.where(ok, pts[:, 0], PAD_COORD)
        ys = jnp.where(ok, ys, PAD_COORD)
        table = G.bin_points(spec, xs, ys, pts[:, 2])

        qy = queries[:, 1] - y_base
        q_ok = queries[:, 0] < PAD_COORD / 2
        q_local = jnp.stack(
            [jnp.where(q_ok, queries[:, 0], PAD_COORD),
             jnp.where(q_ok, qy, PAD_COORD)], axis=1)

        # --- paper Stage 1 on the local grid --------------------------------
        res = K.grid_knn(spec, table, q_local, k, max_level, window,
                         min(4096, queries.shape[0]), True)
        r_obs = K.mean_nn_distance(res.d2)
        alpha = A.adaptive_alpha(r_obs, n_points, area, alphas=alphas,
                                 r_min=r_min, r_max=r_max)

        # --- Stage 2: ring rotation (global Eq. 1 sum) ----------------------
        qx = queries[:, 0]
        qy_g = queries[:, 1]

        def interp_step(carry, _):
            acc, blk = carry
            acc, blk = _ring_interp_step(ring_axis, fwd, qx, qy_g, alpha,
                                         acc, blk, q_block)
            return (acc, blk), None

        acc0 = (jnp.zeros_like(qx), jnp.zeros_like(qx))
        ((swz, sw), _), _ = jax.lax.scan(interp_step, (acc0, points), None,
                                         length=p_ring)
        return swz / sw, res.overflow

    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(ring_axis, None), P(ring_axis, None), P(), P()),
        out_specs=(P(ring_axis), P(ring_axis)),
    )
    return jax.jit(fn), spec, rps


def slab_aidw(mesh: Mesh, ring_axis: str, points_xyz, queries_xy, *,
              k: int = 15, cell_factor: float = 4.0,
              bounds=(0.0, 1.0, 0.0, 1.0), window: int = 256,
              q_block: int = 0):
    """Convenience wrapper: host-side slab partition, run, un-permute."""
    p = mesh.shape[ring_axis]
    pts = np.asarray(points_xyz)
    qs = np.asarray(queries_xy)
    m, n = len(pts), len(qs)
    fn, spec, rps = make_slab_aidw(
        mesh, ring_axis, m_global=m, k=k, cell_factor=cell_factor,
        bounds=bounds, window=window, q_block=q_block)
    cw = spec.cell_width
    pts_s, _ = partition_by_slab(pts, p, rps, cw, bounds[2])
    qs_s, q_idx = partition_by_slab(qs, p, rps, cw, bounds[2])
    area = (bounds[1] - bounds[0]) * (bounds[3] - bounds[2])
    vals, overflow = fn(
        jnp.asarray(pts_s.reshape(-1, 3)), jnp.asarray(qs_s.reshape(-1, 2)),
        jnp.float32(m), jnp.float32(area))
    vals = np.asarray(vals).reshape(p, -1)
    out = np.empty(n, np.float32)
    flat_idx = q_idx.reshape(-1)
    keep = flat_idx >= 0
    out[flat_idx[keep]] = vals.reshape(-1)[keep]
    return out, int(np.asarray(overflow).reshape(-1)[keep].sum())
