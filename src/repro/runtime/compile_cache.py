"""Persistent XLA compilation cache + process-wide compile observability.

This is the one place the repo touches ``jax.experimental.compilation_cache``
semantics.  Two independent services live here:

* :func:`enable` turns on JAX's persistent compilation cache at a directory
  (argument, else the ``AIDW_CACHE_DIR`` env var), so a restarted process —
  or a subprocess fleet host sharing the same directory — deserializes XLA
  executables instead of recompiling them.  The two persistence thresholds
  (``min_compile_time_secs``, ``min_entry_size_bytes``) are forced to zero:
  the default 1-second floor would silently skip most CPU-backend compiles,
  which are exactly the ones our CI cold-start gates measure.

* :func:`install_listeners` hooks ``jax._src.monitoring`` so the process
  keeps live counters of persistent-cache hits, cache-eligible compile
  requests, and backend compiles (count + wall seconds).  The backend
  counter fires on every dispatch that reaches the XLA compile layer —
  including persistent-cache *retrievals* — but NOT on in-memory jit-cache
  hits or on calls to AOT ``Compiled`` executables, which makes its delta
  the exact "did the hot path compile?" predicate the serving layer's
  post-warmup anomaly detection needs.

:func:`sync_registry` folds the since-last-sync deltas into an
``obs.Registry`` as ``compile_cache_hits`` / ``compile_cache_misses`` /
``backend_compiles`` counters, so fleet-level ``merge_states`` stays
additive (each host contributes its own deltas, never absolute totals
twice).

``python -m repro.runtime.compile_cache --cache-dir DIR [--min-hits N]``
runs a self-test: compile one canonical jit signature against the cache and
print the stats as JSON; with ``--min-hits`` it exits nonzero unless the
persistent cache served at least N hits — CI uses two successive runs to
assert a second process start actually hits the shared cache.
"""

from __future__ import annotations

import json
import os
import threading
import weakref

__all__ = ["enable", "install_listeners", "cache_stats", "backend_compiles",
           "sync_registry", "background_compile_options"]

_LOCK = threading.Lock()
_LISTENERS_INSTALLED = False
_COUNTS = {
    "persistent_cache_hits": 0,     # executables deserialized from disk
    "cache_requests": 0,            # compile requests while cache enabled
    "backend_compiles": 0,          # dispatches reaching the compile layer
    "backend_compile_s": 0.0,       # wall seconds spent in that layer
}
# per-Registry baseline of the last sync_registry() fold, keyed weakly so a
# dropped registry doesn't pin its baseline forever
_SYNCED: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()

_HIT_EVENT = "/jax/compilation_cache/cache_hits"
_REQUEST_EVENT = "/jax/compilation_cache/compile_requests_use_cache"
_COMPILE_DURATION_EVENT = "/jax/core/compile/backend_compile_duration"


def _on_event(event: str, **kwargs) -> None:
    with _LOCK:
        if event == _HIT_EVENT:
            _COUNTS["persistent_cache_hits"] += 1
        elif event == _REQUEST_EVENT:
            _COUNTS["cache_requests"] += 1


def _on_duration(event: str, duration_secs: float, **kwargs) -> None:
    if event != _COMPILE_DURATION_EVENT:
        return
    with _LOCK:
        _COUNTS["backend_compiles"] += 1
        _COUNTS["backend_compile_s"] += float(duration_secs)


def install_listeners() -> None:
    """Idempotently register the jax monitoring hooks that feed
    :func:`cache_stats`.  Safe to call before or after ``enable``; compiles
    that happened before the first call are not counted."""
    global _LISTENERS_INSTALLED
    with _LOCK:
        if _LISTENERS_INSTALLED:
            return
        _LISTENERS_INSTALLED = True
    from jax._src import monitoring

    monitoring.register_event_listener(_on_event)
    monitoring.register_event_duration_secs_listener(_on_duration)


def enable(cache_dir: str | None = None) -> str | None:
    """Enable the persistent compilation cache at ``cache_dir`` (falling
    back to ``$AIDW_CACHE_DIR``) and install the compile listeners.

    Returns the resolved cache directory, or ``None`` when neither the
    argument nor the env var names one — in that case only the listeners
    are installed (compile counting works without a cache)."""
    install_listeners()
    cache_dir = cache_dir or os.environ.get("AIDW_CACHE_DIR")
    if not cache_dir:
        return None
    cache_dir = os.path.abspath(os.path.expanduser(cache_dir))
    os.makedirs(cache_dir, exist_ok=True)
    import jax

    jax.config.update("jax_compilation_cache_dir", cache_dir)
    # the defaults (1s floor, nonzero size floor) skip fast CPU compiles —
    # exactly the executables the cold-start gates need persisted
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)
    return cache_dir


def background_compile_options() -> dict | None:
    """Compiler options for compiles running CONCURRENTLY with serving.

    On the CPU backend, XLA's parallel LLVM codegen (default split count
    32) fans compile work out across every core — a background prewarm
    would steal the very cores the worker is executing on and double the
    foreground p99.  ``split_count=1`` keeps codegen on the (deprioritized)
    compiling thread, and on small-core boxes is FASTER outright (the
    parallel-split overhead is pure waste there).  Non-CPU backends return
    ``None``: device compiles don't contend with host-side serving.

    Note the trade-off: compiler options are part of the persistent-cache
    key, so entries written under these options are only shared with other
    *prewarm* compiles — a lazily-compiling process misses them (and vice
    versa).  The prewarm paths all use this same function, so fleet hosts
    still share one set of entries."""
    import jax

    if jax.default_backend() == "cpu":
        return {"xla_cpu_parallel_codegen_split_count": 1}
    return None


def cache_stats() -> dict:
    """Point-in-time copy of the process compile counters.  ``misses`` is
    derived (requests that reached the compile layer without a persistent
    hit); all fields are 0 until :func:`install_listeners` ran."""
    with _LOCK:
        snap = dict(_COUNTS)
    snap["persistent_cache_misses"] = max(
        0, snap["cache_requests"] - snap["persistent_cache_hits"])
    return snap


def backend_compiles() -> int:
    """Number of dispatches that reached the XLA compile layer so far.
    Deltas of this value bracket hot-path work: in-memory jit-cache hits and
    AOT ``Compiled`` calls do not move it."""
    with _LOCK:
        return _COUNTS["backend_compiles"]


def sync_registry(registry) -> dict:
    """Fold the counter deltas since this registry's last sync into it as
    ``compile_cache_hits`` / ``compile_cache_misses`` / ``backend_compiles``
    counters.  Delta-based so per-host registries stay additive under the
    fleet's ``Registry.merge_states``.  Returns the deltas applied."""
    snap = cache_stats()
    base = _SYNCED.get(registry) or {k: 0 for k in snap}
    delta = {k: snap[k] - base.get(k, 0) for k in snap}
    _SYNCED[registry] = snap
    registry.inc("compile_cache_hits", int(delta["persistent_cache_hits"]))
    registry.inc("compile_cache_misses",
                 int(max(0, delta["persistent_cache_misses"])))
    registry.inc("backend_compiles", int(delta["backend_compiles"]))
    return delta


def _selftest(argv=None) -> int:
    import argparse
    import time

    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--cache-dir", default=None,
                   help="cache directory (default: $AIDW_CACHE_DIR)")
    p.add_argument("--min-hits", type=int, default=None, metavar="N",
                   help="exit nonzero unless the persistent cache served "
                        ">= N hits (use on the second of two runs)")
    args = p.parse_args(argv)

    resolved = enable(args.cache_dir)
    import jax
    import jax.numpy as jnp

    # one canonical signature: stable across runs so the second process's
    # compile request is a byte-identical cache key
    @jax.jit
    def probe(x):
        return jnp.tanh(x @ x.T).sum()

    t0 = time.perf_counter()
    probe(jnp.arange(4096, dtype=jnp.float32).reshape(64, 64)) \
        .block_until_ready()
    stats = cache_stats()
    stats["cache_dir"] = resolved
    stats["probe_s"] = time.perf_counter() - t0
    print(json.dumps(stats, indent=1))
    if args.min_hits is not None and \
            stats["persistent_cache_hits"] < args.min_hits:
        print(f"FAIL: {stats['persistent_cache_hits']} persistent cache "
              f"hits < required {args.min_hits}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(_selftest())
