"""Fault-tolerance runtime: health, stragglers, elastic rescale planning.

The container is single-host, so the *policies* here are exercised against
simulated telemetry in tests; the *mechanisms* they drive (atomic checkpoint
commit, cross-mesh restore, deterministic step-indexed data) are the real
implementations in ``repro.checkpoint`` / ``repro.data``.

Failure model and response, as deployed on a fleet:

  node death       -> heartbeat timeout -> ElasticPlanner proposes the largest
                      viable mesh over survivors -> job restarts, restores the
                      latest complete checkpoint with new shardings
                      (CheckpointManager.restore(shardings=new)) and replays
                      the data stream from the restored step (pure function of
                      step index -> no data loss/duplication).
  straggler        -> StragglerDetector flags chips whose step time exceeds
                      k x the fleet EWMA; the planner can evict its host
                      (same path as node death) or keep it on probation.
  silent data corr.-> loss/grad-norm spike guard in the train loop triggers a
                      rollback-to-checkpoint (train.py --max-grad-spikes).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field


class HeartbeatMonitor:
    """Tracks last-seen time per host; hosts are dead after ``timeout_s``.

    Shared by the training fleet (node death -> elastic rescale) and the
    serving fleet (``repro.serving.cluster.router`` drains a dead host and
    resubmits its queries).  Membership is dynamic: ``add`` registers a
    host mid-run (a recovered or newly joined fleet member — it starts
    alive, last seen "now"), ``remove`` forgets one (drained hosts stop
    counting toward ``dead_hosts`` so a drain isn't re-reported forever).
    """

    def __init__(self, hosts, timeout_s: float = 60.0, clock=time.monotonic):
        self._clock = clock
        self.timeout_s = timeout_s
        now = clock()
        self._last = {h: now for h in hosts}

    def beat(self, host):
        self._last[host] = self._clock()

    def add(self, host) -> None:
        """Register ``host`` (idempotent); it starts alive as of now."""
        self._last.setdefault(host, self._clock())

    def remove(self, host) -> None:
        """Forget ``host`` (idempotent): no longer reported dead or alive."""
        self._last.pop(host, None)

    @property
    def hosts(self) -> list:
        return list(self._last)

    def dead_hosts(self) -> list:
        now = self._clock()
        return [h for h, t in self._last.items() if now - t > self.timeout_s]

    def alive_hosts(self) -> list:
        now = self._clock()
        return [h for h, t in self._last.items() if now - t <= self.timeout_s]


class StragglerDetector:
    """EWMA step-time outlier detection, per worker.

    A worker is a straggler when its own step-time EWMA exceeds
    ``threshold`` x the fleet-median EWMA for ``patience`` consecutive steps.
    """

    def __init__(self, workers, *, alpha: float = 0.2, threshold: float = 1.5,
                 patience: int = 3):
        self.alpha = alpha
        self.threshold = threshold
        self.patience = patience
        self._ewma = {w: None for w in workers}
        self._strikes = {w: 0 for w in workers}

    def observe(self, worker, step_time_s: float):
        prev = self._ewma[worker]
        self._ewma[worker] = (step_time_s if prev is None
                              else self.alpha * step_time_s + (1 - self.alpha) * prev)

    def _median(self) -> float:
        vals = sorted(v for v in self._ewma.values() if v is not None)
        if not vals:
            return 0.0
        return vals[len(vals) // 2]

    def end_step(self) -> list:
        med = self._median()
        out = []
        for w, v in self._ewma.items():
            if v is not None and med > 0 and v > self.threshold * med:
                self._strikes[w] += 1
            else:
                self._strikes[w] = 0
            if self._strikes[w] >= self.patience:
                out.append(w)
        return out


@dataclass(frozen=True)
class RescalePlan:
    mesh_shape: tuple
    mesh_axes: tuple
    n_chips: int
    dropped_chips: int
    global_batch_divisor: int   # batch must stay divisible by this
    reshard_restore: bool = True


class ElasticPlanner:
    """Propose the largest viable mesh after losing hosts.

    Keeps the model axis FIXED (tensor-parallel degree is baked into layout
    economics) and shrinks the data/pod axes to the largest whole number of
    surviving model-groups.  Chips stranded by the shrink idle until the next
    full-repair window.
    """

    def __init__(self, model_parallel: int, chips_per_host: int = 4):
        self.model_parallel = model_parallel
        self.chips_per_host = chips_per_host

    def plan(self, surviving_chips: int) -> RescalePlan:
        mp = self.model_parallel
        data = surviving_chips // mp
        if data < 1:
            raise RuntimeError(
                f"cannot fit model-parallel degree {mp} on {surviving_chips} chips")
        used = data * mp
        return RescalePlan(
            mesh_shape=(data, mp), mesh_axes=("data", "model"),
            n_chips=used, dropped_chips=surviving_chips - used,
            global_batch_divisor=data)


@dataclass
class SpikeGuard:
    """Loss/grad-norm spike detector -> rollback trigger (silent corruption)."""

    window: int = 20
    factor: float = 10.0
    _hist: list = field(default_factory=list)

    def observe(self, value: float) -> bool:
        """Returns True if ``value`` is a spike vs the recent median."""
        import math
        if not math.isfinite(value):
            return True
        h = sorted(self._hist[-self.window:])
        spike = bool(h) and value > self.factor * h[len(h) // 2]
        self._hist.append(value)
        return spike
