"""Fault-tolerance runtime: heartbeats, stragglers, elastic rescale plans."""
from .fault_tolerance import (ElasticPlanner, HeartbeatMonitor, RescalePlan,
                              SpikeGuard, StragglerDetector)
