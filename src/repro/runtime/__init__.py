"""Runtime services: fault tolerance (heartbeats, stragglers, elastic
rescale plans) and the persistent-compilation-cache layer."""
from . import compile_cache
from .fault_tolerance import (ElasticPlanner, HeartbeatMonitor, RescalePlan,
                              SpikeGuard, StragglerDetector)

__all__ = ["ElasticPlanner", "HeartbeatMonitor", "RescalePlan", "SpikeGuard",
           "StragglerDetector", "compile_cache"]
