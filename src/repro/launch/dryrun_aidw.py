import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""AIDW production dry-run — the paper's technique on the 512-chip mesh.

Workload: the billion-point regime the paper's citations gesture at (Guan &
Wu 2010 process ~1e9 LiDAR points): m = n = 2^30 points/queries in the unit
square, k = 15.  Cells:

* ``paper``      — the paper's own scheme scaled up: queries sharded over all
                   512 chips, data points + grid REPLICATED per chip (this is
                   exactly the single-GPU algorithm, fanned out).  Fits only
                   because 2^30 x 12 B = 12.9 GB/chip — at 2^31 it is DEAD.
* ``ring``       — beyond-paper domain decomposition: data sharded into 512
                   ring blocks (25 MB/chip), both stages rotate blocks via
                   collective-permute.  NAIVE version materializes the
                   (n_loc, m_loc) distance tile.
* ``ring_blocked`` — + query chunking inside each ring step (the §Perf
                   iteration that makes the tile HBM-resident).
* ``slab``       — final iteration: Stage-1 keeps the paper's GRID search,
                   domain-decomposed into row slabs with halo exchange
                   (core/slab.py); only Stage 2 rings.  Halves step FLOPs.

Since both stages sit inside a length-512 lax.scan (HLO cost analysis counts
the body once), FLOPs/wire are reported analytically (exact — the body is
three dense einsums) alongside the compiled memory_analysis, which is the
quantity the scan does NOT distort.
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aidw as A
from repro.core.jax_compat import set_mesh as compat_set_mesh
from repro.core import grid as G
from repro.core import knn as K
from repro.core.distributed import make_ring_aidw
from repro.launch.dryrun import (HBM_BW, LINK_BW, PEAK_FLOPS, collective_stats,
                                 roofline_terms)
from repro.launch.mesh import make_ring_mesh

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun_aidw"

M = N = 2 ** 30        # data points / queries (paper protocol: equal counts)
K_NN = 15
CELL_FACTOR = 4.0      # Eq.(2) width * 4: 1B-point grid table must fit HBM
                       # (cf=1 -> 4.3e9 cells x 4 B = 17 GB replicated: OOM)


def _unit_square_spec(m: int, cell_factor: float) -> G.GridSpec:
    """Static GridSpec for the synthetic unit-square workload (bounds known)."""
    cw = cell_factor * G.expected_nn_distance(m, 1.0)
    n = int((1.0 + cw) / cw)
    return G.GridSpec(0.0, 0.0, cw, n, n)


def paper_step_fn(spec: G.GridSpec, n_chips: int):
    """The paper's scheme at scale: replicated data+grid, sharded queries."""

    def step(px, py, pz, queries):
        table = G.bin_points(spec, px, py, pz)
        res = K.grid_knn(spec, table, queries, K_NN, None, 256, 4096, True)
        r_obs = K.mean_nn_distance(res.d2)
        alpha = A.adaptive_alpha(r_obs, M, 1.0)
        # double blocking: (512 x 2^19) tiles + accumulators (1B-point scale)
        return A.weighted_interpolate(queries, jnp.stack([px, py], 1), pz,
                                      alpha, 512, 2 ** 19)

    return step


def analytic_aidw(kind: str, n_chips: int, q_block: int) -> dict:
    """Exact FLOPs/wire for the scan-hidden parts (8 FLOPs per q-p pair:
    2 sub, 2 mul, 1 add for d2; ~3 for weight+accumulate)."""
    n_loc = N // n_chips
    m_loc = M // n_chips
    pair_flops = 8.0
    stage2 = n_loc * float(M) * pair_flops
    if kind == "paper":
        # grid kNN ~ window(256) candidates/query + stage2 over ALL m
        knn = n_loc * 256 * pair_flops
        wire = 0.0
    elif kind == "slab":
        knn = n_loc * 256 * pair_flops               # local grid search
        wire = (2.0 * m_loc * 12.0                   # halo (both neighbours)
                + n_chips * (m_loc * 12.0))          # stage-2 rotations
    elif kind == "grid_ring":
        # grid-aware ring (PR 5): rotating slab CSR tables; per query the
        # candidate count comes from the census, the wire adds the slab's
        # CSR offset array to every rotation
        from repro.launch.analytic import aidw_ring_stage1_census

        census = aidw_ring_stage1_census(M, n_chips, K_NN,
                                         cell_factor=CELL_FACTOR)
        knn = n_loc * census.grid_candidates * pair_flops
        cells_loc = 4.0 * (M / n_chips)              # ~n_cells/P offsets x 4B
        wire = 2.0 * n_chips * (m_loc * 12.0 + cells_loc)
    else:
        knn = n_loc * float(M) * pair_flops          # ring brute kNN
        wire = 2.0 * n_chips * (m_loc * 12.0)        # 2 stages x 512 rotations
    return {"flops": knn + stage2, "wire_bytes": wire}


def run_cell(kind: str, *, force: bool = False, q_block: int = 512) -> dict:
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    out_path = ARTIFACTS / f"aidw_1b__{kind}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    mesh = make_ring_mesh(512)
    n_chips = 512
    rec = {"cell": f"aidw_1b_{kind}", "m": M, "n": N, "k": K_NN,
           "n_chips": n_chips}
    t0 = time.time()
    try:
        from jax.sharding import NamedSharding, PartitionSpec as P

        if kind == "slab":
            from repro.core.slab import make_slab_aidw

            fn, spec, rps = make_slab_aidw(
                mesh, "ring", m_global=M, k=K_NN, cell_factor=CELL_FACTOR,
                q_block=q_block)
            rec["grid"] = {"rows_local": spec.n_rows, "cols": spec.n_cols,
                           "rows_per_slab": rps}
            args = (jax.ShapeDtypeStruct((M, 3), jnp.float32),
                    jax.ShapeDtypeStruct((N, 2), jnp.float32),
                    jax.ShapeDtypeStruct((), jnp.float32),
                    jax.ShapeDtypeStruct((), jnp.float32))
            with compat_set_mesh(mesh):
                compiled = fn.lower(*args).compile()
        elif kind == "paper":
            spec = _unit_square_spec(M, CELL_FACTOR)
            rec["grid"] = {"rows": spec.n_rows, "cols": spec.n_cols,
                           "cell_width": spec.cell_width}
            fn = paper_step_fn(spec, n_chips)
            rep = NamedSharding(mesh, P())
            shq = NamedSharding(mesh, P(("ring",), None))
            jitted = jax.jit(fn, in_shardings=(rep, rep, rep, shq))
            args = (jax.ShapeDtypeStruct((M,), jnp.float32),) * 3 + (
                jax.ShapeDtypeStruct((N, 2), jnp.float32),)
        elif kind == "grid_ring":
            from repro.core.distributed import make_grid_ring_aidw
            from repro.core.slab import slab_rows

            spec = _unit_square_spec(M, CELL_FACTOR)
            rps = slab_rows(spec, n_chips)
            max_level = K.auto_max_level(spec, M // n_chips, K_NN)
            halo = max_level
            # cap: owned points + 2*halo rows of boundary copies
            per_row = M / max(spec.n_rows, 1)
            cap = int(M // n_chips + 2 * halo * per_row + 64)
            n_local = (rps + 2 * halo) * spec.n_cols
            rec["grid"] = {"rows": spec.n_rows, "cols": spec.n_cols,
                           "rps": rps, "halo": halo, "cap": cap}
            cap2 = int(M // n_chips + 64)
            fn = make_grid_ring_aidw(mesh, "ring", spec=spec, rps=rps,
                                     halo=halo, max_level=max_level,
                                     k=K_NN, q_block=q_block)
            ring_cap = 256
            args = ((jax.ShapeDtypeStruct((n_chips, cap), jnp.float32),) * 3
                    + (jax.ShapeDtypeStruct((n_chips, n_local + 1),
                                            jnp.int32),
                       jax.ShapeDtypeStruct((n_chips,), jnp.int32))
                    + (jax.ShapeDtypeStruct((n_chips, cap2),
                                            jnp.float32),) * 3
                    + (jax.ShapeDtypeStruct((n_chips, ring_cap),
                                            jnp.float32),) * 3
                    + (jax.ShapeDtypeStruct((N, 2), jnp.float32),
                       jax.ShapeDtypeStruct((), jnp.float32),
                       jax.ShapeDtypeStruct((), jnp.float32)))
        else:
            qb = 0 if kind == "ring" else q_block
            fn = make_ring_aidw(mesh, "ring", k=K_NN, q_block=qb)
            args = (jax.ShapeDtypeStruct((M, 3), jnp.float32),
                    jax.ShapeDtypeStruct((N, 2), jnp.float32),
                    jax.ShapeDtypeStruct((), jnp.float32),
                    jax.ShapeDtypeStruct((), jnp.float32))

        if kind != "slab":
            with compat_set_mesh(mesh):
                lowered = jitted.lower(*args) if kind == "paper" else \
                    jax.jit(fn).lower(*args)
                compiled = lowered.compile()
        mem = compiled.memory_analysis()
        peak = ((getattr(mem, "argument_size_in_bytes", 0) or 0)
                + (getattr(mem, "temp_size_in_bytes", 0) or 0)
                + (getattr(mem, "output_size_in_bytes", 0) or 0)
                - (getattr(mem, "alias_size_in_bytes", 0) or 0))
        an = analytic_aidw(kind, n_chips, q_block)
        flops_chip = an["flops"]
        wire_chip = an["wire_bytes"] / n_chips
        # HBM traffic: stage tiles r/w once per rotation (ring) or one sweep
        if kind == "paper":
            hbm = M * 12.0 * 2  # data sweep x2 stages (+ grid table reads)
        elif kind == "slab":
            hbm = 3 * (M // n_chips) * 12.0 + (M // n_chips) * 12.0 * n_chips
        else:
            hbm = (M // n_chips) * 12.0 * 2 * n_chips  # rotations sweep
        rec.update(
            status="ok", compile_s=round(time.time() - t0, 1),
            memory={"peak_bytes_per_device": peak,
                    "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                    "temp_bytes": getattr(mem, "temp_size_in_bytes", None)},
            per_chip={"flops": flops_chip, "hbm_bytes": hbm,
                      "collective_wire_bytes": wire_chip},
            analytic=an,
            roofline=roofline_terms(flops_chip, hbm, wire_chip),
            fits_hbm=bool(peak <= 16e9),
        )
    except Exception as e:  # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-3000:])
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--cell", default="all",
                   choices=["paper", "ring", "ring_blocked", "grid_ring",
                            "slab", "all"])
    p.add_argument("--force", action="store_true")
    args = p.parse_args()
    cells = (["paper", "ring", "ring_blocked", "grid_ring", "slab"]
             if args.cell == "all" else [args.cell])
    for c in cells:
        rec = run_cell(c, force=args.force)
        r = rec.get("roofline", {})
        print(f"{rec['status']:8s} aidw_1b_{c:13s} "
              f"peak={rec.get('memory', {}).get('peak_bytes_per_device', 0) / 1e9:8.1f}GB "
              f"fits={rec.get('fits_hbm')} dom={r.get('dominant', '-')} "
              f"err={rec.get('error', '')[:60]}", flush=True)


if __name__ == "__main__":
    main()
