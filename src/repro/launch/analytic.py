"""Analytic per-cell FLOPs / HBM-traffic model for the roofline.

Also home of the AIDW ring Stage-1 census (:func:`aidw_ring_stage1_census`):
the candidate-distance accounting that quantifies what the grid-aware ring
layout buys over the brute-force ring — O(window) candidate evaluations per
query instead of O(m) — at fixed (m, P).  The session benchmark
(``benchmarks/session_bench.py`` ring rows) cross-checks the model against
the MEASURED per-query candidate counts the grid-ring executor reports.

Why analytic: XLA's HLO cost analysis (a) counts while-loop bodies once (the
layer scan under-reports ~L x), and (b) is unstable across SPMD partitioning
choices (measured: non-monotonic FLOPs vs depth on the 256-way mesh; see
EXPERIMENTS.md §Roofline-methodology).  We control every einsum in the model,
so exact executed-FLOP accounting is straightforward; it is validated against
single-device unrolled compiles (where cost analysis IS exact) in
tests/test_analytic_flops.py.

Conventions:
* 2 FLOPs per MAC (XLA's convention, verified).
* Counts what the implementation EXECUTES: full (not causal-halved) S^2
  attention scores (we mask, not skip), MoE capacity slots E*C (not just
  routed tokens), remat recompute under training.
* train multiplier: fwd + recompute + 2x bwd = 4x layer fwd (cfg.remat=True),
  3x for the unembed stem (outside the checkpoint); +~10 FLOPs/param AdamW.
* per-chip = global / n_chips, except attention when the head count does not
  divide the tensor axis (then those FLOPs replicate across it).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from repro.models import api
from repro.models.config import ModelConfig
from repro.nn.moe import moe_capacity


@dataclass(frozen=True)
class RingStage1Census:
    """Per-query Stage-1 candidate accounting for the two ring layouts."""

    m: int                       # data points
    p: int                       # ring width (devices / slabs)
    k: int
    brute_candidates: float      # brute ring: every point, every query
    grid_candidates: float       # grid ring: expected window gather
    grid_offset_gathers: float   # grid ring: CSR level-count int gathers
    reduction: float             # brute / grid candidate-distance ratio


def aidw_ring_stage1_census(m: int, p: int, k: int = 15, *,
                            window: int = 256, cell_factor: float = 1.0,
                            area: float = 1.0,
                            max_level: int | None = None) -> RingStage1Census:
    """Candidate-distance census: brute ring vs grid-aware ring at (m, P).

    Brute ring Stage 1 merges every rotating O(m/P) block into the running
    top-k — m candidate distances per query per full rotation, regardless
    of P.  The grid-aware ring searches the paper's even grid instead: with
    Eq. (2)'s cell width (x ``cell_factor``) the expected points-per-cell is
    ``ppc = m * cw^2 / area``; the count pass closes at the first level L
    with ``(2L+1)^2 * ppc >= k`` plus the safety ring, so the expected
    gather is ``min(window, (2(L+1)+1)^2 * ppc)`` candidates — from the
    OWNING slab only (the exactly-once contribution contract leaves
    non-owner slabs with ~empty masked windows on certified queries).  The
    level-count machinery costs ``P * 2 * (L_max+1) * (2*L_max+1)`` int32
    CSR-offset gathers per query per rotation — reported separately
    because offset gathers are not distance FLOPs.

    The reduction is what the paper's headline measures (grid vs brute kNN,
    Garcia et al. brute baseline), re-derived for the sharded layouts.
    """
    cw = cell_factor / (2.0 * math.sqrt(m / area))
    ppc = max(m * cw * cw / area, 1e-6)
    lvl = 0
    while (2 * lvl + 1) ** 2 * ppc < k:
        lvl += 1
    lvl += 1                     # the paper's safety ring
    grid = min(float(window), (2 * lvl + 1) ** 2 * ppc)
    if max_level is None:
        max_level = int(math.ceil(
            0.5 * (math.sqrt(4.0 * k / ppc) - 1.0))) + 3
    offset_gathers = float(p) * 2.0 * (max_level + 1) * (2 * max_level + 1)
    return RingStage1Census(
        m=m, p=p, k=k, brute_candidates=float(m), grid_candidates=grid,
        grid_offset_gathers=offset_gathers,
        reduction=float(m) / max(grid, 1.0))


@dataclass(frozen=True)
class CellCost:
    flops_global: float          # executed FLOPs, whole step, all chips
    flops_chip: float            # per chip (incl. replication penalties)
    hbm_bytes_chip: float        # HBM traffic per chip (model below)
    notes: str = ""


def _attn_flops_token(cfg: ModelConfig, s_ctx: int) -> float:
    """QK^T + PV per token (full, unmasked-skip) for one layer."""
    return 4.0 * cfg.n_heads * cfg.head_dim * s_ctx


def _dense_layer_matmul_params(cfg: ModelConfig) -> float:
    D, dh = cfg.d_model, cfg.head_dim
    return (D * (cfg.n_heads + 2 * cfg.n_kv_heads) * dh
            + cfg.n_heads * dh * D)


def _mlp_flops_token(cfg: ModelConfig, n_tokens: int) -> float:
    D = cfg.d_model
    if not cfg.is_moe:
        return 2.0 * 3 * D * cfg.d_ff
    C = moe_capacity(n_tokens, cfg.n_experts, cfg.top_k, cfg.capacity_factor)
    slots_per_token = cfg.n_experts * C / n_tokens
    f = 2.0 * 3 * D * cfg.moe_d_ff * slots_per_token + 2.0 * D * cfg.n_experts
    if cfg.n_shared_experts:
        f += 2.0 * 3 * D * cfg.d_ff * cfg.n_shared_experts
    return f


def _ssm_layer_flops_token(cfg: ModelConfig, *, decode: bool) -> float:
    from repro.models.lm import ssm_dims
    d = ssm_dims(cfg)
    D = cfg.d_model
    proj = 2.0 * D * d.d_in_proj + 2.0 * d.d_inner * D
    conv = 2.0 * d.d_conv * d.conv_ch
    H, N, P, Q = d.n_heads, d.d_state, d.head_dim, cfg.ssm_chunk
    if decode:
        ssd = H * (6.0 * N * P)
    else:
        ssd = H * (2.0 * Q * N + 2.0 * Q * P + 4.0 * N * P)
    return proj + conv + ssd


def _attn_block_fwd(cfg: ModelConfig, n_tokens: int, s_ctx: int) -> float:
    """One attention+MLP transformer block, fwd, global."""
    return n_tokens * (2.0 * _dense_layer_matmul_params(cfg)
                       + _attn_flops_token(cfg, s_ctx)
                       + _mlp_flops_token(cfg, n_tokens))


def _fwd_layers_global(cfg: ModelConfig, shape: api.ShapeSpec) -> tuple[float, float]:
    """(layer_flops, attn_only_flops) fwd, global, whole layer stack."""
    B, S = shape.global_batch, shape.seq_len
    decode = shape.kind == "decode"
    n_tokens = B * (1 if decode else S)
    s_ctx = S  # decode attends the full cache; train/prefill compute full S^2

    if cfg.enc_dec:
        enc_tokens = B * cfg.enc_len
        enc = cfg.n_enc_layers * _attn_block_fwd(cfg, enc_tokens, cfg.enc_len)
        if decode:
            enc = 0.0  # encoder ran at prefill
        dec_self = cfg.n_layers * n_tokens * (
            2.0 * _dense_layer_matmul_params(cfg) + _attn_flops_token(cfg, s_ctx))
        dec_cross = cfg.n_layers * n_tokens * (
            2.0 * _dense_layer_matmul_params(cfg) + _attn_flops_token(cfg, cfg.enc_len))
        dec_mlp = cfg.n_layers * n_tokens * _mlp_flops_token(cfg, n_tokens)
        attn = (0.0 if decode else cfg.n_enc_layers * enc_tokens *
                _attn_flops_token(cfg, cfg.enc_len)) + \
            cfg.n_layers * n_tokens * (_attn_flops_token(cfg, s_ctx)
                                       + _attn_flops_token(cfg, cfg.enc_len))
        return enc + dec_self + dec_cross + dec_mlp, attn

    if cfg.family == "ssm":
        per_tok = _ssm_layer_flops_token(cfg, decode=decode)
        return cfg.n_layers * n_tokens * per_tok, 0.0

    if cfg.family == "hybrid":
        mamba = cfg.n_layers * n_tokens * _ssm_layer_flops_token(cfg, decode=decode)
        n_shared = cfg.n_layers // cfg.attn_every
        shared = n_shared * _attn_block_fwd(cfg, n_tokens, s_ctx)
        attn = n_shared * n_tokens * _attn_flops_token(cfg, s_ctx)
        return mamba + shared, attn

    layer = cfg.n_layers * _attn_block_fwd(cfg, n_tokens, s_ctx)
    attn = cfg.n_layers * n_tokens * _attn_flops_token(cfg, s_ctx)
    return layer, attn


def _stem_fwd_global(cfg: ModelConfig, shape: api.ShapeSpec) -> float:
    B, S = shape.global_batch, shape.seq_len
    V, D = cfg.vocab, cfg.d_model
    if shape.kind == "train":
        return 2.0 * V * D * B * S
    if shape.kind == "prefill":
        return 2.0 * V * D * B       # last position only
    return 2.0 * V * D * B           # decode: one token


def cell_cost(cfg: ModelConfig, shape: api.ShapeSpec, n_chips: int,
              tensor_parallel: int = 16) -> CellCost:
    layers_fwd, attn_fwd = _fwd_layers_global(cfg, shape)
    stem_fwd = _stem_fwd_global(cfg, shape)

    if shape.kind == "train":
        layer_mult = 4.0 if cfg.remat else 3.0
        flops = layers_fwd * layer_mult + stem_fwd * 3.0 \
            + 10.0 * cfg.param_count()
        attn_total = attn_fwd * layer_mult
    else:
        flops = layers_fwd + stem_fwd
        attn_total = attn_fwd

    # replication penalty: attention einsums replicate across the tensor axis
    # when n_heads doesn't divide it (e.g. llama3.2's 24 heads on TP=16).
    repl = tensor_parallel if (cfg.uses_attention
                               and cfg.n_heads % tensor_parallel) else 1
    flops_chip = (flops - attn_total) / n_chips + attn_total * repl / n_chips
    notes = f"attn replicated x{repl} (heads % tp != 0)" if repl > 1 else ""

    return CellCost(flops_global=flops, flops_chip=flops_chip,
                    hbm_bytes_chip=_hbm_bytes_chip(cfg, shape, n_chips),
                    notes=notes)


def _hbm_bytes_chip(cfg: ModelConfig, shape: api.ShapeSpec, n_chips: int) -> float:
    """HBM traffic model per chip per step (documented in EXPERIMENTS.md):

    train : weights 3 reads bf16 + grad r/w f32 + AdamW state r/w f32
            (+ master r/w) + saved activations w+r + logits w+r
    prefill: weights 1 read + cache write + activations write once
    decode : weights 1 read + FULL cache read + 1 slot write
    """
    B, S = shape.global_batch, shape.seq_len
    P_local = cfg.param_count() / n_chips
    D = cfg.d_model
    batch_shards = max(n_chips // 16, 1)           # data(+pod) axes
    b_loc = max(B / batch_shards, 1)

    act_layer = b_loc * S * D * 2.0                 # bf16 saved input per layer
    n_layers_total = cfg.n_layers + (cfg.n_enc_layers if cfg.enc_dec else 0)

    # decode cache bytes (local): derived from cache defs
    cache_local = 0.0
    if shape.kind == "decode":
        for d in api.cache_defs(cfg, B, S).values():
            n = 1
            for s in d.shape:
                n *= s
            width = 2 if d.dtype != bool else 1
            cache_local += n * width / n_chips

    if shape.kind == "train":
        weights = P_local * (3 * 2.0)               # 3 bf16 passes
        grads = P_local * 8.0                       # f32 write + read
        opt = P_local * (16.0 + 8.0 + 2.0)          # mu/nu r+w, master r+w, param w
        acts = 2.0 * n_layers_total * act_layer     # write + read
        logits = 2.0 * b_loc * S * (cfg.vocab / 16) * 4.0
        return weights + grads + opt + acts + logits
    if shape.kind == "prefill":
        weights = P_local * 2.0
        acts = n_layers_total * act_layer           # cache/act write
        return weights + acts
    # decode
    weights = P_local * 2.0
    return weights + cache_local * 1.02             # full cache read + slot write
