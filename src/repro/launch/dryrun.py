import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This proves the distribution config is coherent on the production meshes
without hardware: parameters/optimizer state/caches/batches are
ShapeDtypeStructs (zero allocation), ``jit(...).lower(...).compile()`` runs
the full SPMD partitioner, and the compiled artifact yields

* ``memory_analysis()``  — per-device bytes (proves it fits),
* ``cost_analysis()``    — per-device HLO FLOPs/bytes for the roofline,
* the optimized HLO text — parsed for collective wire bytes (§Roofline).

Artifacts land in ``artifacts/dryrun/<arch>__<shape>__<mesh>.json`` and are
resumable (existing cells are skipped unless --force).

NOTE: the XLA_FLAGS line above MUST precede any jax import — device count is
locked at first backend init.  Tests and benchmarks do NOT import this
module's environment (they see 1 device).
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

if os.environ.get("REPRO_SHARDY", "0") == "1":
    # newer XLA partitioner: avoids GSPMD's involuntary full-rematerialization
    # path on FSDP x TP transitions (§Perf iteration 5)
    jax.config.update("jax_use_shardy_partitioner", True)

from repro.configs import ARCH_IDS, get_config
from repro.core.jax_compat import set_mesh as compat_set_mesh
from repro.launch.mesh import make_production_mesh, make_ring_mesh
from repro.models import api, sharding
from repro.models.config import ModelConfig
from repro.nn.param import abstract_params, make_shardings, count_params
from repro.optim import adamw
from repro.training import trainer

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

# TPU v5e hardware constants (see DESIGN.md §5)
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# ring-algorithm wire-cost factors (x result bytes, per chip)
_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|c\d+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Sum bytes of every tensor literal in an HLO type string (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-op-type {count, result_bytes, wire_bytes} from optimized HLO."""
    stats = {c: {"count": 0, "result_bytes": 0, "wire_bytes": 0.0}
             for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+ = (.+?) (all-reduce|all-gather|reduce-scatter|"
                     r"all-to-all|collective-permute)(-start|-done)?\(", line)
        if not m:
            continue
        if m.group(3) == "-done":
            continue  # counted at -start
        op = m.group(2)
        b = _shape_bytes(m.group(1))
        stats[op]["count"] += 1
        stats[op]["result_bytes"] += b
        stats[op]["wire_bytes"] += b * _WIRE_FACTOR[op]
    return stats


def roofline_terms(flops: float, hbm_bytes: float, wire_bytes: float) -> dict:
    """All quantities are PER-CHIP (post-SPMD local module)."""
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm_bytes / HBM_BW
    coll_s = wire_bytes / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom.replace("_s", "")
    terms["step_time_lower_bound_s"] = max(compute_s, memory_s, coll_s)
    return terms


def _mesh_for(name: str):
    if name == "pod":
        return make_production_mesh(multi_pod=False)
    if name == "multipod":
        return make_production_mesh(multi_pod=True)
    if name == "ring512":
        return make_ring_mesh(512)
    raise ValueError(name)


# --- §Perf hillclimb variants: cfg overrides + trainer knobs ------------
# baseline  : naive pjit sharding (paper of record for the iteration log)
# rs        : gradients constrained to param shardings -> reduce-scatter
# rs_sp     : + Megatron-style sequence-sharded residual stream
# rs_sp_lc  : + chunked CE loss (logits one chunk at a time)
# ep        : + expert-parallel dispatch-buffer constraint (MoE archs)


def variant_overrides(name: str, mesh) -> tuple[dict, dict]:
    """-> (cfg overrides, trainer kwargs)"""
    b_axes = tuple(a for a in mesh.axis_names if a != "model")
    bs = {"act_spec": (b_axes, None, None)}    # batch-shard residual stream
    seq = {"act_spec": (b_axes, "model", None)}  # + sequence sharding (SP)
    lc = {"loss_chunk": 512}
    ep = {"moe_spec": ("model", None, None)}
    epsm = {"moe_impl": "ep"}
    rs = {"constrain_grads": True}
    g16 = {"constrain_grads": True, "grad_dtype": "bf16"}
    nm = {"constrain_grads": True, "grad_dtype": "bf16", "master_weights": False}
    table = {
        "baseline": ({}, {}),
        "rs": ({}, rs),
        "bs": ({**bs}, rs),
        "bs_lc": ({**bs, **lc}, rs),
        "sp": ({**seq}, rs),
        "sp_lc": ({**seq, **lc}, rs),
        "sp_lc_g16": ({**seq, **lc}, g16),
        "sp_lc_nm": ({**seq, **lc}, nm),
        "bs_lc_epsm": ({**bs, **lc, **epsm}, g16),
        "sp_lc_epsm": ({**seq, **lc, **epsm}, g16),
        "sp_lc_ep": ({**seq, **lc, **ep}, rs),
        "sp_lc_g16_ep": ({**seq, **lc, **ep}, g16),
        "bs_lc_ep": ({**bs, **lc, **ep}, rs),
        "ep": ({**ep}, rs),
    }
    return table[name]


def lower_cell(cfg: ModelConfig, shape: api.ShapeSpec, mesh, *,
               constrain_grads: bool = False, grad_dtype=None,
               master_weights: bool = True):
    """Build (jitted_fn, arg_structs, in_shardings) for one cell."""
    defs = api.param_defs(cfg)
    params_abs = abstract_params(defs)
    param_sh = make_shardings(defs, mesh, sharding.param_rules(mesh))

    batch_abs = api.input_specs(cfg, shape)
    batch_sh = sharding.shard_batch(
        mesh, sharding.data_specs(mesh, cfg, batch_abs))

    if shape.kind == "train":
        opt_cfg = adamw.AdamWConfig(master_weights=master_weights)
        opt_abs = jax.eval_shape(lambda p: trainer.init_opt_state(opt_cfg, p),
                                 params_abs)
        opt_sh = trainer.opt_state_specs(opt_cfg, param_sh)
        from jax.sharding import NamedSharding, PartitionSpec as P
        opt_sh["step"] = NamedSharding(mesh, P())
        step = trainer.make_train_step(
            cfg, opt_cfg, grad_shardings=param_sh if constrain_grads else None,
            grad_dtype=jnp.bfloat16 if grad_dtype == "bf16" else None)
        jitted = jax.jit(step, in_shardings=(param_sh, opt_sh, batch_sh),
                         donate_argnums=(0, 1))
        return jitted, (params_abs, opt_abs, batch_abs)

    if shape.kind == "prefill":
        fn = api.prefill_fn(cfg)
        jitted = jax.jit(fn, in_shardings=(param_sh, batch_sh))
        return jitted, (params_abs, batch_abs)

    # decode
    cache_defs_ = api.cache_defs(cfg, shape.global_batch, shape.seq_len)
    cache_abs = abstract_params(cache_defs_)
    cache_sh = make_shardings(
        cache_defs_, mesh, sharding.cache_rules(mesh, cfg, shape.global_batch))
    fn = api.decode_fn(cfg)
    jitted = jax.jit(fn, in_shardings=(param_sh, cache_sh, batch_sh),
                     donate_argnums=(1,))
    return jitted, (params_abs, cache_abs, batch_abs)


def run_cell(arch: str, shape_name: str, mesh_name: str, *,
             force: bool = False, variant: str = "baseline") -> dict:
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    suffix = "" if variant == "baseline" else f"__{variant}"
    if os.environ.get("REPRO_SHARDY", "0") == "1":
        suffix += "__shardy"
    out_path = ARTIFACTS / f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = get_config(arch)
    shape = api.SHAPES[shape_name]
    ok, reason = api.applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "params": cfg.param_count(), "active_params": cfg.active_param_count()}
    if not ok:
        rec.update(status="skipped", reason=reason)
        out_path.write_text(json.dumps(rec, indent=1))
        return rec

    mesh = _mesh_for(mesh_name)
    overrides, tkw = variant_overrides(variant, mesh)
    cfg = cfg.with_(**overrides)
    rec["variant"] = variant
    n_chips = mesh.devices.size
    t0 = time.time()
    try:
        jitted, args = lower_cell(cfg, shape, mesh, **tkw)
        with compat_set_mesh(mesh):
            lowered = jitted.lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        colls = collective_stats(hlo)
        flops = float(cost.get("flops", 0.0))
        hbm_bytes = float(cost.get("bytes accessed", 0.0))
        wire = sum(c["wire_bytes"] for c in colls.values())
        rec.update(
            status="ok",
            n_chips=n_chips,
            lower_s=round(t1 - t0, 2), compile_s=round(t2 - t1, 2),
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
                "peak_bytes_per_device": (
                    (getattr(mem, "argument_size_in_bytes", 0) or 0)
                    + (getattr(mem, "temp_size_in_bytes", 0) or 0)
                    + (getattr(mem, "output_size_in_bytes", 0) or 0)
                    - (getattr(mem, "alias_size_in_bytes", 0) or 0)),
            },
            per_chip={"flops": flops, "hbm_bytes": hbm_bytes,
                      "collective_wire_bytes": wire},
            collectives=colls,
            roofline=roofline_terms(flops, hbm_bytes, wire),
        )
        # useful-compute ratio: MODEL_FLOPS / (HLO flops * chips)
        mf = model_flops(cfg, shape)
        rec["model_flops"] = mf
        hlo_total = flops * n_chips
        rec["useful_compute_ratio"] = (mf / hlo_total) if hlo_total else None
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def model_flops(cfg: ModelConfig, shape: api.ShapeSpec) -> float:
    """MODEL_FLOPS: 6*N*D for train, 2*N*D forward-only (N = active params)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


# ---------------------------------------------------------------------------
# probe mode: corrected per-layer metrics via 1-vs-2-layer UNROLLED compiles
# ---------------------------------------------------------------------------
#
# XLA's HLO cost analysis counts a while-loop body ONCE, so the scanned-layer
# production lowering under-reports FLOPs/bytes/collectives by ~the trip
# count.  The probe compiles the same cell at depth-1 and depth-2 with the
# layer scan fully unrolled and attention query-chunking disabled (both
# while-free), takes the exact marginal per-depth-unit cost under the real
# SPMD partitioning, and extrapolates:  total = f(1) + (units-1) * (f(2)-f(1)).
# Validated against analytic 6*N*D in tests/test_dryrun_probe.py.

PROBE_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "probe"


def _depth_units(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every
    return cfg.n_layers


def _probe_cfg(cfg: ModelConfig, shape: api.ShapeSpec, units: int) -> ModelConfig:
    kw: dict = {"unroll_layers": True}
    if shape.kind != "decode":
        kw["q_chunk"] = shape.seq_len  # no q-chunk while loop
    if cfg.family == "hybrid":
        kw["n_layers"] = units * cfg.attn_every
    elif cfg.enc_dec:
        kw.update(n_layers=units, n_enc_layers=units)
    else:
        kw["n_layers"] = units
    return cfg.with_(**kw)


def _probe_metrics(cfg: ModelConfig, shape, mesh, **tkw) -> dict:
    jitted, args = lower_cell(cfg, shape, mesh, **tkw)
    with compat_set_mesh(mesh):
        compiled = jitted.lower(*args).compile()
    cost = compiled.cost_analysis() or {}
    colls = collective_stats(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "hbm_bytes": float(cost.get("bytes accessed", 0.0)),
        "wire_bytes": sum(c["wire_bytes"] for c in colls.values()),
        "collectives": colls,
    }


def probe_cell(arch: str, shape_name: str, mesh_name: str = "pod", *,
               force: bool = False, variant: str = "baseline") -> dict:
    PROBE_DIR.mkdir(parents=True, exist_ok=True)
    suffix = "" if variant == "baseline" else f"__{variant}"
    if os.environ.get("REPRO_SHARDY", "0") == "1":
        suffix += "__shardy"
    out_path = PROBE_DIR / f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = get_config(arch)
    shape = api.SHAPES[shape_name]
    ok, reason = api.applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not ok:
        rec.update(status="skipped", reason=reason)
        out_path.write_text(json.dumps(rec, indent=1))
        return rec

    mesh = _mesh_for(mesh_name)
    overrides, tkw = variant_overrides(variant, mesh)
    cfg = cfg.with_(**overrides)
    rec["variant"] = variant
    rec["shardy"] = os.environ.get("REPRO_SHARDY", "0") == "1"
    t0 = time.time()
    try:
        m1 = _probe_metrics(_probe_cfg(cfg, shape, 1), shape, mesh, **tkw)
        m2 = _probe_metrics(_probe_cfg(cfg, shape, 2), shape, mesh, **tkw)
        units = _depth_units(cfg)
        corr = {}
        for key in ("flops", "hbm_bytes", "wire_bytes"):
            delta = max(m2[key] - m1[key], 0.0)
            corr[key] = m1[key] + (units - 1) * delta
        colls = {}
        for op in _COLLECTIVES:
            c1, c2 = m1["collectives"][op], m2["collectives"][op]
            colls[op] = {
                k: c1[k] + (units - 1) * max(c2[k] - c1[k], 0)
                for k in ("count", "result_bytes", "wire_bytes")
            }
        mf = model_flops(cfg, shape)
        n_chips = mesh.devices.size
        rec.update(
            status="ok", units=units, probe_s=round(time.time() - t0, 1),
            probe_1=m1, probe_2=m2,
            per_chip=corr, collectives=colls,
            roofline=roofline_terms(corr["flops"], corr["hbm_bytes"],
                                    corr["wire_bytes"]),
            model_flops=mf,
            useful_compute_ratio=(mf / (corr["flops"] * n_chips)
                                  if corr["flops"] else None),
        )
    except Exception as e:  # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="all", help="arch id or 'all'")
    p.add_argument("--shape", default="all", help="shape name or 'all'")
    p.add_argument("--mesh", default="all",
                   choices=["pod", "multipod", "ring512", "all"])
    p.add_argument("--probe", action="store_true",
                   help="corrected per-layer metrics (single-pod, see above)")
    p.add_argument("--variant", default="baseline")
    p.add_argument("--force", action="store_true")
    args = p.parse_args()

    if args.probe:
        archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
        shapes = list(api.SHAPES) if args.shape == "all" else [args.shape]
        for arch in archs:
            for shape_name in shapes:
                rec = probe_cell(arch, shape_name, force=args.force,
                                 variant=args.variant)
                r = rec.get("roofline", {})
                print(f"{rec['status']:8s} {arch:24s} {shape_name:12s} "
                      f"dom={r.get('dominant','-'):10s} "
                      f"useful={rec.get('useful_compute_ratio') or 0:.3f} "
                      f"err={rec.get('error','')[:80]}", flush=True)
        return

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(api.SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["pod", "multipod"] if args.mesh == "all" else [args.mesh]

    results = []
    for mesh_name in meshes:
        for arch in archs:
            for shape_name in shapes:
                rec = run_cell(arch, shape_name, mesh_name, force=args.force,
                               variant=args.variant)
                r = rec.get("roofline", {})
                print(f"{rec['status']:8s} {arch:24s} {shape_name:12s} "
                      f"{mesh_name:9s} dom={r.get('dominant','-'):10s} "
                      f"compile={rec.get('compile_s','-')}s "
                      f"err={rec.get('error','')[:80]}", flush=True)
                results.append(rec)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_err} errors")


if __name__ == "__main__":
    main()
