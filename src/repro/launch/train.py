"""Training driver: ``python -m repro.launch.train --arch granite-3-2b --reduced``.

Demonstrates the full substrate end to end on whatever devices exist (CPU
container: 1 device; forced host devices for multi-device runs): deterministic
sharded data pipeline, pjit'd train step, async atomic checkpointing with
resume, straggler telemetry, and spike-guard rollback.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config, reduced
from repro.data.pipeline import LMStreamConfig, Prefetcher, lm_batch
from repro.launch.mesh import make_host_mesh
from repro.models import api, sharding
from repro.models.config import ModelConfig
from repro.nn.param import init_params, make_shardings
from repro.optim import adamw
from repro.runtime.fault_tolerance import SpikeGuard, StragglerDetector
from repro.training import trainer


def build(cfg: ModelConfig, opt_cfg, mesh, *, grad_accum=1, compress=False):
    defs = api.param_defs(cfg)
    param_sh = make_shardings(defs, mesh, sharding.param_rules(mesh))
    step_fn = trainer.make_train_step(cfg, opt_cfg, grad_accum=grad_accum,
                                      compress=compress)
    jitted = jax.jit(step_fn, donate_argnums=(0, 1))
    return defs, param_sh, jitted


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="granite-3-2b")
    p.add_argument("--reduced", action="store_true",
                   help="reduced same-family config (CPU-scale)")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--grad-accum", type=int, default=1)
    p.add_argument("--compress-grads", action="store_true")
    p.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    p.add_argument("--ckpt-every", type=int, default=20)
    p.add_argument("--resume", action="store_true")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--log-every", type=int, default=5)
    args = p.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    mesh = make_host_mesh()
    print(f"arch={cfg.name} params={cfg.param_count():,} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=10,
                                total_steps=args.steps)
    defs, param_sh, jitted = build(cfg, opt_cfg, mesh,
                                   grad_accum=args.grad_accum,
                                   compress=args.compress_grads)

    mgr = CheckpointManager(args.ckpt_dir, keep=3)
    start_step = 0
    with mesh:
        params = init_params(defs, jax.random.PRNGKey(args.seed))
        params = jax.device_put(params, param_sh)
        opt_state = trainer.init_opt_state(opt_cfg, params,
                                           compress=args.compress_grads)
        if args.resume and mgr.latest_step() is not None:
            (params, opt_state), start_step = mgr.restore(
                (params, opt_state))
            print(f"resumed from step {start_step}")

        stream = LMStreamConfig(vocab=cfg.vocab, seq_len=args.seq,
                                global_batch=args.batch, seed=args.seed)
        fetch = Prefetcher(lambda s: lm_batch(stream, s), start_step=start_step)
        guard = SpikeGuard()
        timer = StragglerDetector(["host0"])
        pending = None

        step = start_step
        try:
            while step < args.steps:
                batch_np = fetch.next()
                if cfg.family == "vlm":
                    batch_np = dict(batch_np)
                    batch_np["vis_embeds"] = np.zeros(
                        (args.batch, cfg.n_vis_tokens, cfg.d_model), np.float32)
                if cfg.enc_dec:
                    batch_np = dict(batch_np)
                    batch_np["enc_embeds"] = np.zeros(
                        (args.batch, cfg.enc_len, cfg.d_model), np.float32)
                batch = jax.device_put({k: jnp.asarray(v) for k, v in batch_np.items()})
                t0 = time.perf_counter()
                params, opt_state, metrics = jitted(params, opt_state, batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                timer.observe("host0", dt)
                timer.end_step()
                step += 1

                if guard.observe(loss):
                    latest = mgr.latest_step()
                    if latest is not None:
                        print(f"step {step}: loss spike ({loss:.3f}) -> rollback to {latest}")
                        (params, opt_state), step = mgr.restore((params, opt_state))
                        continue

                if step % args.log_every == 0:
                    print(f"step {step:5d} loss {loss:.4f} "
                          f"gnorm {float(metrics['grad_norm']):.3f} "
                          f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f} ms")
                if step % args.ckpt_every == 0:
                    if pending is not None:
                        pending.result()
                    pending = mgr.save_async(step, (params, opt_state))
        finally:
            if pending is not None:
                pending.result()
            fetch.close()
            mgr.close()
    print("final save:", mgr.save(step, (params, opt_state)))


if __name__ == "__main__":
    main()
