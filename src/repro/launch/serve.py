"""Serving driver: ``python -m repro.launch.serve --arch llama3.2-3b --reduced``.

Runs the slot-based continuous-batching engine over synthetic requests and
reports prefill/decode throughput.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import api
from repro.nn.param import init_params
from repro.serving.engine import Request, ServingEngine


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="llama3.2-3b")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--requests", type=int, default=12)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if cfg.enc_dec:
        raise SystemExit("serve demo targets decoder-only archs")

    params = init_params(api.param_defs(cfg), jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]

    max_len = args.prompt_len + args.max_new + 8
    engine = ServingEngine(cfg, params, batch_size=args.batch, max_len=max_len)
    stats = engine.run(reqs)
    done = sum(r.done for r in reqs)
    print(f"arch={cfg.name} served={done}/{len(reqs)} "
          f"prefills={stats['prefills']} decode_steps={stats['decode_steps']} "
          f"tokens={stats['tokens']} ({stats['tokens_per_s']:.1f} tok/s)")
    for r in reqs[:3]:
        print(f"  req {r.uid}: {len(r.out_tokens)} tokens -> {r.out_tokens[:8]}...")


if __name__ == "__main__":
    main()
