"""Serving driver.

LM mode (default): ``python -m repro.launch.serve --arch llama3.2-3b
--reduced`` runs the slot-based continuous-batching engine over synthetic
requests and reports prefill/decode throughput.

AIDW mode: ``python -m repro.launch.serve --aidw [--mesh] [--async]
[--cluster N]`` runs the session-backed interpolation engine over synthetic
spatial request traffic; ``--mesh`` shards the session's query path across
every visible device (simulate a pod slice on CPU with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``), an incremental
``update_dataset(inserts=..., deletes=...)`` between waves exercises the
delta-rebinning path, and ``--async`` drives the same traffic through
:class:`repro.serving.AsyncAidwServer` (admission queue + worker thread +
deadline-aware coalescing) and prints the latency telemetry report.
``--cluster N`` serves the traffic from an N-host
:class:`repro.serving.cluster.AidwCluster` fleet instead (epoch-ordered
updates, query routing, merged fleet telemetry).
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import api
from repro.nn.param import init_params
from repro.serving.engine import Request, ServingEngine


def run_aidw(args) -> None:
    from repro.core.jax_compat import make_auto_mesh
    from repro.data.pipeline import spatial_points, spatial_queries
    from repro.serving.engine import AidwEngine, InterpolationRequest

    n_dev = len(jax.devices())
    mesh = make_auto_mesh((n_dev,), ("q",)) if args.mesh or \
        args.layout != "replicated" else None
    pts = spatial_points(args.points, seed=args.seed)
    if args.cluster:
        run_aidw_cluster(args, pts, mesh)
        return
    if args.async_:
        run_aidw_async(args, pts, mesh)
        return
    engine = AidwEngine(pts, max_batch=args.max_batch, mesh=mesh,
                        layout=args.layout,
                        query_domain=spatial_queries(1024, seed=1))

    def wave(wave_id: int) -> None:
        reqs = [InterpolationRequest(
            uid=wave_id * args.requests + i,
            queries_xy=spatial_queries(max(args.req_queries - 7 * i, 1),
                                       seed=wave_id * 100 + i))
            for i in range(args.requests)]
        report = engine.run(reqs)            # per-call report for THIS wave
        assert all(r.done for r in reqs)
        print(f"wave {wave_id}: {report['queries']} queries in "
              f"{report['batches']} coalesced batches "
              f"({report['queries_per_s']:.0f} q/s)")

    wave(0)
    # incremental churn: replace 1% of the dataset, Stage-1 stays resident
    rng = np.random.default_rng(args.seed + 1)
    n_delta = max(args.points // 100, 1)
    engine.update_dataset(
        inserts=spatial_points(n_delta, seed=args.seed + 2),
        deletes=rng.choice(args.points, n_delta, replace=False))
    wave(1)
    s = engine.session.stats
    print(f"aidw serve: devices={s['devices']} stage1_builds={s['stage1_builds']} "
          f"delta_updates={s['delta_updates']} buckets={s['bucket_misses']} "
          f"queries={s['queries']} (cumulative: {engine.stats})")


def run_aidw_async(args, pts, mesh) -> None:
    """The same two-wave traffic through the ASYNC server: admission queue,
    worker thread, deadline mix, delta update serialized mid-stream."""
    from repro.data.pipeline import spatial_points, spatial_queries
    from repro.serving import AsyncAidwServer

    with AsyncAidwServer(pts, max_batch=args.max_batch, mesh=mesh,
                         layout=args.layout, prewarm=args.prewarm,
                         query_domain=spatial_queries(1024, seed=1)) as srv:
        def wave(wave_id: int, deadline_s):
            return [srv.submit(
                spatial_queries(max(args.req_queries - 7 * i, 1),
                                seed=wave_id * 100 + i),
                deadline_s=deadline_s if i % 3 == 0 else None)
                for i in range(args.requests)]

        w0 = wave(0, deadline_s=30.0)
        rng = np.random.default_rng(args.seed + 1)
        n_delta = max(args.points // 100, 1)
        srv.update_dataset(                   # FIFO barrier inside the stream
            inserts=spatial_points(n_delta, seed=args.seed + 2),
            deletes=rng.choice(args.points, n_delta, replace=False))
        w1 = wave(1, deadline_s=30.0)
        srv.flush(timeout=600)
        rep = srv.report()
        done = sum(r.status == "done" for r in w0 + w1)
        print(f"async waves: {done}/{len(w0) + len(w1)} served, "
              f"{rep['shed']} shed, {rep['batches']} batches, "
              f"{rep['queries_per_s']:.0f} q/s")
        lat = rep["latency"]["total"]
        print(f"async latency: p50 {lat['p50_s'] * 1e3:.1f}ms "
              f"p95 {lat['p95_s'] * 1e3:.1f}ms p99 {lat['p99_s'] * 1e3:.1f}ms")
        s = srv.session.stats
        print(f"aidw serve: devices={s['devices']} "
              f"stage1_builds={s['stage1_builds']} "
              f"delta_updates={s['delta_updates']} queries={s['queries']}")
        _dump_debugz(args, srv.debugz())


def run_aidw_cluster(args, pts, mesh=None) -> None:
    """Two waves + a fleet-wide epoch-ordered update through an N-host
    in-process cluster; prints the MERGED fleet telemetry.  With ``mesh``
    every host serves its batches across the whole visible-device mesh
    (in-process hosts share the devices)."""
    import numpy as np

    from repro.data.pipeline import spatial_points, spatial_queries
    from repro.serving.cluster import AidwCluster

    with AidwCluster(pts, n_hosts=args.cluster, max_batch=args.max_batch,
                     query_domain=spatial_queries(1024, seed=1),
                     policy=args.policy, mesh=mesh,
                     layout=args.layout) as cl:
        def wave(wave_id: int):
            return [cl.submit(
                spatial_queries(max(args.req_queries - 7 * i, 1),
                                seed=wave_id * 100 + i),
                deadline_s=30.0 if i % 3 == 0 else None)
                for i in range(args.requests)]

        w0 = wave(0)
        rng = np.random.default_rng(args.seed + 1)
        n_delta = max(args.points // 100, 1)
        epoch = cl.update_dataset(       # epoch-ordered fleet-wide barrier
            inserts=spatial_points(n_delta, seed=args.seed + 2),
            deletes=rng.choice(args.points, n_delta, replace=False),
            timeout=600)
        w1 = wave(1)
        cl.flush(timeout=600)
        rep = cl.report()
        fleet = rep["fleet"]
        done = sum(r.status == "done" for r in w0 + w1)
        print(f"cluster[{args.cluster} hosts, {rep['routing']['policy']}]: "
              f"{done}/{len(w0) + len(w1)} served, epoch {epoch}, "
              f"{fleet['shed']} shed, {fleet['queries_per_s']:.0f} q/s fleet")
        lat = fleet["latency"]["total"]
        print(f"fleet latency: p50 {lat['p50_s'] * 1e3:.1f}ms "
              f"p95 {lat['p95_s'] * 1e3:.1f}ms p99 {lat['p99_s'] * 1e3:.1f}ms")
        for h in rep["hosts"]:
            print(f"  host {h['host_id']}: epoch {h['epoch']} "
                  f"completed {h['completed']} "
                  f"queries {h['queries']} (n_points "
                  f"{h['session']['n_points']})")
        _dump_debugz(args, cl.debugz())


def _dump_debugz(args, bundle: dict) -> None:
    """Write the diagnostics bundle for ``--debug-dump PATH`` and print
    the tail-latency attribution it carries (single-server bundles have
    per-host shape; fleet bundles are pre-merged)."""
    if not getattr(args, "debug_dump", None):
        return
    import json

    from repro.obs import render_attribution, tail_attribution

    attr = bundle.get("attribution")
    if attr is None and bundle.get("recorder"):
        attr = tail_attribution([bundle["recorder"]],
                                registry_state=bundle.get("registry"))
        bundle = {**bundle, "attribution": attr}
    with open(args.debug_dump, "w") as f:
        json.dump(bundle, f, indent=1)
    print(f"debugz bundle -> {args.debug_dump}")
    if attr is not None:
        print(render_attribution(attr))


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--aidw", action="store_true",
                   help="serve AIDW interpolation instead of the LM engine")
    p.add_argument("--mesh", action="store_true",
                   help="AIDW: shard the session across all visible devices")
    p.add_argument("--layout", default="replicated",
                   choices=("replicated", "ring", "grid_ring"),
                   help="AIDW mesh layout: replicate the plan, brute-force "
                        "ring-shard the points, or grid-aware ring-shard "
                        "them (slab CSR + halo; implies --mesh)")
    p.add_argument("--async", dest="async_", action="store_true",
                   help="AIDW: drive traffic through the AsyncAidwServer "
                        "(admission queue + worker thread + deadlines)")
    p.add_argument("--cluster", type=int, default=0, metavar="N",
                   help="AIDW: serve from an N-host in-process fleet "
                        "(epoch-ordered updates + routing + fleet report)")
    p.add_argument("--policy", default="round_robin",
                   choices=("round_robin", "least_loaded"),
                   help="cluster routing policy")
    p.add_argument("--prewarm", choices=("background", "sync"), default=None,
                   help="AIDW --async: AOT-compile + warm the whole bucket "
                        "ladder at server construction ('sync' blocks, "
                        "'background' compiles off the worker thread while "
                        "serving lazily)")
    p.add_argument("--compilation-cache-dir", metavar="DIR", default=None,
                   help="persistent XLA compilation cache directory "
                        "(default: AIDW_CACHE_DIR env; a restart with the "
                        "same directory deserializes instead of recompiling)")
    p.add_argument("--debug-dump", metavar="PATH",
                   help="AIDW --async/--cluster: write the debugz "
                        "diagnostics bundle (queue/epoch state, SLO "
                        "events, flight-recorder traces, tail-latency "
                        "attribution) to PATH as JSON after the waves")
    p.add_argument("--points", type=int, default=16384)
    p.add_argument("--req-queries", type=int, default=384)
    p.add_argument("--max-batch", type=int, default=4096)
    p.add_argument("--arch", default="llama3.2-3b")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--requests", type=int, default=12)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    # before any compile: flag > AIDW_CACHE_DIR env > disabled
    from repro.runtime import compile_cache
    compile_cache.enable(args.compilation_cache_dir)

    if args.aidw:
        run_aidw(args)
        return

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if cfg.enc_dec:
        raise SystemExit("serve demo targets decoder-only archs")

    params = init_params(api.param_defs(cfg), jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]

    max_len = args.prompt_len + args.max_new + 8
    engine = ServingEngine(cfg, params, batch_size=args.batch, max_len=max_len)
    stats = engine.run(reqs)
    done = sum(r.done for r in reqs)
    print(f"arch={cfg.name} served={done}/{len(reqs)} "
          f"prefills={stats['prefills']} decode_steps={stats['decode_steps']} "
          f"tokens={stats['tokens']} ({stats['tokens_per_s']:.1f} tok/s)")
    for r in reqs[:3]:
        print(f"  req {r.uid}: {len(r.out_tokens)} tokens -> {r.out_tokens[:8]}...")


if __name__ == "__main__":
    main()
