"""Production mesh construction (pure functions — importing this module never
touches jax device state)."""

from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.core.jax_compat import make_auto_mesh


def _make(shape, axes) -> Mesh:
    return make_auto_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips/pod; multi-pod adds a leading 2-pod axis (512 chips).

    Axes: ``data`` (batch + FSDP), ``model`` (tensor/expert parallel),
    ``pod`` (pure DP across pods; only gradient all-reduce crosses it).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_ring_mesh(n_devices: int | None = None, name: str = "ring") -> Mesh:
    """1-D mesh over all devices — used by the domain-decomposed ring AIDW."""
    n = n_devices or len(jax.devices())
    return _make((n,), (name,))


def make_host_mesh(shape=None, axes=("data", "model")) -> Mesh:
    """Small mesh over whatever devices exist (tests on forced host devices)."""
    n = len(jax.devices())
    if shape is None:
        m = 1
        while m * 2 <= n // (m * 2) and n % (m * 2) == 0:
            m *= 2
        m = m if n % m == 0 else 1
        shape = (n // m, m)
    return _make(shape, axes)
