"""repro: TPU-native AIDW/kNN interpolation framework + LM-scale distributed substrate."""

__version__ = "1.0.0"
