"""Primitive layers: norms, projections, embeddings, RoPE — pure functions.

Convention: parameters are dict leaves produced from ``ParamDef`` trees; all
apply functions take arrays and return arrays, compute dtype follows the
activation dtype, reductions accumulate in float32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array | None = None,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mean = xf.mean(axis=-1, keepdims=True)
    var = jnp.mean((xf - mean) ** 2, axis=-1, keepdims=True)
    out = (xf - mean) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def dense(x: jax.Array, w: jax.Array) -> jax.Array:
    """x (..., d_in) @ w (d_in, *out_dims) with f32 accumulation on the MXU."""
    return jax.lax.dot_general(
        x, w.astype(x.dtype),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)


def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed(x: jax.Array, table: jax.Array) -> jax.Array:
    """Logits against the (possibly tied) embedding table (V, D)."""
    return jax.lax.dot_general(
        x, table.astype(x.dtype),
        (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    g = dense(x, w_gate)
    u = dense(x, w_up)
    return dense(jax.nn.silu(g) * u, w_down)


def rope_freqs(d_head: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x (B, S, H, d_head); positions (B, S) int32."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)                      # (d_head/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, d/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: jax.Array | None = None) -> jax.Array:
    """Mean next-token loss; logits (B, S, V) f32, labels (B, S) int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
