"""Pure-JAX neural-network substrate (no external framework)."""
