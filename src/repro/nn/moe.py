"""Mixture-of-Experts layer: top-k routing with sort-based capacity dispatch.

TPU-friendly formulation (no per-token control flow, no ragged GEMMs):

1. router top-k -> (token, expert, weight) assignments,
2. stable argsort of assignments by expert id groups each expert's tokens,
3. position-within-group (rank - group start) + static capacity C gives every
   assignment a slot in an (E, C, D) buffer; overflow assignments are dropped
   (classic capacity-factor dropping — the dispatch one-hot einsum used by
   small-E models would be O(T*E*C) memory and is hopeless at E=128),
4. batched expert SwiGLU via (E, ...) einsums on the stacked expert weights,
5. combine: gather each assignment's output slot, scale by router weight,
   segment-sum back over tokens.

Expert weights are sharded expert-major ("expert" -> model axis) so step 4 is
expert-parallel; the scatter/gather in 3/5 lowers to collective dispatch under
pjit (measured in the roofline; a shard_map all-to-all variant is a §Perf
iteration).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.jax_compat import pvary, shard_map

from .layers import dense


def moe_capacity(n_tokens: int, n_experts: int, top_k: int,
                 capacity_factor: float = 1.25, multiple: int = 8) -> int:
    c = int(n_tokens * top_k * capacity_factor / n_experts) + 1
    return max(multiple, -(-c // multiple) * multiple)


def _constrain(t, spec):
    if spec is None:
        return t
    from jax.sharding import PartitionSpec as P
    try:
        return jax.lax.with_sharding_constraint(t, P(*spec))
    except (ValueError, RuntimeError):
        return t


def moe_apply(x: jax.Array, w_router: jax.Array, w_gate: jax.Array,
              w_up: jax.Array, w_down: jax.Array, *, top_k: int,
              capacity_factor: float = 1.25, buf_spec=None) -> jax.Array:
    """x (B, S, D); router (D, E); experts (E, D, F)/(E, F, D).  Returns (B, S, D)."""
    import jax as _jax  # noqa: F811
    B, S, D = x.shape
    E = w_router.shape[1]
    T = B * S
    xt = x.reshape(T, D)

    # 1. routing (f32 for numerics)
    logits = dense(xt, w_router).astype(jnp.float32)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    rw, eidx = jax.lax.top_k(probs, top_k)                    # (T, k)
    rw = rw / jnp.maximum(rw.sum(-1, keepdims=True), 1e-9)

    # 2. sort assignments by expert id (stable: ties keep token order)
    flat_e = eidx.reshape(-1)                                 # (T*k,)
    order = jnp.argsort(flat_e, stable=True).astype(jnp.int32)
    sorted_e = flat_e[order]
    tok = (order // top_k).astype(jnp.int32)                  # token per assignment

    # 3. slot assignment with static capacity
    C = moe_capacity(T, E, top_k, capacity_factor)
    group_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos = jnp.arange(T * top_k, dtype=jnp.int32) - group_start.astype(jnp.int32)
    keep = pos < C
    slot = jnp.where(keep, sorted_e * C + pos, E * C)         # E*C = drop slot

    buf = jnp.zeros((E * C, D), x.dtype)
    buf = buf.at[slot].set(xt[tok], mode="drop")              # (E*C, D)
    buf = _constrain(buf.reshape(E, C, D), buf_spec)          # EP placement

    # 4. batched expert SwiGLU
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    u = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, w_down.astype(x.dtype),
                         preferred_element_type=jnp.float32).astype(x.dtype)
    out_buf = _constrain(out_buf, buf_spec).reshape(E * C, D)

    # 5. combine: gather slots, weight, segment-sum over tokens
    w_sorted = rw.reshape(-1)[order].astype(x.dtype)          # (T*k,)
    contrib = out_buf[jnp.minimum(slot, E * C - 1)] * (w_sorted * keep)[:, None]
    out = jnp.zeros((T, D), x.dtype).at[tok].add(contrib)
    return out.reshape(B, S, D)


# ---------------------------------------------------------------------------
# expert-parallel dispatch (shard_map over the tensor axis)
# ---------------------------------------------------------------------------
#
# §Perf iteration (qwen3 cell): under plain pjit the capacity scatter
# materializes the FULL (E*C, D) buffer per chip and all-reduces it
# (~2 x 43 GB/layer on qwen3 train_4k).  Here each model-rank owns E/tp
# experts and dispatches ONLY the assignments routed to its local experts —
# tokens are replicated across the tensor axis (they are sharded over
# data/pod), so no all-to-all is needed; partial outputs are combined with
# one (T_local, D) psum.  Wire: ~2 x 0.27 GB/layer — a ~160x reduction.


from functools import partial as _partial


@_partial(jax.custom_vjp, nondiff_argnums=(1,))
def _psum_identity_grad(x, axis):
    return jax.lax.psum(x, axis)


def _psum_ig_fwd(x, axis):
    return jax.lax.psum(x, axis), None


def _psum_ig_bwd(axis, _, g):
    # cotangent is replicated across ``axis``; mark it varying to match the
    # primal input's manual-axes type (identity is the true psum backward).
    return (pvary(g, axis),)


_psum_identity_grad.defvjp(_psum_ig_fwd, _psum_ig_bwd)


def moe_apply_ep(x, w_router, w_gate, w_up, w_down, *, top_k: int,
                 capacity_factor: float = 1.25, axis: str = "model"):
    """Expert-parallel MoE via FULLY-manual shard_map (all mesh axes).

    Tokens stay sharded over the batch axes (local sort/scatter — no
    distributed sort, which GSPMD lowers via copy-reduction all-reduces that
    crash XLA-CPU); experts are sharded over ``axis``; each rank dispatches
    only assignments routed to its local experts and partial outputs combine
    with ONE f32 psum over ``axis``.  Routing runs on every ``axis`` rank
    redundantly (router is tiny).  Call inside a mesh context.
    """
    from jax.sharding import PartitionSpec as P

    from repro.core.jax_compat import get_ambient_mesh

    mesh = get_ambient_mesh()
    if mesh is None or mesh.empty or axis not in mesh.axis_names:
        # no mesh context (single-device unit tests): plain dispatch
        return moe_apply(x, w_router, w_gate, w_up, w_down, top_k=top_k,
                         capacity_factor=capacity_factor)
    E = w_router.shape[1]
    b_axes = tuple(a for a in mesh.axis_names if a != axis)
    # expert-parallel degree from the EXPLICIT mesh: jax.lax.axis_size is
    # newer than 0.4.37, and e_loc must be static anyway (it shapes the
    # local dispatch buffer)
    tp = int(mesh.shape[axis])
    e_loc = E // tp

    def local_fn(x, w_router, w_gate, w_up, w_down):
        rank = jax.lax.axis_index(axis)
        lo = rank * e_loc

        B, S, D = x.shape                                     # local shard
        T = B * S
        xt = x.reshape(T, D)
        logits = dense(xt, w_router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        rw, eidx = jax.lax.top_k(probs, top_k)
        rw = rw / jnp.maximum(rw.sum(-1, keepdims=True), 1e-9)

        # keep only assignments routed to OUR experts; foreign -> drop bucket
        flat_e = eidx.reshape(-1) - lo                        # local ids
        mine = (flat_e >= 0) & (flat_e < e_loc)
        flat_e = jnp.where(mine, flat_e, e_loc)
        order = jnp.argsort(flat_e, stable=True).astype(jnp.int32)
        sorted_e = flat_e[order]
        tok = (order // top_k).astype(jnp.int32)

        C = moe_capacity(T, E, top_k, capacity_factor)
        group_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
        pos = jnp.arange(T * top_k, dtype=jnp.int32) - group_start.astype(jnp.int32)
        keep = (pos < C) & (sorted_e < e_loc)
        slot = jnp.where(keep, sorted_e * C + pos, e_loc * C)

        buf = jnp.zeros((e_loc * C, D), x.dtype)
        buf = buf.at[slot].set(xt[tok], mode="drop").reshape(e_loc, C, D)
        g = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(x.dtype),
                       preferred_element_type=jnp.float32).astype(x.dtype)
        u = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(x.dtype),
                       preferred_element_type=jnp.float32).astype(x.dtype)
        h = jax.nn.silu(g) * u
        out_buf = jnp.einsum("ecf,efd->ecd", h, w_down.astype(x.dtype),
                             preferred_element_type=jnp.float32
                             ).astype(x.dtype).reshape(e_loc * C, D)

        w_sorted = rw.reshape(-1)[order].astype(x.dtype)
        contrib = out_buf[jnp.minimum(slot, e_loc * C - 1)] \
            * (w_sorted * keep)[:, None]
        partial = jnp.zeros((T, D), x.dtype).at[tok].add(contrib)
        out = _psum_identity_grad(partial.astype(jnp.float32), axis)
        return out.astype(x.dtype).reshape(B, S, D)

    return shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(b_axes, None, None), P(None, None),
                  P(axis, None, None), P(axis, None, None),
                  P(axis, None, None)),
        out_specs=P(b_axes, None, None),
    )(x, w_router, w_gate, w_up, w_down)
