"""Parameter definition system: shapes + logical sharding axes, no framework.

Models declare their parameters as a pytree of :class:`ParamDef` (shape +
logical axis names + initializer).  From that single declaration we derive:

* materialized parameters (:func:`init_params`) — for real training,
* ``jax.ShapeDtypeStruct`` stand-ins (:func:`abstract_params`) — for the
  multi-pod dry-run, which must never allocate,
* ``NamedSharding`` pytrees (:func:`make_shardings`) — by mapping logical
  axes ("embed", "heads", "ffn", "vocab", "expert", ...) onto mesh axes
  through a rules table, skipping any mapping that does not divide evenly
  (GSPMD would pad; we prefer explicit replication).
"""

from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class ParamDef(NamedTuple):
    shape: tuple
    logical: tuple          # logical axis name (or None) per dim
    init: str = "normal"    # normal | zeros | ones | scaled(fan_in)
    dtype: Any = jnp.bfloat16

    def struct(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _tree_map_defs(fn: Callable[[ParamDef], Any], defs):
    return jax.tree.map(fn, defs, is_leaf=is_def)


def abstract_params(defs):
    """ShapeDtypeStruct pytree — dry-run params, zero allocation."""
    return _tree_map_defs(lambda d: d.struct(), defs)


def init_params(defs, rng: jax.Array):
    """Materialize parameters.  Deterministic: one fold per leaf path."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(rng, max(len(leaves), 1))

    def one(d: ParamDef, key):
        if d.init == "zeros":
            return jnp.zeros(d.shape, d.dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, d.dtype)
        if d.init == "scaled":
            fan_in = d.shape[0] if len(d.shape) == 1 else math.prod(d.shape[:-1])
            std = 1.0 / math.sqrt(max(fan_in, 1))
            return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(d.dtype)
        return (jax.random.normal(key, d.shape, jnp.float32) * 0.02).astype(d.dtype)

    return jax.tree.unflatten(treedef, [one(d, k) for d, k in zip(leaves, keys)])


def logical_to_spec(defs, rules: dict[str, Any]) -> Any:
    """PartitionSpec pytree from logical axes via ``rules``.

    ``rules[name]`` is a mesh axis (str), tuple of mesh axes, or None.
    A mapping is applied only if the dim size divides evenly over the mesh
    axes product (checked by the caller via :func:`make_shardings`, which
    knows the mesh; here we emit the raw spec).
    """
    def one(d: ParamDef):
        return P(*[rules.get(ax) if ax is not None else None for ax in d.logical])
    return _tree_map_defs(one, defs)


def make_shardings(defs, mesh: Mesh, rules: dict[str, Any]):
    """NamedSharding pytree; drops any axis mapping that does not divide."""
    axis_size = {name: int(s) for name, s in zip(mesh.axis_names, mesh.devices.shape)}

    def mesh_factor(assignment) -> int:
        if assignment is None:
            return 1
        if isinstance(assignment, (tuple, list)):
            return math.prod(axis_size[a] for a in assignment)
        return axis_size[assignment]

    def one(d: ParamDef):
        entries = []
        for dim, ax in zip(d.shape, d.logical):
            assignment = rules.get(ax) if ax is not None else None
            if assignment is not None and dim % mesh_factor(assignment) != 0:
                assignment = None  # would need padding: replicate instead
            entries.append(tuple(assignment) if isinstance(assignment, list) else assignment)
        return NamedSharding(mesh, P(*entries))

    return _tree_map_defs(one, defs)


def spec_shardings(tree_of_specs, mesh: Mesh):
    """PartitionSpec pytree -> NamedSharding pytree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_of_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def count_params(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    return sum(math.prod(d.shape) for d in leaves)
