"""Grouped-query attention with query-chunked softmax and KV caching.

The (Sq, Sk) score matrix is never materialized for the full query axis:
queries are processed in chunks of ``q_chunk`` rows (softmax still sees the
full key axis per row, so the result is exact — this is memory chunking, not
an approximation).  At 32k prefill this bounds the per-layer transient to
``(B, Hkv, G, q_chunk, Sk)`` instead of quadratic-in-S.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _attend_chunk(q, k, v, q_pos, k_pos, k_valid, *, causal: bool, scale: float):
    """q (B, Cq, Hkv, G, dh); k/v (B, Sk, Hkv, dh); returns (B, Cq, Hkv, G, dh)."""
    scores = jnp.einsum(
        "bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32
    ) * scale                                                 # (B,Hkv,G,Cq,Sk)
    mask = k_valid[:, None, None, None, :]
    if causal:
        mask = mask & (q_pos[:, None, None, :, None] >= k_pos[:, None, None, None, :])
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", probs, v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


def gqa_attention(
    q: jax.Array,        # (B, Sq, H, dh)
    k: jax.Array,        # (B, Sk, Hkv, dh)
    v: jax.Array,        # (B, Sk, Hkv, dh)
    *,
    q_pos: jax.Array,            # (B, Sq) absolute positions
    k_pos: jax.Array,            # (B, Sk)
    k_valid: jax.Array | None = None,   # (B, Sk) bool
    causal: bool = True,
    q_chunk: int = 512,
) -> jax.Array:
    B, Sq, H, dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    scale = dh ** -0.5
    qg = q.reshape(B, Sq, Hkv, G, dh)
    if k_valid is None:
        k_valid = jnp.ones(k.shape[:2], dtype=bool)

    if Sq <= q_chunk:
        out = _attend_chunk(qg, k, v, q_pos, k_pos, k_valid,
                            causal=causal, scale=scale)
        return out.reshape(B, Sq, H, dh)

    pad = (-Sq) % q_chunk
    if pad:  # query padding is output-only: padded rows are sliced off
        qg = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)))
    Sp = Sq + pad
    nc = Sp // q_chunk
    qs = jnp.moveaxis(qg.reshape(B, nc, q_chunk, Hkv, G, dh), 1, 0)
    ps = jnp.moveaxis(q_pos.reshape(B, nc, q_chunk), 1, 0)

    def body(_, qc):
        qi, pi = qc
        return None, _attend_chunk(qi, k, v, pi, k_pos, k_valid,
                                   causal=causal, scale=scale)

    _, outs = jax.lax.scan(body, None, (qs, ps))              # (nc,B,Cq,Hkv,G,dh)
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sp, H, dh)[:, :Sq]


def update_cache(cache_k, cache_v, k_new, v_new, pos):
    """Insert (B, Sn, Hkv, dh) at ``pos`` along the S axis of (B, Smax, Hkv, dh)."""
    cache_k = jax.lax.dynamic_update_slice(cache_k, k_new.astype(cache_k.dtype),
                                           (0, pos, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v_new.astype(cache_v.dtype),
                                           (0, pos, 0, 0))
    return cache_k, cache_v
