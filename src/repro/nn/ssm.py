"""Mamba2 / SSD (state-space duality) layer — chunked scan + single-step decode.

Follows the SSD formulation (Dao & Gu 2024, arXiv:2405.21060): the selective
SSM  h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t,  y_t = C_t h_t + D x_t  is
evaluated chunk-wise — a quadratic attention-like intra-chunk term plus an
inter-chunk state scan — which maps onto MXU einsums instead of a length-S
sequential scan.  All decay arithmetic in f32 via in-chunk cumulative
log-decays.  Decode is the exact single-step recurrence over the carried
(H, N, P) state plus a rolling depthwise-conv window.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import rms_norm


class SsmDims(NamedTuple):
    d_model: int
    d_inner: int      # expand * d_model
    n_heads: int      # d_inner // head_dim
    head_dim: int     # P
    d_state: int      # N
    n_groups: int     # G (B/C groups; 1 for mamba2 defaults)
    d_conv: int       # depthwise conv width

    @property
    def conv_ch(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state

    @property
    def d_in_proj(self) -> int:
        return 2 * self.d_inner + 2 * self.n_groups * self.d_state + self.n_heads


def causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                state: jax.Array | None = None):
    """Depthwise causal conv. x (B, S, C); w (K, C); returns (y, new_state).

    ``state`` is the trailing (K-1) inputs from the previous segment (decode).
    """
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    y = sum(xp[:, i:i + S, :] * w[i] for i in range(K)) + b
    new_state = xp[:, -(K - 1):, :] if K > 1 else xp[:, :0, :]
    return jax.nn.silu(y), new_state


def _split_proj(zxbcdt: jax.Array, dims: SsmDims):
    GN = dims.n_groups * dims.d_state
    z, xc, Bc, Cc, dt = jnp.split(
        zxbcdt,
        [dims.d_inner, 2 * dims.d_inner, 2 * dims.d_inner + GN,
         2 * dims.d_inner + 2 * GN],
        axis=-1,
    )
    return z, jnp.concatenate([xc, Bc, Cc], axis=-1), dt


def _split_conv(xbc: jax.Array, dims: SsmDims):
    GN = dims.n_groups * dims.d_state
    xc, Bc, Cc = jnp.split(xbc, [dims.d_inner, dims.d_inner + GN], axis=-1)
    B, S = xc.shape[:2]
    xh = xc.reshape(B, S, dims.n_heads, dims.head_dim)
    Bg = Bc.reshape(B, S, dims.n_groups, dims.d_state)
    Cg = Cc.reshape(B, S, dims.n_groups, dims.d_state)
    return xh, Bg, Cg


def _expand_groups(a: jax.Array, dims: SsmDims) -> jax.Array:
    """(B, S, G, N) -> (B, S, H, N) by repeating each group over its heads."""
    reps = dims.n_heads // dims.n_groups
    return jnp.repeat(a, reps, axis=2) if reps > 1 else a


def ssd_chunked(xh, Bg, Cg, dt, A, D_skip, dims: SsmDims, *, chunk: int = 256,
                h0: jax.Array | None = None):
    """Chunked SSD scan.  xh (B,S,H,P); Bg/Cg (B,S,G,N); dt (B,S,H) f32.

    Returns (y (B,S,H,P), h_final (B,H,N,P)).
    """
    B, S, H, P = xh.shape
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        # dt = 0 on padding -> exp(0)=1 decay, zero state injection: exact.
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bg = jnp.pad(Bg, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cg = jnp.pad(Cg, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    S_pad = S + pad
    nc = S_pad // Q
    N = dims.d_state

    Bh = _expand_groups(Bg, dims).astype(jnp.float32).reshape(B, nc, Q, H, N)
    Ch = _expand_groups(Cg, dims).astype(jnp.float32).reshape(B, nc, Q, H, N)
    Xf = xh.astype(jnp.float32).reshape(B, nc, Q, H, P)
    dtc = dt.astype(jnp.float32).reshape(B, nc, Q, H)

    l = dtc * A                                                # (B,c,Q,H) < 0
    cum = jnp.cumsum(l, axis=2)                                # inclusive
    cum_last = cum[:, :, -1:, :]                               # (B,c,1,H)

    # ---- intra-chunk (the "attention-like" quadratic term) ----
    # decay(q,k) = exp(cum_q - cum_k), valid k <= q
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Ch, Bh)
    dq = cum.transpose(0, 1, 3, 2)                             # (B,c,H,Q)
    ddiff = dq[..., :, None] - dq[..., None, :]                # (B,c,H,Q,Q)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(causal, jnp.exp(ddiff), 0.0)
    dtk = dtc.transpose(0, 1, 3, 2)                            # (B,c,H,Q)
    y_intra = jnp.einsum("bchqk,bchk,bckhp->bcqhp",
                         scores * L, dtk, Xf)

    # ---- per-chunk states ----
    decay_end = jnp.exp(cum_last - cum)                        # (B,c,Q,H)
    S_c = jnp.einsum("bckh,bckhn,bckhp->bchnp", dtc * decay_end, Bh, Xf)

    # ---- inter-chunk scan over nc chunks ----
    chunk_decay = jnp.exp(cum_last[:, :, 0, :])                # (B,c,H)
    h_init = (jnp.zeros((B, H, N, P), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))

    def step(h, inputs):
        cd, sc = inputs                                        # (B,H), (B,H,N,P)
        h_out = h
        h = cd[:, :, None, None] * h + sc
        return h, h_out

    h_final, h_prevs = jax.lax.scan(
        step, h_init,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(S_c, 1, 0)),
    )
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                      # (B,c,H,N,P)

    y_inter = jnp.einsum("bcqhn,bchnp,bcqh->bcqhp", Ch, h_prevs, jnp.exp(cum))
    y = y_intra + y_inter + D_skip[None, None, :, None] * Xf
    y = y.reshape(B, S_pad, H, P)[:, :S]
    return y.astype(xh.dtype), h_final


def ssd_decode_step(xh, Bg, Cg, dt, A, D_skip, h, dims: SsmDims):
    """Exact single-token recurrence.  xh (B,1,H,P); h (B,H,N,P) f32."""
    Bh = _expand_groups(Bg, dims).astype(jnp.float32)[:, 0]    # (B,H,N)
    Ch = _expand_groups(Cg, dims).astype(jnp.float32)[:, 0]
    Xf = xh.astype(jnp.float32)[:, 0]                          # (B,H,P)
    dt0 = dt[:, 0]                                             # (B,H)
    a = jnp.exp(dt0 * A)                                       # (B,H)
    h = a[:, :, None, None] * h + jnp.einsum(
        "bh,bhn,bhp->bhnp", dt0, Bh, Xf)
    y = jnp.einsum("bhn,bhnp->bhp", Ch, h) + D_skip[None, :, None] * Xf
    return y[:, None].astype(xh.dtype), h


def mamba_block(params, x: jax.Array, dims: SsmDims, *, chunk: int = 256,
                conv_state=None, ssm_state=None, decode: bool = False):
    """Full Mamba2 block: in_proj -> conv -> SSD -> gated norm -> out_proj.

    Returns (out, (new_conv_state, new_ssm_state)).
    """
    from .layers import dense  # local import to avoid cycle

    zxbcdt = dense(x, params["w_in"])
    z, xbc, dt = _split_proj(zxbcdt, dims)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))          # (H,)
    D_skip = params["D"].astype(jnp.float32)

    xbc, new_conv = causal_conv(xbc, params["conv_w"], params["conv_b"], conv_state)
    xh, Bg, Cg = _split_conv(xbc, dims)

    if decode:
        y, new_h = ssd_decode_step(xh, Bg, Cg, dt, A, D_skip, ssm_state, dims)
    else:
        y, new_h = ssd_chunked(xh, Bg, Cg, dt, A, D_skip, dims, chunk=chunk,
                               h0=ssm_state)

    B, S = x.shape[:2]
    y = y.reshape(B, S, dims.d_inner)
    y = rms_norm(y, params["norm"]) * jax.nn.silu(z)
    return dense(y, params["w_out"]), (new_conv, new_h)
