"""Checkpointing: sharded save, async atomic commit, cross-mesh restore."""
from .manager import CheckpointManager
