"""Checkpointing: sharded save, async commit, cross-mesh (elastic) restore.

Layout (one directory per step):

    <root>/step_000123.tmp/...   (written)
    <root>/step_000123/          (atomic rename = commit marker)
        MANIFEST.json            tree structure + dtypes + shapes
        <leaf-path>.npy          one file per pytree leaf

Properties the runtime relies on:

* **Atomicity** — a checkpoint directory either has its final name (complete)
  or a ``.tmp`` suffix (ignored at restore, reaped at cleanup).  A crash
  mid-write can never yield a half-readable checkpoint.
* **Async** — ``save_async`` snapshots to host RAM (device_get) on the caller
  thread, then writes on a background thread; training resumes immediately.
* **Cross-mesh restore** — leaves are stored UNSHARDED (gathered); restore
  takes a pytree of NamedShardings for the NEW mesh and device_puts each leaf
  accordingly, so a job restarted on a different surviving topology (elastic
  rescale after node failure) resharding-restores transparently.
* **Retention** — keep the newest ``keep`` complete checkpoints.

On a real multi-host fleet each host would write only its addressable shards
(same layout, per-host subdirectories); the single-process container writes
full arrays — the commit/restore protocol is identical.
"""

from __future__ import annotations

import json
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from pathlib import Path

import jax
import ml_dtypes  # numpy extension dtypes (bfloat16, ...)
import numpy as np

# dtypes numpy can't round-trip through .npy: store as a same-width view
_VIEW_AS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _leaf_name(path) -> str:
    try:
        return jax.tree_util.keystr(path, simple=True, separator="__")
    except TypeError:  # jax 0.4.x keystr has no simple/separator kwargs;
        # reproduce simple=True output so checkpoints stay cross-version
        return "__".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path)


class CheckpointManager:
    def __init__(self, root: str | Path, *, keep: int = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pool = ThreadPoolExecutor(max_workers=1,
                                        thread_name_prefix="ckpt")
        self._lock = threading.Lock()

    # -- write ---------------------------------------------------------------

    def save(self, step: int, tree) -> Path:
        return self._write(step, self._snapshot(tree))

    def save_async(self, step: int, tree) -> Future:
        host_tree = self._snapshot(tree)              # sync device->host copy
        return self._pool.submit(self._write, step, host_tree)

    def _snapshot(self, tree):
        return jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)

    def _write(self, step: int, host_tree) -> Path:
        final = self.root / f"step_{step:09d}"
        tmp = final.with_suffix(".tmp")
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)

        leaves, treedef = jax.tree_util.tree_flatten_with_path(host_tree)
        manifest = {"step": step, "leaves": []}
        for path, arr in leaves:
            name = _leaf_name(path)
            arr = np.asarray(arr)
            stored = arr.view(_VIEW_AS[str(arr.dtype)]) \
                if str(arr.dtype) in _VIEW_AS else arr
            np.save(tmp / f"{name}.npy", stored)
            manifest["leaves"].append(
                {"name": name, "shape": list(arr.shape), "dtype": str(arr.dtype)})
        manifest["treedef"] = str(treedef)
        (tmp / "MANIFEST.json").write_text(json.dumps(manifest, indent=1))

        with self._lock:
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)                          # atomic commit
            self._retain()
        return final

    def _retain(self):
        done = self.complete_steps()
        for s in done[: max(len(done) - self.keep, 0)]:
            shutil.rmtree(self.root / f"step_{s:09d}", ignore_errors=True)

    # -- read ----------------------------------------------------------------

    def complete_steps(self) -> list[int]:
        steps = []
        for d in self.root.iterdir():
            if d.is_dir() and d.name.startswith("step_") \
                    and not d.name.endswith(".tmp") \
                    and (d / "MANIFEST.json").exists():
                steps.append(int(d.name.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.complete_steps()
        return steps[-1] if steps else None

    def restore(self, target_tree, *, step: int | None = None,
                shardings=None):
        """Restore into the structure of ``target_tree``.

        ``shardings``: optional matching pytree of NamedSharding for the
        CURRENT mesh (possibly different from the save-time mesh) — each leaf
        is device_put with its new sharding (elastic restore).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint under {self.root}")
        d = self.root / f"step_{step:09d}"

        leaves, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
        shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                        else [None] * len(leaves))
        manifest = json.loads((d / "MANIFEST.json").read_text())
        saved_dtype = {l["name"]: l["dtype"] for l in manifest["leaves"]}
        out = []
        for (path, ref), sh in zip(leaves, shard_leaves):
            name = _leaf_name(path)
            arr = np.load(d / f"{name}.npy")
            src_dt = saved_dtype.get(name, str(arr.dtype))
            if src_dt in _VIEW_AS:
                arr = arr.view(getattr(ml_dtypes, src_dt))
            if list(arr.shape) != list(ref.shape):
                raise ValueError(
                    f"shape mismatch for {name}: "
                    f"ckpt {arr.shape} vs target {ref.shape}")
            a = arr.astype(ref.dtype)
            out.append(jax.device_put(a, sh) if sh is not None
                       else jax.device_put(a))
        return jax.tree_util.tree_unflatten(treedef, out), step

    def cleanup_tmp(self):
        for d in self.root.glob("*.tmp"):
            shutil.rmtree(d, ignore_errors=True)

    def close(self):
        self._pool.shutdown(wait=True)
