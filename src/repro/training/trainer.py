"""Training step construction: loss -> grads -> AdamW update, jit/pjit-ready.

``make_train_step`` returns the donated-argument step the launcher jits; it
optionally folds in gradient-accumulation microbatching (the accumulation
scan also gives XLA the window to overlap per-bucket gradient reduction with
the next microbatch's backprop) and error-feedback gradient compression.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import api
from repro.models.config import ModelConfig
from repro.optim import adamw, compression


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: adamw.AdamWConfig,
    *,
    grad_accum: int = 1,
    compress: bool = False,
    grad_shardings=None,
    grad_dtype=None,
) -> Callable:
    """``grad_shardings``: optional NamedSharding pytree matching params —
    constrains gradients to the parameter layout so XLA reduce-scatters into
    FSDP shards instead of all-reducing full tensors (§Perf knob).
    ``grad_dtype``: reduce gradients in this dtype (bf16 halves the wire
    bytes of the data-axis gradient reduction; §Perf knob)."""
    loss_fn = api.loss_fn(cfg)

    def compute_grads(params, batch):
        if grad_accum == 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        # split the batch leading dim into microbatches and scan: grads for
        # microbatch i reduce while microbatch i+1 computes (XLA overlap).
        def micro(carry, mb):
            loss, grads = jax.value_and_grad(loss_fn)(params, mb)
            acc_loss, acc_grads = carry
            return (acc_loss + loss,
                    jax.tree.map(jnp.add, acc_grads, grads)), None

        mbs = jax.tree.map(
            lambda a: a.reshape((grad_accum, a.shape[0] // grad_accum) + a.shape[1:]),
            batch)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grads_sum), _ = jax.lax.scan(micro, (jnp.float32(0), zeros), mbs)
        scale = 1.0 / grad_accum
        return loss_sum * scale, jax.tree.map(lambda g: g * scale, grads_sum)

    def train_step(params, opt_state, batch):
        loss, grads = compute_grads(params, batch)
        if grad_dtype is not None:
            grads = jax.tree.map(lambda g: g.astype(grad_dtype), grads)
        if grad_shardings is not None:
            grads = jax.lax.with_sharding_constraint(grads, grad_shardings)
        metrics = {"loss": loss.astype(jnp.float32)}
        if compress:
            grads, new_err = compression.compress_with_feedback(
                grads, opt_state["error"])
            inner = {k: v for k, v in opt_state.items() if k != "error"}
            params, inner, m = adamw.apply_updates(opt_cfg, params, inner, grads)
            inner["error"] = new_err
            return params, inner, {**metrics, **m}
        params, opt_state, m = adamw.apply_updates(opt_cfg, params, opt_state, grads)
        return params, opt_state, {**metrics, **m}

    return train_step


def init_opt_state(cfg: adamw.AdamWConfig, params, *, compress: bool = False):
    state = adamw.init_state(cfg, params)
    if compress:
        state["error"] = compression.init_error(params)
    return state


def opt_state_specs(cfg: adamw.AdamWConfig, param_specs, *, compress: bool = False):
    specs = adamw.state_specs(cfg, param_specs)
    if compress:
        specs["error"] = param_specs
    return specs
