"""Training loop substrate: step construction, data, fault tolerance glue."""
