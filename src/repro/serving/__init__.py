"""Batched serving: LM continuous batching + session-backed AIDW serving.

AIDW serving has two drive modes over ONE deadline-aware coalescer
(``scheduler``): the synchronous :class:`AidwEngine` facade (caller hands it
request lists) and the online :class:`AsyncAidwServer` (admission-queue
worker thread with backpressure, deadline shedding, serialized dataset
updates, and telemetry).

Scale-out lives in the ``repro.serving.cluster`` subpackage: a fleet of
host processes, each one ``AsyncAidwServer`` over a full dataset replica,
kept consistent by an **epoch-numbered update protocol** — every
``update_dataset`` gets a monotonically increasing epoch from one
coordinator and is broadcast into every host's FIFO admission stream, so
all hosts apply the same deltas in the same order between the same batches
(the same barrier the single-process worker provides, reconstructed fleet-
wide).  The contract: a query served by ANY host sees the dataset state a
single server would reach after applying epochs ``1..k`` in order, for the
``k`` stamped on the request — so cluster results are bit-identical to a
single server replaying the same epoch log.  Queries are spread by a
routing layer (round-robin / queue-depth-aware, heartbeat-drained via
``repro.runtime.fault_tolerance``), and per-host latency histograms merge
bin-exactly into fleet p50/p95/p99 (``cluster.telemetry``).  Import from
``repro.serving.cluster`` (kept out of this namespace: the subpackage
imports this one).

Observability (PR 8) rides on :mod:`repro.obs` end to end.  Every layer
reports into ONE :class:`repro.obs.Registry` per engine — the session's
stage walls (``session/plan_s`` .. ``session/stage2_s``), the serving
histograms ``Telemetry`` registers (``serving/queue_wait_s`` /
``execute_s`` / ``total_s`` / ``shed_s``), and the coalescer's
``serving/coalesce_s`` / ``serving/scatter_s`` — so
``AsyncAidwServer.report()`` (the ``stages`` block),
``metrics_snapshot()``, and the Prometheus text exposition
(``metrics_text()``, names like ``aidw_serving_queue_wait_s``) are views
of the same bins, and the fleet rollup merges them bin-exactly.  Tracing
is opt-in per server (``trace_sample_rate=``; sampling decided once at
the root): a sampled request carries ``trace_id``/``parent_span`` on
:class:`InterpolationRequest` through admission, coalescing, and the rpc
control plane, yielding ``queue_wait``/``coalesce``/``execute``/
``scatter`` spans per request and ``apply_epoch`` spans per update
barrier — one connected cross-host trace per fleet query, exported as
Chrome ``trace_event`` JSON via ``spans()`` +
:func:`repro.obs.chrome_trace`.  Fleet QPS is anchored on the UNION of
per-host wall-clock windows (``Telemetry.state()['window']``), never on
summed per-host rates.
"""

from .engine import AidwEngine, InterpolationRequest, Request, ServingEngine
from .queue import AdmissionQueue, AdmissionQueueClosed, AdmissionQueueFull
from .scheduler import DeadlineCoalescer, ExecuteTimeModel
from .server import AsyncAidwServer
from .telemetry import LatencyHistogram, Telemetry

__all__ = [
    "AidwEngine", "InterpolationRequest", "Request", "ServingEngine",
    "AdmissionQueue", "AdmissionQueueClosed", "AdmissionQueueFull",
    "DeadlineCoalescer", "ExecuteTimeModel",
    "AsyncAidwServer", "LatencyHistogram", "Telemetry",
]
