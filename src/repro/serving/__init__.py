"""Batched serving: slot-based continuous batching engine."""
