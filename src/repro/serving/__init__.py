"""Batched serving: LM continuous batching + session-backed AIDW serving.

AIDW serving has two drive modes over ONE deadline-aware coalescer
(``scheduler``): the synchronous :class:`AidwEngine` facade (caller hands it
request lists) and the online :class:`AsyncAidwServer` (admission-queue
worker thread with backpressure, deadline shedding, serialized dataset
updates, and telemetry).
"""

from .engine import AidwEngine, InterpolationRequest, Request, ServingEngine
from .queue import AdmissionQueue, AdmissionQueueClosed, AdmissionQueueFull
from .scheduler import DeadlineCoalescer, ExecuteTimeModel
from .server import AsyncAidwServer
from .telemetry import LatencyHistogram, Telemetry

__all__ = [
    "AidwEngine", "InterpolationRequest", "Request", "ServingEngine",
    "AdmissionQueue", "AdmissionQueueClosed", "AdmissionQueueFull",
    "DeadlineCoalescer", "ExecuteTimeModel",
    "AsyncAidwServer", "LatencyHistogram", "Telemetry",
]
