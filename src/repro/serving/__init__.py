"""Batched serving: LM continuous batching + session-backed AIDW serving."""

from .engine import AidwEngine, InterpolationRequest, Request, ServingEngine

__all__ = ["AidwEngine", "InterpolationRequest", "Request", "ServingEngine"]
