"""Batched serving: LM continuous batching + session-backed AIDW serving.

AIDW serving has two drive modes over ONE deadline-aware coalescer
(``scheduler``): the synchronous :class:`AidwEngine` facade (caller hands it
request lists) and the online :class:`AsyncAidwServer` (admission-queue
worker thread with backpressure, deadline shedding, serialized dataset
updates, and telemetry).

Scale-out lives in the ``repro.serving.cluster`` subpackage: a fleet of
host processes, each one ``AsyncAidwServer`` over a full dataset replica,
kept consistent by an **epoch-numbered update protocol** — every
``update_dataset`` gets a monotonically increasing epoch from one
coordinator and is broadcast into every host's FIFO admission stream, so
all hosts apply the same deltas in the same order between the same batches
(the same barrier the single-process worker provides, reconstructed fleet-
wide).  The contract: a query served by ANY host sees the dataset state a
single server would reach after applying epochs ``1..k`` in order, for the
``k`` stamped on the request — so cluster results are bit-identical to a
single server replaying the same epoch log.  Queries are spread by a
routing layer (round-robin / queue-depth-aware, heartbeat-drained via
``repro.runtime.fault_tolerance``), and per-host latency histograms merge
bin-exactly into fleet p50/p95/p99 (``cluster.telemetry``).  Import from
``repro.serving.cluster`` (kept out of this namespace: the subpackage
imports this one).
"""

from .engine import AidwEngine, InterpolationRequest, Request, ServingEngine
from .queue import AdmissionQueue, AdmissionQueueClosed, AdmissionQueueFull
from .scheduler import DeadlineCoalescer, ExecuteTimeModel
from .server import AsyncAidwServer
from .telemetry import LatencyHistogram, Telemetry

__all__ = [
    "AidwEngine", "InterpolationRequest", "Request", "ServingEngine",
    "AdmissionQueue", "AdmissionQueueClosed", "AdmissionQueueFull",
    "DeadlineCoalescer", "ExecuteTimeModel",
    "AsyncAidwServer", "LatencyHistogram", "Telemetry",
]
