"""Serving telemetry: latency histograms, throughput, and shed counters.

The async serving subsystem (``serving/server.py``) and the synchronous
:class:`repro.serving.engine.AidwEngine` facade both report through one
:class:`Telemetry` object so a load test reads the same metrics regardless of
the drive mode:

* per-request **queue** latency (submit -> dispatch), **execute** latency
  (dispatch -> results on host), and **total** latency (submit -> done), each
  recorded into a log-spaced :class:`LatencyHistogram` with p50/p95/p99;
* **throughput** — completed queries per second over the observed completion
  window;
* **shedding / backpressure counters** — requests shed because their deadline
  had already expired (at admission or at dispatch), and requests rejected by
  the bounded admission queue (``rejected_full``);
* **overflow** — total queries whose kNN candidate window overflowed,
  aggregated from the per-request propagation (``InterpolationRequest.overflow``).

Everything here is dependency-free host-side bookkeeping: no JAX arrays, no
device syncs — ``record_*`` calls cost a few dict updates, so the worker
thread can call them per batch without perturbing the latencies it measures.
"""

from __future__ import annotations

import math
import threading
import time
from bisect import bisect_left

__all__ = ["LatencyHistogram", "Telemetry"]


class LatencyHistogram:
    """Log-spaced latency histogram with quantile estimation.

    Bins span ``lo``..``hi`` seconds with ``bins_per_decade`` log10-spaced
    buckets (default: 1us..1000s, 10 buckets/decade => 91 bins, <1KB).
    ``percentile`` returns the upper edge of the bucket holding the requested
    rank, clamped to the exact observed max — a <=26% overestimate by
    construction, which is the right bias for latency SLO reporting.
    """

    def __init__(self, lo: float = 1e-6, hi: float = 1e3,
                 bins_per_decade: int = 10):
        self.lo = float(lo)
        self.hi = float(hi)
        self.bins_per_decade = int(bins_per_decade)
        decades = math.log10(hi / lo)
        n = int(round(decades * bins_per_decade))
        self._edges = [lo * 10.0 ** (i / bins_per_decade)
                       for i in range(1, n + 1)]
        self._counts = [0] * (n + 1)        # +1: overflow bucket above hi
        self.count = 0
        self.sum = 0.0
        self.max = 0.0

    def record(self, seconds: float) -> None:
        s = max(float(seconds), 0.0)
        self._counts[bisect_left(self._edges, s)] += 1
        self.count += 1
        self.sum += s
        if s > self.max:
            self.max = s

    def percentile(self, p: float) -> float:
        """p in [0, 100] -> seconds (0.0 when empty)."""
        if self.count == 0:
            return 0.0
        rank = p / 100.0 * self.count
        seen = 0
        for i, c in enumerate(self._counts):
            seen += c
            if seen >= rank and c:
                edge = self._edges[i] if i < len(self._edges) else self.max
                return min(edge, self.max)
        return self.max

    def snapshot(self) -> dict:
        return {
            "count": self.count,
            "mean_s": self.sum / self.count if self.count else 0.0,
            "p50_s": self.percentile(50),
            "p95_s": self.percentile(95),
            "p99_s": self.percentile(99),
            "max_s": self.max,
        }

    # -- cross-host merging (repro.serving.cluster.telemetry) ----------------

    def state(self) -> dict:
        """Full mergeable state (JSON-serializable): bin counts plus the bin
        parameters, so fleet-level percentiles can be computed exactly from
        per-host histograms instead of averaging per-host percentiles (which
        has no statistical meaning)."""
        return {"lo": self.lo, "hi": self.hi,
                "bins_per_decade": self.bins_per_decade,
                "counts": list(self._counts),
                "count": self.count, "sum": self.sum, "max": self.max}

    def merge_state(self, state: dict) -> None:
        """Fold another histogram's :meth:`state` into this one.  Bin layouts
        must match — merging histograms with different edges would silently
        misattribute counts, so mismatch raises."""
        if (state["lo"], state["hi"], state["bins_per_decade"]) != \
                (self.lo, self.hi, self.bins_per_decade) or \
                len(state["counts"]) != len(self._counts):
            raise ValueError("cannot merge histograms with different bins")
        for i, c in enumerate(state["counts"]):
            self._counts[i] += int(c)
        self.count += int(state["count"])
        self.sum += float(state["sum"])
        self.max = max(self.max, float(state["max"]))

    @classmethod
    def from_states(cls, states) -> "LatencyHistogram":
        """Merge per-host states into one fleet histogram."""
        states = list(states)
        if not states:
            return cls()
        h = cls(states[0]["lo"], states[0]["hi"],
                states[0]["bins_per_decade"])
        for s in states:
            h.merge_state(s)
        return h


class Telemetry:
    """Aggregated serving metrics for one engine/server instance.

    ``clock`` is injectable (tests pass a fake monotonic clock); all
    timestamps recorded on requests are in this clock's epoch.
    """

    def __init__(self, clock=time.monotonic):
        self.clock = clock
        self.queue = LatencyHistogram()
        self.execute = LatencyHistogram()
        self.total = LatencyHistogram()
        # shed requests terminate fast by construction — folding their
        # time-to-shed into `total` would IMPROVE reported SLO percentiles
        # the more requests are dropped, so they get their own histogram
        self.shed = LatencyHistogram()
        self.counters = {
            "submitted": 0, "completed": 0, "shed": 0, "rejected_full": 0,
            "batches": 0, "queries": 0, "overflow_queries": 0,
            "dataset_updates": 0,
        }
        self._t_first: float | None = None
        self._t_last: float | None = None
        # submit/reject/admission-shed arrive from client threads while the
        # worker records batches: one lock keeps counters and histograms sane
        self._lock = threading.Lock()

    def reset(self) -> None:
        """Zero histograms, counters, and the throughput window.  Load
        harnesses call this after warmup so the report reflects steady
        state, not first-bucket compiles."""
        with self._lock:
            self.queue = LatencyHistogram()
            self.execute = LatencyHistogram()
            self.total = LatencyHistogram()
            self.shed = LatencyHistogram()
            for k in self.counters:
                self.counters[k] = 0
            self._t_first = self._t_last = None

    # -- recording -----------------------------------------------------------

    def record_submit(self, req) -> None:
        with self._lock:
            self.counters["submitted"] += 1

    def record_rejected(self) -> None:
        with self._lock:
            self.counters["rejected_full"] += 1

    def record_shed(self, req) -> None:
        with self._lock:
            self.counters["shed"] += 1
            if req.t_submit is not None and req.t_done is not None:
                self.shed.record(req.t_done - req.t_submit)

    def record_update(self) -> None:
        with self._lock:
            self.counters["dataset_updates"] += 1

    def record_batch(self, group, execute_s: float) -> None:
        """One dispatched coalesced batch; per-request timestamps are set."""
        with self._lock:
            self.counters["batches"] += 1
            self.execute.record(execute_s)
            for r in group:
                self.counters["completed"] += 1
                self.counters["queries"] += r.queries_xy.shape[0]
                self.counters["overflow_queries"] += r.overflow
                if r.t_submit is not None and r.t_dispatch is not None:
                    self.queue.record(r.t_dispatch - r.t_submit)
                if r.t_submit is not None and r.t_done is not None:
                    self.total.record(r.t_done - r.t_submit)
                t_done = r.t_done if r.t_done is not None else self.clock()
                # throughput window opens at the first SUBMIT and closes at
                # the last completion — completion-to-completion would be
                # zero-width for a single-batch run (absurd q/s) and would
                # exclude the first batch's own latency
                t_start = r.t_submit if r.t_submit is not None else t_done
                if self._t_first is None or t_start < self._t_first:
                    self._t_first = t_start
                if self._t_last is None or t_done > self._t_last:
                    self._t_last = t_done

    # -- reporting -----------------------------------------------------------

    def queries_per_s(self) -> float:
        if self._t_first is None or self._t_last is None:
            return 0.0
        return self.counters["queries"] / max(self._t_last - self._t_first,
                                              1e-9)

    def report(self) -> dict:
        """JSON-serializable snapshot (the load generator's report body)."""
        with self._lock:
            return {
                **self.counters,
                "queries_per_s": self.queries_per_s(),
                "latency": {
                    "queue": self.queue.snapshot(),
                    "execute": self.execute.snapshot(),
                    "total": self.total.snapshot(),
                    "shed": self.shed.snapshot(),
                },
            }

    def state(self) -> dict:
        """Mergeable cross-host snapshot: counters, per-host rate, and FULL
        histogram states (bin counts, not just percentiles).  Fleet
        aggregation lives in :func:`repro.serving.cluster.telemetry
        .merge_reports`; per-host throughput windows are kept per host
        because monotonic clocks are not comparable across processes."""
        with self._lock:
            return {
                "counters": dict(self.counters),
                "queries_per_s": self.queries_per_s(),
                "hists": {
                    "queue": self.queue.state(),
                    "execute": self.execute.state(),
                    "total": self.total.state(),
                    "shed": self.shed.state(),
                },
            }
