"""Serving telemetry: latency histograms, throughput, and shed counters.

The async serving subsystem (``serving/server.py``) and the synchronous
:class:`repro.serving.engine.AidwEngine` facade both report through one
:class:`Telemetry` object so a load test reads the same metrics regardless of
the drive mode:

* per-request **queue** latency (submit -> dispatch), **execute** latency
  (dispatch -> results on host), and **total** latency (submit -> done), each
  recorded into a log-spaced :class:`LatencyHistogram` with p50/p95/p99;
* **throughput** — completed queries per second over the observed completion
  window;
* **shedding / backpressure counters** — requests shed because their deadline
  had already expired (at admission or at dispatch), and requests rejected by
  the bounded admission queue (``rejected_full``);
* **overflow** — total queries whose kNN candidate window overflowed,
  aggregated from the per-request propagation (``InterpolationRequest.overflow``).

Everything here is dependency-free host-side bookkeeping: no JAX arrays, no
device syncs — ``record_*`` calls cost a few dict updates, so the worker
thread can call them per batch without perturbing the latencies it measures.

Since PR 8 the histograms live in the one :class:`repro.obs.Registry`
(names ``serving/queue_wait_s`` / ``serving/execute_s`` / ``serving/total_s``
/ ``serving/shed_s``), so the Prometheus endpoint and ``report()`` read the
same bins; ``LatencyHistogram`` remains exported here as the documented
alias of :class:`repro.obs.metrics.Histogram`.
"""

from __future__ import annotations

import threading
import time

from ..obs import Registry
from ..obs.metrics import Histogram

__all__ = ["LatencyHistogram", "Telemetry"]


class LatencyHistogram(Histogram):
    """Documented alias of :class:`repro.obs.metrics.Histogram` — the
    log-spaced mergeable latency histogram previously defined here.  All
    behaviour (binning, ``state``/``merge_state``/``from_states`` bin-exact
    merging) lives on the base class; existing imports keep working."""


class Telemetry:
    """Aggregated serving metrics for one engine/server instance.

    ``clock`` is injectable (tests pass a fake monotonic clock); all
    timestamps recorded on requests are in this clock's epoch.  ``wall`` is
    the injectable WALL clock (``time.time``): monotonic clocks are not
    comparable across processes, so the throughput window is additionally
    anchored to wall time and carried in :meth:`state` — the fleet rollup
    computes fleet QPS over the union wall window instead of summing
    per-host rates measured over different windows.  ``registry`` is the
    shared :class:`repro.obs.Registry` the histograms are registered in
    (one is created when not provided).
    """

    _HIST_NAMES = {"queue": "serving/queue_wait_s",
                   "execute": "serving/execute_s",
                   "total": "serving/total_s",
                   "shed": "serving/shed_s"}

    def __init__(self, clock=time.monotonic, wall=time.time,
                 registry: Registry | None = None):
        self.clock = clock
        self.wall = wall
        self.registry = registry if registry is not None else Registry()
        self.queue = self.registry.histogram(self._HIST_NAMES["queue"])
        self.execute = self.registry.histogram(self._HIST_NAMES["execute"])
        self.total = self.registry.histogram(self._HIST_NAMES["total"])
        # shed requests terminate fast by construction — folding their
        # time-to-shed into `total` would IMPROVE reported SLO percentiles
        # the more requests are dropped, so they get their own histogram
        self.shed = self.registry.histogram(self._HIST_NAMES["shed"])
        self.counters = {
            "submitted": 0, "completed": 0, "shed": 0, "rejected_full": 0,
            "batches": 0, "queries": 0, "overflow_queries": 0,
            "dataset_updates": 0,
        }
        self._t_first: float | None = None
        self._t_last: float | None = None
        self._w_first: float | None = None    # wall-clock window anchors
        self._w_last: float | None = None
        # submit/reject/admission-shed arrive from client threads while the
        # worker records batches: one lock keeps counters and histograms sane
        self._lock = threading.Lock()

    def reset(self) -> None:
        """Zero histograms, counters, and the throughput window.  Load
        harnesses call this after warmup so the report reflects steady
        state, not first-bucket compiles."""
        with self._lock:
            self.queue = self.registry.reset_histogram(
                self._HIST_NAMES["queue"])
            self.execute = self.registry.reset_histogram(
                self._HIST_NAMES["execute"])
            self.total = self.registry.reset_histogram(
                self._HIST_NAMES["total"])
            self.shed = self.registry.reset_histogram(
                self._HIST_NAMES["shed"])
            for k in self.counters:
                self.counters[k] = 0
            self._t_first = self._t_last = None
            self._w_first = self._w_last = None

    # -- recording -----------------------------------------------------------

    def record_submit(self, req) -> None:
        with self._lock:
            self.counters["submitted"] += 1

    def record_rejected(self) -> None:
        with self._lock:
            self.counters["rejected_full"] += 1

    def record_shed(self, req) -> None:
        with self._lock:
            self.counters["shed"] += 1
            if req.t_submit is not None and req.t_done is not None:
                self.shed.record(req.t_done - req.t_submit)

    def record_update(self) -> None:
        with self._lock:
            self.counters["dataset_updates"] += 1

    def record_batch(self, group, execute_s: float) -> None:
        """One dispatched coalesced batch; per-request timestamps are set."""
        with self._lock:
            self.counters["batches"] += 1
            self.execute.record(execute_s)
            for r in group:
                self.counters["completed"] += 1
                self.counters["queries"] += r.queries_xy.shape[0]
                self.counters["overflow_queries"] += r.overflow
                # exemplar: the sampled trace id when the request has one,
                # else the flight recorder's deterministic uid-derived id —
                # a p99 bucket then names a pullable trace either way
                uid = getattr(r, "uid", None)
                ex = getattr(r, "trace_id", None) or (
                    f"req-{uid}" if uid is not None else None)
                if r.t_submit is not None and r.t_dispatch is not None:
                    self.queue.record(r.t_dispatch - r.t_submit,
                                      exemplar=ex)
                if r.t_submit is not None and r.t_done is not None:
                    self.total.record(r.t_done - r.t_submit, exemplar=ex)
                t_done = r.t_done if r.t_done is not None else self.clock()
                # throughput window opens at the first SUBMIT and closes at
                # the last completion — completion-to-completion would be
                # zero-width for a single-batch run (absurd q/s) and would
                # exclude the first batch's own latency
                t_start = r.t_submit if r.t_submit is not None else t_done
                if self._t_first is None or t_start < self._t_first:
                    self._t_first = t_start
                if self._t_last is None or t_done > self._t_last:
                    self._t_last = t_done
            # re-anchor the window in wall time from the monotonic bounds:
            # one offset sample per batch keeps the wall window exactly as
            # wide as the monotonic one, and absolute (comparable across
            # hosts) to within clock-sampling jitter
            if self._t_first is not None and self.wall is not None:
                off = self.wall() - self.clock()
                self._w_first = self._t_first + off
                self._w_last = self._t_last + off

    # -- reporting -----------------------------------------------------------

    def queries_per_s(self) -> float:
        if self._t_first is None or self._t_last is None:
            return 0.0
        return self.counters["queries"] / max(self._t_last - self._t_first,
                                              1e-9)

    def report(self) -> dict:
        """JSON-serializable snapshot (the load generator's report body)."""
        with self._lock:
            return {
                **self.counters,
                "queries_per_s": self.queries_per_s(),
                "latency": {
                    "queue": self.queue.snapshot(),
                    "execute": self.execute.snapshot(),
                    "total": self.total.snapshot(),
                    "shed": self.shed.snapshot(),
                },
            }

    def state(self) -> dict:
        """Mergeable cross-host snapshot: counters, per-host rate, FULL
        histogram states (bin counts, not just percentiles), and the
        WALL-anchored throughput window.  Fleet aggregation lives in
        :func:`repro.serving.cluster.telemetry.merge_reports`: monotonic
        clocks are not comparable across processes, so fleet QPS is
        computed from the union of the per-host ``window`` wall spans
        (``sum(queries) / (max(t1_wall) - min(t0_wall))``) — never by
        summing per-host rates measured over different windows."""
        with self._lock:
            return {
                "counters": dict(self.counters),
                "queries_per_s": self.queries_per_s(),
                "window": {"t0_wall": self._w_first,
                           "t1_wall": self._w_last,
                           "queries": self.counters["queries"]},
                "hists": {
                    "queue": self.queue.state(),
                    "execute": self.execute.state(),
                    "total": self.total.state(),
                    "shed": self.shed.state(),
                },
            }
