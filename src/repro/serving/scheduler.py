"""Deadline-aware microbatch coalescing — the batch former IS a perf subsystem.

Mei & Tian's data-layout study (arXiv:1402.4986) shows batch composition
dominates GPU IDW throughput, so the scheduler that forms microbatches is on
the critical path of the paper's 1017x story, not plumbing around it.  This
module is the ONE coalescing implementation behind both drive modes:
:class:`repro.serving.engine.AidwEngine` (synchronous: caller hands it a
request list) and :class:`repro.serving.server.AsyncAidwServer` (a worker
thread pulls from the admission queue).

Coalescing contract:

* **FIFO, never reordering** — requests join a batch in arrival order; a
  batch closes when adding the next request would exceed ``max_batch``
  queries (a request larger than ``max_batch`` forms its own batch).  With no
  deadlines anywhere this reproduces the classic greedy coalescing
  byte-for-byte: identical groups, identical concatenated batches, identical
  (bitwise) results through the session's bucketed executables.
* **deadline-aware early close** — each group tracks the earliest deadline of
  its members; the coalescer refuses to grow the batch past the point where
  ``now + estimate(execute_time(next_size)) + slack`` overshoots that
  deadline.  ``estimate`` is MEASURED, not assumed: an EWMA per compiled
  bucket size (:class:`ExecuteTimeModel`), reusing the session's
  power-of-two bucketing so the estimate keys on the executable that would
  actually run — growing a batch within one bucket costs nothing, crossing a
  bucket boundary is what changes the execute time.
* **dispatch-time shedding** — a request whose deadline has already passed
  when the coalescer reaches it is shed (status ``"shed"``) instead of served
  late.  Predicted-late-but-not-expired requests are NOT shed (the estimate
  is a forecast): they dispatch best-effort at the front of their own batch.

``clock`` is injectable everywhere for deterministic deadline tests.
"""

from __future__ import annotations

import time
from collections import deque

import numpy as np

from repro.core.session import bucket_size

__all__ = ["DeadlineCoalescer", "ExecuteTimeModel", "dispatch_batch",
           "launch_batch", "scatter_batch", "shed_request",
           "STATUS_PENDING", "STATUS_QUEUED", "STATUS_DONE", "STATUS_SHED"]

STATUS_PENDING = "pending"   # created, not yet admitted
STATUS_QUEUED = "queued"     # admitted, waiting for a batch
STATUS_DONE = "done"         # served; .values/.overflow populated
STATUS_SHED = "shed"         # deadline expired before dispatch; never served


class ExecuteTimeModel:
    """EWMA execute-time estimate keyed on (query bucket, dataset bucket).

    ``record(n, seconds)`` folds a measured batch execute time into the EWMA
    for ``(bucket_size(n, min_bucket), dataset bucket)``, where the dataset
    bucket is the power-of-two bucket of the CURRENT ``n_points`` (the engine
    refreshes :attr:`n_points` on every ``update_dataset``).  Execute time
    depends on the dataset size through the kNN candidate windows, so after a
    large delta update the per-query-bucket EWMA primed at the OLD size would
    mis-calibrate the deadline early-close until the EWMA relearned; keying
    on both keeps per-size estimates live across churn.

    ``estimate(n)`` reads the estimate back: exact key first, then the
    nearest query bucket measured AT the current dataset size (scaled
    linearly in n — bucket executables are ~linear in batch size), then the
    nearest dataset bucket (dataset-size dependence is measured, not
    modeled).  0.0 before ANY measurement — optimistic, so the scheduler
    never closes batches early on a cold model.
    """

    def __init__(self, min_bucket: int = 64, alpha: float = 0.3,
                 n_points: int | None = None):
        self.min_bucket = int(min_bucket)
        self.alpha = float(alpha)
        self.n_points = n_points        # engine-maintained; None = unkeyed
        self._ewma: dict[tuple[int, int], float] = {}

    def bucket(self, n: int) -> int:
        return bucket_size(n, self.min_bucket)

    def _dataset_bucket(self) -> int:
        return 0 if self.n_points is None \
            else bucket_size(int(self.n_points), 1)

    def record(self, n: int, seconds: float) -> None:
        key = (self.bucket(n), self._dataset_bucket())
        prev = self._ewma.get(key)
        self._ewma[key] = float(seconds) if prev is None else \
            self.alpha * float(seconds) + (1.0 - self.alpha) * prev

    def estimate(self, n: int) -> float:
        if not self._ewma:
            return 0.0
        nb, mb = self.bucket(n), self._dataset_bucket()
        hit = self._ewma.get((nb, mb))
        if hit is not None:
            return hit
        same_m = [k for k in self._ewma if k[1] == mb]
        if same_m:
            k = min(same_m, key=lambda k: abs(k[0] - nb))
        else:
            # nothing measured at this dataset size yet (right after a
            # resizing update): nearest dataset bucket, still scaled in n
            k = min(self._ewma, key=lambda k: (abs(k[1] - mb),
                                               abs(k[0] - nb)))
        return self._ewma[k] * (nb / k[0])


def shed_request(req, now: float) -> None:
    """Mark ``req`` shed (deadline expired before dispatch): terminal, never
    served, distinct status so clients can tell shed from served."""
    req.status = STATUS_SHED
    req.done = True
    req.t_done = now


class DeadlineCoalescer:
    """FIFO coalescer with deadline-aware early batch close (module
    docstring).  Stateless across calls except for the shared
    :class:`ExecuteTimeModel`."""

    def __init__(self, max_batch: int, estimator: ExecuteTimeModel | None
                 = None, *, clock=time.monotonic, slack_s: float = 0.0):
        self.max_batch = int(max_batch)
        self.estimator = estimator or ExecuteTimeModel()
        self.clock = clock
        self.slack_s = float(slack_s)

    # -- deadline predicates -------------------------------------------------

    @staticmethod
    def _expired(req, now: float) -> bool:
        return req.deadline is not None and now >= req.deadline

    def _would_miss(self, earliest_deadline: float | None, n: int,
                    now: float) -> bool:
        if earliest_deadline is None:
            return False
        return now + self.estimator.estimate(n) + self.slack_s \
            > earliest_deadline

    # -- batch formation -----------------------------------------------------

    def next_batch(self, pending: deque, now: float | None = None):
        """Pop ONE coalesced group off the front of ``pending``.

        Returns ``(group, shed)``: ``group`` is [] only when ``pending`` ran
        dry (after shedding).  Items without a ``queries_xy`` attribute
        (e.g. dataset-update barriers) stop the scan — the caller handles
        them between batches, preserving FIFO order with queries.
        """
        now = self.clock() if now is None else now
        shed: list = []
        while pending and hasattr(pending[0], "queries_xy") \
                and self._expired(pending[0], now):
            r = pending.popleft()
            shed_request(r, now)
            shed.append(r)
        if not pending or not hasattr(pending[0], "queries_xy"):
            return [], shed
        first = pending.popleft()
        group = [first]
        size = first.queries_xy.shape[0]
        earliest = first.deadline
        while pending:
            r = pending[0]
            if not hasattr(r, "queries_xy"):
                break                        # update barrier: close here
            if self._expired(r, now):
                pending.popleft()
                shed_request(r, now)
                shed.append(r)
                continue
            n_next = size + r.queries_xy.shape[0]
            if n_next > self.max_batch:
                break
            cand = earliest if r.deadline is None else (
                r.deadline if earliest is None else min(earliest, r.deadline))
            if self._would_miss(cand, n_next, now):
                break                        # deadline-aware early close
            pending.popleft()
            group.append(r)
            size = n_next
            earliest = cand
        return group, shed

    def coalesce(self, requests, now: float | None = None):
        """Partition a whole request list into dispatch groups (the
        synchronous drive mode).  Returns ``(groups, shed)``.

        Accepts QUERY requests only — barrier items (no ``queries_xy``)
        belong to the streaming drive mode, where the caller owns the deque
        and handles them between ``next_batch`` calls; here they would
        never be popped, so they are rejected loudly instead of hanging.
        """
        now = self.clock() if now is None else now
        pending = deque(requests)
        groups: list[list] = []
        shed: list = []
        while pending:
            group, s = self.next_batch(pending, now)
            shed.extend(s)
            if group:
                groups.append(group)
            elif pending:
                raise ValueError(
                    f"coalesce() takes query requests only, got "
                    f"{type(pending[0]).__name__} (drive barriers through "
                    f"next_batch)")
        return groups, shed


def launch_batch(session, group, *, clock=time.monotonic):
    """Dispatch one coalesced group on ``session`` WITHOUT materializing
    results.  JAX dispatch is asynchronous — ``session.query`` returns
    device arrays before the computation finishes — so a worker can form
    and launch batch N+1 while batch N's results transfer, hiding the
    host-side scatter latency (the pipelined drive mode:
    ``AsyncAidwServer(pipeline_depth=...)``).  Returns ``(res, t0)`` for a
    later :func:`scatter_batch`.
    """
    t0 = clock()
    for r in group:
        r.t_dispatch = t0
    res = session.query(np.concatenate(
        [r.queries_xy for r in group], axis=0))
    return res, t0


def scatter_batch(group, res, t0, *, estimator: ExecuteTimeModel | None
                  = None, telemetry=None, clock=time.monotonic,
                  tracer=None, recorder=None):
    """Materialize a launched batch and scatter results to their requests.

    Slices values AND the per-query overflow mask back to each owning
    request (so a client can tell ITS bucket overflowed, not just that
    some query in some batch did), stamps timestamps/status, and feeds the
    measured execute time into the scheduler's estimate.  Under pipelined
    dispatch the measured span includes the overlap window, so the
    estimator's deadline forecasts become conservative — acceptable for a
    measured experiment, one reason pipelining is off by default.

    Observability: the batch's coalesce hold (dispatch minus the LAST
    member's arrival) and the host-side scatter wall go into the
    telemetry's registry (``serving/coalesce_s``/``serving/scatter_s``),
    and each TRACED request (``trace_id`` set) gets retroactive
    queue_wait/coalesce/execute/scatter spans from the timestamps already
    stamped — tracing adds no work between them.  The ``np.asarray``
    materialization above IS the execute fence (host sync), so the
    execute span honours the obs fencing contract.  The always-on
    ``recorder`` (:class:`repro.obs.recorder.FlightRecorder`) observes
    every request from the SAME fence points — retention decisions need
    the per-request zero-weight/overflow slices, which is why the mask
    slicing below is per-request to begin with.
    """
    vals = np.asarray(res.values)            # host sync: results materialized
    mask = None if res.overflow_mask is None \
        else np.asarray(res.overflow_mask)
    zmask = getattr(res, "zero_weight_mask", None)
    if zmask is not None:
        zmask = np.asarray(zmask)
    t1 = clock()
    off = 0
    for r in group:
        n = r.queries_xy.shape[0]
        r.values = vals[off:off + n]
        r.overflow = 0 if mask is None else int(mask[off:off + n].sum())
        if zmask is not None:
            r.zero_weight = int(zmask[off:off + n].sum())
        r.status = STATUS_DONE
        r.done = True
        r.t_done = t1
        off += n
    t2 = clock()
    last_submit = max((r.t_submit for r in group
                       if r.t_submit is not None), default=t0)
    if estimator is not None:
        estimator.record(off, t1 - t0)
    if telemetry is not None:
        telemetry.record_batch(group, t1 - t0)
        reg = getattr(telemetry, "registry", None)
        if reg is not None:
            reg.observe("serving/coalesce_s", max(t0 - last_submit, 0.0))
            reg.observe("serving/scatter_s", t2 - t1)
    if tracer is not None:
        for r in group:
            tid = getattr(r, "trace_id", None)
            if tid is None:
                continue
            parent = getattr(r, "parent_span", None)
            if r.t_submit is not None:
                tracer.record("queue_wait", r.t_submit, r.t_dispatch,
                              trace_id=tid, parent_id=parent)
                tracer.record("coalesce", min(last_submit, r.t_dispatch),
                              t0, trace_id=tid, parent_id=parent)
            tracer.record("execute", t0, t1, trace_id=tid, parent_id=parent,
                          args={"batch_queries": off})
            tracer.record("scatter", t1, t2, trace_id=tid, parent_id=parent)
    if recorder is not None:
        for r in group:
            recorder.observe_request(r, t0=t0, t1=t1, t2=t2,
                                     last_submit=last_submit)
    return res


def dispatch_batch(session, group, *, estimator: ExecuteTimeModel | None
                   = None, telemetry=None, clock=time.monotonic,
                   tracer=None, recorder=None):
    """Execute one coalesced group and scatter results back (launch +
    scatter, back to back — the default, non-pipelined drive mode).
    Returns the batch-level :class:`repro.core.pipeline.AidwResult`.
    """
    res, t0 = launch_batch(session, group, clock=clock)
    return scatter_batch(group, res, t0, estimator=estimator,
                         telemetry=telemetry, clock=clock, tracer=tracer,
                         recorder=recorder)
