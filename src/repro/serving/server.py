"""AsyncAidwServer — the online drive mode of the AIDW serving subsystem.

Turns the session-backed engine into a real server: clients ``submit()``
query batches (optionally deadline-bound) from any thread; ONE background
worker thread drains the bounded :class:`repro.serving.queue.AdmissionQueue`,
forms deadline-aware microbatches with the shared
:class:`repro.serving.scheduler.DeadlineCoalescer`, and executes them on the
resident :class:`repro.core.session.InterpolationSession` — so all device
work stays single-threaded (JAX dispatch is not re-entered concurrently)
while admission and result pickup are fully concurrent.

Write-path integration: ``update_dataset(inserts=/deletes=)`` enqueues a
barrier op into the SAME admission queue the query requests flow through.
The worker applies it between batches, in FIFO order with the queries around
it — churn is serialized with query execution on one thread, so an
incremental CSR patch can never race a query batch that is mid-flight, and a
query submitted after the update observes the updated dataset.

Ring visibility + compaction epochs (``layout='grid_ring'`` sessions): a
delta's inserts tier into per-slab hot append rings and its deletes
tombstone in place (the O(Δ) staging contract in ``repro.core.slab``), and
the very next query batch searches ring + CSR exactly — ring-resident
results sit within 1 ulp of a fresh plan's.  ``submit_compaction()`` /
``compact()`` enqueue a COMPACTION epoch through the same FIFO: queries
admitted before it see the ring-resident state, queries after it see tables
bitwise-identical to a fresh build at the same GridSpec.  Standalone
servers also self-enqueue a compaction after any local-epoch delta that
leaves ring occupancy at/above ``compact_highwater``; cluster-epoch'd hosts
never self-compact — the coordinator broadcasts compaction epochs so a
single server replaying the epoch log replays them at the same points in
the total order.

Lifecycle: ``submit() -> result()`` per request; ``flush()`` waits for
everything admitted so far; ``close()`` stops the worker (context-manager
support included).  Telemetry (queue/execute/total latency histograms, QPS,
shed/overflow counters) accumulates on ``server.telemetry`` and snapshots
via ``server.report()``.
"""

from __future__ import annotations

import itertools
import os
import sys
import threading
import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from . import scheduler as S
from ..obs import FlightRecorder, SloMonitor, Tracer
from ..runtime import compile_cache
from .engine import AidwEngine, InterpolationRequest
from .queue import AdmissionQueue, AdmissionQueueFull, validate_queries

__all__ = ["AsyncAidwServer"]


@dataclass
class _UpdateOp:
    """Dataset-update barrier flowing through the admission queue.

    Carries no ``queries_xy``, which is exactly how the coalescer recognizes
    it as a batch boundary (scheduler.next_batch stops the scan).

    ``epoch`` is the cluster-assigned epoch number for this update (see
    ``repro.serving.cluster.epochs``); ``None`` auto-increments the server's
    local epoch counter, so a standalone server replaying the same updates
    in the same order stamps the same epoch sequence as a cluster host.

    ``compact=True`` is the background COMPACTION epoch of the LSM ingest
    tier (``repro.core.slab`` hot-ring contract): it carries no data, but
    flows through the same FIFO — every query admitted before it is served
    against the ring-resident state, every query after it against the
    compacted (bitwise-fresh) tables — and bumps the epoch like any other
    update, so a single server replaying a cluster's epoch log replays
    its compactions at the same points in the order.

    ``trace_id``/``parent_span`` propagate the coordinator's trace context
    (``repro.obs``) through the barrier: the worker records the apply as an
    ``apply_epoch`` span under them, so a cluster-wide epoch broadcast
    renders as one connected trace across hosts.
    """

    points_xyz: object = None
    inserts: object = None
    deletes: object = None
    epoch: int | None = None         # explicit cluster epoch; None = +1
    compact: bool = False            # fold hot rings instead of a delta
    t_enqueue: float | None = None   # when the barrier entered the FIFO
    error: BaseException | None = None
    cancelled: bool = False          # timed-out caller withdrew the op
    skipped: bool = False            # worker honoured the withdrawal
    trace_id: str | None = None      # obs trace context (None = untraced)
    parent_span: str | None = None
    applied: threading.Event = field(default_factory=threading.Event)


@dataclass
class _ShardOp:
    """Shard-local compute op for the fleet's data-partitioned query path
    (``repro.serving.cluster.fleet.ShardedAidwCluster``).

    Like :class:`_UpdateOp` it carries no ``queries_xy``, so the coalescer
    treats it as a batch boundary and the worker executes it inline —
    which is exactly the consistency hook: a shard op is FIFO-ordered with
    epoch updates through the one admission queue, and is stamped with the
    epoch it executed under, so the fleet can detect (and retry) a query
    whose two phases straddled an update.

    ``kind``: ``"knn"`` (Stage 1 — this shard's top-k squared distances,
    the matching neighbour values, and the certification mask) or
    ``"partial"`` (Stage 2 — Eq. (1) partial sums at the client-merged
    per-query ``alpha``; skipped entirely in local Stage-2 mode).
    """

    kind: str
    queries: object
    alpha: object = None
    result: tuple | None = None
    epoch: int | None = None
    error: BaseException | None = None
    cancelled: bool = False          # timed-out caller withdrew the op
    applied: threading.Event = field(default_factory=threading.Event)


class AsyncAidwServer:
    """Admission queue + worker thread + deadline-aware coalescing over one
    :class:`repro.core.session.InterpolationSession`.

    Constructor arguments mirror :class:`repro.serving.engine.AidwEngine`
    (``mesh=`` serves every device of a mesh) plus the queueing knobs:
    ``max_depth`` bounds the admission queue (backpressure), ``slack_s`` pads
    the deadline-aware close test, ``linger_s`` optionally waits for more
    arrivals when a batch is still small (0.0 = dispatch as soon as the
    queue runs dry, which keeps pre-enqueued workloads byte-for-byte
    identical to the synchronous engine).  ``prewarm='background'``
    AOT-compiles the session's whole power-of-two bucket ladder off the
    worker thread at construction (serving starts immediately; compiled
    executables swap in as they land), then warms each bucket through the
    worker; ``'sync'`` blocks the constructor until warm.  :meth:`prewarm`
    is the same operation as a fleet control-plane call.
    """

    def __init__(self, points_xyz, cfg=None, *, max_batch: int = 8192,
                 max_depth: int = 1024, query_domain=None,
                 min_bucket: int = 64, mesh=None, layout: str = "replicated",
                 slack_s: float = 0.0, linger_s: float = 0.0,
                 pipeline_depth: int = 0, compact_highwater: float = 0.75,
                 ring_cap: int = 256, clock=time.monotonic, tracer=None,
                 trace_sample_rate: float | None = None, host_id="0",
                 wall=time.time, recorder=None, record_tail: bool = True,
                 recorder_opts: dict | None = None,
                 prewarm: str | None = None):
        # tracing is opt-in: pass a Tracer, or a trace_sample_rate to build
        # one on the SERVING clock (span timestamps must share the clock
        # domain of t_submit/t_dispatch/t_done — the obs clock contract)
        if tracer is None and trace_sample_rate is not None:
            tracer = Tracer(clock=clock, wall=wall,
                            sample_rate=trace_sample_rate, host=str(host_id))
        self.tracer = tracer
        self.host_id = str(host_id)
        # the flight recorder is ALWAYS-ON by default (tail-sampling —
        # head-sampled tracers never see the stragglers); record_tail=False
        # opts out for overhead A/B baselines, recorder_opts tunes
        # ring/top_percentile/min_window without constructing one by hand
        if recorder is None and record_tail:
            recorder = FlightRecorder(clock=clock, wall=wall,
                                      host=self.host_id,
                                      **(recorder_opts or {}))
        self.recorder = recorder
        # ONE construction path for the session/estimator/coalescer/
        # telemetry stack: the engine builds it, the server drives it from
        # a worker thread (and the sync facade stays usable via .engine)
        self.engine = AidwEngine(
            points_xyz, cfg, max_batch=max_batch, query_domain=query_domain,
            min_bucket=min_bucket, mesh=mesh, layout=layout, slack_s=slack_s,
            ring_cap=ring_cap, clock=clock, tracer=tracer, wall=wall)
        self.registry = self.engine.registry
        self.session = self.engine.session
        self.clock = clock
        self.estimator = self.engine.estimator
        self.coalescer = self.engine.coalescer
        self.telemetry = self.engine.telemetry
        self.queue = AdmissionQueue(max_depth, clock=clock)
        self._max_depth = int(max_depth)
        # SLO monitor: cold-path only — sampled/evaluated on report()/
        # debugz() pulls, never on the request path.  The ring-occupancy
        # threshold is the compaction highwater: occupancy pinned at/above
        # it means compactions are not keeping up with churn.
        self.slo = SloMonitor(
            clock=clock, recorder=self.recorder,
            targets={"ring_occupancy": compact_highwater
                     if compact_highwater > 0 else None})
        self.linger_s = float(linger_s)
        # pipeline_depth > 0: launch up to that many batches ahead of the
        # host-side scatter (jax async dispatch overlap — measured
        # experiment, see scheduler.launch_batch; 0 = classic dispatch,
        # byte-for-byte the synchronous engine's batch composition)
        self.pipeline_depth = int(pipeline_depth)
        self._pipeline: deque = deque()     # worker-local (group, res, t0)
        # dataset epoch: 0 for the construction-time dataset, bumped by every
        # applied update (or pinned to the update's explicit cluster epoch);
        # requests are stamped with the epoch they were SERVED under.
        # _epoch_gap records a withdrawn explicit-epoch barrier — the host
        # is missing that delta, and refuses further deltas until a full
        # update re-syncs it
        self.epoch = 0
        self._epoch_gap: int | None = None
        # LSM hot-ring high-water: after a LOCAL-epoch delta leaves ring
        # occupancy at/above this fraction, the worker self-enqueues a
        # background compaction epoch (standalone mode only — cluster-
        # epoch'd hosts compact when the coordinator says so, or the
        # replay-equivalence of the epoch log would break).  <= 0 disables.
        self.compact_highwater = float(compact_highwater)
        self._uid = itertools.count()
        self._reqs: dict[int, InterpolationRequest] = {}
        self._cv = threading.Condition()
        self._inflight = 0              # admitted, not yet done/shed
        self._worker_error: BaseException | None = None
        self._worker = threading.Thread(
            target=self._work, name="aidw-serving-worker", daemon=True)
        self._worker.start()
        # cold-start kill: AOT-compile the session's whole bucket ladder.
        # 'background' compiles OFF the worker thread (serving starts
        # immediately on the lazy jit path; compiled executables swap in
        # per bucket as they land) and then routes one warm batch per
        # bucket THROUGH the worker — AOT lower/compile is pure host work,
        # so it never violates the single-threaded-device-work invariant.
        # 'sync' blocks the constructor until the ladder is warm.
        if prewarm not in (None, "background", "sync"):
            raise ValueError(f"prewarm must be None, 'background' or "
                             f"'sync', got {prewarm!r}")
        compile_cache.install_listeners()
        self.prewarm_mode = prewarm
        self._prewarmed = threading.Event()
        self._prewarm_compiled = threading.Event()
        self._prewarm_stop = threading.Event()
        self._prewarm_error: BaseException | None = None
        self._prewarm_thread: threading.Thread | None = None
        if prewarm == "sync":
            self._do_prewarm()
        elif prewarm == "background":
            self._prewarm_thread = threading.Thread(
                target=self._do_prewarm, name="aidw-prewarm", daemon=True)
            self._prewarm_thread.start()

    # -- client API ----------------------------------------------------------

    def submit(self, queries_xy, *, deadline_s: float | None = None,
               uid: int | None = None, block: bool = True,
               timeout: float | None = None, trace_id: str | None = None,
               parent_span: str | None = None) -> InterpolationRequest:
        """Admit one request; returns its :class:`InterpolationRequest`.

        ``deadline_s`` is RELATIVE seconds from now (converted to an absolute
        deadline on the server clock).  A request already expired on arrival
        is shed immediately (``status == "shed"``, never enqueued).  A full
        queue blocks (backpressure) unless ``block=False``/``timeout``, in
        which case :class:`repro.serving.queue.AdmissionQueueFull` escapes to
        the caller.

        ``trace_id``/``parent_span`` join an EXISTING trace (a fleet router
        propagating its context); when absent and the server has a tracer,
        the sampler decides once here at the root — a ``None`` outcome makes
        every downstream span call a no-op for this request.
        """
        self._raise_worker_error()
        # validate at the boundary: a malformed array admitted here would
        # crash the WORKER and take down serving for every other client
        q = validate_queries(queries_xy)
        now = self.clock()
        if uid is None:
            uid = next(self._uid)
            with self._cv:                   # never collide with caller uids
                while uid in self._reqs:
                    uid = next(self._uid)
        req = InterpolationRequest(
            uid=uid, queries_xy=q,
            deadline=None if deadline_s is None else now + deadline_s)
        req.t_submit = now
        req.status = "queued"
        if trace_id is not None:
            req.trace_id = trace_id
            req.parent_span = parent_span
        elif self.tracer is not None:
            req.trace_id = self.tracer.new_trace()   # sampling at the root
        # count in-flight BEFORE admission: the worker may pop + dispatch +
        # decrement the instant put() releases the queue lock, and a late
        # increment here would strand _inflight at 1 (flush would hang)
        with self._cv:
            if req.uid in self._reqs:
                raise ValueError(f"duplicate request uid {req.uid}")
            self._reqs[req.uid] = req
            self._inflight += 1
        self.telemetry.record_submit(req)
        try:
            admitted = self.queue.put(req, block=block, timeout=timeout)
        except Exception as e:
            with self._cv:
                self._reqs.pop(req.uid, None)
                self._inflight -= 1
                self._cv.notify_all()
            if isinstance(e, AdmissionQueueFull):
                # only genuine backpressure counts as a rejection — a closed
                # queue (shutdown/crash) would misread as capacity pressure
                self.telemetry.record_rejected()
            raise
        if not admitted:                      # expired on arrival: shed
            S.shed_request(req, self.clock())
            self.telemetry.record_shed(req)
            if self.recorder is not None:
                self.recorder.observe_shed(req)
            with self._cv:
                self._inflight -= 1
                self._cv.notify_all()
        return req

    def result(self, req: InterpolationRequest | int,
               timeout: float | None = None) -> InterpolationRequest:
        """Block until the request reaches a terminal state and return it
        (``status`` is ``"done"`` or ``"shed"``); raises TimeoutError."""
        if isinstance(req, int):
            with self._cv:
                if req not in self._reqs:
                    raise KeyError(f"unknown request uid {req}")
                req = self._reqs[req]
        with self._cv:
            if not self._cv.wait_for(lambda: req.done or
                                     self._worker_error is not None,
                                     timeout=timeout):
                raise TimeoutError(f"request {req.uid} not done "
                                   f"after {timeout}s")
        if req.done:          # completed before any worker crash: still good
            return req
        self._raise_worker_error()
        return req

    def flush(self, timeout: float | None = None) -> None:
        """Wait until every request admitted so far is done or shed, then
        reap the terminal uid registry (callers hold their own request
        objects; without this a long-running submit/flush loop would grow
        host memory without bound).  ``result(uid)`` lookups for flushed
        requests therefore need the request OBJECT, not the bare uid."""
        with self._cv:
            if not self._cv.wait_for(lambda: self._inflight == 0 or
                                     self._worker_error is not None,
                                     timeout=timeout):
                raise TimeoutError(
                    f"{self._inflight} requests still in flight "
                    f"after {timeout}s")
        self._raise_worker_error()
        self.reap()

    def reap(self) -> int:
        """Drop terminal requests from the uid registry (long-running
        servers call this after collecting results; returns how many)."""
        with self._cv:
            done = [u for u, r in self._reqs.items() if r.done]
            for u in done:
                del self._reqs[u]
            return len(done)

    # -- cold-start prewarm --------------------------------------------------

    def _do_prewarm(self) -> None:
        """Compile the session's full bucket ladder, then warm each bucket
        with one dummy batch routed THROUGH the worker (the eager helper
        ops around the executable compile there, on the thread that owns
        device execution).  Runs on the caller's thread ('sync'/explicit
        prewarm()) or the dedicated prewarm thread ('background') — AOT
        lower/compile is host-only work either way."""
        try:
            t0 = self.clock()
            sess = self.session
            ladder = sess.bucket_ladder(self.engine.max_batch)
            if threading.current_thread() is self._prewarm_thread:
                # background mode: serving has the cores, prewarm takes
                # the leftovers.  Per-thread nice (Linux: PRIO_PROCESS
                # with a TID targets one thread) plus single-split CPU
                # codegen (below) keeps compile work on THIS thread —
                # the off-path p99 gate in benchmarks/coldstart_bench.py
                # holds the line at 1.1x steady state.
                try:
                    os.setpriority(os.PRIO_PROCESS,
                                   threading.get_native_id(), 19)
                except (AttributeError, OSError):
                    pass
            # lowering is GIL-bound Python tracing: at the default 5ms
            # switch interval a foreground dispatch can stall a full
            # quantum behind it.  A short interval preempts the tracing
            # thread often enough that dispatch latency stays flat
            # (restored below).
            switch0 = sys.getswitchinterval()
            sys.setswitchinterval(min(switch0, 0.0005))
            try:
                opts = compile_cache.background_compile_options()
                for b in ladder:
                    if self._prewarm_stop.is_set():
                        return
                    sess.precompile(buckets=[b], compiler_options=opts)
            finally:
                sys.setswitchinterval(switch0)
            # phase boundary: the EXPENSIVE part (seconds of XLA compiles,
            # off the worker thread) is done; what follows are ordinary
            # worker-queue batches (milliseconds).  The cold-start bench's
            # off-path gate measures contention against this event — a
            # foreground request queueing behind a warm batch is FIFO
            # head-of-line blocking, not compile leakage.
            self._prewarm_compiled.set()
            anchor = np.asarray(sess._host_pts[0, :2], dtype=np.float32)
            for b in ladder:
                if self._prewarm_stop.is_set():
                    return
                # dummy warm batch: exact bucket size (no pad), in-domain
                # coordinates, results discarded.  Submitted one at a time
                # (awaited before the next) so the coalescer cannot merge
                # them — each bucket must dispatch STANDALONE to warm its
                # own helper-op shapes.  Counted by telemetry like any
                # request — prewarming servers see len(ladder) extra
                # completed batches.
                self.result(self.submit(np.tile(anchor, (b, 1))),
                            timeout=600.0)
            self._prewarmed.set()
            self.registry.set("serving/prewarmed", 1, merge="max")
            self.registry.observe("serving/prewarm_s", self.clock() - t0)
            compile_cache.sync_registry(self.registry)
            if self.recorder is not None:
                self.recorder.event(
                    "prewarm_done", severity="info",
                    data={"buckets": ladder,
                          "wall_s": self.clock() - t0})
        except BaseException as e:
            self._prewarm_error = e

    def prewarm(self, wait: bool = True,
                timeout: float | None = None) -> dict:
        """AOT-compile + warm this server's whole bucket ladder (the fleet
        control-plane op: a joining or restarted host calls this before
        entering rotation).  No-op when already prewarmed; with a
        'background' thread in flight, ``wait=True`` blocks until it
        lands.  Returns a status dict (prewarmed flag, live AOT bucket
        count, persistent-cache stats)."""
        if self._prewarm_thread is None and not self._prewarmed.is_set():
            self.prewarm_mode = self.prewarm_mode or "sync"
            self._do_prewarm()
        if wait:
            deadline = None if timeout is None \
                else time.monotonic() + timeout
            while not self._prewarmed.is_set():
                if self._prewarm_error is not None:
                    break
                if deadline is not None and time.monotonic() > deadline:
                    raise TimeoutError(
                        f"prewarm not finished after {timeout}s")
                self._prewarmed.wait(timeout=0.05)
        if self._prewarm_error is not None:
            raise RuntimeError("prewarm failed") from self._prewarm_error
        return {"prewarmed": self._prewarmed.is_set(),
                "mode": self.prewarm_mode,
                "aot_buckets": int(
                    self.session.stats.get("aot_buckets", 0)),
                "compile_cache": compile_cache.cache_stats()}

    def submit_update(self, points_xyz=None, *, inserts=None, deletes=None,
                      deltas=None, epoch: int | None = None,
                      timeout: float | None = None,
                      trace_id: str | None = None,
                      parent_span: str | None = None) -> _UpdateOp:
        """Enqueue a dataset update WITHOUT waiting for it to apply.

        The op is a FIFO barrier in the admission queue: every request
        admitted before it is served against the old dataset, every request
        after against the new one.  This non-blocking half is the cluster
        hook — a coordinator broadcasts one epoch-tagged op per host and
        only then waits, so hosts apply the update concurrently while their
        per-host FIFO order against queries is already pinned.  ``timeout``
        bounds admission only (a full queue exerting backpressure raises
        :class:`~repro.serving.queue.AdmissionQueueFull` at the bound).
        Returns the op handle for :meth:`wait_update`.
        """
        self._raise_worker_error()
        if deltas is not None:
            inserts, deletes = deltas
        if trace_id is None and self.tracer is not None:
            # standalone traced server: sample an update root locally (a
            # fleet host's rate-0 tracer declines here, keeping sampling
            # at the coordinator — the propagated trace_id branch above)
            trace_id = self.tracer.new_trace()
        op = _UpdateOp(points_xyz=points_xyz, inserts=inserts,
                       deletes=deletes, epoch=epoch, trace_id=trace_id,
                       parent_span=parent_span, t_enqueue=self.clock())
        self.queue.put(op, timeout=timeout)
        return op

    def wait_update(self, op: _UpdateOp,
                    timeout: float | None = None) -> None:
        """Block until a :meth:`submit_update` op is applied; raises the
        op's error (poisoned update) or TimeoutError (op withdrawn)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        # poll in short slices so a worker that dies AFTER admission (its
        # crash handler resolves queued ops, but belt-and-braces) can never
        # strand this wait
        while not op.applied.wait(timeout=0.05):
            self._raise_worker_error()
            if deadline is not None and time.monotonic() > deadline:
                # withdraw the op (best effort: the worker skips cancelled
                # barriers it has not started) so a timed-out update cannot
                # silently apply later and double-apply on the caller's retry
                op.cancelled = True
                raise TimeoutError(
                    f"dataset update not applied after {timeout}s "
                    f"(op withdrawn; safe to retry)")
        if op.error is not None:
            raise op.error
        if op.skipped:
            # applied-event set by the SKIP path of a withdrawn op: a retry
            # of this wait must not read as success — nothing was applied.
            # (cancelled-but-applied-anyway — the worker was already mid-
            # apply when the caller withdrew — correctly reads as success)
            raise TimeoutError(
                "dataset update was withdrawn after an earlier timeout; "
                "it never applied")

    def shard_knn(self, queries_xy, *, timeout: float | None = None):
        """Stage-1-only pass over THIS server's dataset: returns
        ``(d2 (n, k), z (n, k), overflow (n,), epoch)`` — this shard's
        top-k heap of squared distances AND neighbour values.  The fleet's
        data-partitioned query path fans this out to every shard host and
        k-way merges (d2, z) client-side; in local Stage-2 mode the merged
        heap alone finishes the query (no partial-sum phase).
        FIFO-serialized with dataset updates through the admission queue
        (the returned epoch is the witness)."""
        return self._run_shard_op(_ShardOp(
            kind="knn", queries=validate_queries(queries_xy)), timeout)

    def shard_partial(self, queries_xy, alpha, *,
                      timeout: float | None = None):
        """Stage-2 partial sums over THIS server's dataset at a
        caller-supplied per-query ``alpha``: returns
        ``(sum_wz (n,), sum_w (n,), epoch)``."""
        q = validate_queries(queries_xy)
        a = np.asarray(alpha)
        if a.shape != (q.shape[0],):
            raise ValueError(f"alpha must be shape ({q.shape[0]},), "
                             f"got {a.shape}")
        return self._run_shard_op(
            _ShardOp(kind="partial", queries=q, alpha=a), timeout)

    def _run_shard_op(self, op: _ShardOp, timeout: float | None):
        self._raise_worker_error()
        deadline = None if timeout is None else time.monotonic() + timeout
        self.queue.put(op, timeout=timeout)
        # short-slice poll like wait_update: a worker that dies mid-op must
        # surface, never strand the fleet coordinator
        while not op.applied.wait(timeout=0.05):
            self._raise_worker_error()
            if deadline is not None and time.monotonic() > deadline:
                # withdraw (best effort): the fleet retries the whole
                # batch, so an orphaned op still in the FIFO must not burn
                # a full kNN/partial pass for a result nobody reads
                op.cancelled = True
                raise TimeoutError(
                    f"shard {op.kind} not executed after {timeout}s "
                    f"(op withdrawn)")
        if op.error is not None:
            raise op.error
        return op.result + (op.epoch,)

    def submit_compaction(self, *, epoch: int | None = None,
                          timeout: float | None = None,
                          trace_id: str | None = None,
                          parent_span: str | None = None) -> _UpdateOp:
        """Enqueue a background COMPACTION epoch without waiting (the LSM
        hot-ring fold — ``repro.core.session.InterpolationSession.compact``).
        A FIFO barrier like any update: queries admitted after it observe
        the compacted (bitwise-fresh) tables.  Returns the op handle for
        :meth:`wait_update`."""
        self._raise_worker_error()
        if trace_id is None and self.tracer is not None:
            trace_id = self.tracer.new_trace()   # standalone sampling, as
        op = _UpdateOp(compact=True, epoch=epoch,  # in submit_update
                       trace_id=trace_id, parent_span=parent_span,
                       t_enqueue=self.clock())
        self.queue.put(op, timeout=timeout)
        return op

    def compact(self, *, epoch: int | None = None,
                timeout: float | None = None) -> None:
        """Fold the session's hot rings through the admission queue and
        block until applied (no-op on layouts without an LSM tier)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        op = self.submit_compaction(epoch=epoch, timeout=timeout)
        self.wait_update(
            op, timeout=None if deadline is None
            else max(deadline - time.monotonic(), 0.0))

    def update_dataset(self, points_xyz=None, *, inserts=None, deletes=None,
                       deltas=None, epoch: int | None = None,
                       timeout: float | None = None) -> None:
        """Refresh the served dataset THROUGH the admission queue.

        Blocks until the worker applied the update (it never races a query
        batch — both run on the worker thread).  ``timeout`` bounds the
        whole call: admission past it raises
        :class:`~repro.serving.queue.AdmissionQueueFull`, application past
        it raises TimeoutError.
        """
        # the timeout bounds the WHOLE call: admission plus the applied-wait,
        # which reuses the same deadline
        deadline = None if timeout is None else time.monotonic() + timeout
        op = self.submit_update(points_xyz, inserts=inserts, deletes=deletes,
                                deltas=deltas, epoch=epoch, timeout=timeout)
        self.wait_update(
            op, timeout=None if deadline is None
            else max(deadline - time.monotonic(), 0.0))

    @property
    def alive(self) -> bool:
        """Worker-thread health (cluster liveness probes read this: a host
        whose admission queue still answers but whose worker died must
        probe as DEAD, not idle)."""
        return self._worker.is_alive() and self._worker_error is None

    def report(self) -> dict:
        """Telemetry snapshot + queue/session counters (JSON-serializable).

        ``merge`` carries the full REGISTRY state (counters, gauges with
        merge modes, full histogram bins — a superset of the old telemetry
        state) so a cluster coordinator can aggregate fleet percentiles
        exactly (:func:`repro.serving.cluster.telemetry.merge_reports`);
        ``stages`` is the human-facing registry snapshot — per-stage walls
        (``session/stage1_s`` .. ``serving/scatter_s``) alongside the
        request-level latency histograms.
        """
        rep = self.telemetry.report()
        rep["epoch"] = self.epoch
        rep["admission"] = dict(self.queue.counters)
        rep["queue_depth"] = len(self.queue)
        rep["session"] = {k: v for k, v in self.session.stats.items()
                          if isinstance(v, (int, float))}
        rep["merge"] = self.telemetry.state()
        rep["stages"] = self.registry.snapshot()
        rep["registry"] = self.registry.state()
        rep["compile"] = self._compile_report()
        rep["slo"] = self._slo_eval()
        if self.recorder is not None:
            rep["recorder"] = self.recorder.snapshot()
        return rep

    def _compile_report(self) -> dict:
        """Cold-start observability block: prewarm state, live AOT bucket
        count, persistent-compilation-cache hit/miss totals (synced into
        the registry as additive counters first, so fleet merges stay
        correct), and any post-warmup hot-path compiles."""
        compile_cache.sync_registry(self.registry)
        return {
            "prewarm": self.prewarm_mode,
            "prewarmed": self._prewarmed.is_set(),
            "aot_buckets": int(self.session.stats.get("aot_buckets", 0)),
            "post_warmup_compiles":
                self.registry.counter("serving/post_warmup_compiles").value,
            "cache": compile_cache.cache_stats(),
        }

    def _slo_eval(self) -> dict:
        """Sample the current cumulative counters/gauges into the SLO
        monitor and evaluate burn rates (cold path: report()/debugz()
        pulls only)."""
        c = self.telemetry.counters
        anomalies = self.recorder.anomalies if self.recorder is not None \
            else {}
        counters = {"requests": c["completed"] + c["shed"],
                    "deadline_miss": anomalies.get("deadline_miss", 0),
                    "shed": c["shed"]}
        gauges = {"queue_depth_frac":
                  len(self.queue) / max(self._max_depth, 1),
                  # a compile reaching the hot path AFTER the ladder was
                  # prewarmed is an anomaly (target 1.0 in the monitor:
                  # any nonzero count breaches)
                  "post_warmup_compiles": float(
                      self.registry.counter(
                          "serving/post_warmup_compiles").value)}
        occ = self.session.stats.get("ring_occupancy")
        if occ is not None:
            gauges["ring_occupancy"] = float(occ)
        self.slo.sample(counters, gauges)
        return self.slo.evaluate()

    def debugz(self) -> dict:
        """One JSON-serializable diagnostics bundle for this server: queue
        and epoch position, session/ring state, full registry state, the
        SLO evaluation, and the flight recorder's retained anomaly traces.
        Non-draining — a debugz pull never changes what the next pull (or
        the running SLO windows) sees."""
        bundle = {
            "host_id": self.host_id,
            "alive": self.alive,
            "epoch": self.epoch,
            "queue_depth": len(self.queue),
            "admission": dict(self.queue.counters),
            "session": {k: v for k, v in self.session.stats.items()
                        if isinstance(v, (int, float))},
            "stages": self.registry.snapshot(),
            "registry": self.registry.state(),
            "compile": self._compile_report(),
            "slo": self._slo_eval(),
            "recorder": self.recorder.state()
            if self.recorder is not None else None,
        }
        return bundle

    # -- observability endpoints (served over rpc by the cluster host) -------

    def metrics_text(self, prefix: str = "aidw") -> str:
        """Prometheus text exposition of the engine's whole registry."""
        return self.registry.prometheus_text(prefix)

    def metrics_snapshot(self) -> dict:
        """JSON snapshot of the registry (scalars + histogram quantiles)."""
        return self.registry.snapshot()

    def spans(self, drain: bool = True) -> list[dict]:
        """Finished span dicts from the server's tracer ([] when tracing is
        off).  ``drain=True`` (default) empties the buffer, so a cluster
        coordinator polling per-host spans never double-collects."""
        if self.tracer is None:
            return []
        return self.tracer.drain() if drain else self.tracer.spans()

    def close(self, timeout: float | None = 30.0) -> None:
        """Stop admitting, let the worker drain, and join it.  Raises
        TimeoutError if the worker is still running after ``timeout``, and
        surfaces a worker crash — a silent return would leave requests
        unresolved behind the caller's back."""
        # stop a background prewarm first: it checks the flag between
        # bucket compiles, so the join below is bounded by one compile
        self._prewarm_stop.set()
        if self._prewarm_thread is not None:
            self._prewarm_thread.join(timeout=timeout)
        self.queue.close()
        self._worker.join(timeout=timeout)
        with self._cv:
            self._cv.notify_all()
        if self._worker.is_alive():
            raise TimeoutError(
                f"serving worker still draining after {timeout}s "
                f"(queue_depth={len(self.queue)})")
        self._raise_worker_error()

    def __enter__(self) -> "AsyncAidwServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- worker --------------------------------------------------------------

    def _raise_worker_error(self) -> None:
        if self._worker_error is not None:
            raise RuntimeError("serving worker died") from self._worker_error

    def _apply_update(self, op: _UpdateOp) -> None:
        if op.cancelled:                    # withdrawn by a timed-out caller
            op.skipped = True
            if op.epoch is not None:
                # an explicit-epoch (cluster) barrier that was withdrawn
                # leaves a GAP in this host's update order: remember it, so
                # later epochs fail loudly instead of silently serving a
                # dataset that is missing epoch k's delta
                self._epoch_gap = op.epoch
            op.applied.set()
            return
        try:
            if op.epoch is not None and self._epoch_gap is not None \
                    and op.points_xyz is None:
                # a delta cannot apply over a hole; a FULL update below re-
                # syncs the host and heals the gap
                raise RuntimeError(
                    f"host missed epoch {self._epoch_gap} (withdrawn after "
                    f"timeout); refusing delta epoch {op.epoch} — re-sync "
                    f"with a full dataset update first")
            if op.epoch is not None and op.epoch <= self.epoch:
                # an out-of-order cluster epoch reaching the worker means the
                # host-side EpochApplier was bypassed — refuse loudly rather
                # than silently diverging from the fleet's update order
                raise RuntimeError(
                    f"epoch {op.epoch} <= current {self.epoch}: updates "
                    f"must apply in increasing epoch order")
            t_apply = self.clock()
            if op.compact:
                self.session.compact()
            else:
                self.engine.update_dataset(op.points_xyz, inserts=op.inserts,
                                           deletes=op.deletes)
            self.epoch = op.epoch if op.epoch is not None else self.epoch + 1
            t_end = self.clock()
            # the FIFO-barrier hold, first-class: from the moment the op
            # entered the admission queue (every query admitted behind it
            # is pinned) to applied — NOT just the device fold wall the
            # session records as session/compact_s.  This is the number
            # that shows up as queue_wait in the victims' breakdowns; the
            # attribution report's stall block names it as the culprit.
            self.registry.observe(
                "session/compact_stall_s" if op.compact
                else "serving/epoch_barrier_s",
                t_end - (op.t_enqueue if op.t_enqueue is not None
                         else t_apply),
                exemplar=op.trace_id)
            if self.tracer is not None and op.trace_id is not None:
                # the session fences its own plan/compact internals, so the
                # wall here is honest device-inclusive apply time
                self.tracer.record(
                    "apply_epoch", t_apply, t_end,
                    trace_id=op.trace_id, parent_id=op.parent_span,
                    args={"epoch": self.epoch, "compact": op.compact})
            if op.points_xyz is not None:
                self._epoch_gap = None      # full refresh healed the hole
            if not op.compact and op.epoch is None \
                    and self.compact_highwater > 0 \
                    and self.session.stats.get("ring_occupancy", 0.0) \
                    >= self.compact_highwater:
                # standalone auto-epoch mode: self-enqueue the background
                # fold BEHIND whatever queries are already admitted (best
                # effort — a full queue skips; the next delta re-triggers)
                try:
                    self.queue.put(_UpdateOp(compact=True,
                                             t_enqueue=self.clock()),
                                   block=False)
                except AdmissionQueueFull:
                    pass
        except BaseException as e:          # surface to the waiting client
            op.error = e
        finally:
            op.applied.set()

    def _run_shard(self, op: _ShardOp) -> None:
        if op.cancelled:                # withdrawn by a timed-out caller
            op.applied.set()
            return
        try:
            if op.kind == "knn":
                d2, z, ovf = self.session.knn(op.queries)
                op.result = (np.asarray(d2), np.asarray(z), np.asarray(ovf))
            elif op.kind == "partial":
                swz, sw = self.session.partial_interpolate(op.queries,
                                                           op.alpha)
                op.result = (np.asarray(swz), np.asarray(sw))
            else:
                raise ValueError(f"unknown shard op kind {op.kind!r}")
            op.epoch = self.epoch
        except BaseException as e:          # surface to the waiting client
            op.error = e
        finally:
            op.applied.set()

    def _step(self, pending: deque) -> None:
        """One worker step over the front of ``pending``: apply an update
        barrier, run a shard op, or form + dispatch one coalesced batch
        (shared by the live loop and the drain-on-close loop)."""
        head = pending[0]
        if not hasattr(head, "queries_xy"):    # update barrier / shard op
            pending.popleft()
            if isinstance(head, _ShardOp):
                self._run_shard(head)
            else:
                self._apply_update(head)
            with self._cv:
                self._cv.notify_all()
            return
        group, shed = self.coalescer.next_batch(pending)
        for r in shed:
            self.telemetry.record_shed(r)
            if self.recorder is not None:
                self.recorder.observe_shed(r)
        if group:
            # stamp the dataset epoch the batch executes under: updates only
            # apply between batches on this same thread, so one stamp covers
            # the whole group (the cluster's consistency-contract witness)
            for r in group:
                r.epoch = self.epoch
            c0 = compile_cache.backend_compiles()
            if self.pipeline_depth:
                res, t0 = S.launch_batch(self.session, group,
                                         clock=self.clock)
                self._pipeline.append((group, res, t0))
                while len(self._pipeline) > self.pipeline_depth:
                    self._scatter_oldest()
                group = []                  # in flight: resolve at scatter
            else:
                S.dispatch_batch(self.session, group,
                                 estimator=self.estimator,
                                 telemetry=self.telemetry, clock=self.clock,
                                 tracer=self.tracer, recorder=self.recorder)
            self._note_hot_compiles(c0)
        if group or shed:
            with self._cv:
                self._inflight -= len(group) + len(shed)
                self._cv.notify_all()

    def _note_hot_compiles(self, c0: int) -> None:
        """Post-warmup hot-path compile detection: once the ladder is
        prewarmed, a dispatch that reaches the XLA compile layer is an
        anomaly — count it and retain a critical flight-recorder event.
        (Before/without prewarm, lazy compiles are expected and ignored.)"""
        if not self._prewarmed.is_set():
            return
        dc = compile_cache.backend_compiles() - c0
        if dc <= 0:
            return
        self.registry.inc("serving/post_warmup_compiles", dc)
        if self.recorder is not None:
            self.recorder.event("hot_path_compile", severity="critical",
                                data={"compiles": dc})

    def _scatter_oldest(self) -> None:
        group, res, t0 = self._pipeline.popleft()
        S.scatter_batch(group, res, t0, estimator=self.estimator,
                        telemetry=self.telemetry, clock=self.clock,
                        tracer=self.tracer, recorder=self.recorder)
        with self._cv:
            self._inflight -= len(group)
            self._cv.notify_all()

    def _drain_pipeline(self) -> None:
        while self._pipeline:
            self._scatter_oldest()

    def _work(self) -> None:
        """Worker loop: drain admissions, apply barriers, dispatch batches.

        ``pending`` is the worker-local FIFO; the admission queue is drained
        into it so batch formation never holds the queue lock.  When
        ``pending`` still has queries, the queue is only polled (non-
        blocking); when idle, the worker blocks on the queue.
        """
        pending: deque = deque()
        try:
            while True:
                if not pending:
                    # idle: materialize pipelined batches before blocking
                    # (flush waits on in-flight hitting zero)
                    self._drain_pipeline()
                    item = self.queue.get(timeout=0.1)
                    if item is None:
                        if self.queue.closed:
                            break
                        continue
                    pending.append(item)
                pending.extend(self.queue.drain())
                if self.linger_s and len(pending) >= 1 \
                        and hasattr(pending[0], "queries_xy"):
                    # optional linger: give near-simultaneous arrivals a
                    # window to coalesce; deadline pressure still closes
                    # early because next_batch re-reads the clock.  The
                    # window itself is bounded in REAL time — a test-injected
                    # frozen clock must not spin this loop forever
                    end = time.monotonic() + self.linger_s
                    while time.monotonic() < end:
                        more = self.queue.drain()
                        if more:
                            pending.extend(more)
                            break
                        time.sleep(min(self.linger_s / 10, 1e-3))
                self._step(pending)
            # drain-on-close: anything admitted before close() still resolves
            pending.extend(self.queue.drain())
            while pending:
                self._step(pending)
            self._drain_pipeline()
        except BaseException as e:
            self._worker_error = e
            # a dead worker must not strand anyone: wake blocked putters,
            # refuse new work, and resolve every queued update barrier so
            # update_dataset callers see the crash instead of hanging
            self.queue.close()
            pending.extend(self.queue.drain())
            for item in pending:
                if not hasattr(item, "queries_xy") \
                        and hasattr(item, "applied"):
                    item.error = item.error or e
                    item.applied.set()
            with self._cv:
                self._cv.notify_all()
